//! Client pool: device fleet with heterogeneous memory + data shards,
//! memory-aware selection (the paper's per-step eligibility filter).

use crate::data::{partition, ClientShard, Partition, SyntheticDataset};
use crate::fleet::{DeviceProfile, FleetProfileConfig};
use crate::manifest::MemCoeffs;
use crate::memory::{can_train, DeviceMemory, MemoryConfig};
use crate::rng::Rng;

/// One simulated device.
pub struct Client {
    /// Stable pool index (also the fleet-simulator client id).
    pub id: usize,
    /// Sampled memory budget + contention model.
    pub memory: DeviceMemory,
    /// Fleet-simulator characteristics: compute/link speeds, availability,
    /// dropout (see `fleet::profile`).
    pub profile: DeviceProfile,
    /// The client's local data shard.
    pub shard: ClientShard,
    /// Version of the frozen prefix this client has cached (comm
    /// accounting: the prefix is re-downloaded only when it changes).
    pub prefix_version: u64,
}

/// The device fleet: every simulated client plus the shared memory model.
pub struct ClientPool {
    /// All clients, indexed by [`Client::id`].
    pub clients: Vec<Client>,
    /// Fleet-wide memory substrate knobs (budgets, contention).
    pub mem_cfg: MemoryConfig,
    rng: Rng,
}

/// Outcome of one round's selection.
pub struct Selection {
    /// Clients that can train the target artifact this round.
    pub trainers: Vec<usize>,
    /// Sampled clients that could NOT fit it (they fall back to the
    /// output-layer artifact under ProFL; other methods drop them).
    pub fallback: Vec<usize>,
    /// Round availability snapshot (bytes) for the sampled set.
    pub availability: Vec<(usize, u64)>,
}

impl ClientPool {
    /// Build the fleet: partition the dataset into shards and sample each
    /// client's memory budget + device profile from seed-forked streams.
    pub fn build(
        num_clients: usize,
        total_samples: usize,
        dataset: &SyntheticDataset,
        scheme: Partition,
        mem_cfg: MemoryConfig,
        fleet: &FleetProfileConfig,
        seed: u64,
    ) -> Self {
        let mut rng = Rng::new(seed ^ 0x5e1e_c7ed);
        // Separate stream for device profiles: memory budgets stay
        // bit-identical to the pre-fleet seed for any given run seed.
        let mut prof_rng = Rng::new(seed ^ 0xf1ee_7000);
        let shards = partition(dataset, num_clients, total_samples, scheme, seed);
        let clients = shards
            .into_iter()
            .enumerate()
            .map(|(id, shard)| Client {
                id,
                memory: DeviceMemory::sample(&mem_cfg, &mut rng, id),
                profile: DeviceProfile::sample(fleet, &mut prof_rng, id),
                shard,
                prefix_version: u64::MAX,
            })
            .collect();
        ClientPool { clients, mem_cfg, rng: rng.fork(0x5e1) }
    }

    /// Number of clients in the fleet.
    pub fn len(&self) -> usize {
        self.clients.len()
    }

    /// Whether the fleet is empty.
    pub fn is_empty(&self) -> bool {
        self.clients.is_empty()
    }

    /// Total training samples across every client's shard.
    pub fn total_samples(&self) -> usize {
        self.clients.iter().map(|c| c.shard.num_samples()).sum()
    }

    /// Sample `per_round` clients uniformly, then split by whether each can
    /// fit `mem` under this round's contention — the paper's selection:
    /// "select the client set S from the pool of clients who can afford
    /// training for the current block".
    pub fn select(&mut self, per_round: usize, mem: &MemCoeffs) -> Selection {
        self.select_excluding(per_round, mem, &[])
    }

    /// [`Self::select`] over the pool minus `busy` (clients with an
    /// upload still in flight under the async round policy — re-sampling
    /// one would supersede work the server already paid for). An empty
    /// `busy` takes exactly the plain-sample path, so the rng stream is
    /// bit-identical to [`Self::select`] — the sync/degenerate-async
    /// reproducibility guarantees rest on this.
    pub fn select_excluding(
        &mut self,
        per_round: usize,
        mem: &MemCoeffs,
        busy: &[usize],
    ) -> Selection {
        let ids = if busy.is_empty() {
            self.rng.sample_indices(self.clients.len(), per_round.min(self.clients.len()))
        } else {
            let eligible: Vec<usize> =
                (0..self.clients.len()).filter(|id| !busy.contains(id)).collect();
            let k = per_round.min(eligible.len());
            self.rng.sample_indices(eligible.len(), k).into_iter().map(|i| eligible[i]).collect()
        };
        let mut sel =
            Selection { trainers: Vec::new(), fallback: Vec::new(), availability: Vec::new() };
        for id in ids {
            let avail = self.clients[id].memory.available(&self.mem_cfg);
            sel.availability.push((id, avail));
            if can_train(avail, &self.mem_cfg, mem) {
                sel.trainers.push(id);
            } else {
                sel.fallback.push(id);
            }
        }
        sel
    }

    /// Fraction of the whole fleet that could train `mem` at static budget
    /// (the PR column of Tables 1/2).
    pub fn participation_rate(&self, mem: &MemCoeffs) -> f64 {
        let n = self
            .clients
            .iter()
            .filter(|c| c.memory.fits_static(&self.mem_cfg, mem))
            .count();
        n as f64 / self.clients.len() as f64
    }

    /// Largest option (by index into `options`, assumed sorted ascending by
    /// memory need) each client can statically afford — HeteroFL's
    /// complexity assignment and AllSmall's global-model pick.
    pub fn capability_assignment(&self, options: &[MemCoeffs]) -> Vec<Option<usize>> {
        self.clients
            .iter()
            .map(|c| {
                let mut best = None;
                for (i, m) in options.iter().enumerate() {
                    if c.memory.fits_static(&self.mem_cfg, m) {
                        best = Some(i);
                    }
                }
                best
            })
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::memory::MB;

    fn pool(seed: u64) -> ClientPool {
        pool_with(seed, "uniform")
    }

    fn pool_with(seed: u64, profile: &str) -> ClientPool {
        let data = SyntheticDataset::new(10, seed);
        let fleet = FleetProfileConfig::named(profile).unwrap();
        ClientPool::build(50, 5_000, &data, Partition::Iid, MemoryConfig::default(), &fleet, seed)
    }

    fn coeffs(total_mb: u64) -> MemCoeffs {
        MemCoeffs { fixed_bytes: total_mb * MB, per_sample_bytes: 0, params_total: 0, params_trainable: 0 }
    }

    #[test]
    fn pool_construction() {
        let p = pool(1);
        assert_eq!(p.len(), 50);
        assert!(p.total_samples() > 2_000);
    }

    #[test]
    fn selection_splits_by_memory() {
        let mut p = pool(2);
        let sel = p.select(20, &coeffs(500));
        assert_eq!(sel.trainers.len() + sel.fallback.len(), 20);
        assert!(!sel.trainers.is_empty());
        assert!(!sel.fallback.is_empty());
        // tiny artifact: everyone trains
        let sel2 = p.select(20, &coeffs(10));
        assert!(sel2.fallback.is_empty());
    }

    #[test]
    fn participation_rate_monotone_in_memory() {
        let p = pool(3);
        let pr_small = p.participation_rate(&coeffs(50));
        let pr_mid = p.participation_rate(&coeffs(500));
        let pr_big = p.participation_rate(&coeffs(950));
        assert!(pr_small >= pr_mid && pr_mid >= pr_big);
        assert_eq!(pr_small, 1.0);
        assert_eq!(pr_big, 0.0);
    }

    #[test]
    fn capability_assignment_orders() {
        let p = pool(4);
        let opts = vec![coeffs(80), coeffs(300), coeffs(700)];
        let assign = p.capability_assignment(&opts);
        for (c, a) in p.clients.iter().zip(&assign) {
            match a {
                Some(i) => assert!(c.memory.budget >= opts[*i].fixed_bytes),
                None => assert!(c.memory.budget < 80 * MB),
            }
        }
        // heterogeneity: at least two distinct tiers present
        let mut tiers: Vec<_> = assign.iter().flatten().collect();
        tiers.sort();
        tiers.dedup();
        assert!(tiers.len() >= 2);
    }

    #[test]
    fn device_profiles_deterministic_and_heterogeneous() {
        let a = pool_with(6, "mobile");
        let b = pool_with(6, "mobile");
        for (ca, cb) in a.clients.iter().zip(&b.clients) {
            assert_eq!(ca.profile, cb.profile, "client {}", ca.id);
        }
        // The mobile fleet must actually mix device tiers.
        let mut tiers: Vec<String> = a.clients.iter().map(|c| format!("{:?}", c.profile.tier)).collect();
        tiers.sort();
        tiers.dedup();
        assert!(tiers.len() >= 2, "expected tier diversity, got {tiers:?}");
    }

    #[test]
    fn selection_deterministic_per_seed() {
        let mut a = pool(5);
        let mut b = pool(5);
        let s1 = a.select(10, &coeffs(400));
        let s2 = b.select(10, &coeffs(400));
        assert_eq!(s1.trainers, s2.trainers);
        assert_eq!(s1.fallback, s2.fallback);
    }

    #[test]
    fn busy_clients_are_never_resampled() {
        // A client with an upload in flight must not re-enter the cohort
        // (a re-dispatch would supersede — discard — its pending work).
        let mut p = pool(6);
        let busy: Vec<usize> = (0..10).collect();
        for round in 0..20 {
            let sel = p.select_excluding(20, &coeffs(400), &busy);
            let sampled: Vec<usize> =
                sel.availability.iter().map(|&(id, _)| id).collect();
            assert_eq!(sampled.len(), 20, "cohort still fills from the rest");
            for id in &sampled {
                assert!(!busy.contains(id), "round {round}: busy client {id} re-sampled");
            }
        }
        // Excluding everyone leaves an empty (but valid) selection.
        let all: Vec<usize> = (0..p.len()).collect();
        let sel = p.select_excluding(20, &coeffs(400), &all);
        assert!(sel.availability.is_empty());
    }

    #[test]
    fn empty_busy_set_matches_plain_select_bit_for_bit() {
        // The degeneracy guarantees need select_excluding(∅) to consume
        // the rng stream exactly like select.
        let mut a = pool(7);
        let mut b = pool(7);
        for _ in 0..5 {
            let s1 = a.select(12, &coeffs(400));
            let s2 = b.select_excluding(12, &coeffs(400), &[]);
            assert_eq!(s1.trainers, s2.trainers);
            assert_eq!(s1.fallback, s2.fallback);
            assert_eq!(s1.availability, s2.availability);
        }
    }
}
