//! Client pool: device fleet with heterogeneous memory + data shards,
//! memory-aware selection (the paper's per-step eligibility filter).
//!
//! # Eager vs lazy fleets
//!
//! [`ClientPool::build`] materializes every client up front — O(fleet)
//! memory, exactly the historical behaviour. [`ClientPool::build_lazy`]
//! materializes clients *on demand* behind a small resident cache, so a
//! million-device fleet costs O(materialized) memory while every
//! observable stream (memory budgets, contention draws, device profiles,
//! shard labels/indices, selection order) stays **bit-identical** to the
//! eager build (property-tested). Three structural facts make this
//! possible:
//!
//! 1. the memory-budget rng consumes exactly one draw per client, and
//!    SplitMix64's state moves by a constant stride per draw — so client
//!    `i`'s budget stream is reachable by an O(1) state jump;
//! 2. the profile rng never advances ([`DeviceProfile::sample`] only
//!    *forks* it), so any client's profile is a pure function of the
//!    initial state;
//! 3. shard bounds come from a `ShardPlan` — sparse rng-state
//!    checkpoints over the partition stream (see `data::partition`).
//!
//! Mutable per-client state (the contention rng, the shard's batch
//! cursor, the cached prefix version) survives cache eviction in a
//! compact residue map, so re-materialization resumes every stream
//! exactly where it left off.
//!
//! Selection is O(cohort + excluded) for both storage modes: the cohort
//! is drawn by a sparse partial Fisher-Yates (`Rng::sample_indices`) and
//! in-flight exclusions are handled by rank-mapping into the eligible
//! id space instead of collecting a fleet-sized eligibility vector.

use crate::data::partition::ShardPlan;
use crate::data::{partition, ClientShard, Partition, SyntheticDataset};
use crate::fleet::{DeviceProfile, FleetProfileConfig};
use crate::manifest::MemCoeffs;
use crate::memory::{can_train, DeviceMemory, MemoryConfig};
use crate::rng::Rng;
use anyhow::{bail, ensure, Result};
use std::collections::HashMap;

/// One simulated device.
pub struct Client {
    /// Stable pool index (also the fleet-simulator client id).
    pub id: usize,
    /// Sampled memory budget + contention model.
    pub memory: DeviceMemory,
    /// Fleet-simulator characteristics: compute/link speeds, availability,
    /// dropout (see `fleet::profile`).
    pub profile: DeviceProfile,
    /// The client's local data shard.
    pub shard: ClientShard,
    /// Version of the frozen prefix this client has cached (comm
    /// accounting: the prefix is re-downloaded only when it changes).
    pub prefix_version: u64,
}

/// Mutable state preserved across lazy-cache eviction: everything about a
/// client that is NOT a pure function of `(seed, id)`. Re-materialization
/// restores these, so eviction is invisible to any seeded run.
struct Residue {
    /// Contention stream position (the budget itself is pure, but the
    /// per-round `available()` draws advance a private rng).
    memory: DeviceMemory,
    /// Shard batch-cycling cursor.
    cursor: usize,
    /// Cached frozen-prefix version (comm accounting).
    prefix_version: u64,
}

/// A materialized client plus its LRU tick.
struct Resident {
    client: Client,
    tick: u64,
}

/// On-demand client storage: pure `(seed, id)` recipes plus a bounded
/// resident cache and the eviction residues (see module docs).
struct LazyFleet {
    num_clients: usize,
    fleet: FleetProfileConfig,
    /// Memory-budget rng state before client 0's draw (one draw/client).
    mem_state0: u64,
    /// Profile rng state (never advances — `sample` only forks it).
    prof_state: u64,
    /// Lazy partition: shard bounds + label-stream checkpoints.
    plan: ShardPlan,
    /// Resident-cache capacity (clients, not bytes).
    cap: usize,
    /// Monotone access counter for LRU eviction.
    tick: u64,
    resident: HashMap<usize, Resident>,
    evicted: HashMap<usize, Residue>,
    peak_resident: usize,
    /// Cache telemetry (pure observation — never read by the pool):
    /// touches served by a resident client.
    hits: u64,
    /// Touches that had to (re)materialize the client.
    misses: u64,
    /// Residents displaced to the residue map.
    evictions: u64,
}

impl LazyFleet {
    /// Rebuild client `id` from its pure recipes, restoring any residue.
    fn rebuild(&mut self, id: usize, mem_cfg: &MemoryConfig) -> Client {
        assert!(id < self.num_clients, "client {id} out of range ({})", self.num_clients);
        let mut mem_rng = Rng::from_state(self.mem_state0);
        mem_rng.skip(id as u64);
        let mut memory = DeviceMemory::sample(mem_cfg, &mut mem_rng, id);
        let mut prof_rng = Rng::from_state(self.prof_state);
        let profile = DeviceProfile::sample(&self.fleet, &mut prof_rng, id);
        let mut shard = self.plan.shard(id);
        let mut prefix_version = u64::MAX;
        if let Some(res) = self.evicted.remove(&id) {
            memory = res.memory;
            shard.set_cursor(res.cursor);
            prefix_version = res.prefix_version;
        }
        Client { id, memory, profile, shard, prefix_version }
    }

    /// Ensure client `id` is resident, evicting the least-recently-used
    /// client (ties broken by smallest id — deterministic) when at
    /// capacity. Bumps the LRU tick either way.
    fn touch(&mut self, id: usize, mem_cfg: &MemoryConfig) {
        self.tick += 1;
        let tick = self.tick;
        if let Some(r) = self.resident.get_mut(&id) {
            r.tick = tick;
            self.hits += 1;
            return;
        }
        self.misses += 1;
        while self.resident.len() >= self.cap.max(1) {
            self.evict_lru();
        }
        let client = self.rebuild(id, mem_cfg);
        self.resident.insert(id, Resident { client, tick });
        self.peak_resident = self.peak_resident.max(self.resident.len());
    }

    /// Evict the least-recently-used resident, snapshotting its mutable
    /// state into the residue map.
    fn evict_lru(&mut self) {
        // Ticks are unique, so the minimum is unique — HashMap iteration
        // order cannot influence the choice.
        let Some(id) = self.resident.iter().min_by_key(|(id, r)| (r.tick, **id)).map(|(id, _)| *id)
        else {
            return;
        };
        let r = self.resident.remove(&id).expect("resident just found");
        self.evictions += 1;
        self.evicted.insert(
            id,
            Residue {
                cursor: r.client.shard.cursor(),
                prefix_version: r.client.prefix_version,
                memory: r.client.memory,
            },
        );
    }

    /// Client `id`'s static memory budget without materializing it (the
    /// budget is a pure O(1) function of `(seed, id)`).
    fn budget(&self, id: usize, mem_cfg: &MemoryConfig) -> u64 {
        let mut mem_rng = Rng::from_state(self.mem_state0);
        mem_rng.skip(id as u64);
        DeviceMemory::sample(mem_cfg, &mut mem_rng, id).budget
    }
}

/// Client storage behind [`ClientPool`]: everything up front, or
/// recipes + a resident cache.
enum Storage {
    Eager(Vec<Client>),
    Lazy(Box<LazyFleet>),
}

/// The device fleet: every simulated client plus the shared memory model.
pub struct ClientPool {
    /// Fleet-wide memory substrate knobs (budgets, contention).
    pub mem_cfg: MemoryConfig,
    storage: Storage,
    rng: Rng,
}

/// Point-in-time pool cache statistics for the telemetry stream (see
/// [`ClientPool::stats`]). For eager pools the cache counters are zero
/// and `materialized == peak_materialized == fleet size`.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub struct PoolStats {
    /// Lazy-cache touches served by an already-resident client.
    pub hits: u64,
    /// Lazy-cache touches that (re)materialized the client.
    pub misses: u64,
    /// Residents displaced to the residue map by the LRU policy.
    pub evictions: u64,
    /// Clients materialized right now.
    pub materialized: usize,
    /// High-water mark of simultaneously materialized clients.
    pub peak_materialized: usize,
}

/// One client's checkpointed mutable residue: everything about the
/// client that is NOT a pure function of `(seed, id)` — the contention
/// rng position, the shard batch cursor, and the cached prefix version.
/// The budget/profile/shard contents are re-derived from the build seed
/// on import (see `docs/CHECKPOINT.md`).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct ClientCkpt {
    /// Stable pool index.
    pub id: usize,
    /// Contention rng stream state ([`Rng::state`]).
    pub mem_rng: u64,
    /// Shard batch-cycling cursor.
    pub cursor: usize,
    /// Cached frozen-prefix version (`u64::MAX` = never downloaded).
    pub prefix_version: u64,
}

/// A lazy pool's checkpointed cache state: residues for both resident and
/// evicted clients, the LRU clock, and the cache telemetry counters.
#[derive(Debug, Clone, PartialEq, Eq, Default)]
pub struct LazyCkpt {
    /// Monotone LRU access counter.
    pub tick: u64,
    /// High-water mark of simultaneously materialized clients.
    pub peak_resident: usize,
    /// Touches served by a resident client.
    pub hits: u64,
    /// Touches that had to (re)materialize the client.
    pub misses: u64,
    /// Residents displaced to the residue map.
    pub evictions: u64,
    /// Resident clients (sorted by id) with their LRU ticks.
    pub resident: Vec<(ClientCkpt, u64)>,
    /// Evicted residues (sorted by id).
    pub evicted: Vec<ClientCkpt>,
}

/// Which storage mode a [`PoolCkptState`] snapshotted, plus its per-client
/// residues. Import rejects a kind that disagrees with the pool being
/// restored into — the storage mode is part of the resolved config.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum PoolCkptKind {
    /// Eager pool: one residue per client, in id order.
    Eager(Vec<ClientCkpt>),
    /// Lazy pool: cache state + residues for touched clients only.
    Lazy(LazyCkpt),
}

/// A [`ClientPool`]'s complete checkpoint image. Everything else about
/// the pool (budgets, profiles, shard bounds) is a pure function of the
/// run config and is rebuilt by the normal construction path on resume;
/// [`ClientPool::import_state`] then repositions the mutable streams.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct PoolCkptState {
    /// Selection rng stream state ([`Rng::state`]).
    pub select_rng: u64,
    /// Storage-mode-specific residues.
    pub kind: PoolCkptKind,
}

/// Outcome of one round's selection.
pub struct Selection {
    /// Clients that can train the target artifact this round.
    pub trainers: Vec<usize>,
    /// Sampled clients that could NOT fit it (they fall back to the
    /// output-layer artifact under ProFL; other methods drop them).
    pub fallback: Vec<usize>,
    /// Round availability snapshot (bytes) for the sampled set.
    pub availability: Vec<(usize, u64)>,
}

/// Map an eligible-space rank to a client id given the sorted, deduped
/// `excluded` ids: the `rank`-th smallest id not in `excluded`. Each
/// excluded id ≤ the running candidate shifts it up by one; the walk
/// stops at the first excluded id beyond it.
fn rank_to_id(rank: usize, excluded: &[usize]) -> usize {
    let mut id = rank;
    for &b in excluded {
        if b <= id {
            id += 1;
        } else {
            break;
        }
    }
    id
}

impl ClientPool {
    /// Build the fleet eagerly: partition the dataset into shards and
    /// sample each client's memory budget + device profile from
    /// seed-forked streams. O(fleet) memory — for million-device fleets
    /// use [`Self::build_lazy`], which is bit-identical.
    pub fn build(
        num_clients: usize,
        total_samples: usize,
        dataset: &SyntheticDataset,
        scheme: Partition,
        mem_cfg: MemoryConfig,
        fleet: &FleetProfileConfig,
        seed: u64,
    ) -> Self {
        let mut rng = Rng::new(seed ^ 0x5e1e_c7ed);
        // Separate stream for device profiles: memory budgets stay
        // bit-identical to the pre-fleet seed for any given run seed.
        let mut prof_rng = Rng::new(seed ^ 0xf1ee_7000);
        let shards = partition(dataset, num_clients, total_samples, scheme, seed);
        let clients = shards
            .into_iter()
            .enumerate()
            .map(|(id, shard)| Client {
                id,
                memory: DeviceMemory::sample(&mem_cfg, &mut rng, id),
                profile: DeviceProfile::sample(fleet, &mut prof_rng, id),
                shard,
                prefix_version: u64::MAX,
            })
            .collect();
        ClientPool { storage: Storage::Eager(clients), mem_cfg, rng: rng.fork(0x5e1) }
    }

    /// Build the fleet lazily: clients materialize on first touch behind
    /// a `resident_cap`-client cache, with every rng stream bit-identical
    /// to [`Self::build`] (see module docs for why that holds). Build
    /// cost is one streaming pass over the partition stream — O(fleet)
    /// time, O(fleet / checkpoint-stride) memory — and each round
    /// afterwards costs O(cohort), independent of fleet size.
    ///
    /// `resident_cap` should comfortably exceed the per-round cohort
    /// (evicting a client mid-round is correct but wasteful).
    #[allow(clippy::too_many_arguments)]
    pub fn build_lazy(
        num_clients: usize,
        total_samples: usize,
        dataset: &SyntheticDataset,
        scheme: Partition,
        mem_cfg: MemoryConfig,
        fleet: &FleetProfileConfig,
        seed: u64,
        resident_cap: usize,
    ) -> Self {
        let mem_state0 = Rng::new(seed ^ 0x5e1e_c7ed).state();
        let prof_state = Rng::new(seed ^ 0xf1ee_7000).state();
        let plan = ShardPlan::build(dataset.num_classes, num_clients, total_samples, scheme, seed);
        // The selection stream forks off the memory rng *after* its
        // per-client draws — jump there without making them.
        let mut post_mem = Rng::from_state(mem_state0);
        post_mem.skip(num_clients as u64);
        let rng = post_mem.fork(0x5e1);
        let lazy = LazyFleet {
            num_clients,
            fleet: fleet.clone(),
            mem_state0,
            prof_state,
            plan,
            cap: resident_cap,
            tick: 0,
            resident: HashMap::new(),
            evicted: HashMap::new(),
            peak_resident: 0,
            hits: 0,
            misses: 0,
            evictions: 0,
        };
        ClientPool { storage: Storage::Lazy(Box::new(lazy)), mem_cfg, rng }
    }

    /// Number of clients in the fleet.
    pub fn len(&self) -> usize {
        match &self.storage {
            Storage::Eager(v) => v.len(),
            Storage::Lazy(l) => l.num_clients,
        }
    }

    /// Whether the fleet is empty.
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Total training samples across every client's shard. (Lazy fleets
    /// answer from the partition plan without materializing anyone.)
    pub fn total_samples(&self) -> usize {
        match &self.storage {
            Storage::Eager(v) => v.iter().map(|c| c.shard.num_samples()).sum(),
            Storage::Lazy(l) => l.plan.total_samples(),
        }
    }

    /// Shared read access to client `id`. Eager fleets serve any id; lazy
    /// fleets serve *resident* clients only (ids flow through
    /// [`Self::select_excluding`] / [`Self::client_mut`] first on every
    /// coordinator path, which materializes them).
    ///
    /// # Panics
    ///
    /// On a lazy fleet, if `id` is not resident.
    pub fn client(&self, id: usize) -> &Client {
        match &self.storage {
            Storage::Eager(v) => &v[id],
            Storage::Lazy(l) => {
                &l.resident
                    .get(&id)
                    .unwrap_or_else(|| {
                        panic!("lazy client {id} not resident; materialize via client_mut/select")
                    })
                    .client
            }
        }
    }

    /// Mutable access to client `id`, materializing it on a lazy fleet
    /// (and bumping its LRU tick).
    pub fn client_mut(&mut self, id: usize) -> &mut Client {
        let mem_cfg = self.mem_cfg;
        match &mut self.storage {
            Storage::Eager(v) => &mut v[id],
            Storage::Lazy(l) => {
                l.touch(id, &mem_cfg);
                &mut l.resident.get_mut(&id).expect("just touched").client
            }
        }
    }

    /// Clients currently materialized (= fleet size for eager pools).
    pub fn materialized(&self) -> usize {
        match &self.storage {
            Storage::Eager(v) => v.len(),
            Storage::Lazy(l) => l.resident.len(),
        }
    }

    /// High-water mark of simultaneously materialized clients (= fleet
    /// size for eager pools). The lazy pool's memory-wall witness: at
    /// 1e6 clients / cohort 50 this stays at the resident cap.
    pub fn peak_materialized(&self) -> usize {
        match &self.storage {
            Storage::Eager(v) => v.len(),
            Storage::Lazy(l) => l.peak_resident,
        }
    }

    /// Cumulative cache statistics for the telemetry stream. Pure
    /// observation: reading them never touches the cache, the LRU clock,
    /// or any rng stream.
    pub fn stats(&self) -> PoolStats {
        match &self.storage {
            Storage::Eager(v) => PoolStats {
                hits: 0,
                misses: 0,
                evictions: 0,
                materialized: v.len(),
                peak_materialized: v.len(),
            },
            Storage::Lazy(l) => PoolStats {
                hits: l.hits,
                misses: l.misses,
                evictions: l.evictions,
                materialized: l.resident.len(),
                peak_materialized: l.peak_resident,
            },
        }
    }

    /// Sample `per_round` clients uniformly, then split by whether each can
    /// fit `mem` under this round's contention — the paper's selection:
    /// "select the client set S from the pool of clients who can afford
    /// training for the current block".
    pub fn select(&mut self, per_round: usize, mem: &MemCoeffs) -> Selection {
        self.select_excluding(per_round, mem, &[])
    }

    /// [`Self::select`] over the pool minus `busy` (clients with an
    /// upload still in flight under the async round policy — re-sampling
    /// one would supersede work the server already paid for). An empty
    /// `busy` takes exactly the plain-sample path, so the rng stream is
    /// bit-identical to [`Self::select`] — the sync/degenerate-async
    /// reproducibility guarantees rest on this.
    ///
    /// Cost is O(cohort + excluded), independent of fleet size: the draw
    /// is a sparse partial Fisher-Yates over the eligible count, and each
    /// drawn rank maps to its client id through the sorted exclusion list
    /// (rank-to-id walk) instead of a fleet-sized eligibility vector. Both
    /// the draws and the resulting ids are bit-identical to the
    /// historical collect-then-index implementation.
    pub fn select_excluding(
        &mut self,
        per_round: usize,
        mem: &MemCoeffs,
        busy: &[usize],
    ) -> Selection {
        let n = self.len();
        let ids: Vec<usize> = if busy.is_empty() {
            self.rng.sample_indices(n, per_round.min(n))
        } else {
            let mut excl: Vec<usize> = busy.iter().copied().filter(|&b| b < n).collect();
            excl.sort_unstable();
            excl.dedup();
            let eligible = n - excl.len();
            let k = per_round.min(eligible);
            self.rng
                .sample_indices(eligible, k)
                .into_iter()
                .map(|rank| rank_to_id(rank, &excl))
                .collect()
        };
        let mem_cfg = self.mem_cfg;
        let mut sel =
            Selection { trainers: Vec::new(), fallback: Vec::new(), availability: Vec::new() };
        for id in ids {
            let avail = self.client_mut(id).memory.available(&mem_cfg);
            sel.availability.push((id, avail));
            if can_train(avail, &mem_cfg, mem) {
                sel.trainers.push(id);
            } else {
                sel.fallback.push(id);
            }
        }
        sel
    }

    /// Fraction of the whole fleet that could train `mem` at static budget
    /// (the PR column of Tables 1/2). O(fleet) time by definition, but
    /// lazy fleets answer from the pure budget recipe — O(1) memory, no
    /// materialization.
    pub fn participation_rate(&self, mem: &MemCoeffs) -> f64 {
        let need = mem.bytes_at(self.mem_cfg.accounting_batch);
        let n = match &self.storage {
            Storage::Eager(v) => {
                v.iter().filter(|c| c.memory.fits_static(&self.mem_cfg, mem)).count()
            }
            Storage::Lazy(l) => {
                (0..l.num_clients).filter(|&id| need <= l.budget(id, &self.mem_cfg)).count()
            }
        };
        n as f64 / self.len() as f64
    }

    /// Largest option (by index into `options`, assumed sorted ascending by
    /// memory need) each client can statically afford — HeteroFL's
    /// complexity assignment and AllSmall's global-model pick. The result
    /// is inherently O(fleet); lazy fleets stream the pure budget recipe
    /// instead of materializing clients.
    pub fn capability_assignment(&self, options: &[MemCoeffs]) -> Vec<Option<usize>> {
        let best_for = |budget: u64| {
            let mut best = None;
            for (i, m) in options.iter().enumerate() {
                if m.bytes_at(self.mem_cfg.accounting_batch) <= budget {
                    best = Some(i);
                }
            }
            best
        };
        match &self.storage {
            Storage::Eager(v) => v.iter().map(|c| best_for(c.memory.budget)).collect(),
            Storage::Lazy(l) => {
                (0..l.num_clients).map(|id| best_for(l.budget(id, &self.mem_cfg))).collect()
            }
        }
    }

    /// Snapshot every mutable stream in the pool — the selection rng, each
    /// client's contention rng / shard cursor / prefix version, and (lazy
    /// pools) the cache state — in deterministic (id-sorted) order, so two
    /// snapshots of identical pools are identical values.
    pub fn export_state(&self) -> PoolCkptState {
        let client_ckpt = |c: &Client| ClientCkpt {
            id: c.id,
            mem_rng: c.memory.rng_state(),
            cursor: c.shard.cursor(),
            prefix_version: c.prefix_version,
        };
        let kind = match &self.storage {
            Storage::Eager(v) => PoolCkptKind::Eager(v.iter().map(client_ckpt).collect()),
            Storage::Lazy(l) => {
                let mut resident: Vec<(ClientCkpt, u64)> =
                    l.resident.values().map(|r| (client_ckpt(&r.client), r.tick)).collect();
                resident.sort_unstable_by_key(|(c, _)| c.id);
                let mut evicted: Vec<ClientCkpt> = l
                    .evicted
                    .iter()
                    .map(|(&id, res)| ClientCkpt {
                        id,
                        mem_rng: res.memory.rng_state(),
                        cursor: res.cursor,
                        prefix_version: res.prefix_version,
                    })
                    .collect();
                evicted.sort_unstable_by_key(|c| c.id);
                PoolCkptKind::Lazy(LazyCkpt {
                    tick: l.tick,
                    peak_resident: l.peak_resident,
                    hits: l.hits,
                    misses: l.misses,
                    evictions: l.evictions,
                    resident,
                    evicted,
                })
            }
        };
        PoolCkptState { select_rng: self.rng.state(), kind }
    }

    /// Reposition a freshly built pool at a checkpointed state. The pool
    /// must have been built by the same recipe (config + seed + storage
    /// mode) that produced the snapshot; every subsequent selection /
    /// contention / shard draw is then bit-identical to the pool the
    /// snapshot was taken from. Errors (never panics) on a snapshot that
    /// does not fit this pool's shape.
    pub fn import_state(&mut self, state: &PoolCkptState) -> Result<()> {
        let n = self.len();
        match (&mut self.storage, &state.kind) {
            (Storage::Eager(v), PoolCkptKind::Eager(list)) => {
                ensure!(
                    list.len() == v.len(),
                    "checkpoint has {} client residues, pool has {} clients",
                    list.len(),
                    v.len()
                );
                for (i, c) in list.iter().enumerate() {
                    ensure!(c.id == i, "client residue {i} carries id {} (must be in id order)", c.id);
                    v[i].memory.set_rng_state(c.mem_rng);
                    v[i].shard.set_cursor(c.cursor);
                    v[i].prefix_version = c.prefix_version;
                }
            }
            (Storage::Lazy(l), PoolCkptKind::Lazy(ck)) => {
                l.resident.clear();
                l.evicted.clear();
                let mem_cfg = self.mem_cfg;
                // Stage every residue (resident + evicted) in the residue
                // map, then re-materialize the residents through the normal
                // rebuild path so budgets/profiles/shards come from the
                // pure recipes.
                for c in ck.evicted.iter().chain(ck.resident.iter().map(|(c, _)| c)) {
                    ensure!(c.id < n, "residue for client {} but the fleet has {n} clients", c.id);
                    let mut mem_rng = Rng::from_state(l.mem_state0);
                    mem_rng.skip(c.id as u64);
                    let mut memory = DeviceMemory::sample(&mem_cfg, &mut mem_rng, c.id);
                    memory.set_rng_state(c.mem_rng);
                    let res =
                        Residue { memory, cursor: c.cursor, prefix_version: c.prefix_version };
                    ensure!(
                        l.evicted.insert(c.id, res).is_none(),
                        "duplicate residue for client {}",
                        c.id
                    );
                }
                for (c, tick) in &ck.resident {
                    let client = l.rebuild(c.id, &mem_cfg);
                    l.resident.insert(c.id, Resident { client, tick: *tick });
                }
                l.tick = ck.tick;
                l.peak_resident = ck.peak_resident;
                l.hits = ck.hits;
                l.misses = ck.misses;
                l.evictions = ck.evictions;
            }
            (Storage::Eager(_), PoolCkptKind::Lazy(_)) => {
                bail!("checkpoint snapshotted a lazy pool but the resolved config builds an eager one")
            }
            (Storage::Lazy(_), PoolCkptKind::Eager(_)) => {
                bail!("checkpoint snapshotted an eager pool but the resolved config builds a lazy one")
            }
        }
        self.rng = Rng::from_state(state.select_rng);
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::memory::MB;

    fn pool(seed: u64) -> ClientPool {
        pool_with(seed, "uniform")
    }

    fn pool_with(seed: u64, profile: &str) -> ClientPool {
        let data = SyntheticDataset::new(10, seed);
        let fleet = FleetProfileConfig::named(profile).unwrap();
        ClientPool::build(50, 5_000, &data, Partition::Iid, MemoryConfig::default(), &fleet, seed)
    }

    fn lazy_pool_with(seed: u64, profile: &str, cap: usize) -> ClientPool {
        let data = SyntheticDataset::new(10, seed);
        let fleet = FleetProfileConfig::named(profile).unwrap();
        ClientPool::build_lazy(
            50,
            5_000,
            &data,
            Partition::Iid,
            MemoryConfig::default(),
            &fleet,
            seed,
            cap,
        )
    }

    fn coeffs(total_mb: u64) -> MemCoeffs {
        MemCoeffs { fixed_bytes: total_mb * MB, per_sample_bytes: 0, params_total: 0, params_trainable: 0 }
    }

    #[test]
    fn pool_construction() {
        let p = pool(1);
        assert_eq!(p.len(), 50);
        assert!(p.total_samples() > 2_000);
    }

    #[test]
    fn selection_splits_by_memory() {
        let mut p = pool(2);
        let sel = p.select(20, &coeffs(500));
        assert_eq!(sel.trainers.len() + sel.fallback.len(), 20);
        assert!(!sel.trainers.is_empty());
        assert!(!sel.fallback.is_empty());
        // tiny artifact: everyone trains
        let sel2 = p.select(20, &coeffs(10));
        assert!(sel2.fallback.is_empty());
    }

    #[test]
    fn participation_rate_monotone_in_memory() {
        let p = pool(3);
        let pr_small = p.participation_rate(&coeffs(50));
        let pr_mid = p.participation_rate(&coeffs(500));
        let pr_big = p.participation_rate(&coeffs(950));
        assert!(pr_small >= pr_mid && pr_mid >= pr_big);
        assert_eq!(pr_small, 1.0);
        assert_eq!(pr_big, 0.0);
    }

    #[test]
    fn capability_assignment_orders() {
        let p = pool(4);
        let opts = vec![coeffs(80), coeffs(300), coeffs(700)];
        let assign = p.capability_assignment(&opts);
        for (id, a) in assign.iter().enumerate() {
            match a {
                Some(i) => assert!(p.client(id).memory.budget >= opts[*i].fixed_bytes),
                None => assert!(p.client(id).memory.budget < 80 * MB),
            }
        }
        // heterogeneity: at least two distinct tiers present
        let mut tiers: Vec<_> = assign.iter().flatten().collect();
        tiers.sort();
        tiers.dedup();
        assert!(tiers.len() >= 2);
    }

    #[test]
    fn device_profiles_deterministic_and_heterogeneous() {
        let a = pool_with(6, "mobile");
        let b = pool_with(6, "mobile");
        for id in 0..a.len() {
            assert_eq!(a.client(id).profile, b.client(id).profile, "client {id}");
        }
        // The mobile fleet must actually mix device tiers.
        let mut tiers: Vec<String> =
            (0..a.len()).map(|id| format!("{:?}", a.client(id).profile.tier)).collect();
        tiers.sort();
        tiers.dedup();
        assert!(tiers.len() >= 2, "expected tier diversity, got {tiers:?}");
    }

    #[test]
    fn selection_deterministic_per_seed() {
        let mut a = pool(5);
        let mut b = pool(5);
        let s1 = a.select(10, &coeffs(400));
        let s2 = b.select(10, &coeffs(400));
        assert_eq!(s1.trainers, s2.trainers);
        assert_eq!(s1.fallback, s2.fallback);
    }

    #[test]
    fn busy_clients_are_never_resampled() {
        // A client with an upload in flight must not re-enter the cohort
        // (a re-dispatch would supersede — discard — its pending work).
        let mut p = pool(6);
        let busy: Vec<usize> = (0..10).collect();
        for round in 0..20 {
            let sel = p.select_excluding(20, &coeffs(400), &busy);
            let sampled: Vec<usize> =
                sel.availability.iter().map(|&(id, _)| id).collect();
            assert_eq!(sampled.len(), 20, "cohort still fills from the rest");
            for id in &sampled {
                assert!(!busy.contains(id), "round {round}: busy client {id} re-sampled");
            }
        }
        // Excluding everyone leaves an empty (but valid) selection.
        let all: Vec<usize> = (0..p.len()).collect();
        let sel = p.select_excluding(20, &coeffs(400), &all);
        assert!(sel.availability.is_empty());
    }

    #[test]
    fn empty_busy_set_matches_plain_select_bit_for_bit() {
        // The degeneracy guarantees need select_excluding(∅) to consume
        // the rng stream exactly like select.
        let mut a = pool(7);
        let mut b = pool(7);
        for _ in 0..5 {
            let s1 = a.select(12, &coeffs(400));
            let s2 = b.select_excluding(12, &coeffs(400), &[]);
            assert_eq!(s1.trainers, s2.trainers);
            assert_eq!(s1.fallback, s2.fallback);
            assert_eq!(s1.availability, s2.availability);
        }
        // And the stream *positions* still align afterwards: a trailing
        // plain select on each pool must agree too.
        let t1 = a.select(12, &coeffs(400));
        let t2 = b.select(12, &coeffs(400));
        assert_eq!(t1.availability, t2.availability, "rng stream positions diverged");
    }

    #[test]
    fn exclusion_rank_mapping_matches_collect_then_index() {
        // rank_to_id must reproduce `eligible[rank]` for the historical
        // eligibility vector, for any exclusion pattern.
        let n = 40usize;
        for excl in [vec![], vec![0], vec![39], vec![0, 1, 2], vec![5, 17, 18, 30], (0..39).collect()]
        {
            let eligible: Vec<usize> = (0..n).filter(|id| !excl.contains(id)).collect();
            for (rank, &want) in eligible.iter().enumerate() {
                assert_eq!(rank_to_id(rank, &excl), want, "excl {excl:?} rank {rank}");
            }
        }
    }

    // --- lazy fleet --------------------------------------------------------

    #[test]
    fn lazy_pool_matches_eager_bit_for_bit() {
        // Budgets, profiles, shard labels/indices, and prefix versions of
        // every client — materialized out of order — must equal the eager
        // build's.
        let mut eager = pool_with(8, "mobile");
        let mut lazy = lazy_pool_with(8, "mobile", 64);
        assert_eq!(eager.len(), lazy.len());
        assert_eq!(eager.total_samples(), lazy.total_samples());
        let order: Vec<usize> = (0..50).rev().collect();
        for &id in &order {
            lazy.client_mut(id); // materialize
            let e = eager.client(id);
            let l = lazy.client(id);
            assert_eq!(e.memory.budget, l.memory.budget, "client {id} budget");
            assert_eq!(e.profile, l.profile, "client {id} profile");
            assert_eq!(e.shard.labels, l.shard.labels, "client {id} labels");
            assert_eq!(e.shard.indices, l.shard.indices, "client {id} indices");
            assert_eq!(e.prefix_version, l.prefix_version);
        }
        // Contention streams advance identically too.
        let cfg = MemoryConfig::default();
        for id in [0usize, 7, 49] {
            for _ in 0..4 {
                let a = eager.client_mut(id).memory.available(&cfg);
                let b = lazy.client_mut(id).memory.available(&cfg);
                assert_eq!(a, b, "client {id} contention stream");
            }
        }
    }

    #[test]
    fn lazy_selection_stream_matches_eager() {
        // Whole selection rounds — cohort ids, availability draws, the
        // trainers/fallback split — bit-identical across storage modes,
        // including with exclusions in play.
        let mut eager = pool_with(9, "mobile");
        let mut lazy = lazy_pool_with(9, "mobile", 64);
        for round in 0..6 {
            let busy: Vec<usize> = if round % 2 == 0 { vec![] } else { vec![3, 4, 5, 20] };
            let a = eager.select_excluding(15, &coeffs(400), &busy);
            let b = lazy.select_excluding(15, &coeffs(400), &busy);
            assert_eq!(a.trainers, b.trainers, "round {round}");
            assert_eq!(a.fallback, b.fallback, "round {round}");
            assert_eq!(a.availability, b.availability, "round {round}");
        }
    }

    #[test]
    fn lazy_eviction_preserves_mutable_state() {
        // A 4-client cache forces constant eviction; contention streams
        // and selection must still match the eager pool exactly because
        // residues restore the evicted state.
        let mut eager = pool(10);
        let mut lazy = lazy_pool_with(10, "uniform", 4);
        for round in 0..10 {
            let a = eager.select(3, &coeffs(400));
            let b = lazy.select(3, &coeffs(400));
            assert_eq!(a.availability, b.availability, "round {round}");
            assert!(lazy.materialized() <= 4, "cache exceeded its cap");
        }
        assert!(lazy.peak_materialized() <= 4);
    }

    #[test]
    fn pool_stats_count_hits_misses_evictions() {
        // Eager: no cache, so counters stay zero and materialized = fleet.
        let mut eager = pool(12);
        eager.select(5, &coeffs(400));
        let s = eager.stats();
        assert_eq!((s.hits, s.misses, s.evictions), (0, 0, 0));
        assert_eq!(s.materialized, eager.len());
        assert_eq!(s.peak_materialized, eager.len());

        // Lazy: first touches miss, repeats hit, a tiny cap evicts.
        let mut lazy = lazy_pool_with(12, "uniform", 4);
        assert_eq!(lazy.stats(), PoolStats::default(), "untouched pool");
        lazy.client_mut(0);
        lazy.client_mut(1);
        lazy.client_mut(0); // resident again -> hit
        let s = lazy.stats();
        assert_eq!(s.hits, 1);
        assert_eq!(s.misses, 2);
        assert_eq!(s.evictions, 0);
        assert_eq!(s.materialized, 2);
        for id in 2..8 {
            lazy.client_mut(id); // overflow the 4-client cap
        }
        let s = lazy.stats();
        assert_eq!(s.misses, 8, "every distinct client missed once");
        assert_eq!(s.evictions, 4, "8 distinct residents through a cap of 4");
        assert_eq!(s.materialized, 4);
        assert_eq!(s.peak_materialized, 4);
        // Stats reads are pure: repeated reads don't drift.
        assert_eq!(lazy.stats(), s);
    }

    #[test]
    fn export_import_resumes_both_storage_modes_bit_for_bit() {
        // Advance a pool mid-run, snapshot it, import the snapshot into a
        // freshly built pool, and check the continued selection /
        // contention streams equal an uninterrupted reference — for both
        // storage modes. Also: export after import is value-identical
        // (snapshot idempotence).
        for lazy in [false, true] {
            let build = || {
                if lazy {
                    lazy_pool_with(13, "mobile", 8)
                } else {
                    pool_with(13, "mobile")
                }
            };
            let mut reference = build();
            let mut live = build();
            for _ in 0..4 {
                reference.select(7, &coeffs(400));
                live.select(7, &coeffs(400));
            }
            let state = live.export_state();
            let mut resumed = build();
            resumed.import_state(&state).unwrap();
            assert_eq!(resumed.export_state(), state, "lazy={lazy}: import/export drifted");
            for round in 0..6 {
                let busy: Vec<usize> = if round % 2 == 0 { vec![] } else { vec![2, 9] };
                let a = reference.select_excluding(7, &coeffs(400), &busy);
                let b = resumed.select_excluding(7, &coeffs(400), &busy);
                assert_eq!(a.availability, b.availability, "lazy={lazy} round {round}");
                assert_eq!(a.trainers, b.trainers, "lazy={lazy} round {round}");
            }
        }
    }

    #[test]
    fn import_rejects_misshapen_snapshots() {
        let mut p = pool(14);
        // Wrong storage kind.
        let lazy_state = lazy_pool_with(14, "uniform", 4).export_state();
        assert!(p.import_state(&lazy_state).is_err());
        // Wrong fleet size.
        let mut state = p.export_state();
        if let PoolCkptKind::Eager(list) = &mut state.kind {
            list.pop();
        }
        assert!(p.import_state(&state).is_err());
        // Out-of-range / duplicate lazy residues.
        let mut lp = lazy_pool_with(14, "uniform", 4);
        let mut bad = lazy_state.clone();
        if let PoolCkptKind::Lazy(l) = &mut bad.kind {
            l.evicted.push(ClientCkpt { id: 10_000, mem_rng: 1, cursor: 0, prefix_version: 0 });
        }
        assert!(lp.import_state(&bad).is_err());
        let mut dup = lazy_state.clone();
        if let PoolCkptKind::Lazy(l) = &mut dup.kind {
            let c = ClientCkpt { id: 1, mem_rng: 1, cursor: 0, prefix_version: 0 };
            l.evicted = vec![c, c];
        }
        assert!(lp.import_state(&dup).is_err());
    }

    #[test]
    fn lazy_pool_materializes_only_the_cohort() {
        // The memory-wall acceptance: a fleet orders of magnitude larger
        // than the cohort must never materialize more than the resident
        // cap — peak materialized ≪ fleet size.
        let data = SyntheticDataset::new(10, 11);
        let fleet = FleetProfileConfig::named("mobile").unwrap();
        let mut p = ClientPool::build_lazy(
            100_000,
            1_000_000,
            &data,
            Partition::Iid,
            MemoryConfig::default(),
            &fleet,
            11,
            256,
        );
        assert_eq!(p.len(), 100_000);
        for _ in 0..5 {
            let sel = p.select(50, &coeffs(400));
            assert_eq!(sel.availability.len(), 50);
        }
        assert!(
            p.peak_materialized() <= 256,
            "peak {} exceeds the resident cap",
            p.peak_materialized()
        );
        assert!(p.peak_materialized() * 100 < p.len(), "peak must be ≪ fleet size");
        // Fleet-wide aggregates still answer without materialization.
        assert!(p.total_samples() > 500_000);
        let pr = p.participation_rate(&coeffs(500));
        assert!((0.0..=1.0).contains(&pr));
        assert!(p.materialized() <= 256);
    }
}
