//! Named parameter store: the server-side global model state.
//!
//! Parameters live as flat `f32` vectors keyed by the manifest's names
//! (`b2/u0/conv1/w`, `op/fc/b`, …). The store owns initialization (He for
//! conv/dense weights, 1/0 for BN scale/shift — mirroring
//! `compile/ops.init_ops`), snapshotting for the effective-movement
//! metric, and the corner-slicing used by HeteroFL width aggregation.

use crate::rng::Rng;
use anyhow::{bail, Context, Result};
use std::collections::BTreeMap;

/// One named parameter: a shape plus its row-major flat data.
#[derive(Debug, Clone, PartialEq)]
pub struct Tensor {
    /// Dimension sizes, outermost first.
    pub shape: Vec<usize>,
    /// Row-major flat values (`shape.iter().product()` elements).
    pub data: Vec<f32>,
}

impl Tensor {
    /// An all-zeros tensor of the given shape.
    pub fn zeros(shape: &[usize]) -> Self {
        Tensor { shape: shape.to_vec(), data: vec![0.0; shape.iter().product()] }
    }

    /// Flat element count.
    pub fn len(&self) -> usize {
        self.data.len()
    }

    /// Whether the tensor has no elements.
    pub fn is_empty(&self) -> bool {
        self.data.is_empty()
    }

    /// Extract the leading-corner sub-tensor of `sub_shape` — HeteroFL's
    /// "first ⌈r·C⌉ channels" slicing, generalized to every axis.
    pub fn slice_corner(&self, sub_shape: &[usize]) -> Result<Tensor> {
        if sub_shape.len() != self.shape.len() {
            bail!("rank mismatch: {:?} vs {:?}", sub_shape, self.shape);
        }
        for (s, f) in sub_shape.iter().zip(&self.shape) {
            if s > f {
                bail!("sub shape {:?} exceeds {:?}", sub_shape, self.shape);
            }
        }
        let mut out = Tensor::zeros(sub_shape);
        copy_corner(&self.shape, &self.data, sub_shape, &mut out.data, CopyDir::FullToSub);
        Ok(out)
    }

    /// Scatter-add a corner sub-tensor (weighted) into `acc`, bumping the
    /// per-position weight accumulator `wacc` (same layout as self).
    pub fn accumulate_corner(
        full_shape: &[usize],
        acc: &mut [f32],
        wacc: &mut [f32],
        sub_shape: &[usize],
        sub_data: &[f32],
        weight: f32,
    ) {
        accumulate_corner_rec(full_shape, acc, wacc, sub_shape, sub_data, weight, 0, 0, 0);
    }
}

enum CopyDir {
    FullToSub,
}

fn copy_corner(full_shape: &[usize], full: &[f32], sub_shape: &[usize], sub: &mut [f32], _dir: CopyDir) {
    // Iterate sub positions in row-major order, mapping to full offsets.
    let rank = full_shape.len();
    if rank == 0 {
        sub[0] = full[0];
        return;
    }
    let full_strides = strides(full_shape);
    let sub_strides = strides(sub_shape);
    let total: usize = sub_shape.iter().product();
    let mut idx = vec![0usize; rank];
    for s_off in 0..total {
        // decode s_off -> idx
        let mut rem = s_off;
        for d in 0..rank {
            idx[d] = rem / sub_strides[d];
            rem %= sub_strides[d];
        }
        let f_off: usize = idx.iter().zip(&full_strides).map(|(i, st)| i * st).sum();
        sub[s_off] = full[f_off];
    }
}

#[allow(clippy::too_many_arguments)]
fn accumulate_corner_rec(
    full_shape: &[usize],
    acc: &mut [f32],
    wacc: &mut [f32],
    sub_shape: &[usize],
    sub: &[f32],
    w: f32,
    dim: usize,
    full_off: usize,
    sub_off: usize,
) {
    if dim == full_shape.len() {
        // Rank-0 tensor: single scalar position.
        acc[full_off] += w * sub[sub_off];
        wacc[full_off] += w;
        return;
    }
    if dim + 1 == full_shape.len() {
        // Last dimension: stride 1 in both layouts, so the whole row is
        // contiguous — sweep it through the chunked arena kernels (same
        // elementwise ops in the same order as the per-position recursion,
        // bit for bit; see `aggregate::simd`).
        let n = sub_shape[dim];
        crate::aggregate::simd::axpy(&mut acc[full_off..full_off + n], &sub[sub_off..sub_off + n], w);
        crate::aggregate::simd::add_scalar(&mut wacc[full_off..full_off + n], w);
        return;
    }
    let fs = strides(full_shape);
    let ss = strides(sub_shape);
    for i in 0..sub_shape[dim] {
        accumulate_corner_rec(
            full_shape,
            acc,
            wacc,
            sub_shape,
            sub,
            w,
            dim + 1,
            full_off + i * fs[dim],
            sub_off + i * ss[dim],
        );
    }
}

fn strides(shape: &[usize]) -> Vec<usize> {
    let mut st = vec![1usize; shape.len()];
    for d in (0..shape.len().saturating_sub(1)).rev() {
        st[d] = st[d + 1] * shape[d + 1];
    }
    st
}

/// The global model parameter store.
#[derive(Debug, Clone, Default)]
pub struct ParamStore {
    params: BTreeMap<String, Tensor>,
}

impl ParamStore {
    /// Initialize every parameter from the manifest inventory.
    /// Rules mirror `compile/ops.init_ops`: He-normal for weights
    /// (fan_in = prod(shape[..-1])), scale=1, shift/bias=0.
    pub fn init(shapes: &BTreeMap<String, Vec<usize>>, seed: u64) -> Self {
        let base = Rng::new(seed);
        let mut params = BTreeMap::new();
        for (i, (name, shape)) in shapes.iter().enumerate() {
            let mut rng = base.fork(i as u64 + 1);
            let n: usize = shape.iter().product();
            let data = if name.ends_with("/scale") {
                vec![1.0; n]
            } else if name.ends_with("/shift") || name.ends_with("/b") {
                vec![0.0; n]
            } else {
                let fan_in: usize = shape[..shape.len().saturating_sub(1)].iter().product::<usize>().max(1);
                let std = (2.0 / fan_in as f64).sqrt() as f32;
                (0..n).map(|_| rng.normal() * std).collect()
            };
            params.insert(name.clone(), Tensor { shape: shape.clone(), data });
        }
        ParamStore { params }
    }

    /// Look up a parameter by name.
    pub fn get(&self, name: &str) -> Result<&Tensor> {
        self.params.get(name).with_context(|| format!("param `{name}` not in store"))
    }

    /// Mutable lookup (DepthFL's in-place write-back path).
    pub fn get_mut(&mut self, name: &str) -> Result<&mut Tensor> {
        self.params.get_mut(name).with_context(|| format!("param `{name}` not in store"))
    }

    /// Insert or replace a parameter.
    pub fn set(&mut self, name: &str, t: Tensor) {
        self.params.insert(name.to_string(), t);
    }

    /// Whether `name` exists in the store.
    pub fn contains(&self, name: &str) -> bool {
        self.params.contains_key(name)
    }

    /// All parameter names, in sorted (BTreeMap) order.
    pub fn names(&self) -> impl Iterator<Item = &String> {
        self.params.keys()
    }

    /// Number of parameters held.
    pub fn len(&self) -> usize {
        self.params.len()
    }

    /// Whether the store holds no parameters.
    pub fn is_empty(&self) -> bool {
        self.params.is_empty()
    }

    /// Flat concatenation of a set of parameters (effective-movement
    /// snapshots operate on these block vectors).
    pub fn flatten(&self, names: &[String]) -> Vec<f32> {
        let mut out = Vec::new();
        for n in names {
            if let Some(t) = self.params.get(n) {
                out.extend_from_slice(&t.data);
            }
        }
        out
    }

    /// Re-initialize a subset (used by ablations / seed sweeps).
    pub fn reinit(&mut self, names: &[String], seed: u64) {
        let shapes: BTreeMap<String, Vec<usize>> =
            names.iter().filter_map(|n| self.params.get(n).map(|t| (n.clone(), t.shape.clone()))).collect();
        let fresh = ParamStore::init(&shapes, seed);
        for (n, t) in fresh.params {
            self.params.insert(n, t);
        }
    }

    /// Total scalar count across every parameter.
    pub fn total_elems(&self) -> usize {
        self.params.values().map(|t| t.len()).sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn shapes(pairs: &[(&str, &[usize])]) -> BTreeMap<String, Vec<usize>> {
        pairs.iter().map(|(n, s)| (n.to_string(), s.to_vec())).collect()
    }

    #[test]
    fn init_rules() {
        let s = shapes(&[
            ("b1/conv/w", &[3, 3, 4, 8]),
            ("b1/bn/scale", &[8]),
            ("b1/bn/shift", &[8]),
            ("head/fc/b", &[10]),
        ]);
        let store = ParamStore::init(&s, 1);
        assert!(store.get("b1/bn/scale").unwrap().data.iter().all(|&v| v == 1.0));
        assert!(store.get("b1/bn/shift").unwrap().data.iter().all(|&v| v == 0.0));
        assert!(store.get("head/fc/b").unwrap().data.iter().all(|&v| v == 0.0));
        let w = store.get("b1/conv/w").unwrap();
        let std: f32 = {
            let m = w.data.iter().sum::<f32>() / w.len() as f32;
            (w.data.iter().map(|v| (v - m) * (v - m)).sum::<f32>() / w.len() as f32).sqrt()
        };
        let expect = (2.0f32 / 36.0).sqrt();
        assert!((std - expect).abs() < expect * 0.3, "std {std} vs {expect}");
    }

    #[test]
    fn init_deterministic_and_seed_sensitive() {
        let s = shapes(&[("w", &[4, 4])]);
        let a = ParamStore::init(&s, 9);
        let b = ParamStore::init(&s, 9);
        let c = ParamStore::init(&s, 10);
        assert_eq!(a.get("w").unwrap().data, b.get("w").unwrap().data);
        assert_ne!(a.get("w").unwrap().data, c.get("w").unwrap().data);
    }

    #[test]
    fn slice_corner_2d() {
        let t = Tensor { shape: vec![3, 4], data: (0..12).map(|v| v as f32).collect() };
        let s = t.slice_corner(&[2, 2]).unwrap();
        assert_eq!(s.data, vec![0.0, 1.0, 4.0, 5.0]);
    }

    #[test]
    fn slice_corner_4d_conv() {
        // (2,2,2,2) kernel, slice to (2,2,1,1): keep first in/out channel.
        let t = Tensor { shape: vec![2, 2, 2, 2], data: (0..16).map(|v| v as f32).collect() };
        let s = t.slice_corner(&[2, 2, 1, 1]).unwrap();
        assert_eq!(s.data, vec![0.0, 4.0, 8.0, 12.0]);
    }

    #[test]
    fn slice_rejects_bad_shapes() {
        let t = Tensor::zeros(&[2, 2]);
        assert!(t.slice_corner(&[3, 1]).is_err());
        assert!(t.slice_corner(&[2]).is_err());
    }

    #[test]
    fn accumulate_corner_roundtrip() {
        let full_shape = vec![2, 3];
        let mut acc = vec![0.0; 6];
        let mut wacc = vec![0.0; 6];
        let sub = vec![1.0, 2.0, 3.0, 4.0]; // (2,2)
        Tensor::accumulate_corner(&full_shape, &mut acc, &mut wacc, &[2, 2], &sub, 0.5);
        assert_eq!(acc, vec![0.5, 1.0, 0.0, 1.5, 2.0, 0.0]);
        assert_eq!(wacc, vec![0.5, 0.5, 0.0, 0.5, 0.5, 0.0]);
    }

    #[test]
    fn accumulate_corner_chunked_rows_match_scalar_reference() {
        // The last-dim rows now sweep through the chunked arena kernels;
        // race them against the naive per-element reference across row
        // lengths straddling the 8-lane chunk width (and its tails).
        for cols in [1usize, 7, 8, 9, 16, 19] {
            let full_cols = cols + 2;
            let full_shape = vec![3, full_cols];
            let total = 3 * full_cols;
            let mut rng = crate::rng::Rng::new(cols as u64);
            let sub_shape = vec![2, cols];
            let sub: Vec<f32> = (0..2 * cols).map(|_| rng.normal()).collect();
            let w = 0.37f32;
            let mut acc = vec![0.0f32; total];
            let mut wacc = vec![0.0f32; total];
            Tensor::accumulate_corner(&full_shape, &mut acc, &mut wacc, &sub_shape, &sub, w);
            let mut racc = vec![0.0f32; total];
            let mut rwacc = vec![0.0f32; total];
            for r in 0..2 {
                for c in 0..cols {
                    let f = r * full_cols + c;
                    racc[f] += w * sub[r * cols + c];
                    rwacc[f] += w;
                }
            }
            for i in 0..total {
                assert_eq!(acc[i].to_bits(), racc[i].to_bits(), "cols={cols} acc[{i}]");
                assert_eq!(wacc[i].to_bits(), rwacc[i].to_bits(), "cols={cols} wacc[{i}]");
            }
        }
    }

    #[test]
    fn flatten_order_stable() {
        let s = shapes(&[("a", &[2]), ("b", &[2])]);
        let mut store = ParamStore::init(&s, 1);
        store.set("a", Tensor { shape: vec![2], data: vec![1.0, 2.0] });
        store.set("b", Tensor { shape: vec![2], data: vec![3.0, 4.0] });
        assert_eq!(store.flatten(&["a".into(), "b".into()]), vec![1.0, 2.0, 3.0, 4.0]);
        assert_eq!(store.flatten(&["b".into(), "a".into()]), vec![3.0, 4.0, 1.0, 2.0]);
    }
}
