//! Deterministic RNG substrate (SplitMix64).
//!
//! Every stochastic decision in the simulator — device memory budgets,
//! contention jitter, client sampling, Dirichlet partitioning, synthetic
//! image noise, parameter init — flows from seeded `SplitMix64` streams,
//! so whole FL runs are bit-reproducible from a single config seed. No
//! wall-clock, no global state, no external RNG crates.

/// The SplitMix64 state stride: every [`Rng::next_u64`] advances the
/// internal state by exactly this constant, so the state after `n` draws
/// is `state0 + n * GAMMA` (wrapping). The lazy client pool exploits this
/// to jump an rng stream to an arbitrary client's position in O(1)
/// instead of replaying every preceding draw (see `clients::LazyFleet`).
pub(crate) const GAMMA: u64 = 0x9e37_79b9_7f4a_7c15;

/// SplitMix64: tiny, fast, splittable, passes BigCrush. Used as both the
/// base generator and the stream-splitting mechanism (`fork`).
#[derive(Clone, Debug)]
pub struct Rng {
    state: u64,
}

impl Rng {
    /// Seed a fresh stream (the seed is avalanched once up front).
    pub fn new(seed: u64) -> Self {
        // Avalanche the seed once so small seeds diverge immediately.
        let mut r = Rng { state: seed ^ GAMMA };
        r.next_u64();
        r
    }

    /// The raw internal state — the checkpoint image of this stream.
    /// Persisting this single `u64` and later calling
    /// [`Self::from_state`] resumes the stream exactly (also used
    /// internally for lazy-pool stream jumping).
    pub fn state(&self) -> u64 {
        self.state
    }

    /// Rebuild a stream at a previously observed [`Self::state`]. The
    /// next draw of the rebuilt stream is bit-identical to the next draw
    /// of the original — the primitive the checkpoint/resume subsystem
    /// (`docs/CHECKPOINT.md`) and the lazy client pool are built on.
    pub fn from_state(state: u64) -> Rng {
        Rng { state }
    }

    /// Advance the stream by `n` draws in O(1) without computing them —
    /// SplitMix64's state moves by a constant stride per draw, so
    /// skipping is pure arithmetic. Bit-identical to calling
    /// [`Self::next_u64`] `n` times and discarding the results.
    pub(crate) fn skip(&mut self, n: u64) {
        self.state = self.state.wrapping_add(n.wrapping_mul(GAMMA));
    }

    /// Derive an independent stream for a named sub-purpose. Streams are
    /// stable across runs: fork(seed, purpose) is a pure function.
    pub fn fork(&self, purpose: u64) -> Rng {
        Rng::new(self.state.wrapping_mul(0xbf58_476d_1ce4_e5b9) ^ purpose.wrapping_mul(0x94d0_49bb_1331_11eb))
    }

    /// Next raw 64-bit draw.
    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(GAMMA);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xbf58_476d_1ce4_e5b9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94d0_49bb_1331_11eb);
        z ^ (z >> 31)
    }

    /// Uniform in [0, 1).
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform in [0, 1) at f32 precision.
    pub fn f32(&mut self) -> f32 {
        self.f64() as f32
    }

    /// Uniform in [lo, hi).
    pub fn uniform(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.f64()
    }

    /// Uniform integer in [0, n).
    pub fn below(&mut self, n: usize) -> usize {
        debug_assert!(n > 0);
        // Lemire-style rejection-free for our n << 2^64 use cases.
        (self.next_u64() % n as u64) as usize
    }

    /// Standard normal via Box-Muller.
    pub fn normal(&mut self) -> f32 {
        let u1 = self.f64().max(1e-12);
        let u2 = self.f64();
        ((-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos()) as f32
    }

    /// Gamma(alpha, 1) via Marsaglia-Tsang (with Johnk boost for alpha<1).
    pub fn gamma(&mut self, alpha: f64) -> f64 {
        if alpha < 1.0 {
            // Gamma(a) = Gamma(a+1) * U^(1/a)
            let g = self.gamma(alpha + 1.0);
            return g * self.f64().max(1e-12).powf(1.0 / alpha);
        }
        let d = alpha - 1.0 / 3.0;
        let c = 1.0 / (9.0 * d).sqrt();
        loop {
            let x = self.normal() as f64;
            let v = (1.0 + c * x).powi(3);
            if v <= 0.0 {
                continue;
            }
            let u = self.f64();
            if u < 1.0 - 0.0331 * x.powi(4) || u.ln() < 0.5 * x * x + d * (1.0 - v + v.ln()) {
                return d * v;
            }
        }
    }

    /// Dirichlet(alpha) over k categories — the Non-IID label partitioner.
    pub fn dirichlet(&mut self, alpha: f64, k: usize) -> Vec<f64> {
        let mut g: Vec<f64> = (0..k).map(|_| self.gamma(alpha).max(1e-12)).collect();
        let s: f64 = g.iter().sum();
        for v in &mut g {
            *v /= s;
        }
        g
    }

    /// Fisher-Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i + 1);
            xs.swap(i, j);
        }
    }

    /// Sample `k` distinct indices from [0, n) (k ≤ n) — the first `k`
    /// positions of a partial Fisher-Yates shuffle. When `k` is small
    /// relative to `n` the permutation is simulated *sparsely* (only the
    /// touched positions live in a map), so a 50-client cohort draw from
    /// a 1M-device fleet is O(k) instead of O(n). Both paths consume
    /// exactly `k` draws and return bit-identical results (regression- and
    /// property-tested), so the switchover is invisible to any seeded run.
    pub fn sample_indices(&mut self, n: usize, k: usize) -> Vec<usize> {
        assert!(k <= n, "sample {k} from {n}");
        // Dense cutover: materializing the identity permutation is faster
        // than map bookkeeping once a meaningful fraction gets touched.
        if k.saturating_mul(4) >= n {
            let mut idx: Vec<usize> = (0..n).collect();
            // partial Fisher-Yates: first k positions
            for i in 0..k {
                let j = i + self.below(n - i);
                idx.swap(i, j);
            }
            idx.truncate(k);
            return idx;
        }
        // Sparse partial Fisher-Yates: `perm` records only displaced
        // positions (absent = identity). Never iterated, so the map's
        // internal order cannot leak into results.
        let mut perm: std::collections::HashMap<usize, usize> =
            std::collections::HashMap::with_capacity(2 * k);
        let mut out = Vec::with_capacity(k);
        for i in 0..k {
            let j = i + self.below(n - i);
            let vj = perm.get(&j).copied().unwrap_or(j);
            let vi = perm.get(&i).copied().unwrap_or(i);
            perm.insert(j, vi);
            out.push(vj);
        }
        out
    }

    /// Weighted categorical draw.
    pub fn categorical(&mut self, probs: &[f64]) -> usize {
        let mut u = self.f64();
        for (i, p) in probs.iter().enumerate() {
            if u < *p {
                return i;
            }
            u -= p;
        }
        probs.len() - 1
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_streams() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
        let mut c = Rng::new(43);
        assert_ne!(a.next_u64(), c.next_u64());
    }

    #[test]
    fn fork_is_pure_and_divergent() {
        let base = Rng::new(7);
        let mut f1 = base.fork(1);
        let mut f1b = base.fork(1);
        let mut f2 = base.fork(2);
        assert_eq!(f1.next_u64(), f1b.next_u64());
        assert_ne!(f1.next_u64(), f2.next_u64());
    }

    #[test]
    fn uniform_bounds_and_mean() {
        let mut r = Rng::new(1);
        let n = 20_000;
        let mut sum = 0.0;
        for _ in 0..n {
            let v = r.uniform(100.0, 900.0);
            assert!((100.0..900.0).contains(&v));
            sum += v;
        }
        let mean = sum / n as f64;
        assert!((mean - 500.0).abs() < 10.0, "mean {mean}");
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(2);
        let n = 50_000;
        let (mut s, mut s2) = (0.0f64, 0.0f64);
        for _ in 0..n {
            let v = r.normal() as f64;
            s += v;
            s2 += v * v;
        }
        let mean = s / n as f64;
        let var = s2 / n as f64 - mean * mean;
        assert!(mean.abs() < 0.02, "mean {mean}");
        assert!((var - 1.0).abs() < 0.05, "var {var}");
    }

    #[test]
    fn dirichlet_sums_to_one_and_alpha_controls_skew() {
        let mut r = Rng::new(3);
        let p = r.dirichlet(1.0, 10);
        assert!((p.iter().sum::<f64>() - 1.0).abs() < 1e-9);
        // small alpha → skewed: max prob should usually dominate
        let mut max_small = 0.0;
        let mut max_large = 0.0;
        for _ in 0..50 {
            max_small += r.dirichlet(0.1, 10).iter().cloned().fold(0.0, f64::max);
            max_large += r.dirichlet(100.0, 10).iter().cloned().fold(0.0, f64::max);
        }
        assert!(max_small > max_large, "{max_small} vs {max_large}");
    }

    #[test]
    fn sample_indices_distinct() {
        let mut r = Rng::new(4);
        for _ in 0..20 {
            let s = r.sample_indices(100, 20);
            assert_eq!(s.len(), 20);
            let mut u = s.clone();
            u.sort_unstable();
            u.dedup();
            assert_eq!(u.len(), 20);
        }
    }

    /// The pre-sparse dense partial Fisher-Yates, kept verbatim as the
    /// reference semantics `sample_indices` must reproduce bit-for-bit.
    fn dense_reference(rng: &mut Rng, n: usize, k: usize) -> Vec<usize> {
        let mut idx: Vec<usize> = (0..n).collect();
        for i in 0..k {
            let j = i + rng.below(n - i);
            idx.swap(i, j);
        }
        idx.truncate(k);
        idx
    }

    #[test]
    fn sparse_sampling_matches_dense_reference_bit_for_bit() {
        // Outputs AND stream positions must match: every seeded cohort
        // draw in the repo (selection, examples, goldens) rests on this.
        for seed in 0..20u64 {
            for &(n, k) in &[(1usize, 0usize), (1, 1), (10, 3), (100, 7), (5_000, 50), (5_000, 4_999)]
            {
                let mut a = Rng::new(seed);
                let mut b = Rng::new(seed);
                assert_eq!(a.sample_indices(n, k), dense_reference(&mut b, n, k), "n={n} k={k}");
                // Identical post-sample stream position.
                assert_eq!(a.next_u64(), b.next_u64(), "stream diverged at n={n} k={k}");
            }
        }
    }

    #[test]
    fn skip_matches_discarded_draws() {
        let mut a = Rng::new(11);
        let mut b = Rng::new(11);
        for _ in 0..137 {
            a.next_u64();
        }
        b.skip(137);
        assert_eq!(a.next_u64(), b.next_u64());
        // from_state resumes exactly where state() was observed.
        let mut c = Rng::from_state(a.state());
        assert_eq!(a.next_u64(), c.next_u64());
    }

    #[test]
    fn gamma_positive_mean_close() {
        let mut r = Rng::new(5);
        let n = 20_000;
        let mean: f64 = (0..n).map(|_| r.gamma(3.0)).sum::<f64>() / n as f64;
        assert!((mean - 3.0).abs() < 0.1, "mean {mean}");
    }
}
