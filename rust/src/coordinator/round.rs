//! Round execution primitives: train rounds, distill rounds, evaluation.
//!
//! Sync-family policies aggregate a fixed cohort with the plain
//! [`Aggregator`]; the async policy routes through
//! [`BufferedAggregator`]: fresh finishers merge at staleness 0 (bit-for
//! bit the sync arithmetic), this round's stragglers are trained and
//! buffered as [`PendingUpdate`]s, and earlier rounds' late arrivals
//! merge with staleness-discounted weights.

use super::{PendingUpdate, ProjectedLate, ServerCtx, TEST_BATCHES};
use crate::aggregate::{transition_decay, Aggregator, BufferedAggregator};
use crate::fleet::{EventKind, RoundPlan};
use crate::json::Value;
use crate::metrics::RoundRecord;
use crate::runtime::{literal_f32, literal_i32, LoadedArtifact, Runtime};
use anyhow::{bail, Result};
use std::collections::HashMap;
use std::sync::Arc;

/// What a train round produced (before the metrics record is finalized).
pub struct RoundOutcome {
    /// Cohort-weighted mean training loss (NaN when nothing trained).
    pub mean_loss: f32,
    /// Cohort-weighted mean training accuracy (NaN when unavailable).
    pub mean_acc: f32,
    /// Clients whose updates aggregated this round.
    pub participants: usize,
    /// Clients trained on the output-layer fallback artifact.
    pub fallback: usize,
    /// Bytes uploaded this round.
    pub bytes_up: u64,
    /// Bytes downloaded this round.
    pub bytes_down: u64,
    /// Analytical peak client memory for this round's artifact (bytes).
    pub client_mem_bytes: u64,
    /// Virtual duration of this round (seconds) under the fleet simulator.
    pub sim_time_s: f64,
    /// Clients cut by the round policy before aggregation.
    pub stragglers: usize,
    /// Clients that dropped out after dispatch.
    pub dropouts: usize,
    /// Async policy: this round's dispatched clients whose uploads moved
    /// into the in-flight queue instead of being discarded.
    pub deferred: usize,
    /// Async policy: straggler updates from earlier rounds merged this
    /// round on arrival.
    pub late_merged: usize,
    /// Async policy: arrived-but-discarded late updates (too stale, or
    /// trained against a since-frozen/remapped block with projection off
    /// or nothing surviving the intersection).
    pub late_dropped: usize,
    /// Mean staleness (rounds) of the late-merged updates (0 when none).
    pub mean_staleness: f64,
    /// Stale projection: updates that crossed a freeze/step transition
    /// and merged their still-trainable suffix instead of being dropped.
    pub projected_merged: usize,
    /// Stale projection: scalars discarded with the since-frozen tensors
    /// of this round's projected merges.
    pub projected_dropped_params: u64,
    /// Mean freeze/step transitions crossed by this round's projected
    /// merges (0 when none) — the transition-staleness measure.
    pub transition_staleness: f64,
    /// Mid-round churn: Interrupt events during this round's spans.
    pub interrupted: usize,
    /// Mid-round churn: Resume events (paused work continuing).
    pub resumed: usize,
    /// Checkpoint churn: partial updates merged this round (fresh or
    /// late), each weighted by its completed-sample fraction.
    pub partial_merged: usize,
    /// Compute seconds lost to churn (aborts + partial-epoch remainders).
    pub wasted_compute_s: f64,
    /// Worker threads the sharded cohort merge replayed on (0 when
    /// nothing merged this round).
    pub merge_workers: usize,
    /// Busy fraction of the sharded merge's worker capacity. Wall-clock
    /// derived — reported for observability, never part of the
    /// deterministic trace.
    pub merge_utilization: f64,
}

impl Default for RoundOutcome {
    /// The "nothing happened yet" round: NaN losses (no cohort trained),
    /// zero counters.
    fn default() -> Self {
        RoundOutcome {
            mean_loss: f32::NAN,
            mean_acc: f32::NAN,
            participants: 0,
            fallback: 0,
            bytes_up: 0,
            bytes_down: 0,
            client_mem_bytes: 0,
            sim_time_s: 0.0,
            stragglers: 0,
            dropouts: 0,
            deferred: 0,
            late_merged: 0,
            late_dropped: 0,
            mean_staleness: 0.0,
            projected_merged: 0,
            projected_dropped_params: 0,
            transition_staleness: 0.0,
            interrupted: 0,
            resumed: 0,
            partial_merged: 0,
            wasted_compute_s: 0.0,
            merge_workers: 0,
            merge_utilization: 0.0,
        }
    }
}

/// One evaluation pass over the held-out test set.
#[derive(Debug, Clone, Copy)]
pub struct EvalResult {
    /// Mean per-sample test loss.
    pub loss: f32,
    /// Test accuracy in [0, 1].
    pub acc: f32,
}

/// Scale a client's merge weight by its checkpointed fraction (churn
/// partials), bumping the partial-merge counter. No fraction ⇒ the
/// weight passes through untouched, so churn-free rounds stay
/// bit-identical. Shared by the coordinator's train/distill/async paths
/// and the HeteroFL/DepthFL sliced merges.
pub(crate) fn partial_scaled(
    fractions: &HashMap<usize, f64>,
    cid: usize,
    weight: f64,
    partial_merged: &mut usize,
) -> f64 {
    match fractions.get(&cid) {
        Some(f) => {
            *partial_merged += 1;
            weight * f
        }
        None => weight,
    }
}

impl<'rt> ServerCtx<'rt> {
    /// One FL train round on `artifact` (tag = cfg.model_tag) with the given
    /// participating clients. `fallback_artifact` (e.g. `train_op_t{t}`)
    /// absorbs memory-constrained clients when provided (ProFL §4.1).
    pub fn run_train_round(
        &mut self,
        artifact: &str,
        fallback_artifact: Option<&str>,
        lr: f32,
        _stage: &str,
        _step: usize,
    ) -> Result<RoundOutcome> {
        let tag = self.cfg.model_tag.clone();
        let art = self.rt.load(&tag, artifact)?;
        let mem = art.meta.participation_mem();
        let t_dispatch = self.telemetry.is_some().then(std::time::Instant::now);
        let sel = self.sample_cohort(&mem);

        // --- fleet dispatch: virtual-time the memory-eligible cohort --------
        // Each trainer's timeline = availability-gated dispatch → download
        // (trainables, plus the frozen prefix when its cache is stale) →
        // local pass over its shard → upload. The round policy then picks
        // the aggregation cohort; the churn policy decides what an
        // offline flip mid-span does to it.
        let tr_bytes = art.meta.trainable_bytes();
        let fr_bytes = art.meta.frozen_bytes();
        let works: Vec<_> = sel
            .trainers
            .iter()
            .map(|&cid| {
                let stale = self.pool.client(cid).prefix_version != self.prefix_version;
                let down = tr_bytes + if stale { fr_bytes } else { 0 };
                self.client_work(cid, &mem, tr_bytes, down)
            })
            .collect();
        if let Some(t0) = t_dispatch {
            let round = self.round;
            let sim_s = self.sim_time_s;
            if let Some(tel) = self.telemetry.as_mut() {
                tel.span(
                    "round.dispatch",
                    round,
                    sim_s,
                    t0.elapsed().as_secs_f64(),
                    &[
                        ("artifact", Value::Str(artifact.to_string())),
                        ("trainers", Value::Num(sel.trainers.len() as f64)),
                        ("fallback_eligible", Value::Num(sel.fallback.len() as f64)),
                    ],
                );
            }
        }
        let plan = self.run_fleet(&works);

        // Aggregate in *selection* order, not upload-arrival order: float
        // accumulation is order-sensitive, and with the default
        // uniform/sync fleet this keeps FedAvg bit-identical to the
        // pre-fleet coordinator.
        let completers: Vec<usize> =
            sel.trainers.iter().copied().filter(|id| plan.completers.contains(id)).collect();
        let fractions: HashMap<usize, f64> = plan.partials.iter().copied().collect();

        let mut outcome = RoundOutcome {
            participants: completers.len(),
            client_mem_bytes: mem.bytes_at(self.cfg.memory.accounting_batch),
            sim_time_s: plan.duration_s(),
            stragglers: plan.stragglers.len(),
            dropouts: plan.dropouts.len(),
            deferred: plan.deferred.len(),
            interrupted: plan.interrupts,
            resumed: plan.resumes,
            wasted_compute_s: plan.wasted_compute_s,
            ..RoundOutcome::default()
        };

        // --- primary cohort ---------------------------------------------------
        let t_merge = self.telemetry.is_some().then(std::time::Instant::now);
        if let Some((_, max_staleness)) = self.async_params() {
            // Async: fresh finishers merge now; window-missers train and
            // buffer; earlier rounds' arrivals merge staleness-discounted.
            let deferred: Vec<usize> =
                sel.trainers.iter().copied().filter(|id| plan.deferred.contains(id)).collect();
            let late = self.take_late_arrivals(&plan, artifact, max_staleness, &mut outcome)?;
            let (loss, acc) = self.run_cohort_async(
                &tag, artifact, &completers, &deferred, &fractions, late, lr, true, &mut outcome,
            )?;
            outcome.mean_loss = loss;
            outcome.mean_acc = acc;
        } else if !completers.is_empty() {
            let (loss, acc) =
                self.train_cohort(&tag, artifact, &completers, &fractions, lr, &mut outcome)?;
            outcome.mean_loss = loss;
            outcome.mean_acc = acc;
        }
        if let Some(t0) = t_merge {
            let round = self.round;
            let sim_s = self.sim_time_s;
            if let Some(tel) = self.telemetry.as_mut() {
                tel.span(
                    "aggregate.merge",
                    round,
                    sim_s,
                    t0.elapsed().as_secs_f64(),
                    &[
                        ("merged", Value::Num(outcome.participants as f64)),
                        ("late_merged", Value::Num(outcome.late_merged as f64)),
                        ("late_dropped", Value::Num(outcome.late_dropped as f64)),
                        ("projected_merged", Value::Num(outcome.projected_merged as f64)),
                        ("partial_merged", Value::Num(outcome.partial_merged as f64)),
                        ("merge_workers", Value::Num(outcome.merge_workers as f64)),
                    ],
                );
            }
        }
        // Downloads shipped to policy-cut stragglers cost bandwidth even
        // though their updates never aggregate.
        self.account_lost_downloads(&plan, tr_bytes, fr_bytes, true, &mut outcome);

        // --- fallback cohort (output-layer-only training) -------------------
        // The op artifact is tiny (§4.1), so fallback clients are assumed to
        // fit inside the primary round window; they are not separately
        // policy-filtered. Over-select over-commits the *trainer* cohort
        // only: the fallback cohort is restricted to the first `per_round`
        // sampled clients (exactly the plain sample — the first k draws of
        // a k+extra Fisher-Yates sample are the k-sample), so fallback
        // participation and comm stay comparable across policies.
        let fallback: Vec<usize> = sel
            .availability
            .iter()
            .take(self.cfg.per_round)
            .map(|&(id, _)| id)
            .filter(|id| sel.fallback.contains(id))
            .collect();
        if let (Some(fb), false) = (fallback_artifact, fallback.is_empty()) {
            let mut fb_out = RoundOutcome::default();
            self.train_cohort(&tag, fb, &fallback, &HashMap::new(), lr, &mut fb_out)?;
            outcome.fallback = fallback.len();
            outcome.bytes_up += fb_out.bytes_up;
            outcome.bytes_down += fb_out.bytes_down;
        }

        self.round += 1;
        Ok(outcome)
    }

    /// Execute one client's local pass on `art` and return its updated
    /// trainable tensors (artifact order), scalar outputs, and sample
    /// weight. Shared by the sync, async, and distill paths.
    fn exec_client(
        &mut self,
        art: &LoadedArtifact,
        param_lits: &[xla::Literal],
        lr_lit: &xla::Literal,
        cid: usize,
        with_labels: bool,
    ) -> Result<(Vec<Vec<f32>>, Vec<f32>, f64)> {
        let scan = self.rt.manifest.scan_steps;
        let batch = self.rt.manifest.train_batch;
        let weight = {
            let data = &self.dataset;
            let client = self.pool.client_mut(cid);
            client.shard.fill_batches(data, scan, batch, &mut self.xs_buf, &mut self.ys_buf);
            client.shard.num_samples() as f64
        };
        let xs = literal_f32(&[scan, batch, 32, 32, 3], &self.xs_buf)?;
        let ys = if with_labels { Some(literal_i32(&[scan, batch], &self.ys_buf)?) } else { None };

        // Borrowed inputs: the shared parameter literals are not cloned
        // per client (L3 hot-path optimization, see EXPERIMENTS.md §Perf).
        let mut inputs: Vec<&xla::Literal> = Vec::with_capacity(param_lits.len() + 3);
        inputs.extend(param_lits.iter());
        inputs.push(&xs);
        if let Some(ys) = &ys {
            inputs.push(ys);
        }
        inputs.push(lr_lit);

        let outs = art.execute(&inputs)?;
        let (updated, scalars) = Runtime::unpack_train_outputs(&art.meta, outs)?;
        Ok((updated.into_iter().map(|(_, v)| v).collect(), scalars, weight))
    }

    /// Charge download bytes for dispatched clients whose updates never
    /// reached an aggregate: deadline/over-select stragglers and
    /// churn-aborted clients received the round artifact and trained (or
    /// started to), so the server's downlink was spent either way
    /// (otherwise straggler-cutting policies look artificially cheap next
    /// to sync/async). A client churn-aborted *mid-download* is charged
    /// only the fraction it actually fetched
    /// ([`RoundPlan::download_fraction`]); pausable downloads complete
    /// across resume windows and are charged exactly once at full size.
    /// Completers and async-deferred clients are charged on their own
    /// paths; dropouts vanish at the dispatch instant — before the
    /// download — and cost nothing.
    fn account_lost_downloads(
        &mut self,
        plan: &RoundPlan,
        tr_bytes: u64,
        fr_bytes: u64,
        with_prefix: bool,
        outcome: &mut RoundOutcome,
    ) {
        let mut charged: Vec<usize> = Vec::new();
        for ev in &plan.events {
            if let EventKind::Dispatch { client } = ev.kind {
                if plan.completers.contains(&client)
                    || plan.deferred.contains(&client)
                    || plan.dropouts.contains(&client)
                {
                    continue;
                }
                charged.push(client);
                self.account_lost_download(plan, client, tr_bytes, fr_bytes, with_prefix, outcome);
            }
        }
        // Async plans truncate events at the close instant, so a client
        // that dispatched *after* the close and then churn-aborted has no
        // Dispatch event above — but it did receive (part of) the
        // artifact.
        for &client in &plan.aborted {
            if !charged.contains(&client) {
                self.account_lost_download(plan, client, tr_bytes, fr_bytes, with_prefix, outcome);
            }
        }
    }

    /// Charge one lost client's download, scaled by the fraction it had
    /// actually fetched when churn cut it. At full fraction this is
    /// exactly the historical charge (prefix-cache bookkeeping included);
    /// a partial download charges `fraction × payload` and does *not*
    /// refresh the client's prefix cache — it never received the whole
    /// thing.
    fn account_lost_download(
        &mut self,
        plan: &RoundPlan,
        cid: usize,
        tr_bytes: u64,
        fr_bytes: u64,
        with_prefix: bool,
        outcome: &mut RoundOutcome,
    ) {
        let frac = plan.download_fraction(cid);
        if frac >= 1.0 {
            if with_prefix {
                self.account_comm(cid, tr_bytes, fr_bytes, false, outcome);
            } else {
                outcome.bytes_down += tr_bytes;
            }
            return;
        }
        let mut payload = tr_bytes;
        let prefix_version = self.prefix_version;
        // client_mut: materializes on a lazy fleet (the client may have
        // been evicted since dispatch).
        if with_prefix && self.pool.client_mut(cid).prefix_version != prefix_version {
            payload += fr_bytes;
        }
        outcome.bytes_down += (frac * payload as f64) as u64;
    }

    /// Comm accounting for one client's exchange this round: trainables
    /// travel down (and, when requested, up); the frozen prefix ships
    /// only while the client's cached copy is stale.
    fn account_comm(
        &mut self,
        cid: usize,
        tr_bytes: u64,
        fr_bytes: u64,
        upload_now: bool,
        outcome: &mut RoundOutcome,
    ) {
        if upload_now {
            outcome.bytes_up += tr_bytes;
        }
        outcome.bytes_down += tr_bytes;
        let prefix_version = self.prefix_version;
        let client = self.pool.client_mut(cid);
        if client.prefix_version != prefix_version {
            outcome.bytes_down += fr_bytes;
            client.prefix_version = prefix_version;
        }
    }

    /// Train one artifact over a cohort and FedAvg the result into the
    /// store (sync-family policies and the fallback cohort). Clients in
    /// `fractions` merged a churn-checkpointed *partial* update: their
    /// weight is scaled by the completed-sample fraction (the simulator
    /// proxy for an epoch-truncated local pass). A zero-weight cohort
    /// (every shard empty) skips aggregation entirely instead of
    /// NaN-corrupting the store.
    fn train_cohort(
        &mut self,
        tag: &str,
        artifact: &str,
        cohort: &[usize],
        fractions: &HashMap<usize, f64>,
        lr: f32,
        outcome: &mut RoundOutcome,
    ) -> Result<(f32, f32)> {
        if cohort.is_empty() {
            bail!("empty cohort for {artifact}");
        }
        let art = self.rt.load(tag, artifact)?;
        let scan = self.rt.manifest.scan_steps;
        let batch = self.rt.manifest.train_batch;

        // Parameter literals built once, shared by every client this round.
        let param_lits = self.rt.param_literals(&art.meta, &self.store)?;
        let lr_lit = xla::Literal::scalar(lr);

        let trainable: Vec<String> =
            art.meta.trainable_names().iter().map(|s| s.to_string()).collect();
        let mut agg = Aggregator::new(&trainable, &self.store)?;
        agg.set_merge_threads(self.engine.threads());
        let mut loss_sum = 0.0f64;
        let mut acc_sum = 0.0f64;

        let tr_bytes = art.meta.trainable_bytes();
        let fr_bytes = art.meta.frozen_bytes();

        for &cid in cohort {
            let (tensors, scalars, weight) =
                self.exec_client(&art, &param_lits, &lr_lit, cid, true)?;
            let weight = partial_scaled(fractions, cid, weight, &mut outcome.partial_merged);
            loss_sum += scalars[0] as f64 * weight;
            if scalars.len() > 1 {
                acc_sum += scalars[1] as f64 / (scan * batch) as f64 * weight;
            }
            // No clone: the PJRT output buffers move into the accumulator
            // and come back out through the update pool after the replay.
            agg.add_owned(tensors, weight);
            self.account_comm(cid, tr_bytes, fr_bytes, true, outcome);
        }

        let total_w = agg.total_weight();
        if total_w <= 0.0 {
            return Ok((f32::NAN, f32::NAN));
        }
        let stats = agg.finish_stats(&mut self.store, Some(&mut self.update_pool))?;
        outcome.merge_workers = stats.workers;
        outcome.merge_utilization = stats.utilization();
        Ok(((loss_sum / total_w) as f32, (acc_sum / total_w) as f32))
    }

    /// Async (FedBuff-style) cohort processing shared by train and
    /// distill rounds: merge `completers` fresh (staleness 0), train and
    /// buffer `deferred` (their uploads are in flight), merge `late`
    /// arrivals staleness-discounted — version-exact ones as-is,
    /// transition-crossers as suffix projections with the extra
    /// `projection_decay^transitions` factor. Clients in `fractions`
    /// checkpointed a churn partial: their weight is scaled by the
    /// completed fraction (fresh merges here; deferred ones buffer the
    /// scaled weight so the late merge inherits it). Returns the fresh
    /// cohort's mean (loss, acc); with `buffer_k = per_round` and no
    /// in-flight traffic the arithmetic is bit-identical to
    /// [`Self::train_cohort`].
    #[allow(clippy::too_many_arguments)]
    fn run_cohort_async(
        &mut self,
        tag: &str,
        artifact: &str,
        completers: &[usize],
        deferred: &[usize],
        fractions: &HashMap<usize, f64>,
        late: (Vec<(PendingUpdate, usize)>, Vec<ProjectedLate>),
        lr: f32,
        with_labels: bool,
        outcome: &mut RoundOutcome,
    ) -> Result<(f32, f32)> {
        let (late, projected) = late;
        let art = self.rt.load(tag, artifact)?;
        let scan = self.rt.manifest.scan_steps;
        let batch = self.rt.manifest.train_batch;
        let param_lits = self.rt.param_literals(&art.meta, &self.store)?;
        let lr_lit = xla::Literal::scalar(lr);
        let trainable: Vec<String> =
            art.meta.trainable_names().iter().map(|s| s.to_string()).collect();
        let alpha = self.cfg.fleet.staleness_alpha;
        let mut agg = BufferedAggregator::new(&trainable, &self.store, alpha)?;
        agg.set_merge_threads(self.engine.threads());
        let mut loss_sum = 0.0f64;
        let mut acc_sum = 0.0f64;
        let mut fresh_w = 0.0f64;
        let tr_bytes = art.meta.trainable_bytes();
        let fr_bytes = art.meta.frozen_bytes();

        // Fresh finishers (selection order, staleness 0 ⇒ undiscounted).
        for &cid in completers {
            let (tensors, scalars, weight) =
                self.exec_client(&art, &param_lits, &lr_lit, cid, with_labels)?;
            let weight = partial_scaled(fractions, cid, weight, &mut outcome.partial_merged);
            loss_sum += scalars[0] as f64 * weight;
            if with_labels && scalars.len() > 1 {
                acc_sum += scalars[1] as f64 / (scan * batch) as f64 * weight;
            }
            agg.add_owned(tensors, weight, 0);
            fresh_w += weight;
            // Train rounds do prefix-cache accounting; distill rounds ship
            // trainables only — exactly mirroring the sync paths, so the
            // degenerate async run stays byte-identical.
            if with_labels {
                self.account_comm(cid, tr_bytes, fr_bytes, true, outcome);
            } else {
                outcome.bytes_up += tr_bytes;
                outcome.bytes_down += tr_bytes;
            }
        }

        // Window-missers: they did receive this round's model and did
        // train — the update just hasn't arrived. Buffer it version-
        // stamped; the upload bytes are accounted when it lands.
        for &cid in deferred {
            let (tensors, _scalars, weight) =
                self.exec_client(&art, &param_lits, &lr_lit, cid, with_labels)?;
            if with_labels {
                self.account_comm(cid, tr_bytes, fr_bytes, false, outcome);
            } else {
                outcome.bytes_down += tr_bytes;
            }
            // A deferred churn partial buffers its scaled weight, so the
            // eventual late merge carries the right sample count.
            let (weight, partial) = match fractions.get(&cid) {
                Some(f) => (weight * f, true),
                None => (weight, false),
            };
            self.pending.insert(
                cid,
                PendingUpdate {
                    client: cid,
                    artifact: artifact.to_string(),
                    prefix_version: self.prefix_version,
                    dispatch_round: self.round,
                    weight,
                    partial,
                    tensors: Arc::new(tensors),
                    bytes_up: tr_bytes,
                },
            );
        }

        // Late arrivals from earlier rounds: staleness-discounted merge.
        let mut staleness_sum = 0usize;
        for (p, staleness) in late {
            outcome.bytes_up += p.bytes_up;
            outcome.late_merged += 1;
            if p.partial {
                outcome.partial_merged += 1;
            }
            staleness_sum += staleness;
            // The pending entry was already removed from the buffer, so
            // this Arc is (usually) the last handle: the merge takes it
            // without touching the tensor bytes, and `finish` recycles
            // the buffers into the update pool.
            agg.add_shared(p.tensors, p.weight, staleness);
        }
        if outcome.late_merged > 0 {
            outcome.mean_staleness = staleness_sum as f64 / outcome.late_merged as f64;
        }

        // Transition-crossing arrivals whose trainable suffix survived
        // projection: masked merges — the since-frozen tensors receive no
        // mass, and the weight compounds decay^transitions on top of the
        // staleness discount.
        let decay = self.projection.unwrap_or(1.0);
        let mut transitions_sum = 0u64;
        let n_projected = projected.len();
        for pr in projected {
            let extra = transition_decay(decay, pr.transitions);
            agg.add_projected_owned(pr.kept, pr.weight, pr.staleness, extra);
            outcome.bytes_up += pr.bytes_up;
            outcome.projected_merged += 1;
            outcome.projected_dropped_params += pr.dropped_params;
            if pr.partial {
                outcome.partial_merged += 1;
            }
            transitions_sum += pr.transitions;
        }
        if n_projected > 0 {
            outcome.transition_staleness = transitions_sum as f64 / n_projected as f64;
        }

        if !agg.has_weight() {
            // Nothing merged (or only zero-weight shards): leave the store
            // untouched.
            return Ok((f32::NAN, f32::NAN));
        }
        let stats = agg.finish_stats(&mut self.store, Some(&mut self.update_pool))?;
        outcome.merge_workers = stats.workers;
        outcome.merge_utilization = stats.utilization();
        let loss = if fresh_w > 0.0 { (loss_sum / fresh_w) as f32 } else { f32::NAN };
        let acc = if fresh_w > 0.0 { (acc_sum / fresh_w) as f32 } else { f32::NAN };
        Ok((loss, acc))
    }

    /// One federated distillation round (§3.2 Map): same cohort mechanics,
    /// MSE objective, updates only the surrogate parameters.
    pub fn run_distill_round(&mut self, artifact: &str, lr: f32) -> Result<RoundOutcome> {
        let tag = self.cfg.model_tag.clone();
        let art = self.rt.load(&tag, artifact)?;
        let mem = art.meta.participation_mem();
        let t_distill = self.telemetry.is_some().then(std::time::Instant::now);
        let sel = self.sample_cohort(&mem);
        let tr_bytes = art.meta.trainable_bytes();

        // Distillation rounds run under the same fleet policy as train
        // rounds (the Map stage costs virtual time too).
        let works: Vec<_> = sel
            .trainers
            .iter()
            .map(|&cid| self.client_work(cid, &mem, tr_bytes, tr_bytes))
            .collect();
        if let Some(t0) = t_distill {
            let round = self.round;
            let sim_s = self.sim_time_s;
            if let Some(tel) = self.telemetry.as_mut() {
                tel.span(
                    "round.distill",
                    round,
                    sim_s,
                    t0.elapsed().as_secs_f64(),
                    &[
                        ("artifact", Value::Str(artifact.to_string())),
                        ("trainers", Value::Num(sel.trainers.len() as f64)),
                    ],
                );
            }
        }
        let plan = self.run_fleet(&works);
        // Selection-order aggregation (see run_train_round).
        let completers: Vec<usize> =
            sel.trainers.iter().copied().filter(|id| plan.completers.contains(id)).collect();
        let fractions: HashMap<usize, f64> = plan.partials.iter().copied().collect();

        let mut outcome = RoundOutcome {
            participants: completers.len(),
            client_mem_bytes: mem.bytes_at(self.cfg.memory.accounting_batch),
            sim_time_s: plan.duration_s(),
            stragglers: plan.stragglers.len(),
            dropouts: plan.dropouts.len(),
            deferred: plan.deferred.len(),
            interrupted: plan.interrupts,
            resumed: plan.resumes,
            wasted_compute_s: plan.wasted_compute_s,
            ..RoundOutcome::default()
        };

        if let Some((_, max_staleness)) = self.async_params() {
            let deferred: Vec<usize> =
                sel.trainers.iter().copied().filter(|id| plan.deferred.contains(id)).collect();
            let late = self.take_late_arrivals(&plan, artifact, max_staleness, &mut outcome)?;
            let (loss, _) = self.run_cohort_async(
                &tag, artifact, &completers, &deferred, &fractions, late, lr, false, &mut outcome,
            )?;
            outcome.mean_loss = loss;
            self.account_lost_downloads(&plan, tr_bytes, 0, false, &mut outcome);
            self.round += 1;
            return Ok(outcome);
        }

        if completers.is_empty() {
            self.account_lost_downloads(&plan, tr_bytes, 0, false, &mut outcome);
            self.round += 1;
            return Ok(outcome);
        }

        let param_lits = self.rt.param_literals(&art.meta, &self.store)?;
        let lr_lit = xla::Literal::scalar(lr);
        let trainable: Vec<String> =
            art.meta.trainable_names().iter().map(|s| s.to_string()).collect();
        let mut agg = Aggregator::new(&trainable, &self.store)?;
        agg.set_merge_threads(self.engine.threads());
        let mut loss_sum = 0.0f64;

        for &cid in &completers {
            let (tensors, scalars, weight) =
                self.exec_client(&art, &param_lits, &lr_lit, cid, false)?;
            let weight = partial_scaled(&fractions, cid, weight, &mut outcome.partial_merged);
            loss_sum += scalars[0] as f64 * weight;
            agg.add_owned(tensors, weight);
            outcome.bytes_up += tr_bytes;
            outcome.bytes_down += tr_bytes;
        }
        let total_w = agg.total_weight();
        if total_w > 0.0 {
            let stats = agg.finish_stats(&mut self.store, Some(&mut self.update_pool))?;
            outcome.merge_workers = stats.workers;
            outcome.merge_utilization = stats.utilization();
            outcome.mean_loss = (loss_sum / total_w) as f32;
        }
        self.account_lost_downloads(&plan, tr_bytes, 0, false, &mut outcome);
        self.round += 1;
        Ok(outcome)
    }

    /// Evaluate an eval artifact over the balanced held-out test set.
    pub fn evaluate(&mut self, artifact: &str) -> Result<EvalResult> {
        let tag = self.cfg.model_tag.clone();
        self.evaluate_tag(&tag, artifact, None)
    }

    /// Evaluate against an arbitrary (tag, artifact) with an optional
    /// replacement store (HeteroFL/AllSmall variant evaluation).
    pub fn evaluate_tag(
        &mut self,
        tag: &str,
        artifact: &str,
        store: Option<&crate::store::ParamStore>,
    ) -> Result<EvalResult> {
        let art = self.rt.load(tag, artifact)?;
        let eval_batch = self.rt.manifest.eval_batch;
        let store = store.unwrap_or(&self.store);
        let param_lits = self.rt.param_literals(&art.meta, store)?;

        let mut total_correct = 0.0f64;
        let mut total_loss = 0.0f64;
        let mut n = 0usize;
        let (mut xs, mut ys) = (Vec::new(), Vec::new());
        for b in 0..TEST_BATCHES {
            self.dataset.test_batch(b * eval_batch, eval_batch, &mut xs, &mut ys);
            let x = literal_f32(&[eval_batch, 32, 32, 3], &xs)?;
            let y = literal_i32(&[eval_batch], &ys)?;
            let mut inputs: Vec<&xla::Literal> = Vec::with_capacity(param_lits.len() + 2);
            inputs.extend(param_lits.iter());
            inputs.push(&x);
            inputs.push(&y);
            let outs = art.execute(&inputs)?;
            total_loss += outs[0].to_vec::<f32>()?[0] as f64;
            total_correct += outs[1].to_vec::<f32>()?[0] as f64;
            n += eval_batch;
        }
        Ok(EvalResult { loss: (total_loss / n as f64) as f32, acc: (total_correct / n as f64) as f32 })
    }

    /// Push a metrics record for a completed round.
    #[allow(clippy::too_many_arguments)]
    pub fn record_round(
        &mut self,
        stage: &str,
        step: usize,
        out: &RoundOutcome,
        test_acc: f32,
        em: f64,
    ) {
        self.metrics.push(RoundRecord {
            round: self.round,
            stage: stage.to_string(),
            step,
            train_loss: out.mean_loss,
            train_acc: out.mean_acc,
            test_acc,
            effective_movement: em,
            participants: out.participants,
            fallback_participants: out.fallback,
            bytes_up: out.bytes_up,
            bytes_down: out.bytes_down,
            client_mem_bytes: out.client_mem_bytes,
            // Cumulative fleet clock: the ctx has already advanced past
            // this round's simulation when the record is pushed.
            sim_time_s: self.sim_time_s,
            stragglers: out.stragglers,
            dropouts: out.dropouts,
            late_merged: out.late_merged,
            late_dropped: out.late_dropped,
            mean_staleness: out.mean_staleness,
            projected_merged: out.projected_merged,
            projected_dropped_params: out.projected_dropped_params,
            transition_staleness: out.transition_staleness,
            interrupted: out.interrupted,
            resumed: out.resumed,
            partial_merged: out.partial_merged,
            wasted_compute_s: out.wasted_compute_s,
        });
        // Telemetry rollup for the finished round: per-round counters plus
        // lazy-pool cache gauges, all pure reads of already-computed state.
        if self.telemetry.is_some() {
            let round = self.round;
            let sim_s = self.sim_time_s;
            let pool = self.pool.stats();
            let attrs =
                [("stage", Value::Str(stage.to_string())), ("step", Value::Num(step as f64))];
            let counters: [(&str, f64); 11] = [
                ("round.participants", out.participants as f64),
                ("round.stragglers", out.stragglers as f64),
                ("round.dropouts", out.dropouts as f64),
                ("round.late_merged", out.late_merged as f64),
                ("round.late_dropped", out.late_dropped as f64),
                ("round.projected_merged", out.projected_merged as f64),
                ("round.projected_dropped_params", out.projected_dropped_params as f64),
                ("round.partial_merged", out.partial_merged as f64),
                ("round.bytes_up", out.bytes_up as f64),
                ("round.bytes_down", out.bytes_down as f64),
                ("round.wasted_compute_s", out.wasted_compute_s),
            ];
            let gauges: [(&str, f64); 11] = [
                ("round.mean_staleness", out.mean_staleness),
                ("round.client_mem_bytes", out.client_mem_bytes as f64),
                ("pool.cache_hits", pool.hits as f64),
                ("pool.cache_misses", pool.misses as f64),
                ("pool.cache_evictions", pool.evictions as f64),
                ("pool.materialized", pool.materialized as f64),
                ("pool.peak_materialized", pool.peak_materialized as f64),
                // Sharded-merge health: busy fraction of the replay
                // workers plus the update-buffer pool's recycle counters.
                ("fleet.merge_utilization", out.merge_utilization),
                ("pool.update_hits", self.update_pool.hits() as f64),
                ("pool.update_misses", self.update_pool.misses() as f64),
                ("pool.update_free", self.update_pool.free_len() as f64),
            ];
            if let Some(tel) = self.telemetry.as_mut() {
                for (name, v) in counters {
                    tel.counter(name, round, sim_s, v, &attrs);
                }
                for (name, v) in gauges {
                    tel.gauge(name, round, sim_s, v, &attrs);
                }
            }
        }
    }
}
