//! Stale-update projection across freeze/step transitions (pure core).
//!
//! Any progressive [`crate::strategy::MemoryStrategy`] (ProFL's
//! shrink→grow, layer freezing, elastic windows — see
//! `docs/STRATEGIES.md`) changes the trained block-prefix *while
//! async uploads are in flight*: a straggler dispatched in step `t` can
//! arrive after the server moved to step `t+1`, where its artifact and
//! frozen-prefix version no longer match. Historically such updates were
//! dropped wholesale (`late_dropped`) — wasting exactly the device work
//! the paper's memory-wall design tries to preserve. Progressive-freezing
//! follow-ups (SmartFreeze, NeuLite) observe that stale gradients remain
//! useful on the *still-trainable suffix*; this module implements that
//! recovery:
//!
//! 1. intersect the update's trained tensor set with the server's current
//!    trainable layout ([`project_tensors`]) — surviving tensors are
//!    remapped to their new positions, since-frozen tensors are discarded
//!    (their scalar count surfaces as `projected_dropped_params`);
//! 2. merge the surviving suffix through the masked aggregator path with
//!    an extra [`crate::aggregate::transition_decay`] factor of
//!    `decay^transitions` compounding onto the ordinary FedBuff staleness
//!    discount.
//!
//! Projection only engages when the update actually *crossed* a
//! transition (prefix-version distance ≥ 1). A mismatch at the same
//! prefix version — a train-round update landing in a same-step
//! distillation round, say — keeps the historical drop: recovering
//! freeze-transition losses is the whole point, and nothing else may
//! merge undecayed across artifacts.
//!
//! Everything here is pure (names, lengths, tensors — no runtime, no
//! XLA), so the decision layer is unit- and golden-testable without
//! compiled artifacts: `rust/tests/golden_projection.rs` pins the full
//! decision trace of an async×projection scenario bit for bit.
//!
//! The coordinator enables this path only under `--stale-projection on`;
//! the default (`off`) keeps the historical drop behaviour bit for bit
//! (see `docs/SIMULATION.md` for the degeneracy contract).

use crate::manifest::Artifact;
use std::sync::Arc;

/// Layout of one artifact's trainable tensors: ordered names plus flat
/// element counts, the contract a [`crate::coordinator::PendingUpdate`]'s
/// positional tensor list is interpreted against.
#[derive(Debug, Clone, Default, PartialEq, Eq)]
pub struct TrainableLayout {
    /// Trainable parameter names, in the artifact's positional order.
    pub names: Vec<String>,
    /// Flat element count of each tensor, parallel to `names`.
    pub lens: Vec<usize>,
}

impl TrainableLayout {
    /// Build a layout from explicit `(name, len)` pairs (tests and the
    /// golden harness).
    pub fn new(pairs: &[(&str, usize)]) -> Self {
        TrainableLayout {
            names: pairs.iter().map(|(n, _)| n.to_string()).collect(),
            lens: pairs.iter().map(|&(_, l)| l).collect(),
        }
    }

    /// The trainable layout of a manifest artifact (name order and flat
    /// lengths of its `role == "trainable"` inputs).
    pub fn of_artifact(a: &Artifact) -> Self {
        let mut names = Vec::new();
        let mut lens = Vec::new();
        for e in &a.inputs {
            if e.role == "trainable" {
                names.push(e.name.clone());
                lens.push(e.shape.iter().product());
            }
        }
        TrainableLayout { names, lens }
    }

    /// Number of trainable tensors in the layout.
    pub fn len(&self) -> usize {
        self.names.len()
    }

    /// Whether the layout has no trainable tensors.
    pub fn is_empty(&self) -> bool {
        self.names.is_empty()
    }
}

/// Project `tensors` (positional in `old` layout order) onto the `new`
/// layout: tensors whose parameter is still trainable (same name, same
/// flat length) are remapped to `(new index, tensor)` pairs; tensors of
/// since-frozen or re-shaped parameters are discarded and their total
/// scalar count returned as the second element.
pub fn project_tensors(
    old: &TrainableLayout,
    new: &TrainableLayout,
    tensors: Vec<Vec<f32>>,
) -> (Vec<(usize, Vec<f32>)>, u64) {
    debug_assert_eq!(old.names.len(), tensors.len(), "update/layout arity mismatch");
    let mut kept = Vec::new();
    let mut dropped = 0u64;
    for (name, t) in old.names.iter().zip(tensors) {
        match new.names.iter().position(|n| n == name) {
            Some(i) if new.lens[i] == t.len() => kept.push((i, t)),
            _ => dropped += t.len() as u64,
        }
    }
    (kept, dropped)
}

/// The server's merge context when a buffered stale update arrives.
#[derive(Debug, Clone, Copy)]
pub struct MergeContext<'a> {
    /// Artifact the current round trains.
    pub artifact: &'a str,
    /// Current frozen-prefix version.
    pub prefix_version: u64,
    /// Current server round index (staleness = round − dispatch round).
    pub round: usize,
    /// Updates older than this many rounds are dropped outright.
    pub max_staleness: usize,
    /// Current trainable layout when stale projection is enabled; `None`
    /// keeps the historical drop-on-mismatch behaviour bit for bit.
    pub projection: Option<&'a TrainableLayout>,
}

/// What the server decided to do with one arriving stale update.
#[derive(Debug, Clone, PartialEq)]
pub enum StaleDecision {
    /// Version-exact (same artifact, same prefix version, within the
    /// staleness window): merge as-is — the tensors ride back untouched
    /// (the same shared handle the pending buffer holds: no copy).
    Exact {
        /// The update's tensors, returned to the caller unchanged.
        tensors: Arc<Vec<Vec<f32>>>,
        /// Rounds elapsed since dispatch.
        staleness: usize,
    },
    /// The update crossed a freeze/step transition but part of it still
    /// lands on the trainable suffix: merge the projection.
    Projected {
        /// Surviving tensors as (current-layout index, tensor) pairs.
        kept: Vec<(usize, Vec<f32>)>,
        /// Scalars discarded with the since-frozen tensors.
        dropped_params: u64,
        /// Rounds elapsed since dispatch.
        staleness: usize,
        /// Freeze/step transitions crossed while in flight.
        transitions: u64,
    },
    /// Too stale, projection disabled, or nothing survives the
    /// intersection: drop the update (the upload still happened — the
    /// caller charges its bytes and records the discard).
    Dropped,
}

/// Classify one buffered stale update against the current merge context.
/// `old_layout` lazily resolves the trainable layout of the artifact the
/// update was trained against — it is only invoked when a projection is
/// actually attempted (version-exact and dropped updates never pay for
/// it), and returning `None` forces a drop. Pure: the coordinator and
/// the artifact-free golden harness share this exact decision procedure.
///
/// The tensors arrive as the pending buffer's shared handle: the exact
/// path hands the same handle back (refcount bump, no copy), and the
/// projection path unwraps it — cloning only if someone else still holds
/// a reference, which never happens on the coordinator path (the update
/// was just removed from the pending map).
pub fn classify_stale(
    ctx: &MergeContext<'_>,
    update_artifact: &str,
    update_prefix: u64,
    dispatch_round: usize,
    tensors: Arc<Vec<Vec<f32>>>,
    old_layout: impl FnOnce() -> Option<TrainableLayout>,
) -> StaleDecision {
    let staleness = ctx.round.saturating_sub(dispatch_round);
    if staleness > ctx.max_staleness {
        return StaleDecision::Dropped;
    }
    if update_artifact == ctx.artifact && update_prefix == ctx.prefix_version {
        return StaleDecision::Exact { tensors, staleness };
    }
    let Some(new_layout) = ctx.projection else {
        return StaleDecision::Dropped;
    };
    let transitions = ctx.prefix_version.saturating_sub(update_prefix);
    if transitions == 0 {
        // A mismatch with *no* crossed transition (e.g. a train-round
        // update landing in a same-step distillation round): projection
        // exists to recover work lost to freezing, so everything else
        // keeps the historical drop — and an undecayed cross-artifact
        // merge can never sneak in.
        return StaleDecision::Dropped;
    }
    let Some(old) = old_layout() else {
        return StaleDecision::Dropped;
    };
    let tensors = Arc::try_unwrap(tensors).unwrap_or_else(|a| (*a).clone());
    let (kept, dropped_params) = project_tensors(&old, new_layout, tensors);
    if kept.is_empty() {
        return StaleDecision::Dropped;
    }
    StaleDecision::Projected { kept, dropped_params, staleness, transitions }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn t1() -> TrainableLayout {
        // ProFL-grow-shaped step 1: block 1 + surrogate tail + op linear.
        TrainableLayout::new(&[("b1/w", 8), ("s2/w", 4), ("s3/w", 4), ("op/fc/w", 2)])
    }

    fn t2() -> TrainableLayout {
        TrainableLayout::new(&[("b2/w", 8), ("s3/w", 4), ("op/fc/w", 2)])
    }

    fn fill(layout: &TrainableLayout, v: f32) -> Vec<Vec<f32>> {
        layout.lens.iter().map(|&l| vec![v; l]).collect()
    }

    #[test]
    fn projection_keeps_suffix_and_counts_frozen_drops() {
        let (kept, dropped) = project_tensors(&t1(), &t2(), fill(&t1(), 2.0));
        // s3/w lands at new index 1, op/fc/w at 2; b1/w + s2/w are gone.
        assert_eq!(kept.len(), 2);
        assert_eq!(kept[0].0, 1);
        assert_eq!(kept[0].1, vec![2.0; 4]);
        assert_eq!(kept[1].0, 2);
        assert_eq!(kept[1].1, vec![2.0; 2]);
        assert_eq!(dropped, 8 + 4, "b1/w and s2/w scalars discarded");
    }

    #[test]
    fn projection_identity_on_same_layout() {
        let (kept, dropped) = project_tensors(&t2(), &t2(), fill(&t2(), 1.5));
        assert_eq!(dropped, 0);
        assert_eq!(kept.len(), t2().len());
        for (i, (idx, t)) in kept.iter().enumerate() {
            assert_eq!(*idx, i, "identity remap");
            assert_eq!(t.len(), t2().lens[i]);
        }
    }

    #[test]
    fn projection_drops_reshaped_parameters() {
        // Same name, different length (a remapped block): not mergeable.
        let old = TrainableLayout::new(&[("op/fc/w", 2)]);
        let new = TrainableLayout::new(&[("op/fc/w", 6)]);
        let (kept, dropped) = project_tensors(&old, &new, vec![vec![1.0, 1.0]]);
        assert!(kept.is_empty());
        assert_eq!(dropped, 2);
    }

    #[test]
    fn classify_exact_inside_window() {
        let new = t2();
        let ctx = MergeContext {
            artifact: "train_t2",
            prefix_version: 5,
            round: 9,
            max_staleness: 8,
            projection: Some(&new),
        };
        let d = classify_stale(&ctx, "train_t2", 5, 7, Arc::new(fill(&t2(), 1.0)), || {
            panic!("exact classification must not resolve the old layout")
        });
        match d {
            StaleDecision::Exact { staleness, tensors } => {
                assert_eq!(staleness, 2);
                assert_eq!(tensors.len(), t2().len(), "tensors ride back untouched");
            }
            other => panic!("expected Exact, got {other:?}"),
        }
    }

    #[test]
    fn classify_projects_across_transitions() {
        let new = t2();
        let ctx = MergeContext {
            artifact: "train_t2",
            prefix_version: 6,
            round: 10,
            max_staleness: 8,
            projection: Some(&new),
        };
        let old = t1();
        let d =
            classify_stale(&ctx, "train_t1", 5, 8, Arc::new(fill(&old, 3.0)), || Some(old.clone()));
        match d {
            StaleDecision::Projected { kept, dropped_params, staleness, transitions } => {
                assert_eq!(kept.len(), 2);
                assert_eq!(dropped_params, 12);
                assert_eq!(staleness, 2);
                assert_eq!(transitions, 1);
            }
            other => panic!("expected Projected, got {other:?}"),
        }
    }

    #[test]
    fn classify_drops_when_disabled_stale_disjoint_or_uncrossed() {
        let new = t2();
        let old = t1();
        // Projection disabled: mismatch drops, exactly the old behaviour.
        let off = MergeContext {
            artifact: "train_t2",
            prefix_version: 6,
            round: 10,
            max_staleness: 8,
            projection: None,
        };
        let d =
            classify_stale(&off, "train_t1", 5, 8, Arc::new(fill(&old, 1.0)), || Some(old.clone()));
        assert_eq!(d, StaleDecision::Dropped);

        // Beyond max_staleness: dropped even with projection on.
        let on = MergeContext { projection: Some(&new), ..off };
        let d =
            classify_stale(&on, "train_t1", 5, 0, Arc::new(fill(&old, 1.0)), || Some(old.clone()));
        assert_eq!(d, StaleDecision::Dropped, "staleness cap applies first");

        // Artifact mismatch at the *same* prefix version (e.g. a train
        // update landing in a same-step distill round): no transition
        // was crossed, so the historical drop stands — projection never
        // produces an undecayed cross-artifact merge.
        let d = classify_stale(&on, "train_t1", 6, 9, Arc::new(fill(&old, 1.0)), || {
            panic!("uncrossed mismatch must not resolve the old layout")
        });
        assert_eq!(d, StaleDecision::Dropped, "zero crossed transitions is a plain drop");

        // Disjoint layouts (train vs distill surrogate): nothing survives.
        let distill = TrainableLayout::new(&[("s2/conv/w", 16)]);
        let d = classify_stale(&on, "distill_t2", 5, 9, Arc::new(vec![vec![0.0; 16]]), || {
            Some(distill.clone())
        });
        assert_eq!(d, StaleDecision::Dropped, "empty intersection is a plain drop");

        // Unresolvable old layout: drop.
        let d = classify_stale(&on, "train_t1", 5, 9, Arc::new(fill(&old, 1.0)), || None);
        assert_eq!(d, StaleDecision::Dropped);
    }

    #[test]
    fn frozen_blocks_never_receive_mass() {
        // Property half of the acceptance list: whatever survives a
        // projection indexes only still-trainable tensors — no kept pair
        // ever points at a name absent from the new layout.
        let old = t1();
        let new = t2();
        let (kept, _) = project_tensors(&old, &new, fill(&old, 1.0));
        for (idx, _) in &kept {
            let name = &new.names[*idx];
            assert!(old.names.contains(name), "kept tensor must come from the update");
            assert!(new.names.contains(name), "kept tensor must be trainable now");
            assert_ne!(name, "b1/w", "frozen block leaked through the projection");
        }
    }
}
