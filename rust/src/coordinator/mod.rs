//! The federated coordinator (L3) — round execution, aggregation, eval.
//!
//! `ServerCtx` owns the global parameter store, the client pool, the PJRT
//! runtime and the metrics sink. One `run_train_round` is the paper's
//! §3.1 round: (1) pick the round's sub-model artifact, (2) sample clients
//! and filter by memory, (3) ship parameters (comm-accounted), (4) each
//! client runs the AOT train step on its local batches, (5) weighted
//! FedAvg (Eq. 1) back into the store.
//!
//! The progressive schedule itself (shrink → grow, freezing) lives in
//! `methods::profl`; baselines drive the same primitives.

pub mod round;

use crate::clients::ClientPool;
use crate::config::RunConfig;
use crate::data::SyntheticDataset;
use crate::manifest::ModelEntry;
use crate::metrics::MetricsSink;
use crate::runtime::Runtime;
use crate::store::ParamStore;
use anyhow::Result;

pub use round::{EvalResult, RoundOutcome};

/// Test-set size = 8 eval batches (balanced classes).
pub const TEST_BATCHES: usize = 8;

pub struct ServerCtx<'rt> {
    pub rt: &'rt Runtime,
    pub cfg: RunConfig,
    pub store: ParamStore,
    pub pool: ClientPool,
    pub dataset: SyntheticDataset,
    pub metrics: MetricsSink,
    pub round: usize,
    /// Version stamp of the frozen prefix currently in the store; clients
    /// cache the prefix and only re-download when this changes.
    pub prefix_version: u64,
    /// Scratch buffers reused across rounds (no allocation on the hot path).
    pub(crate) xs_buf: Vec<f32>,
    pub(crate) ys_buf: Vec<i32>,
}

impl<'rt> ServerCtx<'rt> {
    pub fn new(rt: &'rt Runtime, cfg: RunConfig) -> Result<Self> {
        let model = rt.model(&cfg.model_tag)?;
        let dataset = SyntheticDataset::new(model.num_classes, cfg.seed ^ 0xda7a);
        let pool = ClientPool::build(
            cfg.num_clients,
            cfg.total_samples,
            &dataset,
            cfg.partition(),
            cfg.memory.into(),
            cfg.seed,
        );
        let store = ParamStore::init(&model.params, cfg.seed ^ 0x1417);
        Ok(ServerCtx {
            rt,
            cfg,
            store,
            pool,
            dataset,
            metrics: MetricsSink::new(),
            round: 0,
            prefix_version: 0,
            xs_buf: Vec::new(),
            ys_buf: Vec::new(),
        })
    }

    pub fn model(&self) -> Result<&ModelEntry> {
        self.rt.model(&self.cfg.model_tag)
    }

    /// Initialize an auxiliary store for a width-ratio variant tag
    /// (HeteroFL/AllSmall local models). Seeded identically so slices of
    /// the full init match the variant's init distribution family.
    pub fn variant_store(&self, tag: &str) -> Result<ParamStore> {
        let model = self.rt.model(tag)?;
        Ok(ParamStore::init(&model.params, self.cfg.seed ^ 0x1417))
    }

    /// Bump the frozen-prefix version (called at step/stage transitions);
    /// forces prefix re-download for every client on next contact.
    pub fn bump_prefix_version(&mut self) {
        self.prefix_version += 1;
    }
}
