//! The federated coordinator (L3) — round execution, aggregation, eval.
//!
//! `ServerCtx` owns the global parameter store, the client pool, the PJRT
//! runtime, the fleet simulator state, and the metrics sink. One
//! `run_train_round` is the paper's §3.1 round: (1) pick the round's
//! sub-model artifact, (2) sample clients and filter by memory, (3)
//! dispatch the cohort as fleet events (download → local train → upload
//! on each device's virtual timeline), (4) the round policy decides who
//! aggregates (sync / deadline / over-select / async), (5) weighted
//! FedAvg (Eq. 1) back into the store, with comm accounting and the
//! virtual clock advanced to the aggregation instant.
//!
//! Under the `async` policy rounds are no longer self-contained: uploads
//! that miss the `buffer_k` window persist in the [`FleetEngine`]'s
//! in-flight queue, and the matching *update tensors* persist here in
//! the `ServerCtx` pending buffer — version-stamped with the dispatch round,
//! artifact, and frozen-prefix version. When the fleet reports a late
//! arrival, the pending update merges with a staleness-discounted weight
//! unless it is older than `max_staleness` rounds or was trained against
//! a block that has since been frozen or remapped (artifact or prefix
//! version mismatch). A mismatched update is dropped by default; with
//! `--stale-projection on` it is instead *projected* onto the
//! still-trained suffix (see [`projection`]) and merged with an extra
//! `--projection-decay`^transitions weight factor — recovering the
//! device work a freeze transition would otherwise waste.
//!
//! The schedule itself — what is trainable each round and when it
//! advances — lives behind the [`crate::strategy::MemoryStrategy`]
//! trait (`strategy::` owns shrink→grow, layer freezing, and elastic
//! windows; `methods::profl` is a thin adapter); baselines drive the
//! same primitives directly. Every
//! [`ServerCtx::bump_prefix_version`] is recorded in a
//! [`TransitionLog`], so transition-staleness stays auditable per run.

pub mod projection;
pub mod round;

use crate::clients::{ClientPool, Selection};
use crate::config::RunConfig;
use crate::data::SyntheticDataset;
use crate::fleet::{ChurnPolicy, ClientWork, FleetEngine, RoundPlan, RoundPolicy};
use crate::freezing::TransitionLog;
use crate::json::Value;
use crate::manifest::{MemCoeffs, ModelEntry};
use crate::metrics::MetricsSink;
use crate::rng::Rng;
use crate::runtime::Runtime;
use crate::store::ParamStore;
use crate::telemetry::Appender;
use crate::aggregate::TensorPool;
use anyhow::Result;
use std::collections::HashMap;
use std::path::Path;
use std::sync::Arc;

use projection::{classify_stale, MergeContext, StaleDecision, TrainableLayout};

pub use round::{EvalResult, RoundOutcome};

/// Test-set size = 8 eval batches (balanced classes).
pub const TEST_BATCHES: usize = 8;

/// A straggler's trained-but-not-yet-merged update, buffered server-side
/// while its upload is in flight across rounds (async policy). The
/// version stamps decide mergeability on arrival. `Clone` exists for the
/// checkpoint writer, which snapshots the buffer without draining it.
#[derive(Clone)]
pub struct PendingUpdate {
    /// Owning client's pool index.
    pub client: usize,
    /// Artifact the client trained (a late update only merges into the
    /// same artifact — a frozen/remapped block drops it).
    pub artifact: String,
    /// Frozen-prefix version at dispatch; a bump invalidates the update.
    pub prefix_version: u64,
    /// Server round index at dispatch (staleness = arrival − dispatch).
    pub dispatch_round: usize,
    /// Sample weight the update carries: shard size, scaled down by the
    /// checkpointed fraction for churn partials.
    pub weight: f64,
    /// Whether this is a checkpoint partial (metrics: `partial_merged`).
    pub partial: bool,
    /// Updated trainable tensors, in the artifact's trainable order.
    /// Shared (`Arc`) so the checkpoint writer's snapshot, the merge
    /// path, and this buffer all reference one allocation — cloning a
    /// `PendingUpdate` bumps a refcount instead of copying tensor data.
    pub tensors: Arc<Vec<Vec<f32>>>,
    /// Upload bytes accounted when the update finally lands.
    pub bytes_up: u64,
}

/// A stale update that crossed ≥ 1 freeze/step transition and survived
/// projection onto the still-trained suffix: what `run_cohort_async`
/// feeds `BufferedAggregator::add_projected`.
pub(crate) struct ProjectedLate {
    /// Surviving tensors as (current-trainable-list index, tensor) pairs.
    pub kept: Vec<(usize, Vec<f32>)>,
    /// Scalars discarded with the since-frozen tensors
    /// (`RoundRecord::projected_dropped_params`).
    pub dropped_params: u64,
    /// Rounds elapsed since dispatch (staleness discount input).
    pub staleness: usize,
    /// Freeze/step transitions crossed while in flight (decay exponent).
    pub transitions: u64,
    /// Sample weight the update carries (pre-discount).
    pub weight: f64,
    /// Whether the update is a churn-checkpointed partial.
    pub partial: bool,
    /// Upload bytes charged when the update lands.
    pub bytes_up: u64,
}

/// The coordinator: global state + round primitives every method drives.
pub struct ServerCtx<'rt> {
    /// PJRT runtime (artifact loading/execution).
    pub rt: &'rt Runtime,
    /// The run's resolved configuration.
    pub cfg: RunConfig,
    /// Global model parameters.
    pub store: ParamStore,
    /// The device fleet.
    pub pool: ClientPool,
    /// Synthetic dataset shared by every client shard.
    pub dataset: SyntheticDataset,
    /// Per-round metrics accumulator.
    pub metrics: MetricsSink,
    /// Server round counter (incremented after every train/distill round).
    pub round: usize,
    /// Resolved round policy (from `cfg.fleet.round_policy`).
    pub policy: RoundPolicy,
    /// Resolved mid-round churn policy (from `cfg.fleet.churn_policy`).
    pub churn: ChurnPolicy,
    /// Virtual fleet clock: seconds of simulated wall time since run
    /// start, advanced by each round's event simulation.
    pub sim_time_s: f64,
    /// Version stamp of the frozen prefix currently in the store; clients
    /// cache the prefix and only re-download when this changes.
    pub prefix_version: u64,
    /// Stale-update projection across freeze transitions: `Some(decay)`
    /// when `--stale-projection on` (decay compounds per crossed
    /// transition), `None` for the historical drop behaviour.
    pub projection: Option<f64>,
    /// Append-only history of freeze/step transitions (every
    /// [`Self::bump_prefix_version`]), exported into `RunSummary`.
    pub(crate) transitions: TransitionLog,
    /// Round-spanning fleet state (async in-flight uploads).
    pub engine: FleetEngine,
    /// Server-side buffer of straggler updates whose uploads are still in
    /// flight (async policy), keyed by client id.
    pub(crate) pending: HashMap<usize, PendingUpdate>,
    /// Dedicated stream for fleet stochastics (dropout draws), forked off
    /// the run seed so event traces are reproducible.
    pub(crate) fleet_rng: Rng,
    /// Scratch buffers reused across rounds (no allocation on the hot path).
    pub(crate) xs_buf: Vec<f32>,
    pub(crate) ys_buf: Vec<i32>,
    /// Recycled update-tensor buffers: the aggregators' deferred ops are
    /// released back here at `finish`, so steady-state rounds reuse the
    /// same allocations (the `RoundScratch` discipline, applied to the
    /// merge path; gauges `pool.update_*` when telemetry is on).
    pub(crate) update_pool: TensorPool,
    /// Structured-telemetry JSONL stream (see [`crate::telemetry`]):
    /// `Some` only when `cfg.telemetry_jsonl` is set. Every hook in the
    /// round loop is gated on this option and only *reads* simulator
    /// state, so an unset stream is bit-for-bit inert.
    pub(crate) telemetry: Option<Appender>,
}

impl<'rt> ServerCtx<'rt> {
    /// Build a coordinator: resolve the fleet/policy config, construct
    /// the pool, and seed-initialize the global store.
    pub fn new(rt: &'rt Runtime, cfg: RunConfig) -> Result<Self> {
        let model = rt.model(&cfg.model_tag)?;
        let dataset = SyntheticDataset::new(model.num_classes, cfg.seed ^ 0xda7a);
        let fleet_profile = cfg.fleet_profile()?;
        let policy = cfg.round_policy()?;
        let churn = cfg.churn_policy()?;
        let projection = cfg.stale_projection()?;
        let pool = if cfg.fleet.lazy_pool {
            // Lazy fleets are bit-identical to eager ones; the resident
            // cap just needs headroom over everything one round touches
            // (cohort + over-selection + fallback + in-flight backlog).
            let cap = (cfg.per_round + cfg.fleet.over_select_extra).saturating_mul(8).max(256);
            ClientPool::build_lazy(
                cfg.num_clients,
                cfg.total_samples,
                &dataset,
                cfg.partition(),
                cfg.memory.into(),
                &fleet_profile,
                cfg.seed,
                cap,
            )
        } else {
            ClientPool::build(
                cfg.num_clients,
                cfg.total_samples,
                &dataset,
                cfg.partition(),
                cfg.memory.into(),
                &fleet_profile,
                cfg.seed,
            )
        };
        let store = ParamStore::init(&model.params, cfg.seed ^ 0x1417);
        let fleet_rng = Rng::new(cfg.seed ^ 0xf1ee_7c10);
        // Resolved by fleet_profile() above to be >= 1; any count is
        // bit-identical (wall-clock knob only).
        let threads = cfg.fleet.threads;
        let telemetry = match cfg.telemetry_jsonl.as_deref() {
            Some(path) => {
                // --telemetry-max-mb caps each stream segment; rotation
                // renames full segments to `<stem>.N.jsonl` (week-long
                // sweeps; see docs/OBSERVABILITY.md). Hash-neutral.
                let cap = cfg.telemetry_max_mb.map(|mb| mb.saturating_mul(1024 * 1024));
                Some(Appender::create_with_cap(Path::new(path), cap)?)
            }
            None => None,
        };
        // Free-list cap: a cohort's worth of update buffers (plus async
        // headroom) is the steady-state working set; anything beyond is
        // a burst that should be returned to the allocator.
        let update_pool = TensorPool::new((cfg.per_round + cfg.fleet.over_select_extra) * 2 + 8);
        Ok(ServerCtx {
            rt,
            cfg,
            store,
            pool,
            dataset,
            metrics: MetricsSink::new(),
            round: 0,
            policy,
            churn,
            sim_time_s: 0.0,
            prefix_version: 0,
            projection,
            transitions: TransitionLog::new(),
            engine: FleetEngine::with_threads(threads),
            pending: HashMap::new(),
            fleet_rng,
            xs_buf: Vec::new(),
            ys_buf: Vec::new(),
            update_pool,
            telemetry,
        })
    }

    /// The telemetry stream, when `--telemetry-jsonl` armed one. Exposed
    /// so method drivers (and tests) can emit their own spans next to the
    /// coordinator's.
    pub fn telemetry_mut(&mut self) -> Option<&mut Appender> {
        self.telemetry.as_mut()
    }

    /// Flush the telemetry stream (no-op when telemetry is off). Called
    /// by the harness at run end so the line count in the manifest sees
    /// every event.
    pub fn flush_telemetry(&mut self) {
        if let Some(tel) = self.telemetry.as_mut() {
            tel.flush();
        }
    }

    /// The run's model entry in the manifest.
    pub fn model(&self) -> Result<&ModelEntry> {
        self.rt.model(&self.cfg.model_tag)
    }

    /// Initialize an auxiliary store for a width-ratio variant tag
    /// (HeteroFL/AllSmall local models). Seeded identically so slices of
    /// the full init match the variant's init distribution family.
    pub fn variant_store(&self, tag: &str) -> Result<ParamStore> {
        let model = self.rt.model(tag)?;
        Ok(ParamStore::init(&model.params, self.cfg.seed ^ 0x1417))
    }

    /// Bump the frozen-prefix version (called at step/stage transitions);
    /// forces prefix re-download for every client on next contact and
    /// invalidates in-flight updates trained against the old prefix
    /// (unless stale projection recovers their trainable suffix). Every
    /// bump is recorded in the [`TransitionLog`] so transition-staleness
    /// is computable for any in-flight update.
    pub fn bump_prefix_version(&mut self) {
        self.prefix_version += 1;
        self.transitions.record(self.prefix_version, self.round, self.sim_time_s);
    }

    /// The run's freeze/step transition history (oldest first).
    pub fn transition_log(&self) -> &TransitionLog {
        &self.transitions
    }

    /// How many clients to sample for a round: `per_round`, plus the
    /// over-commitment margin under the over-select policy.
    pub fn sample_size(&self) -> usize {
        match self.policy {
            RoundPolicy::OverSelect { extra } => self.cfg.per_round + extra,
            _ => self.cfg.per_round,
        }
    }

    /// `(buffer_k, max_staleness)` when running under the async policy.
    pub fn async_params(&self) -> Option<(usize, usize)> {
        match self.policy {
            RoundPolicy::Async { buffer_k, max_staleness } => Some((buffer_k, max_staleness)),
            _ => None,
        }
    }

    /// Precompute one cohort member's round timing from its device
    /// profile: availability-gated dispatch, artifact download, local
    /// training (shard size × FLOPs proxy), update upload.
    pub fn client_work(
        &self,
        cid: usize,
        mem: &MemCoeffs,
        bytes_up: u64,
        bytes_down: u64,
    ) -> ClientWork {
        let c = self.pool.client(cid);
        ClientWork {
            id: cid,
            ready_s: c.profile.trace.next_online(self.sim_time_s),
            down_s: c.profile.down_time_s(bytes_down),
            train_s: c.profile.train_time_s(c.shard.num_samples(), mem),
            up_s: c.profile.up_time_s(bytes_up),
            dropout_p: c.profile.dropout_p,
            trace: c.profile.trace,
        }
    }

    /// Sample this round's cohort, excluding clients whose earlier upload
    /// is still in flight (async policy): re-dispatching them would
    /// supersede — i.e. silently discard — work the server has already
    /// paid for. With nothing in flight this is exactly the plain sample,
    /// so the rng stream (and the sync/degenerate-async guarantees) are
    /// untouched.
    pub fn sample_cohort(&mut self, mem: &MemCoeffs) -> Selection {
        let busy: Vec<usize> = self.engine.inflight().iter().map(|u| u.client).collect();
        self.pool.select_excluding(self.sample_size(), mem, &busy)
    }

    /// Run one round's cohort through the discrete-event simulator under
    /// the configured round + churn policies, advancing the virtual clock
    /// to the aggregation instant. Async rounds thread the engine's
    /// in-flight queue through; [`Self::sample_cohort`] keeps in-flight
    /// clients out of the cohort, and the `pending.remove` below is the
    /// matching backstop for callers that sampled some other way (a
    /// fresh dispatch supersedes the stale in-flight upload).
    pub fn run_fleet(&mut self, works: &[ClientWork]) -> RoundPlan {
        let keep = match self.policy {
            RoundPolicy::OverSelect { .. } => self.cfg.per_round,
            _ => usize::MAX,
        };
        if self.async_params().is_some() {
            for w in works {
                self.pending.remove(&w.id);
            }
        }
        let t0 = self.telemetry.is_some().then(std::time::Instant::now);
        let plan = self.engine.simulate_round(
            self.round,
            self.sim_time_s,
            works,
            self.policy,
            keep,
            self.churn,
            &mut self.fleet_rng,
        );
        self.sim_time_s = plan.end_s;
        // Telemetry observation point: the simulation above never sees
        // these reads, so the stream is inert when unset.
        if let Some(t0) = t0 {
            let round = self.round;
            let sim_s = self.sim_time_s;
            let queue_peak = self.engine.last_queue_peak();
            let inflight = self.engine.inflight().len();
            let pending = self.pending.len();
            let threads = self.engine.threads();
            let utilization = self.engine.last_worker_utilization();
            if let Some(tel) = self.telemetry.as_mut() {
                tel.span(
                    "round.simulate",
                    round,
                    sim_s,
                    t0.elapsed().as_secs_f64(),
                    &[
                        ("cohort", Value::Num(works.len() as f64)),
                        ("completers", Value::Num(plan.completers.len() as f64)),
                        ("stragglers", Value::Num(plan.stragglers.len() as f64)),
                        ("dropouts", Value::Num(plan.dropouts.len() as f64)),
                        ("late_arrivals", Value::Num(plan.late_arrivals.len() as f64)),
                    ],
                );
                tel.gauge("fleet.queue_peak", round, sim_s, queue_peak as f64, &[]);
                tel.gauge("fleet.inflight_len", round, sim_s, inflight as f64, &[]);
                tel.gauge("coordinator.pending_len", round, sim_s, pending as f64, &[]);
                tel.gauge("fleet.threads", round, sim_s, threads as f64, &[]);
                // Wall-clock busy fraction of the span-planner pool; the
                // one deliberately nondeterministic value in the stream
                // (gauges are observations, not simulation state).
                tel.gauge("fleet.worker_utilization", round, sim_s, utilization, &[]);
            }
        }
        plan
    }

    /// Collect the pending updates behind this round's late arrivals and
    /// classify each against the current merge context (see
    /// [`projection::classify_stale`]):
    ///
    /// * version-exact updates merge as-is (`exact`, in arrival order);
    /// * updates trained against a since-frozen/remapped block are
    ///   dropped by default — or, under `--stale-projection on`,
    ///   projected onto the still-trained suffix (`projected`);
    /// * updates older than `max_staleness` rounds are always dropped.
    ///
    /// Dropped uploads still arrived — their bytes are charged and the
    /// discard is recorded (`late_dropped`), so the async policy cannot
    /// under-report its losses.
    pub(crate) fn take_late_arrivals(
        &mut self,
        plan: &RoundPlan,
        artifact: &str,
        max_staleness: usize,
        outcome: &mut RoundOutcome,
    ) -> Result<(Vec<(PendingUpdate, usize)>, Vec<ProjectedLate>)> {
        let mut exact = Vec::new();
        let mut projected = Vec::new();
        if plan.late_arrivals.is_empty() {
            return Ok((exact, projected));
        }
        // Borrow the model entry through `rt` (independent of &mut self).
        let rt = self.rt;
        let model = rt.model(&self.cfg.model_tag)?;
        // The current trainable layout is only materialized when the
        // projection path can fire; the off path allocates nothing.
        let new_layout = if self.projection.is_some() {
            Some(TrainableLayout::of_artifact(model.artifact(artifact)?))
        } else {
            None
        };
        let mctx = MergeContext {
            artifact,
            prefix_version: self.prefix_version,
            round: self.round,
            max_staleness,
            projection: new_layout.as_ref(),
        };
        for la in &plan.late_arrivals {
            let Some(p) = self.pending.remove(&la.client) else { continue };
            let PendingUpdate {
                client,
                artifact: trained,
                prefix_version,
                dispatch_round,
                weight,
                partial,
                tensors,
                bytes_up,
            } = p;
            // The dispatch-time layout resolves lazily: only an update
            // that actually attempts a projection pays for it.
            let decision =
                classify_stale(&mctx, &trained, prefix_version, dispatch_round, tensors, || {
                    model.artifact(&trained).ok().map(TrainableLayout::of_artifact)
                });
            match decision {
                StaleDecision::Exact { tensors, staleness } => {
                    let p = PendingUpdate {
                        client,
                        artifact: trained,
                        prefix_version,
                        dispatch_round,
                        weight,
                        partial,
                        tensors,
                        bytes_up,
                    };
                    exact.push((p, staleness));
                }
                StaleDecision::Projected { kept, dropped_params, staleness, transitions } => {
                    projected.push(ProjectedLate {
                        kept,
                        dropped_params,
                        staleness,
                        transitions,
                        weight,
                        partial,
                        bytes_up,
                    });
                }
                StaleDecision::Dropped => {
                    outcome.bytes_up += bytes_up;
                    outcome.late_dropped += 1;
                }
            }
        }
        Ok((exact, projected))
    }
}
