//! The federated coordinator (L3) — round execution, aggregation, eval.
//!
//! `ServerCtx` owns the global parameter store, the client pool, the PJRT
//! runtime, the fleet simulator state, and the metrics sink. One
//! `run_train_round` is the paper's §3.1 round: (1) pick the round's
//! sub-model artifact, (2) sample clients and filter by memory, (3)
//! dispatch the cohort as fleet events (download → local train → upload
//! on each device's virtual timeline), (4) the round policy decides who
//! aggregates (sync / deadline / over-select / async), (5) weighted
//! FedAvg (Eq. 1) back into the store, with comm accounting and the
//! virtual clock advanced to the aggregation instant.
//!
//! Under the `async` policy rounds are no longer self-contained: uploads
//! that miss the `buffer_k` window persist in the [`FleetEngine`]'s
//! in-flight queue, and the matching *update tensors* persist here in
//! [`ServerCtx::pending`] — version-stamped with the dispatch round,
//! artifact, and frozen-prefix version. When the fleet reports a late
//! arrival, the pending update merges with a staleness-discounted weight
//! unless it is older than `max_staleness` rounds or was trained against
//! a block that has since been frozen or remapped (artifact or prefix
//! version mismatch), in which case it is dropped.
//!
//! The progressive schedule itself (shrink → grow, freezing) lives in
//! `methods::profl`; baselines drive the same primitives.

pub mod round;

use crate::clients::{ClientPool, Selection};
use crate::config::RunConfig;
use crate::data::SyntheticDataset;
use crate::fleet::{ChurnPolicy, ClientWork, FleetEngine, RoundPlan, RoundPolicy};
use crate::manifest::{MemCoeffs, ModelEntry};
use crate::metrics::MetricsSink;
use crate::rng::Rng;
use crate::runtime::Runtime;
use crate::store::ParamStore;
use anyhow::Result;
use std::collections::HashMap;

pub use round::{EvalResult, RoundOutcome};

/// Test-set size = 8 eval batches (balanced classes).
pub const TEST_BATCHES: usize = 8;

/// A straggler's trained-but-not-yet-merged update, buffered server-side
/// while its upload is in flight across rounds (async policy). The
/// version stamps decide mergeability on arrival.
pub struct PendingUpdate {
    pub client: usize,
    /// Artifact the client trained (a late update only merges into the
    /// same artifact — a frozen/remapped block drops it).
    pub artifact: String,
    /// Frozen-prefix version at dispatch; a bump invalidates the update.
    pub prefix_version: u64,
    /// Server round index at dispatch (staleness = arrival − dispatch).
    pub dispatch_round: usize,
    /// Sample weight the update carries: shard size, scaled down by the
    /// checkpointed fraction for churn partials.
    pub weight: f64,
    /// Whether this is a checkpoint partial (metrics: `partial_merged`).
    pub partial: bool,
    /// Updated trainable tensors, in the artifact's trainable order.
    pub tensors: Vec<Vec<f32>>,
    /// Upload bytes accounted when the update finally lands.
    pub bytes_up: u64,
}

pub struct ServerCtx<'rt> {
    pub rt: &'rt Runtime,
    pub cfg: RunConfig,
    pub store: ParamStore,
    pub pool: ClientPool,
    pub dataset: SyntheticDataset,
    pub metrics: MetricsSink,
    pub round: usize,
    /// Resolved round policy (from `cfg.fleet.round_policy`).
    pub policy: RoundPolicy,
    /// Resolved mid-round churn policy (from `cfg.fleet.churn_policy`).
    pub churn: ChurnPolicy,
    /// Virtual fleet clock: seconds of simulated wall time since run
    /// start, advanced by each round's event simulation.
    pub sim_time_s: f64,
    /// Version stamp of the frozen prefix currently in the store; clients
    /// cache the prefix and only re-download when this changes.
    pub prefix_version: u64,
    /// Round-spanning fleet state (async in-flight uploads).
    pub engine: FleetEngine,
    /// Server-side buffer of straggler updates whose uploads are still in
    /// flight (async policy), keyed by client id.
    pub(crate) pending: HashMap<usize, PendingUpdate>,
    /// Dedicated stream for fleet stochastics (dropout draws), forked off
    /// the run seed so event traces are reproducible.
    pub(crate) fleet_rng: Rng,
    /// Scratch buffers reused across rounds (no allocation on the hot path).
    pub(crate) xs_buf: Vec<f32>,
    pub(crate) ys_buf: Vec<i32>,
}

impl<'rt> ServerCtx<'rt> {
    pub fn new(rt: &'rt Runtime, cfg: RunConfig) -> Result<Self> {
        let model = rt.model(&cfg.model_tag)?;
        let dataset = SyntheticDataset::new(model.num_classes, cfg.seed ^ 0xda7a);
        let fleet_profile = cfg.fleet_profile()?;
        let policy = cfg.round_policy()?;
        let churn = cfg.churn_policy()?;
        let pool = ClientPool::build(
            cfg.num_clients,
            cfg.total_samples,
            &dataset,
            cfg.partition(),
            cfg.memory.into(),
            &fleet_profile,
            cfg.seed,
        );
        let store = ParamStore::init(&model.params, cfg.seed ^ 0x1417);
        let fleet_rng = Rng::new(cfg.seed ^ 0xf1ee_7c10);
        Ok(ServerCtx {
            rt,
            cfg,
            store,
            pool,
            dataset,
            metrics: MetricsSink::new(),
            round: 0,
            policy,
            churn,
            sim_time_s: 0.0,
            prefix_version: 0,
            engine: FleetEngine::new(),
            pending: HashMap::new(),
            fleet_rng,
            xs_buf: Vec::new(),
            ys_buf: Vec::new(),
        })
    }

    pub fn model(&self) -> Result<&ModelEntry> {
        self.rt.model(&self.cfg.model_tag)
    }

    /// Initialize an auxiliary store for a width-ratio variant tag
    /// (HeteroFL/AllSmall local models). Seeded identically so slices of
    /// the full init match the variant's init distribution family.
    pub fn variant_store(&self, tag: &str) -> Result<ParamStore> {
        let model = self.rt.model(tag)?;
        Ok(ParamStore::init(&model.params, self.cfg.seed ^ 0x1417))
    }

    /// Bump the frozen-prefix version (called at step/stage transitions);
    /// forces prefix re-download for every client on next contact and
    /// invalidates in-flight updates trained against the old prefix.
    pub fn bump_prefix_version(&mut self) {
        self.prefix_version += 1;
    }

    /// How many clients to sample for a round: `per_round`, plus the
    /// over-commitment margin under the over-select policy.
    pub fn sample_size(&self) -> usize {
        match self.policy {
            RoundPolicy::OverSelect { extra } => self.cfg.per_round + extra,
            _ => self.cfg.per_round,
        }
    }

    /// `(buffer_k, max_staleness)` when running under the async policy.
    pub fn async_params(&self) -> Option<(usize, usize)> {
        match self.policy {
            RoundPolicy::Async { buffer_k, max_staleness } => Some((buffer_k, max_staleness)),
            _ => None,
        }
    }

    /// Precompute one cohort member's round timing from its device
    /// profile: availability-gated dispatch, artifact download, local
    /// training (shard size × FLOPs proxy), update upload.
    pub fn client_work(
        &self,
        cid: usize,
        mem: &MemCoeffs,
        bytes_up: u64,
        bytes_down: u64,
    ) -> ClientWork {
        let c = &self.pool.clients[cid];
        ClientWork {
            id: cid,
            ready_s: c.profile.trace.next_online(self.sim_time_s),
            down_s: c.profile.down_time_s(bytes_down),
            train_s: c.profile.train_time_s(c.shard.num_samples(), mem),
            up_s: c.profile.up_time_s(bytes_up),
            dropout_p: c.profile.dropout_p,
            trace: c.profile.trace,
        }
    }

    /// Sample this round's cohort, excluding clients whose earlier upload
    /// is still in flight (async policy): re-dispatching them would
    /// supersede — i.e. silently discard — work the server has already
    /// paid for. With nothing in flight this is exactly the plain sample,
    /// so the rng stream (and the sync/degenerate-async guarantees) are
    /// untouched.
    pub fn sample_cohort(&mut self, mem: &MemCoeffs) -> Selection {
        let busy: Vec<usize> = self.engine.inflight().iter().map(|u| u.client).collect();
        self.pool.select_excluding(self.sample_size(), mem, &busy)
    }

    /// Run one round's cohort through the discrete-event simulator under
    /// the configured round + churn policies, advancing the virtual clock
    /// to the aggregation instant. Async rounds thread the engine's
    /// in-flight queue through; [`Self::sample_cohort`] keeps in-flight
    /// clients out of the cohort, and the `pending.remove` below is the
    /// matching backstop for callers that sampled some other way (a
    /// fresh dispatch supersedes the stale in-flight upload).
    pub fn run_fleet(&mut self, works: &[ClientWork]) -> RoundPlan {
        let keep = match self.policy {
            RoundPolicy::OverSelect { .. } => self.cfg.per_round,
            _ => usize::MAX,
        };
        if self.async_params().is_some() {
            for w in works {
                self.pending.remove(&w.id);
            }
        }
        let plan = self.engine.simulate_round(
            self.round,
            self.sim_time_s,
            works,
            self.policy,
            keep,
            self.churn,
            &mut self.fleet_rng,
        );
        self.sim_time_s = plan.end_s;
        plan
    }

    /// Collect the pending updates behind this round's late arrivals,
    /// dropping any that are too stale or were trained against a
    /// since-frozen/remapped block (artifact or prefix-version mismatch).
    /// Dropped uploads still arrived — their bytes are charged and the
    /// discard is recorded (`late_dropped`), so the async policy cannot
    /// under-report its losses. Returns `(update, staleness)` pairs in
    /// arrival order.
    pub(crate) fn take_late_arrivals(
        &mut self,
        plan: &RoundPlan,
        artifact: &str,
        max_staleness: usize,
        outcome: &mut RoundOutcome,
    ) -> Vec<(PendingUpdate, usize)> {
        let mut out = Vec::new();
        for la in &plan.late_arrivals {
            if let Some(p) = self.pending.remove(&la.client) {
                let staleness = self.round.saturating_sub(p.dispatch_round);
                if staleness <= max_staleness
                    && p.artifact == artifact
                    && p.prefix_version == self.prefix_version
                {
                    out.push((p, staleness));
                } else {
                    outcome.bytes_up += p.bytes_up;
                    outcome.late_dropped += 1;
                }
            }
        }
        out
    }
}
