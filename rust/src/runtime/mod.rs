//! PJRT runtime: load AOT artifacts, execute them from the round path.
//!
//! Wraps the `xla` crate (xla_extension 0.5.1, PJRT C API): CPU client →
//! `HloModuleProto::from_text_file` → compile → execute. Executables are
//! compiled lazily and cached per artifact name; parameter literals are
//! built once per round and shared across all client executions of that
//! round (clients differ only in their data literals).
//!
//! Python never appears here — this module plus `artifacts/` is the whole
//! deployment surface.

use crate::manifest::{Artifact, Manifest, ModelEntry};
use crate::store::{ParamStore, Tensor};
use anyhow::{bail, Context, Result};
use std::cell::RefCell;
use std::collections::HashMap;
use std::path::PathBuf;
use std::rc::Rc;

/// Convert an f32 tensor to an XLA literal.
pub fn literal_f32(shape: &[usize], data: &[f32]) -> Result<xla::Literal> {
    let bytes: &[u8] =
        unsafe { std::slice::from_raw_parts(data.as_ptr() as *const u8, data.len() * 4) };
    xla::Literal::create_from_shape_and_untyped_data(xla::ElementType::F32, shape, bytes)
        .map_err(|e| anyhow::anyhow!("literal_f32 {shape:?}: {e}"))
}

/// Convert an i32 tensor to an XLA literal.
pub fn literal_i32(shape: &[usize], data: &[i32]) -> Result<xla::Literal> {
    let bytes: &[u8] =
        unsafe { std::slice::from_raw_parts(data.as_ptr() as *const u8, data.len() * 4) };
    xla::Literal::create_from_shape_and_untyped_data(xla::ElementType::S32, shape, bytes)
        .map_err(|e| anyhow::anyhow!("literal_i32 {shape:?}: {e}"))
}

/// One compiled artifact, ready to execute.
pub struct LoadedArtifact {
    /// Cache key: `tag/artifact`.
    pub name: String,
    /// Manifest metadata (inputs, outputs, memory coefficients).
    pub meta: Artifact,
    exe: xla::PjRtLoadedExecutable,
}

impl LoadedArtifact {
    /// Execute with positional literals (owned or borrowed); returns the
    /// flattened output tuple (aot.py lowers with return_tuple=True).
    pub fn execute<L: std::borrow::Borrow<xla::Literal>>(&self, inputs: &[L]) -> Result<Vec<xla::Literal>> {
        let bufs = self
            .exe
            .execute::<L>(inputs)
            .with_context(|| format!("executing {}", self.name))?;
        let lit = bufs[0][0].to_literal_sync()?;
        Ok(lit.to_tuple()?)
    }
}

/// Runtime = PJRT client + artifact cache + manifest.
pub struct Runtime {
    /// The PJRT CPU client executables compile against.
    pub client: xla::PjRtClient,
    /// The artifact inventory (`artifacts/manifest.json`).
    pub manifest: Manifest,
    root: PathBuf,
    cache: RefCell<HashMap<String, Rc<LoadedArtifact>>>,
}

impl Runtime {
    /// Open the artifacts directory: parse the manifest and bring up the
    /// PJRT CPU client.
    pub fn new(artifacts_dir: &std::path::Path) -> Result<Self> {
        let (manifest, root) = Manifest::load(artifacts_dir)?;
        let client = xla::PjRtClient::cpu().map_err(|e| anyhow::anyhow!("PJRT cpu client: {e}"))?;
        Ok(Runtime { client, manifest, root, cache: RefCell::new(HashMap::new()) })
    }

    /// Look up a model tag in the manifest.
    pub fn model(&self, tag: &str) -> Result<&ModelEntry> {
        self.manifest.model(tag)
    }

    /// Load (compile-and-cache) an artifact.
    pub fn load(&self, tag: &str, artifact: &str) -> Result<Rc<LoadedArtifact>> {
        let key = format!("{tag}/{artifact}");
        if let Some(a) = self.cache.borrow().get(&key) {
            return Ok(a.clone());
        }
        let meta = self.manifest.model(tag)?.artifact(artifact)?.clone();
        let path = self.root.join(&meta.path);
        let proto = xla::HloModuleProto::from_text_file(&path)
            .map_err(|e| anyhow::anyhow!("parsing HLO {path:?}: {e}"))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self.client.compile(&comp).map_err(|e| anyhow::anyhow!("compiling {key}: {e}"))?;
        let loaded = Rc::new(LoadedArtifact { name: key.clone(), meta, exe });
        self.cache.borrow_mut().insert(key, loaded.clone());
        Ok(loaded)
    }

    /// Number of artifacts compiled and cached so far.
    pub fn cached_count(&self) -> usize {
        self.cache.borrow().len()
    }

    /// Build the parameter literals for an artifact in input order
    /// (trainable then frozen), reading values from the store.
    pub fn param_literals(&self, art: &Artifact, store: &ParamStore) -> Result<Vec<xla::Literal>> {
        let mut lits = Vec::new();
        for entry in &art.inputs {
            match entry.role.as_str() {
                "trainable" | "frozen" | "param" => {
                    let t = store.get(&entry.name)?;
                    if t.shape != entry.shape {
                        bail!(
                            "shape mismatch for `{}`: store {:?} vs artifact {:?}",
                            entry.name,
                            t.shape,
                            entry.shape
                        );
                    }
                    lits.push(literal_f32(&t.shape, &t.data)?);
                }
                _ => break, // data inputs always trail the parameters
            }
        }
        Ok(lits)
    }

    /// Unpack train-step outputs: updated trainables (by name) + scalar
    /// tail. `outputs` layout: [trainable..., loss, correct] or [..., loss].
    pub fn unpack_train_outputs(
        art: &Artifact,
        outs: Vec<xla::Literal>,
    ) -> Result<(Vec<(String, Vec<f32>)>, Vec<f32>)> {
        let tr_names = art.trainable_names();
        if outs.len() < tr_names.len() {
            bail!("artifact returned {} outputs, expected ≥ {}", outs.len(), tr_names.len());
        }
        let n_tr = tr_names.len();
        let mut updated = Vec::with_capacity(n_tr);
        for (i, name) in tr_names.iter().enumerate() {
            updated.push((name.to_string(), outs[i].to_vec::<f32>()?));
        }
        let mut scalars = Vec::new();
        for lit in &outs[n_tr..] {
            scalars.push(lit.to_vec::<f32>()?[0]);
        }
        Ok((updated, scalars))
    }
}

/// Write updated trainables into a store (shapes come from the artifact).
pub fn apply_updates(
    store: &mut ParamStore,
    art: &Artifact,
    updated: Vec<(String, Vec<f32>)>,
) -> Result<()> {
    for (name, data) in updated {
        let shape = art
            .inputs
            .iter()
            .find(|i| i.name == name)
            .map(|i| i.shape.clone())
            .with_context(|| format!("output `{name}` not among inputs"))?;
        store.set(&name, Tensor { shape, data });
    }
    Ok(())
}
