//! Structured telemetry: a zero-dependency JSONL event/span appender plus
//! run-provenance manifests.
//!
//! Two production surfaces for the fleet round loop (see
//! `docs/OBSERVABILITY.md` for the full catalog and a jq cookbook):
//!
//! 1. **[`Appender`]** — a buffered JSONL writer emitting typed events:
//!    named spans (`round.dispatch`, `round.simulate`, `aggregate.merge`,
//!    `freeze.observe`) and counters/gauges (event-queue peak depth,
//!    in-flight queue length, lazy-pool cache hits/misses/evictions,
//!    late merges/drops, projected params, per-block effective-movement
//!    scalars). Every line is a self-contained JSON object carrying a
//!    monotonic sequence number, a wall-clock stamp, and the virtual
//!    sim-time of the round it describes, so a million-device run is
//!    observable *live* (`tail -f | jq`) instead of post-hoc via CSV.
//!
//! 2. **[`build_manifest`]** — a `manifest.json` provenance record
//!    written at run end: sha256 of the resolved [`RunConfig`], the run
//!    seed, crate version + `git describe`, the CLI argv, the telemetry
//!    stream's path and line count, and rollup digests of the
//!    [`RunSummary`] (including a sha256 over the per-round history).
//!    Two runs with the same config and seed produce identical manifests
//!    modulo the single wall-time field — the reproducibility contract
//!    the checkpoint/resume roadmap item builds on.
//!
//! Week-long sweeps can cap the stream with `--telemetry-max-mb`: the
//! live file rotates to `<stem>.N.jsonl` when it crosses the cap and
//! the manifest records every segment (see [`Appender`] docs).
//!
//! **Strictly off by default.** The stream only exists when
//! `--telemetry-jsonl <path>` (or `PROFL_TELEMETRY_JSONL`) is set; every
//! hook in the round loop is gated on the appender's presence and only
//! *reads* simulator state — no RNG draws, no float arithmetic, no event
//! reordering — so golden traces, benches, and all degeneracy contracts
//! are bit-for-bit untouched (integration-armored in
//! `rust/tests/telemetry.rs`).

use crate::config::RunConfig;
use crate::json::Value;
use crate::metrics::RunSummary;
use anyhow::{Context, Result};
use std::collections::BTreeMap;
use std::fs::File;
use std::io::{BufWriter, Write};
use std::path::{Path, PathBuf};
use std::time::{SystemTime, UNIX_EPOCH};

/// Manifest schema version (bump on breaking field changes).
pub const MANIFEST_SCHEMA: u64 = 1;

/// The manifest's single nondeterministic field: wall-clock creation
/// time in unix milliseconds. Strip it before comparing manifests for
/// reproducibility (the deterministic-manifest tests do exactly that).
pub const MANIFEST_WALL_KEY: &str = "created_wall_ms";

/// Current wall clock as unix milliseconds (0 if the clock is broken).
fn wall_ms() -> u64 {
    SystemTime::now().duration_since(UNIX_EPOCH).map(|d| d.as_millis() as u64).unwrap_or(0)
}

/// A JSON number that stays parseable: non-finite floats (NaN before an
/// EM window fills, say) become `null` instead of the unparseable bare
/// `NaN` token.
pub fn fnum(x: f64) -> Value {
    if x.is_finite() {
        Value::Num(x)
    } else {
        Value::Null
    }
}

/// Buffered JSONL event appender with a monotonic sequence number.
///
/// Each emitted line is one JSON object with the required keys
/// `seq` / `wall_ms` / `sim_s` / `round` / `kind` / `name`, a
/// kind-specific payload (`dur_s` for spans, `value` for
/// counters/gauges), and an optional `attrs` object. Lines are flushed
/// on drop, so the stream is complete even when the run ends by falling
/// out of scope. Write errors never fail the run — telemetry is an
/// observer, not a participant — they are counted instead.
///
/// # Rotation
///
/// With a size cap ([`Appender::create_with_cap`], wired to
/// `--telemetry-max-mb`), the live stream rotates once it crosses the
/// cap: the current file is renamed to `<stem>.N.jsonl` (N = 1, 2, …)
/// and a fresh live file opens at the original path. Sequence numbers
/// stay monotonic across segments, so `sort_by .seq` over every segment
/// reconstructs the full stream; a segment may exceed the cap by at
/// most one line (the check runs after each write). Rotation failures
/// are swallowed like write errors — the stream just keeps growing.
pub struct Appender {
    out: BufWriter<File>,
    path: PathBuf,
    seq: u64,
    dropped_writes: u64,
    /// Rotate the live segment once it holds at least this many bytes.
    max_bytes: Option<u64>,
    /// Bytes written to the *current* segment.
    segment_bytes: u64,
    /// Completed rotations so far (== highest `<stem>.N.jsonl` index).
    rotations: u64,
}

impl Appender {
    /// Create (truncate) the JSONL stream at `path`, creating missing
    /// parent directories. No size cap: the stream never rotates.
    pub fn create(path: &Path) -> Result<Self> {
        Self::create_with_cap(path, None)
    }

    /// [`Self::create`] with an optional size cap in bytes; crossing it
    /// rotates the live file to `<stem>.N.jsonl` (see the type docs).
    pub fn create_with_cap(path: &Path, max_bytes: Option<u64>) -> Result<Self> {
        if let Some(dir) = path.parent() {
            if !dir.as_os_str().is_empty() {
                std::fs::create_dir_all(dir)
                    .with_context(|| format!("creating telemetry dir {}", dir.display()))?;
            }
        }
        let f = File::create(path)
            .with_context(|| format!("creating telemetry stream {}", path.display()))?;
        Ok(Appender {
            out: BufWriter::new(f),
            path: path.to_path_buf(),
            seq: 0,
            dropped_writes: 0,
            max_bytes,
            segment_bytes: 0,
            rotations: 0,
        })
    }

    /// The live stream's path (rotated segments live next to it).
    pub fn path(&self) -> &Path {
        &self.path
    }

    /// Lines successfully emitted so far (== the next sequence number),
    /// across every segment.
    pub fn lines(&self) -> u64 {
        self.seq
    }

    /// Lines lost to I/O errors (telemetry never fails the run).
    pub fn dropped_writes(&self) -> u64 {
        self.dropped_writes
    }

    /// Completed size-cap rotations (0 when uncapped or under the cap).
    pub fn rotations(&self) -> u64 {
        self.rotations
    }

    /// Rename the live file to the next `<stem>.N.jsonl` segment and
    /// reopen a truncated live file at the original path. Best-effort:
    /// on any I/O failure the appender keeps writing where it was.
    fn rotate(&mut self) {
        let _ = self.out.flush();
        let seg = segment_path(&self.path, self.rotations + 1);
        if std::fs::rename(&self.path, &seg).is_err() {
            return;
        }
        // The old handle now points at the renamed segment; only swap
        // it out if the fresh live file actually opens.
        if let Ok(f) = File::create(&self.path) {
            self.out = BufWriter::new(f);
            self.segment_bytes = 0;
            self.rotations += 1;
        }
    }

    /// Emit one event line. `payload` and `attrs` keys must not collide
    /// with the required keys (they would overwrite them).
    fn emit(
        &mut self,
        kind: &str,
        name: &str,
        round: usize,
        sim_s: f64,
        payload: &[(&str, Value)],
        attrs: &[(&str, Value)],
    ) {
        let mut m = BTreeMap::new();
        m.insert("seq".to_string(), Value::Num(self.seq as f64));
        m.insert("wall_ms".to_string(), Value::Num(wall_ms() as f64));
        m.insert("sim_s".to_string(), fnum(sim_s));
        m.insert("round".to_string(), Value::Num(round as f64));
        m.insert("kind".to_string(), Value::Str(kind.to_string()));
        m.insert("name".to_string(), Value::Str(name.to_string()));
        for (k, v) in payload {
            m.insert((*k).to_string(), v.clone());
        }
        if !attrs.is_empty() {
            let a: BTreeMap<String, Value> =
                attrs.iter().map(|(k, v)| ((*k).to_string(), v.clone())).collect();
            m.insert("attrs".to_string(), Value::Obj(a));
        }
        let line = Value::Obj(m).to_json();
        if writeln!(self.out, "{line}").is_ok() {
            self.seq += 1;
            self.segment_bytes += line.len() as u64 + 1;
            if let Some(cap) = self.max_bytes {
                if self.segment_bytes >= cap {
                    self.rotate();
                }
            }
        } else {
            self.dropped_writes += 1;
        }
    }

    /// Emit a named span: a timed section of the round loop, `dur_s`
    /// wall seconds long, stamped with the round and its virtual time.
    pub fn span(
        &mut self,
        name: &str,
        round: usize,
        sim_s: f64,
        dur_s: f64,
        attrs: &[(&str, Value)],
    ) {
        self.emit("span", name, round, sim_s, &[("dur_s", fnum(dur_s))], attrs);
    }

    /// Emit a counter: a cumulative monotone quantity (bytes, merges…).
    pub fn counter(
        &mut self,
        name: &str,
        round: usize,
        sim_s: f64,
        value: f64,
        attrs: &[(&str, Value)],
    ) {
        self.emit("counter", name, round, sim_s, &[("value", fnum(value))], attrs);
    }

    /// Emit a gauge: an instantaneous level (queue depth, EM scalar…).
    pub fn gauge(
        &mut self,
        name: &str,
        round: usize,
        sim_s: f64,
        value: f64,
        attrs: &[(&str, Value)],
    ) {
        self.emit("gauge", name, round, sim_s, &[("value", fnum(value))], attrs);
    }

    /// Flush buffered lines to disk (best-effort; also runs on drop).
    pub fn flush(&mut self) {
        let _ = self.out.flush();
    }
}

impl Drop for Appender {
    fn drop(&mut self) {
        let _ = self.out.flush();
    }
}

impl std::fmt::Debug for Appender {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("Appender")
            .field("path", &self.path)
            .field("seq", &self.seq)
            .field("dropped_writes", &self.dropped_writes)
            .finish()
    }
}

// ---- sha256 (hand-rolled: the crate is dependency-free by policy) ------

const SHA256_K: [u32; 64] = [
    0x428a2f98, 0x71374491, 0xb5c0fbcf, 0xe9b5dba5, 0x3956c25b, 0x59f111f1, 0x923f82a4, 0xab1c5ed5,
    0xd807aa98, 0x12835b01, 0x243185be, 0x550c7dc3, 0x72be5d74, 0x80deb1fe, 0x9bdc06a7, 0xc19bf174,
    0xe49b69c1, 0xefbe4786, 0x0fc19dc6, 0x240ca1cc, 0x2de92c6f, 0x4a7484aa, 0x5cb0a9dc, 0x76f988da,
    0x983e5152, 0xa831c66d, 0xb00327c8, 0xbf597fc7, 0xc6e00bf3, 0xd5a79147, 0x06ca6351, 0x14292967,
    0x27b70a85, 0x2e1b2138, 0x4d2c6dfc, 0x53380d13, 0x650a7354, 0x766a0abb, 0x81c2c92e, 0x92722c85,
    0xa2bfe8a1, 0xa81a664b, 0xc24b8b70, 0xc76c51a3, 0xd192e819, 0xd6990624, 0xf40e3585, 0x106aa070,
    0x19a4c116, 0x1e376c08, 0x2748774c, 0x34b0bcb5, 0x391c0cb3, 0x4ed8aa4a, 0x5b9cca4f, 0x682e6ff3,
    0x748f82ee, 0x78a5636f, 0x84c87814, 0x8cc70208, 0x90befffa, 0xa4506ceb, 0xbef9a3f7, 0xc67178f2,
];

/// SHA-256 of `data` as a lowercase hex string (FIPS 180-4; verified
/// against the standard test vectors in this module's tests). Hand-rolled
/// because the crate takes no dependencies beyond `anyhow`.
pub fn sha256_hex(data: &[u8]) -> String {
    let mut h: [u32; 8] = [
        0x6a09e667, 0xbb67ae85, 0x3c6ef372, 0xa54ff53a, 0x510e527f, 0x9b05688c, 0x1f83d9ab,
        0x5be0cd19,
    ];
    let bitlen = (data.len() as u64).wrapping_mul(8);
    let mut msg = data.to_vec();
    msg.push(0x80);
    while msg.len() % 64 != 56 {
        msg.push(0);
    }
    msg.extend_from_slice(&bitlen.to_be_bytes());

    let mut w = [0u32; 64];
    for chunk in msg.chunks_exact(64) {
        for (i, word) in w.iter_mut().take(16).enumerate() {
            *word = u32::from_be_bytes([
                chunk[4 * i],
                chunk[4 * i + 1],
                chunk[4 * i + 2],
                chunk[4 * i + 3],
            ]);
        }
        for i in 16..64 {
            let s0 = w[i - 15].rotate_right(7) ^ w[i - 15].rotate_right(18) ^ (w[i - 15] >> 3);
            let s1 = w[i - 2].rotate_right(17) ^ w[i - 2].rotate_right(19) ^ (w[i - 2] >> 10);
            w[i] = w[i - 16].wrapping_add(s0).wrapping_add(w[i - 7]).wrapping_add(s1);
        }
        let [mut a, mut b, mut c, mut d, mut e, mut f, mut g, mut hh] = h;
        for i in 0..64 {
            let s1 = e.rotate_right(6) ^ e.rotate_right(11) ^ e.rotate_right(25);
            let ch = (e & f) ^ ((!e) & g);
            let t1 = hh
                .wrapping_add(s1)
                .wrapping_add(ch)
                .wrapping_add(SHA256_K[i])
                .wrapping_add(w[i]);
            let s0 = a.rotate_right(2) ^ a.rotate_right(13) ^ a.rotate_right(22);
            let maj = (a & b) ^ (a & c) ^ (b & c);
            let t2 = s0.wrapping_add(maj);
            hh = g;
            g = f;
            f = e;
            e = d.wrapping_add(t1);
            d = c;
            c = b;
            b = a;
            a = t1.wrapping_add(t2);
        }
        h[0] = h[0].wrapping_add(a);
        h[1] = h[1].wrapping_add(b);
        h[2] = h[2].wrapping_add(c);
        h[3] = h[3].wrapping_add(d);
        h[4] = h[4].wrapping_add(e);
        h[5] = h[5].wrapping_add(f);
        h[6] = h[6].wrapping_add(g);
        h[7] = h[7].wrapping_add(hh);
    }
    use std::fmt::Write as _;
    let mut out = String::with_capacity(64);
    for x in h {
        let _ = write!(out, "{x:08x}");
    }
    out
}

// ---- resolved-config serialization + hash ------------------------------

fn n_usize(x: usize) -> Value {
    Value::Num(x as f64)
}

fn n_u64(x: u64) -> Value {
    Value::Num(x as f64)
}

fn n_str(x: &str) -> Value {
    Value::Str(x.to_string())
}

fn opt_f64(x: Option<f64>) -> Value {
    match x {
        Some(v) => fnum(v),
        None => Value::Null,
    }
}

fn obj(pairs: Vec<(&str, Value)>) -> Value {
    Value::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
}

/// Canonical JSON image of a resolved [`RunConfig`]: every field, in
/// deterministic (sorted-key) order. [`config_sha256`] hashes this text,
/// so any flag change — CLI or programmatic — changes the hash. The
/// `seed` is emitted as a *string* so 64-bit values survive exactly
/// (JSON numbers here are f64).
pub fn config_value(cfg: &RunConfig) -> Value {
    let f = &cfg.fleet;
    obj(vec![
        ("model_tag", n_str(&cfg.model_tag)),
        ("num_clients", n_usize(cfg.num_clients)),
        ("per_round", n_usize(cfg.per_round)),
        ("total_samples", n_usize(cfg.total_samples)),
        ("dirichlet_alpha", opt_f64(cfg.dirichlet_alpha)),
        ("lr", fnum(cfg.lr as f64)),
        ("lr_step_decay", fnum(cfg.lr_step_decay as f64)),
        ("eval_every", n_usize(cfg.eval_every)),
        ("max_rounds_per_step", n_usize(cfg.max_rounds_per_step)),
        ("min_rounds_per_step", n_usize(cfg.min_rounds_per_step)),
        ("max_rounds_total", n_usize(cfg.max_rounds_total)),
        ("distill_rounds", n_usize(cfg.distill_rounds)),
        ("shrinking", Value::Bool(cfg.shrinking)),
        (
            "freeze",
            obj(vec![
                ("window_h", n_usize(cfg.freeze.window_h)),
                ("phi", fnum(cfg.freeze.phi)),
                ("patience_w", n_usize(cfg.freeze.patience_w)),
                ("fit_points", n_usize(cfg.freeze.fit_points)),
                ("min_observations", n_usize(cfg.freeze.min_observations)),
            ]),
        ),
        (
            "memory",
            obj(vec![
                ("budget_min_mb", n_u64(cfg.memory.budget_min_mb)),
                ("budget_max_mb", n_u64(cfg.memory.budget_max_mb)),
                ("contention_lo", fnum(cfg.memory.contention_lo)),
                ("accounting_batch", n_u64(cfg.memory.accounting_batch)),
            ]),
        ),
        (
            "fleet",
            obj(vec![
                ("profile", n_str(&f.profile)),
                ("round_policy", n_str(&f.round_policy)),
                ("deadline_s", fnum(f.deadline_s)),
                ("over_select_extra", n_usize(f.over_select_extra)),
                ("dropout_p", opt_f64(f.dropout_p)),
                ("buffer_k", match f.buffer_k {
                    Some(k) => n_usize(k),
                    None => Value::Null,
                }),
                ("staleness_alpha", fnum(f.staleness_alpha)),
                ("max_staleness", n_usize(f.max_staleness)),
                ("stale_projection", n_str(&f.stale_projection)),
                ("projection_decay", fnum(f.projection_decay)),
                ("churn_policy", n_str(&f.churn_policy)),
                ("churn_epochs", n_usize(f.churn_epochs)),
                ("trace_period_s", opt_f64(f.trace_period_s)),
                ("trace_duty", opt_f64(f.trace_duty)),
                ("lazy_pool", Value::Bool(f.lazy_pool)),
            ]),
        ),
        (
            "strategy",
            obj(vec![
                ("name", match &cfg.strategy.name {
                    Some(s) => n_str(s),
                    None => Value::Null,
                }),
                ("elastic_phases", match cfg.strategy.elastic_phases {
                    Some(p) => n_usize(p),
                    None => Value::Null,
                }),
                ("freeze_step_cap", match cfg.strategy.freeze_step_cap {
                    Some(c) => n_usize(c),
                    None => Value::Null,
                }),
            ]),
        ),
        ("acc_tail", n_usize(cfg.acc_tail)),
        ("seed", n_str(&cfg.seed.to_string())),
        ("telemetry_jsonl", match &cfg.telemetry_jsonl {
            Some(p) => n_str(p),
            None => Value::Null,
        }),
    ])
}

/// sha256 over the canonical JSON of the resolved config — the manifest's
/// reproducible config fingerprint.
pub fn config_sha256(cfg: &RunConfig) -> String {
    sha256_hex(config_value(cfg).to_json().as_bytes())
}

// ---- run manifest ------------------------------------------------------

/// `git describe --always --dirty --tags` of the working tree, or
/// `"unknown"` outside a git checkout (manifests must never fail a run).
pub fn git_describe() -> String {
    std::process::Command::new("git")
        .args(["describe", "--always", "--dirty", "--tags"])
        .output()
        .ok()
        .filter(|o| o.status.success())
        .and_then(|o| String::from_utf8(o.stdout).ok())
        .map(|s| s.trim().to_string())
        .filter(|s| !s.is_empty())
        .unwrap_or_else(|| "unknown".into())
}

/// Number of newline-terminated lines in the file at `path` (0 when the
/// file is absent/unreadable) — how `main` counts a finished run's
/// telemetry stream for the manifest without holding the appender open.
pub fn count_lines(path: &Path) -> u64 {
    std::fs::read_to_string(path).map(|s| s.lines().count() as u64).unwrap_or(0)
}

/// Path of the `n`-th rotated segment of the stream at `base`:
/// `runs/t.jsonl` → `runs/t.1.jsonl`, `runs/t.2.jsonl`, … (extension-less
/// bases get `.N.jsonl` appended).
pub fn segment_path(base: &Path, n: u64) -> PathBuf {
    let stem = base.file_stem().and_then(|s| s.to_str()).unwrap_or("telemetry");
    match base.extension().and_then(|s| s.to_str()) {
        Some(ext) => base.with_file_name(format!("{stem}.{n}.{ext}")),
        None => base.with_file_name(format!("{stem}.{n}.jsonl")),
    }
}

/// Rotated segments of the stream at `base`, in rotation order: probes
/// `<stem>.1.jsonl`, `<stem>.2.jsonl`, … until the first gap and returns
/// each existing segment with its line count. Empty when the stream
/// never rotated — exactly the case where the manifest must stay
/// byte-identical to the pre-rotation format.
pub fn discover_segments(base: &Path) -> Vec<(PathBuf, u64)> {
    let mut out = Vec::new();
    for n in 1.. {
        let seg = segment_path(base, n);
        if !seg.is_file() {
            break;
        }
        let lines = count_lines(&seg);
        out.push((seg, lines));
    }
    out
}

/// Per-method telemetry stream path for multi-method runs: `compare`
/// with `--telemetry-jsonl runs/t.jsonl` writes one stream per method at
/// `runs/t.<method>.jsonl` instead of truncating a single file five
/// times. The method name is lowercased so paths are shell-friendly.
pub fn method_stream_path(base: &Path, method: &str) -> PathBuf {
    let method = method.to_lowercase();
    let stem = base.file_stem().and_then(|s| s.to_str()).unwrap_or("telemetry");
    match base.extension().and_then(|s| s.to_str()) {
        Some(ext) => base.with_file_name(format!("{stem}.{method}.{ext}")),
        None => base.with_file_name(format!("{stem}.{method}.jsonl")),
    }
}

/// Build the run-provenance manifest. Deterministic except for the
/// single [`MANIFEST_WALL_KEY`] field: same config + seed + summary ⇒
/// identical JSON after stripping that key (tested). `telemetry` carries
/// the finished stream's `(path, line_count)` when one was written.
pub fn build_manifest(
    cfg: &RunConfig,
    argv: &[String],
    summary: Option<&RunSummary>,
    telemetry: Option<(&Path, u64)>,
) -> Value {
    let summary_value = match summary {
        None => Value::Null,
        Some(s) => {
            let mut history_text = String::new();
            for r in &s.history {
                history_text.push_str(&r.csv_row());
                history_text.push('\n');
            }
            obj(vec![
                ("method", n_str(&s.method)),
                ("model_tag", n_str(&s.model_tag)),
                ("partition", n_str(&s.partition)),
                ("final_acc", fnum(s.final_acc)),
                ("participation_rate", fnum(s.participation_rate)),
                ("peak_client_mem", n_u64(s.peak_client_mem)),
                ("total_bytes_up", n_u64(s.total_bytes_up)),
                ("total_bytes_down", n_u64(s.total_bytes_down)),
                ("rounds", n_usize(s.rounds)),
                ("sim_time_s", fnum(s.sim_time_s)),
                ("late_merges", n_usize(s.late_merges())),
                ("late_drops", n_usize(s.late_drops())),
                ("projected_merges", n_usize(s.projected_merges())),
                ("projected_dropped_params", n_u64(s.projected_dropped_params())),
                ("transitions", n_usize(s.transitions.len())),
                ("history_rounds", n_usize(s.history.len())),
                ("history_sha256", n_str(&sha256_hex(history_text.as_bytes()))),
            ])
        }
    };
    let telemetry_value = match telemetry {
        None => Value::Null,
        Some((path, lines)) => {
            let mut fields = vec![
                ("path", n_str(&path.display().to_string())),
                ("lines", n_u64(lines)),
            ];
            // Size-cap rotation: record every rotated segment so no part
            // of the stream is orphaned from its provenance. Absent when
            // the stream never rotated, keeping pre-rotation manifests
            // byte-identical.
            let segments = discover_segments(path);
            if !segments.is_empty() {
                let list: Vec<Value> = segments
                    .iter()
                    .map(|(p, l)| {
                        obj(vec![
                            ("path", n_str(&p.display().to_string())),
                            ("lines", n_u64(*l)),
                        ])
                    })
                    .collect();
                fields.push(("segments", Value::Arr(list)));
            }
            obj(fields)
        }
    };
    obj(vec![
        ("schema", n_u64(MANIFEST_SCHEMA)),
        (MANIFEST_WALL_KEY, n_u64(wall_ms())),
        ("crate_version", n_str(env!("CARGO_PKG_VERSION"))),
        ("git_describe", n_str(&git_describe())),
        ("argv", Value::Arr(argv.iter().map(|a| n_str(a)).collect())),
        ("seed", n_str(&cfg.seed.to_string())),
        ("config", config_value(cfg)),
        ("config_sha256", n_str(&config_sha256(cfg))),
        ("telemetry", telemetry_value),
        ("summary", summary_value),
    ])
}

/// Build a multi-method manifest: the `compare` subcommand emits one
/// telemetry stream per method (see [`method_stream_path`]), and the
/// manifest's `telemetry` field records *every* stream —
/// `{streams: [{method, path, lines}, …]}` in execution order — so no
/// stream is orphaned from its provenance record.
pub fn build_multi_manifest(
    cfg: &RunConfig,
    argv: &[String],
    streams: &[(String, PathBuf, u64)],
) -> Value {
    let mut m = build_manifest(cfg, argv, None, None);
    let list: Vec<Value> = streams
        .iter()
        .map(|(method, path, lines)| {
            obj(vec![
                ("method", n_str(method)),
                ("path", n_str(&path.display().to_string())),
                ("lines", n_u64(*lines)),
            ])
        })
        .collect();
    if let Value::Obj(map) = &mut m {
        map.insert("telemetry".to_string(), obj(vec![("streams", Value::Arr(list))]));
    }
    m
}

/// Write `manifest` (pretty: one compact JSON object + newline) to
/// `path`, creating missing parent directories.
pub fn write_manifest(path: &Path, manifest: &Value) -> Result<()> {
    if let Some(dir) = path.parent() {
        if !dir.as_os_str().is_empty() {
            std::fs::create_dir_all(dir)
                .with_context(|| format!("creating manifest dir {}", dir.display()))?;
        }
    }
    std::fs::write(path, manifest.to_json() + "\n")
        .with_context(|| format!("writing manifest {}", path.display()))
}

/// Strip the wall-time field from a manifest, for reproducibility
/// comparisons (two same-config runs are identical after this).
pub fn strip_wall_time(manifest: &Value) -> Value {
    match manifest {
        Value::Obj(m) => {
            let mut m = m.clone();
            m.remove(MANIFEST_WALL_KEY);
            Value::Obj(m)
        }
        other => other.clone(),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tmp(name: &str) -> PathBuf {
        std::env::temp_dir().join("profl_telemetry_unit").join(name)
    }

    #[test]
    fn sha256_standard_vectors() {
        assert_eq!(
            sha256_hex(b""),
            "e3b0c44298fc1c149afbf4c8996fb92427ae41e4649b934ca495991b7852b855"
        );
        assert_eq!(
            sha256_hex(b"abc"),
            "ba7816bf8f01cfea414140de5dae2223b00361a396177a9cb410ff61f20015ad"
        );
        assert_eq!(
            sha256_hex(b"abcdbcdecdefdefgefghfghighijhijkijkljklmklmnlmnomnopnopq"),
            "248d6a61d20638b8e5c026930c3e6039a33ce45964ff2167f6ecedd419db06c1"
        );
        // Cross the one-block boundary (padding of a 64-byte message
        // spills into a second block).
        assert_eq!(
            sha256_hex(&[b'a'; 64]),
            "ffe054fe7ae0cb6dc65c3af9b61d5209f439851db43d0ba5997337df154668eb"
        );
    }

    #[test]
    fn appender_orders_escapes_and_flushes_on_drop() {
        let path = tmp("appender_basic.jsonl");
        {
            let mut a = Appender::create(&path).unwrap();
            a.span("round.simulate", 1, 30.0, 0.001, &[("cohort", n_usize(8))]);
            a.counter("round.late_merged", 1, 30.0, 2.0, &[]);
            // Hostile content: quotes, backslashes, newlines, controls.
            a.gauge("freeze.em", 2, 60.5, f64::NAN, &[(
                "note",
                n_str("line\nbreak \"quoted\" back\\slash \t tab \u{1} ctl"),
            )]);
            assert_eq!(a.lines(), 3);
            assert_eq!(a.dropped_writes(), 0);
            // No explicit flush: drop must do it.
        }
        let text = std::fs::read_to_string(&path).unwrap();
        let lines: Vec<&str> = text.lines().collect();
        assert_eq!(lines.len(), 3);
        let mut prev_seq = -1i64;
        for line in &lines {
            let v = Value::parse(line).unwrap();
            let seq = v.get("seq").unwrap().as_u64().unwrap() as i64;
            assert!(seq > prev_seq, "seq strictly increasing");
            prev_seq = seq;
            for key in ["seq", "wall_ms", "sim_s", "round", "kind", "name"] {
                assert!(v.get(key).is_ok(), "required key {key} missing in {line}");
            }
        }
        let v0 = Value::parse(lines[0]).unwrap();
        assert_eq!(v0.get("kind").unwrap().as_str().unwrap(), "span");
        assert_eq!(v0.get("name").unwrap().as_str().unwrap(), "round.simulate");
        assert!(v0.get("dur_s").unwrap().as_f64().unwrap() > 0.0);
        assert_eq!(
            v0.get("attrs").unwrap().get("cohort").unwrap().as_usize().unwrap(),
            8
        );
        // NaN gauges must still parse (they serialize as null).
        let v2 = Value::parse(lines[2]).unwrap();
        assert_eq!(v2.get("value").unwrap(), &Value::Null);
        assert!(v2.get("attrs").unwrap().get("note").unwrap().as_str().unwrap().contains('\n'));
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn size_cap_rotates_segments_with_monotonic_seq() {
        let dir = tmp("rotate");
        std::fs::remove_dir_all(&dir).ok(); // stale segments from prior runs
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("stream.jsonl");
        {
            // ~95-byte lines against a 200-byte cap: rotates every 2-3
            // lines, so 7 lines span at least 3 files.
            let mut a = Appender::create_with_cap(&path, Some(200)).unwrap();
            for i in 0..7 {
                a.counter("c", i, 0.0, i as f64, &[]);
            }
            assert!(a.rotations() >= 2, "200B cap must rotate within 7 lines");
            assert_eq!(a.lines(), 7, "lines() counts across segments");
        }
        let segments = discover_segments(&path);
        assert!(segments.len() >= 2);
        assert_eq!(segments[0].0, dir.join("stream.1.jsonl"));
        // Reassemble rotation order + live file: every line present,
        // seq strictly monotonic across the whole stream.
        let mut seqs = Vec::new();
        let mut files: Vec<PathBuf> = segments.iter().map(|(p, _)| p.clone()).collect();
        files.push(path.clone());
        for (i, p) in files.iter().enumerate() {
            let text = std::fs::read_to_string(p).unwrap();
            if let Some((_, lines)) = segments.get(i) {
                assert_eq!(text.lines().count() as u64, *lines, "segment line count");
            }
            for line in text.lines() {
                seqs.push(Value::parse(line).unwrap().get("seq").unwrap().as_u64().unwrap());
            }
        }
        assert_eq!(seqs.len(), 7, "no line lost to rotation");
        assert!(seqs.windows(2).all(|w| w[1] > w[0]), "seq monotonic: {seqs:?}");
        // The manifest names every rotated segment...
        let cfg = RunConfig::default();
        let m = build_manifest(&cfg, &[], None, Some((&path, count_lines(&path))));
        let parsed = Value::parse(&m.to_json()).unwrap();
        match parsed.get("telemetry").unwrap().get("segments").unwrap() {
            Value::Arr(a) => assert_eq!(a.len(), segments.len()),
            other => panic!("segments should be an array, got {other:?}"),
        }
        // ...and an unrotated stream's manifest carries no segments key
        // at all (byte-compatible with the pre-rotation format).
        let plain = dir.join("plain.jsonl");
        {
            let mut a = Appender::create(&plain).unwrap();
            a.counter("c", 0, 0.0, 0.0, &[]);
        }
        let m = build_manifest(&cfg, &[], None, Some((&plain, 1)));
        assert!(m.get("telemetry").unwrap().get("segments").is_err());
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn count_lines_counts_and_tolerates_absence() {
        let path = tmp("count_lines.jsonl");
        {
            let mut a = Appender::create(&path).unwrap();
            for i in 0..5 {
                a.counter("c", i, 0.0, i as f64, &[]);
            }
        }
        assert_eq!(count_lines(&path), 5);
        assert_eq!(count_lines(Path::new("/nonexistent/profl/stream.jsonl")), 0);
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn manifest_is_deterministic_modulo_wall_time() {
        let cfg = RunConfig::default();
        let argv = vec!["profl".to_string(), "run".to_string()];
        let m1 = build_manifest(&cfg, &argv, None, None);
        std::thread::sleep(std::time::Duration::from_millis(2));
        let m2 = build_manifest(&cfg, &argv, None, None);
        assert_eq!(
            strip_wall_time(&m1).to_json(),
            strip_wall_time(&m2).to_json(),
            "same config + argv ⇒ identical manifests modulo wall time"
        );
        // The manifest round-trips through the strict parser.
        let parsed = Value::parse(&m1.to_json()).unwrap();
        assert_eq!(parsed.get("schema").unwrap().as_u64().unwrap(), MANIFEST_SCHEMA);
        assert_eq!(
            parsed.get("config_sha256").unwrap().as_str().unwrap().len(),
            64
        );
        assert_eq!(parsed.get("seed").unwrap().as_str().unwrap(), "42");
    }

    #[test]
    fn config_hash_changes_when_any_flag_changes() {
        let base = RunConfig::default();
        let h0 = config_sha256(&base);
        assert_eq!(h0, config_sha256(&base.clone()), "hash is reproducible");

        let mut c = base.clone();
        c.seed = 43;
        assert_ne!(h0, config_sha256(&c), "seed");
        let mut c = base.clone();
        c.fleet.round_policy = "async".into();
        assert_ne!(h0, config_sha256(&c), "round policy");
        let mut c = base.clone();
        c.fleet.churn_policy = "resume".into();
        assert_ne!(h0, config_sha256(&c), "churn policy");
        let mut c = base.clone();
        c.fleet.stale_projection = "on".into();
        assert_ne!(h0, config_sha256(&c), "projection");
        let mut c = base.clone();
        c.dirichlet_alpha = Some(0.5);
        assert_ne!(h0, config_sha256(&c), "alpha");
        let mut c = base.clone();
        c.telemetry_jsonl = Some("t.jsonl".into());
        assert_ne!(h0, config_sha256(&c), "telemetry path");
        let mut c = base.clone();
        c.fleet.lazy_pool = true;
        assert_ne!(h0, config_sha256(&c), "lazy pool");
        let mut c = base.clone();
        c.strategy.name = Some("elastic".into());
        assert_ne!(h0, config_sha256(&c), "strategy name");
        let mut c = base.clone();
        c.strategy.elastic_phases = Some(3);
        assert_ne!(h0, config_sha256(&c), "elastic phases");
        let mut c = base.clone();
        c.strategy.freeze_step_cap = Some(16);
        assert_ne!(h0, config_sha256(&c), "freeze step cap");
    }

    #[test]
    fn method_stream_paths_are_unique_per_method() {
        let base = Path::new("runs/t.jsonl");
        assert_eq!(method_stream_path(base, "ProFL"), Path::new("runs/t.profl.jsonl"));
        assert_eq!(method_stream_path(base, "HeteroFL"), Path::new("runs/t.heterofl.jsonl"));
        // Extension-less bases still get distinct jsonl streams.
        assert_eq!(
            method_stream_path(Path::new("stream"), "DepthFL"),
            Path::new("stream.depthfl.jsonl")
        );
    }

    #[test]
    fn multi_manifest_records_every_stream() {
        let cfg = RunConfig::default();
        let argv = vec!["profl".to_string(), "compare".to_string()];
        let streams = vec![
            ("AllSmall".to_string(), PathBuf::from("t.allsmall.jsonl"), 10),
            ("ProFL".to_string(), PathBuf::from("t.profl.jsonl"), 42),
        ];
        let m = build_multi_manifest(&cfg, &argv, &streams);
        let parsed = Value::parse(&m.to_json()).unwrap();
        let list = match parsed.get("telemetry").unwrap().get("streams").unwrap() {
            Value::Arr(a) => a.clone(),
            other => panic!("streams should be an array, got {other:?}"),
        };
        assert_eq!(list.len(), 2);
        assert_eq!(list[0].get("method").unwrap().as_str().unwrap(), "AllSmall");
        assert_eq!(list[1].get("path").unwrap().as_str().unwrap(), "t.profl.jsonl");
        assert_eq!(list[1].get("lines").unwrap().as_u64().unwrap(), 42);
        // Deterministic modulo wall time, like the single-stream form.
        let m2 = build_multi_manifest(&cfg, &argv, &streams);
        assert_eq!(strip_wall_time(&m).to_json(), strip_wall_time(&m2).to_json());
    }

    #[test]
    fn manifest_write_creates_parents_and_roundtrips() {
        let path = tmp("nested/deeper/manifest.json");
        let cfg = RunConfig::smoke("m");
        let m = build_manifest(&cfg, &["x".to_string()], None, Some((Path::new("t.jsonl"), 7)));
        write_manifest(&path, &m).unwrap();
        let text = std::fs::read_to_string(&path).unwrap();
        let v = Value::parse(text.trim()).unwrap();
        let tel = v.get("telemetry").unwrap();
        assert_eq!(tel.get("path").unwrap().as_str().unwrap(), "t.jsonl");
        assert_eq!(tel.get("lines").unwrap().as_u64().unwrap(), 7);
        std::fs::remove_file(&path).ok();
    }
}
