//! HeteroFL baseline: static width scaling. Each client is assigned the
//! largest channel-scaled variant of the full model its memory affords
//! and always trains that variant; the server keeps the full-width global
//! model and aggregates channel slices position-wise (untouched channels
//! keep their previous value). Reproduces the paper's observation that
//! when no client affords high ratios, most of the model never trains and
//! accuracy collapses (ResNet34/VGG16 rows of Tables 1/2).
//!
//! Under the `async` round policy the width-sliced updates buffer the
//! same way the coordinator's do: window-missers are trained and parked
//! until the fleet reports their upload's arrival, then merged into the
//! sliced accumulator with a staleness-discounted weight.

use super::Method;
use crate::aggregate::{staleness_discount, transition_decay, SlicedAggregator};
use crate::config::RunConfig;
use crate::coordinator::round::partial_scaled;
use crate::coordinator::ServerCtx;
use crate::fleet::EventKind;
use crate::manifest::{Manifest, MemCoeffs};
use crate::metrics::RunSummary;
use crate::runtime::{literal_f32, literal_i32, Runtime};
use anyhow::{Context, Result};
use std::collections::HashMap;

/// The HeteroFL baseline (see module docs).
pub struct HeteroFL {
    /// Complexity levels, ascending by cost (the paper's 4 levels).
    pub ratios: Vec<f64>,
}

impl Default for HeteroFL {
    fn default() -> Self {
        HeteroFL { ratios: vec![0.125, 0.25, 0.5, 1.0] }
    }
}

/// One client's executed width-sliced update (plus its accounting).
struct SlicedUpdate {
    sub_shapes: Vec<Vec<usize>>,
    tensors: Vec<Vec<f32>>,
    weight: f64,
    loss: f32,
    bytes: u64,
    mem_bytes: u64,
}

/// Run one client's local pass on its assigned width variant: slice the
/// full global model down to the variant's corner shapes, execute, and
/// return the updated slices.
fn run_client(
    ctx: &mut ServerCtx<'_>,
    options: &[(String, MemCoeffs, u64)],
    opt_i: usize,
    cid: usize,
    scan: usize,
    batch: usize,
    lr_lit: &xla::Literal,
) -> Result<SlicedUpdate> {
    let (tag, mem, _) = &options[opt_i];
    let art = ctx.rt.load(tag, "train_full")?;

    // Slice the full global model down to this variant's shapes.
    let mut param_lits = Vec::with_capacity(art.meta.inputs.len());
    let mut sub_shapes = Vec::new();
    for entry in &art.meta.inputs {
        if entry.role != "trainable" {
            break;
        }
        let sub = ctx.store.get(&entry.name)?.slice_corner(&entry.shape)?;
        param_lits.push(literal_f32(&sub.shape, &sub.data)?);
        sub_shapes.push(sub.shape);
    }

    let weight = {
        let data = &ctx.dataset;
        let client = ctx.pool.client_mut(cid);
        client.shard.fill_batches(data, scan, batch, &mut ctx.xs_buf, &mut ctx.ys_buf);
        client.shard.num_samples() as f64
    };
    let xs = literal_f32(&[scan, batch, 32, 32, 3], &ctx.xs_buf)?;
    let ys = literal_i32(&[scan, batch], &ctx.ys_buf)?;
    let mut inputs: Vec<&xla::Literal> = param_lits.iter().collect();
    inputs.push(&xs);
    inputs.push(&ys);
    inputs.push(lr_lit);
    let outs = art.execute(&inputs)?;
    let (updated, scalars) = Runtime::unpack_train_outputs(&art.meta, outs)?;
    Ok(SlicedUpdate {
        sub_shapes,
        tensors: updated.into_iter().map(|(_, v)| v).collect(),
        weight,
        loss: scalars[0],
        bytes: art.meta.trainable_bytes(),
        mem_bytes: mem.bytes_at(ctx.cfg.memory.accounting_batch),
    })
}

impl Method for HeteroFL {
    fn name(&self) -> &'static str {
        "HeteroFL"
    }

    fn inclusive(&self) -> bool {
        true
    }

    fn run(&self, rt: &Runtime, cfg: &RunConfig) -> Result<RunSummary> {
        let mut ctx = ServerCtx::new(rt, cfg.clone())?;
        let base = rt.model(&cfg.model_tag)?;
        let num_blocks = base.num_blocks;
        let scan = rt.manifest.scan_steps;
        let batch = rt.manifest.train_batch;
        let alpha = ctx.cfg.fleet.staleness_alpha;

        // Resolve each ratio's tag + memory need + comm bytes (ascending).
        let mut options: Vec<(String, MemCoeffs, u64)> = Vec::new();
        for &r in &self.ratios {
            let tag = Manifest::ratio_tag(&cfg.model_tag, r);
            let model = rt.model(&tag).with_context(|| format!("HeteroFL needs ratio tag {tag}"))?;
            let art = model.artifact("train_full")?;
            options.push((tag, art.participation_mem(), art.trainable_bytes()));
        }
        let mems: Vec<MemCoeffs> = options.iter().map(|(_, m, _)| *m).collect();
        let assignment = ctx.pool.capability_assignment(&mems);
        let pr = assignment.iter().filter(|a| a.is_some()).count() as f64 / assignment.len() as f64;

        // Full-model trainable list (order = train_full input order).
        let full_art = base.artifact("train_full")?.clone();
        let trainable: Vec<String> =
            full_art.trainable_names().iter().map(|s| s.to_string()).collect();
        let eval_art = format!("eval_t{num_blocks}");
        let zero = MemCoeffs::default();

        // Async policy: trained-but-not-arrived sliced updates, keyed by
        // client, stamped with their dispatch round, the prefix version
        // at dispatch, and whether they are churn-checkpointed partials.
        let mut pending: HashMap<usize, (SlicedUpdate, usize, u64, bool)> = HashMap::new();

        ctx.bump_prefix_version();
        for round in 0..ctx.cfg.max_rounds_total {
            // Uniform sample, minus clients with uploads still in flight.
            let sel = ctx.sample_cohort(&zero);
            // Fleet dispatch: each assigned client's variant sets its FLOPs
            // proxy and comm bytes; the round policy trims the cohort.
            let mut works = Vec::new();
            for &cid in &sel.trainers {
                let Some(opt_i) = assignment[cid] else { continue }; // too small: dropped
                let (_, mem, tr_b) = &options[opt_i];
                works.push(ctx.client_work(cid, mem, *tr_b, *tr_b));
            }
            if ctx.async_params().is_some() {
                // A fresh dispatch supersedes the client's stale buffered
                // update (mirrors the fleet engine's in-flight queue).
                for w in &works {
                    pending.remove(&w.id);
                }
            }
            let plan = ctx.run_fleet(&works);
            // Selection-order aggregation (see coordinator::round).
            let completers: Vec<usize> =
                sel.trainers.iter().copied().filter(|id| plan.completers.contains(id)).collect();
            let deferred: Vec<usize> =
                sel.trainers.iter().copied().filter(|id| plan.deferred.contains(id)).collect();
            // Churn partials: scale the sliced update's weight by the
            // checkpointed fraction (mirrors coordinator::round).
            let fractions: HashMap<usize, f64> = plan.partials.iter().copied().collect();

            let lr_lit = xla::Literal::scalar(ctx.cfg.lr);
            let mut agg = SlicedAggregator::new(&trainable, &ctx.store)?;
            agg.set_merge_threads(ctx.engine.threads());
            let mut participants = 0usize;
            let mut partial_merged = 0usize;
            let (mut bytes_up, mut bytes_down) = (0u64, 0u64);
            let (mut loss_sum, mut w_sum) = (0.0f64, 0.0f64);
            let mut mem_peak = 0u64;

            for &cid in &completers {
                let Some(opt_i) = assignment[cid] else { continue };
                let mut u = run_client(&mut ctx, &options, opt_i, cid, scan, batch, &lr_lit)?;
                u.weight = partial_scaled(&fractions, cid, u.weight, &mut partial_merged);
                loss_sum += u.loss as f64 * u.weight;
                w_sum += u.weight;
                bytes_up += u.bytes;
                bytes_down += u.bytes;
                mem_peak = mem_peak.max(u.mem_bytes);
                // No clone: the sliced update moves into the accumulator.
                agg.add_owned(u.sub_shapes, u.tensors, u.weight);
                participants += 1;
            }

            // Async policy: train window-missers now (their upload is in
            // flight) and merge earlier rounds' arrivals discounted.
            // NOTE: this mirrors ServerCtx::{run_fleet supersede,
            // take_late_arrivals} and depthfl's copy — keep the three
            // consistent when touching staleness/supersede semantics.
            let (mut late_merged, mut late_dropped, mut staleness_sum) = (0usize, 0usize, 0usize);
            if let Some((_, max_staleness)) = ctx.async_params() {
                for &cid in &deferred {
                    let Some(opt_i) = assignment[cid] else { continue };
                    let mut u = run_client(&mut ctx, &options, opt_i, cid, scan, batch, &lr_lit)?;
                    bytes_down += u.bytes;
                    mem_peak = mem_peak.max(u.mem_bytes);
                    // Deferred partials buffer their scaled weight so the
                    // late merge inherits the right sample count.
                    let partial = match fractions.get(&cid) {
                        Some(f) => {
                            u.weight *= f;
                            true
                        }
                        None => false,
                    };
                    pending.insert(cid, (u, ctx.round, ctx.prefix_version, partial));
                }
                for la in &plan.late_arrivals {
                    if let Some((u, dispatched, dispatch_pv, partial)) = pending.remove(&la.client)
                    {
                        let staleness = ctx.round.saturating_sub(dispatched);
                        if staleness <= max_staleness {
                            // HeteroFL's width slices never freeze, so a
                            // late merge crosses no layout change; the
                            // transition decay (projection semantics,
                            // shared with the coordinator) is exactly 1.0
                            // while the prefix version holds — which it
                            // does for this method's whole run.
                            let crossed = ctx.prefix_version.saturating_sub(dispatch_pv);
                            let decay = ctx.projection.unwrap_or(1.0);
                            let w = u.weight
                                * staleness_discount(staleness, alpha)
                                * transition_decay(decay, crossed);
                            bytes_up += u.bytes;
                            agg.add_owned(u.sub_shapes, u.tensors, w);
                            late_merged += 1;
                            if partial {
                                partial_merged += 1;
                            }
                            staleness_sum += staleness;
                        } else {
                            // Arrived but too stale: the upload still
                            // happened — charge it and record the discard.
                            bytes_up += u.bytes;
                            late_dropped += 1;
                        }
                    }
                }
            }

            // Downloads shipped to policy-cut stragglers and churn
            // casualties cost bandwidth even though their updates never
            // aggregate (dropouts vanish at dispatch, before the
            // download). Async plans truncate events at the close, so
            // post-close aborts are charged off the aborted list. A
            // mid-download abort is charged only its fetched fraction.
            let mut lost: Vec<usize> = Vec::new();
            for ev in &plan.events {
                if let EventKind::Dispatch { client } = ev.kind {
                    if plan.completers.contains(&client)
                        || plan.deferred.contains(&client)
                        || plan.dropouts.contains(&client)
                    {
                        continue;
                    }
                    lost.push(client);
                }
            }
            for &client in &plan.aborted {
                if !lost.contains(&client) {
                    lost.push(client);
                }
            }
            for client in lost {
                if let Some(opt_i) = assignment[client] {
                    let full = options[opt_i].2;
                    let frac = plan.download_fraction(client);
                    bytes_down += if frac >= 1.0 { full } else { (frac * full as f64) as u64 };
                }
            }

            let (mut merge_workers, mut merge_utilization) = (0usize, 0.0f64);
            if agg.total_weight() > 0.0 {
                let stats = agg.finish_stats(&mut ctx.store)?;
                merge_workers = stats.workers;
                merge_utilization = stats.utilization();
            }
            ctx.round += 1;

            let test_acc = if round % ctx.cfg.eval_every == 0 || round + 1 == ctx.cfg.max_rounds_total {
                ctx.evaluate(&eval_art)?.acc
            } else {
                f32::NAN
            };
            let out = crate::coordinator::RoundOutcome {
                mean_loss: if w_sum > 0.0 { (loss_sum / w_sum) as f32 } else { f32::NAN },
                participants,
                bytes_up,
                bytes_down,
                client_mem_bytes: mem_peak,
                sim_time_s: plan.duration_s(),
                stragglers: plan.stragglers.len(),
                dropouts: plan.dropouts.len(),
                deferred: plan.deferred.len(),
                late_merged,
                late_dropped,
                mean_staleness: if late_merged > 0 {
                    staleness_sum as f64 / late_merged as f64
                } else {
                    0.0
                },
                interrupted: plan.interrupts,
                resumed: plan.resumes,
                partial_merged,
                wasted_compute_s: plan.wasted_compute_s,
                merge_workers,
                merge_utilization,
                ..Default::default()
            };
            ctx.record_round("heterofl", 0, &out, test_acc, f64::NAN);
        }

        let (up, down) = ctx.metrics.total_bytes();
        Ok(RunSummary {
            method: self.name().into(),
            model_tag: cfg.model_tag.clone(),
            partition: cfg.partition().label(),
            final_acc: ctx.metrics.final_acc(ctx.cfg.acc_tail),
            participation_rate: pr,
            peak_client_mem: ctx.metrics.peak_client_mem(),
            total_bytes_up: up,
            total_bytes_down: down,
            rounds: ctx.round,
            sim_time_s: ctx.sim_time_s,
            transitions: ctx.transition_log().entries().to_vec(),
            history: ctx.metrics.records.clone(),
        })
    }
}
