//! ProFL (the paper's method): progressive model shrinking → progressive
//! model growing, block freezing determination, memory-aware cohorts with
//! output-layer fallback.
//!
//! Shrinking (§3.2): train blocks T→2 back-to-front (prefix frozen at
//! init), then *Map* each converged block into its surrogate conv via
//! federated distillation. Yields (a) init parameters for every block and
//! (b) the output modules used while growing.
//!
//! Growing (§3.1): train blocks 1→T front-to-back on top of the frozen,
//! already-converged prefix; each step's sub-model is
//! [θ*₁,F … θ*ₜ₋₁,F, θₜ, θ_op].
//!
//! Freezing (§3.3): the effective-movement detector by default;
//! `FreezePolicy::ParamAware` reproduces Table 4's baseline (rounds
//! allocated ∝ block parameter count).

use super::Method;
use crate::config::RunConfig;
use crate::coordinator::ServerCtx;
use crate::freezing::FreezeDetector;
use crate::metrics::RunSummary;
use crate::runtime::Runtime;
use anyhow::Result;

/// How a progressive step decides it is done.
#[derive(Debug, Clone, Copy, PartialEq, Default)]
pub enum FreezePolicy {
    /// Effective movement + least-squares slope (the paper's §3.3).
    #[default]
    EffectiveMovement,
    /// Table 4 baseline: per-step round budget ∝ block parameter count.
    ParamAware,
}

/// The paper's method: progressive shrink → grow with block freezing.
#[derive(Default)]
pub struct ProFL {
    /// Freeze-decision policy for each progressive step.
    pub policy: FreezePolicy,
    /// Override cfg.shrinking (used by the `profl-noshrink` ablation).
    pub shrinking_override: Option<bool>,
}

impl ProFL {
    /// Round budget for step t under ParamAware: share of the total grow
    /// budget proportional to the block's parameter count (min 4 rounds).
    fn param_aware_rounds(counts: &[u64], t: usize, total_budget: usize) -> usize {
        let total: u64 = counts.iter().sum();
        let share = counts[t - 1] as f64 / total as f64;
        ((total_budget as f64 * share) as usize).max(4)
    }

    /// Train one progressive step until frozen/budget-exhausted.
    /// Returns the number of rounds consumed.
    fn run_step(
        &self,
        ctx: &mut ServerCtx,
        t: usize,
        stage: &str,
        lr: f32,
        budget: usize,
    ) -> Result<usize> {
        // Borrow the model entry through `rt` (independent of &mut ctx).
        let rt = ctx.rt;
        let tag = ctx.cfg.model_tag.clone();
        let model = rt.model(&tag)?;
        let block_names: Vec<String> = model.block_params[t - 1].clone();
        let counts = model.block_param_counts.clone();
        let train_art = format!("train_t{t}");
        let op_art = format!("train_op_t{t}");
        let eval_art = format!("eval_t{t}");

        let max_rounds = match self.policy {
            FreezePolicy::EffectiveMovement => ctx.cfg.max_rounds_per_step.min(budget),
            FreezePolicy::ParamAware => {
                Self::param_aware_rounds(&counts, t, ctx.cfg.max_rounds_per_step * counts.len())
                    .min(budget)
            }
        };
        let min_rounds = ctx.cfg.min_rounds_per_step.min(max_rounds);
        let mut det = FreezeDetector::new(ctx.cfg.freeze.into());

        let mut used = 0;
        for r in 0..max_rounds {
            let out = ctx.run_train_round(&train_art, Some(&op_art), lr, stage, t)?;
            let snapshot = ctx.store.flatten(&block_names);
            let t_observe = ctx.telemetry_mut().is_some().then(std::time::Instant::now);
            let (em, em_freeze) = det.observe(&snapshot);
            if let Some(t0) = t_observe {
                let round = ctx.round;
                let sim_s = ctx.sim_time_s;
                let consecutive = det.consecutive();
                if let Some(tel) = ctx.telemetry_mut() {
                    use crate::json::Value;
                    let attrs = [
                        ("stage", Value::Str(stage.to_string())),
                        ("step", Value::Num(t as f64)),
                        ("consecutive", Value::Num(consecutive as f64)),
                        ("freeze", Value::Bool(em_freeze)),
                    ];
                    tel.span("freeze.observe", round, sim_s, t0.elapsed().as_secs_f64(), &attrs);
                    tel.gauge("freeze.em", round, sim_s, em.unwrap_or(f64::NAN), &attrs);
                }
            }
            let test_acc = if r % ctx.cfg.eval_every == 0 || r + 1 == max_rounds {
                ctx.evaluate(&eval_art)?.acc
            } else {
                f32::NAN
            };
            ctx.record_round(stage, t, &out, test_acc, em.unwrap_or(f64::NAN));
            used += 1;
            let freeze = match self.policy {
                FreezePolicy::EffectiveMovement => em_freeze,
                FreezePolicy::ParamAware => false, // runs to its budget
            };
            if freeze && r + 1 >= min_rounds {
                break;
            }
        }
        Ok(used)
    }
}

impl Method for ProFL {
    fn name(&self) -> &'static str {
        match self.policy {
            FreezePolicy::EffectiveMovement => "ProFL",
            FreezePolicy::ParamAware => "ParamAware",
        }
    }

    fn inclusive(&self) -> bool {
        true
    }

    fn run(&self, rt: &Runtime, cfg: &RunConfig) -> Result<RunSummary> {
        let mut cfg = cfg.clone();
        if let Some(s) = self.shrinking_override {
            cfg.shrinking = s;
        }
        let mut ctx = ServerCtx::new(rt, cfg.clone())?;
        let model = rt.model(&cfg.model_tag)?;
        let num_blocks = model.num_blocks;
        let op_mem = model
            .artifact(&format!("train_op_t{num_blocks}"))
            .map(|a| a.participation_mem())
            .unwrap_or_default();

        let mut lr = ctx.cfg.lr;
        let mut remaining = ctx.cfg.max_rounds_total * 2; // shrink + grow budget

        // ---- Stage 1: progressive model shrinking (T → 2) -------------------
        if ctx.cfg.shrinking {
            for t in (2..=num_blocks).rev() {
                ctx.bump_prefix_version();
                let used = self.run_step(&mut ctx, t, "shrink", lr, remaining)?;
                remaining = remaining.saturating_sub(used);
                // Map: distill the converged block into its surrogate.
                let distill_art = format!("distill_t{t}");
                for _ in 0..ctx.cfg.distill_rounds {
                    let out = ctx.run_distill_round(&distill_art, lr)?;
                    ctx.record_round("map", t, &out, f32::NAN, f64::NAN);
                    remaining = remaining.saturating_sub(1);
                }
            }
        }

        // ---- Stage 2: progressive model growing (1 → T) ---------------------
        for t in 1..=num_blocks {
            ctx.bump_prefix_version();
            let budget = remaining.max(ctx.cfg.min_rounds_per_step);
            let used = self.run_step(&mut ctx, t, "grow", lr, budget)?;
            remaining = remaining.saturating_sub(used);
            lr *= ctx.cfg.lr_step_decay;
        }

        // ---- Summary ---------------------------------------------------------
        let final_eval = ctx.evaluate(&format!("eval_t{num_blocks}"))?;
        let (up, down) = ctx.metrics.total_bytes();
        let mut final_acc = ctx.metrics.final_acc(ctx.cfg.acc_tail);
        if final_acc == 0.0 {
            final_acc = final_eval.acc as f64;
        }
        // ProFL participation: anyone who can at least train the output
        // layer takes part (§4.1) — effectively the whole fleet.
        let pr = ctx.pool.participation_rate(&op_mem);
        Ok(RunSummary {
            method: self.name().into(),
            model_tag: ctx.cfg.model_tag.clone(),
            partition: ctx.cfg.partition().label(),
            final_acc,
            participation_rate: pr,
            peak_client_mem: ctx.metrics.peak_client_mem(),
            total_bytes_up: up,
            total_bytes_down: down,
            rounds: ctx.round,
            sim_time_s: ctx.sim_time_s,
            transitions: ctx.transition_log().entries().to_vec(),
            history: ctx.metrics.records.clone(),
        })
    }
}
