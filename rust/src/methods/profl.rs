//! ProFL (the paper's method): progressive model shrinking → progressive
//! model growing, block freezing determination, memory-aware cohorts with
//! output-layer fallback.
//!
//! The schedule itself — shrink T→2 with *Map* distillation, grow 1→T,
//! EM-gated freezing (or the ParamAware budget baseline) — lives in
//! [`strategy::progressive`](crate::strategy::progressive) as a
//! [`MemoryStrategy`](crate::strategy::MemoryStrategy); this method is
//! the thin [`Method`] adapter that applies the `profl-noshrink`
//! ablation override and hands the schedule to the shared
//! [`run_strategy`](crate::strategy::run_strategy) driver. The port is
//! bit-for-bit: the driver replays the legacy round loop call-for-call,
//! so per-round records and golden traces are unchanged.

use super::Method;
use crate::config::RunConfig;
use crate::metrics::RunSummary;
use crate::runtime::Runtime;
use crate::strategy::{run_strategy, Progressive};
use anyhow::Result;

pub use crate::strategy::progressive::FreezePolicy;

/// The paper's method: progressive shrink → grow with block freezing.
#[derive(Default)]
pub struct ProFL {
    /// Freeze-decision policy for each progressive step.
    pub policy: FreezePolicy,
    /// Override cfg.shrinking (used by the `profl-noshrink` ablation).
    pub shrinking_override: Option<bool>,
}

impl Method for ProFL {
    fn name(&self) -> &'static str {
        match self.policy {
            FreezePolicy::EffectiveMovement => "ProFL",
            FreezePolicy::ParamAware => "ParamAware",
        }
    }

    fn inclusive(&self) -> bool {
        true
    }

    fn run(&self, rt: &Runtime, cfg: &RunConfig) -> Result<RunSummary> {
        let mut cfg = cfg.clone();
        if let Some(s) = self.shrinking_override {
            cfg.shrinking = s;
        }
        let mut schedule = Progressive::new(self.policy);
        run_strategy(&mut schedule, rt, &cfg)
    }
}
