//! AllSmall baseline: the global model is width-scaled down until the
//! minimum-memory client can train it, so every device participates —
//! at the cost of a severely limited architecture (paper §4.1).

use super::Method;
use crate::config::RunConfig;
use crate::coordinator::ServerCtx;
use crate::manifest::Manifest;
use crate::metrics::RunSummary;
use crate::runtime::Runtime;
use anyhow::{Context, Result};

/// The AllSmall baseline (see module docs).
pub struct AllSmall {
    /// Width ratios to consider, descending (the first that fits ~everyone
    /// wins; the paper sizes by the minimum client memory).
    pub ratios: Vec<f64>,
}

impl Default for AllSmall {
    fn default() -> Self {
        AllSmall { ratios: vec![0.5, 0.25, 0.125] }
    }
}

impl Method for AllSmall {
    fn name(&self) -> &'static str {
        "AllSmall"
    }

    fn inclusive(&self) -> bool {
        true
    }

    fn run(&self, rt: &Runtime, cfg: &RunConfig) -> Result<RunSummary> {
        // Probe a throwaway pool (same seed ⇒ same device budgets as every
        // other method) to size the global model by the minimum client.
        let probe = ServerCtx::new(rt, cfg.clone())?;
        let mut chosen: Option<(String, f64)> = None;
        for &r in &self.ratios {
            let tag = Manifest::ratio_tag(&cfg.model_tag, r);
            let Ok(model) = rt.model(&tag) else { continue };
            let mem = model.artifact("train_full")?.participation_mem();
            if probe.pool.participation_rate(&mem) >= 1.0 {
                chosen = Some((tag, r));
                break;
            }
        }
        // Nothing fits everyone: take the smallest available ratio.
        let (tag, _ratio) = match chosen {
            Some(c) => c,
            None => {
                let r = *self.ratios.last().context("no ratios configured")?;
                (Manifest::ratio_tag(&cfg.model_tag, r), r)
            }
        };
        drop(probe);

        // Train the small global model end-to-end with everyone.
        let mut small_cfg = cfg.clone();
        small_cfg.model_tag = tag.clone();
        let mut ctx = ServerCtx::new(rt, small_cfg)?;
        let model = rt.model(&tag)?;
        let num_blocks = model.num_blocks;
        let full_mem = model.artifact("train_full")?.participation_mem();
        let pr = ctx.pool.participation_rate(&full_mem);
        let eval_art = format!("eval_t{num_blocks}");

        ctx.bump_prefix_version();
        for r in 0..ctx.cfg.max_rounds_total {
            let out = ctx.run_train_round("train_full", None, ctx.cfg.lr, "allsmall", 0)?;
            let test_acc = if r % ctx.cfg.eval_every == 0 || r + 1 == ctx.cfg.max_rounds_total {
                ctx.evaluate(&eval_art)?.acc
            } else {
                f32::NAN
            };
            ctx.record_round("allsmall", 0, &out, test_acc, f64::NAN);
        }

        let (up, down) = ctx.metrics.total_bytes();
        Ok(RunSummary {
            method: self.name().into(),
            model_tag: cfg.model_tag.clone(),
            partition: cfg.partition().label(),
            final_acc: ctx.metrics.final_acc(ctx.cfg.acc_tail),
            participation_rate: pr,
            peak_client_mem: ctx.metrics.peak_client_mem(),
            total_bytes_up: up,
            total_bytes_down: down,
            rounds: ctx.round,
            sim_time_s: ctx.sim_time_s,
            transitions: ctx.transition_log().entries().to_vec(),
            history: ctx.metrics.records.clone(),
        })
    }
}
