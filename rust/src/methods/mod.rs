//! FL methods: ProFL (the paper) + all four baselines (Tables 1/2), the
//! ParamAware freezing baseline (Table 4), and the memory-strategy zoo
//! additions (`layerfreeze`, `elastic` — see `docs/STRATEGIES.md`).
//!
//! Every method consumes the same primitives (`ServerCtx` rounds) and
//! produces a `RunSummary`, so the table benches are a cartesian product
//! of (method × model × dataset × partition) over one interface.
//!
//! The single [`registry`] drives both [`by_name`] (CLI lookup,
//! including aliases) and [`table_methods`] (paper-table order), so the
//! two can no longer drift apart; `profl --list-methods` prints it.

pub mod allsmall;
pub mod depthfl;
pub mod exclusive;
pub mod heterofl;
pub mod profl;

use crate::config::RunConfig;
use crate::metrics::RunSummary;
use crate::runtime::Runtime;
use anyhow::Result;

pub use allsmall::AllSmall;
pub use depthfl::DepthFL;
pub use exclusive::ExclusiveFL;
pub use heterofl::HeteroFL;
pub use profl::{FreezePolicy, ProFL};

pub use crate::strategy::{Elastic, LayerFreeze};

/// One FL method (ProFL or a baseline), runnable end to end.
pub trait Method {
    /// Display name (tables, CLI).
    fn name(&self) -> &'static str;
    /// Whether the method can use every client (the paper's "Inclusive?").
    fn inclusive(&self) -> bool;
    /// Execute a full run and produce its summary.
    fn run(&self, rt: &Runtime, cfg: &RunConfig) -> Result<RunSummary>;
}

/// One registry row: the canonical CLI name, accepted aliases, whether
/// the method joins the Table-1 `compare` sweep (in registry order),
/// the paper's "Inclusive?" flag, and the constructor.
pub struct MethodSpec {
    /// Canonical CLI spelling (lowercase).
    pub name: &'static str,
    /// Additional accepted CLI spellings.
    pub aliases: &'static [&'static str],
    /// Whether `table_methods()` (the `compare` subcommand) includes it.
    pub table: bool,
    /// The paper's "Inclusive?" column.
    pub inclusive: bool,
    /// Constructor.
    pub make: fn() -> Box<dyn Method>,
}

/// The single source of truth for every runnable method.
pub fn registry() -> &'static [MethodSpec] {
    &REGISTRY
}

static REGISTRY: [MethodSpec; 9] = [
    MethodSpec {
        name: "allsmall",
        aliases: &[],
        table: true,
        inclusive: true,
        make: || Box::new(AllSmall::default()),
    },
    MethodSpec {
        name: "exclusivefl",
        aliases: &["exclusive"],
        table: true,
        inclusive: false,
        make: || Box::new(ExclusiveFL),
    },
    MethodSpec {
        name: "heterofl",
        aliases: &[],
        table: true,
        inclusive: true,
        make: || Box::new(HeteroFL::default()),
    },
    MethodSpec {
        name: "depthfl",
        aliases: &[],
        table: true,
        inclusive: true,
        make: || Box::new(DepthFL),
    },
    MethodSpec {
        name: "profl",
        aliases: &[],
        table: true,
        inclusive: true,
        make: || Box::new(ProFL::default()),
    },
    MethodSpec {
        name: "profl-noshrink",
        aliases: &[],
        table: false,
        inclusive: true,
        make: || Box::new(ProFL { shrinking_override: Some(false), ..Default::default() }),
    },
    MethodSpec {
        name: "paramaware",
        aliases: &[],
        table: false,
        inclusive: true,
        make: || Box::new(ProFL { policy: FreezePolicy::ParamAware, ..Default::default() }),
    },
    MethodSpec {
        name: "layerfreeze",
        aliases: &["layer-freeze"],
        table: false,
        inclusive: true,
        make: || Box::new(LayerFreeze::default()),
    },
    MethodSpec {
        name: "elastic",
        aliases: &["neulite"],
        table: false,
        inclusive: true,
        make: || Box::new(Elastic::default()),
    },
];

/// All Table-1/2 methods in paper order.
pub fn table_methods() -> Vec<Box<dyn Method>> {
    registry().iter().filter(|s| s.table).map(|s| (s.make)()).collect()
}

/// Look up a method by CLI name (canonical or alias, case-insensitive).
pub fn by_name(name: &str) -> Option<Box<dyn Method>> {
    let lower = name.to_ascii_lowercase();
    registry()
        .iter()
        .find(|s| s.name == lower || s.aliases.contains(&lower.as_str()))
        .map(|s| (s.make)())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn registry_round_trips_every_name_and_alias() {
        for spec in registry() {
            let m = by_name(spec.name).unwrap_or_else(|| panic!("{} unresolvable", spec.name));
            assert_eq!(m.inclusive(), spec.inclusive, "{}: inclusive flag drifted", spec.name);
            for alias in spec.aliases {
                let a = by_name(alias).unwrap_or_else(|| panic!("alias {alias} unresolvable"));
                assert_eq!(a.name(), m.name(), "alias {alias} resolves elsewhere");
                assert_eq!(a.inclusive(), m.inclusive());
            }
            // Case-insensitive lookup resolves to the same method.
            let upper = by_name(&spec.name.to_ascii_uppercase()).expect("case-insensitive");
            assert_eq!(upper.name(), m.name());
        }
        assert!(by_name("warpdrive").is_none());
        assert!(by_name("").is_none());
    }

    #[test]
    fn table_methods_follow_registry_order() {
        let names: Vec<&str> = table_methods().iter().map(|m| m.name()).collect();
        assert_eq!(names, vec!["AllSmall", "ExclusiveFL", "HeteroFL", "DepthFL", "ProFL"]);
    }

    #[test]
    fn canonical_names_are_unique_and_lowercase() {
        let mut seen = std::collections::BTreeSet::new();
        for spec in registry() {
            assert_eq!(spec.name, spec.name.to_ascii_lowercase());
            assert!(seen.insert(spec.name), "duplicate canonical name {}", spec.name);
            for alias in spec.aliases {
                assert!(seen.insert(alias), "alias {alias} shadows another name");
            }
        }
    }
}
