//! FL methods: ProFL (the paper) + all four baselines (Tables 1/2) and the
//! ParamAware freezing baseline (Table 4).
//!
//! Every method consumes the same primitives (`ServerCtx` rounds) and
//! produces a `RunSummary`, so the table benches are a cartesian product
//! of (method × model × dataset × partition) over one interface.

pub mod allsmall;
pub mod depthfl;
pub mod exclusive;
pub mod heterofl;
pub mod profl;

use crate::config::RunConfig;
use crate::metrics::RunSummary;
use crate::runtime::Runtime;
use anyhow::Result;

pub use allsmall::AllSmall;
pub use depthfl::DepthFL;
pub use exclusive::ExclusiveFL;
pub use heterofl::HeteroFL;
pub use profl::{FreezePolicy, ProFL};

/// One FL method (ProFL or a baseline), runnable end to end.
pub trait Method {
    /// Display name (tables, CLI).
    fn name(&self) -> &'static str;
    /// Whether the method can use every client (the paper's "Inclusive?").
    fn inclusive(&self) -> bool;
    /// Execute a full run and produce its summary.
    fn run(&self, rt: &Runtime, cfg: &RunConfig) -> Result<RunSummary>;
}

/// All Table-1/2 methods in paper order.
pub fn table_methods() -> Vec<Box<dyn Method>> {
    vec![
        Box::new(AllSmall::default()),
        Box::new(ExclusiveFL),
        Box::new(HeteroFL::default()),
        Box::new(DepthFL),
        Box::new(ProFL::default()),
    ]
}

/// Look up a method by CLI name.
pub fn by_name(name: &str) -> Option<Box<dyn Method>> {
    match name.to_ascii_lowercase().as_str() {
        "profl" => Some(Box::new(ProFL::default())),
        "profl-noshrink" => Some(Box::new(ProFL { shrinking_override: Some(false), ..Default::default() })),
        "paramaware" => Some(Box::new(ProFL { policy: FreezePolicy::ParamAware, ..Default::default() })),
        "allsmall" => Some(Box::new(AllSmall::default())),
        "exclusivefl" | "exclusive" => Some(Box::new(ExclusiveFL)),
        "heterofl" => Some(Box::new(HeteroFL::default())),
        "depthfl" => Some(Box::new(DepthFL)),
        _ => None,
    }
}
