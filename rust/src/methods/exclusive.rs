//! ExclusiveFL baseline: only clients with enough memory for the *full*
//! model participate; everyone else is simply dropped (paper §4.1). On
//! large models no client qualifies and training is impossible (the "NA"
//! cells of Tables 1/2).

use super::Method;
use crate::config::RunConfig;
use crate::coordinator::ServerCtx;
use crate::metrics::RunSummary;
use crate::runtime::Runtime;
use anyhow::Result;

/// The ExclusiveFL baseline (see module docs).
pub struct ExclusiveFL;

impl Method for ExclusiveFL {
    fn name(&self) -> &'static str {
        "ExclusiveFL"
    }

    fn inclusive(&self) -> bool {
        false
    }

    fn run(&self, rt: &Runtime, cfg: &RunConfig) -> Result<RunSummary> {
        let mut ctx = ServerCtx::new(rt, cfg.clone())?;
        let model = rt.model(&cfg.model_tag)?;
        let num_blocks = model.num_blocks;
        let full_mem = model.artifact("train_full")?.participation_mem();
        let pr = ctx.pool.participation_rate(&full_mem);

        if pr == 0.0 {
            // No client can train the full model: the method cannot run.
            return Ok(RunSummary {
                method: self.name().into(),
                model_tag: cfg.model_tag.clone(),
                partition: cfg.partition().label(),
                final_acc: f64::NAN,
                participation_rate: 0.0,
                peak_client_mem: 0,
                total_bytes_up: 0,
                total_bytes_down: 0,
                rounds: 0,
                sim_time_s: 0.0,
                transitions: ctx.transition_log().entries().to_vec(),
                history: Vec::new(),
            });
        }

        let eval_art = format!("eval_t{num_blocks}");
        ctx.bump_prefix_version();
        for r in 0..ctx.cfg.max_rounds_total {
            // No fallback: memory-constrained sampled clients are dropped.
            let out = ctx.run_train_round("train_full", None, ctx.cfg.lr, "exclusive", 0)?;
            let test_acc = if r % ctx.cfg.eval_every == 0 || r + 1 == ctx.cfg.max_rounds_total {
                ctx.evaluate(&eval_art)?.acc
            } else {
                f32::NAN
            };
            ctx.record_round("exclusive", 0, &out, test_acc, f64::NAN);
        }

        let (up, down) = ctx.metrics.total_bytes();
        Ok(RunSummary {
            method: self.name().into(),
            model_tag: cfg.model_tag.clone(),
            partition: cfg.partition().label(),
            final_acc: ctx.metrics.final_acc(ctx.cfg.acc_tail),
            participation_rate: pr,
            peak_client_mem: ctx.metrics.peak_client_mem(),
            total_bytes_up: up,
            total_bytes_down: down,
            rounds: ctx.round,
            sim_time_s: ctx.sim_time_s,
            transitions: ctx.transition_log().entries().to_vec(),
            history: ctx.metrics.records.clone(),
        })
    }
}
