//! DepthFL baseline: depth scaling. Each client trains the deepest prefix
//! (blocks 1..d with a classifier per block, mutual self-distillation)
//! its memory affords; clients that cannot fit even depth 1 are dropped —
//! which is what caps DepthFL's participation (§4.2), since depth-1 still
//! retains the memory-heavy first block's activations. Inference is the
//! ensemble (mean softmax) of all classifiers.
//!
//! Under the `async` round policy the per-depth updates buffer like the
//! coordinator's: window-missers are trained and parked until the fleet
//! reports their upload's arrival, then merged into the per-parameter
//! accumulator with a staleness-discounted weight.

use super::Method;
use crate::aggregate::{staleness_discount, transition_decay};
use crate::config::RunConfig;
use crate::coordinator::round::partial_scaled;
use crate::coordinator::ServerCtx;
use crate::fleet::EventKind;
use crate::manifest::MemCoeffs;
use crate::metrics::RunSummary;
use crate::runtime::{literal_f32, literal_i32, Runtime};
use anyhow::Result;
use std::collections::HashMap;

/// The DepthFL baseline (see module docs).
pub struct DepthFL;

/// One client's executed depth-prefix update (named tensors, since each
/// depth covers a different parameter subset).
struct DepthUpdate {
    updated: Vec<(String, Vec<f32>)>,
    weight: f64,
    loss: f32,
    bytes: u64,
    mem_bytes: u64,
}

/// Run one client's local pass on its assigned depth artifact.
fn run_client(
    ctx: &mut ServerCtx<'_>,
    depth_index: usize,
    mems: &[MemCoeffs],
    cid: usize,
    scan: usize,
    batch: usize,
    lr_lit: &xla::Literal,
) -> Result<DepthUpdate> {
    let d = depth_index + 1;
    let tag = ctx.cfg.model_tag.clone();
    let art = ctx.rt.load(&tag, &format!("depthfl_train_d{d}"))?;
    let param_lits = ctx.rt.param_literals(&art.meta, &ctx.store)?;
    let weight = {
        let data = &ctx.dataset;
        let client = ctx.pool.client_mut(cid);
        client.shard.fill_batches(data, scan, batch, &mut ctx.xs_buf, &mut ctx.ys_buf);
        client.shard.num_samples() as f64
    };
    let xs = literal_f32(&[scan, batch, 32, 32, 3], &ctx.xs_buf)?;
    let ys = literal_i32(&[scan, batch], &ctx.ys_buf)?;
    let mut inputs: Vec<&xla::Literal> = param_lits.iter().collect();
    inputs.push(&xs);
    inputs.push(&ys);
    inputs.push(lr_lit);
    let outs = art.execute(&inputs)?;
    let (updated, scalars) = Runtime::unpack_train_outputs(&art.meta, outs)?;
    Ok(DepthUpdate {
        updated,
        weight,
        loss: scalars[0],
        bytes: art.meta.trainable_bytes(),
        mem_bytes: mems[depth_index].bytes_at(ctx.cfg.memory.accounting_batch),
    })
}

/// Merge one update into the per-parameter weighted accumulator.
fn accumulate(
    acc: &mut HashMap<String, (Vec<f32>, f64)>,
    updated: &[(String, Vec<f32>)],
    weight: f64,
) {
    for (name, data) in updated {
        let e = acc.entry(name.clone()).or_insert_with(|| (vec![0.0; data.len()], 0.0));
        for (a, v) in e.0.iter_mut().zip(data) {
            *a += weight as f32 * v;
        }
        e.1 += weight;
    }
}

impl Method for DepthFL {
    fn name(&self) -> &'static str {
        "DepthFL"
    }

    fn inclusive(&self) -> bool {
        false
    }

    fn run(&self, rt: &Runtime, cfg: &RunConfig) -> Result<RunSummary> {
        let mut ctx = ServerCtx::new(rt, cfg.clone())?;
        let model = rt.model(&cfg.model_tag)?;
        let num_blocks = model.num_blocks;
        let scan = rt.manifest.scan_steps;
        let batch = rt.manifest.train_batch;
        let alpha = ctx.cfg.fleet.staleness_alpha;

        // Depth options ascending: depth d needs depthfl_train_d{d}.
        let mut mems = Vec::new();
        let mut depth_bytes = Vec::new();
        for d in 1..=num_blocks {
            let art = model.artifact(&format!("depthfl_train_d{d}"))?;
            mems.push(art.participation_mem());
            depth_bytes.push(art.trainable_bytes());
        }
        let assignment = ctx.pool.capability_assignment(&mems);
        let pr = assignment.iter().filter(|a| a.is_some()).count() as f64 / assignment.len() as f64;

        if pr == 0.0 {
            return Ok(RunSummary {
                method: self.name().into(),
                model_tag: cfg.model_tag.clone(),
                partition: cfg.partition().label(),
                final_acc: f64::NAN,
                participation_rate: 0.0,
                peak_client_mem: 0,
                total_bytes_up: 0,
                total_bytes_down: 0,
                rounds: 0,
                sim_time_s: 0.0,
                transitions: Vec::new(),
                history: Vec::new(),
            });
        }

        // Async policy: trained-but-not-arrived updates, keyed by client,
        // stamped with their dispatch round, the prefix version at
        // dispatch, and whether they are churn-checkpointed partials.
        let mut pending: HashMap<usize, (DepthUpdate, usize, u64, bool)> = HashMap::new();

        let zero = MemCoeffs::default();
        ctx.bump_prefix_version();
        for round in 0..ctx.cfg.max_rounds_total {
            // Uniform sample, minus clients with uploads still in flight.
            let sel = ctx.sample_cohort(&zero);
            // Fleet dispatch: a client's depth sets its FLOPs proxy and
            // comm bytes; the round policy trims the cohort.
            let mut works = Vec::new();
            for &cid in &sel.trainers {
                let Some(di) = assignment[cid] else { continue };
                works.push(ctx.client_work(cid, &mems[di], depth_bytes[di], depth_bytes[di]));
            }
            if ctx.async_params().is_some() {
                // A fresh dispatch supersedes the client's stale buffered
                // update (mirrors the fleet engine's in-flight queue).
                for w in &works {
                    pending.remove(&w.id);
                }
            }
            let plan = ctx.run_fleet(&works);
            // Selection-order aggregation (see coordinator::round).
            let completers: Vec<usize> =
                sel.trainers.iter().copied().filter(|id| plan.completers.contains(id)).collect();
            let deferred: Vec<usize> =
                sel.trainers.iter().copied().filter(|id| plan.deferred.contains(id)).collect();

            // Churn partials: scale the depth update's weight by the
            // checkpointed fraction (mirrors coordinator::round).
            let fractions: HashMap<usize, f64> = plan.partials.iter().copied().collect();

            let lr_lit = xla::Literal::scalar(ctx.cfg.lr);
            // Per-parameter weighted accumulation: clients contribute only
            // the parameters their depth covers.
            let mut acc: HashMap<String, (Vec<f32>, f64)> = HashMap::new();
            let mut participants = 0usize;
            let mut partial_merged = 0usize;
            let (mut bytes_up, mut bytes_down) = (0u64, 0u64);
            let (mut loss_sum, mut w_sum) = (0.0f64, 0.0f64);
            let mut mem_peak = 0u64;

            for &cid in &completers {
                let Some(di) = assignment[cid] else { continue };
                let mut u = run_client(&mut ctx, di, &mems, cid, scan, batch, &lr_lit)?;
                u.weight = partial_scaled(&fractions, cid, u.weight, &mut partial_merged);
                loss_sum += u.loss as f64 * u.weight;
                w_sum += u.weight;
                accumulate(&mut acc, &u.updated, u.weight);
                bytes_up += u.bytes;
                bytes_down += u.bytes;
                mem_peak = mem_peak.max(u.mem_bytes);
                participants += 1;
            }

            // Async policy: train window-missers now (their upload is in
            // flight) and merge earlier rounds' arrivals discounted.
            // NOTE: this mirrors ServerCtx::{run_fleet supersede,
            // take_late_arrivals} and heterofl's copy — keep the three
            // consistent when touching staleness/supersede semantics.
            let (mut late_merged, mut late_dropped, mut staleness_sum) = (0usize, 0usize, 0usize);
            if let Some((_, max_staleness)) = ctx.async_params() {
                for &cid in &deferred {
                    let Some(di) = assignment[cid] else { continue };
                    let mut u = run_client(&mut ctx, di, &mems, cid, scan, batch, &lr_lit)?;
                    bytes_down += u.bytes;
                    mem_peak = mem_peak.max(u.mem_bytes);
                    // Deferred partials buffer their scaled weight so the
                    // late merge inherits the right sample count.
                    let partial = match fractions.get(&cid) {
                        Some(f) => {
                            u.weight *= f;
                            true
                        }
                        None => false,
                    };
                    pending.insert(cid, (u, ctx.round, ctx.prefix_version, partial));
                }
                for la in &plan.late_arrivals {
                    if let Some((u, dispatched, dispatch_pv, partial)) = pending.remove(&la.client)
                    {
                        let staleness = ctx.round.saturating_sub(dispatched);
                        if staleness <= max_staleness {
                            // Depth prefixes never freeze mid-run, so the
                            // transition decay (projection semantics,
                            // shared with the coordinator) stays exactly
                            // 1.0 — the prefix version never bumps after
                            // dispatch for this method.
                            let crossed = ctx.prefix_version.saturating_sub(dispatch_pv);
                            let decay = ctx.projection.unwrap_or(1.0);
                            let w = u.weight
                                * staleness_discount(staleness, alpha)
                                * transition_decay(decay, crossed);
                            accumulate(&mut acc, &u.updated, w);
                            bytes_up += u.bytes;
                            late_merged += 1;
                            if partial {
                                partial_merged += 1;
                            }
                            staleness_sum += staleness;
                        } else {
                            // Arrived but too stale: the upload still
                            // happened — charge it and record the discard.
                            bytes_up += u.bytes;
                            late_dropped += 1;
                        }
                    }
                }
            }

            // Downloads shipped to policy-cut stragglers and churn
            // casualties cost bandwidth even though their updates never
            // aggregate (dropouts vanish at dispatch, before the
            // download). Async plans truncate events at the close, so
            // post-close aborts are charged off the aborted list.
            let mut lost: Vec<usize> = Vec::new();
            for ev in &plan.events {
                if let EventKind::Dispatch { client } = ev.kind {
                    if plan.completers.contains(&client)
                        || plan.deferred.contains(&client)
                        || plan.dropouts.contains(&client)
                    {
                        continue;
                    }
                    lost.push(client);
                }
            }
            for &client in &plan.aborted {
                if !lost.contains(&client) {
                    lost.push(client);
                }
            }
            for client in lost {
                if let Some(di) = assignment[client] {
                    // Mid-download aborts charge only the fetched fraction.
                    let full = depth_bytes[di];
                    let frac = plan.download_fraction(client);
                    bytes_down += if frac >= 1.0 { full } else { (frac * full as f64) as u64 };
                }
            }

            // Write back the parameters that received any updates.
            for (name, (sum, w)) in acc {
                if w > 0.0 {
                    let t = ctx.store.get_mut(&name)?;
                    for (dst, s) in t.data.iter_mut().zip(&sum) {
                        *dst = s / w as f32;
                    }
                }
            }
            ctx.round += 1;

            let test_acc = if round % ctx.cfg.eval_every == 0 || round + 1 == ctx.cfg.max_rounds_total {
                ctx.evaluate("depthfl_eval")?.acc
            } else {
                f32::NAN
            };
            let out = crate::coordinator::RoundOutcome {
                mean_loss: if w_sum > 0.0 { (loss_sum / w_sum) as f32 } else { f32::NAN },
                participants,
                bytes_up,
                bytes_down,
                client_mem_bytes: mem_peak,
                sim_time_s: plan.duration_s(),
                stragglers: plan.stragglers.len(),
                dropouts: plan.dropouts.len(),
                deferred: plan.deferred.len(),
                late_merged,
                late_dropped,
                mean_staleness: if late_merged > 0 {
                    staleness_sum as f64 / late_merged as f64
                } else {
                    0.0
                },
                interrupted: plan.interrupts,
                resumed: plan.resumes,
                partial_merged,
                wasted_compute_s: plan.wasted_compute_s,
                ..Default::default()
            };
            ctx.record_round("depthfl", 0, &out, test_acc, f64::NAN);
        }

        let (up, down) = ctx.metrics.total_bytes();
        Ok(RunSummary {
            method: self.name().into(),
            model_tag: cfg.model_tag.clone(),
            partition: cfg.partition().label(),
            final_acc: ctx.metrics.final_acc(ctx.cfg.acc_tail),
            participation_rate: pr,
            peak_client_mem: ctx.metrics.peak_client_mem(),
            total_bytes_up: up,
            total_bytes_down: down,
            rounds: ctx.round,
            sim_time_s: ctx.sim_time_s,
            transitions: ctx.transition_log().entries().to_vec(),
            history: ctx.metrics.records.clone(),
        })
    }
}
