//! FedAvg aggregation (Eq. 1) — the per-round L3 hot path.
//!
//! Standard path: weighted average of same-shape client updates,
//! accumulated in a contiguous arena (`Aggregator`). HeteroFL path:
//! width-scaled updates are corner-scattered into the full tensor with
//! per-position weight normalization (`SlicedAggregator`) — positions no
//! client covered keep the previous global value, exactly HeteroFL's
//! rule. Async path: [`BufferedAggregator`] adds FedBuff-style
//! staleness-discounted merging on top of the standard accumulator and
//! can `finish` after any `buffer_k` arrivals instead of a fixed cohort.
//!
//! Every `finish` hard-fails on a zero total weight: in release builds a
//! zero-weight cohort would otherwise multiply the store by `inf` and
//! silently NaN-corrupt every global parameter.
//!
//! All accumulators share one storage discipline: a contiguous
//! per-aggregation *arena* (one flat `Vec<f32>` + per-tensor offsets)
//! instead of a vec-of-vecs. Same accumulation order, same arithmetic —
//! bit-for-bit identical results (regression-tested) — but one
//! allocation per round and a cache-friendly sweep per client, which is
//! what keeps aggregation memcpy-bound at 100+-tensor models (see
//! `docs/PERFORMANCE.md` and `benches/l3_hotpaths.rs`).
//!
//! # Deferred, shardable merge
//!
//! `add*` calls no longer touch the arena eagerly: each records a
//! [`MergeOp`] (the update's tensors, by move or `Arc`, plus its weight)
//! in call order, and `finish` *replays* the whole op list into the
//! arena. With `merge_threads <= 1` the replay is literally the
//! historical eager loop — same ops, same tensor order, same f32
//! rounding. With more threads the arena is split into disjoint
//! contiguous windows and every worker replays **all** ops restricted to
//! its window; because the SIMD kernels are strictly elementwise (no
//! cross-position reassociation), each element still receives exactly
//! the same additions in exactly the same order, so the result is
//! bit-identical to serial at any thread count — the same proof shape as
//! the fleet engine's parallel span planner (`docs/SIMULATION.md`).
//!
//! Weight bookkeeping (`total_weight`, per-tensor masked weights) stays
//! eager so it accumulates in call order, exactly as before.
//!
//! The deferred ops also carry the zero-copy story: the round loop hands
//! its update buffers over by move ([`Aggregator::add_owned`]) or by
//! refcount bump ([`Aggregator::add_shared`]) instead of cloning, and
//! [`Aggregator::finish_stats`] can return the spent buffers to a
//! [`TensorPool`] so steady-state rounds allocate O(1) tensor buffers
//! (witnessed by the counting-allocator rows in `benches/fleet_scale.rs`).

use crate::store::{ParamStore, Tensor};
use anyhow::{bail, Result};
use std::sync::Arc;
use std::time::Instant;

/// FedBuff-style staleness discount: an update dispatched `staleness`
/// rounds ago keeps `1 / (1 + staleness)^alpha` of its sample weight.
/// `alpha = 0` (or `staleness = 0`) is exactly 1.0, bit-for-bit — the
/// degeneracy the async round policy's sync-equivalence relies on.
pub fn staleness_discount(staleness: usize, alpha: f64) -> f64 {
    1.0 / (1.0 + staleness as f64).powf(alpha)
}

/// Extra weight decay for a stale update that crossed `transitions`
/// freeze/step transitions before merging (the suffix-projection path):
/// `decay^transitions`. Exactly `1.0` for zero transitions, so an update
/// merged inside its own step keeps its staleness-discounted weight bit
/// for bit — the projection machinery costs nothing when no transition
/// is crossed.
pub fn transition_decay(decay: f64, transitions: u64) -> f64 {
    if transitions == 0 {
        1.0
    } else {
        decay.powi(transitions.min(i32::MAX as u64) as i32)
    }
}

/// Autovectorization-friendly elementwise kernels for the contiguous f32
/// arenas. Fixed `LANES`-wide chunks give the compiler a straight-line
/// body it can lower to SIMD without touching arithmetic order: every
/// operation stays strictly elementwise (`acc[i] += w * x[i]` never
/// reassociates across positions), so each kernel is bit-identical to
/// the naive scalar loop it replaces — regression-tested against the
/// pre-SIMD nested-vec reference below and raced in
/// `benches/l3_hotpaths.rs`. The elementwise property is also what makes
/// the sharded merge exact: running `axpy` over any sub-slice of the
/// arena produces the same per-element bits as running it over the whole
/// slice.
pub(crate) mod simd {
    /// Chunk width: 8 f32 lanes = one AVX2 register, two NEON registers.
    const LANES: usize = 8;

    /// `acc[i] += w * x[i]` over two equal-length contiguous slices.
    #[inline]
    pub(crate) fn axpy(acc: &mut [f32], x: &[f32], w: f32) {
        debug_assert_eq!(acc.len(), x.len());
        let split = acc.len() - acc.len() % LANES;
        let (a_main, a_tail) = acc.split_at_mut(split);
        let (x_main, x_tail) = x.split_at(split);
        for (a, v) in a_main.chunks_exact_mut(LANES).zip(x_main.chunks_exact(LANES)) {
            for i in 0..LANES {
                a[i] += w * v[i];
            }
        }
        for (a, v) in a_tail.iter_mut().zip(x_tail) {
            *a += w * v;
        }
    }

    /// `acc[i] *= s` in place (the `finish` normalization sweep).
    #[inline]
    pub(crate) fn scale(acc: &mut [f32], s: f32) {
        let mut chunks = acc.chunks_exact_mut(LANES);
        for c in &mut chunks {
            for x in c.iter_mut() {
                *x *= s;
            }
        }
        for x in chunks.into_remainder() {
            *x *= s;
        }
    }

    /// `acc[i] += w` in place (the sliced path's per-position weights).
    #[inline]
    pub(crate) fn add_scalar(acc: &mut [f32], w: f32) {
        let mut chunks = acc.chunks_exact_mut(LANES);
        for c in &mut chunks {
            for x in c.iter_mut() {
                *x += w;
            }
        }
        for x in chunks.into_remainder() {
            *x += w;
        }
    }
}

// ---------------------------------------------------------------------------
// Zero-copy update handles + deferred merge ops
// ---------------------------------------------------------------------------

/// A full update's tensors, held by the aggregator without copying:
/// either moved in (`Owned`) or shared by refcount (`Shared` — the
/// pending/in-flight path, where the coordinator's bookkeeping and the
/// merge both need the same buffers).
enum UpdateTensors {
    Owned(Vec<Vec<f32>>),
    Shared(Arc<Vec<Vec<f32>>>),
}

impl UpdateTensors {
    fn tensors(&self) -> &[Vec<f32>] {
        match self {
            UpdateTensors::Owned(v) => v,
            UpdateTensors::Shared(a) => a,
        }
    }
}

/// One deferred client contribution, recorded by `add*` in call order
/// and replayed by `finish` — serially or sharded, bit-identically.
enum MergeOp {
    /// Full-cover update: tensor `i` accumulates at arena offset `i`.
    Full { tensors: UpdateTensors, weight: f64 },
    /// Masked (suffix-projected) update: each part pairs a tensor with
    /// its index into the aggregator's name list.
    Masked { parts: Vec<(usize, Vec<f32>)>, weight: f64 },
}

/// Timing report from one merge replay, for the
/// `fleet.merge_utilization` telemetry gauge and the perf harness.
/// Mirrors the span planner's worker accounting (`docs/SIMULATION.md`):
/// wall time and utilization vary run to run, but the merged bits never
/// do.
#[derive(Debug, Clone, Copy)]
pub struct MergeStats {
    /// Worker threads used for the replay (1 = inline serial merge).
    pub workers: usize,
    /// Sum of per-worker busy nanoseconds.
    pub busy_ns: u64,
    /// Wall-clock nanoseconds of the whole replay.
    pub wall_ns: u64,
}

impl MergeStats {
    /// Mean worker busy fraction in `[0, 1]`: `busy / (workers * wall)`.
    /// Exactly `1.0` for the serial path (one worker is busy the whole
    /// wall time by construction).
    pub fn utilization(&self) -> f64 {
        if self.workers <= 1 || self.wall_ns == 0 {
            1.0
        } else {
            (self.busy_ns as f64 / (self.workers as f64 * self.wall_ns as f64)).min(1.0)
        }
    }
}

/// Reusable pool of update-tensor buffers (`Vec<Vec<f32>>`), the
/// aggregation analogue of the fleet engine's `RoundScratch`: the round
/// loop `acquire`s a buffer set per client, fills it, moves it into the
/// aggregator, and `finish_stats` releases the spent buffers back — so
/// steady-state rounds reuse the same allocations instead of
/// allocating/freeing one buffer per tensor per client per round.
///
/// `acquire` may return a buffer that still holds previous contents;
/// callers clear or overwrite before use. The free list is capped so a
/// one-off burst (an over-selected cohort) cannot pin memory forever.
pub struct TensorPool {
    free: Vec<Vec<Vec<f32>>>,
    cap: usize,
    hits: u64,
    misses: u64,
}

impl TensorPool {
    /// Pool retaining at most `cap` free buffer sets.
    pub fn new(cap: usize) -> Self {
        TensorPool { free: Vec::new(), cap, hits: 0, misses: 0 }
    }

    /// Take a buffer set — recycled if one is free (hit), empty
    /// otherwise (miss). Recycled sets keep their inner capacities, so a
    /// clear-and-refill pattern allocates nothing at steady state.
    pub fn acquire(&mut self) -> Vec<Vec<f32>> {
        match self.free.pop() {
            Some(b) => {
                self.hits += 1;
                b
            }
            None => {
                self.misses += 1;
                Vec::new()
            }
        }
    }

    /// Return a spent buffer set to the free list (dropped if the list
    /// is at capacity).
    pub fn release(&mut self, bufs: Vec<Vec<f32>>) {
        if self.free.len() < self.cap {
            self.free.push(bufs);
        }
    }

    /// Acquires served from the free list so far.
    pub fn hits(&self) -> u64 {
        self.hits
    }

    /// Acquires that had to hand out a fresh (empty) buffer set.
    pub fn misses(&self) -> u64 {
        self.misses
    }

    /// Buffer sets currently parked on the free list.
    pub fn free_len(&self) -> usize {
        self.free.len()
    }
}

/// Apply one op to the arena window `[lo, lo + window.len())`,
/// intersecting each tensor's flat range with the window. With the full
/// arena as the window this is exactly the historical eager `add` body.
fn apply_op(op: &MergeOp, offsets: &[usize], window: &mut [f32], lo: usize) {
    let hi = lo + window.len();
    match op {
        MergeOp::Full { tensors, weight } => {
            let w = *weight as f32;
            for (i, t) in tensors.tensors().iter().enumerate() {
                axpy_window(offsets[i], t, w, window, lo, hi);
            }
        }
        MergeOp::Masked { parts, weight } => {
            let w = *weight as f32;
            for (idx, t) in parts {
                axpy_window(offsets[*idx], t, w, window, lo, hi);
            }
        }
    }
}

/// `axpy` the part of tensor `t` (arena offset `off`) that falls inside
/// the window `[lo, hi)`. Elementwise, so sub-slicing never changes bits.
fn axpy_window(off: usize, t: &[f32], w: f32, window: &mut [f32], lo: usize, hi: usize) {
    let a = off.max(lo);
    let b = (off + t.len()).min(hi);
    if a < b {
        simd::axpy(&mut window[a - lo..b - lo], &t[a - off..b - off], w);
    }
}

/// Replay the op list into the arena, serially (`threads <= 1`) or over
/// `threads` disjoint contiguous windows. Every worker replays all ops
/// restricted to its window, so each element sees the same additions in
/// the same order as the serial sweep — bit-identical at any count.
fn replay_ops(ops: &[MergeOp], offsets: &[usize], acc: &mut [f32], threads: usize) -> MergeStats {
    let wall = Instant::now();
    if threads <= 1 || acc.is_empty() || ops.is_empty() {
        for op in ops {
            apply_op(op, offsets, acc, 0);
        }
        let ns = wall.elapsed().as_nanos() as u64;
        return MergeStats { workers: 1, busy_ns: ns, wall_ns: ns };
    }
    let chunk = acc.len().div_ceil(threads);
    let busy: Vec<u64> = std::thread::scope(|s| {
        let handles: Vec<_> = acc
            .chunks_mut(chunk)
            .enumerate()
            .map(|(w, slice)| {
                let lo = w * chunk;
                s.spawn(move || {
                    let t0 = Instant::now();
                    for op in ops {
                        apply_op(op, offsets, slice, lo);
                    }
                    t0.elapsed().as_nanos() as u64
                })
            })
            .collect();
        handles.into_iter().map(|h| h.join().expect("merge worker panicked")).collect()
    });
    MergeStats {
        workers: busy.len(),
        busy_ns: busy.iter().sum(),
        wall_ns: wall.elapsed().as_nanos() as u64,
    }
}

/// Contiguous accumulation arena shared by the aggregators: one flat
/// `Vec<f32>` holding every tensor's accumulator back to back, addressed
/// by per-tensor offsets. Compared to the historical `Vec<Vec<f32>>`,
/// construction is a single allocation and the per-client sweep walks
/// one contiguous region — at 100+-tensor models the pointer-chase and
/// allocator overhead dominate, which is exactly where the round hot
/// path lives (see `benches/l3_hotpaths.rs` and `docs/PERFORMANCE.md`).
/// Element order inside each tensor (and the tensor order itself) is
/// unchanged, so every accumulation is bit-identical to the nested
/// layout. Shapes are *not* stored here: only the sliced path needs
/// them, so the plain/buffered aggregators no longer clone a shape vec
/// per tensor per round.
struct Arena {
    names: Vec<String>,
    /// Tensor `i` occupies `acc[offsets[i]..offsets[i + 1]]`.
    offsets: Vec<usize>,
    acc: Vec<f32>,
}

impl Arena {
    /// Lay out an arena for `names`, sized from the store's tensors.
    fn new(names: &[String], store: &ParamStore) -> Result<Self> {
        let mut offsets = Vec::with_capacity(names.len() + 1);
        let mut total = 0usize;
        offsets.push(0);
        for n in names {
            total += store.get(n)?.len();
            offsets.push(total);
        }
        Ok(Arena { names: names.to_vec(), offsets, acc: vec![0.0; total] })
    }

    /// Number of tensors in the layout.
    fn len(&self) -> usize {
        self.names.len()
    }

    /// Tensor `i`'s accumulator slice.
    fn slot(&mut self, i: usize) -> &mut [f32] {
        &mut self.acc[self.offsets[i]..self.offsets[i + 1]]
    }

    /// Tensor `i`'s accumulator slice (shared).
    fn slot_ref(&self, i: usize) -> &[f32] {
        &self.acc[self.offsets[i]..self.offsets[i + 1]]
    }

    /// Tensor `i`'s expected element count.
    fn slot_len(&self, i: usize) -> usize {
        self.offsets[i + 1] - self.offsets[i]
    }
}

/// In-place weighted-average accumulator over a fixed parameter list.
/// `add*` records deferred ops; `finish` replays them into a contiguous
/// arena — serially or sharded, bit-identical either way (see the module
/// docs for the proof shape).
pub struct Aggregator {
    arena: Arena,
    /// Deferred contributions in call order.
    ops: Vec<MergeOp>,
    total_weight: f64,
    /// Per-tensor weight contributed by masked (suffix-projected) adds;
    /// allocated on the first [`Self::add_masked`] so the full-cover path
    /// is untouched (the bit-for-bit degeneracy contract).
    masked_weight: Option<Vec<f64>>,
    merge_threads: usize,
}

impl Aggregator {
    /// Build an accumulator for `names`, sized from the store's tensors.
    pub fn new(names: &[String], store: &ParamStore) -> Result<Self> {
        Ok(Aggregator {
            arena: Arena::new(names, store)?,
            ops: Vec::new(),
            total_weight: 0.0,
            masked_weight: None,
            merge_threads: 1,
        })
    }

    /// Worker threads for the `finish` replay (default 1 = the inline
    /// serial merge). Results are bit-identical at any count; >1 only
    /// buys wall-clock time on large cohorts.
    pub fn set_merge_threads(&mut self, threads: usize) {
        self.merge_threads = threads.max(1);
    }

    /// Add one client's update set (tensors in `names` order), copying
    /// the slices into an owned op. Prefer [`Self::add_owned`] /
    /// [`Self::add_shared`] on the round hot path — this borrowed form
    /// exists for callers that genuinely only have views.
    pub fn add<T: AsRef<[f32]>>(&mut self, tensors: &[T], weight: f64) {
        let owned: Vec<Vec<f32>> = tensors.iter().map(|t| t.as_ref().to_vec()).collect();
        self.add_owned(owned, weight);
    }

    /// Add one client's update set by move — no copy; the buffers are
    /// held until `finish` replays them (and can then be recycled via a
    /// [`TensorPool`]).
    pub fn add_owned(&mut self, tensors: Vec<Vec<f32>>, weight: f64) {
        self.debug_check_full(&tensors);
        self.ops.push(MergeOp::Full { tensors: UpdateTensors::Owned(tensors), weight });
        self.total_weight += weight;
    }

    /// Add one client's update set by `Arc` refcount bump — the
    /// zero-copy path for version-stamped pending/in-flight updates the
    /// coordinator also keeps a handle to.
    pub fn add_shared(&mut self, tensors: Arc<Vec<Vec<f32>>>, weight: f64) {
        self.debug_check_full(&tensors);
        self.ops.push(MergeOp::Full { tensors: UpdateTensors::Shared(tensors), weight });
        self.total_weight += weight;
    }

    fn debug_check_full(&self, tensors: &[Vec<f32>]) {
        debug_assert_eq!(tensors.len(), self.arena.len());
        if cfg!(debug_assertions) {
            for (i, t) in tensors.iter().enumerate() {
                debug_assert_eq!(t.len(), self.arena.slot_len(i), "tensor {i} length drifted");
            }
        }
    }

    /// Add a *masked* update covering only part of the parameter list
    /// (copying the parts): each entry of `parts` pairs a tensor with
    /// its index into the aggregator's name list. This is how a stale
    /// update projected onto the still-trained suffix merges — the
    /// frozen-block tensors it used to carry are simply absent. Masked
    /// weight is tracked per tensor; tensors nobody covers keep the
    /// previous global value at [`Self::finish`] (mirroring
    /// [`SlicedAggregator`]'s rule).
    pub fn add_masked<T: AsRef<[f32]>>(&mut self, parts: &[(usize, T)], weight: f64) {
        let owned: Vec<(usize, Vec<f32>)> =
            parts.iter().map(|(i, t)| (*i, t.as_ref().to_vec())).collect();
        self.add_masked_owned(owned, weight);
    }

    /// [`Self::add_masked`] by move — no copy of the projected parts.
    pub fn add_masked_owned(&mut self, parts: Vec<(usize, Vec<f32>)>, weight: f64) {
        let n = self.arena.len();
        let masked = self.masked_weight.get_or_insert_with(|| vec![0.0; n]);
        for (idx, t) in &parts {
            debug_assert_eq!(t.len(), self.arena.slot_len(*idx), "projected tensor shape drifted");
            masked[*idx] += weight;
        }
        self.ops.push(MergeOp::Masked { parts, weight });
    }

    /// Normalize and write back into the store. Fails on a zero total
    /// weight instead of scaling the store by `inf`.
    ///
    /// With masked adds in play, normalization is per tensor
    /// (`total_weight + masked_weight[i]`) and tensors that received no
    /// weight at all keep their previous store value; without them the
    /// historical single-division path runs unchanged, bit for bit.
    pub fn finish(self, store: &mut ParamStore) -> Result<()> {
        self.finish_stats(store, None).map(|_| ())
    }

    /// [`Self::finish`] returning replay timing, optionally recycling
    /// the spent update buffers into `pool` (owned buffers always;
    /// shared ones only when the aggregator held the last reference).
    pub fn finish_stats(
        mut self,
        store: &mut ParamStore,
        pool: Option<&mut TensorPool>,
    ) -> Result<MergeStats> {
        let masked = self.masked_weight.take();
        match &masked {
            None if self.total_weight <= 0.0 => {
                bail!("aggregating a zero-weight cohort (total weight {})", self.total_weight)
            }
            Some(m) if self.total_weight <= 0.0 && m.iter().all(|&w| w <= 0.0) => {
                bail!("aggregating a zero-weight cohort (total weight {})", self.total_weight)
            }
            _ => {}
        }
        let stats =
            replay_ops(&self.ops, &self.arena.offsets, &mut self.arena.acc, self.merge_threads);
        match masked {
            None => {
                // Full-cover path (every add spanned all tensors): one
                // shared weight, one shared reciprocal — the
                // pre-projection arithmetic, unchanged (the flat sweep
                // scales tensors in exactly the per-tensor order the
                // nested layout did).
                let inv = 1.0 / self.total_weight as f32;
                simd::scale(&mut self.arena.acc, inv);
                // Write through the store's existing buffers: no
                // per-tensor allocation at finish.
                for (i, name) in self.arena.names.iter().enumerate() {
                    store.get_mut(name)?.data.copy_from_slice(self.arena.slot_ref(i));
                }
            }
            Some(masked) => {
                for (i, mw) in masked.iter().enumerate() {
                    let w = self.total_weight + mw;
                    if w <= 0.0 {
                        continue; // uncovered tensor: keep the previous global value
                    }
                    let inv = 1.0 / w as f32;
                    simd::scale(self.arena.slot(i), inv);
                    store
                        .get_mut(&self.arena.names[i])?
                        .data
                        .copy_from_slice(self.arena.slot_ref(i));
                }
            }
        }
        if let Some(pool) = pool {
            for op in self.ops.drain(..) {
                match op {
                    MergeOp::Full { tensors: UpdateTensors::Owned(b), .. } => pool.release(b),
                    MergeOp::Full { tensors: UpdateTensors::Shared(a), .. } => {
                        if let Ok(b) = Arc::try_unwrap(a) {
                            pool.release(b);
                        }
                    }
                    MergeOp::Masked { .. } => {}
                }
            }
        }
        Ok(stats)
    }

    /// Total sample weight accumulated so far (NOT a client count: `add`
    /// weights are shard sample counts). Masked adds are *not* included —
    /// they weight individual tensors, not the cohort.
    pub fn total_weight(&self) -> f64 {
        self.total_weight
    }

    /// Whether any positive weight has accumulated (full-cover or
    /// masked), i.e. whether [`Self::finish`] would write the store.
    pub fn has_weight(&self) -> bool {
        self.total_weight > 0.0
            || self.masked_weight.as_ref().is_some_and(|m| m.iter().any(|&w| w > 0.0))
    }
}

/// FedBuff-style buffered accumulator (async round policy): updates merge
/// on arrival with a staleness-discounted weight
/// (`w / (1 + staleness)^alpha`), and the buffer is ready to `finish`
/// after any `buffer_k` arrivals — there is no fixed cohort.
///
/// Internally this composes the plain [`Aggregator`], so a merge at
/// staleness 0 (discount exactly 1.0) is arithmetically identical to the
/// synchronous FedAvg path, bit for bit — and it inherits the deferred
/// sharded replay and zero-copy add paths unchanged.
pub struct BufferedAggregator {
    inner: Aggregator,
    alpha: f64,
    merged: usize,
    staleness_sum: usize,
}

impl BufferedAggregator {
    /// Build a buffered accumulator for `names` with staleness-discount
    /// exponent `alpha`.
    pub fn new(names: &[String], store: &ParamStore, alpha: f64) -> Result<Self> {
        let inner = Aggregator::new(names, store)?;
        Ok(BufferedAggregator { inner, alpha, merged: 0, staleness_sum: 0 })
    }

    /// Worker threads for the `finish` replay (see
    /// [`Aggregator::set_merge_threads`]).
    pub fn set_merge_threads(&mut self, threads: usize) {
        self.inner.set_merge_threads(threads);
    }

    /// Merge one update that was dispatched `staleness` rounds ago
    /// (copying the slices; prefer the owned/shared forms on hot paths).
    pub fn add<T: AsRef<[f32]>>(&mut self, tensors: &[T], weight: f64, staleness: usize) {
        let w = weight * staleness_discount(staleness, self.alpha);
        self.inner.add(tensors, w);
        self.merged += 1;
        self.staleness_sum += staleness;
    }

    /// [`Self::add`] by move — no copy.
    pub fn add_owned(&mut self, tensors: Vec<Vec<f32>>, weight: f64, staleness: usize) {
        let w = weight * staleness_discount(staleness, self.alpha);
        self.inner.add_owned(tensors, w);
        self.merged += 1;
        self.staleness_sum += staleness;
    }

    /// [`Self::add`] by `Arc` refcount bump — the zero-copy path for
    /// pending updates the coordinator still holds.
    pub fn add_shared(&mut self, tensors: Arc<Vec<Vec<f32>>>, weight: f64, staleness: usize) {
        let w = weight * staleness_discount(staleness, self.alpha);
        self.inner.add_shared(tensors, w);
        self.merged += 1;
        self.staleness_sum += staleness;
    }

    /// Merge one stale update that crossed ≥ 1 freeze/step transition and
    /// was projected onto the still-trained suffix: `parts` pairs each
    /// surviving tensor with its index into the *current* trainable list,
    /// and `extra_decay` (see [`transition_decay`]) compounds onto the
    /// ordinary staleness discount. Tensors absent from `parts` (the
    /// since-frozen blocks) receive no mass at all.
    pub fn add_projected<T: AsRef<[f32]>>(
        &mut self,
        parts: &[(usize, T)],
        weight: f64,
        staleness: usize,
        extra_decay: f64,
    ) {
        let w = weight * staleness_discount(staleness, self.alpha) * extra_decay;
        self.inner.add_masked(parts, w);
        self.merged += 1;
        self.staleness_sum += staleness;
    }

    /// [`Self::add_projected`] by move — no copy of the projected parts.
    pub fn add_projected_owned(
        &mut self,
        parts: Vec<(usize, Vec<f32>)>,
        weight: f64,
        staleness: usize,
        extra_decay: f64,
    ) {
        let w = weight * staleness_discount(staleness, self.alpha) * extra_decay;
        self.inner.add_masked_owned(parts, w);
        self.merged += 1;
        self.staleness_sum += staleness;
    }

    /// Number of updates merged so far.
    pub fn merged(&self) -> usize {
        self.merged
    }

    /// Mean staleness (rounds) of the merged updates; 0.0 when empty.
    pub fn mean_staleness(&self) -> f64 {
        if self.merged == 0 {
            0.0
        } else {
            self.staleness_sum as f64 / self.merged as f64
        }
    }

    /// FedBuff's trigger: the server may aggregate once `buffer_k`
    /// updates have arrived, regardless of who they came from.
    pub fn ready(&self, buffer_k: usize) -> bool {
        self.merged >= buffer_k
    }

    /// Total (discounted) full-cover weight accumulated so far.
    pub fn total_weight(&self) -> f64 {
        self.inner.total_weight()
    }

    /// Whether any positive weight (full-cover or projected) has
    /// accumulated — i.e. whether [`Self::finish`] would write the store.
    pub fn has_weight(&self) -> bool {
        self.inner.has_weight()
    }

    /// Normalize and write back; fails on a zero-weight buffer.
    pub fn finish(self, store: &mut ParamStore) -> Result<()> {
        self.inner.finish(store)
    }

    /// [`Self::finish`] returning replay timing, optionally recycling
    /// spent buffers into `pool`.
    pub fn finish_stats(
        self,
        store: &mut ParamStore,
        pool: Option<&mut TensorPool>,
    ) -> Result<MergeStats> {
        self.inner.finish_stats(store, pool)
    }
}

/// One deferred width-sliced contribution (HeteroFL path).
struct SlicedOp {
    sub_shapes: Vec<Vec<usize>>,
    tensors: Vec<Vec<f32>>,
    weight: f64,
}

/// HeteroFL-style aggregation over width-heterogeneous updates. Value
/// and per-position weight accumulators live in two flat arenas sharing
/// one offset table (same contiguity rationale — and bit-identical
/// arithmetic — as [`Aggregator`]'s arena).
///
/// Like [`Aggregator`], adds are deferred and `finish` replays them;
/// the sharded replay splits at whole-tensor boundaries (corner
/// scattering walks multi-dimensional strides, so element ranges inside
/// a tensor are not independently addressable), with each worker
/// replaying every op restricted to its tensor range — per-position
/// accumulation order is unchanged, so results are bit-identical to
/// serial at any thread count.
pub struct SlicedAggregator {
    arena: Arena,
    /// Full tensor shapes (only the sliced path needs them — corner
    /// scattering is shape-aware).
    shapes: Vec<Vec<usize>>,
    /// Per-position weights, laid out exactly like `arena.acc`.
    wacc: Vec<f32>,
    /// Deferred contributions in call order.
    ops: Vec<SlicedOp>,
    total_weight: f64,
    merge_threads: usize,
}

impl SlicedAggregator {
    /// Build a sliced accumulator for `names`, sized from the store.
    pub fn new(names: &[String], store: &ParamStore) -> Result<Self> {
        let arena = Arena::new(names, store)?;
        let mut shapes = Vec::with_capacity(names.len());
        for n in names {
            shapes.push(store.get(n)?.shape.clone());
        }
        let wacc = vec![0.0; arena.acc.len()];
        Ok(SlicedAggregator {
            arena,
            shapes,
            wacc,
            ops: Vec::new(),
            total_weight: 0.0,
            merge_threads: 1,
        })
    }

    /// Worker threads for the `finish` replay (see
    /// [`Aggregator::set_merge_threads`]); sharding is at whole-tensor
    /// granularity here.
    pub fn set_merge_threads(&mut self, threads: usize) {
        self.merge_threads = threads.max(1);
    }

    /// Add a client's update whose tensors are corner slices of the full
    /// shapes (sub_shapes[i] element-wise ≤ full_shapes[i]), copying
    /// both. Prefer [`Self::add_owned`] on the round hot path.
    pub fn add(&mut self, sub_shapes: &[Vec<usize>], tensors: &[Vec<f32>], weight: f64) {
        self.add_owned(sub_shapes.to_vec(), tensors.to_vec(), weight);
    }

    /// [`Self::add`] by move — no copy; the update is held until
    /// `finish` replays it.
    pub fn add_owned(&mut self, sub_shapes: Vec<Vec<usize>>, tensors: Vec<Vec<f32>>, weight: f64) {
        debug_assert_eq!(sub_shapes.len(), self.arena.len());
        debug_assert_eq!(tensors.len(), self.arena.len());
        self.ops.push(SlicedOp { sub_shapes, tensors, weight });
        self.total_weight += weight;
    }

    /// Total sample weight accumulated so far (across all positions).
    pub fn total_weight(&self) -> f64 {
        self.total_weight
    }

    /// Replay the deferred ops into the value/weight arenas: serially
    /// (`threads <= 1` — the historical eager loop verbatim) or with
    /// workers owning disjoint whole-tensor ranges.
    fn replay(&mut self) -> MergeStats {
        let threads = self.merge_threads.max(1);
        let n = self.arena.len();
        let Self { arena, shapes, wacc, ops, .. } = self;
        let Arena { offsets, acc, .. } = arena;
        let wall = Instant::now();
        if threads <= 1 || n == 0 || ops.is_empty() {
            for op in ops.iter() {
                let w = op.weight as f32;
                for i in 0..n {
                    let r = offsets[i]..offsets[i + 1];
                    Tensor::accumulate_corner(
                        &shapes[i],
                        &mut acc[r.clone()],
                        &mut wacc[r],
                        &op.sub_shapes[i],
                        &op.tensors[i],
                        w,
                    );
                }
            }
            let ns = wall.elapsed().as_nanos() as u64;
            return MergeStats { workers: 1, busy_ns: ns, wall_ns: ns };
        }
        // Partition the tensor list into contiguous index ranges and
        // split both arenas at the matching flat offsets.
        let t_chunk = n.div_ceil(threads);
        let mut groups: Vec<(usize, usize, &mut [f32], &mut [f32])> = Vec::new();
        let mut acc_rem: &mut [f32] = acc;
        let mut wacc_rem: &mut [f32] = wacc;
        let mut t_lo = 0usize;
        while t_lo < n {
            let t_hi = (t_lo + t_chunk).min(n);
            let split = offsets[t_hi] - offsets[t_lo];
            let (a, ar) = acc_rem.split_at_mut(split);
            let (wv, wr) = wacc_rem.split_at_mut(split);
            groups.push((t_lo, t_hi, a, wv));
            acc_rem = ar;
            wacc_rem = wr;
            t_lo = t_hi;
        }
        let ops_ref: &[SlicedOp] = ops;
        let offs: &[usize] = offsets;
        let shp: &[Vec<usize>] = shapes;
        let busy: Vec<u64> = std::thread::scope(|s| {
            let handles: Vec<_> = groups
                .into_iter()
                .map(|(t_lo, t_hi, a, wv)| {
                    s.spawn(move || {
                        let t0 = Instant::now();
                        let base = offs[t_lo];
                        for op in ops_ref {
                            let w = op.weight as f32;
                            for i in t_lo..t_hi {
                                let r = offs[i] - base..offs[i + 1] - base;
                                Tensor::accumulate_corner(
                                    &shp[i],
                                    &mut a[r.clone()],
                                    &mut wv[r],
                                    &op.sub_shapes[i],
                                    &op.tensors[i],
                                    w,
                                );
                            }
                        }
                        t0.elapsed().as_nanos() as u64
                    })
                })
                .collect();
            handles.into_iter().map(|h| h.join().expect("merge worker panicked")).collect()
        });
        MergeStats {
            workers: busy.len(),
            busy_ns: busy.iter().sum(),
            wall_ns: wall.elapsed().as_nanos() as u64,
        }
    }

    /// Positions with weight keep the normalized average; untouched
    /// positions keep the previous global value. Fails if no weight was
    /// ever added (a zero-weight cohort would silently no-op and mask
    /// the caller's bug).
    pub fn finish(self, store: &mut ParamStore) -> Result<()> {
        self.finish_stats(store).map(|_| ())
    }

    /// [`Self::finish`] returning replay timing. Writes through the
    /// store's existing buffers in place — covered positions get the
    /// normalized average, uncovered ones simply keep their bytes (no
    /// `prev` clone, no shape clone, no re-`set`).
    pub fn finish_stats(mut self, store: &mut ParamStore) -> Result<MergeStats> {
        if self.total_weight <= 0.0 {
            bail!("aggregating a zero-weight cohort (total weight {})", self.total_weight);
        }
        let stats = self.replay();
        for (i, name) in self.arena.names.iter().enumerate() {
            let data = &mut store.get_mut(name)?.data;
            let off = self.arena.offsets[i];
            for (j, o) in data.iter_mut().enumerate() {
                let w = self.wacc[off + j];
                if w > 0.0 {
                    *o = self.arena.acc[off + j] / w;
                }
            }
        }
        Ok(stats)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::collections::BTreeMap;

    fn store_with(pairs: &[(&str, Vec<usize>, Vec<f32>)]) -> ParamStore {
        let shapes: BTreeMap<String, Vec<usize>> =
            pairs.iter().map(|(n, s, _)| (n.to_string(), s.clone())).collect();
        let mut store = ParamStore::init(&shapes, 0);
        for (n, s, d) in pairs {
            store.set(n, Tensor { shape: s.clone(), data: d.clone() });
        }
        store
    }

    #[test]
    fn weighted_average_exact() {
        let mut store = store_with(&[("w", vec![2], vec![0.0, 0.0])]);
        let names = vec!["w".to_string()];
        let mut agg = Aggregator::new(&names, &store).unwrap();
        agg.add(&[vec![1.0, 2.0]], 1.0);
        agg.add(&[vec![3.0, 6.0]], 3.0);
        agg.finish(&mut store).unwrap();
        let t = store.get("w").unwrap();
        assert_eq!(t.data, vec![2.5, 5.0]); // (1*1+3*3)/4, (2*1+6*3)/4
    }

    #[test]
    fn single_client_identity() {
        let mut store = store_with(&[("w", vec![3], vec![0.0; 3])]);
        let names = vec!["w".to_string()];
        let mut agg = Aggregator::new(&names, &store).unwrap();
        agg.add(&[vec![7.0, 8.0, 9.0]], 0.123);
        agg.finish(&mut store).unwrap();
        let t = store.get("w").unwrap();
        for (a, b) in t.data.iter().zip([7.0, 8.0, 9.0]) {
            assert!((a - b).abs() < 1e-6);
        }
    }

    #[test]
    fn zero_weight_cohort_fails_instead_of_corrupting() {
        // Release builds used to scale the store by `inf` here (the
        // debug_assert was compiled out); now every finish hard-fails.
        let mut store = store_with(&[("w", vec![2], vec![5.0, 5.0])]);
        let names = vec!["w".to_string()];

        let agg = Aggregator::new(&names, &store).unwrap();
        assert!(agg.finish(&mut store).is_err(), "no adds at all");

        let mut agg = Aggregator::new(&names, &store).unwrap();
        agg.add(&[vec![1.0, 1.0]], 0.0); // empty-shard client
        assert!(agg.finish(&mut store).is_err(), "only zero-weight adds");

        let sliced = SlicedAggregator::new(&names, &store).unwrap();
        assert!(sliced.finish(&mut store).is_err(), "sliced: no adds");
        let mut sliced = SlicedAggregator::new(&names, &store).unwrap();
        sliced.add(&[vec![2]], &[vec![1.0, 1.0]], 0.0);
        assert!(sliced.finish(&mut store).is_err(), "sliced: zero-weight adds");

        let buffered = BufferedAggregator::new(&names, &store, 0.5).unwrap();
        assert!(buffered.finish(&mut store).is_err(), "buffered: empty buffer");

        // The store is untouched either way.
        assert_eq!(store.get("w").unwrap().data, vec![5.0, 5.0]);
    }

    #[test]
    fn arena_matches_nested_vec_reference_bit_for_bit() {
        // The contiguous arena must reproduce the historical
        // vec-of-vecs accumulation exactly: same adds, same order, same
        // f32 rounding. The reference below is the pre-arena algorithm,
        // kept verbatim.
        // Sizes straddle the SIMD chunk width (8): sub-chunk, exact
        // multiples, and ragged tails, so both the chunked body and the
        // scalar remainder of every kernel are exercised.
        let mut rng = crate::rng::Rng::new(77);
        let sizes = [3usize, 1, 8, 5, 16, 19, 64, 7];
        let pairs: Vec<(String, Vec<usize>, Vec<f32>)> = sizes
            .iter()
            .enumerate()
            .map(|(i, &n)| (format!("t{i}"), vec![n], vec![0.0; n]))
            .collect();
        let pair_refs: Vec<(&str, Vec<usize>, Vec<f32>)> =
            pairs.iter().map(|(n, s, d)| (n.as_str(), s.clone(), d.clone())).collect();
        let mut store = store_with(&pair_refs);
        let names: Vec<String> = pairs.iter().map(|(n, _, _)| n.clone()).collect();

        let clients: Vec<(Vec<Vec<f32>>, f64)> = (0..7)
            .map(|_| {
                let ts: Vec<Vec<f32>> =
                    sizes.iter().map(|&n| (0..n).map(|_| rng.normal()).collect()).collect();
                (ts, rng.uniform(0.5, 30.0))
            })
            .collect();

        // Reference: nested accumulators, shared-inverse normalization.
        let mut ref_acc: Vec<Vec<f32>> = sizes.iter().map(|&n| vec![0.0; n]).collect();
        let mut ref_total = 0.0f64;
        for (ts, w) in &clients {
            let wf = *w as f32;
            for (a, t) in ref_acc.iter_mut().zip(ts) {
                for (x, v) in a.iter_mut().zip(t) {
                    *x += wf * v;
                }
            }
            ref_total += w;
        }
        let inv = 1.0 / ref_total as f32;
        for a in &mut ref_acc {
            for x in a.iter_mut() {
                *x *= inv;
            }
        }

        let mut agg = Aggregator::new(&names, &store).unwrap();
        for (ts, w) in &clients {
            agg.add(ts, *w);
        }
        agg.finish(&mut store).unwrap();
        for (i, name) in names.iter().enumerate() {
            let got = &store.get(name).unwrap().data;
            for (g, r) in got.iter().zip(&ref_acc[i]) {
                assert_eq!(g.to_bits(), r.to_bits(), "{name}: {g} vs {r}");
            }
        }
    }

    #[test]
    fn simd_kernels_match_scalar_reference_bit_for_bit() {
        // Every length around the 8-lane chunk width, hostile weights
        // included: the chunked kernels must reproduce the naive scalar
        // loops exactly (they are elementwise, so no reassociation).
        let mut rng = crate::rng::Rng::new(0x51_3d);
        for len in 0..40usize {
            for w in [0.0f32, 1.0, -0.375, 1e-7, 3.1e6] {
                let x: Vec<f32> = (0..len).map(|_| rng.normal()).collect();
                let base: Vec<f32> = (0..len).map(|_| rng.normal()).collect();

                let mut got = base.clone();
                simd::axpy(&mut got, &x, w);
                let mut want = base.clone();
                for (a, v) in want.iter_mut().zip(&x) {
                    *a += w * v;
                }
                for (g, r) in got.iter().zip(&want) {
                    assert_eq!(g.to_bits(), r.to_bits(), "axpy len={len} w={w}");
                }

                let mut got = base.clone();
                simd::scale(&mut got, w);
                let mut want = base.clone();
                for a in want.iter_mut() {
                    *a *= w;
                }
                for (g, r) in got.iter().zip(&want) {
                    assert_eq!(g.to_bits(), r.to_bits(), "scale len={len} w={w}");
                }

                let mut got = base.clone();
                simd::add_scalar(&mut got, w);
                let mut want = base.clone();
                for a in want.iter_mut() {
                    *a += w;
                }
                for (g, r) in got.iter().zip(&want) {
                    assert_eq!(g.to_bits(), r.to_bits(), "add_scalar len={len} w={w}");
                }
            }
        }
    }

    #[test]
    fn total_weight_is_sample_weight_not_client_count() {
        let store = store_with(&[("w", vec![1], vec![0.0])]);
        let names = vec!["w".to_string()];
        let mut agg = Aggregator::new(&names, &store).unwrap();
        agg.add(&[vec![1.0]], 100.0);
        agg.add(&[vec![1.0]], 50.0);
        assert_eq!(agg.total_weight(), 150.0, "two clients, 150 samples");
    }

    #[test]
    fn buffered_at_zero_staleness_matches_plain_bit_for_bit() {
        // The sync-degeneracy contract: staleness 0 (any alpha) and
        // alpha 0 (any staleness... of 0) leave weights untouched, so the
        // buffered path accumulates exactly like the plain path.
        for alpha in [0.0, 0.5, 1.0] {
            let mut s1 = store_with(&[("w", vec![3], vec![0.0; 3])]);
            let mut s2 = s1.clone();
            let names = vec!["w".to_string()];
            let u1 = vec![0.1, -2.0, 3.5];
            let u2 = vec![7.25, 0.5, -1.0];

            let mut plain = Aggregator::new(&names, &s1).unwrap();
            plain.add(&[u1.clone()], 17.0);
            plain.add(&[u2.clone()], 3.0);
            plain.finish(&mut s1).unwrap();

            let mut buffered = BufferedAggregator::new(&names, &s2, alpha).unwrap();
            buffered.add(&[u1.clone()], 17.0, 0);
            buffered.add(&[u2.clone()], 3.0, 0);
            assert_eq!(buffered.merged(), 2);
            assert_eq!(buffered.mean_staleness(), 0.0);
            buffered.finish(&mut s2).unwrap();

            let a = &s1.get("w").unwrap().data;
            let b = &s2.get("w").unwrap().data;
            for (x, y) in a.iter().zip(b) {
                assert_eq!(x.to_bits(), y.to_bits(), "alpha={alpha}: {x} vs {y}");
            }
        }
    }

    #[test]
    fn staleness_discount_down_weights_old_updates() {
        assert_eq!(staleness_discount(0, 0.7), 1.0, "fresh updates keep full weight");
        assert_eq!(staleness_discount(5, 0.0), 1.0, "alpha 0 disables discounting");
        assert!((staleness_discount(1, 1.0) - 0.5).abs() < 1e-12);
        assert!((staleness_discount(3, 0.5) - 0.5).abs() < 1e-12); // 1/sqrt(4)
        assert!(staleness_discount(10, 0.5) < staleness_discount(2, 0.5));

        // Weighted-mean check: fresh update (w=1) and staleness-1 update
        // (w=1, alpha=1 → effective 0.5): mean = (0*1 + 3*0.5) / 1.5 = 1.
        let mut store = store_with(&[("w", vec![1], vec![0.0])]);
        let names = vec!["w".to_string()];
        let mut agg = BufferedAggregator::new(&names, &store, 1.0).unwrap();
        agg.add(&[vec![0.0]], 1.0, 0);
        agg.add(&[vec![3.0]], 1.0, 1);
        assert!((agg.total_weight() - 1.5).abs() < 1e-12);
        assert!((agg.mean_staleness() - 0.5).abs() < 1e-12);
        agg.finish(&mut store).unwrap();
        assert!((store.get("w").unwrap().data[0] - 1.0).abs() < 1e-6);
    }

    #[test]
    fn transition_decay_degenerates_and_compounds() {
        assert_eq!(transition_decay(0.5, 0).to_bits(), 1.0f64.to_bits(), "zero crossings = 1.0");
        assert_eq!(transition_decay(0.0, 0), 1.0, "even decay 0 is inert without a crossing");
        assert_eq!(transition_decay(0.5, 1), 0.5);
        assert_eq!(transition_decay(0.5, 2), 0.25);
        assert_eq!(transition_decay(1.0, 7), 1.0, "decay 1 disables the penalty");
        assert_eq!(transition_decay(0.0, 3), 0.0, "decay 0 kills any crossed update");
        // Monotone non-increasing in transitions crossed (decay <= 1).
        for decay in [0.0, 0.25, 0.5, 1.0] {
            for k in 0..6u64 {
                assert!(transition_decay(decay, k + 1) <= transition_decay(decay, k));
            }
        }
    }

    #[test]
    fn masked_add_normalizes_per_tensor_and_preserves_uncovered() {
        // Two tensors; a full-cover client plus a projected update that
        // covers only tensor 1. Tensor 0 averages over the full client
        // alone; tensor 1 over both; an entirely uncovered tensor keeps
        // the previous global value.
        let mut store = store_with(&[
            ("a", vec![2], vec![9.0, 9.0]),
            ("b", vec![2], vec![9.0, 9.0]),
            ("c", vec![2], vec![7.0, 7.0]),
        ]);
        let names = vec!["a".to_string(), "b".to_string(), "c".to_string()];
        let mut agg = Aggregator::new(&names, &store).unwrap();
        agg.add(&[vec![1.0, 1.0], vec![2.0, 2.0], vec![0.0, 0.0]], 1.0);
        agg.add_masked(&[(1usize, vec![6.0, 6.0])], 3.0);
        assert!(agg.has_weight());
        agg.finish(&mut store).unwrap();
        assert_eq!(store.get("a").unwrap().data, vec![1.0, 1.0], "full weight only");
        // b: (1*2 + 3*6) / (1 + 3) = 5.0
        assert_eq!(store.get("b").unwrap().data, vec![5.0, 5.0]);
        assert_eq!(store.get("c").unwrap().data, vec![0.0, 0.0], "covered by the full add");

        // Masked-only merge: uncovered tensors keep the store value.
        let mut store = store_with(&[("a", vec![1], vec![9.0]), ("b", vec![1], vec![9.0])]);
        let names = vec!["a".to_string(), "b".to_string()];
        let mut agg = Aggregator::new(&names, &store).unwrap();
        agg.add_masked(&[(1usize, vec![4.0])], 2.0);
        assert_eq!(agg.total_weight(), 0.0, "masked weight is per-tensor, not cohort");
        assert!(agg.has_weight());
        agg.finish(&mut store).unwrap();
        assert_eq!(store.get("a").unwrap().data, vec![9.0], "frozen tensor untouched");
        assert_eq!(store.get("b").unwrap().data, vec![4.0]);
    }

    #[test]
    fn masked_zero_weight_still_fails_finish() {
        let mut store = store_with(&[("w", vec![1], vec![5.0])]);
        let names = vec!["w".to_string()];
        let mut agg = Aggregator::new(&names, &store).unwrap();
        agg.add_masked(&[(0usize, vec![1.0])], 0.0); // zero-weight projection
        assert!(!agg.has_weight());
        assert!(agg.finish(&mut store).is_err(), "masked zero weight must not no-op silently");
        assert_eq!(store.get("w").unwrap().data, vec![5.0]);
    }

    #[test]
    fn projected_merge_discounts_staleness_and_transitions() {
        // A fresh full client (w=1) plus a projected update (w=4) at
        // staleness 1 with alpha=1 (discount 0.5) crossing one transition
        // with decay 0.5: effective projected weight = 4 * 0.5 * 0.5 = 1.
        // Covered tensor: (1*0 + 1*6) / 2 = 3; uncovered: full only.
        let mut store = store_with(&[("a", vec![1], vec![0.0]), ("b", vec![1], vec![0.0])]);
        let names = vec!["a".to_string(), "b".to_string()];
        let mut agg = BufferedAggregator::new(&names, &store, 1.0).unwrap();
        agg.add(&[vec![2.0], vec![0.0]], 1.0, 0);
        agg.add_projected(&[(1usize, vec![6.0])], 4.0, 1, transition_decay(0.5, 1));
        assert_eq!(agg.merged(), 2);
        assert!(agg.has_weight());
        agg.finish(&mut store).unwrap();
        assert_eq!(store.get("a").unwrap().data, vec![2.0]);
        assert_eq!(store.get("b").unwrap().data, vec![3.0]);
    }

    #[test]
    fn projected_weight_never_exceeds_original() {
        // discount * decay ∈ (0, 1] for alpha >= 0, decay ∈ [0, 1]: a
        // projected update can only lose influence relative to merging
        // fresh, never gain it — and more transitions mean less weight.
        for alpha in [0.0, 0.5, 1.0] {
            for decay in [0.0, 0.25, 0.5, 1.0] {
                for staleness in 0..5usize {
                    let mut prev = f64::INFINITY;
                    for transitions in 0..5u64 {
                        let f = staleness_discount(staleness, alpha)
                            * transition_decay(decay, transitions);
                        assert!(f <= 1.0 + 1e-12, "amplified: {f}");
                        assert!(f >= 0.0);
                        assert!(f <= prev + 1e-12, "not monotone in transitions");
                        prev = f;
                    }
                }
            }
        }
    }

    #[test]
    fn buffered_ready_after_buffer_k_arrivals() {
        let store = store_with(&[("w", vec![1], vec![0.0])]);
        let names = vec!["w".to_string()];
        let mut agg = BufferedAggregator::new(&names, &store, 0.5).unwrap();
        assert!(!agg.ready(2));
        agg.add(&[vec![1.0]], 1.0, 0);
        assert!(!agg.ready(2), "one arrival is not enough");
        agg.add(&[vec![2.0]], 1.0, 3);
        assert!(agg.ready(2), "any buffer_k arrivals suffice — no fixed cohort");
        assert_eq!(agg.merged(), 2);
    }

    #[test]
    fn sliced_aggregation_covers_and_preserves() {
        // full (2,4); client A covers (2,2) corner, client B covers (2,3).
        let mut store = store_with(&[("w", vec![2, 4], vec![9.0; 8])]);
        let names = vec!["w".to_string()];
        let mut agg = SlicedAggregator::new(&names, &store).unwrap();
        agg.add(&[vec![2, 2]], &[vec![1.0, 1.0, 1.0, 1.0]], 1.0);
        agg.add(&[vec![2, 3]], &[vec![2.0, 2.0, 2.0, 2.0, 2.0, 2.0]], 1.0);
        agg.finish(&mut store).unwrap();
        let t = store.get("w").unwrap();
        // col 0,1: avg(1,2)=1.5; col 2: only B -> 2.0; col 3: untouched -> 9.0
        assert_eq!(t.data, vec![1.5, 1.5, 2.0, 9.0, 1.5, 1.5, 2.0, 9.0]);
    }

    #[test]
    fn sliced_full_cover_equals_plain_fedavg() {
        let mut s1 = store_with(&[("w", vec![2, 2], vec![0.0; 4])]);
        let mut s2 = s1.clone();
        let names = vec!["w".to_string()];
        let u1 = vec![1.0, 2.0, 3.0, 4.0];
        let u2 = vec![5.0, 6.0, 7.0, 8.0];

        let mut plain = Aggregator::new(&names, &s1).unwrap();
        plain.add(&[u1.clone()], 2.0);
        plain.add(&[u2.clone()], 1.0);
        plain.finish(&mut s1).unwrap();

        let mut sliced = SlicedAggregator::new(&names, &s2).unwrap();
        sliced.add(&[vec![2, 2]], &[u1], 2.0);
        sliced.add(&[vec![2, 2]], &[u2], 1.0);
        sliced.finish(&mut s2).unwrap();

        let a = &s1.get("w").unwrap().data;
        let b = &s2.get("w").unwrap().data;
        for (x, y) in a.iter().zip(b) {
            assert!((x - y).abs() < 1e-6, "{x} vs {y}");
        }
    }

    // -----------------------------------------------------------------
    // Sharded-merge + zero-copy contracts
    // -----------------------------------------------------------------

    /// Deterministic multi-tensor workload straddling the SIMD lane
    /// width and the shard chunk boundaries.
    fn merge_workload(
        seed: u64,
    ) -> (Vec<(String, Vec<usize>, Vec<f32>)>, Vec<(Vec<Vec<f32>>, f64)>) {
        let mut rng = crate::rng::Rng::new(seed);
        let sizes = [5usize, 16, 3, 64, 1, 23, 8, 40];
        let pairs: Vec<(String, Vec<usize>, Vec<f32>)> = sizes
            .iter()
            .enumerate()
            .map(|(i, &n)| (format!("t{i}"), vec![n], vec![0.0; n]))
            .collect();
        let clients: Vec<(Vec<Vec<f32>>, f64)> = (0..9)
            .map(|_| {
                let ts: Vec<Vec<f32>> =
                    sizes.iter().map(|&n| (0..n).map(|_| rng.normal()).collect()).collect();
                (ts, rng.uniform(0.5, 30.0))
            })
            .collect();
        (pairs, clients)
    }

    #[test]
    fn sharded_merge_is_bit_identical_to_serial() {
        // Full-cover + masked adds mixed, replayed at thread counts
        // {1, 2, 4, 8, 13}: every count must reproduce the serial bits.
        let (pairs, clients) = merge_workload(0xa66);
        let pair_refs: Vec<(&str, Vec<usize>, Vec<f32>)> =
            pairs.iter().map(|(n, s, d)| (n.as_str(), s.clone(), d.clone())).collect();
        let names: Vec<String> = pairs.iter().map(|(n, _, _)| n.clone()).collect();

        let run = |threads: usize| {
            let mut store = store_with(&pair_refs);
            let mut agg = Aggregator::new(&names, &store).unwrap();
            agg.set_merge_threads(threads);
            for (i, (ts, w)) in clients.iter().enumerate() {
                if i % 3 == 2 {
                    // A projected (masked) update over a tensor subset.
                    let parts: Vec<(usize, Vec<f32>)> = ts
                        .iter()
                        .enumerate()
                        .filter(|(j, _)| j % 2 == 0)
                        .map(|(j, t)| (j, t.clone()))
                        .collect();
                    agg.add_masked_owned(parts, *w);
                } else {
                    agg.add_owned(ts.clone(), *w);
                }
            }
            let stats = agg.finish_stats(&mut store, None).unwrap();
            assert_eq!(stats.workers, threads, "one worker per arena chunk");
            let bits: Vec<Vec<u32>> = names
                .iter()
                .map(|n| store.get(n).unwrap().data.iter().map(|x| x.to_bits()).collect())
                .collect();
            bits
        };

        let serial = run(1);
        for threads in [2usize, 4, 8, 13] {
            assert_eq!(run(threads), serial, "threads={threads} diverged from serial");
        }
    }

    #[test]
    fn sliced_sharded_merge_is_bit_identical_to_serial() {
        let mut rng = crate::rng::Rng::new(0x57_1c);
        let shapes = [vec![4usize, 6], vec![8], vec![2, 2, 3], vec![5, 5], vec![1], vec![7, 3]];
        let pairs: Vec<(String, Vec<usize>, Vec<f32>)> = shapes
            .iter()
            .enumerate()
            .map(|(i, s)| {
                let n: usize = s.iter().product();
                (format!("t{i}"), s.clone(), (0..n).map(|_| rng.normal()).collect())
            })
            .collect();
        let pair_refs: Vec<(&str, Vec<usize>, Vec<f32>)> =
            pairs.iter().map(|(n, s, d)| (n.as_str(), s.clone(), d.clone())).collect();
        let names: Vec<String> = pairs.iter().map(|(n, _, _)| n.clone()).collect();

        // Corner-sliced clients at varying widths (including full cover).
        let clients: Vec<(Vec<Vec<usize>>, Vec<Vec<f32>>, f64)> = (0..7)
            .map(|c| {
                let subs: Vec<Vec<usize>> = shapes
                    .iter()
                    .map(|s| s.iter().map(|&d| ((d * (c % 3 + 1)).div_ceil(3)).max(1)).collect())
                    .collect();
                let ts: Vec<Vec<f32>> = subs
                    .iter()
                    .map(|s: &Vec<usize>| {
                        let n: usize = s.iter().product();
                        (0..n).map(|_| rng.normal()).collect()
                    })
                    .collect();
                (subs, ts, rng.uniform(0.5, 20.0))
            })
            .collect();

        let run = |threads: usize| {
            let mut store = store_with(&pair_refs);
            let mut agg = SlicedAggregator::new(&names, &store).unwrap();
            agg.set_merge_threads(threads);
            for (subs, ts, w) in &clients {
                agg.add_owned(subs.clone(), ts.clone(), *w);
            }
            agg.finish(&mut store).unwrap();
            let bits: Vec<Vec<u32>> = names
                .iter()
                .map(|n| store.get(n).unwrap().data.iter().map(|x| x.to_bits()).collect())
                .collect();
            bits
        };

        let serial = run(1);
        for threads in [2usize, 4, 8, 11] {
            assert_eq!(run(threads), serial, "threads={threads} diverged from serial");
        }
    }

    #[test]
    fn owned_shared_and_borrowed_adds_are_bit_identical() {
        let (pairs, clients) = merge_workload(0x0c0);
        let pair_refs: Vec<(&str, Vec<usize>, Vec<f32>)> =
            pairs.iter().map(|(n, s, d)| (n.as_str(), s.clone(), d.clone())).collect();
        let names: Vec<String> = pairs.iter().map(|(n, _, _)| n.clone()).collect();

        let mut s1 = store_with(&pair_refs);
        let mut agg = Aggregator::new(&names, &s1).unwrap();
        for (ts, w) in &clients {
            agg.add(ts, *w);
        }
        agg.finish(&mut s1).unwrap();

        let mut s2 = store_with(&pair_refs);
        let mut agg = Aggregator::new(&names, &s2).unwrap();
        for (i, (ts, w)) in clients.iter().enumerate() {
            if i % 2 == 0 {
                agg.add_owned(ts.clone(), *w);
            } else {
                let arc = Arc::new(ts.clone());
                agg.add_shared(Arc::clone(&arc), *w);
                // The coordinator-side handle stays alive across the
                // merge, exactly like a pending update.
                assert_eq!(arc.len(), ts.len());
            }
        }
        agg.finish(&mut s2).unwrap();

        for n in &names {
            let a = &s1.get(n).unwrap().data;
            let b = &s2.get(n).unwrap().data;
            for (x, y) in a.iter().zip(b) {
                assert_eq!(x.to_bits(), y.to_bits(), "{n}: {x} vs {y}");
            }
        }
    }

    #[test]
    fn pool_recycles_buffers_and_counts_hits() {
        let (pairs, clients) = merge_workload(0x900d);
        let pair_refs: Vec<(&str, Vec<usize>, Vec<f32>)> =
            pairs.iter().map(|(n, s, d)| (n.as_str(), s.clone(), d.clone())).collect();
        let names: Vec<String> = pairs.iter().map(|(n, _, _)| n.clone()).collect();
        let mut store = store_with(&pair_refs);
        let mut pool = TensorPool::new(clients.len());

        for round in 0..3 {
            let mut agg = Aggregator::new(&names, &store).unwrap();
            for (ts, w) in &clients {
                let mut buf = pool.acquire();
                buf.clear();
                buf.extend(ts.iter().cloned());
                agg.add_owned(buf, *w);
            }
            agg.finish_stats(&mut store, Some(&mut pool)).unwrap();
            if round == 0 {
                assert_eq!(pool.misses(), clients.len() as u64, "cold pool: all misses");
            }
            assert_eq!(pool.free_len(), clients.len(), "finish returned every buffer");
        }
        // Rounds 2 and 3 were served entirely from the free list.
        assert_eq!(pool.hits(), 2 * clients.len() as u64);
        assert_eq!(pool.misses(), clients.len() as u64);

        // Shared buffers with a live outside handle are NOT recycled...
        let mut pool = TensorPool::new(8);
        let mut agg = Aggregator::new(&names, &store).unwrap();
        let held = Arc::new(clients[0].0.clone());
        agg.add_shared(Arc::clone(&held), 1.0);
        // ...but a sole-owner shared buffer is.
        agg.add_shared(Arc::new(clients[1].0.clone()), 1.0);
        agg.finish_stats(&mut store, Some(&mut pool)).unwrap();
        assert_eq!(pool.free_len(), 1, "only the sole-owner Arc unwraps into the pool");
        assert_eq!(held.len(), names.len(), "outside handle still valid");
    }

    #[test]
    fn merge_stats_degenerate_cleanly() {
        let (pairs, clients) = merge_workload(0x57a7);
        let pair_refs: Vec<(&str, Vec<usize>, Vec<f32>)> =
            pairs.iter().map(|(n, s, d)| (n.as_str(), s.clone(), d.clone())).collect();
        let names: Vec<String> = pairs.iter().map(|(n, _, _)| n.clone()).collect();

        let mut store = store_with(&pair_refs);
        let mut agg = Aggregator::new(&names, &store).unwrap();
        agg.add_owned(clients[0].0.clone(), clients[0].1);
        let stats = agg.finish_stats(&mut store, None).unwrap();
        assert_eq!(stats.workers, 1, "default is the inline serial merge");
        assert_eq!(stats.utilization(), 1.0, "serial utilization is 1.0 by construction");

        let zero = MergeStats { workers: 4, busy_ns: 0, wall_ns: 0 };
        assert_eq!(zero.utilization(), 1.0, "zero wall never divides by zero");
        let half = MergeStats { workers: 2, busy_ns: 100, wall_ns: 100 };
        assert!((half.utilization() - 0.5).abs() < 1e-12);
        let capped = MergeStats { workers: 2, busy_ns: 1000, wall_ns: 100 };
        assert_eq!(capped.utilization(), 1.0, "clock skew clamps at 1.0");
    }
}
