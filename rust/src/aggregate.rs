//! FedAvg aggregation (Eq. 1) — the per-round L3 hot path.
//!
//! Standard path: weighted average of same-shape client updates,
//! accumulated in-place (`Aggregator`). HeteroFL path: width-scaled
//! updates are corner-scattered into the full tensor with per-position
//! weight normalization (`SlicedAggregator`) — positions no client
//! covered keep the previous global value, exactly HeteroFL's rule.

use crate::store::{ParamStore, Tensor};
use anyhow::Result;

/// In-place weighted-average accumulator over a fixed parameter list.
pub struct Aggregator {
    names: Vec<String>,
    acc: Vec<Vec<f32>>,
    shapes: Vec<Vec<usize>>,
    total_weight: f64,
}

impl Aggregator {
    pub fn new(names: &[String], store: &ParamStore) -> Result<Self> {
        let mut acc = Vec::with_capacity(names.len());
        let mut shapes = Vec::with_capacity(names.len());
        for n in names {
            let t = store.get(n)?;
            acc.push(vec![0.0; t.len()]);
            shapes.push(t.shape.clone());
        }
        Ok(Aggregator { names: names.to_vec(), acc, shapes, total_weight: 0.0 })
    }

    /// Add one client's update set (tensors in `names` order). Accepts any
    /// slice-of-slices so the round loop can feed PJRT outputs without
    /// cloning (EXPERIMENTS.md §Perf iteration 3).
    pub fn add<T: AsRef<[f32]>>(&mut self, tensors: &[T], weight: f64) {
        debug_assert_eq!(tensors.len(), self.acc.len());
        let w = weight as f32;
        for (a, t) in self.acc.iter_mut().zip(tensors) {
            let t = t.as_ref();
            debug_assert_eq!(a.len(), t.len());
            for (x, v) in a.iter_mut().zip(t) {
                *x += w * v;
            }
        }
        self.total_weight += weight;
    }

    /// Normalize and write back into the store.
    pub fn finish(self, store: &mut ParamStore) -> Result<()> {
        debug_assert!(self.total_weight > 0.0, "aggregating zero clients");
        let inv = 1.0 / self.total_weight as f32;
        for ((name, mut a), shape) in self.names.into_iter().zip(self.acc).zip(self.shapes) {
            for x in &mut a {
                *x *= inv;
            }
            store.set(&name, Tensor { shape, data: a });
        }
        Ok(())
    }

    pub fn clients_added(&self) -> f64 {
        self.total_weight
    }
}

/// HeteroFL-style aggregation over width-heterogeneous updates.
pub struct SlicedAggregator {
    names: Vec<String>,
    full_shapes: Vec<Vec<usize>>,
    acc: Vec<Vec<f32>>,
    wacc: Vec<Vec<f32>>,
}

impl SlicedAggregator {
    pub fn new(names: &[String], store: &ParamStore) -> Result<Self> {
        let mut full_shapes = Vec::new();
        let mut acc = Vec::new();
        let mut wacc = Vec::new();
        for n in names {
            let t = store.get(n)?;
            full_shapes.push(t.shape.clone());
            acc.push(vec![0.0; t.len()]);
            wacc.push(vec![0.0; t.len()]);
        }
        Ok(SlicedAggregator { names: names.to_vec(), full_shapes, acc, wacc })
    }

    /// Add a client's update whose tensors are corner slices of the full
    /// shapes (sub_shapes[i] element-wise ≤ full_shapes[i]).
    pub fn add(&mut self, sub_shapes: &[Vec<usize>], tensors: &[Vec<f32>], weight: f64) {
        for i in 0..self.names.len() {
            Tensor::accumulate_corner(
                &self.full_shapes[i],
                &mut self.acc[i],
                &mut self.wacc[i],
                &sub_shapes[i],
                &tensors[i],
                weight as f32,
            );
        }
    }

    /// Positions with weight keep the normalized average; untouched
    /// positions keep the previous global value.
    pub fn finish(self, store: &mut ParamStore) -> Result<()> {
        for (i, name) in self.names.iter().enumerate() {
            let prev = store.get(name)?.clone();
            let mut out = prev.data;
            for j in 0..out.len() {
                if self.wacc[i][j] > 0.0 {
                    out[j] = self.acc[i][j] / self.wacc[i][j];
                }
            }
            store.set(name, Tensor { shape: self.full_shapes[i].clone(), data: out });
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::collections::BTreeMap;

    fn store_with(pairs: &[(&str, Vec<usize>, Vec<f32>)]) -> ParamStore {
        let shapes: BTreeMap<String, Vec<usize>> =
            pairs.iter().map(|(n, s, _)| (n.to_string(), s.clone())).collect();
        let mut store = ParamStore::init(&shapes, 0);
        for (n, s, d) in pairs {
            store.set(n, Tensor { shape: s.clone(), data: d.clone() });
        }
        store
    }

    #[test]
    fn weighted_average_exact() {
        let mut store = store_with(&[("w", vec![2], vec![0.0, 0.0])]);
        let names = vec!["w".to_string()];
        let mut agg = Aggregator::new(&names, &store).unwrap();
        agg.add(&[vec![1.0, 2.0]], 1.0);
        agg.add(&[vec![3.0, 6.0]], 3.0);
        agg.finish(&mut store).unwrap();
        let t = store.get("w").unwrap();
        assert_eq!(t.data, vec![2.5, 5.0]); // (1*1+3*3)/4, (2*1+6*3)/4
    }

    #[test]
    fn single_client_identity() {
        let mut store = store_with(&[("w", vec![3], vec![0.0; 3])]);
        let names = vec!["w".to_string()];
        let mut agg = Aggregator::new(&names, &store).unwrap();
        agg.add(&[vec![7.0, 8.0, 9.0]], 0.123);
        agg.finish(&mut store).unwrap();
        let t = store.get("w").unwrap();
        for (a, b) in t.data.iter().zip([7.0, 8.0, 9.0]) {
            assert!((a - b).abs() < 1e-6);
        }
    }

    #[test]
    fn sliced_aggregation_covers_and_preserves() {
        // full (2,4); client A covers (2,2) corner, client B covers (2,3).
        let mut store = store_with(&[("w", vec![2, 4], vec![9.0; 8])]);
        let names = vec!["w".to_string()];
        let mut agg = SlicedAggregator::new(&names, &store).unwrap();
        agg.add(&[vec![2, 2]], &[vec![1.0, 1.0, 1.0, 1.0]], 1.0);
        agg.add(&[vec![2, 3]], &[vec![2.0, 2.0, 2.0, 2.0, 2.0, 2.0]], 1.0);
        agg.finish(&mut store).unwrap();
        let t = store.get("w").unwrap();
        // col 0,1: avg(1,2)=1.5; col 2: only B -> 2.0; col 3: untouched -> 9.0
        assert_eq!(t.data, vec![1.5, 1.5, 2.0, 9.0, 1.5, 1.5, 2.0, 9.0]);
    }

    #[test]
    fn sliced_full_cover_equals_plain_fedavg() {
        let mut s1 = store_with(&[("w", vec![2, 2], vec![0.0; 4])]);
        let mut s2 = s1.clone();
        let names = vec!["w".to_string()];
        let u1 = vec![1.0, 2.0, 3.0, 4.0];
        let u2 = vec![5.0, 6.0, 7.0, 8.0];

        let mut plain = Aggregator::new(&names, &s1).unwrap();
        plain.add(&[u1.clone()], 2.0);
        plain.add(&[u2.clone()], 1.0);
        plain.finish(&mut s1).unwrap();

        let mut sliced = SlicedAggregator::new(&names, &s2).unwrap();
        sliced.add(&[vec![2, 2]], &[u1], 2.0);
        sliced.add(&[vec![2, 2]], &[u2], 1.0);
        sliced.finish(&mut s2).unwrap();

        let a = &s1.get("w").unwrap().data;
        let b = &s2.get("w").unwrap().data;
        for (x, y) in a.iter().zip(b) {
            assert!((x - y).abs() < 1e-6, "{x} vs {y}");
        }
    }
}
