//! Device-memory substrate: budgets, contention, participation decisions.
//!
//! Mirrors the paper's setup (§4.1): each of the N devices gets an
//! available-memory budget drawn uniformly from 100–900 MB "while
//! considering resource contention" — we model contention as a per-round
//! multiplicative factor U[contention_lo, 1.0] on the static budget
//! (co-resident apps steal a varying slice). A client can train an
//! artifact in round r iff the artifact's analytical training footprint
//! (paper-width-twin coefficients × accounting batch) fits its available
//! memory that round.
//!
//! Since available ≤ budget, [`can_train`] implies [`DeviceMemory::fits_static`]
//! — every dispatched client fits its artifact's static footprint, the
//! invariant the memory-strategy zoo's per-client depth caps rely on
//! (see `strategy::` and `docs/STRATEGIES.md`; property-tested in
//! `tests/proptests.rs`).

use crate::manifest::MemCoeffs;
use crate::rng::Rng;

/// One (decimal) megabyte, the paper's memory unit.
pub const MB: u64 = 1_000_000;

/// Memory-substrate knobs: budget range, contention, accounting batch.
#[derive(Debug, Clone, Copy)]
pub struct MemoryConfig {
    /// Static budget range lower bound, MB (paper: 100).
    pub budget_min_mb: u64,
    /// Static budget range upper bound, MB (paper: 900).
    pub budget_max_mb: u64,
    /// Per-round contention factor lower bound (available = budget × U[lo, 1]).
    pub contention_lo: f64,
    /// Batch size used for footprint accounting (paper-scale, decoupled
    /// from the mini models' execution batch).
    pub accounting_batch: u64,
}

impl Default for MemoryConfig {
    fn default() -> Self {
        MemoryConfig { budget_min_mb: 100, budget_max_mb: 900, contention_lo: 0.7, accounting_batch: 128 }
    }
}

/// One device's memory state.
#[derive(Debug, Clone)]
pub struct DeviceMemory {
    /// Static installed budget (bytes).
    pub budget: u64,
    rng: Rng,
}

impl DeviceMemory {
    /// Sample one device's static budget (uniform in the config range)
    /// and fork its per-round contention stream.
    pub fn sample(cfg: &MemoryConfig, rng: &mut Rng, client_id: usize) -> Self {
        let budget = (rng.uniform(cfg.budget_min_mb as f64, cfg.budget_max_mb as f64) * MB as f64) as u64;
        DeviceMemory { budget, rng: rng.fork(0xc0ffee ^ client_id as u64) }
    }

    /// Available memory this round (contention resampled per call).
    pub fn available(&mut self, cfg: &MemoryConfig) -> u64 {
        (self.budget as f64 * self.rng.uniform(cfg.contention_lo, 1.0)) as u64
    }

    /// Would `mem` fit statically (ignoring contention)? Used for stable
    /// capability grouping (e.g. HeteroFL ratio assignment).
    pub fn fits_static(&self, cfg: &MemoryConfig, mem: &MemCoeffs) -> bool {
        mem.bytes_at(cfg.accounting_batch) <= self.budget
    }

    /// The contention stream's raw rng state (checkpoint image; the
    /// static budget is re-derived from the build seed on resume).
    pub(crate) fn rng_state(&self) -> u64 {
        self.rng.state()
    }

    /// Reposition the contention stream at a checkpointed state.
    pub(crate) fn set_rng_state(&mut self, state: u64) {
        self.rng = Rng::from_state(state);
    }
}

/// Round-level participation decision for a concrete artifact.
pub fn can_train(avail: u64, cfg: &MemoryConfig, mem: &MemCoeffs) -> bool {
    mem.bytes_at(cfg.accounting_batch) <= avail
}

#[cfg(test)]
mod tests {
    use super::*;

    fn coeffs(fixed_mb: u64, per_sample_kb: u64) -> MemCoeffs {
        MemCoeffs {
            fixed_bytes: fixed_mb * MB,
            per_sample_bytes: per_sample_kb * 1000,
            params_total: 0,
            params_trainable: 0,
        }
    }

    #[test]
    fn budgets_in_range() {
        let cfg = MemoryConfig::default();
        let mut rng = Rng::new(1);
        for i in 0..200 {
            let d = DeviceMemory::sample(&cfg, &mut rng, i);
            assert!((100 * MB..=900 * MB).contains(&d.budget));
        }
    }

    #[test]
    fn contention_reduces_availability() {
        let cfg = MemoryConfig::default();
        let mut rng = Rng::new(2);
        let mut d = DeviceMemory::sample(&cfg, &mut rng, 0);
        for _ in 0..50 {
            let a = d.available(&cfg);
            assert!(a <= d.budget);
            assert!(a as f64 >= d.budget as f64 * cfg.contention_lo * 0.999);
        }
    }

    #[test]
    fn participation_thresholds() {
        let cfg = MemoryConfig::default();
        // 691 MB full-model footprint (ResNet18 paper twin at batch 128)
        let full = coeffs(131, 4375); // 131MB fixed + 4.375MB/sample*128 = 691MB
        assert!(!can_train(600 * MB, &cfg, &full));
        assert!(can_train(700 * MB, &cfg, &full));
    }

    #[test]
    fn accounting_batch_scales_footprint() {
        let mut cfg = MemoryConfig::default();
        let m = coeffs(10, 1000);
        let at128 = m.bytes_at(cfg.accounting_batch);
        cfg.accounting_batch = 32;
        assert!(m.bytes_at(cfg.accounting_batch) < at128);
    }

    #[test]
    fn can_train_implies_fits_static() {
        // The dispatch filter samples contended availability, which never
        // exceeds the static budget — so any client admitted for an
        // artifact also fits it statically. Strategy depth caps
        // (layerfreeze/elastic) lean on this implication.
        let cfg = MemoryConfig::default();
        let mut rng = Rng::new(7);
        let m = crate::strategy::layout_mem(
            &[2_000_000, 3_000_000, 3_000_000, 3_200_000],
            &crate::strategy::BlockLayout { frozen: 1, depth: 3 },
        );
        for i in 0..500 {
            let mut d = DeviceMemory::sample(&cfg, &mut rng, i);
            let a = d.available(&cfg);
            if can_train(a, &cfg, &m) {
                assert!(d.fits_static(&cfg, &m), "client {i} admitted but does not fit");
            }
        }
    }

    #[test]
    fn fleet_participation_rates_match_paper_shape() {
        // With U[100,900] budgets: a 691MB artifact should admit few
        // clients; a 112MB one nearly all — Table 1's PR column shape.
        let cfg = MemoryConfig::default();
        let mut rng = Rng::new(3);
        let mut devices: Vec<DeviceMemory> = (0..1000).map(|i| DeviceMemory::sample(&cfg, &mut rng, i)).collect();
        let full = coeffs(131, 4375); // ~691MB
        let op = coeffs(12, 780); // ~112MB
        let pr = |devices: &mut Vec<DeviceMemory>, m: &MemCoeffs| {
            let mut n = 0;
            for d in devices.iter_mut() {
                let a = d.available(&cfg);
                if can_train(a, &cfg, m) {
                    n += 1;
                }
            }
            n as f64 / 1000.0
        };
        let pr_full = pr(&mut devices, &full);
        let pr_op = pr(&mut devices, &op);
        assert!(pr_full < 0.25, "full PR {pr_full}");
        assert!(pr_op > 0.9, "op PR {pr_op}");
    }
}
