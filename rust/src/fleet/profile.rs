//! Device profiles: compute throughput, link speeds, availability,
//! dropout — the per-client half of the fleet simulator.
//!
//! Profiles are sampled per client at pool construction with the same
//! fork discipline as `DeviceMemory::sample`: every client draws from its
//! own forked `Rng` stream, so profiles are a pure function of
//! `(fleet profile, seed, client_id)` regardless of fleet size or draw
//! counts elsewhere.
//!
//! Training time uses the artifact's parameter count as a FLOPs proxy:
//! a device with `throughput` processes `throughput` sample·Mparam units
//! per virtual second, so one local pass over `n` samples of an
//! `M`-Mparam sub-model takes `n * M / throughput` seconds. This is the
//! standard linear device model used by heterogeneity-aware FL simulators
//! (cf. arXiv:2408.09101 §5, arXiv:2408.10826 §4).

use super::trace::AvailabilityTrace;
use crate::manifest::MemCoeffs;
use crate::rng::Rng;
use anyhow::{bail, Result};

/// Coarse device class, assigned by weighted draw at sampling time.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum DeviceTier {
    /// Slow tail (old phones, slow uplinks).
    Low,
    /// Mid-range devices.
    Mid,
    /// Fast, well-connected devices.
    High,
}

impl DeviceTier {
    fn from_index(i: usize) -> Self {
        match i {
            0 => DeviceTier::Low,
            1 => DeviceTier::Mid,
            _ => DeviceTier::High,
        }
    }
}

/// One tier's sampling ranges. Throughput is in sample·Mparam units per
/// virtual second; links are in MB/s.
#[derive(Debug, Clone, Copy)]
pub struct TierSpec {
    /// Relative draw weight of this tier in the fleet mix.
    pub weight: f64,
    /// Compute throughput sampling range (sample·Mparam per second).
    pub throughput: (f64, f64),
    /// Uplink speed sampling range (MB/s).
    pub uplink_mbs: (f64, f64),
    /// Downlink speed sampling range (MB/s).
    pub downlink_mbs: (f64, f64),
}

/// A named fleet composition: tier mix + shared availability/dropout
/// behaviour. Resolved from `RunConfig.fleet.profile`.
#[derive(Debug, Clone)]
pub struct FleetProfileConfig {
    /// Profile name (`uniform` | `mobile` | `datacenter`).
    pub name: String,
    /// Tier specs, index-aligned with [`DeviceTier`].
    pub tiers: Vec<TierSpec>,
    /// Per-round probability that a dispatched client silently vanishes.
    pub dropout_p: f64,
    /// Availability duty cycle (`>= 1.0` = always on).
    pub duty: f64,
    /// Availability period (virtual seconds).
    pub period_s: f64,
}

impl FleetProfileConfig {
    /// Resolve a named profile: `uniform` | `mobile` | `datacenter`.
    pub fn named(name: &str) -> Result<Self> {
        let p = match name {
            // Homogeneous mid-range fleet, always reachable, no dropout —
            // the backwards-compatible default: under the `sync` policy it
            // reproduces the pre-fleet round semantics exactly (every
            // memory-eligible sampled client aggregates).
            "uniform" => FleetProfileConfig {
                name: name.into(),
                tiers: vec![TierSpec {
                    weight: 1.0,
                    throughput: (80.0, 120.0),
                    uplink_mbs: (5.0, 15.0),
                    downlink_mbs: (10.0, 30.0),
                }],
                dropout_p: 0.0,
                duty: 1.0,
                period_s: 1.0,
            },
            // The paper's regime: a long tail of slow phones on slow
            // uplinks with intermittent availability — deadline pressure
            // bites here.
            "mobile" => FleetProfileConfig {
                name: name.into(),
                tiers: vec![
                    TierSpec {
                        weight: 0.5,
                        throughput: (8.0, 25.0),
                        uplink_mbs: (0.5, 2.0),
                        downlink_mbs: (2.0, 8.0),
                    },
                    TierSpec {
                        weight: 0.35,
                        throughput: (25.0, 80.0),
                        uplink_mbs: (1.0, 4.0),
                        downlink_mbs: (4.0, 16.0),
                    },
                    TierSpec {
                        weight: 0.15,
                        throughput: (80.0, 200.0),
                        uplink_mbs: (2.0, 8.0),
                        downlink_mbs: (8.0, 32.0),
                    },
                ],
                dropout_p: 0.1,
                duty: 0.85,
                period_s: 900.0,
            },
            // Fast, wired, reliable — the degenerate case where every
            // policy behaves like `sync`.
            "datacenter" => FleetProfileConfig {
                name: name.into(),
                tiers: vec![
                    TierSpec {
                        weight: 0.2,
                        throughput: (150.0, 250.0),
                        uplink_mbs: (50.0, 120.0),
                        downlink_mbs: (50.0, 120.0),
                    },
                    TierSpec {
                        weight: 0.8,
                        throughput: (250.0, 500.0),
                        uplink_mbs: (50.0, 120.0),
                        downlink_mbs: (50.0, 120.0),
                    },
                ],
                dropout_p: 0.0,
                duty: 1.0,
                period_s: 1.0,
            },
            other => bail!("unknown fleet profile `{other}` (uniform|mobile|datacenter)"),
        };
        Ok(p)
    }
}

/// One device's simulator-facing characteristics (sampled once per run).
#[derive(Debug, Clone, PartialEq)]
pub struct DeviceProfile {
    /// Coarse device class the profile was drawn from.
    pub tier: DeviceTier,
    /// sample·Mparam per virtual second.
    pub throughput: f64,
    /// Upload speed, bytes per virtual second.
    pub uplink_bps: f64,
    /// Download speed, bytes per virtual second.
    pub downlink_bps: f64,
    /// Per-round dropout probability once dispatched.
    pub dropout_p: f64,
    /// Periodic availability trace (gates dispatch; sampled mid-span by
    /// the churn engine).
    pub trace: AvailabilityTrace,
}

impl DeviceProfile {
    /// Sample a client's profile from its own forked stream (see module
    /// docs; mirrors `DeviceMemory::sample`).
    pub fn sample(cfg: &FleetProfileConfig, rng: &mut Rng, client_id: usize) -> Self {
        let mut r = rng.fork(0xdec1_ce00 ^ client_id as u64);
        let total: f64 = cfg.tiers.iter().map(|t| t.weight).sum();
        let probs: Vec<f64> = cfg.tiers.iter().map(|t| t.weight / total.max(1e-12)).collect();
        let ti = r.categorical(&probs);
        let spec = cfg.tiers[ti];
        let throughput = r.uniform(spec.throughput.0, spec.throughput.1);
        let uplink_bps = r.uniform(spec.uplink_mbs.0, spec.uplink_mbs.1) * 1e6;
        let downlink_bps = r.uniform(spec.downlink_mbs.0, spec.downlink_mbs.1) * 1e6;
        let trace = if cfg.duty >= 1.0 {
            AvailabilityTrace::always_on()
        } else {
            AvailabilityTrace::sample(cfg.period_s, cfg.duty, &mut r)
        };
        DeviceProfile {
            tier: DeviceTier::from_index(ti),
            throughput,
            uplink_bps,
            downlink_bps,
            dropout_p: cfg.dropout_p,
            trace,
        }
    }

    /// Virtual seconds for one local pass over `samples` of an artifact
    /// with memory coefficients `mem` (params_total as the FLOPs proxy;
    /// floored at 0.01 Mparam so metadata-free test artifacts still cost
    /// nonzero time).
    pub fn train_time_s(&self, samples: usize, mem: &MemCoeffs) -> f64 {
        let mparams = (mem.params_total as f64 / 1e6).max(0.01);
        samples as f64 * mparams / self.throughput.max(1e-9)
    }

    /// Virtual seconds to upload `bytes` at this device's uplink speed.
    pub fn up_time_s(&self, bytes: u64) -> f64 {
        bytes as f64 / self.uplink_bps.max(1.0)
    }

    /// Virtual seconds to download `bytes` at this device's downlink
    /// speed.
    pub fn down_time_s(&self, bytes: u64) -> f64 {
        bytes as f64 / self.downlink_bps.max(1.0)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn coeffs(mparams: u64) -> MemCoeffs {
        MemCoeffs {
            fixed_bytes: 0,
            per_sample_bytes: 0,
            params_total: mparams * 1_000_000,
            params_trainable: mparams * 1_000_000,
        }
    }

    #[test]
    fn named_profiles_resolve() {
        for name in ["uniform", "mobile", "datacenter"] {
            let p = FleetProfileConfig::named(name).unwrap();
            assert_eq!(p.name, name);
            assert!(!p.tiers.is_empty());
        }
        assert!(FleetProfileConfig::named("nope").is_err());
    }

    #[test]
    fn sampling_is_deterministic_per_client_fork() {
        let cfg = FleetProfileConfig::named("mobile").unwrap();
        let mut a = Rng::new(5);
        let mut b = Rng::new(5);
        for id in 0..20 {
            let pa = DeviceProfile::sample(&cfg, &mut a, id);
            let pb = DeviceProfile::sample(&cfg, &mut b, id);
            assert_eq!(pa, pb, "client {id}");
        }
        // Different clients diverge.
        let p0 = DeviceProfile::sample(&cfg, &mut a, 0);
        let p1 = DeviceProfile::sample(&cfg, &mut a, 1);
        assert_ne!(p0.throughput, p1.throughput);
    }

    #[test]
    fn sampled_values_in_tier_ranges() {
        let cfg = FleetProfileConfig::named("mobile").unwrap();
        let mut rng = Rng::new(7);
        let mut tiers_seen = std::collections::BTreeSet::new();
        for id in 0..200 {
            let p = DeviceProfile::sample(&cfg, &mut rng, id);
            let spec = cfg.tiers[match p.tier {
                DeviceTier::Low => 0,
                DeviceTier::Mid => 1,
                DeviceTier::High => 2,
            }];
            assert!(p.throughput >= spec.throughput.0 && p.throughput < spec.throughput.1);
            assert!(p.uplink_bps >= spec.uplink_mbs.0 * 1e6);
            assert!(p.downlink_bps >= spec.downlink_mbs.0 * 1e6);
            tiers_seen.insert(format!("{:?}", p.tier));
        }
        assert!(tiers_seen.len() >= 2, "mobile fleet should mix tiers");
    }

    #[test]
    fn train_time_scales_with_model_and_samples() {
        let cfg = FleetProfileConfig::named("uniform").unwrap();
        let mut rng = Rng::new(9);
        let p = DeviceProfile::sample(&cfg, &mut rng, 0);
        let small = p.train_time_s(100, &coeffs(1));
        let big = p.train_time_s(100, &coeffs(10));
        let more = p.train_time_s(200, &coeffs(1));
        assert!(big > small * 9.0);
        assert!((more - 2.0 * small).abs() < 1e-9);
    }

    #[test]
    fn comm_times_follow_link_speeds() {
        let p = DeviceProfile {
            tier: DeviceTier::Mid,
            throughput: 100.0,
            uplink_bps: 1e6,
            downlink_bps: 2e6,
            dropout_p: 0.0,
            trace: AvailabilityTrace::always_on(),
        };
        assert!((p.up_time_s(2_000_000) - 2.0).abs() < 1e-9);
        assert!((p.down_time_s(2_000_000) - 1.0).abs() < 1e-9);
    }
}
