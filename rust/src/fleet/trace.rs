//! Availability traces: when is a device reachable for dispatch?
//!
//! Devices follow a per-client periodic on/off square wave (charging /
//! screen-off windows in the mobile profile): within each `period_s`
//! window the device is online for the first `duty` fraction, shifted by
//! a client-specific `phase_s` sampled at fleet construction. The trace
//! gates *dispatch* only — a device that goes offline mid-round is
//! modelled by the dropout probability instead, which keeps the event
//! algebra simple while still producing realistic cohort skew.

use crate::rng::Rng;

#[derive(Debug, Clone, PartialEq)]
pub struct AvailabilityTrace {
    /// On/off cycle length (virtual seconds).
    pub period_s: f64,
    /// Fraction of each period the device is online; `>= 1.0` = always on.
    pub duty: f64,
    /// Per-client phase offset into the cycle.
    pub phase_s: f64,
}

impl AvailabilityTrace {
    /// A device that never leaves the fleet (uniform/datacenter profiles).
    pub fn always_on() -> Self {
        AvailabilityTrace { period_s: 1.0, duty: 1.0, phase_s: 0.0 }
    }

    /// Sample a client's trace: fixed period/duty, random phase.
    pub fn sample(period_s: f64, duty: f64, rng: &mut Rng) -> Self {
        let phase_s = rng.uniform(0.0, period_s.max(1e-9));
        AvailabilityTrace { period_s, duty, phase_s }
    }

    /// Position inside the current cycle at virtual time `t`.
    fn cycle_pos(&self, t: f64) -> f64 {
        (t + self.phase_s).rem_euclid(self.period_s)
    }

    pub fn is_online(&self, t: f64) -> bool {
        if self.duty >= 1.0 {
            return true;
        }
        if self.duty <= 0.0 {
            return false;
        }
        self.cycle_pos(t) < self.duty * self.period_s
    }

    /// Earliest time `>= t` at which the device is online. A zero-duty
    /// trace returns `f64::INFINITY` (the client can never be dispatched;
    /// deadline policies turn it into a straggler).
    pub fn next_online(&self, t: f64) -> f64 {
        if self.duty >= 1.0 {
            return t;
        }
        if self.duty <= 0.0 {
            return f64::INFINITY;
        }
        if self.is_online(t) {
            t
        } else {
            t + (self.period_s - self.cycle_pos(t))
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn always_on_is_always_on() {
        let tr = AvailabilityTrace::always_on();
        for t in [0.0, 17.3, 1e9] {
            assert!(tr.is_online(t));
            assert_eq!(tr.next_online(t), t);
        }
    }

    #[test]
    fn duty_cycle_toggles() {
        // period 100, duty 0.6, phase 0: online on [0,60), offline [60,100).
        let tr = AvailabilityTrace { period_s: 100.0, duty: 0.6, phase_s: 0.0 };
        assert!(tr.is_online(0.0));
        assert!(tr.is_online(59.9));
        assert!(!tr.is_online(60.0));
        assert!(!tr.is_online(99.9));
        assert!(tr.is_online(100.0));
    }

    #[test]
    fn next_online_jumps_to_cycle_start() {
        let tr = AvailabilityTrace { period_s: 100.0, duty: 0.6, phase_s: 0.0 };
        assert_eq!(tr.next_online(30.0), 30.0);
        assert!((tr.next_online(75.0) - 100.0).abs() < 1e-9);
        assert!((tr.next_online(175.0) - 200.0).abs() < 1e-9);
        assert!(tr.is_online(tr.next_online(75.0)));
    }

    #[test]
    fn zero_duty_never_online() {
        let tr = AvailabilityTrace { period_s: 100.0, duty: 0.0, phase_s: 0.0 };
        assert!(!tr.is_online(5.0));
        assert_eq!(tr.next_online(5.0), f64::INFINITY);
    }

    #[test]
    fn sampled_phase_in_period_and_deterministic() {
        let mut a = Rng::new(11);
        let mut b = Rng::new(11);
        let ta = AvailabilityTrace::sample(600.0, 0.8, &mut a);
        let tb = AvailabilityTrace::sample(600.0, 0.8, &mut b);
        assert_eq!(ta, tb);
        assert!((0.0..600.0).contains(&ta.phase_s));
    }
}
