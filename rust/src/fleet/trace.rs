//! Availability traces: when is a device reachable — and for how long?
//!
//! Devices follow a per-client periodic on/off square wave (charging /
//! screen-off windows in the mobile profile): within each `period_s`
//! window the device is online for the first `duty` fraction, shifted by
//! a client-specific `phase_s` sampled at fleet construction. The trace
//! gates dispatch (`next_online`) *and* is sampled inside every
//! compute/upload span by the churn engine: [`Self::next_offline`] finds
//! the interruption instant, and [`Self::walk_work`] completes a pausable
//! span across offline windows (the `resume`/`checkpoint` churn
//! policies). Under `ChurnPolicy::None` the mid-span lookups are skipped
//! and the trace gates dispatch only (the pre-churn behaviour).

use crate::rng::Rng;

/// One offline window a pausable span crossed while work was pending:
/// the device went offline at `off_s` and work resumed at `on_s`.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct OfflineSpan {
    /// When the device went offline (absolute virtual seconds).
    pub off_s: f64,
    /// When it came back online and work resumed.
    pub on_s: f64,
}

/// A device's periodic on/off availability square wave.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct AvailabilityTrace {
    /// On/off cycle length (virtual seconds).
    pub period_s: f64,
    /// Fraction of each period the device is online; `>= 1.0` = always on.
    pub duty: f64,
    /// Per-client phase offset into the cycle.
    pub phase_s: f64,
}

impl AvailabilityTrace {
    /// A device that never leaves the fleet (uniform/datacenter profiles).
    pub fn always_on() -> Self {
        AvailabilityTrace { period_s: 1.0, duty: 1.0, phase_s: 0.0 }
    }

    /// Sample a client's trace: fixed period/duty, random phase.
    pub fn sample(period_s: f64, duty: f64, rng: &mut Rng) -> Self {
        let phase_s = rng.uniform(0.0, period_s.max(1e-9));
        AvailabilityTrace { period_s, duty, phase_s }
    }

    /// Position inside the current cycle at virtual time `t`.
    fn cycle_pos(&self, t: f64) -> f64 {
        (t + self.phase_s).rem_euclid(self.period_s)
    }

    /// Whether the device is reachable at virtual time `t`.
    pub fn is_online(&self, t: f64) -> bool {
        if self.duty >= 1.0 {
            return true;
        }
        if self.duty <= 0.0 {
            return false;
        }
        self.cycle_pos(t) < self.duty * self.period_s
    }

    /// Earliest time `>= t` at which the device is online. A zero-duty
    /// trace returns `f64::INFINITY` (the client can never be dispatched;
    /// deadline policies turn it into a straggler).
    pub fn next_online(&self, t: f64) -> f64 {
        if self.duty >= 1.0 {
            return t;
        }
        if self.duty <= 0.0 {
            return f64::INFINITY;
        }
        if self.is_online(t) {
            t
        } else {
            t + (self.period_s - self.cycle_pos(t))
        }
    }

    /// Earliest time `>= t` at which the device goes offline. Always-on
    /// traces return `f64::INFINITY` (no mid-span churn possible); an
    /// offline instant returns `t` itself.
    pub fn next_offline(&self, t: f64) -> f64 {
        if self.duty >= 1.0 {
            return f64::INFINITY;
        }
        if self.duty <= 0.0 || !self.is_online(t) {
            return t;
        }
        t + (self.duty * self.period_s - self.cycle_pos(t))
    }

    /// Complete `dur` seconds of *pausable* work starting at `t`: work
    /// advances only while the device is online and pauses across offline
    /// windows (the `resume`/`checkpoint` churn semantics). Returns the
    /// completion time and the offline windows crossed, in order. A span
    /// starting at an offline instant counts that window too. Zero-duty
    /// traces never finish (`INFINITY`, no windows) — callers gate
    /// dispatch on `next_online`, so this is a defensive dead end.
    pub fn walk_work(&self, t: f64, dur: f64) -> (f64, Vec<OfflineSpan>) {
        if self.duty >= 1.0 || dur <= 0.0 {
            return (t + dur, Vec::new());
        }
        if self.duty <= 0.0 {
            return (f64::INFINITY, Vec::new());
        }
        let mut spans = Vec::new();
        let mut cur = t;
        let mut remaining = dur;
        loop {
            if !self.is_online(cur) {
                let on = self.next_online(cur);
                spans.push(OfflineSpan { off_s: cur, on_s: on });
                cur = on;
            }
            let off = self.next_offline(cur);
            if remaining <= off - cur {
                return (cur + remaining, spans);
            }
            remaining -= off - cur;
            cur = off;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn always_on_is_always_on() {
        let tr = AvailabilityTrace::always_on();
        for t in [0.0, 17.3, 1e9] {
            assert!(tr.is_online(t));
            assert_eq!(tr.next_online(t), t);
        }
    }

    #[test]
    fn duty_cycle_toggles() {
        // period 100, duty 0.6, phase 0: online on [0,60), offline [60,100).
        let tr = AvailabilityTrace { period_s: 100.0, duty: 0.6, phase_s: 0.0 };
        assert!(tr.is_online(0.0));
        assert!(tr.is_online(59.9));
        assert!(!tr.is_online(60.0));
        assert!(!tr.is_online(99.9));
        assert!(tr.is_online(100.0));
    }

    #[test]
    fn next_online_jumps_to_cycle_start() {
        let tr = AvailabilityTrace { period_s: 100.0, duty: 0.6, phase_s: 0.0 };
        assert_eq!(tr.next_online(30.0), 30.0);
        assert!((tr.next_online(75.0) - 100.0).abs() < 1e-9);
        assert!((tr.next_online(175.0) - 200.0).abs() < 1e-9);
        assert!(tr.is_online(tr.next_online(75.0)));
    }

    #[test]
    fn zero_duty_never_online() {
        let tr = AvailabilityTrace { period_s: 100.0, duty: 0.0, phase_s: 0.0 };
        assert!(!tr.is_online(5.0));
        assert_eq!(tr.next_online(5.0), f64::INFINITY);
    }

    #[test]
    fn next_offline_finds_window_end() {
        // period 100, duty 0.6, phase 0: online [0,60), offline [60,100).
        let tr = AvailabilityTrace { period_s: 100.0, duty: 0.6, phase_s: 0.0 };
        assert!((tr.next_offline(0.0) - 60.0).abs() < 1e-9);
        assert!((tr.next_offline(59.0) - 60.0).abs() < 1e-9);
        assert_eq!(tr.next_offline(60.0), 60.0, "already offline");
        assert_eq!(tr.next_offline(99.0), 99.0);
        assert!((tr.next_offline(100.0) - 160.0).abs() < 1e-9);
        assert_eq!(AvailabilityTrace::always_on().next_offline(5.0), f64::INFINITY);
    }

    #[test]
    fn walk_work_pauses_across_offline_windows() {
        let tr = AvailabilityTrace { period_s: 100.0, duty: 0.6, phase_s: 0.0 };
        // Fits inside the online window: no pause.
        let (end, spans) = tr.walk_work(10.0, 20.0);
        assert_eq!(end, 30.0);
        assert!(spans.is_empty());
        // 80s of work from t=10: 50s until 60, pause to 100, 30s more.
        let (end, spans) = tr.walk_work(10.0, 80.0);
        assert!((end - 130.0).abs() < 1e-9);
        assert_eq!(spans.len(), 1);
        assert_eq!((spans[0].off_s, spans[0].on_s), (60.0, 100.0));
        // Spanning two offline windows.
        let (end, spans) = tr.walk_work(0.0, 130.0);
        assert!((end - 210.0).abs() < 1e-9);
        assert_eq!(spans.len(), 2);
        // Starting offline counts that window first.
        let (end, spans) = tr.walk_work(70.0, 10.0);
        assert!((end - 110.0).abs() < 1e-9);
        assert_eq!((spans[0].off_s, spans[0].on_s), (70.0, 100.0));
        // Always-on: identity.
        let (end, spans) = AvailabilityTrace::always_on().walk_work(3.0, 9.0);
        assert_eq!((end, spans.len()), (12.0, 0));
    }

    #[test]
    fn walk_work_never_finishes_early() {
        let tr = AvailabilityTrace { period_s: 100.0, duty: 0.3, phase_s: 17.0 };
        for t in [0.0, 12.5, 40.0, 99.0] {
            for dur in [0.5, 10.0, 75.0, 240.0] {
                let (end, spans) = tr.walk_work(t, dur);
                assert!(end >= t + dur - 1e-9, "t={t} dur={dur} end={end}");
                // Online time consumed equals the requested duration.
                let paused: f64 = spans.iter().map(|s| s.on_s - s.off_s).sum();
                assert!((end - t - paused - dur).abs() < 1e-6, "t={t} dur={dur}");
            }
        }
    }

    #[test]
    fn sampled_phase_in_period_and_deterministic() {
        let mut a = Rng::new(11);
        let mut b = Rng::new(11);
        let ta = AvailabilityTrace::sample(600.0, 0.8, &mut a);
        let tb = AvailabilityTrace::sample(600.0, 0.8, &mut b);
        assert_eq!(ta, tb);
        assert!((0.0..600.0).contains(&ta.phase_s));
    }
}
