//! Discrete-event substrate: virtual clock + deterministic event queue.
//!
//! The queue is a binary min-heap ordered by `(time_s, seq)`: ties on
//! virtual time break by insertion order, so a round's event trace is a
//! pure function of the inputs — no wall clock, no hash-map iteration
//! order, nothing platform-dependent.

use std::cmp::{Ordering, Reverse};
use std::collections::BinaryHeap;

/// What can happen to a dispatched client during one simulated round.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum EventKind {
    /// Server ships the round's sub-model to the client.
    Dispatch { client: usize },
    /// Client finished its local training pass.
    TrainDone { client: usize },
    /// Client's update arrived back at the server.
    UploadDone { client: usize },
    /// An upload dispatched in an *earlier* round arrived at the server
    /// (async policy: the cross-round in-flight queue).
    LateUpload { client: usize },
    /// The client's availability trace flipped offline in the middle of a
    /// compute or upload span (mid-round churn). Under the `abort` churn
    /// policy (or a `checkpoint` interruption before the first epoch
    /// boundary) this kills the client's round work; under
    /// `resume`/`checkpoint` it marks the start of a paused window.
    Interrupt { client: usize },
    /// The client came back online and its paused work continued
    /// (`resume`/`checkpoint` churn policies).
    Resume { client: usize },
    /// The round policy's aggregation deadline fired.
    Deadline,
}

impl EventKind {
    /// The client this event concerns, if any.
    pub fn client(&self) -> Option<usize> {
        match *self {
            EventKind::Dispatch { client }
            | EventKind::TrainDone { client }
            | EventKind::UploadDone { client }
            | EventKind::LateUpload { client }
            | EventKind::Interrupt { client }
            | EventKind::Resume { client } => Some(client),
            EventKind::Deadline => None,
        }
    }
}

/// One scheduled occurrence. `seq` is the queue-assigned insertion index
/// (unique per queue), which doubles as the deterministic tie-breaker.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Event {
    /// Absolute virtual time the event fires (seconds since run start).
    pub time_s: f64,
    /// Queue insertion index: unique, and the tie-breaker at equal times.
    pub seq: u64,
    /// What happened.
    pub kind: EventKind,
}

// Times are finite by construction (virtual seconds), so total_cmp gives
// a genuine total order and Eq is sound.
impl Eq for Event {}

impl Ord for Event {
    fn cmp(&self, other: &Self) -> Ordering {
        self.time_s.total_cmp(&other.time_s).then(self.seq.cmp(&other.seq))
    }
}

impl PartialOrd for Event {
    fn partial_cmp(&self, other: &Self) -> Option<Ordering> {
        Some(self.cmp(other))
    }
}

/// Min-heap event queue with deterministic FIFO tie-breaking.
#[derive(Debug, Default)]
pub struct EventQueue {
    heap: BinaryHeap<Reverse<Event>>,
    next_seq: u64,
    /// High-water mark of `heap.len()` since the last [`Self::clear`]
    /// (pure observation for telemetry; never read by the simulation).
    peak: usize,
}

impl EventQueue {
    /// An empty queue (sequence counter at zero).
    pub fn new() -> Self {
        EventQueue::default()
    }

    /// Schedule `kind` at absolute virtual time `time_s`.
    pub fn push(&mut self, time_s: f64, kind: EventKind) {
        let seq = self.next_seq;
        self.next_seq += 1;
        self.heap.push(Reverse(Event { time_s, seq, kind }));
        self.peak = self.peak.max(self.heap.len());
    }

    /// Earliest event (ties in insertion order), removing it.
    pub fn pop(&mut self) -> Option<Event> {
        self.heap.pop().map(|Reverse(e)| e)
    }

    /// Reset to the pristine state — empty heap, sequence counter back at
    /// zero — **keeping the allocated capacity**. The fleet engine's
    /// per-round scratch reuses one queue across rounds this way; the
    /// seq reset matters because golden traces pin seq numbers, which
    /// must restart at 0 each round exactly like a fresh queue's.
    pub fn clear(&mut self) {
        self.heap.clear();
        self.next_seq = 0;
        self.peak = 0;
    }

    /// Number of events still scheduled.
    pub fn len(&self) -> usize {
        self.heap.len()
    }

    /// High-water mark of the scheduled-event count since the last
    /// [`Self::clear`] — the round's peak queue depth, surfaced to
    /// telemetry via `FleetEngine::last_queue_peak`.
    pub fn peak_len(&self) -> usize {
        self.peak
    }

    /// Whether nothing is scheduled.
    pub fn is_empty(&self) -> bool {
        self.heap.is_empty()
    }
}

/// Monotone virtual clock (seconds since run start). The event loop is
/// the only writer; `advance_to` enforces monotonicity in debug builds.
#[derive(Debug, Clone, Copy, Default)]
pub struct VirtualClock {
    now_s: f64,
}

impl VirtualClock {
    /// A clock starting at `start_s` virtual seconds.
    pub fn new(start_s: f64) -> Self {
        VirtualClock { now_s: start_s }
    }

    /// Current virtual time (seconds since run start).
    pub fn now_s(&self) -> f64 {
        self.now_s
    }

    /// Advance to `t` (must not move backwards; asserted in debug).
    pub fn advance_to(&mut self, t: f64) {
        debug_assert!(t >= self.now_s, "clock moved backwards: {} -> {t}", self.now_s);
        self.now_s = self.now_s.max(t);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pops_in_time_order() {
        let mut q = EventQueue::new();
        q.push(5.0, EventKind::Deadline);
        q.push(1.0, EventKind::Dispatch { client: 0 });
        q.push(3.0, EventKind::TrainDone { client: 0 });
        let times: Vec<f64> = std::iter::from_fn(|| q.pop()).map(|e| e.time_s).collect();
        assert_eq!(times, vec![1.0, 3.0, 5.0]);
    }

    #[test]
    fn ties_break_by_insertion_order() {
        let mut q = EventQueue::new();
        for c in 0..5 {
            q.push(2.0, EventKind::Dispatch { client: c });
        }
        let clients: Vec<usize> =
            std::iter::from_fn(|| q.pop()).filter_map(|e| e.kind.client()).collect();
        assert_eq!(clients, vec![0, 1, 2, 3, 4]);
    }

    #[test]
    fn interleaved_push_pop_stays_ordered() {
        let mut q = EventQueue::new();
        q.push(10.0, EventKind::UploadDone { client: 1 });
        q.push(4.0, EventKind::Dispatch { client: 2 });
        assert_eq!(q.pop().unwrap().time_s, 4.0);
        q.push(6.0, EventKind::TrainDone { client: 2 });
        assert_eq!(q.pop().unwrap().time_s, 6.0);
        assert_eq!(q.pop().unwrap().time_s, 10.0);
        assert!(q.is_empty());
    }

    #[test]
    fn peak_len_tracks_high_water_mark() {
        let mut q = EventQueue::new();
        assert_eq!(q.peak_len(), 0);
        q.push(1.0, EventKind::Dispatch { client: 0 });
        q.push(2.0, EventKind::Dispatch { client: 1 });
        q.push(3.0, EventKind::Deadline);
        assert_eq!(q.peak_len(), 3);
        q.pop();
        q.pop();
        assert_eq!(q.peak_len(), 3, "peak survives drains");
        q.push(4.0, EventKind::Deadline);
        assert_eq!(q.peak_len(), 3, "refilling below the peak keeps it");
        q.clear();
        assert_eq!(q.peak_len(), 0, "clear resets the round's peak");
    }

    #[test]
    fn clear_resets_seq_and_keeps_ordering_semantics() {
        let mut q = EventQueue::new();
        q.push(1.0, EventKind::Dispatch { client: 0 });
        q.push(2.0, EventKind::Dispatch { client: 1 });
        assert_eq!(q.pop().unwrap().seq, 0);
        q.clear();
        assert!(q.is_empty());
        // A cleared queue numbers events exactly like a fresh one.
        q.push(5.0, EventKind::Deadline);
        let e = q.pop().unwrap();
        assert_eq!(e.seq, 0, "seq must restart at 0 after clear");
        assert_eq!(e.time_s, 5.0);
    }

    #[test]
    fn clock_is_monotone() {
        let mut c = VirtualClock::new(1.0);
        c.advance_to(3.5);
        assert_eq!(c.now_s(), 3.5);
        c.advance_to(3.5); // equal is allowed
        assert_eq!(c.now_s(), 3.5);
    }
}
