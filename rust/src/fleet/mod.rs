//! Fleet simulator (L3): deterministic discrete-event engine for
//! heterogeneous-device round dynamics.
//!
//! The seed coordinator modelled the fleet as a memoryless synchronous
//! loop — every sampled client trained "instantly", so the system could
//! say nothing about wall-clock time-to-accuracy, stragglers, or
//! dropout. This module adds the missing dimension: every client carries
//! a [`DeviceProfile`] (compute throughput, link speeds, availability
//! trace, dropout probability), a train round dispatches its cohort as
//! events on a virtual clock, and a [`RoundPolicy`] decides who makes it
//! into the aggregate:
//!
//! * [`RoundPolicy::Sync`] — wait for every dispatched client; round
//!   time is the slowest participant's finish time.
//! * [`RoundPolicy::Deadline`] — aggregate whatever has arrived when the
//!   deadline fires; the rest are counted as stragglers.
//! * [`RoundPolicy::OverSelect`] — sample `per_round + extra` clients
//!   and keep the first `per_round` finishers (FedScale-style
//!   over-commitment).
//! * [`RoundPolicy::Async`] — semi-synchronous FedBuff-style buffering:
//!   the round closes at the `buffer_k`-th upload arrival, and uploads
//!   that miss the window are *not* discarded — they persist in the
//!   [`FleetEngine`]'s cross-round in-flight queue and surface as
//!   [`RoundPlan::late_arrivals`] in the round where they land, tagged
//!   with their dispatch round so the server can staleness-discount (or
//!   drop) them.
//!
//! `sync`/`deadline`/`over-select` rounds are self-contained, so the
//! plain [`simulate_round`] function serves them. `async` spans rounds:
//! the [`FleetEngine`] owns the in-flight uploads between
//! `simulate_round` calls and is the one entry point that handles every
//! policy.
//!
//! **Mid-round churn.** Availability is not just a dispatch predicate:
//! the trace is sampled *inside* every compute and upload span, and when
//! a device flips offline mid-span the engine emits an
//! [`EventKind::Interrupt`] and applies the configured [`ChurnPolicy`]:
//!
//! * [`ChurnPolicy::None`] — pre-churn behaviour (trace gates dispatch
//!   only); the backwards-compatible default.
//! * [`ChurnPolicy::Abort`] — the round work is lost at the interruption
//!   instant; executed train seconds accrue to
//!   [`RoundPlan::wasted_compute_s`].
//! * [`ChurnPolicy::Resume`] — work pauses across the offline window and
//!   continues at the next online one ([`EventKind::Resume`]), stretching
//!   the span (and, under `async`, the in-flight queue) across round
//!   deadlines.
//! * [`ChurnPolicy::Checkpoint`] — training checkpoints at epoch
//!   granularity: an interrupted client uploads the last completed
//!   epoch's partial update ([`RoundPlan::partials`], weight ∝ completed
//!   samples); the partial-epoch remainder is wasted. Downloads and
//!   uploads pause/resume like `resume`.
//!
//! Everything is seeded: same config + seed ⇒ identical event order,
//! `sim_time_s`, and straggler/dropout/churn counts, bit for bit. With
//! `buffer_k` ≥ the dispatched cohort size, an async round closes at the
//! last upload — exactly the sync schedule, which is what makes the
//! async policy degenerate to `sync` bit-for-bit (see `lib.rs` docs).
//! Likewise any churn policy degenerates to `none` on always-on traces:
//! the fast path pushes the identical event stream, so churn costs
//! nothing when unused (golden-trace- and integration-tested).

pub mod event;
pub mod profile;
pub mod trace;

pub use event::{Event, EventKind, EventQueue, VirtualClock};
pub use profile::{DeviceProfile, DeviceTier, FleetProfileConfig, TierSpec};
pub use trace::{AvailabilityTrace, OfflineSpan};

use crate::rng::Rng;
use anyhow::{bail, Result};

/// How a train round decides when to aggregate.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum RoundPolicy {
    /// Wait for every dispatched client (classic synchronous FedAvg).
    Sync,
    /// Aggregate at `start + secs`; unfinished clients become stragglers.
    Deadline {
        /// Cut-off, virtual seconds after the round opens.
        secs: f64,
    },
    /// Sample `extra` clients beyond `per_round`, keep the first
    /// `per_round` finishers, count the rest as stragglers.
    OverSelect {
        /// Over-commitment margin beyond `per_round`.
        extra: usize,
    },
    /// Semi-synchronous FedBuff-style buffering: close the round at the
    /// `buffer_k`-th arrival; later uploads stay in flight and merge on
    /// arrival unless older than `max_staleness` rounds.
    Async {
        /// Arrivals that close a round.
        buffer_k: usize,
        /// Staleness cap (rounds) for late merges.
        max_staleness: usize,
    },
}

/// Config-supplied fallbacks for the bare policy spellings
/// (`deadline`, `over-select`, `async` without a `:K` argument).
#[derive(Debug, Clone, Copy)]
pub struct PolicyDefaults {
    /// Seconds for a bare `deadline`.
    pub deadline_s: f64,
    /// Extra clients for a bare `over-select`.
    pub over_select_extra: usize,
    /// Arrivals closing a round for a bare `async`.
    pub buffer_k: usize,
    /// Staleness cap (rounds) for async late merges.
    pub max_staleness: usize,
}

impl Default for PolicyDefaults {
    fn default() -> Self {
        PolicyDefaults { deadline_s: 60.0, over_select_extra: 4, buffer_k: 10, max_staleness: 8 }
    }
}

impl RoundPolicy {
    /// Parse a CLI/config spelling. Accepts `sync`, `deadline`,
    /// `deadline:SECS`, `over-select`, `over-select:K`, `async`,
    /// `async:K`; the bare forms take their value from `defaults`.
    pub fn parse(s: &str, defaults: &PolicyDefaults) -> Result<Self> {
        let (head, arg) = match s.split_once(':') {
            Some((h, a)) => (h, Some(a)),
            None => (s, None),
        };
        match head {
            "sync" => Ok(RoundPolicy::Sync),
            "deadline" => {
                let secs: f64 = match arg {
                    Some(a) => a.parse().map_err(|e| anyhow::anyhow!("bad deadline `{a}`: {e}"))?,
                    None => defaults.deadline_s,
                };
                // Zero would close every round at its open instant
                // (nobody can finish in 0 virtual seconds) — reject it
                // along with negatives and non-finite values.
                if !secs.is_finite() || secs <= 0.0 {
                    bail!("deadline must be a finite positive number of seconds, got {secs}");
                }
                Ok(RoundPolicy::Deadline { secs })
            }
            "over-select" | "overselect" => {
                let extra = match arg {
                    Some(a) => a.parse().map_err(|e| anyhow::anyhow!("bad over-select `{a}`: {e}"))?,
                    None => defaults.over_select_extra,
                };
                Ok(RoundPolicy::OverSelect { extra })
            }
            "async" => {
                let buffer_k = match arg {
                    Some(a) => a.parse().map_err(|e| anyhow::anyhow!("bad buffer-k `{a}`: {e}"))?,
                    None => defaults.buffer_k,
                };
                if buffer_k == 0 {
                    bail!("async needs buffer_k >= 1 (the round would never close)");
                }
                Ok(RoundPolicy::Async { buffer_k, max_staleness: defaults.max_staleness })
            }
            other => bail!("unknown round policy `{other}` (sync|deadline[:S]|over-select[:K]|async[:K])"),
        }
    }
}

/// What happens when a device's availability trace flips offline in the
/// middle of a compute or upload span (mid-round churn). Orthogonal to
/// the [`RoundPolicy`]: every round policy composes with every churn
/// policy deterministically.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ChurnPolicy {
    /// The trace gates dispatch only; a device that goes offline
    /// mid-span keeps working (dropout is the only mid-round loss).
    /// The backwards-compatible default.
    None,
    /// Work is lost at the interruption instant: the client leaves the
    /// round and its executed train seconds count as wasted compute.
    Abort,
    /// Work pauses across the offline window and continues at the next
    /// online one, stretching the span's finish time.
    Resume,
    /// Training checkpoints at epoch granularity: an interrupted client
    /// uploads the last completed epoch's partial update (aggregated with
    /// weight ∝ completed samples); the partial-epoch remainder is
    /// wasted. An interruption before the first epoch boundary loses the
    /// work (abort semantics). Downloads/uploads pause and resume.
    Checkpoint {
        /// Local epochs per round (checkpoint granularity).
        epochs: usize,
    },
}

impl ChurnPolicy {
    /// Parse a CLI/config spelling: `none` (or `off`), `abort`, `resume`,
    /// `checkpoint`, `checkpoint:E`. Bare `checkpoint` takes its epoch
    /// granularity from `default_epochs`.
    pub fn parse(s: &str, default_epochs: usize) -> Result<Self> {
        let (head, arg) = match s.split_once(':') {
            Some((h, a)) => (h, Some(a)),
            None => (s, None),
        };
        if arg.is_some() && head != "checkpoint" {
            bail!("churn policy `{head}` takes no argument");
        }
        match head {
            "none" | "off" => Ok(ChurnPolicy::None),
            "abort" => Ok(ChurnPolicy::Abort),
            "resume" => Ok(ChurnPolicy::Resume),
            "checkpoint" => {
                let epochs = match arg {
                    Some(a) => {
                        a.parse().map_err(|e| anyhow::anyhow!("bad checkpoint epochs `{a}`: {e}"))?
                    }
                    None => default_epochs,
                };
                if epochs == 0 {
                    bail!("checkpoint needs epochs >= 1 (granularity of partial updates)");
                }
                Ok(ChurnPolicy::Checkpoint { epochs })
            }
            other => bail!("unknown churn policy `{other}` (none|abort|resume|checkpoint[:E])"),
        }
    }
}

/// One cohort member's precomputed timing for a round: when it can be
/// dispatched and how long each leg takes. Built by
/// `ServerCtx::client_work` from the client's [`DeviceProfile`], shard
/// size, and the round artifact's byte/FLOP footprint.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct ClientWork {
    /// Client id (the pool index).
    pub id: usize,
    /// Earliest dispatch time (availability-gated), absolute seconds.
    pub ready_s: f64,
    /// Sub-model download time.
    pub down_s: f64,
    /// Local training time.
    pub train_s: f64,
    /// Update upload time.
    pub up_s: f64,
    /// Probability the client vanishes after dispatch this round.
    pub dropout_p: f64,
    /// Availability trace, sampled inside compute/upload spans by the
    /// churn engine (ignored under [`ChurnPolicy::None`]).
    pub trace: AvailabilityTrace,
}

/// An upload crossing a round boundary (async policy): the client was
/// dispatched in `dispatch_round` and its update reaches the server at
/// absolute virtual time `arrive_s`.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct InFlightUpload {
    /// Uploading client's id.
    pub client: usize,
    /// Absolute virtual arrival time at the server.
    pub arrive_s: f64,
    /// Round the client was dispatched in (staleness = arrival − this).
    pub dispatch_round: usize,
}

/// What the simulator decided for one round.
#[derive(Debug, Clone, PartialEq)]
pub struct RoundPlan {
    /// Clients whose updates are aggregated, in upload-arrival order.
    /// (The coordinator re-sorts these into selection order before
    /// FedAvg so float accumulation stays reproducible across policies.)
    pub completers: Vec<usize>,
    /// Dispatched-or-selected clients cut by the round policy.
    pub stragglers: Vec<usize>,
    /// Clients that dropped out after dispatch.
    pub dropouts: Vec<usize>,
    /// Async policy: earlier rounds' uploads that arrived inside this
    /// round's window (arrival order), tagged with their dispatch round.
    pub late_arrivals: Vec<InFlightUpload>,
    /// Async policy: this round's dispatched clients whose uploads missed
    /// the window and moved into the engine's in-flight queue instead of
    /// being discarded (arrival order).
    pub deferred: Vec<usize>,
    /// Clients whose round work was lost to mid-round churn (`abort`
    /// policy, or a `checkpoint` interruption before the first epoch
    /// boundary), in interruption order.
    pub aborted: Vec<usize>,
    /// Completed download fraction of each churn-aborted client at its
    /// interruption instant, in interruption order (pairs with
    /// `aborted`). Below 1.0 only when the `abort` policy cut the client
    /// *mid-download*; comm accounting then charges
    /// `fraction × download bytes` instead of the full artifact (an
    /// aborted download used to be charged in full). Pausable downloads
    /// (`resume`/`checkpoint`) complete across offline windows and are
    /// charged exactly once at full size on their ordinary paths.
    pub download_frac: Vec<(usize, f64)>,
    /// Checkpoint policy: clients that checkpointed a *partial* update
    /// this round, with the completed-work fraction in (0, 1), in
    /// dispatch-processing order. Their upload may still be cut by the
    /// round policy (straggler) or deferred (async); the coordinator
    /// scales the merge weight of whichever partials reach an aggregate.
    pub partials: Vec<(usize, f64)>,
    /// Interrupt events processed while simulating this round's cohort
    /// (under `async` this includes interrupts past the close instant:
    /// they belong to this round's dispatches, so per-round totals stay
    /// conserved across a run).
    pub interrupts: usize,
    /// Resume events processed while simulating this round's cohort.
    pub resumes: usize,
    /// Compute seconds spent on work that never reached an aggregate
    /// because of churn: abort losses plus partial-epoch remainders.
    /// Charged when the responsible Interrupt event is processed, so
    /// losses past a deadline cut stay in straggler territory instead of
    /// being double-attributed to churn.
    pub wasted_compute_s: f64,
    /// Virtual time at which the round opened.
    pub start_s: f64,
    /// Virtual time at which the server aggregates.
    pub end_s: f64,
    /// Processed events in execution order (determinism witnesses),
    /// truncated to the round window.
    pub events: Vec<Event>,
}

impl RoundPlan {
    /// Virtual seconds this round occupied (aggregation − start).
    pub fn duration_s(&self) -> f64 {
        self.end_s - self.start_s
    }

    /// Completed download fraction for `client` this round: the recorded
    /// fraction when churn aborted it mid-download, 1.0 otherwise.
    pub fn download_fraction(&self, client: usize) -> f64 {
        self.download_frac.iter().find(|(c, _)| *c == client).map_or(1.0, |&(_, f)| f)
    }

    /// The no-op plan: nothing dispatched, clock untouched.
    fn empty(start_s: f64) -> Self {
        RoundPlan {
            completers: Vec::new(),
            stragglers: Vec::new(),
            dropouts: Vec::new(),
            late_arrivals: Vec::new(),
            deferred: Vec::new(),
            aborted: Vec::new(),
            download_frac: Vec::new(),
            partials: Vec::new(),
            interrupts: 0,
            resumes: 0,
            wasted_compute_s: 0.0,
            start_s,
            end_s: start_s,
            events: Vec::new(),
        }
    }
}

/// Per-round churn bookkeeping shared by the sync-family and async event
/// loops: staged abort decisions (resolved when the matching Interrupt
/// event pops, so the trace stays in execution order), checkpoint
/// fractions, and counters. The lookup tables are plain cohort-sized
/// vectors (never iterated for output, scanned on lookup), so clearing
/// them between rounds reuses their allocations — part of the
/// [`RoundScratch`] no-allocation round contract.
#[derive(Debug, Default)]
struct ChurnState {
    /// Client → (interrupt-time bits, wasted compute seconds, completed
    /// download fraction): the span scheduler decided this client's work
    /// is lost; applied when the Interrupt event with exactly that
    /// timestamp pops (earlier Interrupts for the same client are pause
    /// witnesses). The fraction is below 1.0 only for a cut that landed
    /// mid-download. At most one entry per client.
    cut: Vec<(usize, (u64, f64, f64))>,
    /// Client → (interrupt-time bits, partial-epoch seconds): the
    /// checkpoint remainder past the last epoch boundary, charged when
    /// that Interrupt pops — symmetric with `cut`, so a round that ends
    /// before the interruption (deadline cut, full buffer) reports the
    /// same zero waste under `checkpoint` as under `abort`.
    partial_waste: Vec<(usize, (u64, f64))>,
    /// (client, fraction) in dispatch-processing order (plan output; also
    /// the upload path's has-a-partial lookup).
    partials: Vec<(usize, f64)>,
    aborted: Vec<usize>,
    /// (client, completed download fraction) per abort, in interruption
    /// order (plan output, pairs with `aborted`).
    down_fracs: Vec<(usize, f64)>,
    wasted_s: f64,
    interrupts: usize,
    resumes: usize,
}

impl ChurnState {
    /// Reset for a new round, keeping every buffer's allocation.
    fn clear(&mut self) {
        self.cut.clear();
        self.partial_waste.clear();
        self.partials.clear();
        self.aborted.clear();
        self.down_fracs.clear();
        self.wasted_s = 0.0;
        self.interrupts = 0;
        self.resumes = 0;
    }

    /// Stage a fatal cut for `client` at interrupt instant `off`.
    fn stage_cut(&mut self, client: usize, off: f64, wasted: f64, down_frac: f64) {
        self.cut.push((client, (off.to_bits(), wasted, down_frac)));
    }

    /// Stage the partial-epoch waste charged at `client`'s checkpoint
    /// Interrupt.
    fn stage_partial_waste(&mut self, client: usize, off: f64, wasted: f64) {
        self.partial_waste.push((client, (off.to_bits(), wasted)));
    }

    /// Record a checkpointed partial (dispatch-processing order).
    fn record_partial(&mut self, client: usize, fraction: f64) {
        self.partials.push((client, fraction));
    }

    /// Whether `client` checkpointed a partial this round.
    fn has_partial(&self, client: usize) -> bool {
        self.partials.iter().any(|&(c, _)| c == client)
    }

    /// Process one popped Interrupt event: count it, and if it is the
    /// staged cut for this client, apply the abort. Returns true when the
    /// client's round work just died.
    fn on_interrupt(&mut self, client: usize, time_s: f64) -> bool {
        self.interrupts += 1;
        if let Some(i) = self.cut.iter().position(|&(c, (bits, _, _))| {
            c == client && bits == time_s.to_bits()
        }) {
            let (_, (_, wasted, down_frac)) = self.cut.swap_remove(i);
            self.aborted.push(client);
            self.down_fracs.push((client, down_frac));
            self.wasted_s += wasted;
            return true;
        }
        if let Some(i) = self
            .partial_waste
            .iter()
            .position(|&(c, (bits, _))| c == client && bits == time_s.to_bits())
        {
            let (_, (_, wasted)) = self.partial_waste.swap_remove(i);
            self.wasted_s += wasted;
        }
        false
    }
}

/// Reusable per-round working state owned by [`FleetEngine`]: the event
/// queue, the cohort's sorted client→work index, the in-flight origin
/// index, and the churn lookup tables. Cleared — not reallocated — at the
/// top of every round, so steady-state round simulation performs no
/// fleet- or round-proportional allocations beyond the plan's own
/// cohort-sized output vectors. Replaces the per-round
/// `HashMap<usize, &ClientWork>` / `HashMap<usize, usize>` builds, which
/// also makes every lookup structure deterministic-iteration by
/// construction.
#[derive(Debug, Default)]
struct RoundScratch {
    queue: EventQueue,
    /// `(client id, index into the round's works slice)`, sorted by id.
    works_by_id: Vec<(usize, usize)>,
    /// `(client id, dispatch round)` per in-flight upload, sorted by id.
    origin: Vec<(usize, usize)>,
    churn: ChurnState,
    /// Worker-pool accounting of the last round's span precompute
    /// (telemetry only; never read by the simulation).
    worker: WorkerStats,
}

impl RoundScratch {
    /// Arm the scratch for a new round over `works`.
    fn begin(&mut self, works: &[ClientWork]) {
        self.queue.clear();
        self.churn.clear();
        self.origin.clear();
        self.works_by_id.clear();
        self.works_by_id.extend(works.iter().enumerate().map(|(i, w)| (w.id, i)));
        self.works_by_id.sort_unstable_by_key(|&(id, _)| id);
    }
}

/// Look up `client`'s index into the round's works slice through the
/// sorted index (the dense replacement for the old per-round `by_id`
/// HashMap; panics on an unknown client exactly like the map indexing
/// did).
fn work_index(works_by_id: &[(usize, usize)], client: usize) -> usize {
    let i = works_by_id
        .binary_search_by_key(&client, |&(id, _)| id)
        .expect("event for a client outside the round's cohort");
    works_by_id[i].1
}

/// Emit the Interrupt/Resume witness pairs for a pausable span's offline
/// windows.
fn push_pauses(q: &mut EventQueue, client: usize, spans: &[OfflineSpan]) {
    for s in spans {
        q.push(s.off_s, EventKind::Interrupt { client });
        q.push(s.on_s, EventKind::Resume { client });
    }
}

/// How a planned compute leg ends. Together with [`ComputePlan::pauses`]
/// this captures *everything* the leg will do to the event stream and the
/// churn tables, so planning (pure, parallelizable) is separated from
/// emission (sequential, seq-assigning) without any behaviour change.
#[derive(Debug, Clone, Copy, PartialEq)]
enum ComputeOutcome {
    /// Training finishes: push `TrainDone(end_s)`.
    Done { end_s: f64 },
    /// The leg dies at `off_s`: push the fatal Interrupt and stage the
    /// cut (`wasted_s` train seconds, `down_frac` of the download moved).
    Cut { off_s: f64, wasted_s: f64, down_frac: f64 },
    /// Checkpoint: `fraction` of the pass survives as a partial update at
    /// `off_s`; `waste_s` seconds past the epoch boundary are lost.
    Partial { off_s: f64, fraction: f64, waste_s: f64 },
}

/// One client's precomputed compute leg (download + local train): the
/// offline windows it pauses across, then the outcome. A pure function of
/// `(ClientWork, dispatch time, churn policy)` — no queue, no rng.
#[derive(Debug, Clone, PartialEq)]
struct ComputePlan {
    /// Interrupt/Resume witness pairs, in crossing order (includes the
    /// checkpoint policy's download-ends-at-offline-boundary pause).
    pauses: Vec<OfflineSpan>,
    outcome: ComputeOutcome,
}

/// How a planned upload leg ends.
#[derive(Debug, Clone, Copy, PartialEq)]
enum UploadOutcome {
    /// The update arrives: push `UploadDone(end_s)`.
    Done { end_s: f64 },
    /// The upload dies at `off_s` (abort churn): push the fatal Interrupt
    /// and stage the cut — the whole finished local pass is wasted.
    Cut { off_s: f64, wasted_s: f64 },
}

/// One client's precomputed upload leg, starting at its TrainDone
/// instant. Pure like [`ComputePlan`].
#[derive(Debug, Clone, PartialEq)]
struct UploadPlan {
    /// A checkpointed partial whose TrainDone landed offline starts with
    /// this Resume (pairing the fatal-free Interrupt that fired at the
    /// checkpoint instant).
    pre_resume: Option<f64>,
    /// Offline windows the upload pauses across, in crossing order.
    pauses: Vec<OfflineSpan>,
    outcome: UploadOutcome,
}

/// Both legs of one client's round, precomputed. The upload leg exists
/// only when the compute leg hands a TrainDone to the upload path (it
/// starts at that instant, which the compute outcome determines — so the
/// whole chain is still a per-client pure function).
#[derive(Debug, Clone, PartialEq)]
struct ClientSpanPlan {
    compute: ComputePlan,
    upload: Option<UploadPlan>,
}

/// Plan one client's compute leg (download + local train) dispatched at
/// `t`. Pure: reads only the work entry, the trace, and the churn policy.
fn plan_compute(w: &ClientWork, t: f64, churn: ChurnPolicy) -> ComputePlan {
    let total = w.down_s + w.train_s;
    if matches!(churn, ChurnPolicy::None) || w.trace.duty >= 1.0 {
        // Pre-churn fast path: bit-identical event stream (degeneracy).
        return ComputePlan {
            pauses: Vec::new(),
            outcome: ComputeOutcome::Done { end_s: t + total },
        };
    }
    match churn {
        ChurnPolicy::None => unreachable!("handled by the fast path"),
        ChurnPolicy::Abort => {
            let off = w.trace.next_offline(t);
            if total <= off - t {
                ComputePlan { pauses: Vec::new(), outcome: ComputeOutcome::Done { end_s: t + total } }
            } else {
                let trained = (off - t - w.down_s).clamp(0.0, w.train_s);
                // A cut inside the download leg fetched only part of the
                // artifact; comm accounting charges that fraction.
                let down_frac =
                    if w.down_s <= 0.0 { 1.0 } else { ((off - t) / w.down_s).clamp(0.0, 1.0) };
                ComputePlan {
                    pauses: Vec::new(),
                    outcome: ComputeOutcome::Cut { off_s: off, wasted_s: trained, down_frac },
                }
            }
        }
        ChurnPolicy::Resume => {
            let (end, pauses) = w.trace.walk_work(t, total);
            ComputePlan { pauses, outcome: ComputeOutcome::Done { end_s: end } }
        }
        ChurnPolicy::Checkpoint { epochs } => {
            // Downloads pause and resume (range requests); training runs
            // in one online stretch and checkpoints at epoch granularity
            // when cut — the client uploads what it has instead of
            // resuming a stale local pass.
            let (t1, mut pauses) = w.trace.walk_work(t, w.down_s);
            let mut ts = t1;
            if !w.trace.is_online(ts) {
                // Download completed exactly at an offline boundary:
                // training starts at the next online window.
                let on = w.trace.next_online(ts);
                pauses.push(OfflineSpan { off_s: ts, on_s: on });
                ts = on;
            }
            let off = w.trace.next_offline(ts);
            if w.train_s <= off - ts {
                ComputePlan { pauses, outcome: ComputeOutcome::Done { end_s: ts + w.train_s } }
            } else {
                let trained = off - ts;
                let done = ((trained / w.train_s) * epochs as f64).floor();
                if done <= 0.0 {
                    // Not even one epoch checkpointed: the work is lost.
                    // The download paused/resumed to completion first, so
                    // it is charged in full (exactly once).
                    ComputePlan {
                        pauses,
                        outcome: ComputeOutcome::Cut { off_s: off, wasted_s: trained, down_frac: 1.0 },
                    }
                } else {
                    let fraction = done / epochs as f64;
                    let waste_s = trained - fraction * w.train_s;
                    ComputePlan {
                        pauses,
                        outcome: ComputeOutcome::Partial { off_s: off, fraction, waste_s },
                    }
                }
            }
        }
    }
}

/// Plan one client's upload leg starting at `t` (its TrainDone instant).
/// `has_partial` is whether this client's *own* compute leg checkpointed
/// a partial — a per-client fact, which keeps the two-leg chain a pure
/// function of the client alone.
fn plan_upload(w: &ClientWork, t: f64, churn: ChurnPolicy, has_partial: bool) -> UploadPlan {
    if matches!(churn, ChurnPolicy::None) || w.trace.duty >= 1.0 {
        return UploadPlan {
            pre_resume: None,
            pauses: Vec::new(),
            outcome: UploadOutcome::Done { end_s: t + w.up_s },
        };
    }
    match churn {
        ChurnPolicy::None => unreachable!("handled by the fast path"),
        ChurnPolicy::Abort => {
            let off = w.trace.next_offline(t);
            if w.up_s <= off - t {
                UploadPlan {
                    pre_resume: None,
                    pauses: Vec::new(),
                    outcome: UploadOutcome::Done { end_s: t + w.up_s },
                }
            } else {
                // The finished local pass dies with the upload; its
                // download completed long before, so full charge.
                UploadPlan {
                    pre_resume: None,
                    pauses: Vec::new(),
                    outcome: UploadOutcome::Cut { off_s: off, wasted_s: w.train_s },
                }
            }
        }
        ChurnPolicy::Resume | ChurnPolicy::Checkpoint { .. } => {
            let mut ts = t;
            let mut pre_resume = None;
            if has_partial && !w.trace.is_online(ts) {
                // Partial checkpoint: its Interrupt fired at TrainDone;
                // pair it with the Resume that starts the upload.
                let on = w.trace.next_online(ts);
                pre_resume = Some(on);
                ts = on;
            }
            let (end, pauses) = w.trace.walk_work(ts, w.up_s);
            UploadPlan { pre_resume, pauses, outcome: UploadOutcome::Done { end_s: end } }
        }
    }
}

/// Plan both legs of one client's round dispatched at `t`: the compute
/// leg, then — when that leg hands a TrainDone to the upload path — the
/// upload leg starting at exactly that instant.
fn plan_client(w: &ClientWork, t: f64, churn: ChurnPolicy) -> ClientSpanPlan {
    let compute = plan_compute(w, t, churn);
    let upload = match compute.outcome {
        ComputeOutcome::Done { end_s } => Some(plan_upload(w, end_s, churn, false)),
        ComputeOutcome::Partial { off_s, .. } => Some(plan_upload(w, off_s, churn, true)),
        ComputeOutcome::Cut { .. } => None,
    };
    ClientSpanPlan { compute, upload }
}

/// Apply a precomputed compute leg to the event stream and churn tables,
/// in exactly the push/stage order the inline scheduler used — seq
/// numbers (and so golden traces) are preserved by construction.
fn emit_compute(q: &mut EventQueue, st: &mut ChurnState, client: usize, plan: &ComputePlan) {
    push_pauses(q, client, &plan.pauses);
    match plan.outcome {
        ComputeOutcome::Done { end_s } => q.push(end_s, EventKind::TrainDone { client }),
        ComputeOutcome::Cut { off_s, wasted_s, down_frac } => {
            q.push(off_s, EventKind::Interrupt { client });
            st.stage_cut(client, off_s, wasted_s, down_frac);
        }
        ComputeOutcome::Partial { off_s, fraction, waste_s } => {
            q.push(off_s, EventKind::Interrupt { client });
            st.record_partial(client, fraction);
            st.stage_partial_waste(client, off_s, waste_s);
            q.push(off_s, EventKind::TrainDone { client });
        }
    }
}

/// Apply a precomputed upload leg — same order contract as
/// [`emit_compute`].
fn emit_upload(q: &mut EventQueue, st: &mut ChurnState, client: usize, plan: &UploadPlan) {
    if let Some(on) = plan.pre_resume {
        q.push(on, EventKind::Resume { client });
    }
    push_pauses(q, client, &plan.pauses);
    match plan.outcome {
        UploadOutcome::Done { end_s } => q.push(end_s, EventKind::UploadDone { client }),
        UploadOutcome::Cut { off_s, wasted_s } => {
            q.push(off_s, EventKind::Interrupt { client });
            st.stage_cut(client, off_s, wasted_s, 1.0);
        }
    }
}

/// Schedule one client's compute leg (download + local train) starting at
/// `t`: plan it, then emit. An aborted leg stages its cut in `st` and
/// pushes only the fatal Interrupt; a checkpointed partial records its
/// fraction and hands a TrainDone to the upload path at the interruption
/// instant.
fn schedule_compute(
    q: &mut EventQueue,
    st: &mut ChurnState,
    w: &ClientWork,
    t: f64,
    churn: ChurnPolicy,
) {
    emit_compute(q, st, w.id, &plan_compute(w, t, churn));
}

/// Schedule one client's upload leg starting at `t` (its TrainDone
/// instant) under the churn policy. A checkpointed partial's upload
/// starts at the next online window (its fatal-free Interrupt already
/// fired with the TrainDone).
fn schedule_upload(
    q: &mut EventQueue,
    st: &mut ChurnState,
    w: &ClientWork,
    t: f64,
    churn: ChurnPolicy,
) {
    emit_upload(q, st, w.id, &plan_upload(w, t, churn, st.has_partial(w.id)));
}

/// Per-worker busy/wall accounting of the last parallel span precompute.
/// Pure observation for telemetry (wall-clock times never feed back into
/// the simulation — the determinism contract is untouched).
#[derive(Debug, Clone, Default)]
struct WorkerStats {
    /// Workers actually spawned (0 = the precompute ran inline).
    workers: usize,
    /// Summed per-worker busy nanoseconds.
    busy_ns: u128,
    /// Wall nanoseconds of the pool region.
    wall_ns: u128,
}

impl WorkerStats {
    /// Mean busy fraction across the pool's workers, in (0, 1]. Inline
    /// rounds (threads = 1, tiny cohorts) report 1.0: the one "worker"
    /// is the event loop itself, busy by definition.
    fn utilization(&self) -> f64 {
        if self.workers <= 1 || self.wall_ns == 0 {
            return 1.0;
        }
        (self.busy_ns as f64 / (self.workers as u128 * self.wall_ns) as f64).min(1.0)
    }
}

/// Precompute every dispatchable client's span plan on `threads` scoped
/// workers (contiguous index chunks, results placed by index — the output
/// is identical for any thread count or scheduling order, because each
/// plan is a pure per-client function). Returns an empty vec when the
/// pool would not help (`threads <= 1`, or a cohort too small to split):
/// the event loop then plans lazily inline, which is the historical path.
fn precompute_spans(
    works: &[ClientWork],
    start_s: f64,
    churn: ChurnPolicy,
    threads: usize,
    worker: &mut WorkerStats,
) -> Vec<Option<ClientSpanPlan>> {
    *worker = WorkerStats::default();
    if threads <= 1 || works.len() < 2 {
        return Vec::new();
    }
    let mut plans: Vec<Option<ClientSpanPlan>> = Vec::with_capacity(works.len());
    plans.resize_with(works.len(), || None);
    let chunk = works.len().div_ceil(threads);
    let pool_start = std::time::Instant::now();
    let mut busy_ns = 0u128;
    let mut spawned = 0usize;
    std::thread::scope(|scope| {
        let mut handles = Vec::with_capacity(threads);
        for (wchunk, pchunk) in works.chunks(chunk).zip(plans.chunks_mut(chunk)) {
            handles.push(scope.spawn(move || {
                let t0 = std::time::Instant::now();
                for (w, slot) in wchunk.iter().zip(pchunk.iter_mut()) {
                    // Non-finite ready time (zero-duty trace): never
                    // dispatched, so nothing to plan.
                    if w.ready_s.is_finite() {
                        *slot = Some(plan_client(w, start_s.max(w.ready_s), churn));
                    }
                }
                t0.elapsed().as_nanos()
            }));
        }
        spawned = handles.len();
        for h in handles {
            busy_ns += h.join().expect("span-planner worker panicked");
        }
    });
    worker.workers = spawned;
    worker.busy_ns = busy_ns;
    worker.wall_ns = pool_start.elapsed().as_nanos();
    plans
}

/// Default worker-thread count for new engines and configs: the
/// `PROFL_THREADS` env var when set to a positive integer, else 1
/// (inline planning). The thread count never changes results — every
/// count is bit-identical by construction — so an env default is safe;
/// it exists so CI can run the entire suite (golden traces included) on
/// a multi-threaded engine without touching each test.
pub fn default_threads() -> usize {
    std::env::var("PROFL_THREADS")
        .ok()
        .and_then(|s| s.parse::<usize>().ok())
        .filter(|&n| n >= 1)
        .unwrap_or(1)
}

/// Round-spanning simulator state. Stateless policies (`sync`,
/// `deadline`, `over-select`) run through the same reusable round
/// scratch (event queue, sorted lookup indices, churn tables); the
/// `async` policy additionally keeps its in-flight uploads here between
/// rounds. One engine can (and should) serve every
/// round of a run — and, via [`Self::reset`], every configuration of a
/// sweep — so the per-round working set is cleared, not reallocated.
///
/// **Parallel span planning.** With `threads > 1` the engine precomputes
/// every dispatchable client's compute/upload span chain on a scoped
/// worker pool before the event loop runs; the sequential loop then
/// merges the precomputed plans in `(time, seq)` event order, drawing
/// the dropout rng exactly as the inline path does. Results are
/// bit-identical at any thread count (plans are pure per-client
/// functions placed by index), so golden traces and degeneracy
/// contracts hold unchanged — `threads` is a wall-clock knob only.
#[derive(Debug)]
pub struct FleetEngine {
    inflight: Vec<InFlightUpload>,
    scratch: RoundScratch,
    threads: usize,
}

impl Default for FleetEngine {
    fn default() -> Self {
        FleetEngine {
            inflight: Vec::new(),
            scratch: RoundScratch::default(),
            threads: default_threads(),
        }
    }
}

impl FleetEngine {
    /// An engine with an empty in-flight queue, planning spans on
    /// [`default_threads`] workers.
    pub fn new() -> Self {
        FleetEngine::default()
    }

    /// An engine planning client spans on `threads` workers (0 is
    /// clamped to 1 = inline planning).
    pub fn with_threads(threads: usize) -> Self {
        let mut e = FleetEngine::default();
        e.set_threads(threads);
        e
    }

    /// Set the span-planner worker count (0 is clamped to 1). Takes
    /// effect from the next round; results are bit-identical either way.
    pub fn set_threads(&mut self, threads: usize) {
        self.threads = threads.max(1);
    }

    /// The span-planner worker count.
    pub fn threads(&self) -> usize {
        self.threads
    }

    /// Mean busy fraction of the last round's span-planner workers, in
    /// (0, 1] (1.0 for inline rounds). Wall-clock observation for the
    /// telemetry stream — the simulation never reads it.
    pub fn last_worker_utilization(&self) -> f64 {
        self.scratch.worker.utilization()
    }

    /// Uploads currently crossing a round boundary (arrival order).
    pub fn inflight(&self) -> &[InFlightUpload] {
        &self.inflight
    }

    /// Replace the cross-round in-flight queue with a checkpointed one
    /// (in the exact order [`Self::inflight`] reported it). Together with
    /// the caller's rng stream position this restores the engine's
    /// complete round-spanning state — the per-round scratch is re-armed
    /// at the top of every round and carries nothing across rounds.
    pub fn restore_inflight(&mut self, inflight: Vec<InFlightUpload>) {
        self.inflight = inflight;
    }

    /// Peak event-queue depth of the most recent [`Self::simulate_round`]
    /// (0 before the first round). Pure observation for the telemetry
    /// stream — the simulation never reads it.
    pub fn last_queue_peak(&self) -> usize {
        self.scratch.queue.peak_len()
    }

    /// Return the engine to its fresh-construction state — empty
    /// in-flight queue, round counter-free — while keeping the scratch
    /// allocations warm. Sweeps (e.g. `examples/churn_sweep.rs`) reuse
    /// one engine across configurations this way instead of rebuilding;
    /// a reset engine's subsequent rounds are bit-identical to a brand
    /// new engine's.
    pub fn reset(&mut self) {
        self.inflight.clear();
        // The scratch is re-armed at the top of every round; nothing else
        // carries state across simulate_round calls.
    }

    /// Run one round's cohort under `policy` with mid-round churn handled
    /// by `churn`. `round` is the server's round index (stamped onto
    /// deferred uploads so staleness can be computed on arrival); `keep`
    /// caps how many finishers aggregate under over-select (`usize::MAX`
    /// otherwise).
    #[allow(clippy::too_many_arguments)]
    pub fn simulate_round(
        &mut self,
        round: usize,
        start_s: f64,
        works: &[ClientWork],
        policy: RoundPolicy,
        keep: usize,
        churn: ChurnPolicy,
        rng: &mut Rng,
    ) -> RoundPlan {
        match policy {
            RoundPolicy::Async { buffer_k, .. } => {
                self.simulate_async(round, start_s, works, buffer_k, churn, rng)
            }
            _ => {
                debug_assert!(
                    self.inflight.is_empty(),
                    "in-flight uploads exist but the policy is not async"
                );
                simulate_sync_family(
                    &mut self.scratch,
                    start_s,
                    works,
                    policy,
                    keep,
                    churn,
                    rng,
                    self.threads,
                )
            }
        }
    }

    /// Async (FedBuff-style) round: simulate the whole cohort to
    /// completion — every dispatch/dropout draw happens in the same
    /// event order as `sync`, so the rng stream stays aligned — then
    /// close the round at the `buffer_k`-th arrival (fresh uploads and
    /// in-flight arrivals both count). Fresh uploads after the close
    /// move into the in-flight queue; in-flight arrivals after the close
    /// stay queued for a later round.
    fn simulate_async(
        &mut self,
        round: usize,
        start_s: f64,
        works: &[ClientWork],
        buffer_k: usize,
        churn: ChurnPolicy,
        rng: &mut Rng,
    ) -> RoundPlan {
        let FleetEngine { inflight, scratch, threads } = self;
        scratch.begin(works);
        let plans = precompute_spans(works, start_s, churn, *threads, &mut scratch.worker);
        let RoundScratch { queue: q, works_by_id, origin, churn: st, .. } = scratch;

        // A fresh dispatch supersedes the same client's stale in-flight
        // upload (the device abandons the old job for the new one). The
        // coordinator excludes in-flight clients from sampling, so this
        // is a backstop for direct engine users.
        inflight
            .retain(|u| works_by_id.binary_search_by_key(&u.client, |&(id, _)| id).is_err());

        // In-flight dispatch-round index (sorted): the dense replacement
        // for the old per-round `origin` HashMap.
        origin.extend(inflight.iter().map(|u| (u.client, u.dispatch_round)));
        origin.sort_unstable_by_key(|&(id, _)| id);
        let origin_of = |origin: &[(usize, usize)], client: usize| -> usize {
            let i = origin
                .binary_search_by_key(&client, |&(id, _)| id)
                .expect("late upload without an in-flight origin");
            origin[i].1
        };

        // In-flight arrivals first (stable stored order), then fresh
        // dispatches — deterministic seq tie-breaking either way.
        for u in inflight.iter() {
            q.push(u.arrive_s.max(start_s), EventKind::LateUpload { client: u.client });
        }
        for w in works {
            // Non-finite ready time (zero-duty trace): never dispatched,
            // falls through to the straggler set below.
            if w.ready_s.is_finite() {
                q.push(start_s.max(w.ready_s), EventKind::Dispatch { client: w.id });
            }
        }

        let mut clock = VirtualClock::new(start_s);
        let mut events = Vec::new();
        let mut fresh: Vec<(f64, usize)> = Vec::new();
        let mut late: Vec<(f64, usize)> = Vec::new();
        let mut dropouts = Vec::new();
        let mut arrivals = 0usize;
        let mut close_s: Option<f64> = None;
        let mut last_arrival_s: Option<f64> = None;

        while let Some(ev) = q.pop() {
            clock.advance_to(ev.time_s);
            events.push(ev);
            match ev.kind {
                EventKind::Dispatch { client } => {
                    let idx = work_index(works_by_id, client);
                    let w = &works[idx];
                    if rng.f64() < w.dropout_p {
                        dropouts.push(client);
                    } else {
                        match plans.get(idx).and_then(|p| p.as_ref()) {
                            Some(p) => emit_compute(q, st, client, &p.compute),
                            None => schedule_compute(q, st, w, ev.time_s, churn),
                        }
                    }
                }
                EventKind::TrainDone { client } => {
                    let idx = work_index(works_by_id, client);
                    match plans.get(idx).and_then(|p| p.as_ref()).and_then(|p| p.upload.as_ref()) {
                        Some(u) => emit_upload(q, st, client, u),
                        None => schedule_upload(q, st, &works[idx], ev.time_s, churn),
                    }
                }
                EventKind::UploadDone { client } => {
                    fresh.push((ev.time_s, client));
                    arrivals += 1;
                    last_arrival_s = Some(ev.time_s);
                    if arrivals == buffer_k && close_s.is_none() {
                        close_s = Some(ev.time_s);
                    }
                }
                EventKind::LateUpload { client } => {
                    late.push((ev.time_s, client));
                    arrivals += 1;
                    last_arrival_s = Some(ev.time_s);
                    if arrivals == buffer_k && close_s.is_none() {
                        close_s = Some(ev.time_s);
                    }
                }
                EventKind::Interrupt { client } => {
                    // An aborted client never produces an arrival; the
                    // window just loses one potential upload.
                    st.on_interrupt(client, ev.time_s);
                }
                EventKind::Resume { .. } => st.resumes += 1,
                // Async rounds schedule no deadline events.
                EventKind::Deadline => {}
            }
        }

        // Fewer than buffer_k arrivals possible: the server closes when
        // nothing more can arrive (the last arrival, or immediately).
        let close_s = close_s.or(last_arrival_s).unwrap_or(start_s);

        let mut completers = Vec::new();
        let mut next_inflight: Vec<InFlightUpload> = Vec::new();
        let mut deferred = Vec::new();
        // In-flight arrivals keep queue priority over this round's
        // deferrals in the next round's event order: re-queue them first.
        for (t, c) in late.iter().copied().filter(|(t, _)| *t > close_s) {
            let dispatch_round = origin_of(origin, c);
            next_inflight.push(InFlightUpload { client: c, arrive_s: t, dispatch_round });
        }
        for (t, c) in fresh {
            if t <= close_s {
                completers.push(c);
            } else {
                deferred.push(c);
                let u = InFlightUpload { client: c, arrive_s: t, dispatch_round: round };
                next_inflight.push(u);
            }
        }
        let late_arrivals: Vec<InFlightUpload> = late
            .iter()
            .copied()
            .filter(|(t, _)| *t <= close_s)
            .map(|(t, c)| InFlightUpload {
                client: c,
                arrive_s: t,
                dispatch_round: origin_of(origin, c),
            })
            .collect();
        *inflight = next_inflight;

        // Unreachable clients are the only stragglers under async — every
        // dispatched client either drops out, aborts, or (eventually)
        // arrives.
        let stragglers: Vec<usize> =
            works.iter().filter(|w| !w.ready_s.is_finite()).map(|w| w.id).collect();
        events.retain(|e| e.time_s <= close_s);
        RoundPlan {
            completers,
            stragglers,
            dropouts,
            late_arrivals,
            deferred,
            aborted: std::mem::take(&mut st.aborted),
            download_frac: std::mem::take(&mut st.down_fracs),
            partials: std::mem::take(&mut st.partials),
            interrupts: st.interrupts,
            resumes: st.resumes,
            wasted_compute_s: st.wasted_s,
            start_s,
            end_s: close_s,
            events,
        }
    }
}

/// Run one self-contained round's cohort through the event loop (`sync`,
/// `deadline`, `over-select` — for `async` use [`FleetEngine`]). `keep`
/// caps how many finishers are aggregated (`usize::MAX` for
/// sync/deadline; `per_round` for over-select). Dropout draws happen in
/// event order from `rng`, so the whole plan is a pure function of its
/// arguments.
///
/// This convenience entry point allocates a one-shot scratch; round loops
/// should go through [`FleetEngine::simulate_round`], which reuses one
/// scratch across rounds (bit-identical results either way).
pub fn simulate_round(
    start_s: f64,
    works: &[ClientWork],
    policy: RoundPolicy,
    keep: usize,
    churn: ChurnPolicy,
    rng: &mut Rng,
) -> RoundPlan {
    let mut scratch = RoundScratch::default();
    simulate_sync_family(&mut scratch, start_s, works, policy, keep, churn, rng, default_threads())
}

/// The sync-family (`sync`/`deadline`/`over-select`) event loop over a
/// caller-owned [`RoundScratch`].
#[allow(clippy::too_many_arguments)]
fn simulate_sync_family(
    scratch: &mut RoundScratch,
    start_s: f64,
    works: &[ClientWork],
    policy: RoundPolicy,
    keep: usize,
    churn: ChurnPolicy,
    rng: &mut Rng,
    threads: usize,
) -> RoundPlan {
    debug_assert!(
        !matches!(policy, RoundPolicy::Async { .. }),
        "async rounds carry cross-round state; use FleetEngine::simulate_round"
    );
    // An empty cohort is a no-op round: nothing to dispatch, so no
    // deadline wait either (the server has nobody to wait for).
    if works.is_empty() {
        return RoundPlan::empty(start_s);
    }
    scratch.begin(works);
    let plans = precompute_spans(works, start_s, churn, threads, &mut scratch.worker);
    let RoundScratch { queue: q, works_by_id, churn: st, .. } = scratch;
    // Clients still owing an upload; the loop may stop early once none remain.
    let mut outstanding = 0usize;
    for w in works {
        // A non-finite ready time (zero-duty availability trace) means the
        // client can never be dispatched: it falls through to the straggler
        // set below instead of poisoning the clock with an INF event.
        if w.ready_s.is_finite() {
            q.push(start_s.max(w.ready_s), EventKind::Dispatch { client: w.id });
            outstanding += 1;
        }
    }
    if outstanding > 0 {
        if let RoundPolicy::Deadline { secs } = policy {
            q.push(start_s + secs, EventKind::Deadline);
        }
    }

    let mut clock = VirtualClock::new(start_s);
    let mut events = Vec::new();
    let mut completers = Vec::new();
    let mut dropouts = Vec::new();
    let mut end_s = start_s;

    while let Some(ev) = q.pop() {
        clock.advance_to(ev.time_s);
        match ev.kind {
            EventKind::Dispatch { client } => {
                events.push(ev);
                let idx = work_index(works_by_id, client);
                let w = &works[idx];
                if rng.f64() < w.dropout_p {
                    dropouts.push(client);
                    outstanding -= 1;
                } else {
                    match plans.get(idx).and_then(|p| p.as_ref()) {
                        Some(p) => emit_compute(q, st, client, &p.compute),
                        None => schedule_compute(q, st, w, ev.time_s, churn),
                    }
                }
            }
            EventKind::TrainDone { client } => {
                events.push(ev);
                let idx = work_index(works_by_id, client);
                match plans.get(idx).and_then(|p| p.as_ref()).and_then(|p| p.upload.as_ref()) {
                    Some(u) => emit_upload(q, st, client, u),
                    None => schedule_upload(q, st, &works[idx], ev.time_s, churn),
                }
            }
            EventKind::UploadDone { client } => {
                events.push(ev);
                completers.push(client);
                outstanding -= 1;
                end_s = clock.now_s();
                if completers.len() >= keep {
                    break; // over-select: cohort is full
                }
            }
            // Self-contained rounds never schedule late uploads.
            EventKind::LateUpload { .. } => {}
            EventKind::Interrupt { client } => {
                events.push(ev);
                if st.on_interrupt(client, ev.time_s) {
                    // The server stops waiting for a client it knows is
                    // gone — mirrors the dropout bookkeeping.
                    outstanding -= 1;
                }
            }
            EventKind::Resume { .. } => {
                events.push(ev);
                st.resumes += 1;
            }
            EventKind::Deadline => {
                events.push(ev);
                end_s = clock.now_s();
                break; // everyone still in flight is a straggler
            }
        }
        if outstanding == 0 {
            break; // all uploads in (or dropped/aborted) — don't idle-wait
        }
    }

    let stragglers: Vec<usize> = works
        .iter()
        .map(|w| w.id)
        .filter(|id| {
            !completers.contains(id) && !dropouts.contains(id) && !st.aborted.contains(id)
        })
        .collect();
    RoundPlan {
        completers,
        stragglers,
        dropouts,
        late_arrivals: Vec::new(),
        deferred: Vec::new(),
        aborted: std::mem::take(&mut st.aborted),
        download_frac: std::mem::take(&mut st.down_fracs),
        partials: std::mem::take(&mut st.partials),
        interrupts: st.interrupts,
        resumes: st.resumes,
        wasted_compute_s: st.wasted_s,
        start_s,
        end_s,
        events,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::clients::ClientPool;
    use crate::data::{Partition, SyntheticDataset};
    use crate::manifest::MemCoeffs;
    use crate::memory::MemoryConfig;

    fn work(id: usize, ready: f64, down: f64, train: f64, up: f64, drop_p: f64) -> ClientWork {
        ClientWork {
            id,
            ready_s: ready,
            down_s: down,
            train_s: train,
            up_s: up,
            dropout_p: drop_p,
            trace: AvailabilityTrace::always_on(),
        }
    }

    /// `work` on a duty-cycled trace (the churn tests' raw material).
    fn churn_work(id: usize, tr: AvailabilityTrace, down: f64, train: f64, up: f64) -> ClientWork {
        ClientWork {
            id,
            ready_s: tr.next_online(0.0),
            down_s: down,
            train_s: train,
            up_s: up,
            dropout_p: 0.0,
            trace: tr,
        }
    }

    fn defaults() -> PolicyDefaults {
        PolicyDefaults { deadline_s: 60.0, over_select_extra: 4, buffer_k: 10, max_staleness: 8 }
    }

    /// Self-contained round with churn disabled and a fresh seed.
    fn sim0(start: f64, works: &[ClientWork], policy: RoundPolicy, seed: u64) -> RoundPlan {
        simulate_round(start, works, policy, usize::MAX, ChurnPolicy::None, &mut Rng::new(seed))
    }

    /// Self-contained sync round from t=0 under `churn`, fresh seed.
    fn simc(works: &[ClientWork], churn: ChurnPolicy) -> RoundPlan {
        simulate_round(0.0, works, RoundPolicy::Sync, usize::MAX, churn, &mut Rng::new(1))
    }

    /// Engine round with churn disabled and a fresh seed.
    fn sim(
        engine: &mut FleetEngine,
        round: usize,
        start: f64,
        works: &[ClientWork],
        policy: RoundPolicy,
        seed: u64,
    ) -> RoundPlan {
        let mut rng = Rng::new(seed);
        engine.simulate_round(round, start, works, policy, usize::MAX, ChurnPolicy::None, &mut rng)
    }

    #[test]
    fn sync_waits_for_slowest() {
        let works =
            vec![work(0, 0.0, 1.0, 5.0, 1.0, 0.0), work(1, 0.0, 2.0, 80.0, 3.0, 0.0)];
        let plan = simulate_round(
            10.0,
            &works,
            RoundPolicy::Sync,
            usize::MAX,
            ChurnPolicy::None,
            &mut Rng::new(1),
        );
        assert_eq!(plan.completers, vec![0, 1]);
        assert!(plan.stragglers.is_empty() && plan.dropouts.is_empty());
        // sim time = slowest participant's finish: 10 + 2 + 80 + 3.
        assert!((plan.end_s - 95.0).abs() < 1e-9);
        assert!((plan.duration_s() - 85.0).abs() < 1e-9);
    }

    #[test]
    fn deadline_cuts_slow_clients_as_stragglers() {
        let works =
            vec![work(0, 0.0, 1.0, 5.0, 1.0, 0.0), work(1, 0.0, 2.0, 80.0, 3.0, 0.0)];
        let plan = simulate_round(
            0.0,
            &works,
            RoundPolicy::Deadline { secs: 20.0 },
            usize::MAX,
            ChurnPolicy::None,
            &mut Rng::new(1),
        );
        assert_eq!(plan.completers, vec![0]);
        assert_eq!(plan.stragglers, vec![1]);
        assert!((plan.end_s - 20.0).abs() < 1e-9, "round ends at the deadline");
    }

    #[test]
    fn deadline_ends_early_when_everyone_finishes() {
        let works = vec![work(0, 0.0, 1.0, 2.0, 1.0, 0.0)];
        let plan = simulate_round(
            0.0,
            &works,
            RoundPolicy::Deadline { secs: 100.0 },
            usize::MAX,
            ChurnPolicy::None,
            &mut Rng::new(1),
        );
        assert_eq!(plan.completers, vec![0]);
        assert!((plan.end_s - 4.0).abs() < 1e-9, "no idle wait until the deadline");
    }

    #[test]
    fn over_select_keeps_first_finishers() {
        let works = vec![
            work(0, 0.0, 0.0, 30.0, 0.0, 0.0),
            work(1, 0.0, 0.0, 10.0, 0.0, 0.0),
            work(2, 0.0, 0.0, 20.0, 0.0, 0.0),
        ];
        let plan = simulate_round(
            0.0,
            &works,
            RoundPolicy::OverSelect { extra: 1 },
            2,
            ChurnPolicy::None,
            &mut Rng::new(1),
        );
        assert_eq!(plan.completers, vec![1, 2], "fastest two win");
        assert_eq!(plan.stragglers, vec![0]);
        assert!((plan.end_s - 20.0).abs() < 1e-9);
    }

    #[test]
    fn certain_dropout_is_counted_not_straggled() {
        let works = vec![work(0, 0.0, 1.0, 1.0, 1.0, 1.0), work(1, 0.0, 1.0, 1.0, 1.0, 0.0)];
        let plan = simulate_round(
            0.0,
            &works,
            RoundPolicy::Sync,
            usize::MAX,
            ChurnPolicy::None,
            &mut Rng::new(3),
        );
        assert_eq!(plan.dropouts, vec![0]);
        assert_eq!(plan.completers, vec![1]);
        assert!(plan.stragglers.is_empty());
    }

    #[test]
    fn availability_delays_dispatch() {
        // Client 0 only becomes reachable at t=50.
        let works = vec![work(0, 50.0, 1.0, 2.0, 1.0, 0.0)];
        let plan = simulate_round(
            0.0,
            &works,
            RoundPolicy::Sync,
            usize::MAX,
            ChurnPolicy::None,
            &mut Rng::new(1),
        );
        assert_eq!(plan.events[0].time_s, 50.0);
        assert!((plan.end_s - 54.0).abs() < 1e-9);
    }

    #[test]
    fn empty_cohort_is_a_noop_round() {
        // Under every policy — in particular, an empty deadline round must
        // not burn deadline_s of virtual time waiting for nobody.
        for policy in
            [RoundPolicy::Sync, RoundPolicy::Deadline { secs: 60.0 }, RoundPolicy::OverSelect { extra: 2 }]
        {
            let plan = sim0(7.0, &[], policy, 1);
            assert!(plan.completers.is_empty() && plan.events.is_empty());
            assert_eq!(plan.end_s, 7.0, "{policy:?}");
        }
        // Async with nothing dispatched and nothing in flight is also a no-op.
        let mut engine = FleetEngine::new();
        let policy = RoundPolicy::Async { buffer_k: 3, max_staleness: 8 };
        let plan = sim(&mut engine, 0, 7.0, &[], policy, 1);
        assert!(plan.completers.is_empty() && plan.events.is_empty());
        assert_eq!(plan.end_s, 7.0);
        assert!(engine.inflight().is_empty());
    }

    #[test]
    fn unreachable_client_is_a_straggler_not_a_completer() {
        // Zero-duty trace ⇒ ready_s = INFINITY: the client must not be
        // dispatched (sync would otherwise wait forever / poison the clock).
        let works = vec![
            work(0, f64::INFINITY, 1.0, 2.0, 1.0, 0.0),
            work(1, 0.0, 1.0, 2.0, 1.0, 0.0),
        ];
        for policy in [RoundPolicy::Sync, RoundPolicy::Deadline { secs: 100.0 }] {
            let plan = sim0(0.0, &works, policy, 1);
            assert_eq!(plan.completers, vec![1], "{policy:?}");
            assert_eq!(plan.stragglers, vec![0], "{policy:?}");
            assert!(plan.end_s.is_finite() && (plan.end_s - 4.0).abs() < 1e-9, "{policy:?}");
        }
        // Async: same classification (an unreachable client can never
        // produce an upload, in flight or otherwise).
        let mut engine = FleetEngine::new();
        let policy = RoundPolicy::Async { buffer_k: 2, max_staleness: 8 };
        let plan = sim(&mut engine, 0, 0.0, &works, policy, 1);
        assert_eq!(plan.completers, vec![1]);
        assert_eq!(plan.stragglers, vec![0]);
        assert!(engine.inflight().is_empty());
    }

    #[test]
    fn policy_parsing() {
        let d = defaults();
        assert_eq!(RoundPolicy::parse("sync", &d).unwrap(), RoundPolicy::Sync);
        assert_eq!(
            RoundPolicy::parse("deadline", &d).unwrap(),
            RoundPolicy::Deadline { secs: 60.0 }
        );
        assert_eq!(
            RoundPolicy::parse("deadline:12.5", &d).unwrap(),
            RoundPolicy::Deadline { secs: 12.5 }
        );
        assert_eq!(
            RoundPolicy::parse("over-select", &d).unwrap(),
            RoundPolicy::OverSelect { extra: 4 }
        );
        assert_eq!(
            RoundPolicy::parse("over-select:9", &d).unwrap(),
            RoundPolicy::OverSelect { extra: 9 }
        );
        assert_eq!(
            RoundPolicy::parse("async", &d).unwrap(),
            RoundPolicy::Async { buffer_k: 10, max_staleness: 8 }
        );
        assert_eq!(
            RoundPolicy::parse("async:3", &d).unwrap(),
            RoundPolicy::Async { buffer_k: 3, max_staleness: 8 }
        );
        assert!(RoundPolicy::parse("warp", &d).is_err());
        assert!(RoundPolicy::parse("deadline:abc", &d).is_err());
        assert!(RoundPolicy::parse("deadline:-5", &d).is_err(), "negative deadline");
        assert!(RoundPolicy::parse("deadline:0", &d).is_err(), "zero deadline closes instantly");
        assert!(RoundPolicy::parse("deadline:NaN", &d).is_err(), "non-finite deadline");
        assert!(RoundPolicy::parse("deadline:inf", &d).is_err(), "infinite deadline");
        assert!(RoundPolicy::parse("async:0", &d).is_err(), "zero buffer_k never closes");
        assert!(RoundPolicy::parse("async:nope", &d).is_err());
        let zero_default = PolicyDefaults { buffer_k: 0, ..defaults() };
        assert!(RoundPolicy::parse("async", &zero_default).is_err(), "bad default buffer_k");
    }

    #[test]
    fn async_with_full_buffer_matches_sync_bit_for_bit() {
        // buffer_k >= cohort size ⇒ the async round closes at the last
        // upload, i.e. exactly the sync schedule — the degeneracy the
        // coordinator's record-level guarantee builds on.
        let works = vec![
            work(0, 0.0, 1.0, 5.0, 1.0, 0.0),
            work(1, 3.0, 2.0, 40.0, 3.0, 0.2),
            work(2, 0.0, 0.5, 9.0, 0.5, 0.2),
        ];
        let sync = simulate_round(
            2.0,
            &works,
            RoundPolicy::Sync,
            usize::MAX,
            ChurnPolicy::None,
            &mut Rng::new(5),
        );
        let mut engine = FleetEngine::new();
        let policy = RoundPolicy::Async { buffer_k: works.len(), max_staleness: 8 };
        let a = sim(&mut engine, 0, 2.0, &works, policy, 5);
        assert_eq!(a.completers, sync.completers);
        assert_eq!(a.stragglers, sync.stragglers);
        assert_eq!(a.dropouts, sync.dropouts);
        assert_eq!(a.events, sync.events, "event traces diverged");
        assert_eq!(a.end_s.to_bits(), sync.end_s.to_bits(), "sim time diverged");
        assert!(a.late_arrivals.is_empty() && a.deferred.is_empty());
        assert!(engine.inflight().is_empty());
    }

    #[test]
    fn async_defers_slow_uploads_and_merges_them_later() {
        let mut engine = FleetEngine::new();
        let policy = RoundPolicy::Async { buffer_k: 1, max_staleness: 8 };
        let works = vec![
            work(0, 0.0, 1.0, 2.0, 1.0, 0.0),   // arrives at t=4
            work(1, 0.0, 1.0, 50.0, 9.0, 0.0),  // arrives at t=60
        ];
        let r0 = sim(&mut engine, 0, 0.0, &works, policy, 1);
        assert_eq!(r0.completers, vec![0], "buffer_k=1 closes at the first arrival");
        assert!((r0.end_s - 4.0).abs() < 1e-9);
        assert_eq!(r0.deferred, vec![1], "slow upload is deferred, not discarded");
        assert!(r0.stragglers.is_empty(), "async discards nobody reachable");
        assert_eq!(engine.inflight().len(), 1);
        assert_eq!(engine.inflight()[0].client, 1);
        assert_eq!(engine.inflight()[0].dispatch_round, 0);
        assert!((engine.inflight()[0].arrive_s - 60.0).abs() < 1e-9);

        // Next round: a fast fresh client plus the in-flight upload. The
        // late upload (t=60) lands after the fresh arrival (t=14) but the
        // round needs 2 arrivals, so it closes at the late one.
        let works2 = vec![work(2, 10.0, 1.0, 2.0, 1.0, 0.0)];
        let policy2 = RoundPolicy::Async { buffer_k: 2, max_staleness: 8 };
        let r1 = sim(&mut engine, 1, r0.end_s, &works2, policy2, 2);
        assert_eq!(r1.completers, vec![2]);
        assert_eq!(r1.late_arrivals.len(), 1);
        assert_eq!(r1.late_arrivals[0].client, 1);
        assert_eq!(r1.late_arrivals[0].dispatch_round, 0);
        assert!((r1.end_s - 60.0).abs() < 1e-9, "round closes at the 2nd arrival");
        assert!(engine.inflight().is_empty(), "merged upload leaves the queue");
    }

    #[test]
    fn async_inflight_survives_rounds_that_close_before_it_lands() {
        let mut engine = FleetEngine::new();
        let policy = RoundPolicy::Async { buffer_k: 1, max_staleness: 8 };
        let slow = vec![work(0, 0.0, 1.0, 200.0, 9.0, 0.0), work(1, 0.0, 0.5, 1.0, 0.5, 0.0)];
        let r0 = sim(&mut engine, 0, 0.0, &slow, policy, 1);
        assert_eq!(r0.deferred, vec![0]);
        // Round 1 closes on its own fresh arrival long before t=210.
        let fast = vec![work(2, 0.0, 0.5, 1.0, 0.5, 0.0)];
        let r1 = sim(&mut engine, 1, r0.end_s, &fast, policy, 2);
        assert_eq!(r1.completers, vec![2]);
        assert!(r1.late_arrivals.is_empty(), "upload still in flight");
        assert_eq!(engine.inflight().len(), 1, "carries across multiple rounds");
        // Round 2 has no fresh cohort: the only possible arrival is the
        // in-flight upload, so the round closes when it lands.
        let r2 = sim(&mut engine, 2, r1.end_s, &[], policy, 3);
        assert_eq!(r2.late_arrivals.len(), 1);
        assert_eq!(r2.late_arrivals[0].dispatch_round, 0, "staleness spans two rounds");
        assert!(engine.inflight().is_empty());
    }

    #[test]
    fn async_redispatch_supersedes_stale_inflight_upload() {
        let mut engine = FleetEngine::new();
        let policy = RoundPolicy::Async { buffer_k: 1, max_staleness: 8 };
        let works = vec![work(0, 0.0, 1.0, 100.0, 1.0, 0.0), work(1, 0.0, 0.5, 1.0, 0.5, 0.0)];
        let r0 = sim(&mut engine, 0, 0.0, &works, policy, 1);
        assert_eq!(r0.deferred, vec![0]);
        // Client 0 is sampled again: its old upload is abandoned, and the
        // fresh dispatch re-enters the round normally.
        let works2 = vec![work(0, 0.0, 0.5, 1.0, 0.5, 0.0)];
        let r1 = sim(&mut engine, 1, r0.end_s, &works2, policy, 2);
        assert!(r1.late_arrivals.is_empty(), "stale upload must not merge");
        assert_eq!(r1.completers, vec![0], "fresh dispatch completes normally");
        assert!(engine.inflight().is_empty());
    }

    /// Build a realistic cohort plan end-to-end from a seeded pool
    /// (profiles sampled with the `Rng` fork discipline) — the fleet
    /// determinism contract: same seed + config ⇒ identical event order,
    /// sim time, and straggler/dropout counts.
    fn pool_works(seed: u64) -> Vec<ClientWork> {
        let data = SyntheticDataset::new(10, seed);
        let fleet = FleetProfileConfig::named("mobile").unwrap();
        let pool = ClientPool::build(
            30,
            3_000,
            &data,
            Partition::Iid,
            MemoryConfig::default(),
            &fleet,
            seed,
        );
        let mem = MemCoeffs {
            fixed_bytes: 0,
            per_sample_bytes: 0,
            params_total: 11_000_000,
            params_trainable: 11_000_000,
        };
        let bytes = 44_000_000u64;
        (0..10)
            .map(|cid| {
                let c = pool.client(cid);
                let p = &c.profile;
                ClientWork {
                    id: cid,
                    ready_s: p.trace.next_online(0.0),
                    down_s: p.down_time_s(bytes),
                    train_s: p.train_time_s(c.shard.num_samples(), &mem),
                    up_s: p.up_time_s(bytes),
                    dropout_p: p.dropout_p,
                    trace: p.trace,
                }
            })
            .collect()
    }

    fn plan_from_pool(seed: u64, policy: RoundPolicy) -> RoundPlan {
        let works = pool_works(seed);
        let mut engine = FleetEngine::new();
        engine.simulate_round(
            0,
            0.0,
            &works,
            policy,
            usize::MAX,
            ChurnPolicy::None,
            &mut Rng::new(seed ^ 0xf1ee),
        )
    }

    #[test]
    fn same_seed_same_plan_bit_for_bit() {
        for policy in [
            RoundPolicy::Sync,
            RoundPolicy::Deadline { secs: 300.0 },
            RoundPolicy::Async { buffer_k: 4, max_staleness: 8 },
        ] {
            let a = plan_from_pool(9, policy);
            let b = plan_from_pool(9, policy);
            assert_eq!(a.events, b.events, "event order diverged");
            assert_eq!(a.end_s.to_bits(), b.end_s.to_bits(), "sim time diverged");
            assert_eq!(a.completers, b.completers);
            assert_eq!(a.stragglers, b.stragglers);
            assert_eq!(a.dropouts, b.dropouts);
            assert_eq!(a.deferred, b.deferred);
            assert_eq!(a.late_arrivals, b.late_arrivals);
        }
    }

    #[test]
    fn seeds_actually_change_the_plan() {
        let a = plan_from_pool(9, RoundPolicy::Sync);
        let b = plan_from_pool(10, RoundPolicy::Sync);
        assert_ne!(a.end_s.to_bits(), b.end_s.to_bits());
    }

    #[test]
    fn mobile_deadline_produces_stragglers() {
        // 60s is below the mobile slow tier's minimum possible round
        // (download > 5.5s, train > 44s, upload > 22s at 11 Mparams /
        // 100 samples / 44MB), so any slow-tier or offline client in the
        // cohort must straggle.
        let plan = plan_from_pool(9, RoundPolicy::Deadline { secs: 60.0 });
        assert!(!plan.stragglers.is_empty(), "60s deadline on mobile should straggle");
        let sync = plan_from_pool(9, RoundPolicy::Sync);
        assert!(sync.stragglers.is_empty());
        assert!(sync.end_s > plan.end_s, "sync waits longer than the deadline cut");
    }

    #[test]
    fn mobile_async_defers_what_deadline_would_cut() {
        // Where the deadline policy cuts stragglers, the async policy
        // keeps their uploads in flight and merges them in later rounds —
        // the fleet-level half of the ISSUE acceptance criterion.
        let deadline = plan_from_pool(9, RoundPolicy::Deadline { secs: 60.0 });
        assert!(!deadline.stragglers.is_empty());

        let works = pool_works(9);
        let mut engine = FleetEngine::new();
        let policy = RoundPolicy::Async { buffer_k: 4, max_staleness: 8 };
        let mut rng = Rng::new(9 ^ 0xf1ee);
        let r0 =
            engine.simulate_round(0, 0.0, &works, policy, usize::MAX, ChurnPolicy::None, &mut rng);
        assert!(!r0.deferred.is_empty(), "slow mobile uploads must miss a k=4 window");
        assert!(r0.stragglers.is_empty(), "async discards nobody reachable");

        // Drain subsequent no-cohort rounds: every deferred upload must
        // eventually merge as a late arrival (none are discarded).
        let mut merged = 0usize;
        let mut start = r0.end_s;
        for round in 1..20 {
            if engine.inflight().is_empty() {
                break;
            }
            let r = engine
                .simulate_round(round, start, &[], policy, usize::MAX, ChurnPolicy::None, &mut rng);
            merged += r.late_arrivals.len();
            start = r.end_s;
        }
        assert_eq!(merged, r0.deferred.len(), "every straggler upload merges eventually");
    }

    #[test]
    fn reset_engine_matches_fresh_engine_bit_for_bit() {
        // One engine reused across sweep configurations (reset between)
        // must reproduce a fresh engine exactly — in-flight state cleared,
        // scratch reuse invisible (seq numbering restarts per round).
        let works = pool_works(9);
        let policies = [
            RoundPolicy::Sync,
            RoundPolicy::Async { buffer_k: 2, max_staleness: 8 },
            RoundPolicy::Deadline { secs: 120.0 },
        ];
        let mut reused = FleetEngine::new();
        for policy in policies {
            let mut fresh_engine = FleetEngine::new();
            reused.reset();
            for round in 0..3 {
                let mut r1 = Rng::new(7 + round as u64);
                let mut r2 = Rng::new(7 + round as u64);
                let a = reused.simulate_round(
                    round, 0.0, &works, policy, usize::MAX, ChurnPolicy::None, &mut r1,
                );
                let b = fresh_engine.simulate_round(
                    round, 0.0, &works, policy, usize::MAX, ChurnPolicy::None, &mut r2,
                );
                assert_eq!(a, b, "{policy:?} round {round}");
            }
        }
        reused.reset();
        assert!(reused.inflight().is_empty());
    }

    // --- mid-round churn -------------------------------------------------

    /// period 100, duty 0.6, phase 0: online [0,60), offline [60,100).
    fn duty_trace() -> AvailabilityTrace {
        AvailabilityTrace { period_s: 100.0, duty: 0.6, phase_s: 0.0 }
    }

    #[test]
    fn churn_policy_parsing() {
        assert_eq!(ChurnPolicy::parse("none", 4).unwrap(), ChurnPolicy::None);
        assert_eq!(ChurnPolicy::parse("off", 4).unwrap(), ChurnPolicy::None);
        assert_eq!(ChurnPolicy::parse("abort", 4).unwrap(), ChurnPolicy::Abort);
        assert_eq!(ChurnPolicy::parse("resume", 4).unwrap(), ChurnPolicy::Resume);
        assert_eq!(
            ChurnPolicy::parse("checkpoint", 4).unwrap(),
            ChurnPolicy::Checkpoint { epochs: 4 }
        );
        assert_eq!(
            ChurnPolicy::parse("checkpoint:8", 4).unwrap(),
            ChurnPolicy::Checkpoint { epochs: 8 }
        );
        assert!(ChurnPolicy::parse("checkpoint:0", 4).is_err(), "zero granularity");
        assert!(ChurnPolicy::parse("checkpoint:x", 4).is_err());
        assert!(ChurnPolicy::parse("checkpoint", 0).is_err(), "bad default epochs");
        assert!(ChurnPolicy::parse("abort:3", 4).is_err(), "abort takes no argument");
        assert!(ChurnPolicy::parse("vanish", 4).is_err());
    }

    #[test]
    fn abort_loses_interrupted_work_and_counts_waste() {
        // Client 0 needs 105s of compute but goes offline at t=60: under
        // `abort` the 55 executed train seconds are wasted and the server
        // stops waiting for it. Client 1 finishes untouched.
        let works = vec![
            churn_work(0, duty_trace(), 5.0, 100.0, 10.0),
            churn_work(1, duty_trace(), 1.0, 10.0, 1.0),
        ];
        let plan = simc(&works, ChurnPolicy::Abort);
        assert_eq!(plan.completers, vec![1]);
        assert_eq!(plan.aborted, vec![0]);
        assert!(plan.stragglers.is_empty(), "aborts are not stragglers");
        assert_eq!(plan.interrupts, 1);
        assert_eq!(plan.resumes, 0);
        assert!((plan.wasted_compute_s - 55.0).abs() < 1e-9);
        // The cut landed mid-*training*: the download had completed, so
        // comm accounting still charges it in full.
        assert_eq!(plan.download_frac, vec![(0, 1.0)]);
        assert_eq!(plan.download_fraction(0), 1.0);
        assert!((plan.end_s - 12.0).abs() < 1e-9, "round ends at the last upload");
        assert!(plan.events.iter().any(|e| matches!(e.kind, EventKind::Interrupt { client: 0 })));
    }

    #[test]
    fn abort_on_upload_wastes_the_whole_local_pass() {
        // Training fits the online window but the upload does not: the
        // finished pass dies with the upload (train_s fully wasted).
        let works = vec![churn_work(0, duty_trace(), 5.0, 50.0, 10.0)];
        let plan = simc(&works, ChurnPolicy::Abort);
        assert_eq!(plan.aborted, vec![0]);
        assert!((plan.wasted_compute_s - 50.0).abs() < 1e-9);
        assert_eq!(plan.download_frac, vec![(0, 1.0)], "download completed before the cut");
    }

    #[test]
    fn abort_mid_download_records_partial_fraction() {
        // Dispatch at t=55 with 5s of online window left and a 10s
        // download: the device fetches exactly half the artifact before
        // the offline flip kills the work. No compute happened (nothing
        // wasted), but comm accounting now knows only 50% of the payload
        // moved — an aborted download used to be charged in full.
        let works = vec![churn_work(0, duty_trace(), 10.0, 20.0, 5.0)];
        let plan = simulate_round(
            55.0,
            &works,
            RoundPolicy::Sync,
            usize::MAX,
            ChurnPolicy::Abort,
            &mut Rng::new(1),
        );
        assert_eq!(plan.aborted, vec![0]);
        assert_eq!(plan.download_frac.len(), 1);
        let (c, f) = plan.download_frac[0];
        assert_eq!(c, 0);
        assert!((f - 0.5).abs() < 1e-9, "fetched 5 of 10 download seconds: {f}");
        assert_eq!(plan.wasted_compute_s, 0.0, "no train seconds executed");
        assert_eq!(plan.download_fraction(0), f);
        assert_eq!(plan.download_fraction(99), 1.0, "unknown clients default to full");
    }

    #[test]
    fn resume_stretches_finish_across_offline_windows() {
        // 105s of compute from t=0 pauses over [60,100) and finishes at
        // 145; the 10s upload fits the second window ⇒ arrival at 155
        // (vs 115 uninterrupted — resume never finishes early).
        let works = vec![churn_work(0, duty_trace(), 5.0, 100.0, 10.0)];
        let plan = simc(&works, ChurnPolicy::Resume);
        assert_eq!(plan.completers, vec![0]);
        assert!(plan.aborted.is_empty() && plan.partials.is_empty());
        assert_eq!((plan.interrupts, plan.resumes), (1, 1));
        assert_eq!(plan.wasted_compute_s, 0.0, "resume loses nothing");
        assert!((plan.end_s - 155.0).abs() < 1e-9);
        let kinds: Vec<EventKind> = plan.events.iter().map(|e| e.kind).collect();
        assert_eq!(
            kinds,
            vec![
                EventKind::Dispatch { client: 0 },
                EventKind::Interrupt { client: 0 },
                EventKind::Resume { client: 0 },
                EventKind::TrainDone { client: 0 },
                EventKind::UploadDone { client: 0 },
            ]
        );
    }

    #[test]
    fn checkpoint_uploads_partial_at_epoch_granularity() {
        // 55 of 100 train seconds executed before the cut: 2 of 4 epochs
        // checkpointed ⇒ fraction 0.5, the 5s past the epoch boundary are
        // wasted, and the partial uploads in the next online window.
        let works = vec![churn_work(0, duty_trace(), 5.0, 100.0, 10.0)];
        let churn = ChurnPolicy::Checkpoint { epochs: 4 };
        let plan = simc(&works, churn);
        assert_eq!(plan.completers, vec![0], "the partial still arrives");
        assert_eq!(plan.partials, vec![(0, 0.5)]);
        assert!(plan.aborted.is_empty());
        assert!((plan.wasted_compute_s - 5.0).abs() < 1e-9);
        assert_eq!((plan.interrupts, plan.resumes), (1, 1));
        assert!((plan.end_s - 110.0).abs() < 1e-9, "upload runs [100,110)");
    }

    #[test]
    fn checkpoint_before_first_epoch_aborts() {
        // Only 55 of 1000 train seconds done — not one epoch boundary
        // reached, so there is nothing to upload: abort semantics.
        let works = vec![churn_work(0, duty_trace(), 5.0, 1000.0, 10.0)];
        let churn = ChurnPolicy::Checkpoint { epochs: 4 };
        let plan = simc(&works, churn);
        assert_eq!(plan.aborted, vec![0]);
        assert!(plan.completers.is_empty() && plan.partials.is_empty());
        assert!((plan.wasted_compute_s - 55.0).abs() < 1e-9);
    }

    #[test]
    fn churn_policies_degenerate_on_always_on_traces() {
        // Acceptance: with always-on traces every churn policy takes the
        // fast path and reproduces the churn-free plan bit for bit —
        // events, buckets, rng stream, and times.
        let works = vec![
            work(0, 0.0, 1.0, 5.0, 1.0, 0.0),
            work(1, 3.0, 2.0, 40.0, 3.0, 0.2),
            work(2, 0.0, 0.5, 9.0, 0.5, 0.2),
        ];
        for policy in [
            RoundPolicy::Sync,
            RoundPolicy::Deadline { secs: 20.0 },
            RoundPolicy::Async { buffer_k: 2, max_staleness: 8 },
        ] {
            let churns = [
                ChurnPolicy::Abort,
                ChurnPolicy::Resume,
                ChurnPolicy::Checkpoint { epochs: 4 },
            ];
            for churn in churns {
                let mut e0 = FleetEngine::new();
                let mut e1 = FleetEngine::new();
                let base = e0.simulate_round(
                    0,
                    2.0,
                    &works,
                    policy,
                    usize::MAX,
                    ChurnPolicy::None,
                    &mut Rng::new(5),
                );
                let under = e1.simulate_round(
                    0,
                    2.0,
                    &works,
                    policy,
                    usize::MAX,
                    churn,
                    &mut Rng::new(5),
                );
                assert_eq!(base, under, "{policy:?} × {churn:?} diverged");
                assert_eq!(base.end_s.to_bits(), under.end_s.to_bits());
            }
        }
    }

    #[test]
    fn churn_buckets_partition_the_cohort() {
        // Conservation: every dispatched-or-selected client lands in
        // exactly one of completers/dropouts/aborted/stragglers/deferred,
        // whatever the policy × churn combination.
        let mk = |phase: f64| AvailabilityTrace { period_s: 100.0, duty: 0.6, phase_s: phase };
        let zero_duty = AvailabilityTrace { period_s: 100.0, duty: 0.0, phase_s: 0.0 };
        let mut works = vec![
            churn_work(0, mk(0.0), 5.0, 100.0, 10.0),
            churn_work(1, mk(30.0), 1.0, 10.0, 1.0),
            churn_work(2, mk(55.0), 2.0, 30.0, 4.0),
            churn_work(3, AvailabilityTrace::always_on(), 1.0, 3.0, 1.0),
            churn_work(4, zero_duty, 1.0, 1.0, 1.0),
        ];
        works[3].dropout_p = 1.0; // certain dropout
        let policies = [
            (RoundPolicy::Sync, usize::MAX),
            (RoundPolicy::Deadline { secs: 30.0 }, usize::MAX),
            (RoundPolicy::OverSelect { extra: 2 }, 2),
            (RoundPolicy::Async { buffer_k: 2, max_staleness: 8 }, usize::MAX),
        ];
        let churns = [
            ChurnPolicy::None,
            ChurnPolicy::Abort,
            ChurnPolicy::Resume,
            ChurnPolicy::Checkpoint { epochs: 4 },
        ];
        for (policy, keep) in policies {
            for churn in churns {
                let mut engine = FleetEngine::new();
                let plan =
                    engine.simulate_round(0, 0.0, &works, policy, keep, churn, &mut Rng::new(7));
                let mut seen = std::collections::BTreeSet::new();
                for bucket in [
                    &plan.completers,
                    &plan.stragglers,
                    &plan.dropouts,
                    &plan.aborted,
                    &plan.deferred,
                ] {
                    for &id in bucket.iter() {
                        assert!(seen.insert(id), "{policy:?}×{churn:?}: client {id} twice");
                    }
                }
                assert_eq!(seen.len(), works.len(), "{policy:?}×{churn:?}: client lost");
                assert!(plan.wasted_compute_s >= 0.0);
            }
        }
    }

    #[test]
    fn async_checkpoint_partial_defers_and_merges_later() {
        // buffer_k=1 closes on the fast client; the interrupted client's
        // partial upload (fraction 0.5) is deferred into the in-flight
        // queue and merges as a late arrival in a later round.
        let mut engine = FleetEngine::new();
        let policy = RoundPolicy::Async { buffer_k: 1, max_staleness: 8 };
        let churn = ChurnPolicy::Checkpoint { epochs: 4 };
        let works = vec![
            churn_work(0, duty_trace(), 5.0, 100.0, 10.0), // partial arrives at 110
            churn_work(1, duty_trace(), 1.0, 2.0, 1.0),    // arrives at 4
        ];
        let r0 = engine.simulate_round(0, 0.0, &works, policy, usize::MAX, churn, &mut Rng::new(1));
        assert_eq!(r0.completers, vec![1]);
        assert_eq!(r0.deferred, vec![0]);
        assert_eq!(r0.partials, vec![(0, 0.5)], "fraction rides the plan for the coordinator");
        assert_eq!(engine.inflight().len(), 1);
        let r1 =
            engine.simulate_round(1, r0.end_s, &[], policy, usize::MAX, churn, &mut Rng::new(2));
        assert_eq!(r1.late_arrivals.len(), 1);
        assert_eq!(r1.late_arrivals[0].client, 0);
        assert!((r1.late_arrivals[0].arrive_s - 110.0).abs() < 1e-9);
        assert!(engine.inflight().is_empty());
    }

    // --- deterministic parallel span planning ---------------------------

    /// A churn-heavy mixed cohort: phased duty cycles, an always-on
    /// certain dropout, and an unreachable zero-duty client — the same
    /// raw material as the partition test, exercising every planner
    /// branch (pauses, cuts, partials, pre-resume uploads).
    fn mixed_churn_works() -> Vec<ClientWork> {
        let mk = |phase: f64| AvailabilityTrace { period_s: 100.0, duty: 0.6, phase_s: phase };
        let zero_duty = AvailabilityTrace { period_s: 100.0, duty: 0.0, phase_s: 0.0 };
        let mut works = vec![
            churn_work(0, mk(0.0), 5.0, 100.0, 10.0),
            churn_work(1, mk(30.0), 1.0, 10.0, 1.0),
            churn_work(2, mk(55.0), 2.0, 30.0, 4.0),
            churn_work(3, AvailabilityTrace::always_on(), 1.0, 3.0, 1.0),
            churn_work(4, zero_duty, 1.0, 1.0, 1.0),
            churn_work(5, mk(10.0), 10.0, 200.0, 20.0),
            churn_work(6, mk(80.0), 3.0, 40.0, 6.0),
        ];
        works[3].dropout_p = 1.0;
        works
    }

    #[test]
    fn thread_count_never_changes_the_plan_bit_for_bit() {
        // The any-thread-count determinism guarantee at the engine level:
        // threads ∈ {1, 4, 8} produce identical RoundPlans — events (seq
        // numbers included), time bits, and every bucket — across every
        // policy × churn combination, including multi-round async runs
        // whose in-flight queue crosses the thread boundary.
        let works = mixed_churn_works();
        let policies = [
            (RoundPolicy::Sync, usize::MAX),
            (RoundPolicy::Deadline { secs: 30.0 }, usize::MAX),
            (RoundPolicy::OverSelect { extra: 2 }, 2),
            (RoundPolicy::Async { buffer_k: 2, max_staleness: 8 }, usize::MAX),
        ];
        let churns = [
            ChurnPolicy::None,
            ChurnPolicy::Abort,
            ChurnPolicy::Resume,
            ChurnPolicy::Checkpoint { epochs: 4 },
        ];
        for (policy, keep) in policies {
            for churn in churns {
                let mut base_engine = FleetEngine::with_threads(1);
                let mut base_rng = Rng::new(7);
                let mut start = 0.0;
                let mut baseline = Vec::new();
                for round in 0..3 {
                    let p = base_engine
                        .simulate_round(round, start, &works, policy, keep, churn, &mut base_rng);
                    start = p.end_s;
                    baseline.push(p);
                }
                for threads in [4, 8] {
                    let mut engine = FleetEngine::with_threads(threads);
                    let mut rng = Rng::new(7);
                    let mut start = 0.0;
                    for (round, expect) in baseline.iter().enumerate() {
                        let p = engine
                            .simulate_round(round, start, &works, policy, keep, churn, &mut rng);
                        assert_eq!(
                            &p, expect,
                            "{policy:?} × {churn:?} diverged at {threads} threads, round {round}"
                        );
                        assert_eq!(p.end_s.to_bits(), expect.end_s.to_bits());
                        start = p.end_s;
                    }
                }
            }
        }
    }

    #[test]
    fn pool_fleet_threads_match_inline_bit_for_bit() {
        // Same guarantee on the realistic seeded mobile cohort (rng-varied
        // dropout draws interleaving with precomputed plans).
        let works = pool_works(9);
        for policy in [
            RoundPolicy::Sync,
            RoundPolicy::Deadline { secs: 300.0 },
            RoundPolicy::Async { buffer_k: 4, max_staleness: 8 },
        ] {
            let mut inline = FleetEngine::with_threads(1);
            let mut pooled = FleetEngine::with_threads(4);
            let mut r1 = Rng::new(9 ^ 0xf1ee);
            let mut r2 = Rng::new(9 ^ 0xf1ee);
            for round in 0..2 {
                let a = inline.simulate_round(
                    round, 0.0, &works, policy, usize::MAX, ChurnPolicy::Resume, &mut r1,
                );
                let b = pooled.simulate_round(
                    round, 0.0, &works, policy, usize::MAX, ChurnPolicy::Resume, &mut r2,
                );
                assert_eq!(a, b, "{policy:?} round {round}");
            }
        }
    }

    #[test]
    fn threads_are_clamped_and_reported() {
        let mut e = FleetEngine::with_threads(0);
        assert_eq!(e.threads(), 1, "0 clamps to inline");
        e.set_threads(6);
        assert_eq!(e.threads(), 6);
        // Inline rounds report full utilization (the event loop is the
        // one worker); pooled rounds report a busy fraction in (0, 1].
        let works = mixed_churn_works();
        let mut inline = FleetEngine::with_threads(1);
        inline.simulate_round(
            0,
            0.0,
            &works,
            RoundPolicy::Sync,
            usize::MAX,
            ChurnPolicy::Resume,
            &mut Rng::new(1),
        );
        assert_eq!(inline.last_worker_utilization(), 1.0);
        let mut pooled = FleetEngine::with_threads(4);
        pooled.simulate_round(
            0,
            0.0,
            &works,
            RoundPolicy::Sync,
            usize::MAX,
            ChurnPolicy::Resume,
            &mut Rng::new(1),
        );
        let u = pooled.last_worker_utilization();
        assert!(u > 0.0 && u <= 1.0, "utilization {u} out of range");
    }

    #[test]
    fn resume_under_deadline_still_cuts_stragglers() {
        // Resume composes with the deadline policy: the paused client's
        // stretched finish (155) misses a 60s deadline and is cut as an
        // ordinary straggler — interrupted work is not special-cased past
        // the server's cutoff.
        let works = vec![
            churn_work(0, duty_trace(), 5.0, 100.0, 10.0),
            churn_work(1, duty_trace(), 1.0, 10.0, 1.0),
        ];
        let policy = RoundPolicy::Deadline { secs: 60.0 };
        let plan =
            simulate_round(0.0, &works, policy, usize::MAX, ChurnPolicy::Resume, &mut Rng::new(1));
        assert_eq!(plan.completers, vec![1]);
        assert_eq!(plan.stragglers, vec![0]);
        assert!(plan.aborted.is_empty());
        assert!((plan.end_s - 60.0).abs() < 1e-9);
    }
}
