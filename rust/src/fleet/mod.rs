//! Fleet simulator (L3): deterministic discrete-event engine for
//! heterogeneous-device round dynamics.
//!
//! The seed coordinator modelled the fleet as a memoryless synchronous
//! loop — every sampled client trained "instantly", so the system could
//! say nothing about wall-clock time-to-accuracy, stragglers, or
//! dropout. This module adds the missing dimension: every client carries
//! a [`DeviceProfile`] (compute throughput, link speeds, availability
//! trace, dropout probability), a train round dispatches its cohort as
//! events on a virtual clock, and a [`RoundPolicy`] decides who makes it
//! into the aggregate:
//!
//! * [`RoundPolicy::Sync`] — wait for every dispatched client; round
//!   time is the slowest participant's finish time.
//! * [`RoundPolicy::Deadline`] — aggregate whatever has arrived when the
//!   deadline fires; the rest are counted as stragglers.
//! * [`RoundPolicy::OverSelect`] — sample `per_round + extra` clients
//!   and keep the first `per_round` finishers (FedScale-style
//!   over-commitment).
//! * [`RoundPolicy::Async`] — semi-synchronous FedBuff-style buffering:
//!   the round closes at the `buffer_k`-th upload arrival, and uploads
//!   that miss the window are *not* discarded — they persist in the
//!   [`FleetEngine`]'s cross-round in-flight queue and surface as
//!   [`RoundPlan::late_arrivals`] in the round where they land, tagged
//!   with their dispatch round so the server can staleness-discount (or
//!   drop) them.
//!
//! `sync`/`deadline`/`over-select` rounds are self-contained, so the
//! plain [`simulate_round`] function serves them. `async` spans rounds:
//! the [`FleetEngine`] owns the in-flight uploads between
//! `simulate_round` calls and is the one entry point that handles every
//! policy.
//!
//! Everything is seeded: same config + seed ⇒ identical event order,
//! `sim_time_s`, and straggler/dropout counts, bit for bit. With
//! `buffer_k` ≥ the dispatched cohort size, an async round closes at the
//! last upload — exactly the sync schedule, which is what makes the
//! async policy degenerate to `sync` bit-for-bit (see `lib.rs` docs).

pub mod event;
pub mod profile;
pub mod trace;

pub use event::{Event, EventKind, EventQueue, VirtualClock};
pub use profile::{DeviceProfile, DeviceTier, FleetProfileConfig, TierSpec};
pub use trace::AvailabilityTrace;

use crate::rng::Rng;
use anyhow::{bail, Result};
use std::collections::HashMap;

/// How a train round decides when to aggregate.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum RoundPolicy {
    /// Wait for every dispatched client (classic synchronous FedAvg).
    Sync,
    /// Aggregate at `start + secs`; unfinished clients become stragglers.
    Deadline { secs: f64 },
    /// Sample `extra` clients beyond `per_round`, keep the first
    /// `per_round` finishers, count the rest as stragglers.
    OverSelect { extra: usize },
    /// Semi-synchronous FedBuff-style buffering: close the round at the
    /// `buffer_k`-th arrival; later uploads stay in flight and merge on
    /// arrival unless older than `max_staleness` rounds.
    Async { buffer_k: usize, max_staleness: usize },
}

/// Config-supplied fallbacks for the bare policy spellings
/// (`deadline`, `over-select`, `async` without a `:K` argument).
#[derive(Debug, Clone, Copy)]
pub struct PolicyDefaults {
    pub deadline_s: f64,
    pub over_select_extra: usize,
    pub buffer_k: usize,
    pub max_staleness: usize,
}

impl Default for PolicyDefaults {
    fn default() -> Self {
        PolicyDefaults { deadline_s: 60.0, over_select_extra: 4, buffer_k: 10, max_staleness: 8 }
    }
}

impl RoundPolicy {
    /// Parse a CLI/config spelling. Accepts `sync`, `deadline`,
    /// `deadline:SECS`, `over-select`, `over-select:K`, `async`,
    /// `async:K`; the bare forms take their value from `defaults`.
    pub fn parse(s: &str, defaults: &PolicyDefaults) -> Result<Self> {
        let (head, arg) = match s.split_once(':') {
            Some((h, a)) => (h, Some(a)),
            None => (s, None),
        };
        match head {
            "sync" => Ok(RoundPolicy::Sync),
            "deadline" => {
                let secs: f64 = match arg {
                    Some(a) => a.parse().map_err(|e| anyhow::anyhow!("bad deadline `{a}`: {e}"))?,
                    None => defaults.deadline_s,
                };
                if !secs.is_finite() || secs < 0.0 {
                    bail!("deadline must be a finite non-negative number of seconds, got {secs}");
                }
                Ok(RoundPolicy::Deadline { secs })
            }
            "over-select" | "overselect" => {
                let extra = match arg {
                    Some(a) => a.parse().map_err(|e| anyhow::anyhow!("bad over-select `{a}`: {e}"))?,
                    None => defaults.over_select_extra,
                };
                Ok(RoundPolicy::OverSelect { extra })
            }
            "async" => {
                let buffer_k = match arg {
                    Some(a) => a.parse().map_err(|e| anyhow::anyhow!("bad buffer-k `{a}`: {e}"))?,
                    None => defaults.buffer_k,
                };
                if buffer_k == 0 {
                    bail!("async needs buffer_k >= 1 (the round would never close)");
                }
                Ok(RoundPolicy::Async { buffer_k, max_staleness: defaults.max_staleness })
            }
            other => bail!("unknown round policy `{other}` (sync|deadline[:S]|over-select[:K]|async[:K])"),
        }
    }
}

/// One cohort member's precomputed timing for a round: when it can be
/// dispatched and how long each leg takes. Built by
/// `ServerCtx::client_work` from the client's [`DeviceProfile`], shard
/// size, and the round artifact's byte/FLOP footprint.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct ClientWork {
    pub id: usize,
    /// Earliest dispatch time (availability-gated), absolute seconds.
    pub ready_s: f64,
    /// Sub-model download time.
    pub down_s: f64,
    /// Local training time.
    pub train_s: f64,
    /// Update upload time.
    pub up_s: f64,
    /// Probability the client vanishes after dispatch this round.
    pub dropout_p: f64,
}

/// An upload crossing a round boundary (async policy): the client was
/// dispatched in `dispatch_round` and its update reaches the server at
/// absolute virtual time `arrive_s`.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct InFlightUpload {
    pub client: usize,
    pub arrive_s: f64,
    pub dispatch_round: usize,
}

/// What the simulator decided for one round.
#[derive(Debug, Clone, PartialEq)]
pub struct RoundPlan {
    /// Clients whose updates are aggregated, in upload-arrival order.
    /// (The coordinator re-sorts these into selection order before
    /// FedAvg so float accumulation stays reproducible across policies.)
    pub completers: Vec<usize>,
    /// Dispatched-or-selected clients cut by the round policy.
    pub stragglers: Vec<usize>,
    /// Clients that dropped out after dispatch.
    pub dropouts: Vec<usize>,
    /// Async policy: earlier rounds' uploads that arrived inside this
    /// round's window (arrival order), tagged with their dispatch round.
    pub late_arrivals: Vec<InFlightUpload>,
    /// Async policy: this round's dispatched clients whose uploads missed
    /// the window and moved into the engine's in-flight queue instead of
    /// being discarded (arrival order).
    pub deferred: Vec<usize>,
    pub start_s: f64,
    /// Virtual time at which the server aggregates.
    pub end_s: f64,
    /// Processed events in execution order (determinism witnesses),
    /// truncated to the round window.
    pub events: Vec<Event>,
}

impl RoundPlan {
    pub fn duration_s(&self) -> f64 {
        self.end_s - self.start_s
    }
}

/// Round-spanning simulator state. Stateless policies (`sync`,
/// `deadline`, `over-select`) pass straight through to
/// [`simulate_round`]; the `async` policy keeps its in-flight uploads
/// here between rounds.
#[derive(Debug, Default)]
pub struct FleetEngine {
    inflight: Vec<InFlightUpload>,
}

impl FleetEngine {
    pub fn new() -> Self {
        FleetEngine::default()
    }

    /// Uploads currently crossing a round boundary (arrival order).
    pub fn inflight(&self) -> &[InFlightUpload] {
        &self.inflight
    }

    /// Run one round's cohort under `policy`. `round` is the server's
    /// round index (stamped onto deferred uploads so staleness can be
    /// computed on arrival); `keep` caps how many finishers aggregate
    /// under over-select (`usize::MAX` otherwise).
    pub fn simulate_round(
        &mut self,
        round: usize,
        start_s: f64,
        works: &[ClientWork],
        policy: RoundPolicy,
        keep: usize,
        rng: &mut Rng,
    ) -> RoundPlan {
        match policy {
            RoundPolicy::Async { buffer_k, .. } => {
                self.simulate_async(round, start_s, works, buffer_k, rng)
            }
            _ => {
                debug_assert!(
                    self.inflight.is_empty(),
                    "in-flight uploads exist but the policy is not async"
                );
                simulate_round(start_s, works, policy, keep, rng)
            }
        }
    }

    /// Async (FedBuff-style) round: simulate the whole cohort to
    /// completion — every dispatch/dropout draw happens in the same
    /// event order as `sync`, so the rng stream stays aligned — then
    /// close the round at the `buffer_k`-th arrival (fresh uploads and
    /// in-flight arrivals both count). Fresh uploads after the close
    /// move into the in-flight queue; in-flight arrivals after the close
    /// stay queued for a later round.
    fn simulate_async(
        &mut self,
        round: usize,
        start_s: f64,
        works: &[ClientWork],
        buffer_k: usize,
        rng: &mut Rng,
    ) -> RoundPlan {
        // A fresh dispatch supersedes the same client's stale in-flight
        // upload (the device abandons the old job for the new one).
        self.inflight.retain(|u| !works.iter().any(|w| w.id == u.client));

        let by_id: HashMap<usize, &ClientWork> = works.iter().map(|w| (w.id, w)).collect();
        let origin: HashMap<usize, usize> =
            self.inflight.iter().map(|u| (u.client, u.dispatch_round)).collect();

        let mut q = EventQueue::new();
        // In-flight arrivals first (stable stored order), then fresh
        // dispatches — deterministic seq tie-breaking either way.
        for u in &self.inflight {
            q.push(u.arrive_s.max(start_s), EventKind::LateUpload { client: u.client });
        }
        for w in works {
            // Non-finite ready time (zero-duty trace): never dispatched,
            // falls through to the straggler set below.
            if w.ready_s.is_finite() {
                q.push(start_s.max(w.ready_s), EventKind::Dispatch { client: w.id });
            }
        }

        let mut clock = VirtualClock::new(start_s);
        let mut events = Vec::new();
        let mut fresh: Vec<(f64, usize)> = Vec::new();
        let mut late: Vec<(f64, usize)> = Vec::new();
        let mut dropouts = Vec::new();
        let mut arrivals = 0usize;
        let mut close_s: Option<f64> = None;
        let mut last_arrival_s: Option<f64> = None;

        while let Some(ev) = q.pop() {
            clock.advance_to(ev.time_s);
            events.push(ev);
            match ev.kind {
                EventKind::Dispatch { client } => {
                    let w = by_id[&client];
                    if rng.f64() < w.dropout_p {
                        dropouts.push(client);
                    } else {
                        q.push(ev.time_s + w.down_s + w.train_s, EventKind::TrainDone { client });
                    }
                }
                EventKind::TrainDone { client } => {
                    q.push(ev.time_s + by_id[&client].up_s, EventKind::UploadDone { client });
                }
                EventKind::UploadDone { client } => {
                    fresh.push((ev.time_s, client));
                    arrivals += 1;
                    last_arrival_s = Some(ev.time_s);
                    if arrivals == buffer_k && close_s.is_none() {
                        close_s = Some(ev.time_s);
                    }
                }
                EventKind::LateUpload { client } => {
                    late.push((ev.time_s, client));
                    arrivals += 1;
                    last_arrival_s = Some(ev.time_s);
                    if arrivals == buffer_k && close_s.is_none() {
                        close_s = Some(ev.time_s);
                    }
                }
                // Async rounds schedule no deadline events.
                EventKind::Deadline => {}
            }
        }

        // Fewer than buffer_k arrivals possible: the server closes when
        // nothing more can arrive (the last arrival, or immediately).
        let close_s = close_s.or(last_arrival_s).unwrap_or(start_s);

        let mut completers = Vec::new();
        let mut next_inflight: Vec<InFlightUpload> = Vec::new();
        let mut deferred = Vec::new();
        // In-flight arrivals keep queue priority over this round's
        // deferrals in the next round's event order: re-queue them first.
        for (t, c) in late.iter().copied().filter(|(t, _)| *t > close_s) {
            let dispatch_round = origin[&c];
            next_inflight.push(InFlightUpload { client: c, arrive_s: t, dispatch_round });
        }
        for (t, c) in fresh {
            if t <= close_s {
                completers.push(c);
            } else {
                deferred.push(c);
                let u = InFlightUpload { client: c, arrive_s: t, dispatch_round: round };
                next_inflight.push(u);
            }
        }
        let late_arrivals: Vec<InFlightUpload> = late
            .iter()
            .copied()
            .filter(|(t, _)| *t <= close_s)
            .map(|(t, c)| InFlightUpload { client: c, arrive_s: t, dispatch_round: origin[&c] })
            .collect();
        self.inflight = next_inflight;

        // Unreachable clients are the only stragglers under async — every
        // dispatched client either drops out or (eventually) arrives.
        let stragglers: Vec<usize> =
            works.iter().filter(|w| !w.ready_s.is_finite()).map(|w| w.id).collect();
        events.retain(|e| e.time_s <= close_s);
        RoundPlan {
            completers,
            stragglers,
            dropouts,
            late_arrivals,
            deferred,
            start_s,
            end_s: close_s,
            events,
        }
    }
}

/// Run one self-contained round's cohort through the event loop (`sync`,
/// `deadline`, `over-select` — for `async` use [`FleetEngine`]). `keep`
/// caps how many finishers are aggregated (`usize::MAX` for
/// sync/deadline; `per_round` for over-select). Dropout draws happen in
/// event order from `rng`, so the whole plan is a pure function of its
/// arguments.
pub fn simulate_round(
    start_s: f64,
    works: &[ClientWork],
    policy: RoundPolicy,
    keep: usize,
    rng: &mut Rng,
) -> RoundPlan {
    debug_assert!(
        !matches!(policy, RoundPolicy::Async { .. }),
        "async rounds carry cross-round state; use FleetEngine::simulate_round"
    );
    // An empty cohort is a no-op round: nothing to dispatch, so no
    // deadline wait either (the server has nobody to wait for).
    if works.is_empty() {
        return RoundPlan {
            completers: Vec::new(),
            stragglers: Vec::new(),
            dropouts: Vec::new(),
            late_arrivals: Vec::new(),
            deferred: Vec::new(),
            start_s,
            end_s: start_s,
            events: Vec::new(),
        };
    }
    let by_id: HashMap<usize, &ClientWork> = works.iter().map(|w| (w.id, w)).collect();
    let mut q = EventQueue::new();
    // Clients still owing an upload; the loop may stop early once none remain.
    let mut outstanding = 0usize;
    for w in works {
        // A non-finite ready time (zero-duty availability trace) means the
        // client can never be dispatched: it falls through to the straggler
        // set below instead of poisoning the clock with an INF event.
        if w.ready_s.is_finite() {
            q.push(start_s.max(w.ready_s), EventKind::Dispatch { client: w.id });
            outstanding += 1;
        }
    }
    if outstanding > 0 {
        if let RoundPolicy::Deadline { secs } = policy {
            q.push(start_s + secs, EventKind::Deadline);
        }
    }

    let mut clock = VirtualClock::new(start_s);
    let mut events = Vec::new();
    let mut completers = Vec::new();
    let mut dropouts = Vec::new();
    let mut end_s = start_s;

    while let Some(ev) = q.pop() {
        clock.advance_to(ev.time_s);
        match ev.kind {
            EventKind::Dispatch { client } => {
                events.push(ev);
                let w = by_id[&client];
                if rng.f64() < w.dropout_p {
                    dropouts.push(client);
                    outstanding -= 1;
                } else {
                    q.push(ev.time_s + w.down_s + w.train_s, EventKind::TrainDone { client });
                }
            }
            EventKind::TrainDone { client } => {
                events.push(ev);
                q.push(ev.time_s + by_id[&client].up_s, EventKind::UploadDone { client });
            }
            EventKind::UploadDone { client } => {
                events.push(ev);
                completers.push(client);
                outstanding -= 1;
                end_s = clock.now_s();
                if completers.len() >= keep {
                    break; // over-select: cohort is full
                }
            }
            // Self-contained rounds never schedule late uploads.
            EventKind::LateUpload { .. } => {}
            EventKind::Deadline => {
                events.push(ev);
                end_s = clock.now_s();
                break; // everyone still in flight is a straggler
            }
        }
        if outstanding == 0 {
            break; // all uploads in (or dropped) — don't wait for a deadline
        }
    }

    let stragglers: Vec<usize> = works
        .iter()
        .map(|w| w.id)
        .filter(|id| !completers.contains(id) && !dropouts.contains(id))
        .collect();
    RoundPlan {
        completers,
        stragglers,
        dropouts,
        late_arrivals: Vec::new(),
        deferred: Vec::new(),
        start_s,
        end_s,
        events,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::clients::ClientPool;
    use crate::data::{Partition, SyntheticDataset};
    use crate::manifest::MemCoeffs;
    use crate::memory::MemoryConfig;

    fn work(id: usize, ready: f64, down: f64, train: f64, up: f64, drop_p: f64) -> ClientWork {
        ClientWork { id, ready_s: ready, down_s: down, train_s: train, up_s: up, dropout_p: drop_p }
    }

    fn defaults() -> PolicyDefaults {
        PolicyDefaults { deadline_s: 60.0, over_select_extra: 4, buffer_k: 10, max_staleness: 8 }
    }

    #[test]
    fn sync_waits_for_slowest() {
        let works =
            vec![work(0, 0.0, 1.0, 5.0, 1.0, 0.0), work(1, 0.0, 2.0, 80.0, 3.0, 0.0)];
        let plan =
            simulate_round(10.0, &works, RoundPolicy::Sync, usize::MAX, &mut Rng::new(1));
        assert_eq!(plan.completers, vec![0, 1]);
        assert!(plan.stragglers.is_empty() && plan.dropouts.is_empty());
        // sim time = slowest participant's finish: 10 + 2 + 80 + 3.
        assert!((plan.end_s - 95.0).abs() < 1e-9);
        assert!((plan.duration_s() - 85.0).abs() < 1e-9);
    }

    #[test]
    fn deadline_cuts_slow_clients_as_stragglers() {
        let works =
            vec![work(0, 0.0, 1.0, 5.0, 1.0, 0.0), work(1, 0.0, 2.0, 80.0, 3.0, 0.0)];
        let plan = simulate_round(
            0.0,
            &works,
            RoundPolicy::Deadline { secs: 20.0 },
            usize::MAX,
            &mut Rng::new(1),
        );
        assert_eq!(plan.completers, vec![0]);
        assert_eq!(plan.stragglers, vec![1]);
        assert!((plan.end_s - 20.0).abs() < 1e-9, "round ends at the deadline");
    }

    #[test]
    fn deadline_ends_early_when_everyone_finishes() {
        let works = vec![work(0, 0.0, 1.0, 2.0, 1.0, 0.0)];
        let plan = simulate_round(
            0.0,
            &works,
            RoundPolicy::Deadline { secs: 100.0 },
            usize::MAX,
            &mut Rng::new(1),
        );
        assert_eq!(plan.completers, vec![0]);
        assert!((plan.end_s - 4.0).abs() < 1e-9, "no idle wait until the deadline");
    }

    #[test]
    fn over_select_keeps_first_finishers() {
        let works = vec![
            work(0, 0.0, 0.0, 30.0, 0.0, 0.0),
            work(1, 0.0, 0.0, 10.0, 0.0, 0.0),
            work(2, 0.0, 0.0, 20.0, 0.0, 0.0),
        ];
        let plan = simulate_round(
            0.0,
            &works,
            RoundPolicy::OverSelect { extra: 1 },
            2,
            &mut Rng::new(1),
        );
        assert_eq!(plan.completers, vec![1, 2], "fastest two win");
        assert_eq!(plan.stragglers, vec![0]);
        assert!((plan.end_s - 20.0).abs() < 1e-9);
    }

    #[test]
    fn certain_dropout_is_counted_not_straggled() {
        let works = vec![work(0, 0.0, 1.0, 1.0, 1.0, 1.0), work(1, 0.0, 1.0, 1.0, 1.0, 0.0)];
        let plan =
            simulate_round(0.0, &works, RoundPolicy::Sync, usize::MAX, &mut Rng::new(3));
        assert_eq!(plan.dropouts, vec![0]);
        assert_eq!(plan.completers, vec![1]);
        assert!(plan.stragglers.is_empty());
    }

    #[test]
    fn availability_delays_dispatch() {
        // Client 0 only becomes reachable at t=50.
        let works = vec![work(0, 50.0, 1.0, 2.0, 1.0, 0.0)];
        let plan =
            simulate_round(0.0, &works, RoundPolicy::Sync, usize::MAX, &mut Rng::new(1));
        assert_eq!(plan.events[0].time_s, 50.0);
        assert!((plan.end_s - 54.0).abs() < 1e-9);
    }

    #[test]
    fn empty_cohort_is_a_noop_round() {
        // Under every policy — in particular, an empty deadline round must
        // not burn deadline_s of virtual time waiting for nobody.
        for policy in
            [RoundPolicy::Sync, RoundPolicy::Deadline { secs: 60.0 }, RoundPolicy::OverSelect { extra: 2 }]
        {
            let plan = simulate_round(7.0, &[], policy, usize::MAX, &mut Rng::new(1));
            assert!(plan.completers.is_empty() && plan.events.is_empty());
            assert_eq!(plan.end_s, 7.0, "{policy:?}");
        }
        // Async with nothing dispatched and nothing in flight is also a no-op.
        let mut engine = FleetEngine::new();
        let policy = RoundPolicy::Async { buffer_k: 3, max_staleness: 8 };
        let plan = engine.simulate_round(0, 7.0, &[], policy, usize::MAX, &mut Rng::new(1));
        assert!(plan.completers.is_empty() && plan.events.is_empty());
        assert_eq!(plan.end_s, 7.0);
        assert!(engine.inflight().is_empty());
    }

    #[test]
    fn unreachable_client_is_a_straggler_not_a_completer() {
        // Zero-duty trace ⇒ ready_s = INFINITY: the client must not be
        // dispatched (sync would otherwise wait forever / poison the clock).
        let works = vec![
            work(0, f64::INFINITY, 1.0, 2.0, 1.0, 0.0),
            work(1, 0.0, 1.0, 2.0, 1.0, 0.0),
        ];
        for policy in [RoundPolicy::Sync, RoundPolicy::Deadline { secs: 100.0 }] {
            let plan = simulate_round(0.0, &works, policy, usize::MAX, &mut Rng::new(1));
            assert_eq!(plan.completers, vec![1], "{policy:?}");
            assert_eq!(plan.stragglers, vec![0], "{policy:?}");
            assert!(plan.end_s.is_finite() && (plan.end_s - 4.0).abs() < 1e-9, "{policy:?}");
        }
        // Async: same classification (an unreachable client can never
        // produce an upload, in flight or otherwise).
        let mut engine = FleetEngine::new();
        let policy = RoundPolicy::Async { buffer_k: 2, max_staleness: 8 };
        let plan = engine.simulate_round(0, 0.0, &works, policy, usize::MAX, &mut Rng::new(1));
        assert_eq!(plan.completers, vec![1]);
        assert_eq!(plan.stragglers, vec![0]);
        assert!(engine.inflight().is_empty());
    }

    #[test]
    fn policy_parsing() {
        let d = defaults();
        assert_eq!(RoundPolicy::parse("sync", &d).unwrap(), RoundPolicy::Sync);
        assert_eq!(
            RoundPolicy::parse("deadline", &d).unwrap(),
            RoundPolicy::Deadline { secs: 60.0 }
        );
        assert_eq!(
            RoundPolicy::parse("deadline:12.5", &d).unwrap(),
            RoundPolicy::Deadline { secs: 12.5 }
        );
        assert_eq!(
            RoundPolicy::parse("over-select", &d).unwrap(),
            RoundPolicy::OverSelect { extra: 4 }
        );
        assert_eq!(
            RoundPolicy::parse("over-select:9", &d).unwrap(),
            RoundPolicy::OverSelect { extra: 9 }
        );
        assert_eq!(
            RoundPolicy::parse("async", &d).unwrap(),
            RoundPolicy::Async { buffer_k: 10, max_staleness: 8 }
        );
        assert_eq!(
            RoundPolicy::parse("async:3", &d).unwrap(),
            RoundPolicy::Async { buffer_k: 3, max_staleness: 8 }
        );
        assert!(RoundPolicy::parse("warp", &d).is_err());
        assert!(RoundPolicy::parse("deadline:abc", &d).is_err());
        assert!(RoundPolicy::parse("deadline:-5", &d).is_err(), "negative deadline");
        assert!(RoundPolicy::parse("deadline:NaN", &d).is_err(), "non-finite deadline");
        assert!(RoundPolicy::parse("async:0", &d).is_err(), "zero buffer_k never closes");
        assert!(RoundPolicy::parse("async:nope", &d).is_err());
        let zero_default = PolicyDefaults { buffer_k: 0, ..defaults() };
        assert!(RoundPolicy::parse("async", &zero_default).is_err(), "bad default buffer_k");
    }

    #[test]
    fn async_with_full_buffer_matches_sync_bit_for_bit() {
        // buffer_k >= cohort size ⇒ the async round closes at the last
        // upload, i.e. exactly the sync schedule — the degeneracy the
        // coordinator's record-level guarantee builds on.
        let works = vec![
            work(0, 0.0, 1.0, 5.0, 1.0, 0.0),
            work(1, 3.0, 2.0, 40.0, 3.0, 0.2),
            work(2, 0.0, 0.5, 9.0, 0.5, 0.2),
        ];
        let sync = simulate_round(2.0, &works, RoundPolicy::Sync, usize::MAX, &mut Rng::new(5));
        let mut engine = FleetEngine::new();
        let policy = RoundPolicy::Async { buffer_k: works.len(), max_staleness: 8 };
        let a = engine.simulate_round(0, 2.0, &works, policy, usize::MAX, &mut Rng::new(5));
        assert_eq!(a.completers, sync.completers);
        assert_eq!(a.stragglers, sync.stragglers);
        assert_eq!(a.dropouts, sync.dropouts);
        assert_eq!(a.events, sync.events, "event traces diverged");
        assert_eq!(a.end_s.to_bits(), sync.end_s.to_bits(), "sim time diverged");
        assert!(a.late_arrivals.is_empty() && a.deferred.is_empty());
        assert!(engine.inflight().is_empty());
    }

    #[test]
    fn async_defers_slow_uploads_and_merges_them_later() {
        let mut engine = FleetEngine::new();
        let policy = RoundPolicy::Async { buffer_k: 1, max_staleness: 8 };
        let works = vec![
            work(0, 0.0, 1.0, 2.0, 1.0, 0.0),   // arrives at t=4
            work(1, 0.0, 1.0, 50.0, 9.0, 0.0),  // arrives at t=60
        ];
        let r0 = engine.simulate_round(0, 0.0, &works, policy, usize::MAX, &mut Rng::new(1));
        assert_eq!(r0.completers, vec![0], "buffer_k=1 closes at the first arrival");
        assert!((r0.end_s - 4.0).abs() < 1e-9);
        assert_eq!(r0.deferred, vec![1], "slow upload is deferred, not discarded");
        assert!(r0.stragglers.is_empty(), "async discards nobody reachable");
        assert_eq!(engine.inflight().len(), 1);
        assert_eq!(engine.inflight()[0].client, 1);
        assert_eq!(engine.inflight()[0].dispatch_round, 0);
        assert!((engine.inflight()[0].arrive_s - 60.0).abs() < 1e-9);

        // Next round: a fast fresh client plus the in-flight upload. The
        // late upload (t=60) lands after the fresh arrival (t=14) but the
        // round needs 2 arrivals, so it closes at the late one.
        let works2 = vec![work(2, 10.0, 1.0, 2.0, 1.0, 0.0)];
        let policy2 = RoundPolicy::Async { buffer_k: 2, max_staleness: 8 };
        let r1 = engine.simulate_round(1, r0.end_s, &works2, policy2, usize::MAX, &mut Rng::new(2));
        assert_eq!(r1.completers, vec![2]);
        assert_eq!(r1.late_arrivals.len(), 1);
        assert_eq!(r1.late_arrivals[0].client, 1);
        assert_eq!(r1.late_arrivals[0].dispatch_round, 0);
        assert!((r1.end_s - 60.0).abs() < 1e-9, "round closes at the 2nd arrival");
        assert!(engine.inflight().is_empty(), "merged upload leaves the queue");
    }

    #[test]
    fn async_inflight_survives_rounds_that_close_before_it_lands() {
        let mut engine = FleetEngine::new();
        let policy = RoundPolicy::Async { buffer_k: 1, max_staleness: 8 };
        let slow = vec![work(0, 0.0, 1.0, 200.0, 9.0, 0.0), work(1, 0.0, 0.5, 1.0, 0.5, 0.0)];
        let r0 = engine.simulate_round(0, 0.0, &slow, policy, usize::MAX, &mut Rng::new(1));
        assert_eq!(r0.deferred, vec![0]);
        // Round 1 closes on its own fresh arrival long before t=210.
        let fast = vec![work(2, 0.0, 0.5, 1.0, 0.5, 0.0)];
        let r1 = engine.simulate_round(1, r0.end_s, &fast, policy, usize::MAX, &mut Rng::new(2));
        assert_eq!(r1.completers, vec![2]);
        assert!(r1.late_arrivals.is_empty(), "upload still in flight");
        assert_eq!(engine.inflight().len(), 1, "carries across multiple rounds");
        // Round 2 has no fresh cohort: the only possible arrival is the
        // in-flight upload, so the round closes when it lands.
        let r2 = engine.simulate_round(2, r1.end_s, &[], policy, usize::MAX, &mut Rng::new(3));
        assert_eq!(r2.late_arrivals.len(), 1);
        assert_eq!(r2.late_arrivals[0].dispatch_round, 0, "staleness spans two rounds");
        assert!(engine.inflight().is_empty());
    }

    #[test]
    fn async_redispatch_supersedes_stale_inflight_upload() {
        let mut engine = FleetEngine::new();
        let policy = RoundPolicy::Async { buffer_k: 1, max_staleness: 8 };
        let works = vec![work(0, 0.0, 1.0, 100.0, 1.0, 0.0), work(1, 0.0, 0.5, 1.0, 0.5, 0.0)];
        let r0 = engine.simulate_round(0, 0.0, &works, policy, usize::MAX, &mut Rng::new(1));
        assert_eq!(r0.deferred, vec![0]);
        // Client 0 is sampled again: its old upload is abandoned, and the
        // fresh dispatch re-enters the round normally.
        let works2 = vec![work(0, 0.0, 0.5, 1.0, 0.5, 0.0)];
        let r1 = engine.simulate_round(1, r0.end_s, &works2, policy, usize::MAX, &mut Rng::new(2));
        assert!(r1.late_arrivals.is_empty(), "stale upload must not merge");
        assert_eq!(r1.completers, vec![0], "fresh dispatch completes normally");
        assert!(engine.inflight().is_empty());
    }

    /// Build a realistic cohort plan end-to-end from a seeded pool
    /// (profiles sampled with the `Rng` fork discipline) — the fleet
    /// determinism contract: same seed + config ⇒ identical event order,
    /// sim time, and straggler/dropout counts.
    fn pool_works(seed: u64) -> Vec<ClientWork> {
        let data = SyntheticDataset::new(10, seed);
        let fleet = FleetProfileConfig::named("mobile").unwrap();
        let pool = ClientPool::build(
            30,
            3_000,
            &data,
            Partition::Iid,
            MemoryConfig::default(),
            &fleet,
            seed,
        );
        let mem = MemCoeffs {
            fixed_bytes: 0,
            per_sample_bytes: 0,
            params_total: 11_000_000,
            params_trainable: 11_000_000,
        };
        let bytes = 44_000_000u64;
        (0..10)
            .map(|cid| {
                let p = &pool.clients[cid].profile;
                ClientWork {
                    id: cid,
                    ready_s: p.trace.next_online(0.0),
                    down_s: p.down_time_s(bytes),
                    train_s: p.train_time_s(pool.clients[cid].shard.num_samples(), &mem),
                    up_s: p.up_time_s(bytes),
                    dropout_p: p.dropout_p,
                }
            })
            .collect()
    }

    fn plan_from_pool(seed: u64, policy: RoundPolicy) -> RoundPlan {
        let works = pool_works(seed);
        let mut engine = FleetEngine::new();
        engine.simulate_round(0, 0.0, &works, policy, usize::MAX, &mut Rng::new(seed ^ 0xf1ee))
    }

    #[test]
    fn same_seed_same_plan_bit_for_bit() {
        for policy in [
            RoundPolicy::Sync,
            RoundPolicy::Deadline { secs: 300.0 },
            RoundPolicy::Async { buffer_k: 4, max_staleness: 8 },
        ] {
            let a = plan_from_pool(9, policy);
            let b = plan_from_pool(9, policy);
            assert_eq!(a.events, b.events, "event order diverged");
            assert_eq!(a.end_s.to_bits(), b.end_s.to_bits(), "sim time diverged");
            assert_eq!(a.completers, b.completers);
            assert_eq!(a.stragglers, b.stragglers);
            assert_eq!(a.dropouts, b.dropouts);
            assert_eq!(a.deferred, b.deferred);
            assert_eq!(a.late_arrivals, b.late_arrivals);
        }
    }

    #[test]
    fn seeds_actually_change_the_plan() {
        let a = plan_from_pool(9, RoundPolicy::Sync);
        let b = plan_from_pool(10, RoundPolicy::Sync);
        assert_ne!(a.end_s.to_bits(), b.end_s.to_bits());
    }

    #[test]
    fn mobile_deadline_produces_stragglers() {
        // 60s is below the mobile slow tier's minimum possible round
        // (download > 5.5s, train > 44s, upload > 22s at 11 Mparams /
        // 100 samples / 44MB), so any slow-tier or offline client in the
        // cohort must straggle.
        let plan = plan_from_pool(9, RoundPolicy::Deadline { secs: 60.0 });
        assert!(!plan.stragglers.is_empty(), "60s deadline on mobile should straggle");
        let sync = plan_from_pool(9, RoundPolicy::Sync);
        assert!(sync.stragglers.is_empty());
        assert!(sync.end_s > plan.end_s, "sync waits longer than the deadline cut");
    }

    #[test]
    fn mobile_async_defers_what_deadline_would_cut() {
        // Where the deadline policy cuts stragglers, the async policy
        // keeps their uploads in flight and merges them in later rounds —
        // the fleet-level half of the ISSUE acceptance criterion.
        let deadline = plan_from_pool(9, RoundPolicy::Deadline { secs: 60.0 });
        assert!(!deadline.stragglers.is_empty());

        let works = pool_works(9);
        let mut engine = FleetEngine::new();
        let policy = RoundPolicy::Async { buffer_k: 4, max_staleness: 8 };
        let mut rng = Rng::new(9 ^ 0xf1ee);
        let r0 = engine.simulate_round(0, 0.0, &works, policy, usize::MAX, &mut rng);
        assert!(!r0.deferred.is_empty(), "slow mobile uploads must miss a k=4 window");
        assert!(r0.stragglers.is_empty(), "async discards nobody reachable");

        // Drain subsequent no-cohort rounds: every deferred upload must
        // eventually merge as a late arrival (none are discarded).
        let mut merged = 0usize;
        let mut start = r0.end_s;
        for round in 1..20 {
            if engine.inflight().is_empty() {
                break;
            }
            let r = engine.simulate_round(round, start, &[], policy, usize::MAX, &mut rng);
            merged += r.late_arrivals.len();
            start = r.end_s;
        }
        assert_eq!(merged, r0.deferred.len(), "every straggler upload merges eventually");
    }
}
