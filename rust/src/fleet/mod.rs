//! Fleet simulator (L3): deterministic discrete-event engine for
//! heterogeneous-device round dynamics.
//!
//! The seed coordinator modelled the fleet as a memoryless synchronous
//! loop — every sampled client trained "instantly", so the system could
//! say nothing about wall-clock time-to-accuracy, stragglers, or
//! dropout. This module adds the missing dimension: every client carries
//! a [`DeviceProfile`] (compute throughput, link speeds, availability
//! trace, dropout probability), a train round dispatches its cohort as
//! events on a virtual clock, and a [`RoundPolicy`] decides who makes it
//! into the aggregate:
//!
//! * [`RoundPolicy::Sync`] — wait for every dispatched client; round
//!   time is the slowest participant's finish time.
//! * [`RoundPolicy::Deadline`] — aggregate whatever has arrived when the
//!   deadline fires; the rest are counted as stragglers.
//! * [`RoundPolicy::OverSelect`] — sample `per_round + extra` clients
//!   and keep the first `per_round` finishers (FedScale-style
//!   over-commitment).
//!
//! Everything is seeded: same config + seed ⇒ identical event order,
//! `sim_time_s`, and straggler/dropout counts, bit for bit.

pub mod event;
pub mod profile;
pub mod trace;

pub use event::{Event, EventKind, EventQueue, VirtualClock};
pub use profile::{DeviceProfile, DeviceTier, FleetProfileConfig, TierSpec};
pub use trace::AvailabilityTrace;

use crate::rng::Rng;
use anyhow::{bail, Result};
use std::collections::HashMap;

/// How a train round decides when to aggregate.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum RoundPolicy {
    /// Wait for every dispatched client (classic synchronous FedAvg).
    Sync,
    /// Aggregate at `start + secs`; unfinished clients become stragglers.
    Deadline { secs: f64 },
    /// Sample `extra` clients beyond `per_round`, keep the first
    /// `per_round` finishers, count the rest as stragglers.
    OverSelect { extra: usize },
}

impl RoundPolicy {
    /// Parse a CLI/config spelling. Accepts `sync`, `deadline`,
    /// `deadline:SECS`, `over-select`, `over-select:K`; the bare forms
    /// take `default_deadline_s` / `default_extra`.
    pub fn parse(s: &str, default_deadline_s: f64, default_extra: usize) -> Result<Self> {
        let (head, arg) = match s.split_once(':') {
            Some((h, a)) => (h, Some(a)),
            None => (s, None),
        };
        match head {
            "sync" => Ok(RoundPolicy::Sync),
            "deadline" => {
                let secs: f64 = match arg {
                    Some(a) => a.parse().map_err(|e| anyhow::anyhow!("bad deadline `{a}`: {e}"))?,
                    None => default_deadline_s,
                };
                if !secs.is_finite() || secs < 0.0 {
                    bail!("deadline must be a finite non-negative number of seconds, got {secs}");
                }
                Ok(RoundPolicy::Deadline { secs })
            }
            "over-select" | "overselect" => {
                let extra = match arg {
                    Some(a) => a.parse().map_err(|e| anyhow::anyhow!("bad over-select `{a}`: {e}"))?,
                    None => default_extra,
                };
                Ok(RoundPolicy::OverSelect { extra })
            }
            other => bail!("unknown round policy `{other}` (sync|deadline[:S]|over-select[:K])"),
        }
    }
}

/// One cohort member's precomputed timing for a round: when it can be
/// dispatched and how long each leg takes. Built by
/// `ServerCtx::client_work` from the client's [`DeviceProfile`], shard
/// size, and the round artifact's byte/FLOP footprint.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct ClientWork {
    pub id: usize,
    /// Earliest dispatch time (availability-gated), absolute seconds.
    pub ready_s: f64,
    /// Sub-model download time.
    pub down_s: f64,
    /// Local training time.
    pub train_s: f64,
    /// Update upload time.
    pub up_s: f64,
    /// Probability the client vanishes after dispatch this round.
    pub dropout_p: f64,
}

/// What the simulator decided for one round.
#[derive(Debug, Clone, PartialEq)]
pub struct RoundPlan {
    /// Clients whose updates are aggregated, in upload-arrival order.
    /// (The coordinator re-sorts these into selection order before
    /// FedAvg so float accumulation stays reproducible across policies.)
    pub completers: Vec<usize>,
    /// Dispatched-or-selected clients cut by the round policy.
    pub stragglers: Vec<usize>,
    /// Clients that dropped out after dispatch.
    pub dropouts: Vec<usize>,
    pub start_s: f64,
    /// Virtual time at which the server aggregates.
    pub end_s: f64,
    /// Processed events in execution order (determinism witnesses).
    pub events: Vec<Event>,
}

impl RoundPlan {
    pub fn duration_s(&self) -> f64 {
        self.end_s - self.start_s
    }
}

/// Run one round's cohort through the event loop. `keep` caps how many
/// finishers are aggregated (`usize::MAX` for sync/deadline;
/// `per_round` for over-select). Dropout draws happen in event order
/// from `rng`, so the whole plan is a pure function of its arguments.
pub fn simulate_round(
    start_s: f64,
    works: &[ClientWork],
    policy: RoundPolicy,
    keep: usize,
    rng: &mut Rng,
) -> RoundPlan {
    // An empty cohort is a no-op round: nothing to dispatch, so no
    // deadline wait either (the server has nobody to wait for).
    if works.is_empty() {
        return RoundPlan {
            completers: Vec::new(),
            stragglers: Vec::new(),
            dropouts: Vec::new(),
            start_s,
            end_s: start_s,
            events: Vec::new(),
        };
    }
    let by_id: HashMap<usize, &ClientWork> = works.iter().map(|w| (w.id, w)).collect();
    let mut q = EventQueue::new();
    // Clients still owing an upload; the loop may stop early once none remain.
    let mut outstanding = 0usize;
    for w in works {
        // A non-finite ready time (zero-duty availability trace) means the
        // client can never be dispatched: it falls through to the straggler
        // set below instead of poisoning the clock with an INF event.
        if w.ready_s.is_finite() {
            q.push(start_s.max(w.ready_s), EventKind::Dispatch { client: w.id });
            outstanding += 1;
        }
    }
    if outstanding > 0 {
        if let RoundPolicy::Deadline { secs } = policy {
            q.push(start_s + secs, EventKind::Deadline);
        }
    }

    let mut clock = VirtualClock::new(start_s);
    let mut events = Vec::new();
    let mut completers = Vec::new();
    let mut dropouts = Vec::new();
    let mut end_s = start_s;

    while let Some(ev) = q.pop() {
        clock.advance_to(ev.time_s);
        match ev.kind {
            EventKind::Dispatch { client } => {
                events.push(ev);
                let w = by_id[&client];
                if rng.f64() < w.dropout_p {
                    dropouts.push(client);
                    outstanding -= 1;
                } else {
                    q.push(ev.time_s + w.down_s + w.train_s, EventKind::TrainDone { client });
                }
            }
            EventKind::TrainDone { client } => {
                events.push(ev);
                q.push(ev.time_s + by_id[&client].up_s, EventKind::UploadDone { client });
            }
            EventKind::UploadDone { client } => {
                events.push(ev);
                completers.push(client);
                outstanding -= 1;
                end_s = clock.now_s();
                if completers.len() >= keep {
                    break; // over-select: cohort is full
                }
            }
            EventKind::Deadline => {
                events.push(ev);
                end_s = clock.now_s();
                break; // everyone still in flight is a straggler
            }
        }
        if outstanding == 0 {
            break; // all uploads in (or dropped) — don't wait for a deadline
        }
    }

    let stragglers: Vec<usize> = works
        .iter()
        .map(|w| w.id)
        .filter(|id| !completers.contains(id) && !dropouts.contains(id))
        .collect();
    RoundPlan { completers, stragglers, dropouts, start_s, end_s, events }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::clients::ClientPool;
    use crate::data::{Partition, SyntheticDataset};
    use crate::manifest::MemCoeffs;
    use crate::memory::MemoryConfig;

    fn work(id: usize, ready: f64, down: f64, train: f64, up: f64, drop_p: f64) -> ClientWork {
        ClientWork { id, ready_s: ready, down_s: down, train_s: train, up_s: up, dropout_p: drop_p }
    }

    #[test]
    fn sync_waits_for_slowest() {
        let works =
            vec![work(0, 0.0, 1.0, 5.0, 1.0, 0.0), work(1, 0.0, 2.0, 80.0, 3.0, 0.0)];
        let plan =
            simulate_round(10.0, &works, RoundPolicy::Sync, usize::MAX, &mut Rng::new(1));
        assert_eq!(plan.completers, vec![0, 1]);
        assert!(plan.stragglers.is_empty() && plan.dropouts.is_empty());
        // sim time = slowest participant's finish: 10 + 2 + 80 + 3.
        assert!((plan.end_s - 95.0).abs() < 1e-9);
        assert!((plan.duration_s() - 85.0).abs() < 1e-9);
    }

    #[test]
    fn deadline_cuts_slow_clients_as_stragglers() {
        let works =
            vec![work(0, 0.0, 1.0, 5.0, 1.0, 0.0), work(1, 0.0, 2.0, 80.0, 3.0, 0.0)];
        let plan = simulate_round(
            0.0,
            &works,
            RoundPolicy::Deadline { secs: 20.0 },
            usize::MAX,
            &mut Rng::new(1),
        );
        assert_eq!(plan.completers, vec![0]);
        assert_eq!(plan.stragglers, vec![1]);
        assert!((plan.end_s - 20.0).abs() < 1e-9, "round ends at the deadline");
    }

    #[test]
    fn deadline_ends_early_when_everyone_finishes() {
        let works = vec![work(0, 0.0, 1.0, 2.0, 1.0, 0.0)];
        let plan = simulate_round(
            0.0,
            &works,
            RoundPolicy::Deadline { secs: 100.0 },
            usize::MAX,
            &mut Rng::new(1),
        );
        assert_eq!(plan.completers, vec![0]);
        assert!((plan.end_s - 4.0).abs() < 1e-9, "no idle wait until the deadline");
    }

    #[test]
    fn over_select_keeps_first_finishers() {
        let works = vec![
            work(0, 0.0, 0.0, 30.0, 0.0, 0.0),
            work(1, 0.0, 0.0, 10.0, 0.0, 0.0),
            work(2, 0.0, 0.0, 20.0, 0.0, 0.0),
        ];
        let plan = simulate_round(
            0.0,
            &works,
            RoundPolicy::OverSelect { extra: 1 },
            2,
            &mut Rng::new(1),
        );
        assert_eq!(plan.completers, vec![1, 2], "fastest two win");
        assert_eq!(plan.stragglers, vec![0]);
        assert!((plan.end_s - 20.0).abs() < 1e-9);
    }

    #[test]
    fn certain_dropout_is_counted_not_straggled() {
        let works = vec![work(0, 0.0, 1.0, 1.0, 1.0, 1.0), work(1, 0.0, 1.0, 1.0, 1.0, 0.0)];
        let plan =
            simulate_round(0.0, &works, RoundPolicy::Sync, usize::MAX, &mut Rng::new(3));
        assert_eq!(plan.dropouts, vec![0]);
        assert_eq!(plan.completers, vec![1]);
        assert!(plan.stragglers.is_empty());
    }

    #[test]
    fn availability_delays_dispatch() {
        // Client 0 only becomes reachable at t=50.
        let works = vec![work(0, 50.0, 1.0, 2.0, 1.0, 0.0)];
        let plan =
            simulate_round(0.0, &works, RoundPolicy::Sync, usize::MAX, &mut Rng::new(1));
        assert_eq!(plan.events[0].time_s, 50.0);
        assert!((plan.end_s - 54.0).abs() < 1e-9);
    }

    #[test]
    fn empty_cohort_is_a_noop_round() {
        // Under every policy — in particular, an empty deadline round must
        // not burn deadline_s of virtual time waiting for nobody.
        for policy in
            [RoundPolicy::Sync, RoundPolicy::Deadline { secs: 60.0 }, RoundPolicy::OverSelect { extra: 2 }]
        {
            let plan = simulate_round(7.0, &[], policy, usize::MAX, &mut Rng::new(1));
            assert!(plan.completers.is_empty() && plan.events.is_empty());
            assert_eq!(plan.end_s, 7.0, "{policy:?}");
        }
    }

    #[test]
    fn unreachable_client_is_a_straggler_not_a_completer() {
        // Zero-duty trace ⇒ ready_s = INFINITY: the client must not be
        // dispatched (sync would otherwise wait forever / poison the clock).
        let works = vec![
            work(0, f64::INFINITY, 1.0, 2.0, 1.0, 0.0),
            work(1, 0.0, 1.0, 2.0, 1.0, 0.0),
        ];
        for policy in [RoundPolicy::Sync, RoundPolicy::Deadline { secs: 100.0 }] {
            let plan = simulate_round(0.0, &works, policy, usize::MAX, &mut Rng::new(1));
            assert_eq!(plan.completers, vec![1], "{policy:?}");
            assert_eq!(plan.stragglers, vec![0], "{policy:?}");
            assert!(plan.end_s.is_finite() && (plan.end_s - 4.0).abs() < 1e-9, "{policy:?}");
        }
    }

    #[test]
    fn policy_parsing() {
        assert_eq!(RoundPolicy::parse("sync", 60.0, 4).unwrap(), RoundPolicy::Sync);
        assert_eq!(
            RoundPolicy::parse("deadline", 60.0, 4).unwrap(),
            RoundPolicy::Deadline { secs: 60.0 }
        );
        assert_eq!(
            RoundPolicy::parse("deadline:12.5", 60.0, 4).unwrap(),
            RoundPolicy::Deadline { secs: 12.5 }
        );
        assert_eq!(
            RoundPolicy::parse("over-select", 60.0, 4).unwrap(),
            RoundPolicy::OverSelect { extra: 4 }
        );
        assert_eq!(
            RoundPolicy::parse("over-select:9", 60.0, 4).unwrap(),
            RoundPolicy::OverSelect { extra: 9 }
        );
        assert!(RoundPolicy::parse("async", 60.0, 4).is_err());
        assert!(RoundPolicy::parse("deadline:abc", 60.0, 4).is_err());
        assert!(RoundPolicy::parse("deadline:-5", 60.0, 4).is_err(), "negative deadline");
        assert!(RoundPolicy::parse("deadline:NaN", 60.0, 4).is_err(), "non-finite deadline");
    }

    /// Build a realistic cohort plan end-to-end from a seeded pool
    /// (profiles sampled with the `Rng` fork discipline) — the fleet
    /// determinism contract: same seed + config ⇒ identical event order,
    /// sim time, and straggler/dropout counts.
    fn plan_from_pool(seed: u64, policy: RoundPolicy) -> RoundPlan {
        let data = SyntheticDataset::new(10, seed);
        let fleet = FleetProfileConfig::named("mobile").unwrap();
        let pool = ClientPool::build(
            30,
            3_000,
            &data,
            Partition::Iid,
            MemoryConfig::default(),
            &fleet,
            seed,
        );
        let mem = MemCoeffs {
            fixed_bytes: 0,
            per_sample_bytes: 0,
            params_total: 11_000_000,
            params_trainable: 11_000_000,
        };
        let bytes = 44_000_000u64;
        let works: Vec<ClientWork> = (0..10)
            .map(|cid| {
                let p = &pool.clients[cid].profile;
                ClientWork {
                    id: cid,
                    ready_s: p.trace.next_online(0.0),
                    down_s: p.down_time_s(bytes),
                    train_s: p.train_time_s(pool.clients[cid].shard.num_samples(), &mem),
                    up_s: p.up_time_s(bytes),
                    dropout_p: p.dropout_p,
                }
            })
            .collect();
        simulate_round(0.0, &works, policy, usize::MAX, &mut Rng::new(seed ^ 0xf1ee))
    }

    #[test]
    fn same_seed_same_plan_bit_for_bit() {
        for policy in [RoundPolicy::Sync, RoundPolicy::Deadline { secs: 300.0 }] {
            let a = plan_from_pool(9, policy);
            let b = plan_from_pool(9, policy);
            assert_eq!(a.events, b.events, "event order diverged");
            assert_eq!(a.end_s.to_bits(), b.end_s.to_bits(), "sim time diverged");
            assert_eq!(a.completers, b.completers);
            assert_eq!(a.stragglers, b.stragglers);
            assert_eq!(a.dropouts, b.dropouts);
        }
    }

    #[test]
    fn seeds_actually_change_the_plan() {
        let a = plan_from_pool(9, RoundPolicy::Sync);
        let b = plan_from_pool(10, RoundPolicy::Sync);
        assert_ne!(a.end_s.to_bits(), b.end_s.to_bits());
    }

    #[test]
    fn mobile_deadline_produces_stragglers() {
        // 60s is below the mobile slow tier's minimum possible round
        // (download > 5.5s, train > 44s, upload > 22s at 11 Mparams /
        // 100 samples / 44MB), so any slow-tier or offline client in the
        // cohort must straggle.
        let plan = plan_from_pool(9, RoundPolicy::Deadline { secs: 60.0 });
        assert!(!plan.stragglers.is_empty(), "60s deadline on mobile should straggle");
        let sync = plan_from_pool(9, RoundPolicy::Sync);
        assert!(sync.stragglers.is_empty());
        assert!(sync.end_s > plan.end_s, "sync waits longer than the deadline cut");
    }
}
