//! Checkpoint/resume for long simulations (`docs/CHECKPOINT.md`).
//!
//! A checkpoint is the *complete* run state at a round boundary —
//! coordinator clock/counters, pending async buffers, transition log,
//! per-round record history, freeze-detector EM state, strategy cursor,
//! fleet in-flight queue, every client-pool rng/cursor residue, and the
//! parameter store — serialized into one versioned, self-describing
//! file. Because every stochastic decision in the simulator flows from
//! seeded SplitMix64 streams (see [`crate::rng`]), restoring those
//! streams' positions makes the resumed run **bit-identical** to the
//! uninterrupted one: same `RoundRecord` history, same CSV, same
//! manifest `history_sha256`, same telemetry counter values, at any
//! thread count.
//!
//! # File format (version 1)
//!
//! All integers are little-endian fixed width; floats are IEEE-754 bit
//! patterns; strings and sequences carry `u64` length prefixes that are
//! validated against the remaining input *before* any allocation.
//!
//! ```text
//! header:  magic "PROFLCKP" (8 bytes)
//!          format_version   u32
//!          crate_version    string   (rejected on skew, naming both)
//!          config_sha256    string   (manifest-style config fingerprint)
//!          payload_sha256   string   (state digest over the payload)
//!          payload_len      u64      (must equal the remaining bytes)
//! payload: the serialized state (see `Checkpoint::encode_payload`)
//! ```
//!
//! [`Checkpoint::decode`] verifies the magic, format version, crate
//! version, payload length, and state digest before touching the payload,
//! and every parse path returns a clean `Err` on truncated, bit-flipped,
//! length-corrupted, or hostile-string input — never a panic, never an
//! unbounded allocation (adversarially tested in
//! `rust/tests/fuzz_inputs.rs`).

use crate::clients::{ClientCkpt, LazyCkpt, PoolCkptKind, PoolCkptState};
use crate::config::RunConfig;
use crate::coordinator::{PendingUpdate, ServerCtx};
use crate::fleet::InFlightUpload;
use crate::freezing::{DetectorSnapshot, Transition, TransitionLog};
use crate::metrics::RoundRecord;
use crate::rng::Rng;
use crate::store::Tensor;
use crate::strategy::{DistillPhase, MemoryStrategy, TrainPhase};
use crate::telemetry::{config_sha256, config_value, sha256_hex};
use anyhow::{bail, ensure, Context, Result};
use std::path::Path;
use std::sync::Arc;

/// The 8-byte file magic.
pub const MAGIC: [u8; 8] = *b"PROFLCKP";

/// The checkpoint format version this crate reads and writes.
pub const FORMAT_VERSION: u32 = 1;

// ---- primitive encoder -------------------------------------------------

/// Little-endian binary encoder for the checkpoint format. Public so the
/// strategy state blobs and the test corpus builders share one encoding
/// vocabulary with the checkpoint writer.
#[derive(Debug, Default)]
pub struct Enc {
    buf: Vec<u8>,
}

impl Enc {
    /// An empty encoder.
    pub fn new() -> Self {
        Enc::default()
    }

    /// Append one byte.
    pub fn u8(&mut self, v: u8) {
        self.buf.push(v);
    }

    /// Append a `u32`, little-endian.
    pub fn u32(&mut self, v: u32) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }

    /// Append a `u64`, little-endian.
    pub fn u64(&mut self, v: u64) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }

    /// Append a `usize` as `u64`.
    pub fn usize(&mut self, v: usize) {
        self.u64(v as u64);
    }

    /// Append an `f32` as its IEEE-754 bit pattern.
    pub fn f32(&mut self, v: f32) {
        self.u32(v.to_bits());
    }

    /// Append an `f64` as its IEEE-754 bit pattern.
    pub fn f64(&mut self, v: f64) {
        self.u64(v.to_bits());
    }

    /// Append a bool as one strict byte (0/1).
    pub fn bool(&mut self, v: bool) {
        self.u8(v as u8);
    }

    /// Append a length-prefixed UTF-8 string.
    pub fn str(&mut self, s: &str) {
        self.u64(s.len() as u64);
        self.buf.extend_from_slice(s.as_bytes());
    }

    /// Append a length-prefixed raw byte blob.
    pub fn bytes(&mut self, b: &[u8]) {
        self.u64(b.len() as u64);
        self.buf.extend_from_slice(b);
    }

    /// Append a length-prefixed `f32` slice (bit patterns).
    pub fn f32s(&mut self, v: &[f32]) {
        self.u64(v.len() as u64);
        for x in v {
            self.f32(*x);
        }
    }

    /// Append a length-prefixed `f64` slice (bit patterns).
    pub fn f64s(&mut self, v: &[f64]) {
        self.u64(v.len() as u64);
        for x in v {
            self.f64(*x);
        }
    }

    /// The encoded bytes.
    pub fn finish(self) -> Vec<u8> {
        self.buf
    }
}

// ---- primitive decoder -------------------------------------------------

/// Strict decoder over untrusted checkpoint bytes. Every length prefix is
/// validated against the remaining input before any allocation, so a
/// corrupted prefix produces a clean `Err` instead of an OOM; every
/// accessor errors (never panics) on truncation.
#[derive(Debug)]
pub struct Dec<'a> {
    buf: &'a [u8],
    pos: usize,
}

impl<'a> Dec<'a> {
    /// A decoder over `buf`, positioned at the start.
    pub fn new(buf: &'a [u8]) -> Self {
        Dec { buf, pos: 0 }
    }

    /// Bytes not yet consumed.
    pub fn remaining(&self) -> usize {
        self.buf.len() - self.pos
    }

    fn take(&mut self, n: usize) -> Result<&'a [u8]> {
        ensure!(n <= self.remaining(), "truncated: need {n} bytes, have {}", self.remaining());
        let out = &self.buf[self.pos..self.pos + n];
        self.pos += n;
        Ok(out)
    }

    /// Read one byte.
    pub fn u8(&mut self) -> Result<u8> {
        Ok(self.take(1)?[0])
    }

    /// Read a little-endian `u32`.
    pub fn u32(&mut self) -> Result<u32> {
        Ok(u32::from_le_bytes(self.take(4)?.try_into().expect("4 bytes")))
    }

    /// Read a little-endian `u64`.
    pub fn u64(&mut self) -> Result<u64> {
        Ok(u64::from_le_bytes(self.take(8)?.try_into().expect("8 bytes")))
    }

    /// Read a `u64` and convert to `usize`.
    pub fn usize(&mut self) -> Result<usize> {
        usize::try_from(self.u64()?).context("value exceeds usize")
    }

    /// Read an `f32` bit pattern.
    pub fn f32(&mut self) -> Result<f32> {
        Ok(f32::from_bits(self.u32()?))
    }

    /// Read an `f64` bit pattern.
    pub fn f64(&mut self) -> Result<f64> {
        Ok(f64::from_bits(self.u64()?))
    }

    /// Read a strict bool byte (only 0/1 accepted).
    pub fn bool(&mut self) -> Result<bool> {
        match self.u8()? {
            0 => Ok(false),
            1 => Ok(true),
            b => bail!("invalid bool byte {b}"),
        }
    }

    /// Read a sequence length prefix for elements of at least
    /// `min_elem_bytes` encoded bytes each, rejecting any count the
    /// remaining input cannot possibly hold — the no-OOM guarantee.
    pub fn seq_len(&mut self, min_elem_bytes: usize) -> Result<usize> {
        let n = self.usize()?;
        let need = n.checked_mul(min_elem_bytes.max(1)).context("length prefix overflows")?;
        ensure!(
            need <= self.remaining(),
            "length prefix {n} needs ≥ {need} bytes, only {} remain",
            self.remaining()
        );
        Ok(n)
    }

    /// Read a length-prefixed UTF-8 string (validated length, validated
    /// UTF-8).
    pub fn str(&mut self) -> Result<String> {
        let n = self.seq_len(1)?;
        let raw = self.take(n)?;
        String::from_utf8(raw.to_vec()).context("invalid UTF-8 in string")
    }

    /// Read a length-prefixed raw byte blob.
    pub fn bytes(&mut self) -> Result<Vec<u8>> {
        let n = self.seq_len(1)?;
        Ok(self.take(n)?.to_vec())
    }

    /// Read a length-prefixed `f32` slice.
    pub fn f32s(&mut self) -> Result<Vec<f32>> {
        let n = self.seq_len(4)?;
        let mut out = Vec::with_capacity(n);
        for _ in 0..n {
            out.push(self.f32()?);
        }
        Ok(out)
    }

    /// Read a length-prefixed `f64` slice.
    pub fn f64s(&mut self) -> Result<Vec<f64>> {
        let n = self.seq_len(8)?;
        let mut out = Vec::with_capacity(n);
        for _ in 0..n {
            out.push(self.f64()?);
        }
        Ok(out)
    }

    /// Error unless every byte was consumed (rejects trailing garbage).
    pub fn done(&self) -> Result<()> {
        ensure!(self.remaining() == 0, "{} trailing bytes after payload", self.remaining());
        Ok(())
    }
}

// ---- mid-phase state ---------------------------------------------------

/// Where inside a strategy phase the checkpoint was taken. Strategy state
/// (`MemoryStrategy::save_state`) only changes *between* phases; this
/// carries the within-phase remainder: the phase being executed, how many
/// of its rounds ran, and (train phases) the freeze detector's state.
#[derive(Debug, Clone)]
pub enum MidPhase {
    /// Mid train-phase.
    Train {
        /// The phase the strategy emitted.
        phase: TrainPhase,
        /// Freeze-detector state after `used` rounds.
        detector: DetectorSnapshot,
        /// Rounds of this phase already executed.
        used: usize,
        /// Whether the EM gate already fired (the phase is complete).
        froze: bool,
    },
    /// Mid distill-phase.
    Distill {
        /// The phase the strategy emitted.
        phase: DistillPhase,
        /// Rounds of this phase already executed.
        used: usize,
    },
}

// ---- the checkpoint value ----------------------------------------------

/// A complete run snapshot at a round boundary. Plain data: every field
/// is open, so tests can build, inspect, and perturb checkpoints
/// directly. [`Self::encode`]/[`Self::decode`] are exact inverses, and
/// encode∘decode∘encode is byte-idempotent (sequences are gathered in
/// deterministic order, floats travel as bit patterns).
#[derive(Clone)]
pub struct Checkpoint {
    /// Writing crate's version — readers reject skew.
    pub crate_version: String,
    /// Manifest-style fingerprint of the resolved config.
    pub config_sha256: String,
    /// Canonical JSON of the resolved config ([`config_value`]), from
    /// which `profl resume` reconstructs the [`RunConfig`].
    pub config_json: String,
    /// Rounds completed (the server's next round index).
    pub round: usize,
    /// Virtual fleet clock, seconds.
    pub sim_time_s: f64,
    /// Current frozen-prefix version.
    pub prefix_version: u64,
    /// The full transition log, oldest first.
    pub transitions: Vec<Transition>,
    /// Fleet rng stream state ([`Rng::state`]).
    pub fleet_rng: u64,
    /// Span-planner worker count at capture (informational: a resume may
    /// override it — results are bit-identical at any thread count).
    pub threads: usize,
    /// Cross-round in-flight uploads, in engine order.
    pub inflight: Vec<InFlightUpload>,
    /// Buffered pending updates, sorted by client id.
    pub pending: Vec<PendingUpdate>,
    /// Every parameter tensor: `(name, shape, data)`, name-sorted.
    pub params: Vec<(String, Vec<usize>, Vec<f32>)>,
    /// Client-pool residues + selection rng.
    pub pool: PoolCkptState,
    /// Per-round record history, oldest first.
    pub records: Vec<RoundRecord>,
    /// Display name of the driving strategy (`MemoryStrategy::name`).
    pub strategy_name: String,
    /// The strategy's opaque state blob (`MemoryStrategy::save_state`).
    pub strategy_blob: Vec<u8>,
    /// Within-phase position, when the checkpoint was taken mid-phase
    /// (always `Some` for run-level checkpoints; component-level tests
    /// may leave it `None`).
    pub mid: Option<MidPhase>,
}

impl Checkpoint {
    // ---- encode --------------------------------------------------------

    fn encode_payload(&self) -> Vec<u8> {
        let mut e = Enc::new();
        e.str(&self.config_json);
        e.usize(self.round);
        e.f64(self.sim_time_s);
        e.u64(self.prefix_version);
        e.usize(self.transitions.len());
        for t in &self.transitions {
            e.u64(t.version);
            e.usize(t.round);
            e.f64(t.sim_time_s);
        }
        e.u64(self.fleet_rng);
        e.usize(self.threads);
        e.usize(self.inflight.len());
        for u in &self.inflight {
            e.usize(u.client);
            e.f64(u.arrive_s);
            e.usize(u.dispatch_round);
        }
        e.usize(self.pending.len());
        for p in &self.pending {
            e.usize(p.client);
            e.str(&p.artifact);
            e.u64(p.prefix_version);
            e.usize(p.dispatch_round);
            e.f64(p.weight);
            e.bool(p.partial);
            e.u64(p.bytes_up);
            e.usize(p.tensors.len());
            for t in p.tensors.iter() {
                e.f32s(t);
            }
        }
        e.usize(self.params.len());
        for (name, shape, data) in &self.params {
            e.str(name);
            e.usize(shape.len());
            for d in shape {
                e.usize(*d);
            }
            e.f32s(data);
        }
        encode_pool(&mut e, &self.pool);
        e.usize(self.records.len());
        for r in &self.records {
            encode_record(&mut e, r);
        }
        e.str(&self.strategy_name);
        e.bytes(&self.strategy_blob);
        match &self.mid {
            None => e.u8(0),
            Some(MidPhase::Train { phase, detector, used, froze }) => {
                e.u8(1);
                encode_train_phase(&mut e, phase);
                encode_detector(&mut e, detector);
                e.usize(*used);
                e.bool(*froze);
            }
            Some(MidPhase::Distill { phase, used }) => {
                e.u8(2);
                e.str(&phase.stage);
                e.usize(phase.step);
                e.str(&phase.artifact);
                e.usize(phase.rounds);
                e.f32(phase.lr);
                e.usize(*used);
            }
        }
        e.finish()
    }

    /// Serialize to the versioned on-disk format (header + digested
    /// payload). Deterministic: equal checkpoints encode to equal bytes.
    pub fn encode(&self) -> Vec<u8> {
        let payload = self.encode_payload();
        let mut e = Enc::new();
        e.buf.extend_from_slice(&MAGIC);
        e.u32(FORMAT_VERSION);
        e.str(&self.crate_version);
        e.str(&self.config_sha256);
        e.str(&sha256_hex(&payload));
        e.u64(payload.len() as u64);
        let mut out = e.finish();
        out.extend_from_slice(&payload);
        out
    }

    // ---- decode --------------------------------------------------------

    /// Parse and fully validate a checkpoint file image: magic, format
    /// version, crate version, payload length, state digest, then every
    /// field. Any corruption — truncation, bit flips, hostile lengths or
    /// strings — yields a descriptive `Err`; this function never panics
    /// and never allocates more than the input size.
    pub fn decode(bytes: &[u8]) -> Result<Checkpoint> {
        let mut d = Dec::new(bytes);
        let magic = d.take(8).context("truncated before magic")?;
        ensure!(magic == MAGIC, "not a profl checkpoint (bad magic {magic:02x?})");
        let version = d.u32()?;
        ensure!(
            version == FORMAT_VERSION,
            "unsupported checkpoint format v{version} (this build reads v{FORMAT_VERSION})"
        );
        let crate_version = d.str().context("bad crate_version")?;
        let ours = env!("CARGO_PKG_VERSION");
        ensure!(
            crate_version == ours,
            "checkpoint written by profl {crate_version}, this binary is profl {ours}; \
             re-run the original version or restart the run"
        );
        let config_sha256 = d.str().context("bad config_sha256")?;
        let payload_sha256 = d.str().context("bad payload_sha256")?;
        let payload_len = d.usize().context("bad payload length")?;
        ensure!(
            payload_len == d.remaining(),
            "payload length {payload_len} disagrees with file ({} bytes remain)",
            d.remaining()
        );
        let payload = d.take(payload_len).expect("length just checked");
        let actual = sha256_hex(payload);
        ensure!(
            actual == payload_sha256,
            "checkpoint state digest mismatch: header says {payload_sha256}, payload hashes to {actual}"
        );
        let mut p = Dec::new(payload);
        let ck = Self::decode_payload(&mut p, crate_version, config_sha256)?;
        p.done()?;
        Ok(ck)
    }

    fn decode_payload(
        d: &mut Dec<'_>,
        crate_version: String,
        config_sha256: String,
    ) -> Result<Checkpoint> {
        let config_json = d.str().context("bad config_json")?;
        let round = d.usize()?;
        let sim_time_s = d.f64()?;
        let prefix_version = d.u64()?;
        let n = d.seq_len(24)?;
        let mut transitions = Vec::with_capacity(n);
        for _ in 0..n {
            let t = Transition { version: d.u64()?, round: d.usize()?, sim_time_s: d.f64()? };
            if let Some(prev) = transitions.last() {
                let prev: &Transition = prev;
                ensure!(
                    t.version > prev.version
                        && t.round >= prev.round
                        && t.sim_time_s >= prev.sim_time_s,
                    "transition log not monotone at version {}",
                    t.version
                );
            }
            transitions.push(t);
        }
        let fleet_rng = d.u64()?;
        let threads = d.usize()?;
        let n = d.seq_len(24)?;
        let mut inflight = Vec::with_capacity(n);
        for _ in 0..n {
            inflight.push(InFlightUpload {
                client: d.usize()?,
                arrive_s: d.f64()?,
                dispatch_round: d.usize()?,
            });
        }
        let n = d.seq_len(57)?;
        let mut pending = Vec::with_capacity(n);
        for _ in 0..n {
            let client = d.usize()?;
            let artifact = d.str()?;
            let prefix_version = d.u64()?;
            let dispatch_round = d.usize()?;
            let weight = d.f64()?;
            let partial = d.bool()?;
            let bytes_up = d.u64()?;
            let nt = d.seq_len(8)?;
            let mut tensors = Vec::with_capacity(nt);
            for _ in 0..nt {
                tensors.push(d.f32s()?);
            }
            if let Some(prev) = pending.last() {
                let prev: &PendingUpdate = prev;
                ensure!(client > prev.client, "pending buffer not sorted by client id");
            }
            pending.push(PendingUpdate {
                client,
                artifact,
                prefix_version,
                dispatch_round,
                weight,
                partial,
                tensors: Arc::new(tensors),
                bytes_up,
            });
        }
        let n = d.seq_len(24)?;
        let mut params = Vec::with_capacity(n);
        for _ in 0..n {
            let name = d.str()?;
            let nd = d.seq_len(8)?;
            let mut shape = Vec::with_capacity(nd);
            for _ in 0..nd {
                shape.push(d.usize()?);
            }
            let data = d.f32s()?;
            params.push((name, shape, data));
        }
        let pool = decode_pool(d)?;
        // 12 usize + 4 u64 + 5 f64 + 3 f32 + an empty stage prefix.
        let n = d.seq_len(188)?;
        let mut records = Vec::with_capacity(n);
        for _ in 0..n {
            records.push(decode_record(d)?);
        }
        let strategy_name = d.str()?;
        let strategy_blob = d.bytes()?;
        let mid = match d.u8()? {
            0 => None,
            1 => {
                let phase = decode_train_phase(d)?;
                let detector = decode_detector(d)?;
                let used = d.usize()?;
                let froze = d.bool()?;
                Some(MidPhase::Train { phase, detector, used, froze })
            }
            2 => {
                let phase = DistillPhase {
                    stage: d.str()?,
                    step: d.usize()?,
                    artifact: d.str()?,
                    rounds: d.usize()?,
                    lr: d.f32()?,
                };
                let used = d.usize()?;
                Some(MidPhase::Distill { phase, used })
            }
            t => bail!("invalid mid-phase tag {t}"),
        };
        Ok(Checkpoint {
            crate_version,
            config_sha256,
            config_json,
            round,
            sim_time_s,
            prefix_version,
            transitions,
            fleet_rng,
            threads,
            inflight,
            pending,
            params,
            pool,
            records,
            strategy_name,
            strategy_blob,
            mid,
        })
    }

    // ---- file I/O ------------------------------------------------------

    /// Write the encoded checkpoint to `path` atomically (tmp + rename),
    /// so a crash mid-write never leaves a torn checkpoint behind.
    pub fn write(&self, path: &Path) -> Result<()> {
        let tmp = path.with_extension("ckpt.tmp");
        std::fs::write(&tmp, self.encode())
            .with_context(|| format!("writing checkpoint {}", tmp.display()))?;
        std::fs::rename(&tmp, path)
            .with_context(|| format!("renaming checkpoint into {}", path.display()))?;
        Ok(())
    }

    /// Read and decode a checkpoint file.
    pub fn read(path: &Path) -> Result<Checkpoint> {
        let bytes = std::fs::read(path)
            .with_context(|| format!("reading checkpoint {}", path.display()))?;
        Self::decode(&bytes).with_context(|| format!("decoding checkpoint {}", path.display()))
    }

    // ---- resume plumbing ----------------------------------------------

    /// Reconstruct the [`RunConfig`] this checkpoint was taken under from
    /// its embedded canonical JSON, and cross-check the embedded
    /// `config_sha256` against the reconstruction — a fingerprint
    /// disagreement names both hashes.
    pub fn resolve_config(&self) -> Result<RunConfig> {
        let v = crate::json::Value::parse(&self.config_json)
            .context("checkpoint embeds unparseable config JSON")?;
        let cfg = RunConfig::from_value(&v)?;
        self.verify_config(&cfg)?;
        Ok(cfg)
    }

    /// Error unless `cfg`'s fingerprint equals the checkpoint's embedded
    /// `config_sha256`, naming both hashes. Thread count and checkpoint
    /// sinks are excluded from the fingerprint (wall-clock knobs), so
    /// resuming with a different `--threads` is legal by construction.
    pub fn verify_config(&self, cfg: &RunConfig) -> Result<()> {
        let resolved = config_sha256(cfg);
        ensure!(
            resolved == self.config_sha256,
            "config fingerprint mismatch: checkpoint was taken under config_sha256 \
             {} but the resolved config hashes to {resolved}",
            self.config_sha256
        );
        Ok(())
    }
}

// ---- sub-encoders ------------------------------------------------------

fn encode_pool(e: &mut Enc, pool: &PoolCkptState) {
    e.u64(pool.select_rng);
    match &pool.kind {
        PoolCkptKind::Eager(list) => {
            e.u8(0);
            e.usize(list.len());
            for c in list {
                encode_client(e, c);
            }
        }
        PoolCkptKind::Lazy(l) => {
            e.u8(1);
            e.u64(l.tick);
            e.usize(l.peak_resident);
            e.u64(l.hits);
            e.u64(l.misses);
            e.u64(l.evictions);
            e.usize(l.resident.len());
            for (c, tick) in &l.resident {
                encode_client(e, c);
                e.u64(*tick);
            }
            e.usize(l.evicted.len());
            for c in &l.evicted {
                encode_client(e, c);
            }
        }
    }
}

fn encode_client(e: &mut Enc, c: &ClientCkpt) {
    e.usize(c.id);
    e.u64(c.mem_rng);
    e.usize(c.cursor);
    e.u64(c.prefix_version);
}

fn decode_client(d: &mut Dec<'_>) -> Result<ClientCkpt> {
    Ok(ClientCkpt {
        id: d.usize()?,
        mem_rng: d.u64()?,
        cursor: d.usize()?,
        prefix_version: d.u64()?,
    })
}

fn decode_pool(d: &mut Dec<'_>) -> Result<PoolCkptState> {
    let select_rng = d.u64()?;
    let kind = match d.u8()? {
        0 => {
            let n = d.seq_len(32)?;
            let mut list = Vec::with_capacity(n);
            for _ in 0..n {
                list.push(decode_client(d)?);
            }
            PoolCkptKind::Eager(list)
        }
        1 => {
            let tick = d.u64()?;
            let peak_resident = d.usize()?;
            let hits = d.u64()?;
            let misses = d.u64()?;
            let evictions = d.u64()?;
            let n = d.seq_len(40)?;
            let mut resident = Vec::with_capacity(n);
            for _ in 0..n {
                let c = decode_client(d)?;
                resident.push((c, d.u64()?));
            }
            let n = d.seq_len(32)?;
            let mut evicted = Vec::with_capacity(n);
            for _ in 0..n {
                evicted.push(decode_client(d)?);
            }
            PoolCkptKind::Lazy(LazyCkpt {
                tick,
                peak_resident,
                hits,
                misses,
                evictions,
                resident,
                evicted,
            })
        }
        t => bail!("invalid pool kind tag {t}"),
    };
    Ok(PoolCkptState { select_rng, kind })
}

fn encode_record(e: &mut Enc, r: &RoundRecord) {
    e.usize(r.round);
    e.str(&r.stage);
    e.usize(r.step);
    e.f32(r.train_loss);
    e.f32(r.train_acc);
    e.f32(r.test_acc);
    e.f64(r.effective_movement);
    e.usize(r.participants);
    e.usize(r.fallback_participants);
    e.u64(r.bytes_up);
    e.u64(r.bytes_down);
    e.u64(r.client_mem_bytes);
    e.f64(r.sim_time_s);
    e.usize(r.stragglers);
    e.usize(r.dropouts);
    e.usize(r.late_merged);
    e.usize(r.late_dropped);
    e.f64(r.mean_staleness);
    e.usize(r.projected_merged);
    e.u64(r.projected_dropped_params);
    e.f64(r.transition_staleness);
    e.usize(r.interrupted);
    e.usize(r.resumed);
    e.usize(r.partial_merged);
    e.f64(r.wasted_compute_s);
}

fn decode_record(d: &mut Dec<'_>) -> Result<RoundRecord> {
    Ok(RoundRecord {
        round: d.usize()?,
        stage: d.str()?,
        step: d.usize()?,
        train_loss: d.f32()?,
        train_acc: d.f32()?,
        test_acc: d.f32()?,
        effective_movement: d.f64()?,
        participants: d.usize()?,
        fallback_participants: d.usize()?,
        bytes_up: d.u64()?,
        bytes_down: d.u64()?,
        client_mem_bytes: d.u64()?,
        sim_time_s: d.f64()?,
        stragglers: d.usize()?,
        dropouts: d.usize()?,
        late_merged: d.usize()?,
        late_dropped: d.usize()?,
        mean_staleness: d.f64()?,
        projected_merged: d.usize()?,
        projected_dropped_params: d.u64()?,
        transition_staleness: d.f64()?,
        interrupted: d.usize()?,
        resumed: d.usize()?,
        partial_merged: d.usize()?,
        wasted_compute_s: d.f64()?,
    })
}

fn encode_train_phase(e: &mut Enc, p: &TrainPhase) {
    e.str(&p.stage);
    e.usize(p.step);
    e.usize(p.layout.frozen);
    e.usize(p.layout.depth);
    e.str(&p.train_artifact);
    match &p.fallback_artifact {
        None => e.u8(0),
        Some(a) => {
            e.u8(1);
            e.str(a);
        }
    }
    e.str(&p.eval_artifact);
    e.usize(p.observe_params.len());
    for s in &p.observe_params {
        e.str(s);
    }
    e.f32(p.lr);
    e.usize(p.max_rounds);
    e.usize(p.min_rounds);
    e.bool(p.em_gated);
}

fn decode_train_phase(d: &mut Dec<'_>) -> Result<TrainPhase> {
    let stage = d.str()?;
    let step = d.usize()?;
    let layout =
        crate::strategy::BlockLayout { frozen: d.usize()?, depth: d.usize()? };
    let train_artifact = d.str()?;
    let fallback_artifact = match d.u8()? {
        0 => None,
        1 => Some(d.str()?),
        t => bail!("invalid option tag {t}"),
    };
    let eval_artifact = d.str()?;
    let n = d.seq_len(8)?;
    let mut observe_params = Vec::with_capacity(n);
    for _ in 0..n {
        observe_params.push(d.str()?);
    }
    Ok(TrainPhase {
        stage,
        step,
        layout,
        train_artifact,
        fallback_artifact,
        eval_artifact,
        observe_params,
        lr: d.f32()?,
        max_rounds: d.usize()?,
        min_rounds: d.usize()?,
        em_gated: d.bool()?,
    })
}

fn encode_detector(e: &mut Enc, s: &DetectorSnapshot) {
    e.usize(s.deltas.len());
    for v in &s.deltas {
        e.f32s(v);
    }
    match &s.prev {
        None => e.u8(0),
        Some(v) => {
            e.u8(1);
            e.f32s(v);
        }
    }
    e.f64s(&s.history);
    e.usize(s.consecutive);
}

fn decode_detector(d: &mut Dec<'_>) -> Result<DetectorSnapshot> {
    let n = d.seq_len(8)?;
    let mut deltas = Vec::with_capacity(n);
    for _ in 0..n {
        deltas.push(d.f32s()?);
    }
    let prev = match d.u8()? {
        0 => None,
        1 => Some(d.f32s()?),
        t => bail!("invalid option tag {t}"),
    };
    let history = d.f64s()?;
    let consecutive = d.usize()?;
    Ok(DetectorSnapshot { deltas, prev, history, consecutive })
}

// ---- gather / apply ----------------------------------------------------

/// Snapshot the complete run state of `ctx` (plus the driving strategy's
/// cursor and the within-phase position `mid`) into a [`Checkpoint`].
/// Pure observation: nothing in the run advances.
pub fn gather(
    ctx: &ServerCtx<'_>,
    strategy: &dyn MemoryStrategy,
    mid: Option<MidPhase>,
) -> Checkpoint {
    let mut pending: Vec<PendingUpdate> = ctx.pending.values().cloned().collect();
    pending.sort_unstable_by_key(|p| p.client);
    let names: Vec<String> = ctx.store.names().cloned().collect();
    let params = names
        .into_iter()
        .map(|name| {
            let t = ctx.store.get(&name).expect("name just listed");
            (name, t.shape.clone(), t.data.clone())
        })
        .collect();
    Checkpoint {
        crate_version: env!("CARGO_PKG_VERSION").to_string(),
        config_sha256: config_sha256(&ctx.cfg),
        config_json: config_value(&ctx.cfg).to_json(),
        round: ctx.round,
        sim_time_s: ctx.sim_time_s,
        prefix_version: ctx.prefix_version,
        transitions: ctx.transitions.entries().to_vec(),
        fleet_rng: ctx.fleet_rng.state(),
        threads: ctx.engine.threads(),
        inflight: ctx.engine.inflight().to_vec(),
        pending,
        params,
        pool: ctx.pool.export_state(),
        records: ctx.metrics.records.clone(),
        strategy_name: strategy.name().to_string(),
        strategy_blob: strategy.save_state(),
        mid,
    }
}

/// Reposition a freshly constructed `ctx` (built from the checkpoint's
/// resolved config) at the checkpointed round boundary: clock, counters,
/// transition log, rng streams, in-flight queue, pending buffers,
/// parameter store, pool residues, and record history. After this call
/// the run continues bit-identically to the uninterrupted original.
pub fn apply_to_ctx(ck: &Checkpoint, ctx: &mut ServerCtx<'_>) -> Result<()> {
    let fleet = ctx.pool.len();
    for u in &ck.inflight {
        ensure!(u.client < fleet, "in-flight upload for client {} of {fleet}", u.client);
    }
    for p in &ck.pending {
        ensure!(p.client < fleet, "pending update for client {} of {fleet}", p.client);
    }
    ensure!(
        ck.params.len() == ctx.store.len(),
        "checkpoint carries {} tensors, the model has {}",
        ck.params.len(),
        ctx.store.len()
    );
    for (name, shape, data) in &ck.params {
        let have = ctx
            .store
            .get(name)
            .with_context(|| format!("checkpoint tensor `{name}` not in the model"))?;
        ensure!(
            have.shape == *shape && have.data.len() == data.len(),
            "checkpoint tensor `{name}` has shape {shape:?}, model expects {:?}",
            have.shape
        );
    }
    for (name, shape, data) in &ck.params {
        ctx.store.set(name, Tensor { shape: shape.clone(), data: data.clone() });
    }
    ctx.pool.import_state(&ck.pool)?;
    ctx.round = ck.round;
    ctx.sim_time_s = ck.sim_time_s;
    ctx.prefix_version = ck.prefix_version;
    ctx.transitions = TransitionLog::from_entries(ck.transitions.clone());
    ctx.fleet_rng = Rng::from_state(ck.fleet_rng);
    ctx.engine.restore_inflight(ck.inflight.clone());
    ctx.pending = ck.pending.iter().map(|p| (p.client, p.clone())).collect();
    for r in &ck.records {
        ctx.metrics.push(r.clone());
    }
    Ok(())
}

// ---- periodic sink -----------------------------------------------------

/// Where and how often a run writes checkpoints, resolved from
/// `--checkpoint <path>` / `--checkpoint-every <rounds>`. A literal
/// `{round}` in the path expands to the round index (one file per
/// boundary); without it the same file is atomically overwritten.
#[derive(Debug, Clone)]
pub struct CkptSink {
    path: String,
    every: usize,
}

impl CkptSink {
    /// The run's sink, or `None` when checkpointing is off. Errors on an
    /// invalid cadence (`--checkpoint-every 0`).
    pub fn from_cfg(cfg: &RunConfig) -> Result<Option<CkptSink>> {
        match cfg.checkpoint_plan()? {
            Some((path, every)) => Ok(Some(CkptSink { path, every })),
            None => Ok(None),
        }
    }

    /// A sink writing to `path` every `every` rounds (for tests/examples).
    pub fn new(path: impl Into<String>, every: usize) -> Self {
        CkptSink { path: path.into(), every: every.max(1) }
    }

    /// Whether a checkpoint is due after completing `rounds_done` rounds.
    pub fn due(&self, rounds_done: usize) -> bool {
        rounds_done > 0 && rounds_done % self.every == 0
    }

    /// The file path for the boundary after `rounds_done` rounds.
    pub fn path_for(&self, rounds_done: usize) -> std::path::PathBuf {
        std::path::PathBuf::from(self.path.replace("{round}", &rounds_done.to_string()))
    }

    /// Write `ck` to [`Self::path_for`] the boundary.
    pub fn write(&self, ck: &Checkpoint, rounds_done: usize) -> Result<()> {
        ck.write(&self.path_for(rounds_done))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// A small but fully populated checkpoint exercising every encoder
    /// branch (lazy pool, pending tensors, mid train-phase, NaN floats).
    fn sample() -> Checkpoint {
        Checkpoint {
            crate_version: env!("CARGO_PKG_VERSION").to_string(),
            config_sha256: "c0ffee".into(),
            config_json: "{\"seed\":\"42\"}".into(),
            round: 7,
            sim_time_s: 123.456,
            prefix_version: 2,
            transitions: vec![
                Transition { version: 1, round: 2, sim_time_s: 10.0 },
                Transition { version: 2, round: 5, sim_time_s: 60.5 },
            ],
            fleet_rng: 0xdead_beef,
            threads: 4,
            inflight: vec![InFlightUpload { client: 3, arrive_s: 130.25, dispatch_round: 6 }],
            pending: vec![PendingUpdate {
                client: 3,
                artifact: "block2".into(),
                prefix_version: 2,
                dispatch_round: 6,
                weight: 41.0,
                partial: true,
                tensors: Arc::new(vec![vec![1.0, -2.5], vec![f32::NAN]]),
                bytes_up: 1024,
            }],
            params: vec![
                ("a/w".into(), vec![2, 2], vec![0.0, 1.0, 2.0, 3.0]),
                ("b/w".into(), vec![3], vec![-1.0, f32::INFINITY, 0.5]),
            ],
            pool: PoolCkptState {
                select_rng: 99,
                kind: PoolCkptKind::Lazy(LazyCkpt {
                    tick: 31,
                    peak_resident: 4,
                    hits: 20,
                    misses: 11,
                    evictions: 7,
                    resident: vec![(
                        ClientCkpt { id: 1, mem_rng: 5, cursor: 2, prefix_version: 1 },
                        30,
                    )],
                    evicted: vec![ClientCkpt { id: 4, mem_rng: 9, cursor: 0, prefix_version: 2 }],
                }),
            },
            records: vec![RoundRecord {
                round: 6,
                stage: "shrink-train".into(),
                step: 1,
                train_loss: 1.5,
                train_acc: 0.3,
                test_acc: f32::NAN,
                effective_movement: 0.8,
                participants: 9,
                fallback_participants: 1,
                bytes_up: 100,
                bytes_down: 200,
                client_mem_bytes: 300,
                sim_time_s: 120.0,
                stragglers: 1,
                dropouts: 0,
                late_merged: 2,
                late_dropped: 0,
                mean_staleness: 1.5,
                projected_merged: 0,
                projected_dropped_params: 0,
                transition_staleness: 0.0,
                interrupted: 0,
                resumed: 0,
                partial_merged: 1,
                wasted_compute_s: 3.25,
            }],
            strategy_name: "ProFL".into(),
            strategy_blob: vec![1, 2, 3],
            mid: Some(MidPhase::Train {
                phase: TrainPhase {
                    stage: "shrink-train".into(),
                    step: 1,
                    layout: crate::strategy::BlockLayout { frozen: 0, depth: 3 },
                    train_artifact: "prefix3".into(),
                    fallback_artifact: Some("op".into()),
                    eval_artifact: "full".into(),
                    observe_params: vec!["a/w".into()],
                    lr: 0.08,
                    max_rounds: 40,
                    min_rounds: 10,
                    em_gated: true,
                },
                detector: DetectorSnapshot {
                    deltas: vec![vec![0.1, -0.1]],
                    prev: Some(vec![1.0, 2.0]),
                    history: vec![0.9, 0.7],
                    consecutive: 1,
                },
                used: 3,
                froze: false,
            }),
        }
    }

    #[test]
    fn encode_decode_encode_is_byte_idempotent() {
        let ck = sample();
        let b1 = ck.encode();
        let ck2 = Checkpoint::decode(&b1).unwrap();
        let b2 = ck2.encode();
        assert_eq!(b1, b2, "serialize→deserialize→serialize changed bytes");
    }

    /// Pending tensors are held behind an `Arc`: encoding must follow
    /// the shared handle (not its refcount), and a clone of the decoded
    /// update must alias the same buffers rather than deep-copying.
    #[test]
    fn pending_arc_handles_round_trip_and_share() {
        let ck = sample();
        // Sharing the pending tensors with an outside holder (as the
        // coordinator's in-flight queue does) must not change the bytes.
        let held = Arc::clone(&ck.pending[0].tensors);
        let bytes = ck.encode();
        assert_eq!(bytes, sample().encode(), "outstanding Arc handle changed the encoding");
        drop(held);

        let ck2 = Checkpoint::decode(&bytes).unwrap();
        let p = &ck2.pending[0];
        assert_eq!(p.tensors.len(), 2);
        assert_eq!(p.tensors[0], vec![1.0, -2.5]);
        assert!(p.tensors[1][0].is_nan(), "NaN payload must survive the round trip");
        // Cloning a decoded PendingUpdate is a refcount bump, not a copy.
        let c = p.clone();
        assert!(Arc::ptr_eq(&c.tensors, &p.tensors), "clone must alias the tensor buffers");
    }

    #[test]
    fn every_truncation_errs_cleanly() {
        let bytes = sample().encode();
        for n in 0..bytes.len() {
            assert!(Checkpoint::decode(&bytes[..n]).is_err(), "prefix of {n} bytes accepted");
        }
    }

    #[test]
    fn digest_detects_payload_bit_flips() {
        let mut bytes = sample().encode();
        let last = bytes.len() - 1;
        bytes[last] ^= 0x40;
        let err = Checkpoint::decode(&bytes).unwrap_err().to_string();
        assert!(err.contains("digest mismatch"), "unexpected error: {err}");
        assert!(err.matches(char::is_alphanumeric).count() > 0);
    }

    #[test]
    fn bad_magic_and_version_are_named() {
        let mut bytes = sample().encode();
        bytes[0] = b'X';
        assert!(Checkpoint::decode(&bytes).unwrap_err().to_string().contains("magic"));
        let mut bytes = sample().encode();
        bytes[8] = 0xff; // format version
        let err = Checkpoint::decode(&bytes).unwrap_err().to_string();
        assert!(err.contains("unsupported checkpoint format"), "{err}");
    }

    #[test]
    fn crate_version_skew_is_a_readable_error() {
        let mut ck = sample();
        ck.crate_version = "0.0.0-other".into();
        let err = Checkpoint::decode(&ck.encode()).unwrap_err().to_string();
        assert!(err.contains("0.0.0-other"), "must name the writing version: {err}");
        assert!(err.contains(env!("CARGO_PKG_VERSION")), "must name our version: {err}");
    }

    #[test]
    fn config_mismatch_names_both_hashes() {
        let ck = sample();
        let cfg = RunConfig::default();
        let err = ck.verify_config(&cfg).unwrap_err().to_string();
        assert!(err.contains("c0ffee"), "must name the stored hash: {err}");
        assert!(err.contains(&config_sha256(&cfg)), "must name the resolved hash: {err}");
    }

    #[test]
    fn oversized_length_prefix_errs_before_allocating() {
        // A corrupted u64 length prefix claiming ~2^63 elements must be
        // rejected by the remaining-bytes bound, not attempted.
        let mut d = Dec::new(&[0xff, 0xff, 0xff, 0xff, 0xff, 0xff, 0xff, 0x7f, 1, 2, 3]);
        assert!(d.f32s().is_err());
        let mut d = Dec::new(&[0xff; 16]);
        assert!(d.str().is_err());
    }

    #[test]
    fn strict_scalars_reject_garbage() {
        let mut d = Dec::new(&[2]);
        assert!(d.bool().is_err());
        let mut e = Enc::new();
        e.str("ok");
        let mut bytes = e.finish();
        bytes[8] = 0xff; // first content byte -> invalid UTF-8 start
        let mut d = Dec::new(&bytes);
        assert!(d.str().is_err());
    }

    #[test]
    fn payload_length_disagreement_is_rejected() {
        let mut bytes = sample().encode();
        bytes.push(0); // trailing garbage after the payload
        let err = Checkpoint::decode(&bytes).unwrap_err().to_string();
        assert!(err.contains("payload length"), "{err}");
    }

    #[test]
    fn sink_cadence_and_round_templating() {
        let sink = CkptSink::new("/tmp/run-{round}.ckpt", 3);
        assert!(!sink.due(0));
        assert!(!sink.due(2));
        assert!(sink.due(3));
        assert!(sink.due(6));
        assert_eq!(sink.path_for(6), std::path::PathBuf::from("/tmp/run-6.ckpt"));
        let plain = CkptSink::new("/tmp/run.ckpt", 1);
        assert!(plain.due(1));
        assert_eq!(plain.path_for(5), std::path::PathBuf::from("/tmp/run.ckpt"));
    }
}
