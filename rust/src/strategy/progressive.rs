//! The paper's progressive schedule as a [`MemoryStrategy`]: model
//! shrinking (T→2, each step *Map*ped into its surrogate via federated
//! distillation) followed by model growing (1→T), with EM-gated
//! freezing — or the ParamAware round-budget baseline (Table 4).
//!
//! This is a bit-for-bit port of the legacy `methods::profl` loops: the
//! phase sequence, per-step budgets, learning-rate decay, and freeze
//! gating reproduce the pre-refactor per-round records exactly
//! (`examples/strategy_zoo.rs` asserts the degeneracy against an inline
//! transcription of the legacy schedule).

use super::{BlockLayout, DistillPhase, MemoryStrategy, ModelView, Phase, StepFeedback, TrainPhase};
use crate::checkpoint::{Dec, Enc};
use crate::config::RunConfig;
use anyhow::{bail, Result};

/// How a progressive step decides it is done.
#[derive(Debug, Clone, Copy, PartialEq, Default)]
pub enum FreezePolicy {
    /// Effective movement + least-squares slope (the paper's §3.3).
    #[default]
    EffectiveMovement,
    /// Table 4 baseline: per-step round budget ∝ block parameter count.
    ParamAware,
}

/// Round budget for step `t` under ParamAware: share of the total grow
/// budget proportional to the block's parameter count (min 4 rounds).
pub fn param_aware_rounds(counts: &[u64], t: usize, total_budget: usize) -> usize {
    let total: u64 = counts.iter().sum();
    let share = counts[t - 1] as f64 / total as f64;
    ((total_budget as f64 * share) as usize).max(4)
}

/// Which pending phase the next feedback belongs to.
#[derive(Debug, Clone, Copy, PartialEq)]
enum Pending {
    None,
    ShrinkTrain,
    Distill,
    GrowTrain,
}

/// Schedule cursor.
#[derive(Debug, Clone, Copy, PartialEq)]
enum Cursor {
    Start,
    /// About to emit the freeze transition for shrink step t.
    ShrinkEnter(usize),
    /// About to emit the train phase for shrink step t.
    ShrinkTrain(usize),
    /// About to emit the Map distillation for shrink step t.
    ShrinkDistill(usize),
    /// About to emit the freeze transition for grow step t.
    GrowEnter(usize),
    /// About to emit the train phase for grow step t.
    GrowTrain(usize),
    Done,
}

/// ProFL's shrink→grow schedule (or its ParamAware ablation) on the
/// [`MemoryStrategy`] trait.
#[derive(Debug)]
pub struct Progressive {
    policy: FreezePolicy,
    cursor: Cursor,
    pending: Pending,
    lr: f32,
    /// Shared shrink+grow round budget (`2 × max_rounds_total` at start).
    remaining: usize,
}

impl Progressive {
    /// A fresh schedule under the given freeze policy. Budget and
    /// learning rate initialize lazily from the config on the first
    /// [`next_phase`](MemoryStrategy::next_phase) call.
    pub fn new(policy: FreezePolicy) -> Self {
        Progressive { policy, cursor: Cursor::Start, pending: Pending::None, lr: 0.0, remaining: 0 }
    }

    /// The legacy `run_step` budget arithmetic for one train phase.
    fn train_phase(&self, model: &ModelView, cfg: &RunConfig, t: usize, stage: &str, budget: usize) -> TrainPhase {
        let counts = &model.block_param_counts;
        let max_rounds = match self.policy {
            FreezePolicy::EffectiveMovement => cfg.max_rounds_per_step.min(budget),
            FreezePolicy::ParamAware => {
                param_aware_rounds(counts, t, cfg.max_rounds_per_step * counts.len()).min(budget)
            }
        };
        let min_rounds = cfg.min_rounds_per_step.min(max_rounds);
        TrainPhase {
            stage: stage.into(),
            step: t,
            layout: BlockLayout { frozen: t - 1, depth: t },
            train_artifact: format!("train_t{t}"),
            fallback_artifact: Some(format!("train_op_t{t}")),
            eval_artifact: format!("eval_t{t}"),
            observe_params: model.block_params[t - 1].clone(),
            lr: self.lr,
            max_rounds,
            min_rounds,
            em_gated: self.policy == FreezePolicy::EffectiveMovement,
        }
    }
}

impl MemoryStrategy for Progressive {
    fn name(&self) -> &'static str {
        match self.policy {
            FreezePolicy::EffectiveMovement => "ProFL",
            FreezePolicy::ParamAware => "ParamAware",
        }
    }

    fn next_phase(
        &mut self,
        model: &ModelView,
        cfg: &RunConfig,
        last: Option<&StepFeedback>,
    ) -> Option<Phase> {
        // Consume the previous phase's feedback (legacy bookkeeping:
        // every executed round draws down the shared budget; each grow
        // step additionally decays the learning rate).
        let used = last.map_or(0, |f| f.rounds_used);
        match std::mem::replace(&mut self.pending, Pending::None) {
            Pending::None => {}
            Pending::ShrinkTrain | Pending::Distill => {
                self.remaining = self.remaining.saturating_sub(used);
            }
            Pending::GrowTrain => {
                self.remaining = self.remaining.saturating_sub(used);
                self.lr *= cfg.lr_step_decay;
            }
        }

        if self.cursor == Cursor::Start {
            self.lr = cfg.lr;
            self.remaining = cfg.max_rounds_total * 2; // shrink + grow budget
            self.cursor = if cfg.shrinking && model.num_blocks >= 2 {
                Cursor::ShrinkEnter(model.num_blocks)
            } else {
                Cursor::GrowEnter(1)
            };
        }

        match self.cursor {
            Cursor::Start => unreachable!("resolved above"),
            Cursor::ShrinkEnter(t) => {
                self.cursor = Cursor::ShrinkTrain(t);
                Some(Phase::Transition)
            }
            Cursor::ShrinkTrain(t) => {
                self.pending = Pending::ShrinkTrain;
                self.cursor = Cursor::ShrinkDistill(t);
                Some(Phase::Train(self.train_phase(model, cfg, t, "shrink", self.remaining)))
            }
            Cursor::ShrinkDistill(t) => {
                self.pending = Pending::Distill;
                self.cursor =
                    if t > 2 { Cursor::ShrinkEnter(t - 1) } else { Cursor::GrowEnter(1) };
                Some(Phase::Distill(DistillPhase {
                    stage: "map".into(),
                    step: t,
                    artifact: format!("distill_t{t}"),
                    rounds: cfg.distill_rounds,
                    lr: self.lr,
                }))
            }
            Cursor::GrowEnter(t) => {
                self.cursor = Cursor::GrowTrain(t);
                Some(Phase::Transition)
            }
            Cursor::GrowTrain(t) => {
                self.pending = Pending::GrowTrain;
                self.cursor =
                    if t < model.num_blocks { Cursor::GrowEnter(t + 1) } else { Cursor::Done };
                let budget = self.remaining.max(cfg.min_rounds_per_step);
                Some(Phase::Train(self.train_phase(model, cfg, t, "grow", budget)))
            }
            Cursor::Done => None,
        }
    }

    fn final_eval_artifact(&self, model: &ModelView) -> String {
        format!("eval_t{}", model.num_blocks)
    }

    fn participation_artifact(&self, model: &ModelView) -> String {
        format!("train_op_t{}", model.num_blocks)
    }

    fn save_state(&self) -> Vec<u8> {
        let mut e = Enc::new();
        let (tag, t) = match self.cursor {
            Cursor::Start => (0u8, 0usize),
            Cursor::ShrinkEnter(t) => (1, t),
            Cursor::ShrinkTrain(t) => (2, t),
            Cursor::ShrinkDistill(t) => (3, t),
            Cursor::GrowEnter(t) => (4, t),
            Cursor::GrowTrain(t) => (5, t),
            Cursor::Done => (6, 0),
        };
        e.u8(tag);
        e.usize(t);
        e.u8(match self.pending {
            Pending::None => 0,
            Pending::ShrinkTrain => 1,
            Pending::Distill => 2,
            Pending::GrowTrain => 3,
        });
        e.f32(self.lr);
        e.usize(self.remaining);
        e.finish()
    }

    fn load_state(&mut self, bytes: &[u8]) -> Result<()> {
        let mut d = Dec::new(bytes);
        let tag = d.u8()?;
        let t = d.usize()?;
        self.cursor = match tag {
            0 => Cursor::Start,
            1 => Cursor::ShrinkEnter(t),
            2 => Cursor::ShrinkTrain(t),
            3 => Cursor::ShrinkDistill(t),
            4 => Cursor::GrowEnter(t),
            5 => Cursor::GrowTrain(t),
            6 => Cursor::Done,
            b => bail!("invalid progressive cursor tag {b}"),
        };
        self.pending = match d.u8()? {
            0 => Pending::None,
            1 => Pending::ShrinkTrain,
            2 => Pending::Distill,
            3 => Pending::GrowTrain,
            b => bail!("invalid progressive pending tag {b}"),
        };
        self.lr = d.f32()?;
        self.remaining = d.usize()?;
        d.done()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn view() -> ModelView {
        ModelView::synthetic(&[2_000_000, 3_000_000, 3_000_000, 3_200_000])
    }

    /// Drive a schedule to completion with a fixed rounds-used script.
    fn enumerate(policy: FreezePolicy, cfg: &RunConfig, used_per_train: usize) -> Vec<Phase> {
        let v = view();
        let mut s = Progressive::new(policy);
        let mut phases = Vec::new();
        let mut last: Option<StepFeedback> = None;
        while let Some(p) = s.next_phase(&v, cfg, last.as_ref()) {
            last = match &p {
                Phase::Transition => None,
                Phase::Train(t) => Some(StepFeedback {
                    rounds_used: used_per_train.min(t.max_rounds),
                    froze: t.em_gated && used_per_train < t.max_rounds,
                }),
                Phase::Distill(d) => Some(StepFeedback { rounds_used: d.rounds, froze: false }),
            };
            phases.push(p);
        }
        phases
    }

    #[test]
    fn shrink_then_grow_phase_order_matches_legacy() {
        let cfg = RunConfig::smoke("m");
        let phases = enumerate(FreezePolicy::EffectiveMovement, &cfg, 4);
        // Shrink t = 4..2: [Transition, Train, Distill] each; grow
        // t = 1..4: [Transition, Train] each.
        let mut expect: Vec<(&str, usize)> = Vec::new();
        for t in [4usize, 3, 2] {
            expect.extend([("transition", t), ("shrink", t), ("map", t)]);
        }
        for t in 1..=4usize {
            expect.extend([("transition", t), ("grow", t)]);
        }
        assert_eq!(phases.len(), expect.len());
        for (p, (kind, step)) in phases.iter().zip(&expect) {
            match p {
                Phase::Transition => assert_eq!(*kind, "transition"),
                Phase::Train(t) => {
                    assert_eq!(&t.stage, kind);
                    assert_eq!(t.step, *step);
                    assert_eq!(t.train_artifact, format!("train_t{step}"));
                    assert_eq!(t.layout, BlockLayout { frozen: step - 1, depth: *step });
                }
                Phase::Distill(d) => {
                    assert_eq!(*kind, "map");
                    assert_eq!(d.step, *step);
                    assert_eq!(d.rounds, cfg.distill_rounds);
                }
            }
        }
    }

    #[test]
    fn noshrink_skips_straight_to_grow() {
        let mut cfg = RunConfig::smoke("m");
        cfg.shrinking = false;
        let phases = enumerate(FreezePolicy::EffectiveMovement, &cfg, 4);
        assert_eq!(phases.len(), 8, "4 × [Transition, Train]");
        assert!(matches!(&phases[1], Phase::Train(t) if t.stage == "grow" && t.step == 1));
    }

    #[test]
    fn budgets_track_legacy_arithmetic() {
        // Smoke profile: remaining = 64; EM caps each step at
        // max_rounds_per_step = 8; every train uses 4, every distill 2.
        let cfg = RunConfig::smoke("m");
        let phases = enumerate(FreezePolicy::EffectiveMovement, &cfg, 4);
        let budgets: Vec<usize> = phases
            .iter()
            .filter_map(|p| match p {
                Phase::Train(t) => Some(t.max_rounds),
                _ => None,
            })
            .collect();
        // All seven train phases fit under the per-step cap of 8 and the
        // budget never runs dry (64 - 3×(4+2) - 4×4 > 0).
        assert_eq!(budgets, vec![8; 7]);
    }

    #[test]
    fn param_aware_budget_is_share_proportional() {
        let counts = [2_000_000u64, 3_000_000, 3_000_000, 3_200_000];
        let total_budget = 32 * 4;
        let r1 = param_aware_rounds(&counts, 1, total_budget);
        let r4 = param_aware_rounds(&counts, 4, total_budget);
        assert!(r4 > r1, "bigger block ⇒ bigger budget");
        assert!(param_aware_rounds(&[1, 1_000_000], 1, 100) >= 4, "min 4 rounds");
        // ParamAware phases never EM-gate.
        let cfg = RunConfig::smoke("m");
        for p in enumerate(FreezePolicy::ParamAware, &cfg, usize::MAX) {
            if let Phase::Train(t) = p {
                assert!(!t.em_gated);
            }
        }
    }

    #[test]
    fn grow_lr_decays_per_step() {
        let mut cfg = RunConfig::smoke("m");
        cfg.shrinking = false;
        cfg.lr_step_decay = 0.5;
        let phases = enumerate(FreezePolicy::EffectiveMovement, &cfg, 4);
        let lrs: Vec<f32> = phases
            .iter()
            .filter_map(|p| match p {
                Phase::Train(t) => Some(t.lr),
                _ => None,
            })
            .collect();
        assert_eq!(lrs, vec![0.08, 0.04, 0.02, 0.01]);
    }

    #[test]
    fn save_load_resumes_the_schedule_at_any_cut() {
        // Cut the schedule after every prefix of next_phase calls: a
        // fresh strategy loaded from the cut's blob must emit exactly
        // the phases the original emits from there on.
        let v = view();
        let cfg = RunConfig::smoke("m");
        let feedback = |p: &Phase| match p {
            Phase::Transition => None,
            Phase::Train(t) => {
                Some(StepFeedback { rounds_used: 4.min(t.max_rounds), froze: t.em_gated })
            }
            Phase::Distill(d) => Some(StepFeedback { rounds_used: d.rounds, froze: false }),
        };
        for policy in [FreezePolicy::EffectiveMovement, FreezePolicy::ParamAware] {
            for cut in 0..24 {
                let mut original = Progressive::new(policy);
                let mut last = None;
                let mut ended_early = false;
                for _ in 0..cut {
                    match original.next_phase(&v, &cfg, last.as_ref()) {
                        Some(p) => last = feedback(&p),
                        None => {
                            ended_early = true;
                            break;
                        }
                    }
                }
                if ended_early {
                    break;
                }
                let mut resumed = Progressive::new(policy);
                resumed.load_state(&original.save_state()).unwrap();
                assert_eq!(
                    resumed.save_state(),
                    original.save_state(),
                    "blob round-trip at cut {cut}"
                );
                let mut last2 = last.clone();
                loop {
                    let a = original.next_phase(&v, &cfg, last.as_ref());
                    let b = resumed.next_phase(&v, &cfg, last2.as_ref());
                    assert_eq!(a, b, "policy {policy:?} diverged after cut {cut}");
                    match a {
                        Some(p) => {
                            last = feedback(&p);
                            last2 = last.clone();
                        }
                        None => break,
                    }
                }
            }
        }
    }

    #[test]
    fn load_rejects_garbage_blobs() {
        let mut s = Progressive::new(FreezePolicy::EffectiveMovement);
        assert!(s.load_state(&[]).is_err(), "truncated");
        assert!(s.load_state(&[9; 22]).is_err(), "bad cursor tag");
        let mut blob = Progressive::new(FreezePolicy::EffectiveMovement).save_state();
        blob.push(0);
        assert!(s.load_state(&blob).is_err(), "trailing bytes");
    }
}
