//! Memory-wall strategies: *what is trainable this round, and when does
//! it advance*.
//!
//! ProFL's progressive shrink→grow schedule is one point in a family of
//! memory-wall strategies. This module factors the family's shared
//! decision — the trainable block layout per round plus the
//! advance/freeze trigger — into the [`MemoryStrategy`] trait, and ships
//! the zoo:
//!
//! | strategy      | layout per phase            | advance trigger          |
//! |---------------|-----------------------------|--------------------------|
//! | `profl`       | one block, shrink→grow      | EM slope (§3.3)          |
//! | `paramaware`  | one block, shrink→grow      | rounds ∝ block params    |
//! | `layerfreeze` | full depth, frozen prefix   | EM slope on front block  |
//! | `elastic`     | window from a budget curve  | fixed per-phase budget   |
//!
//! A strategy is a *schedule generator*: [`MemoryStrategy::next_phase`]
//! yields [`Phase`]s (freeze transition, train step, distill step) and
//! receives [`StepFeedback`] about how the previous phase actually went
//! (rounds consumed, whether freezing fired). The [`run_strategy`]
//! driver executes phases against a [`ServerCtx`] — the coordinator
//! round loop, the freeze [`TransitionLog`](crate::freezing::TransitionLog),
//! and the `freeze.observe` telemetry spans all consume the trait rather
//! than ProFL internals. ProFL and ParamAware are ported onto the trait
//! bit-for-bit: the driver replays the exact legacy call sequence, so
//! pre-refactor per-round records and golden traces survive unchanged.
//!
//! The module also carries a *pure* memory model ([`BlockLayout`],
//! [`layout_mem`], [`depth_cap`]) so schedules and footprints can be
//! enumerated, property-tested, and compared without compiled artifacts
//! (`examples/strategy_zoo.rs`, `tests/proptests.rs`). See
//! `docs/STRATEGIES.md` for the trait contract and how to add a
//! strategy.

pub mod elastic;
pub mod layerfreeze;
pub mod progressive;

pub use elastic::Elastic;
pub use layerfreeze::LayerFreeze;
pub use progressive::{FreezePolicy, Progressive};

use crate::checkpoint::{apply_to_ctx, gather, Checkpoint, CkptSink, MidPhase};
use crate::config::RunConfig;
use crate::coordinator::ServerCtx;
use crate::freezing::FreezeDetector;
use crate::manifest::{MemCoeffs, ModelEntry};
use crate::metrics::RunSummary;
use crate::runtime::Runtime;
use anyhow::Result;

/// The slice of a manifest [`ModelEntry`] a strategy consumes. It is a
/// plain-data view so schedules can be enumerated without compiled
/// artifacts (tests and `examples/strategy_zoo.rs` build one directly).
#[derive(Debug, Clone)]
pub struct ModelView {
    /// Progressive block count T.
    pub num_blocks: usize,
    /// Parameter counts per block (index 0 = block 1).
    pub block_param_counts: Vec<u64>,
    /// Parameter names belonging to each block (index 0 = block 1).
    pub block_params: Vec<Vec<String>>,
}

impl ModelView {
    /// Project a manifest entry down to the strategy-visible fields.
    pub fn of(model: &ModelEntry) -> Self {
        ModelView {
            num_blocks: model.num_blocks,
            block_param_counts: model.block_param_counts.clone(),
            block_params: model.block_params.clone(),
        }
    }

    /// A synthetic T-block view from parameter counts alone — for
    /// artifact-free schedule enumeration (tests, the zoo example).
    pub fn synthetic(counts: &[u64]) -> Self {
        ModelView {
            num_blocks: counts.len(),
            block_param_counts: counts.to_vec(),
            block_params: (1..=counts.len()).map(|t| vec![format!("block{t}_w")]).collect(),
        }
    }
}

/// A contiguous trainable window over a T-block model: blocks
/// `[0, frozen)` are frozen (weights resident, no gradients), blocks
/// `[frozen, depth)` are trainable, blocks past `depth` are not
/// materialized this round.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct BlockLayout {
    /// Frozen prefix length in blocks.
    pub frozen: usize,
    /// Resident model depth in blocks (`frozen <= depth`).
    pub depth: usize,
}

impl BlockLayout {
    /// The full-model layout: every block resident and trainable.
    pub fn full(num_blocks: usize) -> Self {
        BlockLayout { frozen: 0, depth: num_blocks }
    }

    /// Number of trainable blocks in the window.
    pub fn trainable_blocks(&self) -> usize {
        self.depth.saturating_sub(self.frozen)
    }
}

/// Bytes per f32 parameter.
pub const BYTES_PER_PARAM: u64 = 4;
/// Extra per-parameter copies a trainable parameter carries (gradient +
/// SGD momentum) on top of its resident weight.
pub const OPT_STATE_FACTOR: u64 = 2;
/// Activation proxy: per-sample activation bytes ≈ resident parameter
/// bytes / 10 (calibrated against the manifest's ResNet18 coefficients:
/// 11.2M params ⇒ ≈4.4MB activations per sample).
pub const ACT_DIVISOR: u64 = 10;

/// Analytic training footprint of a [`BlockLayout`] over per-block
/// parameter counts. Resident weights cost 1× their bytes, trainable
/// parameters add [`OPT_STATE_FACTOR`]× for gradients + optimizer
/// state, and per-sample activations scale with the resident depth.
///
/// Two invariants hold by construction (and are property-tested):
/// growing the trainable window never shrinks the footprint, and no
/// layout exceeds [`BlockLayout::full`] (full-model training).
pub fn layout_mem(counts: &[u64], layout: &BlockLayout) -> MemCoeffs {
    let depth = layout.depth.min(counts.len());
    let frozen = layout.frozen.min(depth);
    let resident: u64 = counts[..depth].iter().sum();
    let trainable: u64 = counts[frozen..depth].iter().sum();
    MemCoeffs {
        fixed_bytes: BYTES_PER_PARAM * (resident + OPT_STATE_FACTOR * trainable),
        per_sample_bytes: BYTES_PER_PARAM * resident / ACT_DIVISOR,
        params_total: resident,
        params_trainable: trainable,
    }
}

/// Deepest layout `{frozen, d}` (`d` in `frozen+1 ..= counts.len()`)
/// whose [`layout_mem`] footprint at the accounting batch fits a static
/// budget; `None` when even a single trainable block does not fit. This
/// is the per-client depth cap `layerfreeze` applies under
/// [`DeviceMemory`](crate::memory::DeviceMemory) fit.
pub fn depth_cap(counts: &[u64], frozen: usize, budget_bytes: u64, batch: u64) -> Option<BlockLayout> {
    for d in (frozen + 1..=counts.len()).rev() {
        let l = BlockLayout { frozen, depth: d };
        if layout_mem(counts, &l).bytes_at(batch) <= budget_bytes {
            return Some(l);
        }
    }
    None
}

/// What actually happened while executing the previous phase.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct StepFeedback {
    /// Rounds the phase consumed (≤ its `max_rounds`).
    pub rounds_used: usize,
    /// Whether an EM-gated phase ended by freezing (vs budget expiry).
    pub froze: bool,
}

/// One federated-training phase: a fixed trainable layout driven for up
/// to `max_rounds` rounds.
#[derive(Debug, Clone, PartialEq)]
pub struct TrainPhase {
    /// Stage tag recorded per round ("shrink", "grow", "layerfreeze", …).
    pub stage: String,
    /// Step index recorded per round (block / boundary number).
    pub step: usize,
    /// The strategy's semantic trainable window (memory accounting).
    pub layout: BlockLayout,
    /// Training artifact dispatched to memory-fit clients.
    pub train_artifact: String,
    /// Fallback artifact for clients that cannot fit `train_artifact`
    /// (ProFL's output-module handshake); `None` excludes them.
    pub fallback_artifact: Option<String>,
    /// Evaluation artifact for the periodic test pass.
    pub eval_artifact: String,
    /// Parameter names fed to the freeze detector each round.
    pub observe_params: Vec<String>,
    /// Client learning rate for the phase.
    pub lr: f32,
    /// Round budget: the phase ends after this many rounds at the latest.
    pub max_rounds: usize,
    /// Rounds that must elapse before an EM freeze may end the phase.
    pub min_rounds: usize,
    /// Whether the EM detector may end the phase early (`false` = the
    /// phase always runs to `max_rounds`).
    pub em_gated: bool,
}

/// One federated-distillation phase (ProFL's *Map*).
#[derive(Debug, Clone, PartialEq)]
pub struct DistillPhase {
    /// Stage tag recorded per round ("map").
    pub stage: String,
    /// Step index recorded per round.
    pub step: usize,
    /// Distillation artifact.
    pub artifact: String,
    /// Number of distillation rounds.
    pub rounds: usize,
    /// Client learning rate.
    pub lr: f32,
}

/// One entry of a strategy's schedule.
#[derive(Debug, Clone, PartialEq)]
pub enum Phase {
    /// A freeze/layout transition: the coordinator bumps its prefix
    /// version and records it in the [`TransitionLog`](crate::freezing::TransitionLog)
    /// (stale in-flight updates from before the transition are projected
    /// or dropped per the stale-projection policy).
    Transition,
    /// A training phase.
    Train(TrainPhase),
    /// A distillation phase.
    Distill(DistillPhase),
}

/// A memory-wall strategy: owns the trainable layout per round, the
/// advance/freeze trigger, and the output-module handshake, expressed
/// as a lazy phase schedule.
///
/// Contract: [`next_phase`](Self::next_phase) is called repeatedly until
/// it returns `None`. The `last` argument carries the
/// [`StepFeedback`] of the *previous* `Train`/`Distill` phase (or `None`
/// after a `Transition` / on the first call) — strategies use it for
/// budget bookkeeping (e.g. ProFL's shared shrink+grow round budget).
pub trait MemoryStrategy {
    /// Display name (summaries, telemetry `strategy` attribute).
    fn name(&self) -> &'static str;

    /// Whether the strategy can use every client (the paper's
    /// "Inclusive?" column).
    fn inclusive(&self) -> bool {
        true
    }

    /// Produce the next phase of the schedule, or `None` when done.
    fn next_phase(
        &mut self,
        model: &ModelView,
        cfg: &RunConfig,
        last: Option<&StepFeedback>,
    ) -> Option<Phase>;

    /// Artifact for the end-of-run evaluation pass.
    fn final_eval_artifact(&self, model: &ModelView) -> String;

    /// Artifact whose footprint defines run-level participation (for
    /// inclusive strategies: the output-module fallback).
    fn participation_artifact(&self, model: &ModelView) -> String;

    /// Serialize the schedule position (cursor, budgets, pending
    /// bookkeeping) into an opaque blob for the checkpoint writer (see
    /// `docs/CHECKPOINT.md`). A stateless strategy returns an empty
    /// blob; the blob format is the strategy's own business — only
    /// [`Self::load_state`] ever reads it back.
    fn save_state(&self) -> Vec<u8> {
        Vec::new()
    }

    /// Restore a position previously produced by [`Self::save_state`]
    /// on a freshly constructed strategy. The default refuses: a
    /// strategy must opt in to resume by round-tripping its own state,
    /// so a checkpoint can never silently restart a schedule whose
    /// cursor it failed to carry.
    fn load_state(&mut self, _bytes: &[u8]) -> Result<()> {
        anyhow::bail!("strategy `{}` does not support checkpoint/resume", self.name())
    }
}

/// Reconstruct the strategy a checkpoint names
/// ([`Checkpoint::strategy_name`], a [`MemoryStrategy::name`] display
/// string), ready for [`MemoryStrategy::load_state`]. Every shipped
/// strategy resolves; anything else is a readable rejection.
pub fn strategy_for_resume(name: &str) -> Result<Box<dyn MemoryStrategy>> {
    match name {
        "ProFL" => Ok(Box::new(Progressive::new(FreezePolicy::EffectiveMovement))),
        "ParamAware" => Ok(Box::new(Progressive::new(FreezePolicy::ParamAware))),
        "LayerFreeze" => Ok(Box::new(LayerFreeze::default())),
        "Elastic" => Ok(Box::new(Elastic::default())),
        other => anyhow::bail!(
            "checkpoint was written by strategy `{other}`, which this build cannot resume \
             (known: ProFL|ParamAware|LayerFreeze|Elastic)"
        ),
    }
}

/// Execute one [`TrainPhase`] against the coordinator. This is the
/// legacy `ProFL::run_step` loop verbatim — per round: train, flatten
/// the observed block, feed the freeze detector (with the telemetry
/// `freeze.observe` span + `freeze.em` gauge, now strategy-tagged),
/// evaluate on the cadence, record, and stop early on an EM freeze once
/// `min_rounds` have elapsed.
fn run_train_phase(
    ctx: &mut ServerCtx,
    strategy: &dyn MemoryStrategy,
    p: &TrainPhase,
    sink: Option<&CkptSink>,
) -> Result<StepFeedback> {
    let mut det = FreezeDetector::new(ctx.cfg.freeze.into());
    run_train_phase_at(ctx, strategy, p, &mut det, 0, sink)
}

/// The [`TrainPhase`] loop starting from phase-round `start_r` with an
/// already-positioned freeze detector — the resume entry point
/// (`start_r = 0` + a fresh detector is a plain phase run). Per-round
/// behaviour is byte-identical to the uninterrupted loop: the round
/// body depends only on the phase-round index `r` and state carried in
/// `ctx`/`det`, both of which the checkpoint restores exactly. When a
/// `sink` is armed, a [`Checkpoint`] is written at every due round
/// boundary *after* the round's record lands (and before an EM-gate
/// break, so the final boundary of a frozen phase is captured too).
fn run_train_phase_at(
    ctx: &mut ServerCtx,
    strategy: &dyn MemoryStrategy,
    p: &TrainPhase,
    det: &mut FreezeDetector,
    start_r: usize,
    sink: Option<&CkptSink>,
) -> Result<StepFeedback> {
    let mut used = start_r;
    let mut froze = false;
    for r in start_r..p.max_rounds {
        let out =
            ctx.run_train_round(&p.train_artifact, p.fallback_artifact.as_deref(), p.lr, &p.stage, p.step)?;
        let snapshot = ctx.store.flatten(&p.observe_params);
        let t_observe = ctx.telemetry_mut().is_some().then(std::time::Instant::now);
        let (em, em_freeze) = det.observe(&snapshot);
        if let Some(t0) = t_observe {
            let round = ctx.round;
            let sim_s = ctx.sim_time_s;
            let consecutive = det.consecutive();
            if let Some(tel) = ctx.telemetry_mut() {
                use crate::json::Value;
                let attrs = [
                    ("stage", Value::Str(p.stage.clone())),
                    ("step", Value::Num(p.step as f64)),
                    ("consecutive", Value::Num(consecutive as f64)),
                    ("freeze", Value::Bool(em_freeze)),
                    ("strategy", Value::Str(strategy.name().to_string())),
                ];
                tel.span("freeze.observe", round, sim_s, t0.elapsed().as_secs_f64(), &attrs);
                tel.gauge("freeze.em", round, sim_s, em.unwrap_or(f64::NAN), &attrs);
            }
        }
        let test_acc = if r % ctx.cfg.eval_every == 0 || r + 1 == p.max_rounds {
            ctx.evaluate(&p.eval_artifact)?.acc
        } else {
            f32::NAN
        };
        ctx.record_round(&p.stage, p.step, &out, test_acc, em.unwrap_or(f64::NAN));
        used += 1;
        if p.em_gated && em_freeze && r + 1 >= p.min_rounds {
            froze = true;
        }
        if let Some(s) = sink {
            if s.due(ctx.round) {
                let mid =
                    MidPhase::Train { phase: p.clone(), detector: det.snapshot(), used, froze };
                s.write(&gather(ctx, strategy, Some(mid)), ctx.round)?;
            }
        }
        if froze {
            break;
        }
    }
    Ok(StepFeedback { rounds_used: used, froze })
}

/// Execute one [`DistillPhase`] — the legacy shrink-stage *Map* loop.
fn run_distill_phase(
    ctx: &mut ServerCtx,
    strategy: &dyn MemoryStrategy,
    d: &DistillPhase,
    sink: Option<&CkptSink>,
) -> Result<StepFeedback> {
    run_distill_phase_at(ctx, strategy, d, 0, sink)
}

/// The [`DistillPhase`] loop starting from phase-round `start_r` — the
/// resume entry point (`start_r = 0` is a plain phase run).
fn run_distill_phase_at(
    ctx: &mut ServerCtx,
    strategy: &dyn MemoryStrategy,
    d: &DistillPhase,
    start_r: usize,
    sink: Option<&CkptSink>,
) -> Result<StepFeedback> {
    let mut used = start_r;
    for _ in start_r..d.rounds {
        let out = ctx.run_distill_round(&d.artifact, d.lr)?;
        ctx.record_round(&d.stage, d.step, &out, f32::NAN, f64::NAN);
        used += 1;
        if let Some(s) = sink {
            if s.due(ctx.round) {
                let mid = MidPhase::Distill { phase: d.clone(), used };
                s.write(&gather(ctx, strategy, Some(mid)), ctx.round)?;
            }
        }
    }
    Ok(StepFeedback { rounds_used: used, froze: false })
}

/// Drive a [`MemoryStrategy`] end to end against the fleet simulator and
/// produce its [`RunSummary`]. The caller passes the *final* config
/// (any method-level overrides already applied) — the driver clones it
/// into the [`ServerCtx`] exactly as the legacy method loop did. When
/// `cfg.checkpoint` is set, the run writes a [`Checkpoint`] of its
/// complete state at every due round boundary (see `docs/CHECKPOINT.md`).
pub fn run_strategy(
    strategy: &mut dyn MemoryStrategy,
    rt: &Runtime,
    cfg: &RunConfig,
) -> Result<RunSummary> {
    let sink = CkptSink::from_cfg(cfg)?;
    let mut ctx = ServerCtx::new(rt, cfg.clone())?;
    drive_strategy(strategy, &mut ctx, sink.as_ref(), None)
}

/// Reconstruct the run a checkpoint captured and continue it to the end.
/// The strategy is rebuilt from the checkpoint's name + state blob, the
/// coordinator from its resolved config + serialized state, the
/// interrupted phase finishes from its saved round index with its saved
/// freeze-detector state, and the schedule loop then proceeds normally —
/// producing the remaining `RoundRecord` history bit-for-bit equal to
/// the uninterrupted run's, at any thread count. The caller passes the
/// resolved config (normally [`Checkpoint::resolve_config`]'s output
/// plus wall-clock overrides like `--threads`); it is re-verified
/// against the checkpoint's `config_sha256` here.
pub fn resume_strategy(rt: &Runtime, ck: &Checkpoint, cfg: &RunConfig) -> Result<RunSummary> {
    ck.verify_config(cfg)?;
    let mut strategy = strategy_for_resume(&ck.strategy_name)?;
    strategy.load_state(&ck.strategy_blob)?;
    let sink = CkptSink::from_cfg(cfg)?;
    let mut ctx = ServerCtx::new(rt, cfg.clone())?;
    apply_to_ctx(ck, &mut ctx)?;
    // Finish the interrupted phase first; its feedback then feeds the
    // normal schedule loop exactly as the uninterrupted run's would.
    let first = match &ck.mid {
        None => None,
        Some(MidPhase::Train { phase, detector, used, froze }) => {
            if *froze || *used >= phase.max_rounds {
                Some(StepFeedback { rounds_used: *used, froze: *froze })
            } else {
                let mut det = FreezeDetector::restore(ctx.cfg.freeze.into(), detector.clone());
                Some(run_train_phase_at(&mut ctx, &*strategy, phase, &mut det, *used, sink.as_ref())?)
            }
        }
        Some(MidPhase::Distill { phase, used }) => {
            if *used >= phase.rounds {
                Some(StepFeedback { rounds_used: *used, froze: false })
            } else {
                Some(run_distill_phase_at(&mut ctx, &*strategy, phase, *used, sink.as_ref())?)
            }
        }
    };
    drive_strategy(&mut *strategy, &mut ctx, sink.as_ref(), first)
}

/// The shared schedule loop + finalization tail behind [`run_strategy`]
/// and [`resume_strategy`]: pull phases until the strategy is done, then
/// evaluate and assemble the [`RunSummary`]. `last` carries the feedback
/// of a phase the caller already executed (the resumed one), or `None`
/// for a fresh run.
fn drive_strategy(
    strategy: &mut dyn MemoryStrategy,
    ctx: &mut ServerCtx,
    sink: Option<&CkptSink>,
    mut last: Option<StepFeedback>,
) -> Result<RunSummary> {
    let model = ctx.rt.model(&ctx.cfg.model_tag)?;
    let view = ModelView::of(model);
    let op_mem = model
        .artifact(&strategy.participation_artifact(&view))
        .map(|a| a.participation_mem())
        .unwrap_or_default();
    let cfg = ctx.cfg.clone();

    while let Some(phase) = strategy.next_phase(&view, &cfg, last.as_ref()) {
        last = match phase {
            Phase::Transition => {
                ctx.bump_prefix_version();
                None
            }
            Phase::Train(p) => Some(run_train_phase(ctx, &*strategy, &p, sink)?),
            Phase::Distill(d) => Some(run_distill_phase(ctx, &*strategy, &d, sink)?),
        };
    }

    let final_eval = ctx.evaluate(&strategy.final_eval_artifact(&view))?;
    let (up, down) = ctx.metrics.total_bytes();
    let mut final_acc = ctx.metrics.final_acc(ctx.cfg.acc_tail);
    if final_acc == 0.0 {
        final_acc = final_eval.acc as f64;
    }
    // Inclusive participation: anyone who can at least train the
    // strategy's participation artifact takes part (§4.1).
    let pr = ctx.pool.participation_rate(&op_mem);
    Ok(RunSummary {
        method: strategy.name().into(),
        model_tag: ctx.cfg.model_tag.clone(),
        partition: ctx.cfg.partition().label(),
        final_acc,
        participation_rate: pr,
        peak_client_mem: ctx.metrics.peak_client_mem(),
        total_bytes_up: up,
        total_bytes_down: down,
        rounds: ctx.round,
        sim_time_s: ctx.sim_time_s,
        transitions: ctx.transition_log().entries().to_vec(),
        history: ctx.metrics.records.clone(),
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    const COUNTS: [u64; 4] = [2_000_000, 3_000_000, 3_000_000, 3_200_000];

    #[test]
    fn layout_mem_monotone_in_window() {
        // Growing the trainable window (deeper, or less frozen) never
        // shrinks the footprint.
        let batch = 128;
        let mut prev = 0;
        for depth in 1..=COUNTS.len() {
            let b = layout_mem(&COUNTS, &BlockLayout { frozen: 0, depth }).bytes_at(batch);
            assert!(b >= prev, "depth {depth}: {b} < {prev}");
            prev = b;
        }
        // Unfreezing front blocks (fixed depth) also only grows it.
        prev = 0;
        for frozen in (0..COUNTS.len()).rev() {
            let b =
                layout_mem(&COUNTS, &BlockLayout { frozen, depth: COUNTS.len() }).bytes_at(batch);
            assert!(b >= prev, "frozen {frozen}: {b} < {prev}");
            prev = b;
        }
    }

    #[test]
    fn layout_mem_bounded_by_full_model() {
        let batch = 128;
        let full = layout_mem(&COUNTS, &BlockLayout::full(COUNTS.len())).bytes_at(batch);
        for frozen in 0..COUNTS.len() {
            for depth in frozen..=COUNTS.len() {
                let b = layout_mem(&COUNTS, &BlockLayout { frozen, depth }).bytes_at(batch);
                assert!(b <= full, "layout {{{frozen}, {depth}}} exceeds full-model {full}");
            }
        }
    }

    #[test]
    fn layout_mem_magnitudes_match_manifest_scale() {
        // ResNet18-scale sanity: ~11.2M params full-model ≈ 134MB fixed
        // + ~4.5MB/sample — the same regime as the manifest coefficients
        // used throughout memory.rs tests (131MB + 4.4MB/sample).
        let m = layout_mem(&COUNTS, &BlockLayout::full(COUNTS.len()));
        assert!((120..150).contains(&(m.fixed_bytes / 1_000_000)), "{}", m.fixed_bytes);
        assert!((3..6).contains(&(m.per_sample_bytes / 1_000_000)), "{}", m.per_sample_bytes);
        assert_eq!(m.params_trainable, COUNTS.iter().sum::<u64>());
    }

    #[test]
    fn depth_cap_respects_budget_and_frozen_floor() {
        let batch = 128;
        // A huge budget admits the full depth; a tiny one admits none.
        let full = depth_cap(&COUNTS, 0, u64::MAX, batch).unwrap();
        assert_eq!(full, BlockLayout::full(COUNTS.len()));
        assert!(depth_cap(&COUNTS, 0, 1, batch).is_none());
        // Every returned layout actually fits, and deepens with budget.
        let mut prev_depth = 0;
        for budget_mb in [30u64, 60, 120, 250, 500, 1000] {
            let budget = budget_mb * 1_000_000;
            if let Some(l) = depth_cap(&COUNTS, 1, budget, batch) {
                assert!(layout_mem(&COUNTS, &l).bytes_at(batch) <= budget);
                assert!(l.depth >= prev_depth, "cap not monotone in budget");
                assert_eq!(l.frozen, 1);
                prev_depth = l.depth;
            }
        }
    }

    #[test]
    fn synthetic_view_shape() {
        let v = ModelView::synthetic(&COUNTS);
        assert_eq!(v.num_blocks, 4);
        assert_eq!(v.block_params.len(), 4);
        assert_eq!(v.block_params[2], vec!["block3_w".to_string()]);
    }

    #[test]
    fn strategy_for_resume_maps_every_display_name() {
        for name in ["ProFL", "ParamAware", "LayerFreeze", "Elastic"] {
            let s = strategy_for_resume(name).unwrap();
            assert_eq!(s.name(), name);
        }
        assert!(strategy_for_resume("FedAvg").is_err(), "non-strategy methods rejected");
        assert!(strategy_for_resume("profl").is_err(), "display names, not CLI spellings");
        // Fresh strategies round-trip their own empty-position blobs.
        for name in ["ProFL", "ParamAware", "LayerFreeze", "Elastic"] {
            let blob = strategy_for_resume(name).unwrap().save_state();
            let mut s = strategy_for_resume(name).unwrap();
            s.load_state(&blob).unwrap();
            assert_eq!(s.save_state(), blob);
        }
    }
}
