//! Heterogeneity-aware progressive layer freezing (arXiv 2408.09101
//! family): the model trains at full depth from round 0, front layers
//! freeze as they converge, and each client's trainable depth is capped
//! by its [`DeviceMemory`](crate::memory::DeviceMemory) fit.
//!
//! Mapping onto this repo's artifact vocabulary: the lowered artifact
//! family exposes frozen-prefix progressions (`train_t{t}` = prefix
//! `t-1` frozen, block `t` trainable), so the executable projection
//! drives the *front-most unfrozen block* through that family and
//! advances the frozen prefix when the EM detector reports convergence
//! — with no shrink stage, no distillation, and no per-step round cap
//! by default (layers freeze when converged, not when a timer expires).
//! The *analytic* layout each phase reports ([`BlockLayout`] with
//! `depth = T`) keeps the full model resident, which is what separates
//! layerfreeze's memory profile from ProFL's in the strategy zoo; the
//! per-client depth cap is the pure [`depth_cap`](super::depth_cap)
//! function, exercised by `examples/strategy_zoo.rs` and the
//! `fits_static` property tests. Clients that cannot fit even the
//! current front block fall back to the output module (inclusive).

use super::{run_strategy, BlockLayout, MemoryStrategy, ModelView, Phase, StepFeedback, TrainPhase};
use crate::checkpoint::{Dec, Enc};
use crate::config::RunConfig;
use crate::methods::Method;
use crate::metrics::RunSummary;
use crate::runtime::Runtime;
use anyhow::{bail, Result};

/// Schedule cursor: which block is the front-most unfrozen one.
#[derive(Debug, Clone, Copy, PartialEq, Default)]
enum Cursor {
    #[default]
    Start,
    /// About to emit the freeze transition entering step t.
    Enter(usize),
    /// About to emit the train phase for step t.
    Train(usize),
    Done,
}

/// Progressive layer freezing on the [`MemoryStrategy`] trait (also a
/// [`Method`]: `--method layerfreeze`).
#[derive(Debug, Default)]
pub struct LayerFreeze {
    cursor: Cursor,
    /// Rounds left of the `max_rounds_total` budget.
    remaining: usize,
    /// Whether the last emitted phase was a train phase (its feedback
    /// draws down the budget).
    awaiting_train: bool,
}

impl MemoryStrategy for LayerFreeze {
    fn name(&self) -> &'static str {
        "LayerFreeze"
    }

    fn next_phase(
        &mut self,
        model: &ModelView,
        cfg: &RunConfig,
        last: Option<&StepFeedback>,
    ) -> Option<Phase> {
        if self.awaiting_train {
            self.awaiting_train = false;
            let used = last.map_or(0, |f| f.rounds_used);
            self.remaining = self.remaining.saturating_sub(used);
        }
        if self.cursor == Cursor::Start {
            self.remaining = cfg.max_rounds_total;
            self.cursor = Cursor::Enter(1);
        }
        match self.cursor {
            Cursor::Start => unreachable!("resolved above"),
            Cursor::Enter(t) => {
                self.cursor = Cursor::Train(t);
                Some(Phase::Transition)
            }
            Cursor::Train(t) => {
                self.awaiting_train = true;
                self.cursor =
                    if t < model.num_blocks { Cursor::Enter(t + 1) } else { Cursor::Done };
                // Late steps are still guaranteed a minimum budget even
                // when earlier blocks refused to converge (same floor as
                // ProFL's grow stage); an explicit per-step cap can be
                // set with `--freeze-step-cap`.
                let budget = self.remaining.max(cfg.min_rounds_per_step);
                let max_rounds = match cfg.strategy.freeze_step_cap {
                    Some(cap) => cap.min(budget),
                    None => budget,
                };
                Some(Phase::Train(TrainPhase {
                    stage: "layerfreeze".into(),
                    step: t,
                    layout: BlockLayout { frozen: t - 1, depth: model.num_blocks },
                    train_artifact: format!("train_t{t}"),
                    fallback_artifact: Some(format!("train_op_t{t}")),
                    eval_artifact: format!("eval_t{t}"),
                    observe_params: model.block_params[t - 1].clone(),
                    lr: cfg.lr,
                    max_rounds,
                    min_rounds: cfg.min_rounds_per_step.min(max_rounds),
                    em_gated: true,
                }))
            }
            Cursor::Done => None,
        }
    }

    fn final_eval_artifact(&self, model: &ModelView) -> String {
        format!("eval_t{}", model.num_blocks)
    }

    fn participation_artifact(&self, model: &ModelView) -> String {
        format!("train_op_t{}", model.num_blocks)
    }

    fn save_state(&self) -> Vec<u8> {
        let mut e = Enc::new();
        let (tag, t) = match self.cursor {
            Cursor::Start => (0u8, 0usize),
            Cursor::Enter(t) => (1, t),
            Cursor::Train(t) => (2, t),
            Cursor::Done => (3, 0),
        };
        e.u8(tag);
        e.usize(t);
        e.usize(self.remaining);
        e.bool(self.awaiting_train);
        e.finish()
    }

    fn load_state(&mut self, bytes: &[u8]) -> Result<()> {
        let mut d = Dec::new(bytes);
        let tag = d.u8()?;
        let t = d.usize()?;
        self.cursor = match tag {
            0 => Cursor::Start,
            1 => Cursor::Enter(t),
            2 => Cursor::Train(t),
            3 => Cursor::Done,
            b => bail!("invalid layerfreeze cursor tag {b}"),
        };
        self.remaining = d.usize()?;
        self.awaiting_train = d.bool()?;
        d.done()
    }
}

impl Method for LayerFreeze {
    fn name(&self) -> &'static str {
        "LayerFreeze"
    }

    fn inclusive(&self) -> bool {
        true
    }

    fn run(&self, rt: &Runtime, cfg: &RunConfig) -> Result<RunSummary> {
        let mut schedule = LayerFreeze::default();
        run_strategy(&mut schedule, rt, cfg)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn view() -> ModelView {
        ModelView::synthetic(&[2_000_000, 3_000_000, 3_000_000, 3_200_000])
    }

    #[test]
    fn full_depth_from_round_zero_and_prefix_advances() {
        let v = view();
        let cfg = RunConfig::smoke("m");
        let mut s = LayerFreeze::default();
        let mut last = None;
        let mut steps = Vec::new();
        while let Some(p) = s.next_phase(&v, &cfg, last.as_ref()) {
            last = match &p {
                Phase::Transition => None,
                Phase::Train(t) => {
                    steps.push((t.step, t.layout));
                    Some(StepFeedback { rounds_used: 5.min(t.max_rounds), froze: true })
                }
                Phase::Distill(_) => unreachable!("layerfreeze never distills"),
            };
        }
        assert_eq!(steps.len(), 4);
        for (i, (step, layout)) in steps.iter().enumerate() {
            assert_eq!(*step, i + 1);
            // The analytic layout keeps the full model resident; only
            // the frozen prefix moves.
            assert_eq!(*layout, BlockLayout { frozen: i, depth: 4 });
        }
    }

    #[test]
    fn budget_is_convergence_driven_unless_capped() {
        let v = view();
        let mut cfg = RunConfig::smoke("m");
        let mut s = LayerFreeze::default();
        // First train phase sees the whole run budget (no per-step cap).
        let p = loop {
            match s.next_phase(&v, &cfg, None) {
                Some(Phase::Train(t)) => break t,
                Some(_) => continue,
                None => panic!("schedule ended early"),
            }
        };
        assert_eq!(p.max_rounds, cfg.max_rounds_total);
        assert!(p.em_gated);
        // With the cap knob set, steps are bounded like ProFL's.
        cfg.strategy.freeze_step_cap = Some(6);
        let mut s = LayerFreeze::default();
        let p = loop {
            match s.next_phase(&v, &cfg, None) {
                Some(Phase::Train(t)) => break t,
                Some(_) => continue,
                None => panic!("schedule ended early"),
            }
        };
        assert_eq!(p.max_rounds, 6);
    }

    #[test]
    fn save_load_round_trips_mid_schedule() {
        let v = view();
        let cfg = RunConfig::smoke("m");
        let mut s = LayerFreeze::default();
        let mut last = None;
        // Advance past the first train phase, then cut.
        for _ in 0..3 {
            if let Some(p) = s.next_phase(&v, &cfg, last.as_ref()) {
                last = match &p {
                    Phase::Train(t) => {
                        Some(StepFeedback { rounds_used: 5.min(t.max_rounds), froze: true })
                    }
                    _ => None,
                };
            }
        }
        let mut resumed = LayerFreeze::default();
        resumed.load_state(&s.save_state()).unwrap();
        assert_eq!(resumed.save_state(), s.save_state());
        let mut last2 = last;
        loop {
            let a = s.next_phase(&v, &cfg, last.as_ref());
            let b = resumed.next_phase(&v, &cfg, last2.as_ref());
            assert_eq!(a, b);
            match a {
                Some(Phase::Train(t)) => {
                    last = Some(StepFeedback { rounds_used: 5.min(t.max_rounds), froze: true });
                    last2 = last;
                }
                Some(_) => {
                    last = None;
                    last2 = None;
                }
                None => break,
            }
        }
        assert!(resumed.load_state(&[7]).is_err(), "garbage blob rejected");
    }
}
