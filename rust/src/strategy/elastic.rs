//! NeuLite-style elastic progressive blocks (arXiv 2408.10826): block
//! boundaries are not a fixed partition — each phase's trainable window
//! is the widest one whose analytic footprint fits a per-phase memory
//! budget curve, so the schedule adapts to the fleet's device budget
//! range instead of the architecture's block count.
//!
//! The budget curve ramps linearly across the configured device budget
//! range (`memory.budget_min_mb → memory.budget_max_mb`, the same range
//! [`DeviceMemory::sample`](crate::memory::DeviceMemory::sample) draws
//! from): early phases target what the *smallest* devices can train,
//! later phases what the largest can. A phase's window starts where the
//! previous one ended (completed blocks freeze), reaches as deep as its
//! curve point admits under [`layout_mem`](super::layout_mem), and runs
//! a fixed share of `max_rounds_total` — the advance trigger is the
//! budget curve, not the EM detector. If the curve never admits the
//! full depth, the deep blocks stay untrained (the honest NeuLite
//! trade-off) and the final evaluation runs at the reached depth.

use super::{run_strategy, BlockLayout, MemoryStrategy, ModelView, Phase, StepFeedback, TrainPhase};
use crate::checkpoint::{Dec, Enc};
use crate::config::RunConfig;
use crate::memory::MB;
use crate::methods::Method;
use crate::metrics::RunSummary;
use crate::runtime::Runtime;
use anyhow::{bail, Result};

/// One planned elastic phase.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct ElasticPhase {
    /// Trainable window for the phase.
    pub layout: BlockLayout,
    /// Memory budget (bytes) the window was fitted under.
    pub budget_bytes: u64,
    /// Round allotment.
    pub rounds: usize,
}

/// Plan the elastic schedule for a model: `elastic_phases` curve points
/// (default: one per block), each fitting the widest window that the
/// linearly-ramping budget admits at the accounting batch. Planning is
/// pure — `examples/strategy_zoo.rs` and the property tests call it
/// without artifacts.
pub fn plan(counts: &[u64], cfg: &RunConfig) -> Vec<ElasticPhase> {
    let phases = cfg.strategy.elastic_phases.unwrap_or(counts.len()).max(1);
    let lo = cfg.memory.budget_min_mb as f64;
    let hi = cfg.memory.budget_max_mb as f64;
    let batch = cfg.memory.accounting_batch;
    let mut out: Vec<ElasticPhase> = Vec::new();
    let mut reached = 0usize;
    for p in 0..phases {
        let budget_mb = lo + (hi - lo) * (p + 1) as f64 / phases as f64;
        let budget_bytes = (budget_mb * MB as f64) as u64;
        let frozen = reached;
        // Widest admissible window; the floor is one block, so a curve
        // point below even that still makes progress.
        let mut depth = (frozen + 1).min(counts.len());
        for cand in (frozen + 1..=counts.len()).rev() {
            let l = BlockLayout { frozen, depth: cand };
            if super::layout_mem(counts, &l).bytes_at(batch) <= budget_bytes {
                depth = cand;
                break;
            }
        }
        out.push(ElasticPhase { layout: BlockLayout { frozen, depth }, budget_bytes, rounds: 0 });
        reached = depth;
        if reached == counts.len() {
            break;
        }
    }
    // Split the run budget evenly; the remainder lands on the last
    // (deepest) phase, and every phase gets at least one round.
    let n = out.len();
    let base = cfg.max_rounds_total / n;
    let rem = cfg.max_rounds_total % n;
    for (i, ph) in out.iter_mut().enumerate() {
        ph.rounds = (base + if i + 1 == n { rem } else { 0 }).max(1);
    }
    out
}

/// Elastic progressive blocks on the [`MemoryStrategy`] trait (also a
/// [`Method`]: `--method elastic`).
#[derive(Debug, Default)]
pub struct Elastic {
    planned: Option<Vec<ElasticPhase>>,
    idx: usize,
    /// Whether the pending emission is the train half of phase `idx`
    /// (the transition half was already emitted).
    entered: bool,
}

impl Elastic {
    /// The depth the planned schedule reaches (for the final eval).
    fn reached_depth(planned: &[ElasticPhase], num_blocks: usize) -> usize {
        planned.last().map_or(num_blocks, |p| p.layout.depth)
    }
}

impl MemoryStrategy for Elastic {
    fn name(&self) -> &'static str {
        "Elastic"
    }

    fn next_phase(
        &mut self,
        model: &ModelView,
        cfg: &RunConfig,
        _last: Option<&StepFeedback>,
    ) -> Option<Phase> {
        let planned =
            self.planned.get_or_insert_with(|| plan(&model.block_param_counts, cfg)).clone();
        let ph = planned.get(self.idx)?;
        if !self.entered {
            self.entered = true;
            return Some(Phase::Transition);
        }
        self.entered = false;
        self.idx += 1;
        let t = ph.layout.depth;
        // The executable projection drives the window's deepest block
        // through the `train_t{t}` artifact family; the EM detector
        // observes the whole window (reported, never gating).
        let window = &model.block_params[ph.layout.frozen..ph.layout.depth];
        let observe_params: Vec<String> = window.iter().flat_map(|b| b.iter().cloned()).collect();
        Some(Phase::Train(TrainPhase {
            stage: "elastic".into(),
            step: t,
            layout: ph.layout,
            train_artifact: format!("train_t{t}"),
            fallback_artifact: Some(format!("train_op_t{t}")),
            eval_artifact: format!("eval_t{t}"),
            observe_params,
            lr: cfg.lr,
            max_rounds: ph.rounds,
            min_rounds: cfg.min_rounds_per_step.min(ph.rounds),
            em_gated: false,
        }))
    }

    fn final_eval_artifact(&self, model: &ModelView) -> String {
        let depth = self
            .planned
            .as_deref()
            .map_or(model.num_blocks, |p| Self::reached_depth(p, model.num_blocks));
        format!("eval_t{depth}")
    }

    fn participation_artifact(&self, model: &ModelView) -> String {
        format!("train_op_t{}", model.num_blocks)
    }

    fn save_state(&self) -> Vec<u8> {
        let mut e = Enc::new();
        match &self.planned {
            None => e.u8(0),
            Some(phases) => {
                e.u8(1);
                e.usize(phases.len());
                for p in phases {
                    e.usize(p.layout.frozen);
                    e.usize(p.layout.depth);
                    e.u64(p.budget_bytes);
                    e.usize(p.rounds);
                }
            }
        }
        e.usize(self.idx);
        e.bool(self.entered);
        e.finish()
    }

    fn load_state(&mut self, bytes: &[u8]) -> Result<()> {
        let mut d = Dec::new(bytes);
        self.planned = match d.u8()? {
            0 => None,
            1 => {
                let n = d.seq_len(32)?;
                let mut phases = Vec::with_capacity(n);
                for _ in 0..n {
                    phases.push(ElasticPhase {
                        layout: BlockLayout { frozen: d.usize()?, depth: d.usize()? },
                        budget_bytes: d.u64()?,
                        rounds: d.usize()?,
                    });
                }
                Some(phases)
            }
            b => bail!("invalid elastic plan tag {b}"),
        };
        self.idx = d.usize()?;
        self.entered = d.bool()?;
        d.done()
    }
}

impl Method for Elastic {
    fn name(&self) -> &'static str {
        "Elastic"
    }

    fn inclusive(&self) -> bool {
        true
    }

    fn run(&self, rt: &Runtime, cfg: &RunConfig) -> Result<RunSummary> {
        let mut schedule = Elastic::default();
        run_strategy(&mut schedule, rt, cfg)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::strategy::layout_mem;

    const COUNTS: [u64; 4] = [2_000_000, 3_000_000, 3_000_000, 3_200_000];

    #[test]
    fn plan_windows_fit_their_budgets_and_tile_the_depth() {
        let cfg = RunConfig::smoke("m");
        let phases = plan(&COUNTS, &cfg);
        assert!(!phases.is_empty());
        let batch = cfg.memory.accounting_batch;
        let mut prev_depth = 0;
        let mut total_rounds = 0;
        for ph in &phases {
            assert_eq!(ph.layout.frozen, prev_depth, "windows tile without gaps");
            assert!(ph.layout.depth > ph.layout.frozen, "non-empty window");
            // Either the window fits its curve point, or it is the
            // single-block floor (progress is guaranteed).
            let fits = layout_mem(&COUNTS, &ph.layout).bytes_at(batch) <= ph.budget_bytes;
            assert!(fits || ph.layout.trainable_blocks() == 1);
            assert!(ph.rounds >= 1);
            prev_depth = ph.layout.depth;
            total_rounds += ph.rounds;
        }
        assert_eq!(total_rounds, cfg.max_rounds_total.max(phases.len()));
    }

    #[test]
    fn wider_budget_range_means_wider_windows() {
        let mut cfg = RunConfig::smoke("m");
        cfg.memory.budget_min_mb = 900;
        cfg.memory.budget_max_mb = 900;
        // A uniformly huge budget fits everything in one window.
        let phases = plan(&COUNTS, &cfg);
        assert_eq!(phases.len(), 1);
        assert_eq!(phases[0].layout, BlockLayout::full(COUNTS.len()));
        // A tiny budget degenerates to one block per phase.
        cfg.memory.budget_min_mb = 10;
        cfg.memory.budget_max_mb = 20;
        let phases = plan(&COUNTS, &cfg);
        assert!(phases.iter().all(|p| p.layout.trainable_blocks() == 1));
    }

    #[test]
    fn schedule_alternates_transition_train_and_ends() {
        let v = ModelView::synthetic(&COUNTS);
        let cfg = RunConfig::smoke("m");
        let mut s = Elastic::default();
        let mut kinds = Vec::new();
        while let Some(p) = s.next_phase(&v, &cfg, None) {
            kinds.push(match p {
                Phase::Transition => 'T',
                Phase::Train(_) => 't',
                Phase::Distill(_) => 'd',
            });
        }
        assert!(!kinds.is_empty());
        assert!(kinds.len() % 2 == 0);
        assert!(kinds.chunks(2).all(|c| c == ['T', 't']));
    }

    #[test]
    fn save_load_round_trips_the_lazy_plan() {
        let v = ModelView::synthetic(&COUNTS);
        let cfg = RunConfig::smoke("m");
        // Cut after 3 emissions (mid phase 2): the resumed strategy must
        // carry the *materialized* plan, not re-plan.
        let mut s = Elastic::default();
        for _ in 0..3 {
            s.next_phase(&v, &cfg, None);
        }
        let mut resumed = Elastic::default();
        resumed.load_state(&s.save_state()).unwrap();
        assert_eq!(resumed.save_state(), s.save_state());
        loop {
            let a = s.next_phase(&v, &cfg, None);
            let b = resumed.next_phase(&v, &cfg, None);
            assert_eq!(a, b);
            if a.is_none() {
                break;
            }
        }
        // A fresh (never-planned) strategy round-trips too.
        let fresh = Elastic::default();
        let mut r2 = Elastic::default();
        r2.load_state(&fresh.save_state()).unwrap();
        assert_eq!(r2.save_state(), fresh.save_state());
        assert!(r2.load_state(&[2]).is_err(), "garbage blob rejected");
    }

    #[test]
    fn elastic_phase_knob_changes_curve_resolution() {
        let mut cfg = RunConfig::smoke("m");
        cfg.strategy.elastic_phases = Some(2);
        let coarse = plan(&COUNTS, &cfg);
        assert!(coarse.len() <= 2);
        cfg.strategy.elastic_phases = Some(8);
        let fine = plan(&COUNTS, &cfg);
        assert!(fine.len() <= 8);
    }
}
