//! # ProFL — breaking the memory wall for heterogeneous federated learning
//!
//! Production-grade reproduction of *"Breaking the Memory Wall for
//! Heterogeneous Federated Learning via Progressive Training"* (KDD 2025)
//! as a three-layer Rust + JAX + Pallas stack:
//!
//! * **L3 (this crate)** — the federated coordinator: memory-aware client
//!   selection, progressive shrink/grow scheduling, block freezing
//!   determination (effective movement), FedAvg aggregation, all
//!   baselines, metrics. Python never runs on the round path.
//! * **L3 fleet simulator (`fleet`)** — a deterministic discrete-event
//!   engine behind every train round: per-client [`fleet::DeviceProfile`]s
//!   (compute, links, availability, dropout), a virtual clock, and a
//!   [`fleet::RoundPolicy`] (`sync` / `deadline` / `over-select` /
//!   FedBuff-style `async`) deciding who aggregates, with mid-round
//!   churn ([`fleet::ChurnPolicy`]: `abort`/`resume`/`checkpoint`)
//!   sampled inside every compute/upload span.
//! * **L2/L1 (`python/compile`)** — JAX block models + Pallas kernels,
//!   AOT-lowered once to HLO-text artifacts (`make artifacts`).
//! * **Runtime bridge** — [`runtime::Runtime`] loads the artifacts through
//!   the PJRT C API (`xla` crate) and executes them from the round loop.
//!
//! ## Documentation map
//!
//! The deep documentation lives under `docs/` at the repo root:
//!
//! * **`docs/ARCHITECTURE.md`** — the round-lifecycle dataflow (event
//!   engine → round policies → churn → stale-update projection), with
//!   the module map and an ASCII diagram of one virtualized round.
//! * **`docs/CLI.md`** — every `--flag` with its default, validation
//!   range, and which round/churn policies it composes with.
//! * **`docs/SIMULATION.md`** — the determinism contract: virtual
//!   clock, rng stream discipline, aggregation order, the degeneracy
//!   ladder, and the golden-trace workflow (`UPDATE_GOLDEN=1`).
//! * **`docs/PERFORMANCE.md`** — the O(cohort) round hot path: lazy
//!   client materialization (`--lazy-pool`), the engine's reusable
//!   round scratch, the contiguous aggregation arena, and the
//!   `make bench-json` → `BENCH_fleet.json` perf trajectory.
//! * **`docs/OBSERVABILITY.md`** — the structured-telemetry surface
//!   ([`telemetry`]): the `--telemetry-jsonl` event stream's schema and
//!   span/counter/gauge catalog, the `manifest.json` run-provenance
//!   record, and a jq cookbook.
//! * **`docs/STRATEGIES.md`** — the memory-strategy zoo ([`strategy`]):
//!   the [`strategy::MemoryStrategy`] trait contract (layouts, phases,
//!   advance/freeze semantics), the shipped strategies
//!   (`profl`/`paramaware`/`layerfreeze`/`elastic`), and how to add one.
//! * **`docs/CHECKPOINT.md`** — the checkpoint/resume subsystem
//!   ([`checkpoint`]): the versioned file format and its digest scheme,
//!   what run state a [`checkpoint::Checkpoint`] captures, the
//!   bit-for-bit resume contract (`--checkpoint` / `profl resume`), and
//!   the failure modes a corrupted or mismatched file is rejected with.
//!
//! `DESIGN.md` holds the full system inventory and experiment index;
//! `ROADMAP.md` the north-star and open items.
//!
//! ## Async rounds, staleness, and projection
//!
//! Under `--round-policy async` rounds are semi-synchronous and
//! round-spanning: a round closes at the `buffer_k`-th upload arrival,
//! and stragglers' uploads persist in the [`fleet::FleetEngine`]'s
//! cross-round in-flight queue (timing) plus the coordinator's
//! version-stamped pending buffer (tensors), then merge on arrival with
//! FedBuff weights `w / (1 + staleness)^alpha` via
//! [`aggregate::BufferedAggregator`].
//!
//! ProFL's progressive schedule means the trained block-prefix changes
//! *while uploads are in flight*. An update trained against a
//! since-frozen layout is dropped by default (`--stale-projection off`)
//! — or, with `--stale-projection on`, **projected onto the
//! still-trained suffix** ([`coordinator::projection`]): frozen-block
//! deltas are discarded and counted (`projected_dropped_params`), the
//! surviving tensors remap to the current layout and merge through the
//! masked aggregator path with an extra
//! `projection_decay^transitions_crossed` weight factor. Every
//! freeze/step transition is recorded in a [`freezing::TransitionLog`]
//! so transition-staleness stays auditable per run.
//!
//! ## Degeneracy ladder
//!
//! Each simulator axis costs nothing when unused, **bit for bit**
//! (integration- and golden-trace-tested; see `docs/SIMULATION.md`):
//! `async` with `buffer_k = per_round` + `alpha = 0` reproduces `sync`;
//! any churn policy on always-on traces reproduces `none`; projection
//! with no transition crossed reproduces the drop behaviour.
//!
//! ## Quick start
//!
//! ```bash
//! make artifacts                      # python AOT (once)
//! cargo run --release --example quickstart
//! cargo run --release -- run --method profl --model resnet18_w8_c10
//! make check                          # fmt + clippy + tests + docs gate
//! ```

#![warn(missing_docs)]

pub mod aggregate;
pub mod bench_util;
pub mod checkpoint;
pub mod cli;
pub mod clients;
pub mod config;
pub mod coordinator;
pub mod data;
pub mod fleet;
pub mod freezing;
pub mod harness;
pub mod json;
pub mod manifest;
pub mod memory;
pub mod methods;
pub mod metrics;
pub mod rng;
pub mod runtime;
pub mod store;
pub mod strategy;
pub mod telemetry;

pub use config::RunConfig;
pub use coordinator::ServerCtx;
pub use metrics::RunSummary;
pub use runtime::Runtime;

use std::path::PathBuf;

/// Default artifacts directory: `$PROFL_ARTIFACTS` or `./artifacts`.
pub fn artifacts_dir() -> PathBuf {
    std::env::var_os("PROFL_ARTIFACTS")
        .map(PathBuf::from)
        .unwrap_or_else(|| PathBuf::from("artifacts"))
}
