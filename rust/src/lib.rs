//! # ProFL — breaking the memory wall for heterogeneous federated learning
//!
//! Production-grade reproduction of *"Breaking the Memory Wall for
//! Heterogeneous Federated Learning via Progressive Training"* (KDD 2025)
//! as a three-layer Rust + JAX + Pallas stack:
//!
//! * **L3 (this crate)** — the federated coordinator: memory-aware client
//!   selection, progressive shrink/grow scheduling, block freezing
//!   determination (effective movement), FedAvg aggregation, all
//!   baselines, metrics. Python never runs on the round path.
//! * **L3 fleet simulator (`fleet`)** — a deterministic discrete-event
//!   engine (virtual clock + binary-heap event queue) behind every train
//!   round: each client carries a [`fleet::DeviceProfile`] (compute
//!   throughput, link speeds, availability trace, dropout), rounds
//!   dispatch their cohort as events, and a [`fleet::RoundPolicy`]
//!   (`sync` wait-for-all / `deadline{secs}` cut stragglers /
//!   `over-select{k}` keep first finishers / `async{buffer_k,
//!   max_staleness}` FedBuff-style buffering) decides who aggregates.
//!   Summaries report simulated time-to-accuracy (`sim_time_s`,
//!   stragglers, dropouts, late merges) alongside accuracy/memory/comm.
//!   CLI: `--round-policy`, `--deadline-s`, `--buffer-k`,
//!   `--staleness-alpha`, `--fleet-profile`.
//!
//!   **Mid-round churn** ([`fleet::ChurnPolicy`]): availability traces
//!   are sampled *inside* every compute/upload span, not just at
//!   dispatch. A device flipping offline mid-span emits an `Interrupt`
//!   event and the configured policy decides the outcome — `abort`
//!   (work lost; `wasted_compute_s` accounted), `resume` (work pauses
//!   and continues at the next online window, stretching finishes
//!   across round deadlines and the async in-flight queue), or
//!   `checkpoint` (a partial update at epoch granularity merges with
//!   weight ∝ completed samples through the aggregators — including
//!   HeteroFL/DepthFL's sliced merges). Round records carry
//!   `interrupted/resumed/partial_merged/wasted_compute_s`. Always-on
//!   traces take the pre-churn fast path, so every churn policy
//!   degenerates to `none` bit-for-bit (golden-trace- and
//!   integration-tested; `rust/tests/golden/` pins the full event
//!   trace of every round-policy × churn-policy combination). CLI:
//!   `--churn-policy`, `--churn-epochs`, `--trace-period`,
//!   `--trace-duty`.
//!
//!   Under `async`, rounds are semi-synchronous and round-spanning: the
//!   round closes at the `buffer_k`-th upload arrival, and stragglers'
//!   uploads are *not* discarded — they persist in the
//!   [`fleet::FleetEngine`]'s cross-round in-flight queue (timing) and
//!   the coordinator's version-stamped pending buffer (tensors), then
//!   merge on arrival with FedBuff weights `w / (1 + staleness)^alpha`
//!   via [`aggregate::BufferedAggregator`]. Updates older than
//!   `max_staleness` rounds, or trained against a since-frozen block
//!   (artifact/prefix-version mismatch — cheap to detect thanks to
//!   ProFL's frozen-prefix training), are dropped.
//!
//!   **Sync-degeneracy guarantee:** `--round-policy async` with
//!   `buffer_k = per_round` and `staleness_alpha = 0` closes every round
//!   at its last upload and discounts nothing, reproducing the `sync`
//!   policy's round records **bit for bit** (same event order, same rng
//!   stream, same FedAvg accumulation order). Integration tests pin this
//!   down; it also means the async machinery costs nothing when unused.
//! * **L2/L1 (`python/compile`)** — JAX block models + Pallas kernels,
//!   AOT-lowered once to HLO-text artifacts (`make artifacts`).
//! * **Runtime bridge** — [`runtime::Runtime`] loads the artifacts through
//!   the PJRT C API (`xla` crate) and executes them from the round loop.
//!
//! ## Quick start
//!
//! ```bash
//! make artifacts                      # python AOT (once)
//! cargo run --release --example quickstart
//! cargo run --release -- run --method profl --model resnet18_w8_c10
//! ```
//!
//! See `DESIGN.md` for the full system inventory and experiment index.

pub mod aggregate;
pub mod bench_util;
pub mod cli;
pub mod clients;
pub mod config;
pub mod coordinator;
pub mod data;
pub mod fleet;
pub mod freezing;
pub mod harness;
pub mod json;
pub mod manifest;
pub mod memory;
pub mod methods;
pub mod metrics;
pub mod rng;
pub mod runtime;
pub mod store;

pub use config::RunConfig;
pub use coordinator::ServerCtx;
pub use metrics::RunSummary;
pub use runtime::Runtime;

use std::path::PathBuf;

/// Default artifacts directory: `$PROFL_ARTIFACTS` or `./artifacts`.
pub fn artifacts_dir() -> PathBuf {
    std::env::var_os("PROFL_ARTIFACTS")
        .map(PathBuf::from)
        .unwrap_or_else(|| PathBuf::from("artifacts"))
}
