//! Federated data partitioning: IID and Dirichlet Non-IID (paper §4.1,
//! α = 1 by default), plus client-shard batch assembly.

use super::{SyntheticDataset, IMG_ELEMS};
use crate::rng::Rng;

/// How training data distributes across clients.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum Partition {
    /// Uniform label distribution on every client.
    Iid,
    /// Dirichlet(alpha) label-distribution skew per client.
    Dirichlet {
        /// Concentration parameter (paper: α = 1).
        alpha: f64,
    },
}

impl Partition {
    /// Human-readable scheme label (table/CSV column).
    pub fn label(&self) -> String {
        match self {
            Partition::Iid => "IID".into(),
            Partition::Dirichlet { alpha } => format!("Non-IID(α={alpha})"),
        }
    }
}

/// One client's local dataset: a label sequence + a private index stream.
/// Images are regenerated on demand from (class, global index) so shards
/// cost O(samples) u16 labels, not O(samples × 3072) floats.
#[derive(Debug, Clone)]
pub struct ClientShard {
    /// Owning client's pool index.
    pub client_id: usize,
    /// Per-sample class labels.
    pub labels: Vec<u16>,
    /// Global sample indices (unique across clients, disjoint from test).
    pub indices: Vec<u64>,
    cursor: usize,
}

impl ClientShard {
    /// Number of local samples (the FedAvg merge weight).
    pub fn num_samples(&self) -> usize {
        self.labels.len()
    }

    /// Fill a stacked (steps × batch) training chunk, cycling through the
    /// shard (clients train multiple local epochs over few samples, as in
    /// cross-device FL). Advances the shard cursor; the epoch RNG reshuffles
    /// nothing — order is the partition order, which is already random.
    pub fn fill_batches(
        &mut self,
        data: &SyntheticDataset,
        steps: usize,
        batch: usize,
        xs: &mut Vec<f32>,
        ys: &mut Vec<i32>,
    ) {
        let n = steps * batch;
        xs.resize(n * IMG_ELEMS, 0.0);
        ys.resize(n, 0);
        for i in 0..n {
            let j = (self.cursor + i) % self.labels.len();
            let class = self.labels[j] as usize;
            data.write_sample(class, self.indices[j], &mut xs[i * IMG_ELEMS..(i + 1) * IMG_ELEMS]);
            ys[i] = class as i32;
        }
        self.cursor = (self.cursor + n) % self.labels.len();
    }
}

/// Split `total_samples` across `num_clients`. Sample counts get a mild
/// random spread (clients differ in data volume, as in production
/// federations); labels per client come from the partition scheme.
pub fn partition(
    data: &SyntheticDataset,
    num_clients: usize,
    total_samples: usize,
    scheme: Partition,
    seed: u64,
) -> Vec<ClientShard> {
    let mut rng = Rng::new(seed ^ 0x9a7c_55aa_1234_5678);
    let k = data.num_classes;

    // Per-client sample counts: uniform share ± 50% jitter, min 8.
    let base = total_samples / num_clients;
    let mut counts: Vec<usize> = (0..num_clients)
        .map(|_| ((base as f64 * rng.uniform(0.5, 1.5)) as usize).max(8))
        .collect();
    // Renormalize roughly to the requested total.
    let s: usize = counts.iter().sum();
    for c in &mut counts {
        *c = (*c * total_samples / s).max(8);
    }

    let mut shards = Vec::with_capacity(num_clients);
    let mut next_index: u64 = 0;
    for (cid, &n) in counts.iter().enumerate() {
        let probs: Vec<f64> = match scheme {
            Partition::Iid => vec![1.0 / k as f64; k],
            Partition::Dirichlet { alpha } => rng.dirichlet(alpha, k),
        };
        let mut labels = Vec::with_capacity(n);
        let mut indices = Vec::with_capacity(n);
        for _ in 0..n {
            let class = rng.categorical(&probs);
            labels.push(class as u16);
            indices.push(next_index);
            next_index += 1;
        }
        shards.push(ClientShard { client_id: cid, labels, indices, cursor: 0 });
    }
    shards
}

#[cfg(test)]
mod tests {
    use super::*;

    fn dataset() -> SyntheticDataset {
        SyntheticDataset::new(10, 1)
    }

    #[test]
    fn partition_covers_all_clients() {
        let shards = partition(&dataset(), 100, 10_000, Partition::Iid, 1);
        assert_eq!(shards.len(), 100);
        assert!(shards.iter().all(|s| s.num_samples() >= 8));
        let total: usize = shards.iter().map(|s| s.num_samples()).sum();
        assert!((8_000..=12_000).contains(&total), "{total}");
    }

    #[test]
    fn indices_globally_unique() {
        let shards = partition(&dataset(), 20, 2_000, Partition::Iid, 2);
        let mut all: Vec<u64> = shards.iter().flat_map(|s| s.indices.iter().copied()).collect();
        let n = all.len();
        all.sort_unstable();
        all.dedup();
        assert_eq!(all.len(), n);
    }

    #[test]
    fn iid_shards_are_roughly_balanced() {
        let shards = partition(&dataset(), 10, 20_000, Partition::Iid, 3);
        for s in &shards {
            let mut hist = [0usize; 10];
            for &l in &s.labels {
                hist[l as usize] += 1;
            }
            let n = s.num_samples() as f64;
            for h in hist {
                let frac = h as f64 / n;
                assert!((0.04..0.25).contains(&frac), "iid frac {frac}");
            }
        }
    }

    #[test]
    fn dirichlet_skews_labels() {
        let shards = partition(&dataset(), 30, 30_000, Partition::Dirichlet { alpha: 0.1 }, 4);
        // With α=0.1 most clients should be dominated by few classes.
        let mut dominated = 0;
        for s in &shards {
            let mut hist = [0usize; 10];
            for &l in &s.labels {
                hist[l as usize] += 1;
            }
            let max = *hist.iter().max().unwrap() as f64;
            if max / s.num_samples() as f64 > 0.5 {
                dominated += 1;
            }
        }
        assert!(dominated > 15, "only {dominated}/30 skewed");
    }

    #[test]
    fn deterministic_partitioning() {
        let a = partition(&dataset(), 10, 1_000, Partition::Dirichlet { alpha: 1.0 }, 5);
        let b = partition(&dataset(), 10, 1_000, Partition::Dirichlet { alpha: 1.0 }, 5);
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x.labels, y.labels);
        }
    }

    #[test]
    fn fill_batches_cycles_and_advances() {
        let data = dataset();
        let mut shards = partition(&data, 2, 40, Partition::Iid, 6);
        let s = &mut shards[0];
        let (mut xs, mut ys) = (Vec::new(), Vec::new());
        s.fill_batches(&data, 2, 8, &mut xs, &mut ys);
        assert_eq!(xs.len(), 16 * IMG_ELEMS);
        assert_eq!(ys.len(), 16);
        let first = ys.clone();
        s.fill_batches(&data, 2, 8, &mut xs, &mut ys);
        // cursor advanced: different windows unless shard length divides 16
        if s.num_samples() % 16 != 0 {
            assert_ne!(first, ys);
        }
        // labels valid
        assert!(ys.iter().all(|&y| (0..10).contains(&y)));
    }
}
