//! Federated data partitioning: IID and Dirichlet Non-IID (paper §4.1,
//! α = 1 by default), plus client-shard batch assembly.

use super::{SyntheticDataset, IMG_ELEMS};
use crate::rng::Rng;

/// How training data distributes across clients.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum Partition {
    /// Uniform label distribution on every client.
    Iid,
    /// Dirichlet(alpha) label-distribution skew per client.
    Dirichlet {
        /// Concentration parameter (paper: α = 1).
        alpha: f64,
    },
}

impl Partition {
    /// Human-readable scheme label (table/CSV column).
    pub fn label(&self) -> String {
        match self {
            Partition::Iid => "IID".into(),
            Partition::Dirichlet { alpha } => format!("Non-IID(α={alpha})"),
        }
    }
}

/// One client's local dataset: a label sequence + a private index stream.
/// Images are regenerated on demand from (class, global index) so shards
/// cost O(samples) u16 labels, not O(samples × 3072) floats.
#[derive(Debug, Clone)]
pub struct ClientShard {
    /// Owning client's pool index.
    pub client_id: usize,
    /// Per-sample class labels.
    pub labels: Vec<u16>,
    /// Global sample indices (unique across clients, disjoint from test).
    pub indices: Vec<u64>,
    cursor: usize,
}

impl ClientShard {
    /// Number of local samples (the FedAvg merge weight).
    pub fn num_samples(&self) -> usize {
        self.labels.len()
    }

    /// Assemble a shard from its materialized parts (lazy-pool path;
    /// cursor starts at 0 exactly like [`partition`]'s output).
    pub(crate) fn from_parts(client_id: usize, labels: Vec<u16>, indices: Vec<u64>) -> Self {
        ClientShard { client_id, labels, indices, cursor: 0 }
    }

    /// Batch-cycling cursor position (lazy-pool eviction snapshot).
    pub(crate) fn cursor(&self) -> usize {
        self.cursor
    }

    /// Restore a cursor position captured by [`Self::cursor`] (lazy-pool
    /// re-materialization: the rebuilt shard resumes its batch cycle
    /// exactly where the evicted one left off).
    pub(crate) fn set_cursor(&mut self, cursor: usize) {
        self.cursor = cursor;
    }

    /// Fill a stacked (steps × batch) training chunk, cycling through the
    /// shard (clients train multiple local epochs over few samples, as in
    /// cross-device FL). Advances the shard cursor; the epoch RNG reshuffles
    /// nothing — order is the partition order, which is already random.
    pub fn fill_batches(
        &mut self,
        data: &SyntheticDataset,
        steps: usize,
        batch: usize,
        xs: &mut Vec<f32>,
        ys: &mut Vec<i32>,
    ) {
        let n = steps * batch;
        xs.resize(n * IMG_ELEMS, 0.0);
        ys.resize(n, 0);
        for i in 0..n {
            let j = (self.cursor + i) % self.labels.len();
            let class = self.labels[j] as usize;
            data.write_sample(class, self.indices[j], &mut xs[i * IMG_ELEMS..(i + 1) * IMG_ELEMS]);
            ys[i] = class as i32;
        }
        self.cursor = (self.cursor + n) % self.labels.len();
    }
}

/// Split `total_samples` across `num_clients`. Sample counts get a mild
/// random spread (clients differ in data volume, as in production
/// federations); labels per client come from the partition scheme.
pub fn partition(
    data: &SyntheticDataset,
    num_clients: usize,
    total_samples: usize,
    scheme: Partition,
    seed: u64,
) -> Vec<ClientShard> {
    let mut rng = Rng::new(seed ^ 0x9a7c_55aa_1234_5678);
    let k = data.num_classes;

    // Per-client sample counts: uniform share ± 50% jitter, min 8.
    let base = total_samples / num_clients;
    let mut counts: Vec<usize> = (0..num_clients)
        .map(|_| ((base as f64 * rng.uniform(0.5, 1.5)) as usize).max(8))
        .collect();
    // Renormalize roughly to the requested total.
    let s: usize = counts.iter().sum();
    for c in &mut counts {
        *c = (*c * total_samples / s).max(8);
    }

    let mut shards = Vec::with_capacity(num_clients);
    let mut next_index: u64 = 0;
    for (cid, &n) in counts.iter().enumerate() {
        let probs: Vec<f64> = match scheme {
            Partition::Iid => vec![1.0 / k as f64; k],
            Partition::Dirichlet { alpha } => rng.dirichlet(alpha, k),
        };
        let mut labels = Vec::with_capacity(n);
        let mut indices = Vec::with_capacity(n);
        for _ in 0..n {
            let class = rng.categorical(&probs);
            labels.push(class as u16);
            indices.push(next_index);
            next_index += 1;
        }
        shards.push(ClientShard { client_id: cid, labels, indices, cursor: 0 });
    }
    shards
}

/// Lazy twin of [`partition`]: shard *bounds* (sample count, global index
/// range, label-stream rng position) for any client in O(1)-ish work, and
/// full shard materialization on demand — without ever holding the whole
/// fleet's shards in memory. Bit-identical to the eager build
/// (property-tested): the plan replays exactly the draws [`partition`]
/// would make, exploiting two SplitMix64 facts:
///
/// 1. the count phase consumes exactly one draw per client, so client
///    `i`'s raw count is reachable by a constant-stride state jump
///    (`Rng::skip`);
/// 2. the label phase is sequential and (under Dirichlet) data-dependent,
///    so the plan stores sparse rng-state checkpoints every
///    [`Self::CHUNK`] clients and walks at most one chunk to materialize
///    a shard. IID walking is pure arithmetic (one draw per label);
///    Dirichlet walking replays the per-client simplex draws.
///
/// Build cost is one O(fleet) streaming pass (no per-client allocation);
/// memory is O(fleet / CHUNK) checkpoint words.
#[derive(Debug, Clone)]
pub(crate) struct ShardPlan {
    scheme: Partition,
    num_clients: usize,
    total_samples: usize,
    num_classes: usize,
    /// Count-phase rng state before client 0's draw.
    counts_state0: u64,
    /// Sum of the raw (pre-renormalization) counts.
    sum_raw: usize,
    /// Sum of the renormalized per-client counts (= fleet total samples).
    total_renorm: usize,
    /// Label-phase (rng state, next global sample index) every
    /// [`Self::CHUNK`] clients.
    checkpoints: Vec<(u64, u64)>,
}

impl ShardPlan {
    /// Checkpoint stride: materializing a shard walks at most this many
    /// predecessors from the nearest checkpoint.
    const CHUNK: usize = 1024;

    /// Stream the count phase once (and, under Dirichlet, the label
    /// phase) to place checkpoints. Mirrors [`partition`]'s rng schedule
    /// draw for draw.
    pub(crate) fn build(
        num_classes: usize,
        num_clients: usize,
        total_samples: usize,
        scheme: Partition,
        seed: u64,
    ) -> Self {
        let counts_state0 = Rng::new(seed ^ 0x9a7c_55aa_1234_5678).state();
        // Pass 1: raw counts (one uniform draw each) → renormalization sum.
        let mut rng = Rng::from_state(counts_state0);
        let base = total_samples / num_clients;
        let mut sum_raw = 0usize;
        for _ in 0..num_clients {
            sum_raw += ((base as f64 * rng.uniform(0.5, 1.5)) as usize).max(8);
        }
        let mut plan = ShardPlan {
            scheme,
            num_clients,
            total_samples,
            num_classes,
            counts_state0,
            sum_raw,
            total_renorm: 0,
            checkpoints: Vec::with_capacity(num_clients / Self::CHUNK + 1),
        };
        // Pass 2: walk the label phase placing (state, next_index)
        // checkpoints. `rng` sits exactly at the post-counts state.
        let mut next_index = 0u64;
        for i in 0..num_clients {
            if i % Self::CHUNK == 0 {
                plan.checkpoints.push((rng.state(), next_index));
            }
            let n = plan.count(i);
            plan.skip_client(&mut rng, n);
            next_index += n as u64;
        }
        plan.total_renorm = next_index as usize;
        plan
    }

    /// Number of clients the plan spans.
    pub(crate) fn num_clients(&self) -> usize {
        self.num_clients
    }

    /// Fleet-total samples (sum of every client's renormalized count).
    pub(crate) fn total_samples(&self) -> usize {
        self.total_renorm
    }

    /// Client `i`'s shard size — the renormalized count, via an O(1)
    /// state jump to its count-phase draw.
    pub(crate) fn count(&self, i: usize) -> usize {
        debug_assert!(i < self.num_clients);
        let mut r = Rng::from_state(self.counts_state0);
        r.skip(i as u64);
        let base = self.total_samples / self.num_clients;
        let raw = ((base as f64 * r.uniform(0.5, 1.5)) as usize).max(8);
        (raw * self.total_samples / self.sum_raw).max(8)
    }

    /// Advance `rng` past one client's label-phase draws without
    /// materializing anything. IID clients build no simplex and draw one
    /// categorical per label (pure stride skip); Dirichlet clients must
    /// replay the data-dependent simplex draws for real.
    fn skip_client(&self, rng: &mut Rng, n: usize) {
        if let Partition::Dirichlet { alpha } = self.scheme {
            let _ = rng.dirichlet(alpha, self.num_classes);
        }
        // Every categorical label costs exactly one draw, whatever the
        // class it lands on.
        rng.skip(n as u64);
    }

    /// Materialize client `i`'s shard, bit-identical to `partition(..)[i]`:
    /// jump to the nearest checkpoint, walk the (at most CHUNK − 1)
    /// intervening clients, then replay client `i`'s own draws for real.
    pub(crate) fn shard(&self, i: usize) -> ClientShard {
        debug_assert!(i < self.num_clients);
        let (state, next) = self.checkpoints[i / Self::CHUNK];
        let mut rng = Rng::from_state(state);
        let mut next_index = next;
        for j in (i / Self::CHUNK) * Self::CHUNK..i {
            let n = self.count(j);
            self.skip_client(&mut rng, n);
            next_index += n as u64;
        }
        let n = self.count(i);
        let k = self.num_classes;
        let probs: Vec<f64> = match self.scheme {
            Partition::Iid => vec![1.0 / k as f64; k],
            Partition::Dirichlet { alpha } => rng.dirichlet(alpha, k),
        };
        let mut labels = Vec::with_capacity(n);
        let mut indices = Vec::with_capacity(n);
        for _ in 0..n {
            labels.push(rng.categorical(&probs) as u16);
            indices.push(next_index);
            next_index += 1;
        }
        ClientShard::from_parts(i, labels, indices)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn dataset() -> SyntheticDataset {
        SyntheticDataset::new(10, 1)
    }

    #[test]
    fn partition_covers_all_clients() {
        let shards = partition(&dataset(), 100, 10_000, Partition::Iid, 1);
        assert_eq!(shards.len(), 100);
        assert!(shards.iter().all(|s| s.num_samples() >= 8));
        let total: usize = shards.iter().map(|s| s.num_samples()).sum();
        assert!((8_000..=12_000).contains(&total), "{total}");
    }

    #[test]
    fn indices_globally_unique() {
        let shards = partition(&dataset(), 20, 2_000, Partition::Iid, 2);
        let mut all: Vec<u64> = shards.iter().flat_map(|s| s.indices.iter().copied()).collect();
        let n = all.len();
        all.sort_unstable();
        all.dedup();
        assert_eq!(all.len(), n);
    }

    #[test]
    fn iid_shards_are_roughly_balanced() {
        let shards = partition(&dataset(), 10, 20_000, Partition::Iid, 3);
        for s in &shards {
            let mut hist = [0usize; 10];
            for &l in &s.labels {
                hist[l as usize] += 1;
            }
            let n = s.num_samples() as f64;
            for h in hist {
                let frac = h as f64 / n;
                assert!((0.04..0.25).contains(&frac), "iid frac {frac}");
            }
        }
    }

    #[test]
    fn dirichlet_skews_labels() {
        let shards = partition(&dataset(), 30, 30_000, Partition::Dirichlet { alpha: 0.1 }, 4);
        // With α=0.1 most clients should be dominated by few classes.
        let mut dominated = 0;
        for s in &shards {
            let mut hist = [0usize; 10];
            for &l in &s.labels {
                hist[l as usize] += 1;
            }
            let max = *hist.iter().max().unwrap() as f64;
            if max / s.num_samples() as f64 > 0.5 {
                dominated += 1;
            }
        }
        assert!(dominated > 15, "only {dominated}/30 skewed");
    }

    #[test]
    fn deterministic_partitioning() {
        let a = partition(&dataset(), 10, 1_000, Partition::Dirichlet { alpha: 1.0 }, 5);
        let b = partition(&dataset(), 10, 1_000, Partition::Dirichlet { alpha: 1.0 }, 5);
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x.labels, y.labels);
        }
    }

    #[test]
    fn shard_plan_matches_eager_partition_bit_for_bit() {
        // The lazy plan must replay partition()'s exact rng schedule:
        // same counts, labels, and global indices for every client, under
        // both schemes, including across the CHUNK checkpoint boundary
        // (exercised here by walking clients out of order).
        for scheme in [Partition::Iid, Partition::Dirichlet { alpha: 0.7 }] {
            for seed in [1u64, 9, 42] {
                let data = SyntheticDataset::new(10, seed);
                let eager = partition(&data, 57, 5_700, scheme, seed);
                let plan = ShardPlan::build(10, 57, 5_700, scheme, seed);
                assert_eq!(plan.num_clients(), 57);
                let eager_total: usize = eager.iter().map(|s| s.num_samples()).sum();
                assert_eq!(plan.total_samples(), eager_total, "{scheme:?} seed {seed}");
                // Out-of-order materialization (each shard is independent).
                for &i in &[56usize, 0, 31, 7, 31] {
                    let lazy = plan.shard(i);
                    assert_eq!(lazy.client_id, eager[i].client_id);
                    assert_eq!(lazy.labels, eager[i].labels, "{scheme:?} seed {seed} client {i}");
                    assert_eq!(lazy.indices, eager[i].indices, "{scheme:?} seed {seed} client {i}");
                    assert_eq!(plan.count(i), eager[i].num_samples());
                }
            }
        }
    }

    #[test]
    fn shard_plan_checkpoints_span_large_fleets() {
        // A fleet larger than one checkpoint chunk: clients on both sides
        // of the boundary must still match the eager build.
        let data = SyntheticDataset::new(10, 3);
        let n = 2_500; // spans three CHUNK=1024 checkpoints
        let eager = partition(&data, n, n * 10, Partition::Iid, 3);
        let plan = ShardPlan::build(10, n, n * 10, Partition::Iid, 3);
        for &i in &[0usize, 1_023, 1_024, 2_047, 2_048, 2_499] {
            let lazy = plan.shard(i);
            assert_eq!(lazy.labels, eager[i].labels, "client {i}");
            assert_eq!(lazy.indices, eager[i].indices, "client {i}");
        }
    }

    #[test]
    fn fill_batches_cycles_and_advances() {
        let data = dataset();
        let mut shards = partition(&data, 2, 40, Partition::Iid, 6);
        let s = &mut shards[0];
        let (mut xs, mut ys) = (Vec::new(), Vec::new());
        s.fill_batches(&data, 2, 8, &mut xs, &mut ys);
        assert_eq!(xs.len(), 16 * IMG_ELEMS);
        assert_eq!(ys.len(), 16);
        let first = ys.clone();
        s.fill_batches(&data, 2, 8, &mut xs, &mut ys);
        // cursor advanced: different windows unless shard length divides 16
        if s.num_samples() % 16 != 0 {
            assert_ne!(first, ys);
        }
        // labels valid
        assert!(ys.iter().all(|&y| (0..10).contains(&y)));
    }
}
