//! Synthetic CIFAR-like data substrate.
//!
//! The sandbox has no network access, so CIFAR10/100 are substituted with a
//! deterministic class-conditional generator that exercises the identical
//! code paths (per-class Dirichlet partitioning, client shards, batch
//! assembly) and produces a *learnable but not saturating* classification
//! task: each class owns a fixed prototype of 2-D Gaussian "texture blobs"
//! with a class color bias; samples are prototype + per-sample jitter +
//! noise. Difficulty rises with class count (prototypes crowd the same
//! space), mirroring CIFAR10 → CIFAR100.
//!
//! Everything is generated on demand from (seed, class, sample-index), so a
//! 100-client × 50k-sample federation costs no resident image memory.

pub mod partition;

use crate::rng::Rng;

pub use partition::{partition, ClientShard, Partition};

/// Image side length (CIFAR geometry).
pub const IMG: usize = 32;
/// Color channels per image.
pub const CHANNELS: usize = 3;
/// Flat element count of one image.
pub const IMG_ELEMS: usize = IMG * IMG * CHANNELS;

const BLOBS: usize = 4;

/// One class's generative prototype: BLOBS Gaussian bumps + a color bias.
#[derive(Clone, Debug)]
struct ClassProto {
    /// Flattened 32x32x3 mean image.
    mean: Vec<f32>,
}

/// Deterministic synthetic dataset with CIFAR geometry.
#[derive(Clone, Debug)]
pub struct SyntheticDataset {
    /// Number of classes (10 = CIFAR10-like, 100 = CIFAR100-like).
    pub num_classes: usize,
    /// Generator seed (everything derives from it deterministically).
    pub seed: u64,
    protos: Vec<ClassProto>,
    /// Sample = proto * signal + noise * sigma; lower signal/noise for more
    /// classes (harder task, like CIFAR100 vs CIFAR10).
    signal: f32,
    noise: f32,
}

impl SyntheticDataset {
    /// Build the per-class prototypes for a `num_classes`-way task.
    pub fn new(num_classes: usize, seed: u64) -> Self {
        let base = Rng::new(seed ^ 0xdead_beef_cafe_f00d);
        let mut protos = Vec::with_capacity(num_classes);
        for c in 0..num_classes {
            protos.push(Self::make_proto(&base, c));
        }
        // CIFAR100-like: same image space, more crowded prototypes.
        let (signal, noise) = if num_classes > 20 { (0.9, 0.55) } else { (1.0, 0.45) };
        SyntheticDataset { num_classes, seed, protos, signal, noise }
    }

    fn make_proto(base: &Rng, class: usize) -> ClassProto {
        let mut rng = base.fork(0x1000 + class as u64);
        let mut mean = vec![0.0f32; IMG_ELEMS];
        // class color bias (weak — blobs carry most signal)
        let bias: [f32; 3] = [rng.normal() * 0.25, rng.normal() * 0.25, rng.normal() * 0.25];
        let mut blob_params = Vec::with_capacity(BLOBS);
        for _ in 0..BLOBS {
            let cx = rng.uniform(4.0, 28.0) as f32;
            let cy = rng.uniform(4.0, 28.0) as f32;
            let sigma = rng.uniform(2.0, 6.0) as f32;
            let amp = rng.normal() * 0.9;
            let col: [f32; 3] = [rng.normal(), rng.normal(), rng.normal()];
            blob_params.push((cx, cy, sigma, amp, col));
        }
        for h in 0..IMG {
            for w in 0..IMG {
                let mut px = [bias[0], bias[1], bias[2]];
                for &(cx, cy, sigma, amp, col) in &blob_params {
                    let d2 = (h as f32 - cy).powi(2) + (w as f32 - cx).powi(2);
                    let g = amp * (-d2 / (2.0 * sigma * sigma)).exp();
                    px[0] += g * col[0];
                    px[1] += g * col[1];
                    px[2] += g * col[2];
                }
                let off = (h * IMG + w) * CHANNELS;
                mean[off] = px[0];
                mean[off + 1] = px[1];
                mean[off + 2] = px[2];
            }
        }
        ClassProto { mean }
    }

    /// Write sample (class, idx) into `out` (len IMG_ELEMS), NHWC layout.
    /// Per-sample deterministic: same (class, idx) ⇒ same image.
    pub fn write_sample(&self, class: usize, idx: u64, out: &mut [f32]) {
        debug_assert_eq!(out.len(), IMG_ELEMS);
        let mut rng = Rng::new(self.seed ^ (class as u64) << 32 ^ idx.wrapping_mul(0x9e37_79b9));
        let proto = &self.protos[class];
        // light geometric jitter: global intensity + per-channel gain
        let gain = 1.0 + 0.15 * rng.normal();
        let cg: [f32; 3] = [
            1.0 + 0.1 * rng.normal(),
            1.0 + 0.1 * rng.normal(),
            1.0 + 0.1 * rng.normal(),
        ];
        for i in 0..IMG_ELEMS {
            let ch = i % CHANNELS;
            out[i] = self.signal * gain * cg[ch] * proto.mean[i] + self.noise * rng.normal();
        }
    }

    /// A balanced held-out test set: `n` samples cycling over classes,
    /// indices disjoint from training (training uses idx < 1<<40).
    pub fn test_batch(&self, start: usize, n: usize, xs: &mut Vec<f32>, ys: &mut Vec<i32>) {
        xs.resize(n * IMG_ELEMS, 0.0);
        ys.resize(n, 0);
        for i in 0..n {
            let gi = start + i;
            let class = gi % self.num_classes;
            let idx = (1u64 << 40) + gi as u64;
            self.write_sample(class, idx, &mut xs[i * IMG_ELEMS..(i + 1) * IMG_ELEMS]);
            ys[i] = class as i32;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn samples_deterministic() {
        let d = SyntheticDataset::new(10, 7);
        let mut a = vec![0.0; IMG_ELEMS];
        let mut b = vec![0.0; IMG_ELEMS];
        d.write_sample(3, 42, &mut a);
        d.write_sample(3, 42, &mut b);
        assert_eq!(a, b);
        d.write_sample(3, 43, &mut b);
        assert_ne!(a, b);
    }

    #[test]
    fn classes_are_separated() {
        // Mean inter-class L2 distance of prototypes must exceed the noise
        // floor, otherwise the task is unlearnable.
        let d = SyntheticDataset::new(10, 1);
        let mut min_dist = f32::MAX;
        for a in 0..10 {
            for b in (a + 1)..10 {
                let dist: f32 = d.protos[a]
                    .mean
                    .iter()
                    .zip(&d.protos[b].mean)
                    .map(|(x, y)| (x - y) * (x - y))
                    .sum::<f32>()
                    .sqrt();
                min_dist = min_dist.min(dist);
            }
        }
        assert!(min_dist > 1.0, "prototypes too close: {min_dist}");
    }

    #[test]
    fn nearest_prototype_classifier_beats_chance() {
        // Sanity: the task must be learnable — a nearest-prototype
        // classifier on noisy samples should be far above 10%.
        let d = SyntheticDataset::new(10, 3);
        let mut correct = 0;
        let total = 200;
        let mut buf = vec![0.0; IMG_ELEMS];
        for i in 0..total {
            let class = i % 10;
            d.write_sample(class, 5000 + i as u64, &mut buf);
            let mut best = (f32::MAX, 0usize);
            for c in 0..10 {
                let dist: f32 =
                    buf.iter().zip(&d.protos[c].mean).map(|(x, y)| (x - y) * (x - y)).sum();
                if dist < best.0 {
                    best = (dist, c);
                }
            }
            if best.1 == class {
                correct += 1;
            }
        }
        let acc = correct as f64 / total as f64;
        assert!(acc > 0.6, "nearest-proto acc {acc}");
    }

    #[test]
    fn test_batch_balanced() {
        let d = SyntheticDataset::new(10, 1);
        let (mut xs, mut ys) = (Vec::new(), Vec::new());
        d.test_batch(0, 100, &mut xs, &mut ys);
        assert_eq!(xs.len(), 100 * IMG_ELEMS);
        for c in 0..10 {
            assert_eq!(ys.iter().filter(|&&y| y == c).count(), 10);
        }
    }

    #[test]
    fn hundred_class_harder_than_ten() {
        let d10 = SyntheticDataset::new(10, 1);
        let d100 = SyntheticDataset::new(100, 1);
        assert!(d100.noise > d10.noise);
        assert_eq!(d100.protos.len(), 100);
    }
}
