//! Block freezing determination (paper §3.3).
//!
//! Server-side convergence tracking from the *scalar* perspective:
//!
//! * Scalar update at round k:    U_s^k = s^k − s^{k−1}
//! * Windowed movement:           D_{s,k}^H = ‖Σ_{h=0}^{H−1} U_s^{k−h}‖
//! * Block movement:              D_{B,k}^H = Σ_{s∈B} D_{s,k}^H
//! * **Effective movement**:      D_{B,k}^H / Σ_{s∈B} Σ_h ‖U_s^{k−h}‖
//!
//! Early in training gradients push scalars consistently in one direction,
//! so the numerator ≈ denominator and EM ≈ 1; near convergence scalars
//! oscillate around the optimum, displacements cancel inside the window
//! and EM → 0. The server fits a least-squares line to the EM series and
//! freezes the block once |slope| stays below φ for W consecutive
//! evaluations (the curve has flattened out).
//!
//! The detector is strategy-agnostic: every EM-gated
//! [`crate::strategy::MemoryStrategy`] phase (ProFL's shrink/grow steps,
//! `layerfreeze`'s front-block advance) runs a fresh [`FreezeDetector`]
//! over its observed parameter set, and every layout change lands in the
//! [`TransitionLog`] via `ServerCtx::bump_prefix_version` regardless of
//! which strategy triggered it (see `docs/STRATEGIES.md`).

use std::collections::VecDeque;

/// One freeze/step transition: the server's frozen-prefix version bumped
/// to `version` entering round `round`, at virtual fleet time
/// `sim_time_s`.
///
/// Transitions are the moments the trained block-prefix changes under
/// in-flight work: an async upload dispatched before a transition and
/// arriving after it was trained against a layout the server no longer
/// serves. The [`TransitionLog`] makes that staleness-in-transitions
/// computable (and auditable) after the fact.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Transition {
    /// The new prefix version (strictly increasing across the log).
    pub version: u64,
    /// Server round index at the bump (the first round of the new step).
    pub round: usize,
    /// Virtual fleet clock at the bump (seconds since run start).
    pub sim_time_s: f64,
}

/// Append-only log of freeze/step transitions, kept by the coordinator.
///
/// Every `ServerCtx::bump_prefix_version` records an entry, so the full
/// history of prefix-layout changes — which round, which virtual time —
/// survives the run and lands in `RunSummary::transitions`. The
/// projection path uses the version distance ([`Self::crossed_since`])
/// as its transition-staleness measure.
#[derive(Debug, Clone, Default)]
pub struct TransitionLog {
    entries: Vec<Transition>,
}

impl TransitionLog {
    /// An empty log (prefix version 0, nothing frozen yet).
    pub fn new() -> Self {
        TransitionLog::default()
    }

    /// Record a bump to `version` at (`round`, `sim_time_s`). Versions,
    /// rounds, and times are monotone by construction (the coordinator
    /// only moves forward); debug builds assert it.
    pub fn record(&mut self, version: u64, round: usize, sim_time_s: f64) {
        if let Some(last) = self.entries.last() {
            debug_assert!(version > last.version, "version went backwards");
            debug_assert!(round >= last.round, "round went backwards");
            debug_assert!(sim_time_s >= last.sim_time_s, "clock went backwards");
        }
        self.entries.push(Transition { version, round, sim_time_s });
    }

    /// Rebuild a log from previously recorded entries (checkpoint
    /// resume). Entries must be monotone in version/round/time, exactly
    /// as [`Self::entries`] returned them; debug builds assert it.
    pub fn from_entries(entries: Vec<Transition>) -> Self {
        let mut log = TransitionLog::new();
        for t in entries {
            log.record(t.version, t.round, t.sim_time_s);
        }
        log
    }

    /// All recorded transitions, oldest first.
    pub fn entries(&self) -> &[Transition] {
        &self.entries
    }

    /// The latest recorded prefix version (0 before any transition).
    pub fn current_version(&self) -> u64 {
        self.entries.last().map_or(0, |t| t.version)
    }

    /// How many transitions an update dispatched at prefix version
    /// `dispatched` has crossed by now — the transition-staleness the
    /// projection decay compounds over.
    pub fn crossed_since(&self, dispatched: u64) -> u64 {
        self.current_version().saturating_sub(dispatched)
    }

    /// Number of transitions recorded so far.
    pub fn len(&self) -> usize {
        self.entries.len()
    }

    /// Whether no transition has been recorded yet.
    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }
}

/// Sliding-window effective-movement tracker for one block vector.
pub struct EffectiveMovement {
    window_h: usize,
    /// Last H deltas (each Vec is U^k over all scalars of the block).
    deltas: VecDeque<Vec<f32>>,
    prev: Option<Vec<f32>>,
}

impl EffectiveMovement {
    /// Tracker with an H-delta sliding window.
    pub fn new(window_h: usize) -> Self {
        assert!(window_h >= 1);
        EffectiveMovement { window_h, deltas: VecDeque::new(), prev: None }
    }

    /// Feed the block's aggregated parameter vector after round k.
    /// Returns EM once H deltas have accumulated.
    pub fn push(&mut self, snapshot: &[f32]) -> Option<f64> {
        if let Some(prev) = &self.prev {
            debug_assert_eq!(prev.len(), snapshot.len());
            let delta: Vec<f32> = snapshot.iter().zip(prev).map(|(a, b)| a - b).collect();
            if self.deltas.len() == self.window_h {
                self.deltas.pop_front();
            }
            self.deltas.push_back(delta);
        }
        self.prev = Some(snapshot.to_vec());
        if self.deltas.len() < self.window_h {
            return None;
        }
        Some(self.compute())
    }

    fn compute(&self) -> f64 {
        let n = self.prev.as_ref().map_or(0, |p| p.len());
        let mut num = 0.0f64; // Σ_s |Σ_h U_s|
        let mut den = 0.0f64; // Σ_s Σ_h |U_s|
        for s in 0..n {
            let mut acc = 0.0f64;
            for d in &self.deltas {
                let u = d[s] as f64;
                acc += u;
                den += u.abs();
            }
            num += acc.abs();
        }
        if den <= 1e-12 {
            0.0 // block did not move at all: converged
        } else {
            num / den
        }
    }

    /// Clear the window (e.g. at a step transition).
    pub fn reset(&mut self) {
        self.deltas.clear();
        self.prev = None;
    }
}

/// Least-squares slope of y over x = 0..n-1.
pub fn ls_slope(ys: &[f64]) -> f64 {
    let n = ys.len() as f64;
    if ys.len() < 2 {
        return f64::INFINITY;
    }
    let xm = (n - 1.0) / 2.0;
    let ym: f64 = ys.iter().sum::<f64>() / n;
    let mut sxy = 0.0;
    let mut sxx = 0.0;
    for (i, y) in ys.iter().enumerate() {
        let dx = i as f64 - xm;
        sxy += dx * (y - ym);
        sxx += dx * dx;
    }
    sxy / sxx
}

/// Freeze-decision knobs (paper §3.3).
#[derive(Debug, Clone, Copy)]
pub struct FreezeConfig {
    /// Delta window H for effective movement.
    pub window_h: usize,
    /// Slope threshold φ.
    pub phi: f64,
    /// Consecutive below-threshold evaluations required (patience W).
    pub patience_w: usize,
    /// Points used in each slope fit.
    pub fit_points: usize,
    /// Never freeze before this many EM observations (warm-up).
    pub min_observations: usize,
}

impl Default for FreezeConfig {
    fn default() -> Self {
        FreezeConfig { window_h: 3, phi: 0.01, patience_w: 3, fit_points: 5, min_observations: 6 }
    }
}

/// The freeze decision engine for one block/step.
pub struct FreezeDetector {
    cfg: FreezeConfig,
    em: EffectiveMovement,
    history: Vec<f64>,
    consecutive: usize,
}

impl FreezeDetector {
    /// A fresh detector for one block/step.
    pub fn new(cfg: FreezeConfig) -> Self {
        FreezeDetector { em: EffectiveMovement::new(cfg.window_h), cfg, history: Vec::new(), consecutive: 0 }
    }

    /// Observe the post-aggregation block vector; returns (em, freeze?).
    pub fn observe(&mut self, block_vec: &[f32]) -> (Option<f64>, bool) {
        let Some(em) = self.em.push(block_vec) else {
            return (None, false);
        };
        self.history.push(em);
        if self.history.len() < self.cfg.min_observations {
            return (Some(em), false);
        }
        let tail = &self.history[self.history.len().saturating_sub(self.cfg.fit_points)..];
        let slope = ls_slope(tail);
        if slope.abs() < self.cfg.phi {
            self.consecutive += 1;
        } else {
            self.consecutive = 0;
        }
        (Some(em), self.consecutive >= self.cfg.patience_w)
    }

    /// The EM series observed so far (one point per filled window).
    pub fn history(&self) -> &[f64] {
        &self.history
    }

    /// Consecutive below-threshold slope evaluations so far — how deep
    /// into the patience window the block is (freeze fires at
    /// `patience_w`). Surfaced as a telemetry gauge next to the EM
    /// scalar.
    pub fn consecutive(&self) -> usize {
        self.consecutive
    }

    /// The detector's complete mutable state, for checkpointing. The
    /// [`FreezeConfig`] is *not* part of the snapshot — it is derived
    /// from the run config and re-supplied to [`Self::restore`].
    pub fn snapshot(&self) -> DetectorSnapshot {
        DetectorSnapshot {
            deltas: self.em.deltas.iter().cloned().collect(),
            prev: self.em.prev.clone(),
            history: self.history.clone(),
            consecutive: self.consecutive,
        }
    }

    /// Rebuild a detector mid-phase from a [`Self::snapshot`]. The next
    /// `observe` of the restored detector is bit-identical to the next
    /// `observe` of the original.
    pub fn restore(cfg: FreezeConfig, snap: DetectorSnapshot) -> Self {
        let mut em = EffectiveMovement::new(cfg.window_h);
        em.deltas = snap.deltas.into_iter().collect();
        em.prev = snap.prev;
        FreezeDetector { cfg, em, history: snap.history, consecutive: snap.consecutive }
    }
}

/// A [`FreezeDetector`]'s mutable state at a round boundary — the EM
/// window deltas, the previous observed vector, the EM series, and the
/// patience counter. Serialized into checkpoints so a resumed run makes
/// the same freeze decisions at the same rounds (`docs/CHECKPOINT.md`).
#[derive(Debug, Clone, Default, PartialEq)]
pub struct DetectorSnapshot {
    /// The sliding window's retained deltas, oldest first.
    pub deltas: Vec<Vec<f32>>,
    /// The last observed block vector (delta base), if any.
    pub prev: Option<Vec<f32>>,
    /// The EM series observed so far.
    pub history: Vec<f64>,
    /// Consecutive below-threshold slope evaluations.
    pub consecutive: usize,
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Rng;

    #[test]
    fn em_is_one_for_consistent_motion() {
        let mut em = EffectiveMovement::new(3);
        let mut v = vec![0.0f32; 100];
        let mut out = None;
        for _ in 0..6 {
            for x in &mut v {
                *x += 0.1; // every scalar moves the same direction
            }
            out = em.push(&v);
        }
        assert!((out.unwrap() - 1.0).abs() < 1e-9);
    }

    #[test]
    fn em_near_zero_for_oscillation() {
        let mut em = EffectiveMovement::new(4);
        let mut out = None;
        for k in 0..10 {
            let v: Vec<f32> = (0..100).map(|s| if (k + s) % 2 == 0 { 0.1 } else { -0.1 }).collect();
            out = em.push(&v);
        }
        // alternating ±0.2 deltas cancel pairwise inside the even window
        assert!(out.unwrap() < 0.05, "em {:?}", out);
    }

    #[test]
    fn em_zero_when_frozen_vector() {
        let mut em = EffectiveMovement::new(3);
        let v = vec![1.0f32; 10];
        let mut out = None;
        for _ in 0..5 {
            out = em.push(&v);
        }
        assert_eq!(out.unwrap(), 0.0);
    }

    #[test]
    fn em_decreases_on_synthetic_convergence() {
        // Simulate SGD-like decay: deltas shrink and decorrelate over time.
        let mut em = EffectiveMovement::new(3);
        let mut rng = Rng::new(1);
        let mut v = vec![0.0f32; 500];
        let mut first = None;
        let mut last = 0.0;
        for k in 0..60 {
            let drift = 1.0 / (1.0 + k as f32 * 0.3); // coherent part decays
            for x in v.iter_mut() {
                *x += drift * 0.1 + 0.05 * rng.normal();
            }
            if let Some(e) = em.push(&v) {
                if first.is_none() {
                    first = Some(e);
                }
                last = e;
            }
        }
        assert!(first.unwrap() > 0.5, "first {:?}", first);
        assert!(last < first.unwrap(), "no decrease: {last} vs {first:?}");
    }

    #[test]
    fn ls_slope_basics() {
        assert!((ls_slope(&[0.0, 1.0, 2.0, 3.0]) - 1.0).abs() < 1e-12);
        assert!(ls_slope(&[5.0, 5.0, 5.0]).abs() < 1e-12);
        assert!(ls_slope(&[3.0, 2.0, 1.0]) < 0.0);
    }

    #[test]
    fn detector_freezes_flat_series_only_after_patience() {
        let cfg = FreezeConfig { window_h: 2, phi: 0.01, patience_w: 3, fit_points: 4, min_observations: 4 };
        let mut det = FreezeDetector::new(cfg);
        // Phase 1: strong coherent motion — must not freeze.
        let mut v = vec![0.0f32; 50];
        let mut frozen = false;
        for _ in 0..6 {
            for x in &mut v {
                *x += 0.5;
            }
            let (_, f) = det.observe(&v);
            frozen |= f;
        }
        assert!(!frozen, "froze during active training");
        // Phase 2: stalled — should freeze after ≥ patience evaluations.
        let mut rounds_to_freeze = 0;
        for k in 1..20 {
            let (_, f) = det.observe(&v); // vector no longer moves
            if f {
                rounds_to_freeze = k;
                break;
            }
        }
        assert!(rounds_to_freeze >= 3, "froze too fast: {rounds_to_freeze}");
        assert!(rounds_to_freeze > 0, "never froze");
    }

    #[test]
    fn transition_log_is_monotone_and_counts_crossings() {
        let mut log = TransitionLog::new();
        assert!(log.is_empty());
        assert_eq!(log.current_version(), 0);
        assert_eq!(log.crossed_since(0), 0, "nothing crossed before any bump");

        log.record(1, 0, 0.0);
        log.record(2, 12, 340.5);
        log.record(3, 12, 340.5); // same round: shrink step + immediate map
        assert_eq!(log.len(), 3);
        assert_eq!(log.current_version(), 3);
        // An update dispatched at version 1 has crossed two transitions.
        assert_eq!(log.crossed_since(1), 2);
        assert_eq!(log.crossed_since(3), 0, "current-version updates cross nothing");
        assert_eq!(log.crossed_since(9), 0, "future versions saturate to zero");

        // Entries are append-only and ordered.
        let e = log.entries();
        assert_eq!(e.len(), 3);
        for pair in e.windows(2) {
            assert!(pair[0].version < pair[1].version);
            assert!(pair[0].round <= pair[1].round);
            assert!(pair[0].sim_time_s <= pair[1].sim_time_s);
        }
        assert_eq!(e[1], Transition { version: 2, round: 12, sim_time_s: 340.5 });
    }

    #[test]
    fn detector_snapshot_restore_is_bit_identical() {
        let cfg = FreezeConfig { window_h: 2, phi: 0.05, patience_w: 2, fit_points: 3, min_observations: 3 };
        let mut rng = Rng::new(9);
        let mut a = FreezeDetector::new(cfg);
        let mut v = vec![0.0f32; 20];
        for _ in 0..5 {
            for x in &mut v {
                *x += 0.1 * rng.normal();
            }
            a.observe(&v);
        }
        let mut b = FreezeDetector::restore(cfg, a.snapshot());
        for _ in 0..6 {
            for x in &mut v {
                *x += 0.1 * rng.normal();
            }
            let va = a.observe(&v);
            let vb = b.observe(&v);
            assert_eq!(va.0.map(f64::to_bits), vb.0.map(f64::to_bits));
            assert_eq!(va.1, vb.1);
            assert_eq!(a.consecutive(), b.consecutive());
        }
    }

    #[test]
    fn transition_log_from_entries_round_trips() {
        let mut log = TransitionLog::new();
        log.record(1, 0, 0.0);
        log.record(2, 12, 340.5);
        let copy = TransitionLog::from_entries(log.entries().to_vec());
        assert_eq!(copy.entries(), log.entries());
        assert_eq!(copy.current_version(), 2);
    }

    #[test]
    fn detector_respects_min_observations() {
        let cfg = FreezeConfig { window_h: 1, phi: 1e9, patience_w: 1, fit_points: 3, min_observations: 10 };
        let mut det = FreezeDetector::new(cfg);
        let v = vec![0.0f32; 10];
        for _ in 0..9 {
            let (_, f) = det.observe(&v);
            assert!(!f);
        }
    }
}
