//! Metrics: per-round records, run summaries, CSV emission, comm accounting.

use crate::freezing::Transition;
use std::io::Write;
use std::path::Path;

/// CSV schema version, carried as a `# schema=v<N>` first line so
/// downstream parsers can detect column-set changes (v1: pre-PR-4
/// columns; v2: projection + churn columns and the header line itself).
pub const CSV_SCHEMA_VERSION: u32 = 2;

/// The CSV column header (everything [`RoundRecord::csv_row`] emits, in
/// order).
pub const CSV_HEADER: &str = "round,stage,step,train_loss,train_acc,test_acc,effective_movement,participants,fallback,bytes_up,bytes_down,client_mem_bytes,sim_time_s,stragglers,dropouts,late_merged,late_dropped,mean_staleness,projected_merged,projected_dropped_params,transition_staleness,interrupted,resumed,partial_merged,wasted_compute_s";

/// One FL round's observables (a row of the Fig 4/5 CSVs).
#[derive(Debug, Clone)]
pub struct RoundRecord {
    /// Server round index (post-increment: the first round records 1).
    pub round: usize,
    /// Stage: "shrink", "grow", or the method name for baselines.
    pub stage: String,
    /// Step/block index (1-based) for progressive methods, 0 otherwise.
    pub step: usize,
    /// Cohort-weighted mean training loss (NaN when nothing trained).
    pub train_loss: f32,
    /// Cohort-weighted mean training accuracy (NaN when unavailable).
    pub train_acc: f32,
    /// Test accuracy (only on eval rounds; NaN otherwise).
    pub test_acc: f32,
    /// Effective movement (NaN before the window fills / for baselines).
    pub effective_movement: f64,
    /// Clients whose updates aggregated this round.
    pub participants: usize,
    /// Clients trained on the output-layer fallback artifact.
    pub fallback_participants: usize,
    /// Bytes uploaded this round.
    pub bytes_up: u64,
    /// Bytes downloaded this round.
    pub bytes_down: u64,
    /// Analytical peak client memory for this round's artifact (bytes).
    pub client_mem_bytes: u64,
    /// Cumulative virtual fleet time at the end of this round (seconds);
    /// the x-axis of time-to-accuracy curves.
    pub sim_time_s: f64,
    /// Clients cut by the round policy (deadline/over-select).
    pub stragglers: usize,
    /// Clients that dropped out after dispatch.
    pub dropouts: usize,
    /// Straggler updates from earlier rounds merged this round (async
    /// round policy; always 0 under sync/deadline/over-select).
    pub late_merged: usize,
    /// Late updates that arrived but were discarded (too stale, or
    /// trained against a since-frozen block with projection off or
    /// nothing surviving the intersection) — async's true losses.
    pub late_dropped: usize,
    /// Mean staleness (rounds) of the late-merged updates (0 when none).
    pub mean_staleness: f64,
    /// Stale projection (`--stale-projection on`): late updates that
    /// crossed a freeze/step transition and merged their still-trainable
    /// suffix instead of being dropped.
    pub projected_merged: usize,
    /// Stale projection: scalars discarded with the since-frozen tensors
    /// of this round's projected merges (the part of the device work a
    /// transition really did waste).
    pub projected_dropped_params: u64,
    /// Mean freeze/step transitions crossed by this round's projected
    /// merges (0 when none) — transition-staleness.
    pub transition_staleness: f64,
    /// Mid-round churn: devices that flipped offline inside a
    /// compute/upload span this round (Interrupt events).
    pub interrupted: usize,
    /// Mid-round churn: paused work that continued (Resume events).
    pub resumed: usize,
    /// Checkpoint churn: partial updates merged this round, each
    /// weighted by its completed-sample fraction.
    pub partial_merged: usize,
    /// Compute seconds lost to churn (aborted work + partial-epoch
    /// remainders past the last checkpoint boundary).
    pub wasted_compute_s: f64,
}

impl RoundRecord {
    /// This record as one CSV row (no trailing newline), in
    /// [`CSV_HEADER`] column order. Shared by [`MetricsSink::write_csv`]
    /// and the run manifest's history digest (`telemetry::build_manifest`
    /// hashes these rows), so the two can never drift apart.
    pub fn csv_row(&self) -> String {
        format!(
            "{},{},{},{},{},{},{},{},{},{},{},{},{},{},{},{},{},{},{},{},{},{},{},{},{}",
            self.round,
            self.stage,
            self.step,
            self.train_loss,
            self.train_acc,
            self.test_acc,
            self.effective_movement,
            self.participants,
            self.fallback_participants,
            self.bytes_up,
            self.bytes_down,
            self.client_mem_bytes,
            self.sim_time_s,
            self.stragglers,
            self.dropouts,
            self.late_merged,
            self.late_dropped,
            self.mean_staleness,
            self.projected_merged,
            self.projected_dropped_params,
            self.transition_staleness,
            self.interrupted,
            self.resumed,
            self.partial_merged,
            self.wasted_compute_s
        )
    }
}

/// Whole-run result: what the table benches consume.
#[derive(Debug, Clone)]
pub struct RunSummary {
    /// Method name (ProFL, HeteroFL, …).
    pub method: String,
    /// Manifest model tag the run trained.
    pub model_tag: String,
    /// Partition label (IID / Non-IID(α)).
    pub partition: String,
    /// Final test accuracy (mean of last `tail` evals, paper-style).
    pub final_acc: f64,
    /// Fleet fraction that could participate in at least one stage.
    pub participation_rate: f64,
    /// Peak per-client training memory across the run (bytes).
    pub peak_client_mem: u64,
    /// Total bytes uploaded across the run.
    pub total_bytes_up: u64,
    /// Total bytes downloaded across the run.
    pub total_bytes_down: u64,
    /// Total FL rounds executed.
    pub rounds: usize,
    /// Total virtual fleet time consumed by the run (seconds).
    pub sim_time_s: f64,
    /// Freeze/step transition history (every prefix-version bump, with
    /// its round and virtual time) — see `freezing::TransitionLog`.
    pub transitions: Vec<Transition>,
    /// Every round's record, in execution order.
    pub history: Vec<RoundRecord>,
}

impl RunSummary {
    /// Total bytes moved (up + down) across the run.
    pub fn comm_total(&self) -> u64 {
        self.total_bytes_up + self.total_bytes_down
    }

    /// Simulated time-to-accuracy: virtual seconds until the first eval
    /// reaching `target` (None if the run never got there).
    pub fn time_to_acc(&self, target: f64) -> Option<f64> {
        self.history
            .iter()
            .find(|r| !r.test_acc.is_nan() && r.test_acc as f64 >= target)
            .map(|r| r.sim_time_s)
    }

    /// Total stragglers/dropouts across the run's history.
    pub fn fleet_losses(&self) -> (usize, usize) {
        let s = self.history.iter().map(|r| r.stragglers).sum();
        let d = self.history.iter().map(|r| r.dropouts).sum();
        (s, d)
    }

    /// Total straggler updates merged late across the run (async policy).
    pub fn late_merges(&self) -> usize {
        self.history.iter().map(|r| r.late_merged).sum()
    }

    /// Total late updates that arrived but were discarded (async policy).
    pub fn late_drops(&self) -> usize {
        self.history.iter().map(|r| r.late_dropped).sum()
    }

    /// Total stale updates merged via suffix projection across the run
    /// (`--stale-projection on`).
    pub fn projected_merges(&self) -> usize {
        self.history.iter().map(|r| r.projected_merged).sum()
    }

    /// Total scalars discarded by projection (the since-frozen tensors of
    /// every projected merge).
    pub fn projected_dropped_params(&self) -> u64 {
        self.history.iter().map(|r| r.projected_dropped_params).sum()
    }

    /// Mean transitions crossed per projected merge across the run
    /// (0 when nothing was projected).
    pub fn mean_transition_staleness(&self) -> f64 {
        let n: usize = self.history.iter().map(|r| r.projected_merged).sum();
        if n == 0 {
            return 0.0;
        }
        let weighted: f64 = self
            .history
            .iter()
            .map(|r| r.transition_staleness * r.projected_merged as f64)
            .sum();
        weighted / n as f64
    }

    /// Total mid-round churn events across the run: (interrupts, resumes).
    pub fn churn_events(&self) -> (usize, usize) {
        let i = self.history.iter().map(|r| r.interrupted).sum();
        let r = self.history.iter().map(|r| r.resumed).sum();
        (i, r)
    }

    /// Total checkpoint partials merged across the run (churn policy
    /// `checkpoint`).
    pub fn partial_merges(&self) -> usize {
        self.history.iter().map(|r| r.partial_merged).sum()
    }

    /// Total compute seconds lost to mid-round churn across the run.
    pub fn wasted_compute_s(&self) -> f64 {
        self.history.iter().map(|r| r.wasted_compute_s).sum()
    }

    /// Peak analytical client memory per strategy stage, in first-seen
    /// execution order. This is the memory-wall headline cut: a
    /// progressive strategy shows a staircase of small peaks where a
    /// full-model baseline shows one tall bar.
    pub fn peak_mem_by_stage(&self) -> Vec<(String, u64)> {
        let mut out: Vec<(String, u64)> = Vec::new();
        for r in &self.history {
            match out.iter_mut().find(|(s, _)| *s == r.stage) {
                Some((_, peak)) => *peak = (*peak).max(r.client_mem_bytes),
                None => out.push((r.stage.clone(), r.client_mem_bytes)),
            }
        }
        out
    }

    /// Transition cadence: (count, mean rounds between consecutive
    /// layout transitions). Mean is 0 with fewer than two transitions.
    pub fn transition_cadence(&self) -> (usize, f64) {
        let n = self.transitions.len();
        if n < 2 {
            return (n, 0.0);
        }
        let spans: usize = self
            .transitions
            .windows(2)
            .map(|w| w[1].round.saturating_sub(w[0].round))
            .sum();
        (n, spans as f64 / (n - 1) as f64)
    }
}

/// Collects rounds, computes the paper's "average accuracy of the last 10
/// evals" summary statistic.
pub struct MetricsSink {
    /// Every recorded round, in execution order.
    pub records: Vec<RoundRecord>,
    eval_accs: Vec<f64>,
}

impl Default for MetricsSink {
    fn default() -> Self {
        Self::new()
    }
}

impl MetricsSink {
    /// An empty sink.
    pub fn new() -> Self {
        MetricsSink { records: Vec::new(), eval_accs: Vec::new() }
    }

    /// Record one round (eval rounds also feed the final-acc statistic).
    pub fn push(&mut self, rec: RoundRecord) {
        if !rec.test_acc.is_nan() {
            self.eval_accs.push(rec.test_acc as f64);
        }
        self.records.push(rec);
    }

    /// Paper: "average accuracy of the last 10 rounds after convergence".
    pub fn final_acc(&self, tail: usize) -> f64 {
        if self.eval_accs.is_empty() {
            return 0.0;
        }
        let k = tail.min(self.eval_accs.len());
        self.eval_accs[self.eval_accs.len() - k..].iter().sum::<f64>() / k as f64
    }

    /// Best test accuracy seen so far.
    pub fn best_acc(&self) -> f64 {
        self.eval_accs.iter().cloned().fold(0.0, f64::max)
    }

    /// Total (bytes_up, bytes_down) across every recorded round.
    pub fn total_bytes(&self) -> (u64, u64) {
        let up = self.records.iter().map(|r| r.bytes_up).sum();
        let down = self.records.iter().map(|r| r.bytes_down).sum();
        (up, down)
    }

    /// Peak analytical client memory across every recorded round.
    pub fn peak_client_mem(&self) -> u64 {
        self.records.iter().map(|r| r.client_mem_bytes).max().unwrap_or(0)
    }

    /// Virtual fleet time at the last recorded round (seconds).
    pub fn total_sim_time(&self) -> f64 {
        self.records.last().map(|r| r.sim_time_s).unwrap_or(0.0)
    }

    /// Write the full history as CSV (Fig 4/5/6 inputs).
    pub fn write_csv(&self, path: &Path) -> anyhow::Result<()> {
        if let Some(dir) = path.parent() {
            std::fs::create_dir_all(dir)?;
        }
        let mut f = std::fs::File::create(path)?;
        writeln!(f, "# schema=v{CSV_SCHEMA_VERSION}")?;
        writeln!(f, "{CSV_HEADER}")?;
        for r in &self.records {
            writeln!(f, "{}", r.csv_row())?;
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn rec(round: usize, test_acc: f32, up: u64) -> RoundRecord {
        RoundRecord {
            round,
            stage: "grow".into(),
            step: 1,
            train_loss: 1.0,
            train_acc: 0.5,
            test_acc,
            effective_movement: 0.5,
            participants: 10,
            fallback_participants: 0,
            bytes_up: up,
            bytes_down: up,
            client_mem_bytes: round as u64 * 100,
            sim_time_s: round as f64 * 30.0,
            stragglers: 1,
            dropouts: 0,
            late_merged: round % 2,
            late_dropped: 0,
            mean_staleness: 0.0,
            projected_merged: round % 2,
            projected_dropped_params: (round as u64 % 2) * 10,
            transition_staleness: if round % 2 == 1 { 2.0 } else { 0.0 },
            interrupted: round % 3,
            resumed: 0,
            partial_merged: round % 2,
            wasted_compute_s: round as f64 * 2.0,
        }
    }

    #[test]
    fn final_acc_tail_mean() {
        let mut m = MetricsSink::new();
        for i in 0..20 {
            m.push(rec(i, if i < 15 { 0.1 } else { 0.8 }, 10));
        }
        assert!((m.final_acc(5) - 0.8).abs() < 1e-6);
        assert!((m.final_acc(100) - (15.0 * 0.1 + 5.0 * 0.8) / 20.0).abs() < 1e-6);
    }

    #[test]
    fn nan_evals_excluded() {
        let mut m = MetricsSink::new();
        m.push(rec(0, 0.5, 1));
        m.push(rec(1, f32::NAN, 1));
        m.push(rec(2, 0.7, 1));
        assert!((m.final_acc(10) - 0.6).abs() < 1e-6);
    }

    #[test]
    fn totals_and_peaks() {
        let mut m = MetricsSink::new();
        m.push(rec(1, 0.5, 100));
        m.push(rec(2, 0.6, 50));
        assert_eq!(m.total_bytes(), (150, 150));
        assert_eq!(m.peak_client_mem(), 200);
    }

    #[test]
    fn sim_time_and_time_to_acc() {
        let mut m = MetricsSink::new();
        for i in 1..=4 {
            m.push(rec(i, if i >= 3 { 0.6 } else { 0.1 }, 1));
        }
        assert_eq!(m.total_sim_time(), 120.0);
        let s = RunSummary {
            method: "t".into(),
            model_tag: "m".into(),
            partition: "IID".into(),
            final_acc: 0.6,
            participation_rate: 1.0,
            peak_client_mem: 0,
            total_bytes_up: 0,
            total_bytes_down: 0,
            rounds: 4,
            sim_time_s: m.total_sim_time(),
            transitions: vec![
                Transition { version: 1, round: 0, sim_time_s: 0.0 },
                Transition { version: 2, round: 2, sim_time_s: 60.0 },
            ],
            history: m.records.clone(),
        };
        assert_eq!(s.time_to_acc(0.5), Some(90.0));
        assert_eq!(s.time_to_acc(0.9), None);
        assert_eq!(s.fleet_losses(), (4, 0));
        assert_eq!(s.late_merges(), 2, "rounds 1 and 3 each merged one late update");
        // Churn rollups: rounds 1..4 with interrupted = round % 3,
        // partial_merged = round % 2, wasted = 2*round.
        assert_eq!(s.churn_events(), (1 + 2 + 0 + 1, 0));
        assert_eq!(s.partial_merges(), 2);
        assert!((s.wasted_compute_s() - 20.0).abs() < 1e-9);
        // Projection rollups: rounds 1 and 3 each projected one update
        // (10 scalars dropped apiece, 2 transitions crossed each).
        assert_eq!(s.projected_merges(), 2);
        assert_eq!(s.projected_dropped_params(), 20);
        assert!((s.mean_transition_staleness() - 2.0).abs() < 1e-9);
        assert_eq!(s.transitions.len(), 2);
    }

    #[test]
    fn per_stage_and_transition_rollups() {
        let mut m = MetricsSink::new();
        // Two shrink rounds (mem 100, 200), then three grow rounds
        // (300..500): peaks group by stage in execution order.
        for i in 1..=5 {
            let mut r = rec(i, 0.5, 1);
            r.stage = if i <= 2 { "shrink".into() } else { "grow".into() };
            m.push(r);
        }
        let s = RunSummary {
            method: "t".into(),
            model_tag: "m".into(),
            partition: "IID".into(),
            final_acc: 0.5,
            participation_rate: 1.0,
            peak_client_mem: 500,
            total_bytes_up: 0,
            total_bytes_down: 0,
            rounds: 5,
            sim_time_s: 150.0,
            transitions: vec![
                Transition { version: 1, round: 0, sim_time_s: 0.0 },
                Transition { version: 2, round: 2, sim_time_s: 60.0 },
                Transition { version: 3, round: 6, sim_time_s: 180.0 },
            ],
            history: m.records.clone(),
        };
        assert_eq!(
            s.peak_mem_by_stage(),
            vec![("shrink".to_string(), 200), ("grow".to_string(), 500)]
        );
        let (n, mean) = s.transition_cadence();
        assert_eq!(n, 3);
        assert!((mean - 3.0).abs() < 1e-9, "spans 2 and 4 average to 3");
        // Degenerate cases: no transitions, single transition.
        let mut one = s.clone();
        one.transitions.truncate(1);
        assert_eq!(one.transition_cadence(), (1, 0.0));
        one.transitions.clear();
        assert_eq!(one.transition_cadence(), (0, 0.0));
    }

    #[test]
    fn csv_roundtrip() {
        let mut m = MetricsSink::new();
        m.push(rec(1, 0.5, 10));
        let dir = std::env::temp_dir().join("profl_test_metrics");
        let path = dir.join("run.csv");
        m.write_csv(&path).unwrap();
        let text = std::fs::read_to_string(&path).unwrap();
        let lines: Vec<&str> = text.lines().collect();
        assert_eq!(lines.len(), 3, "schema line + header + one record");
        assert!(text.starts_with("# schema=v"), "schema marker first");
        assert_eq!(lines[0], format!("# schema=v{CSV_SCHEMA_VERSION}"));
        assert_eq!(lines[1], CSV_HEADER);
        assert_eq!(lines[2], m.records[0].csv_row());
        // Column count stays in lockstep with the header.
        assert_eq!(
            lines[1].split(',').count(),
            lines[2].split(',').count(),
            "row/header column drift"
        );
        std::fs::remove_dir_all(dir).ok();
    }
}
