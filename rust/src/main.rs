//! `profl` CLI — the leader entrypoint.
//!
//! Subcommands:
//!   run        one FL run (method × model × partition), CSV + summary out
//!   compare    all Table-1 methods on one model/partition
//!   inspect    print manifest inventory + memory model (Fig 6 numbers)
//!   blocks     per-block parameter table (Table 5)
//!
//! The table/figure harnesses live in `examples/` (one binary per paper
//! table/figure); this binary is the operational front door.

use anyhow::{bail, Result};
use profl::cli::Args;
use profl::methods::{by_name, registry, table_methods};
use profl::{artifacts_dir, RunConfig, Runtime};
use std::path::PathBuf;

const USAGE: &str = "\
profl — ProFL progressive federated learning coordinator

USAGE: profl <SUBCOMMAND> [OPTIONS]

SUBCOMMANDS:
  run       Run one method end-to-end and print its summary
  resume    Continue a checkpointed run, bit-for-bit (see below)
  compare   Run every Table-1 method on one model/partition
  inspect   Print manifest inventory with the memory model
  blocks    Table 5: per-block parameter quantity/percentage

COMMON OPTIONS:
  --artifacts <dir>   Artifacts dir (default $PROFL_ARTIFACTS or ./artifacts)
  --model <tag>       Manifest model tag        [default: resnet18_w8_c10]
  --alpha <f64>       Dirichlet alpha (Non-IID); omit for IID
  --profile <name>    fast | smoke | paper      [default: fast]
  --seed <u64>        RNG seed
  --method <name>     run only: profl | profl-noshrink | paramaware |
                      allsmall | exclusivefl | heterofl | depthfl |
                      layerfreeze | elastic
  --csv <path>        run only: write per-round CSV
  --list-methods      Print the method registry (names, aliases) and exit

STRATEGY OPTIONS (memory-strategy zoo; see docs/STRATEGIES.md):
  --strategy <name>   run only: pick the block-progression strategy by
                      name instead of --method: profl | paramaware |
                      layerfreeze | elastic
  --elastic-phases <n>  elastic: number of budget-curve points (default:
                      one per model block)
  --freeze-step-cap <r> layerfreeze: cap rounds per freeze step (default:
                      convergence-driven, uncapped)

FLEET OPTIONS (discrete-event simulator; see fleet:: docs):
  --round-policy <p>  sync | deadline[:S] | over-select[:K] | async[:K]
                      [default: sync]
  --deadline-s <f64>  Deadline (virtual s) for the deadline policy
  --over-select <k>   Extra clients sampled under over-select
  --buffer-k <k>      async: arrivals that close a round [default: per_round]
  --staleness-alpha <f64>  async: late-merge discount w/(1+s)^alpha [default: 0.5]
  --max-staleness <r> async: drop updates older than r rounds [default: 8]
  --stale-projection <m>  async: off | on — project late updates that crossed
                      a freeze transition onto the still-trained suffix
                      instead of dropping them [default: off]
  --projection-decay <f64>  Extra weight decay per crossed transition for
                      projected merges, in [0,1] [default: 0.5]
  --fleet-profile <p> uniform | mobile | datacenter  [default: uniform]
  --dropout <f64>     Per-round dropout probability override
  --churn-policy <p>  Mid-round churn: none | abort | resume | checkpoint[:E]
                      [default: none]
  --churn-epochs <e>  checkpoint: epoch granularity of partial updates
                      [default: 4]
  --trace-period <s>  Availability trace cycle length override (virtual s)
  --trace-duty <f64>  Availability trace online fraction override
  --lazy-pool         Materialize clients on demand (O(cohort) memory per
                      round; bit-identical to the eager build) — for
                      very large --clients fleets
  --threads <n>       Worker threads for per-client span planning
                      [default: 1, env fallback: PROFL_THREADS]. Results
                      are bit-identical at any thread count (see
                      docs/SIMULATION.md); >1 only buys wall-clock time
                      on large cohorts.

OBSERVABILITY (see docs/OBSERVABILITY.md):
  --telemetry-jsonl <path>  Stream structured spans/counters/gauges for
                      every round as JSONL to <path> (off by default;
                      env fallback: PROFL_TELEMETRY_JSONL). `run` also
                      writes a manifest.json provenance record beside
                      the CSV (or beside the stream when no --csv).
  --telemetry-max-mb <n>  Rotate the telemetry stream to <stem>.N.jsonl
                      once the live file crosses n MiB (off by default;
                      the manifest records every segment). Hash-neutral:
                      does not change config_sha256.

CHECKPOINT/RESUME (strategy-backed methods only; see docs/CHECKPOINT.md):
  --checkpoint <path> run: write a full-state checkpoint at round
                      boundaries (`{round}` in the path expands to the
                      round index). Ignored by non-strategy baselines.
  --checkpoint-every <n>  Rounds between checkpoints [default: 1];
                      requires --checkpoint.
  resume <path>       Reconstruct the run from a checkpoint file and
                      continue it; the remaining rounds, CSV, and
                      manifest hashes reproduce the uninterrupted run
                      bit-for-bit. Only hash-neutral knobs may be
                      overridden on resume: --threads (defaults to the
                      checkpoint's), --checkpoint, --checkpoint-every,
                      --csv, --artifacts, --telemetry-max-mb.
";

fn make_cfg(args: &Args) -> Result<RunConfig> {
    let model = args.get_or("model", "resnet18_w8_c10");
    let mut cfg = match args.get_or("profile", "fast") {
        "fast" => RunConfig { model_tag: model.into(), ..Default::default() },
        "smoke" => RunConfig::smoke(model),
        "paper" => RunConfig::paper(model),
        other => bail!("unknown profile `{other}` (fast|smoke|paper)"),
    };
    cfg.dirichlet_alpha = args.parse_opt("alpha")?;
    if let Some(s) = args.parse_opt("seed")? {
        cfg.seed = s;
    }
    if let Some(r) = args.parse_opt("rounds")? {
        cfg.max_rounds_total = r;
    }
    if let Some(p) = args.get("round-policy") {
        cfg.fleet.round_policy = p.into();
    }
    if let Some(d) = args.parse_opt("deadline-s")? {
        cfg.fleet.deadline_s = d;
    }
    if let Some(k) = args.parse_opt("over-select")? {
        cfg.fleet.over_select_extra = k;
    }
    cfg.fleet.buffer_k = args.parse_opt("buffer-k")?.or(cfg.fleet.buffer_k);
    if let Some(a) = args.parse_opt("staleness-alpha")? {
        cfg.fleet.staleness_alpha = a;
    }
    if let Some(m) = args.parse_opt("max-staleness")? {
        cfg.fleet.max_staleness = m;
    }
    if let Some(p) = args.get("stale-projection") {
        cfg.fleet.stale_projection = p.into();
    }
    if let Some(d) = args.parse_opt("projection-decay")? {
        cfg.fleet.projection_decay = d;
    }
    if let Some(f) = args.get("fleet-profile") {
        cfg.fleet.profile = f.into();
    }
    cfg.fleet.dropout_p = args.parse_opt("dropout")?.or(cfg.fleet.dropout_p);
    if let Some(c) = args.get("churn-policy") {
        cfg.fleet.churn_policy = c.into();
    }
    if let Some(e) = args.parse_opt("churn-epochs")? {
        cfg.fleet.churn_epochs = e;
    }
    cfg.fleet.trace_period_s = args.parse_opt("trace-period")?.or(cfg.fleet.trace_period_s);
    cfg.fleet.trace_duty = args.parse_opt("trace-duty")?.or(cfg.fleet.trace_duty);
    if args.flag("lazy-pool") {
        cfg.fleet.lazy_pool = true;
    }
    if let Some(n) = args.parse_opt("threads")? {
        cfg.fleet.threads = n;
    }
    cfg.telemetry_jsonl =
        args.get("telemetry-jsonl").map(String::from).or_else(profl::harness::telemetry_env);
    cfg.telemetry_max_mb = args.parse_opt("telemetry-max-mb")?;
    cfg.strategy.name = args.get("strategy").map(String::from).or(cfg.strategy.name);
    cfg.strategy.elastic_phases =
        args.parse_opt("elastic-phases")?.or(cfg.strategy.elastic_phases);
    cfg.strategy.freeze_step_cap =
        args.parse_opt("freeze-step-cap")?.or(cfg.strategy.freeze_step_cap);
    cfg.checkpoint = args.get("checkpoint").map(String::from);
    if let Some(e) = args.parse_opt("checkpoint-every")? {
        if cfg.checkpoint.is_none() {
            bail!("--checkpoint-every requires --checkpoint <path>");
        }
        cfg.checkpoint_every = e;
    }
    // Fail fast on bad fleet/strategy spellings (before artifacts load).
    cfg.round_policy()?;
    cfg.churn_policy()?;
    cfg.stale_projection()?;
    cfg.fleet_profile()?;
    cfg.strategy_name()?;
    cfg.checkpoint_plan()?;
    Ok(cfg)
}

fn print_summary(s: &profl::RunSummary) {
    let (stragglers, dropouts) = s.fleet_losses();
    println!(
        "{:<14} {:<22} {:<14} acc={:>6.2}%  PR={:>5.1}%  peak_mem={:>6.1}MB  comm={:>8.1}MB  rounds={}  sim_time={:.0}s (stragglers={} dropouts={})",
        s.method,
        s.model_tag,
        s.partition,
        s.final_acc * 100.0,
        s.participation_rate * 100.0,
        s.peak_client_mem as f64 / 1e6,
        s.comm_total() as f64 / 1e6,
        s.rounds,
        s.sim_time_s,
        stragglers,
        dropouts
    );
}

/// Shared `run`/`resume` output tail: summary line, optional per-round
/// CSV, and the run-provenance manifest beside the CSV (else beside the
/// telemetry stream).
fn emit_outputs(args: &Args, cfg: &RunConfig, summary: &profl::RunSummary) -> Result<()> {
    print_summary(summary);
    if let Some(path) = args.get("csv") {
        let mut sink = profl::metrics::MetricsSink::new();
        for r in &summary.history {
            sink.push(r.clone());
        }
        sink.write_csv(std::path::Path::new(path))?;
        eprintln!("[profl] wrote {path}");
    }
    let manifest_dir = args
        .get("csv")
        .or_else(|| cfg.telemetry_jsonl.as_deref())
        .map(|p| std::path::Path::new(p).parent().map(PathBuf::from).unwrap_or_default());
    if let Some(dir) = manifest_dir {
        let telemetry = cfg.telemetry_jsonl.as_deref().map(|p| {
            let path = std::path::Path::new(p);
            (path, profl::telemetry::count_lines(path))
        });
        let argv: Vec<String> = std::env::args().collect();
        let manifest = profl::telemetry::build_manifest(cfg, &argv, Some(summary), telemetry);
        let mpath = dir.join("manifest.json");
        profl::telemetry::write_manifest(&mpath, &manifest)?;
        eprintln!("[profl] wrote {}", mpath.display());
    }
    Ok(())
}

fn main() -> Result<()> {
    let mut argv: Vec<String> = std::env::args().skip(1).collect();
    // `resume <path>` carries a positional the flag parser rejects;
    // pull it out before parsing.
    let mut resume_path: Option<String> = None;
    if argv.first().map(String::as_str) == Some("resume")
        && argv.get(1).map_or(false, |a| !a.starts_with('-'))
    {
        resume_path = Some(argv.remove(1));
    }
    let args = Args::parse(argv.into_iter())?;
    if args.flag("list-methods") {
        println!("{:<16} {:<14} {:<8} {:<10}", "NAME", "ALIASES", "TABLE", "INCLUSIVE");
        for spec in registry() {
            let aliases = if spec.aliases.is_empty() { "-".to_string() } else { spec.aliases.join(",") };
            println!(
                "{:<16} {:<14} {:<8} {:<10}",
                spec.name,
                aliases,
                if spec.table { "yes" } else { "no" },
                if spec.inclusive { "yes" } else { "no" },
            );
        }
        return Ok(());
    }
    if args.flag("help") || args.subcommand.is_none() {
        print!("{USAGE}");
        return Ok(());
    }
    let dir = args.get("artifacts").map(PathBuf::from).unwrap_or_else(artifacts_dir);
    let rt = Runtime::new(&dir)?;

    match args.subcommand.as_deref().unwrap() {
        "run" => {
            let cfg = make_cfg(&args)?;
            // --strategy is an alias route into the same registry; an
            // explicit --method that disagrees is a user error.
            let method = match (args.get("method"), cfg.strategy.name.as_deref()) {
                (Some(m), Some(s)) if !m.eq_ignore_ascii_case(s) => {
                    bail!("--method {m} conflicts with --strategy {s}; pass one of the two")
                }
                (Some(m), _) => m.to_string(),
                (None, Some(s)) => s.to_string(),
                (None, None) => "profl".to_string(),
            };
            let m =
                by_name(&method).ok_or_else(|| anyhow::anyhow!("unknown method `{method}`"))?;
            eprintln!(
                "[profl] running {} on {} ({})",
                m.name(),
                cfg.model_tag,
                cfg.partition().label()
            );
            let summary = m.run(&rt, &cfg)?;
            emit_outputs(&args, &cfg, &summary)?;
        }
        "resume" => {
            let path = resume_path
                .ok_or_else(|| anyhow::anyhow!("usage: profl resume <checkpoint> [OPTIONS]"))?;
            let ck = profl::checkpoint::Checkpoint::read(std::path::Path::new(&path))?;
            let mut cfg = ck.resolve_config()?;
            // Only hash-neutral knobs may be overridden on resume —
            // anything hash-relevant would change config_sha256 and be
            // rejected by the checkpoint's fingerprint check anyway.
            cfg.fleet.threads = args.parse_opt("threads")?.unwrap_or(ck.threads);
            cfg.telemetry_max_mb = args.parse_opt("telemetry-max-mb")?;
            cfg.checkpoint = args.get("checkpoint").map(String::from);
            if let Some(e) = args.parse_opt("checkpoint-every")? {
                if cfg.checkpoint.is_none() {
                    bail!("--checkpoint-every requires --checkpoint <path>");
                }
                cfg.checkpoint_every = e;
            }
            cfg.checkpoint_plan()?;
            eprintln!(
                "[profl] resuming {} on {} at round {} (from {path})",
                ck.strategy_name, cfg.model_tag, ck.round
            );
            let summary = profl::strategy::resume_strategy(&rt, &ck, &cfg)?;
            emit_outputs(&args, &cfg, &summary)?;
        }
        "compare" => {
            let cfg = make_cfg(&args)?;
            // Each method gets its own telemetry stream
            // (`<stem>.<method>.jsonl`): a single shared path would be
            // truncated by every successive method, keeping only the
            // last one's events.
            let base = cfg.telemetry_jsonl.clone();
            let mut streams: Vec<(String, PathBuf, u64)> = Vec::new();
            for m in table_methods() {
                let mut mcfg = cfg.clone();
                if let Some(b) = &base {
                    let p = profl::telemetry::method_stream_path(
                        std::path::Path::new(b),
                        m.name(),
                    );
                    mcfg.telemetry_jsonl = Some(p.display().to_string());
                }
                let s = m.run(&rt, &mcfg)?;
                print_summary(&s);
                if let Some(p) = &mcfg.telemetry_jsonl {
                    let path = PathBuf::from(p);
                    let lines = profl::telemetry::count_lines(&path);
                    streams.push((m.name().to_string(), path, lines));
                }
            }
            if let Some(b) = &base {
                let argv: Vec<String> = std::env::args().collect();
                let manifest = profl::telemetry::build_multi_manifest(&cfg, &argv, &streams);
                let dir =
                    std::path::Path::new(b).parent().map(PathBuf::from).unwrap_or_default();
                let mpath = dir.join("manifest.json");
                profl::telemetry::write_manifest(&mpath, &manifest)?;
                eprintln!("[profl] wrote {}", mpath.display());
            }
        }
        "inspect" => {
            let filter = args.get("model");
            for (tag, entry) in &rt.manifest.models {
                if let Some(m) = filter {
                    if m != tag {
                        continue;
                    }
                }
                println!(
                    "{tag}: {} blocks, {} classes, {} artifacts",
                    entry.num_blocks,
                    entry.num_classes,
                    entry.artifacts.len()
                );
                for (name, art) in &entry.artifacts {
                    let mem = art.participation_mem();
                    println!(
                        "  {:<22} kind={:<8} mem@128={:>7.1}MB  train_params={:>9}",
                        name,
                        art.kind,
                        mem.bytes_at(128) as f64 / 1e6,
                        mem.params_trainable,
                    );
                }
            }
        }
        "blocks" => {
            let model = args.get_or("model", "resnet18_w8_c10");
            let entry = rt.model(model)?;
            let total: u64 = entry.block_param_counts.iter().sum();
            println!("Table 5 — {model} (total {:.2}M params)", total as f64 / 1e6);
            for (i, c) in entry.block_param_counts.iter().enumerate() {
                println!(
                    "  Block{}: {:>10} params ({:>5.1}%)",
                    i + 1,
                    c,
                    *c as f64 / total as f64 * 100.0
                );
            }
        }
        other => bail!("unknown subcommand `{other}`\n{USAGE}"),
    }
    Ok(())
}
