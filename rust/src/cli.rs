//! Tiny argument parser (clap is unavailable offline).
//!
//! Supports `--key value`, `--key=value`, boolean `--flag`, positional
//! subcommands, and generates usage text from registered options.
//!
//! Options are untyped at parse time — callers pull values out with
//! [`Args::get`]/[`Args::parse_opt`] — so new flags (the strategy knobs
//! `--strategy`/`--elastic-phases`/`--freeze-step-cap`, say) need no
//! parser registration, only a consumer. A repeated `--key` keeps the
//! *last* value, letting scripts append overrides to a base invocation.

use anyhow::{bail, Result};
use std::collections::BTreeMap;

/// Can `tok` serve as an option's value? Anything not `-`-prefixed, plus
/// `-`-prefixed tokens that parse as numbers (`--lr -0.1`); other
/// `-`-prefixed tokens are treated as the next flag.
fn is_value_token(tok: &str) -> bool {
    !tok.starts_with('-') || tok.parse::<f64>().is_ok()
}

/// Parsed process arguments: one optional subcommand plus options/flags.
#[derive(Debug, Default)]
pub struct Args {
    /// First non-flag token, when present.
    pub subcommand: Option<String>,
    values: BTreeMap<String, String>,
    flags: Vec<String>,
}

impl Args {
    /// Parse process args: first non-flag token becomes the subcommand.
    pub fn parse(argv: impl Iterator<Item = String>) -> Result<Self> {
        let mut out = Args::default();
        let mut argv = argv.peekable();
        while let Some(tok) = argv.next() {
            if let Some(stripped) = tok.strip_prefix("--") {
                if let Some((k, v)) = stripped.split_once('=') {
                    out.values.insert(k.to_string(), v.to_string());
                } else if argv.peek().map(|n| is_value_token(n)).unwrap_or(false) {
                    out.values.insert(stripped.to_string(), argv.next().unwrap());
                } else {
                    out.flags.push(stripped.to_string());
                }
            } else if out.subcommand.is_none() {
                out.subcommand = Some(tok);
            } else {
                bail!("unexpected positional argument `{tok}`");
            }
        }
        Ok(out)
    }

    /// The raw value of `--key`, if provided.
    pub fn get(&self, key: &str) -> Option<&str> {
        self.values.get(key).map(|s| s.as_str())
    }

    /// The value of `--key`, or `default` when absent.
    pub fn get_or<'a>(&'a self, key: &str, default: &'a str) -> &'a str {
        self.get(key).unwrap_or(default)
    }

    /// Whether the boolean `--key` flag was passed.
    pub fn flag(&self, key: &str) -> bool {
        self.flags.iter().any(|f| f == key)
    }

    /// Parse `--key`'s value as `T` (None when absent, error on garbage).
    pub fn parse_opt<T: std::str::FromStr>(&self, key: &str) -> Result<Option<T>>
    where
        T::Err: std::fmt::Display,
    {
        match self.get(key) {
            None => Ok(None),
            Some(s) => match s.parse() {
                Ok(v) => Ok(Some(v)),
                Err(e) => bail!("invalid --{key} `{s}`: {e}"),
            },
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn args(toks: &[&str]) -> Args {
        Args::parse(toks.iter().map(|s| s.to_string())).unwrap()
    }

    #[test]
    fn subcommand_and_values() {
        let a = args(&["run", "--model", "resnet18_w8_c10", "--alpha=1.0", "--verbose"]);
        assert_eq!(a.subcommand.as_deref(), Some("run"));
        assert_eq!(a.get("model"), Some("resnet18_w8_c10"));
        assert_eq!(a.parse_opt::<f64>("alpha").unwrap(), Some(1.0));
        assert!(a.flag("verbose"));
        assert!(!a.flag("quiet"));
    }

    #[test]
    fn trailing_flag_not_eaten() {
        let a = args(&["run", "--fast", "--model", "m"]);
        assert!(a.flag("fast"));
        assert_eq!(a.get("model"), Some("m"));
    }

    #[test]
    fn negative_numbers_are_values_not_flags() {
        let a = args(&["run", "--lr", "-0.1", "--delta", "-3", "--verbose"]);
        assert_eq!(a.parse_opt::<f32>("lr").unwrap(), Some(-0.1));
        assert_eq!(a.get("delta"), Some("-3"));
        assert!(a.flag("verbose"));
        // Non-numeric dash tokens still aren't eaten as values.
        let a = args(&["run", "--fast", "--model", "m"]);
        assert!(a.flag("fast"));
        assert_eq!(a.get("model"), Some("m"));
    }

    #[test]
    fn repeated_key_keeps_last_value() {
        let a = args(&["run", "--strategy", "profl", "--strategy", "elastic"]);
        assert_eq!(a.get("strategy"), Some("elastic"));
    }

    #[test]
    fn bad_number_errors() {
        let a = args(&["run", "--seed", "abc"]);
        assert!(a.parse_opt::<u64>("seed").is_err());
    }

    #[test]
    fn rejects_extra_positionals() {
        assert!(Args::parse(["a", "b"].iter().map(|s| s.to_string())).is_err());
    }
}
