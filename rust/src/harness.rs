//! Experiment harness shared by the table/figure examples.
//!
//! Each paper table/figure has an `examples/` binary; this module holds
//! the common machinery: budget-profile handling (so the same binary can
//! run a 2-minute shape check or a paper-scale sweep), table formatting,
//! and result persistence under `artifacts/results/`.

use crate::cli::Args;
use crate::config::RunConfig;
use crate::metrics::RunSummary;
use anyhow::Result;
use std::io::Write;
use std::path::PathBuf;

/// Parse the standard example flags: `--profile fast|smoke|paper`,
/// `--alpha`, `--seed`, `--models a,b,c` (model tags), plus the fleet
/// flags (`--round-policy`, `--deadline-s`, `--over-select`,
/// `--buffer-k`, `--staleness-alpha`, `--max-staleness`,
/// `--stale-projection`, `--projection-decay`, `--fleet-profile`,
/// `--dropout`, `--churn-policy`, `--churn-epochs`, `--trace-period`,
/// `--trace-duty`, `--lazy-pool`, `--threads`), the strategy knobs (`--strategy`,
/// `--elastic-phases`, `--freeze-step-cap` — see `docs/STRATEGIES.md`)
/// and the observability switch (`--telemetry-jsonl`, env fallback
/// `PROFL_TELEMETRY_JSONL`). See `docs/CLI.md` for the full flag
/// reference.
pub struct ExpOpts {
    /// Budget profile: `fast` (default), `smoke`, or `paper`.
    pub profile: String,
    /// Dirichlet alpha (Non-IID partition); `None` = IID.
    pub alpha: Option<f64>,
    /// RNG seed override.
    pub seed: Option<u64>,
    /// Model tags to run (comma-separated on the CLI).
    pub models: Option<Vec<String>>,
    /// Total-round override.
    pub rounds: Option<usize>,
    /// Round policy spelling (`sync`/`deadline[:S]`/…).
    pub round_policy: Option<String>,
    /// Deadline seconds for the `deadline` policy.
    pub deadline_s: Option<f64>,
    /// Extra clients sampled under `over-select`.
    pub over_select: Option<usize>,
    /// Arrivals that close an `async` round.
    pub buffer_k: Option<usize>,
    /// FedBuff staleness-discount exponent.
    pub staleness_alpha: Option<f64>,
    /// Staleness cap (rounds) for late merges.
    pub max_staleness: Option<usize>,
    /// Stale-update projection switch (`off`/`on`).
    pub stale_projection: Option<String>,
    /// Per-transition decay for projected merges.
    pub projection_decay: Option<f64>,
    /// Named fleet profile (`uniform`/`mobile`/`datacenter`).
    pub fleet_profile: Option<String>,
    /// Per-round dropout probability override.
    pub dropout_p: Option<f64>,
    /// Mid-round churn policy spelling.
    pub churn_policy: Option<String>,
    /// Checkpoint epoch granularity.
    pub churn_epochs: Option<usize>,
    /// Availability-trace period override (seconds).
    pub trace_period_s: Option<f64>,
    /// Availability-trace duty override (online fraction).
    pub trace_duty: Option<f64>,
    /// Lazy on-demand client materialization (O(cohort) memory/round).
    pub lazy_pool: bool,
    /// Worker threads for per-client span planning (bit-identical at any
    /// count; `None` keeps the config default / `PROFL_THREADS`).
    pub threads: Option<usize>,
    /// Memory-strategy override (`profl`/`paramaware`/`layerfreeze`/`elastic`).
    pub strategy: Option<String>,
    /// Elastic: number of budget-curve points.
    pub elastic_phases: Option<usize>,
    /// LayerFreeze: per-step round cap.
    pub freeze_step_cap: Option<usize>,
    /// Structured-telemetry JSONL stream path (`--telemetry-jsonl`, or
    /// the `PROFL_TELEMETRY_JSONL` env var); `None` = telemetry off.
    pub telemetry_jsonl: Option<String>,
    /// Checkpoint path template (`--checkpoint`; hash-neutral, see
    /// `docs/CHECKPOINT.md`); `None` = checkpointing off.
    pub checkpoint: Option<String>,
    /// Rounds between checkpoints (`--checkpoint-every`).
    pub checkpoint_every: Option<usize>,
}

impl ExpOpts {
    /// Parse the process arguments.
    pub fn from_env() -> Result<Self> {
        Self::from_args(&Args::parse(std::env::args().skip(1))?)
    }

    /// Build from an already-parsed `Args` (examples that also read their
    /// own flags parse argv once and share it).
    pub fn from_args(args: &Args) -> Result<Self> {
        Ok(ExpOpts {
            profile: args.get_or("profile", "fast").to_string(),
            alpha: args.parse_opt("alpha")?,
            seed: args.parse_opt("seed")?,
            models: args.get("models").map(|s| s.split(',').map(String::from).collect()),
            rounds: args.parse_opt("rounds")?,
            round_policy: args.get("round-policy").map(String::from),
            deadline_s: args.parse_opt("deadline-s")?,
            over_select: args.parse_opt("over-select")?,
            buffer_k: args.parse_opt("buffer-k")?,
            staleness_alpha: args.parse_opt("staleness-alpha")?,
            max_staleness: args.parse_opt("max-staleness")?,
            stale_projection: args.get("stale-projection").map(String::from),
            projection_decay: args.parse_opt("projection-decay")?,
            fleet_profile: args.get("fleet-profile").map(String::from),
            dropout_p: args.parse_opt("dropout")?,
            churn_policy: args.get("churn-policy").map(String::from),
            churn_epochs: args.parse_opt("churn-epochs")?,
            trace_period_s: args.parse_opt("trace-period")?,
            trace_duty: args.parse_opt("trace-duty")?,
            lazy_pool: args.flag("lazy-pool"),
            threads: args.parse_opt("threads")?,
            strategy: args.get("strategy").map(String::from),
            elastic_phases: args.parse_opt("elastic-phases")?,
            freeze_step_cap: args.parse_opt("freeze-step-cap")?,
            telemetry_jsonl: args
                .get("telemetry-jsonl")
                .map(String::from)
                .or_else(telemetry_env),
            checkpoint: args.get("checkpoint").map(String::from),
            checkpoint_every: args.parse_opt("checkpoint-every")?,
        })
    }

    /// Materialize a [`RunConfig`] for `model`: budget profile first,
    /// then every provided override on top.
    pub fn cfg(&self, model: &str) -> RunConfig {
        let mut cfg = match self.profile.as_str() {
            "smoke" => RunConfig::smoke(model),
            "paper" => RunConfig::paper(model),
            _ => RunConfig { model_tag: model.into(), ..Default::default() },
        };
        cfg.dirichlet_alpha = self.alpha;
        if let Some(s) = self.seed {
            cfg.seed = s;
        }
        if let Some(r) = self.rounds {
            cfg.max_rounds_total = r;
            cfg.max_rounds_per_step = (r / 4).max(4);
        }
        if let Some(p) = &self.round_policy {
            cfg.fleet.round_policy = p.clone();
        }
        if let Some(d) = self.deadline_s {
            cfg.fleet.deadline_s = d;
        }
        if let Some(k) = self.over_select {
            cfg.fleet.over_select_extra = k;
        }
        cfg.fleet.buffer_k = self.buffer_k.or(cfg.fleet.buffer_k);
        if let Some(a) = self.staleness_alpha {
            cfg.fleet.staleness_alpha = a;
        }
        if let Some(m) = self.max_staleness {
            cfg.fleet.max_staleness = m;
        }
        if let Some(p) = &self.stale_projection {
            cfg.fleet.stale_projection = p.clone();
        }
        if let Some(d) = self.projection_decay {
            cfg.fleet.projection_decay = d;
        }
        if let Some(f) = &self.fleet_profile {
            cfg.fleet.profile = f.clone();
        }
        cfg.fleet.dropout_p = self.dropout_p.or(cfg.fleet.dropout_p);
        if let Some(c) = &self.churn_policy {
            cfg.fleet.churn_policy = c.clone();
        }
        if let Some(e) = self.churn_epochs {
            cfg.fleet.churn_epochs = e;
        }
        cfg.fleet.trace_period_s = self.trace_period_s.or(cfg.fleet.trace_period_s);
        cfg.fleet.trace_duty = self.trace_duty.or(cfg.fleet.trace_duty);
        if self.lazy_pool {
            cfg.fleet.lazy_pool = true;
        }
        if let Some(n) = self.threads {
            cfg.fleet.threads = n;
        }
        cfg.strategy.name = self.strategy.clone().or(cfg.strategy.name);
        cfg.strategy.elastic_phases = self.elastic_phases.or(cfg.strategy.elastic_phases);
        cfg.strategy.freeze_step_cap = self.freeze_step_cap.or(cfg.strategy.freeze_step_cap);
        cfg.telemetry_jsonl = self.telemetry_jsonl.clone();
        cfg.checkpoint = self.checkpoint.clone();
        if let Some(e) = self.checkpoint_every {
            cfg.checkpoint_every = e;
        }
        cfg
    }
}

/// The `PROFL_TELEMETRY_JSONL` fallback for `--telemetry-jsonl` (empty
/// values count as unset). Shared by the harness and the main binary so
/// every entry point honours the same switch.
pub fn telemetry_env() -> Option<String> {
    std::env::var("PROFL_TELEMETRY_JSONL").ok().filter(|s| !s.is_empty())
}

/// Results directory: artifacts/results/ (gitignored with the artifacts).
pub fn results_dir() -> PathBuf {
    let dir = crate::artifacts_dir().join("results");
    std::fs::create_dir_all(&dir).ok();
    dir
}

/// Format one summary as a paper-table row.
pub fn fmt_row(s: &RunSummary) -> String {
    let acc = if s.final_acc.is_nan() { "   NA ".to_string() } else { format!("{:5.1}%", s.final_acc * 100.0) };
    format!(
        "{:<14} {:<10} {:>6}  PR={:>4.0}%  peak={:>6.1}MB  comm={:>8.1}MB  sim={:>8.0}s",
        s.method,
        s.partition,
        acc,
        s.participation_rate * 100.0,
        s.peak_client_mem as f64 / 1e6,
        s.comm_total() as f64 / 1e6,
        s.sim_time_s,
    )
}

/// Append a block of results to `artifacts/results/<name>.txt` (and echo).
pub fn save_text(name: &str, text: &str) -> Result<()> {
    let path = results_dir().join(format!("{name}.txt"));
    let mut f = std::fs::File::create(&path)?;
    f.write_all(text.as_bytes())?;
    eprintln!("[harness] wrote {path:?}");
    Ok(())
}

/// The paper's Table 1/2 reference values (accuracy %, PR %) for shape
/// comparison printouts. Keyed (family, classes, iid?, method).
pub fn paper_reference(family: &str, classes: usize, iid: bool, method: &str) -> Option<(f64, f64)> {
    // (acc, pr) from Tables 1 and 2 of the paper.
    let t: &[(&str, usize, bool, &str, f64, f64)] = &[
        ("resnet18", 10, true, "AllSmall", 76.7, 100.0),
        ("resnet18", 10, true, "ExclusiveFL", 65.3, 8.0),
        ("resnet18", 10, true, "HeteroFL", 75.5, 100.0),
        ("resnet18", 10, true, "DepthFL", 70.4, 47.0),
        ("resnet18", 10, true, "ProFL", 84.1, 100.0),
        ("resnet18", 10, false, "AllSmall", 69.2, 100.0),
        ("resnet18", 10, false, "ExclusiveFL", 58.6, 8.0),
        ("resnet18", 10, false, "HeteroFL", 62.9, 100.0),
        ("resnet18", 10, false, "DepthFL", 60.8, 47.0),
        ("resnet18", 10, false, "ProFL", 78.4, 100.0),
        ("resnet18", 100, true, "ProFL", 55.4, 100.0),
        ("resnet18", 100, false, "ProFL", 48.3, 100.0),
        ("resnet34", 10, true, "AllSmall", 66.9, 100.0),
        ("resnet34", 10, true, "ExclusiveFL", f64::NAN, 0.0),
        ("resnet34", 10, true, "HeteroFL", 9.8, 100.0),
        ("resnet34", 10, true, "DepthFL", 71.7, 34.0),
        ("resnet34", 10, true, "ProFL", 82.2, 100.0),
        ("vgg11", 10, true, "AllSmall", 82.1, 100.0),
        ("vgg11", 10, true, "ExclusiveFL", 83.7, 24.0),
        ("vgg11", 10, true, "HeteroFL", 83.9, 100.0),
        ("vgg11", 10, true, "DepthFL", 86.4, 43.0),
        ("vgg11", 10, true, "ProFL", 87.6, 100.0),
        ("vgg16", 10, true, "AllSmall", 78.8, 100.0),
        ("vgg16", 10, true, "ExclusiveFL", f64::NAN, 0.0),
        ("vgg16", 10, true, "HeteroFL", 11.6, 100.0),
        ("vgg16", 10, true, "DepthFL", 76.9, 37.0),
        ("vgg16", 10, true, "ProFL", 82.4, 100.0),
    ];
    t.iter()
        .find(|(f, c, i, m, _, _)| *f == family && *c == classes && *i == iid && *m == method)
        .map(|(_, _, _, _, a, p)| (*a, *p))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn paper_reference_lookup() {
        let (acc, pr) = paper_reference("resnet18", 10, true, "ProFL").unwrap();
        assert_eq!(acc, 84.1);
        assert_eq!(pr, 100.0);
        assert!(paper_reference("resnet18", 10, true, "Nope").is_none());
        // ResNet34 ExclusiveFL is the NA cell
        let (acc, pr) = paper_reference("resnet34", 10, true, "ExclusiveFL").unwrap();
        assert!(acc.is_nan());
        assert_eq!(pr, 0.0);
    }

    #[test]
    fn cfg_profiles() {
        let o = ExpOpts {
            profile: "smoke".into(),
            alpha: Some(0.5),
            seed: Some(7),
            models: None,
            rounds: None,
            round_policy: Some("deadline".into()),
            deadline_s: Some(90.0),
            over_select: None,
            buffer_k: Some(5),
            staleness_alpha: Some(0.25),
            max_staleness: None,
            stale_projection: Some("on".into()),
            projection_decay: Some(0.75),
            fleet_profile: Some("mobile".into()),
            dropout_p: None,
            churn_policy: Some("checkpoint".into()),
            churn_epochs: Some(3),
            trace_period_s: Some(240.0),
            trace_duty: None,
            lazy_pool: true,
            threads: Some(4),
            strategy: Some("elastic".into()),
            elastic_phases: Some(3),
            freeze_step_cap: None,
            telemetry_jsonl: Some("stream.jsonl".into()),
            checkpoint: Some("run-{round}.ckpt".into()),
            checkpoint_every: Some(2),
        };
        let c = o.cfg("m");
        assert_eq!(c.seed, 7);
        assert_eq!(c.dirichlet_alpha, Some(0.5));
        assert!(c.num_clients <= 20);
        assert_eq!(c.fleet.round_policy, "deadline");
        assert_eq!(c.fleet.deadline_s, 90.0);
        assert_eq!(c.fleet.profile, "mobile");
        assert_eq!(c.fleet.buffer_k, Some(5));
        assert_eq!(c.fleet.staleness_alpha, 0.25);
        assert_eq!(c.fleet.max_staleness, 8, "unset knob keeps the default");
        assert_eq!(c.fleet.stale_projection, "on");
        assert_eq!(c.fleet.projection_decay, 0.75);
        assert_eq!(c.fleet.churn_policy, "checkpoint");
        assert_eq!(c.fleet.churn_epochs, 3);
        assert_eq!(c.fleet.trace_period_s, Some(240.0));
        assert_eq!(c.fleet.trace_duty, None, "unset override keeps the profile's duty");
        assert!(c.fleet.lazy_pool);
        assert_eq!(c.fleet.threads, 4);
        assert_eq!(c.strategy.name.as_deref(), Some("elastic"));
        assert_eq!(c.strategy.elastic_phases, Some(3));
        assert_eq!(c.strategy.freeze_step_cap, None, "unset knob keeps the default");
        assert_eq!(c.telemetry_jsonl.as_deref(), Some("stream.jsonl"));
        assert_eq!(c.checkpoint.as_deref(), Some("run-{round}.ckpt"));
        assert_eq!(c.checkpoint_every, 2);
    }
}
