//! Minimal JSON substrate (parser + writer).
//!
//! The offline build environment ships no serde/serde_json, so the
//! manifest contract is parsed by this self-contained recursive-descent
//! parser (strict enough for machine-written JSON: full string escapes,
//! numbers, nesting; rejects trailing garbage). The writer emits compact
//! JSON for run summaries / configs.

use anyhow::{bail, Context, Result};
use std::collections::BTreeMap;
use std::fmt::Write as _;

/// A parsed JSON value.
#[derive(Debug, Clone, PartialEq)]
pub enum Value {
    /// `null`.
    Null,
    /// `true` / `false`.
    Bool(bool),
    /// Any JSON number (stored as f64, like JavaScript).
    Num(f64),
    /// A string.
    Str(String),
    /// An array.
    Arr(Vec<Value>),
    /// An object (sorted keys for deterministic emission).
    Obj(BTreeMap<String, Value>),
}

impl Value {
    /// Parse a complete JSON document (rejects trailing garbage).
    pub fn parse(text: &str) -> Result<Value> {
        let mut p = Parser { bytes: text.as_bytes(), pos: 0, depth: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.pos != p.bytes.len() {
            bail!("trailing characters at offset {}", p.pos);
        }
        Ok(v)
    }

    // ---- typed accessors --------------------------------------------------

    /// Required object key lookup.
    pub fn get(&self, key: &str) -> Result<&Value> {
        match self {
            Value::Obj(m) => m.get(key).with_context(|| format!("missing key `{key}`")),
            _ => bail!("expected object for key `{key}`"),
        }
    }

    /// Optional object key lookup (None on missing key or non-object).
    pub fn opt(&self, key: &str) -> Option<&Value> {
        match self {
            Value::Obj(m) => m.get(key),
            _ => None,
        }
    }

    /// The value as an object, or an error.
    pub fn as_obj(&self) -> Result<&BTreeMap<String, Value>> {
        match self {
            Value::Obj(m) => Ok(m),
            _ => bail!("expected object, got {self:?}"),
        }
    }

    /// The value as an array, or an error.
    pub fn as_arr(&self) -> Result<&Vec<Value>> {
        match self {
            Value::Arr(a) => Ok(a),
            _ => bail!("expected array"),
        }
    }

    /// The value as a string slice, or an error.
    pub fn as_str(&self) -> Result<&str> {
        match self {
            Value::Str(s) => Ok(s),
            _ => bail!("expected string, got {self:?}"),
        }
    }

    /// The value as a number, or an error.
    pub fn as_f64(&self) -> Result<f64> {
        match self {
            Value::Num(n) => Ok(*n),
            _ => bail!("expected number, got {self:?}"),
        }
    }

    /// The value as an unsigned integer (truncating), or an error.
    pub fn as_u64(&self) -> Result<u64> {
        Ok(self.as_f64()? as u64)
    }

    /// The value as a usize (truncating), or an error.
    pub fn as_usize(&self) -> Result<usize> {
        Ok(self.as_f64()? as usize)
    }

    /// The value as a bool, or an error.
    pub fn as_bool(&self) -> Result<bool> {
        match self {
            Value::Bool(b) => Ok(*b),
            _ => bail!("expected bool"),
        }
    }

    /// Shape-style array: `[4, 32, 32, 3]` -> `Vec<usize>`.
    pub fn as_shape(&self) -> Result<Vec<usize>> {
        self.as_arr()?.iter().map(|v| v.as_usize()).collect()
    }

    // ---- writer ------------------------------------------------------------

    /// Emit compact JSON.
    pub fn to_json(&self) -> String {
        let mut s = String::new();
        self.write(&mut s);
        s
    }

    fn write(&self, out: &mut String) {
        match self {
            Value::Null => out.push_str("null"),
            Value::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Value::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 1e15 {
                    let _ = write!(out, "{}", *n as i64);
                } else {
                    let _ = write!(out, "{n}");
                }
            }
            Value::Str(s) => write_escaped(out, s),
            Value::Arr(a) => {
                out.push('[');
                for (i, v) in a.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    v.write(out);
                }
                out.push(']');
            }
            Value::Obj(m) => {
                out.push('{');
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    write_escaped(out, k);
                    out.push(':');
                    v.write(out);
                }
                out.push('}');
            }
        }
    }
}

fn write_escaped(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

/// Nesting cap for untrusted documents (checkpoint-embedded configs are
/// ~3 levels deep): deeper input gets a clean error instead of blowing
/// the recursive-descent stack.
const MAX_DEPTH: usize = 128;

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
    depth: usize,
}

impl<'a> Parser<'a> {
    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn expect(&mut self, c: u8) -> Result<()> {
        if self.peek() == Some(c) {
            self.pos += 1;
            Ok(())
        } else {
            bail!("expected `{}` at offset {}", c as char, self.pos)
        }
    }

    fn value(&mut self) -> Result<Value> {
        self.skip_ws();
        match self.peek() {
            Some(c @ (b'{' | b'[')) => {
                if self.depth >= MAX_DEPTH {
                    bail!("nesting deeper than {MAX_DEPTH} at offset {}", self.pos);
                }
                self.depth += 1;
                let v = if c == b'{' { self.object() } else { self.array() }?;
                self.depth -= 1;
                Ok(v)
            }
            Some(b'"') => Ok(Value::Str(self.string()?)),
            Some(b't') => self.lit("true", Value::Bool(true)),
            Some(b'f') => self.lit("false", Value::Bool(false)),
            Some(b'n') => self.lit("null", Value::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            other => bail!("unexpected {:?} at offset {}", other.map(|c| c as char), self.pos),
        }
    }

    fn lit(&mut self, word: &str, v: Value) -> Result<Value> {
        if self.bytes[self.pos..].starts_with(word.as_bytes()) {
            self.pos += word.len();
            Ok(v)
        } else {
            bail!("invalid literal at offset {}", self.pos)
        }
    }

    fn object(&mut self) -> Result<Value> {
        self.expect(b'{')?;
        let mut map = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Value::Obj(map));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            let val = self.value()?;
            map.insert(key, val);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b'}') => {
                    self.pos += 1;
                    return Ok(Value::Obj(map));
                }
                other => bail!("expected , or }} got {:?} at {}", other.map(|c| c as char), self.pos),
            }
        }
    }

    fn array(&mut self) -> Result<Value> {
        self.expect(b'[')?;
        let mut arr = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Value::Arr(arr));
        }
        loop {
            arr.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b']') => {
                    self.pos += 1;
                    return Ok(Value::Arr(arr));
                }
                other => bail!("expected , or ] got {:?} at {}", other.map(|c| c as char), self.pos),
            }
        }
    }

    fn string(&mut self) -> Result<String> {
        self.expect(b'"')?;
        let mut s = String::new();
        loop {
            match self.peek() {
                None => bail!("unterminated string"),
                Some(b'"') => {
                    self.pos += 1;
                    return Ok(s);
                }
                Some(b'\\') => {
                    self.pos += 1;
                    match self.peek() {
                        Some(b'"') => s.push('"'),
                        Some(b'\\') => s.push('\\'),
                        Some(b'/') => s.push('/'),
                        Some(b'n') => s.push('\n'),
                        Some(b't') => s.push('\t'),
                        Some(b'r') => s.push('\r'),
                        Some(b'b') => s.push('\u{8}'),
                        Some(b'f') => s.push('\u{c}'),
                        Some(b'u') => {
                            let hex = std::str::from_utf8(
                                self.bytes.get(self.pos + 1..self.pos + 5).context("bad \\u")?,
                            )?;
                            let cp = u32::from_str_radix(hex, 16)?;
                            s.push(char::from_u32(cp).context("bad codepoint")?);
                            self.pos += 4;
                        }
                        other => bail!("bad escape {:?}", other.map(|c| c as char)),
                    }
                    self.pos += 1;
                }
                Some(_) => {
                    // consume one UTF-8 scalar
                    let start = self.pos;
                    let text = std::str::from_utf8(&self.bytes[start..])?;
                    let c = text.chars().next().unwrap();
                    s.push(c);
                    self.pos += c.len_utf8();
                }
            }
        }
    }

    fn number(&mut self) -> Result<Value> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit() || matches!(c, b'.' | b'e' | b'E' | b'+' | b'-'))
        {
            self.pos += 1;
        }
        let text = std::str::from_utf8(&self.bytes[start..self.pos])?;
        Ok(Value::Num(text.parse::<f64>().with_context(|| format!("bad number `{text}`"))?))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn depth_bomb_is_a_clean_error_not_a_stack_overflow() {
        let deep = "[".repeat(100_000);
        assert!(Value::parse(&deep).is_err());
        let mixed = format!("{}1{}", "[{\"k\":".repeat(50_000), "}]".repeat(50_000));
        assert!(Value::parse(&mixed).is_err());
        // At the cap boundary: MAX_DEPTH nests parse, one more errors.
        let ok = format!("{}1{}", "[".repeat(MAX_DEPTH), "]".repeat(MAX_DEPTH));
        assert!(Value::parse(&ok).is_ok());
        let over = format!("{}1{}", "[".repeat(MAX_DEPTH + 1), "]".repeat(MAX_DEPTH + 1));
        assert!(Value::parse(&over).is_err());
    }

    #[test]
    fn parses_scalars() {
        assert_eq!(Value::parse("42").unwrap().as_f64().unwrap(), 42.0);
        assert_eq!(Value::parse("-3.5e2").unwrap().as_f64().unwrap(), -350.0);
        assert_eq!(Value::parse("\"hi\\nthere\"").unwrap().as_str().unwrap(), "hi\nthere");
        assert!(Value::parse("true").unwrap().as_bool().unwrap());
        assert_eq!(Value::parse("null").unwrap(), Value::Null);
    }

    #[test]
    fn parses_nested() {
        let v = Value::parse(r#"{"a": [1, 2, {"b": "c"}], "d": {}}"#).unwrap();
        let arr = v.get("a").unwrap().as_arr().unwrap();
        assert_eq!(arr.len(), 3);
        assert_eq!(arr[2].get("b").unwrap().as_str().unwrap(), "c");
        assert!(v.get("d").unwrap().as_obj().unwrap().is_empty());
    }

    #[test]
    fn rejects_garbage() {
        assert!(Value::parse("{").is_err());
        assert!(Value::parse("[1,]").is_err());
        assert!(Value::parse("1 2").is_err());
        assert!(Value::parse("\"unterminated").is_err());
    }

    #[test]
    fn unicode_escapes() {
        assert_eq!(Value::parse(r#""é""#).unwrap().as_str().unwrap(), "é");
    }

    #[test]
    fn shape_accessor() {
        let v = Value::parse("[4, 32, 32, 3]").unwrap();
        assert_eq!(v.as_shape().unwrap(), vec![4, 32, 32, 3]);
    }

    #[test]
    fn writer_roundtrip() {
        let text = r#"{"a":[1,2.5,"x\"y"],"b":true,"c":null}"#;
        let v = Value::parse(text).unwrap();
        let out = v.to_json();
        let v2 = Value::parse(&out).unwrap();
        assert_eq!(v, v2);
    }

    #[test]
    fn manifest_like_document() {
        let doc = r#"{
            "version": 1,
            "models": {"m": {"block_param_counts": [150000, 530000],
                             "params": {"b1/w": [3,3,3,8]}}}
        }"#;
        let v = Value::parse(doc).unwrap();
        assert_eq!(v.get("version").unwrap().as_u64().unwrap(), 1);
        let m = v.get("models").unwrap().get("m").unwrap();
        assert_eq!(m.get("block_param_counts").unwrap().as_shape().unwrap()[1], 530000);
        assert_eq!(m.get("params").unwrap().get("b1/w").unwrap().as_shape().unwrap(), vec![3, 3, 3, 8]);
    }
}
