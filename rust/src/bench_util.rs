//! In-tree micro-benchmark harness (criterion is unavailable offline).
//!
//! Criterion-style protocol: warm-up iterations, then timed samples,
//! reporting min / mean / median / p95 / max. Deterministic sample counts
//! so bench output is comparable across commits; used by every target in
//! `rust/benches/`.

use std::time::{Duration, Instant};

/// One benchmark's timed samples.
pub struct BenchResult {
    /// Benchmark name (printed in the report row).
    pub name: String,
    /// Per-iteration wall times, in run order.
    pub samples: Vec<Duration>,
}

impl BenchResult {
    /// Mean sample duration.
    pub fn mean(&self) -> Duration {
        let total: Duration = self.samples.iter().sum();
        total / self.samples.len() as u32
    }

    /// The `p`-quantile sample (0.0 = min, 1.0 = max).
    pub fn percentile(&self, p: f64) -> Duration {
        let mut s = self.samples.clone();
        s.sort();
        let idx = ((s.len() - 1) as f64 * p).round() as usize;
        s[idx]
    }

    /// Print the criterion-style summary row.
    pub fn report(&self) {
        println!(
            "{:<44} mean {:>12?}  median {:>12?}  p95 {:>12?}  min {:>12?}  (n={})",
            self.name,
            self.mean(),
            self.percentile(0.5),
            self.percentile(0.95),
            self.percentile(0.0),
            self.samples.len()
        );
    }
}

/// Benchmark a closure: `warmup` unmeasured runs then `iters` samples.
pub fn bench<F: FnMut()>(name: &str, warmup: usize, iters: usize, mut f: F) -> BenchResult {
    for _ in 0..warmup {
        f();
    }
    let mut samples = Vec::with_capacity(iters);
    for _ in 0..iters {
        let t0 = Instant::now();
        f();
        samples.push(t0.elapsed());
    }
    let r = BenchResult { name: name.to_string(), samples };
    r.report();
    r
}

/// Throughput helper: elements per second at the mean sample.
pub fn throughput(result: &BenchResult, elems: usize) -> f64 {
    elems as f64 / result.mean().as_secs_f64()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_collects_samples() {
        let r = bench("noop", 1, 5, || {
            std::hint::black_box(1 + 1);
        });
        assert_eq!(r.samples.len(), 5);
        assert!(r.mean() < Duration::from_millis(10));
    }

    #[test]
    fn percentiles_ordered() {
        let r = BenchResult {
            name: "x".into(),
            samples: (1..=100).map(Duration::from_micros).collect(),
        };
        assert!(r.percentile(0.0) <= r.percentile(0.5));
        assert!(r.percentile(0.5) <= r.percentile(0.95));
        assert!(r.percentile(0.95) <= r.percentile(1.0));
    }

    #[test]
    fn throughput_math() {
        let r = BenchResult { name: "x".into(), samples: vec![Duration::from_secs(1); 3] };
        assert!((throughput(&r, 1000) - 1000.0).abs() < 1e-6);
    }
}
