//! In-tree micro-benchmark harness (criterion is unavailable offline).
//!
//! Criterion-style protocol: warm-up iterations, then timed samples,
//! reporting min / mean / median / p95 / max. Deterministic sample counts
//! so bench output is comparable across commits; used by every target in
//! `rust/benches/`. [`BenchStats`] is the machine-readable summary the
//! `fleet_scale` bench serializes into `BENCH_fleet.json`
//! (`make bench-json`; see `docs/PERFORMANCE.md`).

use std::cell::OnceCell;
use std::time::{Duration, Instant};

/// One benchmark's timed samples. Construct via [`BenchResult::new`] and
/// treat as immutable afterwards: quantile queries share one lazily
/// sorted ordering of the samples, computed on first use (the old
/// implementation cloned and re-sorted the sample vector on *every*
/// `percentile` call — three sorts per `report`).
pub struct BenchResult {
    /// Benchmark name (printed in the report row).
    pub name: String,
    /// Per-iteration wall times, in run order.
    pub samples: Vec<Duration>,
    /// Samples sorted ascending, filled on first quantile query.
    sorted: OnceCell<Vec<Duration>>,
}

/// Machine-readable summary of one benchmark (all times nanoseconds).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct BenchStats {
    /// Number of timed samples.
    pub n: usize,
    /// Mean sample, ns.
    pub mean_ns: u64,
    /// Median sample, ns.
    pub median_ns: u64,
    /// 95th-percentile sample, ns.
    pub p95_ns: u64,
    /// Fastest sample, ns.
    pub min_ns: u64,
    /// Slowest sample, ns.
    pub max_ns: u64,
}

impl BenchResult {
    /// Wrap a sample set (sorting deferred to the first quantile query).
    pub fn new(name: impl Into<String>, samples: Vec<Duration>) -> Self {
        BenchResult { name: name.into(), samples, sorted: OnceCell::new() }
    }

    /// The cached ascending ordering (sorted exactly once).
    fn sorted(&self) -> &[Duration] {
        self.sorted.get_or_init(|| {
            let mut s = self.samples.clone();
            s.sort();
            s
        })
    }

    /// Mean sample duration.
    pub fn mean(&self) -> Duration {
        let total: Duration = self.samples.iter().sum();
        total / self.samples.len() as u32
    }

    /// The `p`-quantile sample (0.0 = min, 1.0 = max).
    pub fn percentile(&self, p: f64) -> Duration {
        let s = self.sorted();
        let idx = ((s.len() - 1) as f64 * p).round() as usize;
        s[idx]
    }

    /// The full numeric summary (one sort, shared with `percentile`).
    pub fn stats(&self) -> BenchStats {
        let ns = |d: Duration| d.as_nanos().min(u64::MAX as u128) as u64;
        BenchStats {
            n: self.samples.len(),
            mean_ns: ns(self.mean()),
            median_ns: ns(self.percentile(0.5)),
            p95_ns: ns(self.percentile(0.95)),
            min_ns: ns(self.percentile(0.0)),
            max_ns: ns(self.percentile(1.0)),
        }
    }

    /// Print the criterion-style summary row.
    pub fn report(&self) {
        println!(
            "{:<44} mean {:>12?}  median {:>12?}  p95 {:>12?}  min {:>12?}  (n={})",
            self.name,
            self.mean(),
            self.percentile(0.5),
            self.percentile(0.95),
            self.percentile(0.0),
            self.samples.len()
        );
    }
}

/// Benchmark a closure: `warmup` unmeasured runs then `iters` samples.
pub fn bench<F: FnMut()>(name: &str, warmup: usize, iters: usize, mut f: F) -> BenchResult {
    for _ in 0..warmup {
        f();
    }
    let mut samples = Vec::with_capacity(iters);
    for _ in 0..iters {
        let t0 = Instant::now();
        f();
        samples.push(t0.elapsed());
    }
    let r = BenchResult::new(name, samples);
    r.report();
    r
}

/// Throughput helper: elements per second at the mean sample.
pub fn throughput(result: &BenchResult, elems: usize) -> f64 {
    elems as f64 / result.mean().as_secs_f64()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_collects_samples() {
        let r = bench("noop", 1, 5, || {
            std::hint::black_box(1 + 1);
        });
        assert_eq!(r.samples.len(), 5);
        assert!(r.mean() < Duration::from_millis(10));
    }

    #[test]
    fn percentiles_ordered() {
        let r = BenchResult::new("x", (1..=100).map(Duration::from_micros).collect());
        assert!(r.percentile(0.0) <= r.percentile(0.5));
        assert!(r.percentile(0.5) <= r.percentile(0.95));
        assert!(r.percentile(0.95) <= r.percentile(1.0));
    }

    #[test]
    fn percentile_does_not_depend_on_sample_order() {
        // The cached ordering must sort: feed samples in reverse.
        let fwd = BenchResult::new("f", (1..=50).map(Duration::from_micros).collect());
        let rev = BenchResult::new("r", (1..=50).rev().map(Duration::from_micros).collect());
        for p in [0.0, 0.5, 0.95, 1.0] {
            assert_eq!(fwd.percentile(p), rev.percentile(p), "p={p}");
        }
    }

    #[test]
    fn stats_summarize_consistently() {
        let r = BenchResult::new("x", (1..=100).map(Duration::from_micros).collect());
        let s = r.stats();
        assert_eq!(s.n, 100);
        assert_eq!(s.min_ns, 1_000);
        assert_eq!(s.max_ns, 100_000);
        assert_eq!(s.median_ns, r.percentile(0.5).as_nanos() as u64);
        assert!(s.min_ns <= s.median_ns && s.median_ns <= s.p95_ns && s.p95_ns <= s.max_ns);
        assert_eq!(s.mean_ns, 50_500);
    }

    #[test]
    fn throughput_math() {
        let r = BenchResult::new("x", vec![Duration::from_secs(1); 3]);
        assert!((throughput(&r, 1000) - 1000.0).abs() < 1e-6);
    }
}
