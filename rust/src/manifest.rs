//! Manifest: the contract between the AOT pipeline and the Rust runtime.
//!
//! `python -m compile.aot` writes `artifacts/manifest.json` describing every
//! lowered HLO artifact: ordered input/output parameter lists (positional
//! marshalling), shapes, memory coefficients (both the executed mini model
//! and its paper-width twin), and per-model block inventories. This module
//! is the serde mirror plus lookup helpers; nothing here touches PJRT.

use crate::json::Value;
use anyhow::{bail, Context, Result};
use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

/// bytes = fixed_bytes + per_sample_bytes * batch (see python/compile/memory.py).
#[derive(Debug, Clone, Copy, Default)]
pub struct MemCoeffs {
    /// Batch-independent footprint (parameters, optimizer state), bytes.
    pub fixed_bytes: u64,
    /// Activation footprint per sample, bytes.
    pub per_sample_bytes: u64,
    /// Total parameter count of the artifact's model.
    pub params_total: u64,
    /// Trainable parameter count.
    pub params_trainable: u64,
}

impl MemCoeffs {
    /// Analytical training footprint at a given batch size.
    pub fn bytes_at(&self, batch: u64) -> u64 {
        self.fixed_bytes + self.per_sample_bytes * batch
    }
}

/// One positional input of an artifact.
#[derive(Debug, Clone)]
pub struct InputEntry {
    /// Parameter (or data) name.
    pub name: String,
    /// Role: trainable | frozen | param | data_x | data_y | lr.
    pub role: String,
    /// Dimension sizes, outermost first.
    pub shape: Vec<usize>,
}

/// One lowered HLO artifact: what the runtime loads and executes.
#[derive(Debug, Clone)]
pub struct Artifact {
    /// HLO text path relative to the artifacts root.
    pub path: String,
    /// Artifact kind: train | distill | eval.
    pub kind: String,
    /// Ordered positional inputs (parameters first, then data).
    pub inputs: Vec<InputEntry>,
    /// Ordered output names.
    pub outputs: Vec<String>,
    /// Progressive step index, when the artifact belongs to one.
    pub step: Option<usize>,
    /// DepthFL depth index, when applicable.
    pub depth: Option<usize>,
    /// Memory coefficients of the executed mini model.
    pub mem: Option<MemCoeffs>,
    /// Paper-width-twin coefficients: what the memory substrate uses for
    /// participation decisions (DESIGN.md §Substitutions).
    pub mem_paper: Option<MemCoeffs>,
    /// Content hash of the HLO text (integrity check).
    pub sha256: String,
}

impl Artifact {
    /// Names of the trainable inputs, in positional order.
    pub fn trainable_names(&self) -> Vec<&str> {
        self.inputs.iter().filter(|i| i.role == "trainable").map(|i| i.name.as_str()).collect()
    }
    /// Names of the frozen/constant parameter inputs, in positional order.
    pub fn frozen_names(&self) -> Vec<&str> {
        self.inputs
            .iter()
            .filter(|i| i.role == "frozen" || i.role == "param")
            .map(|i| i.name.as_str())
            .collect()
    }
    /// Bytes of one direction of parameter traffic for the trainable set
    /// (what clients upload each round).
    pub fn trainable_bytes(&self) -> u64 {
        self.inputs
            .iter()
            .filter(|i| i.role == "trainable")
            .map(|i| 4 * i.shape.iter().product::<usize>() as u64)
            .sum()
    }
    /// Bytes of the frozen-prefix payload (shipped only on cache misses).
    pub fn frozen_bytes(&self) -> u64 {
        self.inputs
            .iter()
            .filter(|i| i.role == "frozen" || i.role == "param")
            .map(|i| 4 * i.shape.iter().product::<usize>() as u64)
            .sum()
    }
    /// Memory coefficients used for participation (paper twin preferred).
    pub fn participation_mem(&self) -> MemCoeffs {
        self.mem_paper.or(self.mem).unwrap_or_default()
    }
}

/// One model tag's inventory: blocks, parameters, artifacts.
#[derive(Debug, Clone)]
pub struct ModelEntry {
    /// Architecture family (resnet18, vgg11, …).
    pub family: String,
    /// Base channel width of the executed mini model.
    pub width: usize,
    /// Classification classes.
    pub num_classes: usize,
    /// Channel-scaling ratio relative to the base tag (1.0 = base).
    pub width_ratio: f64,
    /// Input image side length.
    pub image_size: usize,
    /// Progressive block count T.
    pub num_blocks: usize,
    /// Parameter counts per block (Table 5).
    pub block_param_counts: Vec<u64>,
    /// Parameter names belonging to each block (index 0 = block 1).
    pub block_params: Vec<Vec<String>>,
    /// Every lowered artifact by name.
    pub artifacts: BTreeMap<String, Artifact>,
    /// Union of every parameter name -> shape the store must hold.
    pub params: BTreeMap<String, Vec<usize>>,
    /// Mini-model memory coefficients by artifact name.
    pub mem: BTreeMap<String, MemCoeffs>,
    /// Paper-width-twin memory coefficients by artifact name.
    pub mem_paper: BTreeMap<String, MemCoeffs>,
}

impl ModelEntry {
    /// Look up an artifact by name.
    pub fn artifact(&self, name: &str) -> Result<&Artifact> {
        self.artifacts.get(name).with_context(|| format!("artifact `{name}` not in manifest"))
    }
    /// Which block (1-based) a parameter belongs to, if any.
    pub fn block_of(&self, param: &str) -> Option<usize> {
        for (i, names) in self.block_params.iter().enumerate() {
            if names.iter().any(|n| n == param) {
                return Some(i + 1);
            }
        }
        None
    }
}

/// The parsed `artifacts/manifest.json`: the AOT pipeline's contract.
#[derive(Debug, Clone)]
pub struct Manifest {
    /// Manifest schema version (currently 1).
    pub version: u32,
    /// Kernel backend the artifacts were lowered with (pallas | native).
    pub kernel_backend: String,
    /// Per-step training batch size of the lowered graphs.
    pub train_batch: usize,
    /// SGD steps fused into one executable call (lax.scan length).
    pub scan_steps: usize,
    /// Evaluation batch size of the eval graphs.
    pub eval_batch: usize,
    /// Every model tag's inventory.
    pub models: BTreeMap<String, ModelEntry>,
}

impl MemCoeffs {
    fn from_value(v: &Value) -> Result<Self> {
        Ok(MemCoeffs {
            fixed_bytes: v.get("fixed_bytes")?.as_u64()?,
            per_sample_bytes: v.get("per_sample_bytes")?.as_u64()?,
            params_total: v.get("params_total")?.as_u64()?,
            params_trainable: v.get("params_trainable")?.as_u64()?,
        })
    }
}

impl Artifact {
    fn from_value(v: &Value) -> Result<Self> {
        let inputs = v
            .get("inputs")?
            .as_arr()?
            .iter()
            .map(|e| {
                Ok(InputEntry {
                    name: e.get("name")?.as_str()?.to_string(),
                    role: e.get("role")?.as_str()?.to_string(),
                    shape: e.get("shape")?.as_shape()?,
                })
            })
            .collect::<Result<Vec<_>>>()?;
        let outputs = v
            .get("outputs")?
            .as_arr()?
            .iter()
            .map(|o| Ok(o.as_str()?.to_string()))
            .collect::<Result<Vec<_>>>()?;
        Ok(Artifact {
            path: v.get("path")?.as_str()?.to_string(),
            kind: v.get("kind")?.as_str()?.to_string(),
            inputs,
            outputs,
            step: v.opt("step").map(|s| s.as_usize()).transpose()?,
            depth: v.opt("depth").map(|s| s.as_usize()).transpose()?,
            mem: v.opt("mem").map(MemCoeffs::from_value).transpose()?,
            mem_paper: v.opt("mem_paper").map(MemCoeffs::from_value).transpose()?,
            sha256: v.opt("sha256").map(|s| s.as_str().map(String::from)).transpose()?.unwrap_or_default(),
        })
    }
}

impl ModelEntry {
    fn from_value(v: &Value) -> Result<Self> {
        let block_params = v
            .get("block_params")?
            .as_arr()?
            .iter()
            .map(|blk| {
                blk.as_arr()?
                    .iter()
                    .map(|n| Ok(n.as_str()?.to_string()))
                    .collect::<Result<Vec<_>>>()
            })
            .collect::<Result<Vec<_>>>()?;
        let mut artifacts = BTreeMap::new();
        for (name, a) in v.get("artifacts")?.as_obj()? {
            artifacts.insert(name.clone(), Artifact::from_value(a).with_context(|| format!("artifact {name}"))?);
        }
        let mut params = BTreeMap::new();
        for (name, shape) in v.get("params")?.as_obj()? {
            params.insert(name.clone(), shape.as_shape()?);
        }
        let mut mem = BTreeMap::new();
        if let Some(m) = v.opt("mem") {
            for (k, c) in m.as_obj()? {
                mem.insert(k.clone(), MemCoeffs::from_value(c)?);
            }
        }
        let mut mem_paper = BTreeMap::new();
        if let Some(m) = v.opt("mem_paper") {
            for (k, c) in m.as_obj()? {
                mem_paper.insert(k.clone(), MemCoeffs::from_value(c)?);
            }
        }
        Ok(ModelEntry {
            family: v.get("family")?.as_str()?.to_string(),
            width: v.get("width")?.as_usize()?,
            num_classes: v.get("num_classes")?.as_usize()?,
            width_ratio: v.get("width_ratio")?.as_f64()?,
            image_size: v.get("image_size")?.as_usize()?,
            num_blocks: v.get("num_blocks")?.as_usize()?,
            block_param_counts: v
                .get("block_param_counts")?
                .as_arr()?
                .iter()
                .map(|c| c.as_u64())
                .collect::<Result<Vec<_>>>()?,
            block_params,
            artifacts,
            params,
            mem,
            mem_paper,
        })
    }
}

impl Manifest {
    /// Parse a manifest document (schema version 1).
    pub fn from_json(text: &str) -> Result<Self> {
        let v = Value::parse(text).context("parsing manifest.json")?;
        let version = v.get("version")?.as_u64()? as u32;
        if version != 1 {
            bail!("unsupported manifest version {version}");
        }
        let mut models = BTreeMap::new();
        for (tag, m) in v.get("models")?.as_obj()? {
            models.insert(tag.clone(), ModelEntry::from_value(m).with_context(|| format!("model {tag}"))?);
        }
        Ok(Manifest {
            version,
            kernel_backend: v.get("kernel_backend")?.as_str()?.to_string(),
            train_batch: v.get("train_batch")?.as_usize()?,
            scan_steps: v.get("scan_steps")?.as_usize()?,
            eval_batch: v.get("eval_batch")?.as_usize()?,
            models,
        })
    }

    /// Read and parse `<artifacts_dir>/manifest.json`.
    pub fn load(artifacts_dir: &Path) -> Result<(Self, PathBuf)> {
        let path = artifacts_dir.join("manifest.json");
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("reading {path:?}; run `make artifacts` first"))?;
        Ok((Manifest::from_json(&text)?, artifacts_dir.to_path_buf()))
    }

    /// Look up a model tag.
    pub fn model(&self, tag: &str) -> Result<&ModelEntry> {
        self.models.get(tag).with_context(|| {
            format!(
                "model `{tag}` not in manifest (have: {:?}); re-run `make artifacts` with the right --models",
                self.models.keys().collect::<Vec<_>>()
            )
        })
    }

    /// The width-ratio variant tag of a base tag, e.g. ("resnet18_w8_c10", 0.25).
    pub fn ratio_tag(base: &str, ratio: f64) -> String {
        if (ratio - 1.0).abs() < 1e-9 {
            base.to_string()
        } else {
            format!("{base}_r{ratio}")
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mem_coeffs_linear() {
        let m = MemCoeffs { fixed_bytes: 100, per_sample_bytes: 7, params_total: 0, params_trainable: 0 };
        assert_eq!(m.bytes_at(0), 100);
        assert_eq!(m.bytes_at(10), 170);
    }

    #[test]
    fn ratio_tag_format() {
        assert_eq!(Manifest::ratio_tag("resnet18_w8_c10", 1.0), "resnet18_w8_c10");
        assert_eq!(Manifest::ratio_tag("resnet18_w8_c10", 0.25), "resnet18_w8_c10_r0.25");
        assert_eq!(Manifest::ratio_tag("resnet18_w8_c10", 0.5), "resnet18_w8_c10_r0.5");
    }

    #[test]
    fn parse_minimal_manifest() {
        let json = r#"{
            "version": 1, "kernel_backend": "native",
            "train_batch": 32, "scan_steps": 4, "eval_batch": 256,
            "models": {
                "m": {
                    "family": "resnet18", "width": 8, "num_classes": 10,
                    "width_ratio": 1.0, "image_size": 32, "num_blocks": 2,
                    "block_param_counts": [10, 20],
                    "block_params": [["b1/w"], ["b2/w"]],
                    "artifacts": {
                        "train_t1": {
                            "path": "m/train_t1.hlo.txt", "kind": "train",
                            "inputs": [
                                {"name": "b1/w", "role": "trainable", "shape": [3,3,1,2]},
                                {"name": "xs", "role": "data_x", "shape": [4,32,32,32,3]},
                                {"name": "ys", "role": "data_y", "shape": [4,32]},
                                {"name": "lr", "role": "lr", "shape": []}
                            ],
                            "outputs": ["b1/w", "loss", "correct"],
                            "mem": {"fixed_bytes": 8, "per_sample_bytes": 2,
                                    "params_total": 2, "params_trainable": 2}
                        }
                    },
                    "params": {"b1/w": [3,3,1,2], "b2/w": [3,3,2,2]}
                }
            }
        }"#;
        let m = Manifest::from_json(json).unwrap();
        let me = m.model("m").unwrap();
        let a = me.artifact("train_t1").unwrap();
        assert_eq!(a.trainable_names(), vec!["b1/w"]);
        assert_eq!(a.trainable_bytes(), 4 * 18);
        assert_eq!(me.block_of("b2/w"), Some(2));
        assert_eq!(me.block_of("head/fc/w"), None);
        assert!(me.artifact("nope").is_err());
    }
}
