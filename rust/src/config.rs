//! Run configuration: one struct drives every method/bench/example.
//!
//! The CLI exposes the common knobs. Defaults are the
//! "fast-table" profile: paper topology (100 clients, 20/round) at
//! bench-feasible round counts. `--profile paper` scales rounds up.

use crate::data::Partition;
use crate::fleet::{ChurnPolicy, FleetProfileConfig, PolicyDefaults, RoundPolicy};
use crate::freezing::FreezeConfig;
use crate::memory::MemoryConfig;
use anyhow::Result;

/// The one config struct every method/bench/example consumes.
#[derive(Debug, Clone)]
pub struct RunConfig {
    /// Manifest model tag, e.g. "resnet18_w8_c10".
    pub model_tag: String,
    /// Device fleet size (paper: 100).
    pub num_clients: usize,
    /// Clients sampled per round (paper: 20).
    pub per_round: usize,
    /// Total training samples across the federation.
    pub total_samples: usize,
    /// IID or Dirichlet alpha.
    pub dirichlet_alpha: Option<f64>,
    /// Client learning rate.
    pub lr: f32,
    /// LR decay multiplier applied per step transition.
    pub lr_step_decay: f32,
    /// Evaluate every k rounds.
    pub eval_every: usize,
    /// Max rounds per progressive step (freezing usually fires earlier).
    pub max_rounds_per_step: usize,
    /// Min rounds per progressive step before freezing may fire.
    pub min_rounds_per_step: usize,
    /// Rounds cap for non-progressive baselines (≈ T × per-step cap).
    pub max_rounds_total: usize,
    /// Distillation rounds per shrink Map step.
    pub distill_rounds: usize,
    /// Run the progressive-model-shrinking stage (ablation switch).
    pub shrinking: bool,
    /// Freezing policy knobs.
    pub freeze: FreezeCfg,
    /// Memory substrate knobs.
    pub memory: MemCfg,
    /// Fleet simulator knobs (device profiles + round policy).
    pub fleet: FleetCfg,
    /// Memory-strategy knobs (see `docs/STRATEGIES.md`).
    pub strategy: StrategyCfg,
    /// Tail length for the final-accuracy statistic (paper: 10).
    pub acc_tail: usize,
    /// Run seed: every stochastic stream forks from it.
    pub seed: u64,
    /// Structured-telemetry JSONL stream path (see `telemetry::`): when
    /// set, the coordinator appends spans/counters/gauges for every
    /// round to this file. `None` (the default) disables telemetry
    /// entirely — the observation hooks are gated on this option, so an
    /// unset path is bit-for-bit inert. CLI: `--telemetry-jsonl` /
    /// `PROFL_TELEMETRY_JSONL`.
    pub telemetry_jsonl: Option<String>,
    /// Telemetry stream size cap in MiB: when set (and telemetry is on),
    /// the live JSONL file rotates to `<stem>.N.jsonl` each time it
    /// crosses the cap, and the run manifest records every segment.
    /// `None` (the default) never rotates. Like `fleet.threads`, this is
    /// a wall-clock knob excluded from `telemetry::config_value` and
    /// therefore from `config_sha256`. CLI: `--telemetry-max-mb`.
    pub telemetry_max_mb: Option<u64>,
    /// Checkpoint file path (see `docs/CHECKPOINT.md`): when set, the
    /// run serializes its complete state here at round boundaries; a
    /// literal `{round}` in the path expands to the round index. `None`
    /// (the default) disables checkpointing. Like `fleet.threads`, this
    /// is a wall-clock knob excluded from `telemetry::config_value` and
    /// therefore from `config_sha256` — checkpointed and plain runs have
    /// the same fingerprint. CLI: `--checkpoint`.
    pub checkpoint: Option<String>,
    /// Checkpoint cadence: write every this many completed rounds
    /// (default 1 = every round boundary). Inert unless `checkpoint` is
    /// set; must be >= 1. CLI: `--checkpoint-every`.
    pub checkpoint_every: usize,
}

/// Fleet-dynamics section: drives the `fleet` discrete-event simulator
/// (see `fleet::` module docs). Strings here are resolved once at
/// `ServerCtx::new` via [`RunConfig::fleet_profile`] /
/// [`RunConfig::round_policy`].
#[derive(Debug, Clone)]
pub struct FleetCfg {
    /// Named device-profile family: `uniform` (homogeneous, always-on,
    /// no dropout — the backwards-compatible default), `mobile`
    /// (three-tier phones, intermittent availability, 10% dropout), or
    /// `datacenter` (fast, wired, reliable). CLI: `--fleet-profile`.
    pub profile: String,
    /// Aggregation policy per train round: `sync` (wait for all),
    /// `deadline` (cut stragglers at `deadline_s`), `over-select`
    /// (sample `per_round + over_select_extra`, keep the first
    /// `per_round` finishers), `async` (FedBuff-style: close the round
    /// at the `buffer_k`-th arrival and keep straggler uploads in flight
    /// across rounds instead of discarding them). Also accepts
    /// `deadline:SECS`, `over-select:K`, and `async:K` spellings.
    /// CLI: `--round-policy`.
    pub round_policy: String,
    /// Deadline in virtual seconds for the `deadline` policy.
    /// CLI: `--deadline-s`.
    pub deadline_s: f64,
    /// Extra clients sampled beyond `per_round` under `over-select`.
    /// CLI: `--over-select`.
    pub over_select_extra: usize,
    /// Per-round dropout probability override; `None` keeps the named
    /// profile's default. CLI: `--dropout`.
    pub dropout_p: Option<f64>,
    /// Arrivals needed to close an `async` round; `None` defaults to
    /// `per_round` — which, with `staleness_alpha = 0`, makes `async`
    /// reproduce the `sync` policy's round records bit-for-bit (the
    /// degeneracy guarantee, see `lib.rs` docs). CLI: `--buffer-k`.
    pub buffer_k: Option<usize>,
    /// Staleness-discount exponent for late merges under `async`:
    /// an update dispatched `s` rounds ago keeps `1 / (1 + s)^alpha` of
    /// its sample weight (FedBuff-style; 0 disables discounting).
    /// CLI: `--staleness-alpha`.
    pub staleness_alpha: f64,
    /// Late updates older than this many rounds are dropped instead of
    /// merged under `async`. CLI: `--max-staleness`.
    pub max_staleness: usize,
    /// Stale-update projection across freeze/step transitions under
    /// `async`: `off` (drop on artifact/prefix-version mismatch — the
    /// backwards-compatible default) or `on` (project the update onto
    /// the still-trained suffix: frozen-block deltas are discarded and
    /// counted, the survivors merge with an extra
    /// `projection_decay^transitions` weight factor).
    /// CLI: `--stale-projection`.
    pub stale_projection: String,
    /// Per-crossed-transition weight decay for projected stale updates,
    /// in [0, 1]. 1 disables the extra penalty; 0 zeroes any update that
    /// crossed a transition. CLI: `--projection-decay`.
    pub projection_decay: f64,
    /// Mid-round churn policy: what happens when a device's availability
    /// trace flips offline *during* a compute or upload span. `none`
    /// (trace gates dispatch only — the backwards-compatible default),
    /// `abort` (work lost, wasted compute counted), `resume` (work
    /// pauses and continues at the next online window), `checkpoint`
    /// (partial update at epoch granularity, merged with weight ∝
    /// completed samples). Also accepts `checkpoint:E`.
    /// CLI: `--churn-policy`.
    pub churn_policy: String,
    /// Checkpoint granularity for the bare `checkpoint` spelling: local
    /// epochs per round a partial update can truncate to.
    /// CLI: `--churn-epochs`.
    pub churn_epochs: usize,
    /// Availability-trace shape override: on/off cycle length in virtual
    /// seconds; `None` keeps the named profile's period.
    /// CLI: `--trace-period`.
    pub trace_period_s: Option<f64>,
    /// Availability-trace shape override: online fraction of each cycle
    /// (`>= 1.0` = always on); `None` keeps the profile's duty.
    /// CLI: `--trace-duty`.
    pub trace_duty: Option<f64>,
    /// Lazy client materialization: build the pool as on-demand
    /// `(seed, id)` recipes behind a small resident cache instead of
    /// materializing every client up front. Bit-identical to the eager
    /// build (see `clients` module docs) but O(cohort) memory per round —
    /// the switch that makes million-device fleets affordable
    /// (`benches/fleet_scale.rs`). Default `false` (eager, historical
    /// behaviour). CLI: `--lazy-pool`.
    pub lazy_pool: bool,
    /// Worker threads for the engine's per-client span precompute. 1 (the
    /// default) plans inline — the historical single-threaded path; any
    /// count produces bit-identical results (the determinism contract,
    /// see `docs/SIMULATION.md`), so this is purely a wall-clock knob.
    /// Defaults to the `PROFL_THREADS` env var when set.
    /// CLI: `--threads`.
    pub threads: usize,
}

impl Default for FleetCfg {
    fn default() -> Self {
        // The bare-spelling policy numbers have exactly one source of
        // truth: the engine's `PolicyDefaults`. Mirroring them here (and
        // pinning the equality in a test below) means a bare `deadline`
        // or `async` spelling can never silently diverge from the
        // configured defaults. `buffer_k` stays `None` — it resolves to
        // `per_round` at `round_policy()` time, deliberately *not* the
        // engine fallback.
        let policy = PolicyDefaults::default();
        FleetCfg {
            profile: "uniform".into(),
            round_policy: "sync".into(),
            deadline_s: policy.deadline_s,
            over_select_extra: policy.over_select_extra,
            dropout_p: None,
            buffer_k: None,
            staleness_alpha: 0.5,
            max_staleness: policy.max_staleness,
            stale_projection: "off".into(),
            projection_decay: 0.5,
            churn_policy: "none".into(),
            churn_epochs: 4,
            trace_period_s: None,
            trace_duty: None,
            lazy_pool: false,
            threads: crate::fleet::default_threads(),
        }
    }
}

/// Memory-strategy section: which strategy a `run` executes and the
/// strategy-specific knobs (see `strategy::` module docs and
/// `docs/STRATEGIES.md`). Defaults leave every knob unset, which is
/// bit-for-bit the pre-strategy behaviour.
#[derive(Debug, Clone, Default)]
pub struct StrategyCfg {
    /// Strategy override for the `run` subcommand: when set, the run
    /// executes this memory strategy regardless of `--method`
    /// (`profl | paramaware | layerfreeze | elastic`). `None` (the
    /// default) keeps `--method` in charge. CLI: `--strategy`.
    pub name: Option<String>,
    /// `elastic`: number of memory-budget-curve phases; `None` plans
    /// one per block. CLI: `--elastic-phases`.
    pub elastic_phases: Option<usize>,
    /// `layerfreeze`: optional per-step round cap; `None` (the default)
    /// lets each front block train until the EM detector freezes it.
    /// CLI: `--freeze-step-cap`.
    pub freeze_step_cap: Option<usize>,
}

/// Strategy names accepted by [`RunConfig::strategy_name`], in display
/// order. Every entry is also a `methods::by_name` spelling.
pub const STRATEGY_NAMES: [&str; 4] = ["profl", "paramaware", "layerfreeze", "elastic"];

/// Plain-data twin of freezing::FreezeConfig.
#[derive(Debug, Clone, Copy)]
pub struct FreezeCfg {
    /// Delta window H for effective movement.
    pub window_h: usize,
    /// Slope threshold φ.
    pub phi: f64,
    /// Consecutive below-threshold evaluations required (patience W).
    pub patience_w: usize,
    /// Points used in each slope fit.
    pub fit_points: usize,
    /// Never freeze before this many EM observations (warm-up).
    pub min_observations: usize,
}

impl From<FreezeCfg> for FreezeConfig {
    fn from(c: FreezeCfg) -> Self {
        FreezeConfig {
            window_h: c.window_h,
            phi: c.phi,
            patience_w: c.patience_w,
            fit_points: c.fit_points,
            min_observations: c.min_observations,
        }
    }
}

/// Plain-data twin of memory::MemoryConfig.
#[derive(Debug, Clone, Copy)]
pub struct MemCfg {
    /// Static budget range lower bound, MB.
    pub budget_min_mb: u64,
    /// Static budget range upper bound, MB.
    pub budget_max_mb: u64,
    /// Per-round contention factor lower bound.
    pub contention_lo: f64,
    /// Batch size used for footprint accounting.
    pub accounting_batch: u64,
}

impl From<MemCfg> for MemoryConfig {
    fn from(c: MemCfg) -> Self {
        MemoryConfig {
            budget_min_mb: c.budget_min_mb,
            budget_max_mb: c.budget_max_mb,
            contention_lo: c.contention_lo,
            accounting_batch: c.accounting_batch,
        }
    }
}

impl Default for RunConfig {
    fn default() -> Self {
        RunConfig {
            model_tag: "resnet18_w8_c10".into(),
            num_clients: 100,
            per_round: 10,
            total_samples: 10_000,
            dirichlet_alpha: None,
            lr: 0.08,
            lr_step_decay: 1.0,
            eval_every: 5,
            max_rounds_per_step: 40,
            min_rounds_per_step: 10,
            max_rounds_total: 160,
            distill_rounds: 4,
            shrinking: true,
            freeze: FreezeCfg { window_h: 3, phi: 0.01, patience_w: 3, fit_points: 5, min_observations: 6 },
            memory: MemCfg { budget_min_mb: 100, budget_max_mb: 900, contention_lo: 0.7, accounting_batch: 128 },
            fleet: FleetCfg::default(),
            strategy: StrategyCfg::default(),
            acc_tail: 10,
            seed: 42,
            telemetry_jsonl: None,
            telemetry_max_mb: None,
            checkpoint: None,
            checkpoint_every: 1,
        }
    }
}

impl RunConfig {
    /// The configured data-partition scheme (IID unless alpha is set).
    pub fn partition(&self) -> Partition {
        match self.dirichlet_alpha {
            Some(alpha) => Partition::Dirichlet { alpha },
            None => Partition::Iid,
        }
    }

    /// Resolve the named fleet profile, applying the dropout and
    /// trace-shape overrides.
    pub fn fleet_profile(&self) -> Result<FleetProfileConfig> {
        let mut p = FleetProfileConfig::named(&self.fleet.profile)?;
        if let Some(d) = self.fleet.dropout_p {
            if !(0.0..=1.0).contains(&d) {
                anyhow::bail!("dropout probability must be in [0, 1], got {d}");
            }
            p.dropout_p = d;
        }
        if let Some(period) = self.fleet.trace_period_s {
            if !period.is_finite() || period <= 0.0 {
                anyhow::bail!("trace period must be a finite positive seconds value, got {period}");
            }
            p.period_s = period;
        }
        if let Some(duty) = self.fleet.trace_duty {
            // duty >= 1 spells always-on; duty <= 0 would make the whole
            // fleet permanently unreachable — reject the typo.
            if !duty.is_finite() || duty <= 0.0 {
                anyhow::bail!("trace duty must be a finite positive fraction, got {duty}");
            }
            p.duty = duty;
        }
        if self.fleet.threads == 0 {
            anyhow::bail!("threads must be >= 1 (1 = inline single-threaded span planning)");
        }
        Ok(p)
    }

    /// Resolve the configured mid-round churn policy string. The bare
    /// `checkpoint` spelling takes its granularity from
    /// `fleet.churn_epochs`.
    pub fn churn_policy(&self) -> Result<ChurnPolicy> {
        ChurnPolicy::parse(&self.fleet.churn_policy, self.fleet.churn_epochs)
    }

    /// Resolve the stale-projection switch: `Some(decay)` when enabled
    /// (`on`), `None` for the historical drop-on-mismatch behaviour
    /// (`off`, the default). The decay must be a finite fraction in
    /// [0, 1] — anything above 1 would *amplify* transition-crossing
    /// updates.
    pub fn stale_projection(&self) -> Result<Option<f64>> {
        match self.fleet.stale_projection.as_str() {
            "off" => Ok(None),
            "on" => {
                let d = self.fleet.projection_decay;
                if !d.is_finite() || !(0.0..=1.0).contains(&d) {
                    anyhow::bail!("projection decay must be in [0, 1], got {d}");
                }
                Ok(Some(d))
            }
            other => anyhow::bail!("unknown stale-projection mode `{other}` (off|on)"),
        }
    }

    /// Resolve the configured round policy string. The bare `async`
    /// spelling takes its buffer size from `fleet.buffer_k`, defaulting
    /// to `per_round` (the sync-degenerate buffer).
    pub fn round_policy(&self) -> Result<RoundPolicy> {
        // `deadline_s` feeds the bare `deadline` spelling below, but a
        // nonsense value is a config bug whatever the active policy —
        // `cli.rs` deliberately accepts negative numerics (`--lr -0.1`),
        // so `--deadline-s -5` (or NaN/inf/0) parses and must be caught
        // here, at resolution, before any round runs.
        let d = self.fleet.deadline_s;
        if !d.is_finite() || d <= 0.0 {
            anyhow::bail!("deadline_s must be a finite positive number of virtual seconds, got {d}");
        }
        let policy = RoundPolicy::parse(
            &self.fleet.round_policy,
            &PolicyDefaults {
                deadline_s: self.fleet.deadline_s,
                over_select_extra: self.fleet.over_select_extra,
                buffer_k: self.fleet.buffer_k.unwrap_or(self.per_round),
                max_staleness: self.fleet.max_staleness,
            },
        )?;
        if matches!(policy, RoundPolicy::Async { .. })
            && !(self.fleet.staleness_alpha.is_finite() && self.fleet.staleness_alpha >= 0.0)
        {
            // A negative alpha would *up-weight* stale updates.
            anyhow::bail!(
                "staleness_alpha must be finite and >= 0, got {}",
                self.fleet.staleness_alpha
            );
        }
        Ok(policy)
    }

    /// Resolve the `--strategy` override: `Ok(Some(name))` for a known
    /// strategy (normalized to lowercase), `Ok(None)` when unset, and
    /// an error for unknown spellings — plus fail-fast validation of
    /// the strategy-specific knobs (a zero cap or zero phase count can
    /// never make progress).
    pub fn strategy_name(&self) -> Result<Option<String>> {
        if let Some(n) = self.strategy.elastic_phases {
            if n == 0 {
                anyhow::bail!("elastic-phases must be >= 1, got 0");
            }
        }
        if let Some(c) = self.strategy.freeze_step_cap {
            if c == 0 {
                anyhow::bail!("freeze-step-cap must be >= 1, got 0");
            }
        }
        match &self.strategy.name {
            None => Ok(None),
            Some(raw) => {
                let lower = raw.to_ascii_lowercase();
                if STRATEGY_NAMES.contains(&lower.as_str()) {
                    Ok(Some(lower))
                } else {
                    anyhow::bail!(
                        "unknown strategy `{raw}` (expected one of: {})",
                        STRATEGY_NAMES.join("|")
                    )
                }
            }
        }
    }

    /// Resolve the checkpoint sink knobs: `Ok(Some((path, every)))` when
    /// `checkpoint` is set, `Ok(None)` when checkpointing is off, and an
    /// error for a zero cadence (which could never fire). Both knobs are
    /// wall-clock-only — excluded from `telemetry::config_value` and so
    /// from `config_sha256` (see `docs/CHECKPOINT.md`).
    pub fn checkpoint_plan(&self) -> Result<Option<(String, usize)>> {
        if self.checkpoint_every == 0 {
            anyhow::bail!("checkpoint-every must be >= 1, got 0");
        }
        Ok(self.checkpoint.as_ref().map(|p| (p.clone(), self.checkpoint_every)))
    }

    /// Reconstruct a `RunConfig` from its canonical JSON image
    /// (`telemetry::config_value`) — the inverse `profl resume` uses to
    /// rebuild the run a checkpoint was taken under. Wall-clock knobs
    /// absent from the image (`fleet.threads`, `checkpoint`,
    /// `checkpoint_every`, `telemetry_max_mb`) take their defaults;
    /// everything the
    /// `config_sha256` fingerprint covers round-trips exactly
    /// (`config_value(from_value(config_value(c))) == config_value(c)`,
    /// pinned by a test below). Strict: missing or mistyped keys error.
    pub fn from_value(v: &crate::json::Value) -> Result<RunConfig> {
        use crate::json::Value;
        fn opt_f64(v: &Value, key: &str) -> Result<Option<f64>> {
            match v.get(key)? {
                Value::Null => Ok(None),
                x => Ok(Some(x.as_f64()?)),
            }
        }
        fn opt_usize(v: &Value, key: &str) -> Result<Option<usize>> {
            match v.get(key)? {
                Value::Null => Ok(None),
                x => Ok(Some(x.as_usize()?)),
            }
        }
        fn opt_str(v: &Value, key: &str) -> Result<Option<String>> {
            match v.get(key)? {
                Value::Null => Ok(None),
                x => Ok(Some(x.as_str()?.to_string())),
            }
        }
        let fz = v.get("freeze")?;
        let mem = v.get("memory")?;
        let fl = v.get("fleet")?;
        let st = v.get("strategy")?;
        let seed: u64 = v
            .get("seed")?
            .as_str()?
            .parse()
            .map_err(|e| anyhow::anyhow!("bad seed string: {e}"))?;
        Ok(RunConfig {
            model_tag: v.get("model_tag")?.as_str()?.to_string(),
            num_clients: v.get("num_clients")?.as_usize()?,
            per_round: v.get("per_round")?.as_usize()?,
            total_samples: v.get("total_samples")?.as_usize()?,
            dirichlet_alpha: opt_f64(v, "dirichlet_alpha")?,
            lr: v.get("lr")?.as_f64()? as f32,
            lr_step_decay: v.get("lr_step_decay")?.as_f64()? as f32,
            eval_every: v.get("eval_every")?.as_usize()?,
            max_rounds_per_step: v.get("max_rounds_per_step")?.as_usize()?,
            min_rounds_per_step: v.get("min_rounds_per_step")?.as_usize()?,
            max_rounds_total: v.get("max_rounds_total")?.as_usize()?,
            distill_rounds: v.get("distill_rounds")?.as_usize()?,
            shrinking: v.get("shrinking")?.as_bool()?,
            freeze: FreezeCfg {
                window_h: fz.get("window_h")?.as_usize()?,
                phi: fz.get("phi")?.as_f64()?,
                patience_w: fz.get("patience_w")?.as_usize()?,
                fit_points: fz.get("fit_points")?.as_usize()?,
                min_observations: fz.get("min_observations")?.as_usize()?,
            },
            memory: MemCfg {
                budget_min_mb: mem.get("budget_min_mb")?.as_u64()?,
                budget_max_mb: mem.get("budget_max_mb")?.as_u64()?,
                contention_lo: mem.get("contention_lo")?.as_f64()?,
                accounting_batch: mem.get("accounting_batch")?.as_u64()?,
            },
            fleet: FleetCfg {
                profile: fl.get("profile")?.as_str()?.to_string(),
                round_policy: fl.get("round_policy")?.as_str()?.to_string(),
                deadline_s: fl.get("deadline_s")?.as_f64()?,
                over_select_extra: fl.get("over_select_extra")?.as_usize()?,
                dropout_p: opt_f64(fl, "dropout_p")?,
                buffer_k: opt_usize(fl, "buffer_k")?,
                staleness_alpha: fl.get("staleness_alpha")?.as_f64()?,
                max_staleness: fl.get("max_staleness")?.as_usize()?,
                stale_projection: fl.get("stale_projection")?.as_str()?.to_string(),
                projection_decay: fl.get("projection_decay")?.as_f64()?,
                churn_policy: fl.get("churn_policy")?.as_str()?.to_string(),
                churn_epochs: fl.get("churn_epochs")?.as_usize()?,
                trace_period_s: opt_f64(fl, "trace_period_s")?,
                trace_duty: opt_f64(fl, "trace_duty")?,
                lazy_pool: fl.get("lazy_pool")?.as_bool()?,
                threads: crate::fleet::default_threads(),
            },
            strategy: StrategyCfg {
                name: opt_str(st, "name")?,
                elastic_phases: opt_usize(st, "elastic_phases")?,
                freeze_step_cap: opt_usize(st, "freeze_step_cap")?,
            },
            acc_tail: v.get("acc_tail")?.as_usize()?,
            seed,
            telemetry_jsonl: opt_str(v, "telemetry_jsonl")?,
            telemetry_max_mb: None,
            checkpoint: None,
            checkpoint_every: 1,
        })
    }

    /// A smoke-test profile: tiny rounds, quick everything. Used by
    /// integration tests and the quickstart example.
    pub fn smoke(model_tag: &str) -> Self {
        RunConfig {
            model_tag: model_tag.into(),
            num_clients: 12,
            per_round: 4,
            total_samples: 1_200,
            eval_every: 4,
            max_rounds_per_step: 8,
            min_rounds_per_step: 3,
            max_rounds_total: 32,
            distill_rounds: 2,
            ..Default::default()
        }
    }

    /// Longer-run profile closer to the paper's regime (for EXPERIMENTS.md
    /// headline runs; still CPU-tractable).
    pub fn paper(model_tag: &str) -> Self {
        RunConfig {
            model_tag: model_tag.into(),
            per_round: 20,
            total_samples: 20_000,
            max_rounds_per_step: 100,
            min_rounds_per_step: 15,
            max_rounds_total: 400,
            distill_rounds: 8,
            ..Default::default()
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn default_matches_paper_topology() {
        // Fleet + memory topology follow the paper; per-round cohort is
        // reduced in the fast profile (single-core testbed) and restored
        // to the paper's 20 by the `paper` profile.
        let c = RunConfig::default();
        assert_eq!(c.num_clients, 100);
        assert_eq!(c.memory.budget_min_mb, 100);
        assert_eq!(c.memory.budget_max_mb, 900);
        assert_eq!(c.acc_tail, 10);
        assert_eq!(RunConfig::paper("m").per_round, 20);
    }

    #[test]
    fn partition_mapping() {
        let mut c = RunConfig::default();
        assert_eq!(c.partition(), Partition::Iid);
        c.dirichlet_alpha = Some(1.0);
        assert_eq!(c.partition(), Partition::Dirichlet { alpha: 1.0 });
    }

    #[test]
    fn smoke_profile_is_small() {
        let c = RunConfig::smoke("resnet18_w8_c10");
        assert!(c.max_rounds_total <= 64);
        assert!(c.num_clients <= 20);
    }

    #[test]
    fn strategy_knobs_resolve_and_validate() {
        let mut c = RunConfig::default();
        // Backwards-compatible default: no strategy override.
        assert_eq!(c.strategy_name().unwrap(), None);
        for name in STRATEGY_NAMES {
            c.strategy.name = Some(name.to_ascii_uppercase());
            assert_eq!(c.strategy_name().unwrap().as_deref(), Some(name), "case-normalized");
        }
        c.strategy.name = Some("heterofl".into());
        assert!(c.strategy_name().is_err(), "methods that aren't strategies are rejected");
        c.strategy.name = Some("profl".into());
        c.strategy.elastic_phases = Some(0);
        assert!(c.strategy_name().is_err(), "zero curve phases");
        c.strategy.elastic_phases = Some(3);
        c.strategy.freeze_step_cap = Some(0);
        assert!(c.strategy_name().is_err(), "zero step cap");
        c.strategy.freeze_step_cap = Some(8);
        assert!(c.strategy_name().is_ok());
    }

    #[test]
    fn fleet_defaults_are_backwards_compatible() {
        // Default fleet: sync policy + uniform always-on profile with no
        // dropout, so pre-fleet round semantics are preserved.
        let c = RunConfig::default();
        assert_eq!(c.round_policy().unwrap(), RoundPolicy::Sync);
        let p = c.fleet_profile().unwrap();
        assert_eq!(p.name, "uniform");
        assert_eq!(p.dropout_p, 0.0);
        assert!(p.duty >= 1.0);
    }

    #[test]
    fn fleet_overrides_resolve() {
        let mut c = RunConfig::default();
        c.fleet.round_policy = "deadline".into();
        c.fleet.deadline_s = 45.0;
        c.fleet.dropout_p = Some(0.25);
        assert_eq!(c.round_policy().unwrap(), RoundPolicy::Deadline { secs: 45.0 });
        assert_eq!(c.fleet_profile().unwrap().dropout_p, 0.25);
        c.fleet.round_policy = "warp".into();
        assert!(c.round_policy().is_err());
        c.fleet.profile = "quantum".into();
        assert!(c.fleet_profile().is_err());
    }

    #[test]
    fn async_policy_resolves_with_per_round_default_buffer() {
        let mut c = RunConfig::default();
        c.fleet.round_policy = "async".into();
        // buffer_k unset ⇒ per_round (the sync-degenerate buffer).
        assert_eq!(
            c.round_policy().unwrap(),
            RoundPolicy::Async { buffer_k: c.per_round, max_staleness: 8 }
        );
        c.fleet.buffer_k = Some(3);
        c.fleet.max_staleness = 5;
        assert_eq!(
            c.round_policy().unwrap(),
            RoundPolicy::Async { buffer_k: 3, max_staleness: 5 }
        );
        // Explicit spelling wins over the config knob.
        c.fleet.round_policy = "async:7".into();
        assert_eq!(
            c.round_policy().unwrap(),
            RoundPolicy::Async { buffer_k: 7, max_staleness: 5 }
        );
        // Rejection cases: a buffer that can never close.
        c.fleet.round_policy = "async:0".into();
        assert!(c.round_policy().is_err());
        c.fleet.round_policy = "async".into();
        c.fleet.buffer_k = Some(0);
        assert!(c.round_policy().is_err());
    }

    #[test]
    fn churn_policy_resolves_and_defaults_off() {
        let mut c = RunConfig::default();
        // Backwards-compatible default: no mid-round churn.
        assert_eq!(c.churn_policy().unwrap(), ChurnPolicy::None);
        c.fleet.churn_policy = "abort".into();
        assert_eq!(c.churn_policy().unwrap(), ChurnPolicy::Abort);
        c.fleet.churn_policy = "resume".into();
        assert_eq!(c.churn_policy().unwrap(), ChurnPolicy::Resume);
        // Bare checkpoint takes churn_epochs; the :E spelling wins.
        c.fleet.churn_policy = "checkpoint".into();
        assert_eq!(c.churn_policy().unwrap(), ChurnPolicy::Checkpoint { epochs: 4 });
        c.fleet.churn_epochs = 6;
        assert_eq!(c.churn_policy().unwrap(), ChurnPolicy::Checkpoint { epochs: 6 });
        c.fleet.churn_policy = "checkpoint:2".into();
        assert_eq!(c.churn_policy().unwrap(), ChurnPolicy::Checkpoint { epochs: 2 });
        c.fleet.churn_policy = "evaporate".into();
        assert!(c.churn_policy().is_err());
        c.fleet.churn_policy = "checkpoint".into();
        c.fleet.churn_epochs = 0;
        assert!(c.churn_policy().is_err(), "zero epoch granularity");
    }

    #[test]
    fn stale_projection_resolves_and_validates() {
        let mut c = RunConfig::default();
        // Backwards-compatible default: projection off (drop behaviour).
        assert_eq!(c.stale_projection().unwrap(), None);
        c.fleet.stale_projection = "on".into();
        assert_eq!(c.stale_projection().unwrap(), Some(0.5), "default decay rides along");
        c.fleet.projection_decay = 1.0;
        assert_eq!(c.stale_projection().unwrap(), Some(1.0), "decay 1 = no extra penalty");
        c.fleet.projection_decay = 0.0;
        assert_eq!(c.stale_projection().unwrap(), Some(0.0), "decay 0 = kill crossed updates");
        // Rejections: amplification, nonsense values, unknown modes.
        c.fleet.projection_decay = 1.5;
        assert!(c.stale_projection().is_err(), "decay > 1 amplifies stale updates");
        c.fleet.projection_decay = -0.1;
        assert!(c.stale_projection().is_err(), "negative decay");
        c.fleet.projection_decay = f64::NAN;
        assert!(c.stale_projection().is_err(), "non-finite decay");
        c.fleet.projection_decay = 0.5;
        c.fleet.stale_projection = "maybe".into();
        assert!(c.stale_projection().is_err(), "unknown mode");
        // `off` ignores a bad decay (the knob is inert).
        c.fleet.stale_projection = "off".into();
        c.fleet.projection_decay = f64::NAN;
        assert!(c.stale_projection().unwrap().is_none());
    }

    #[test]
    fn trace_shape_overrides_resolve_and_validate() {
        let mut c = RunConfig::default();
        c.fleet.profile = "mobile".into();
        let base = c.fleet_profile().unwrap();
        assert_eq!((base.period_s, base.duty), (900.0, 0.85));
        c.fleet.trace_period_s = Some(120.0);
        c.fleet.trace_duty = Some(0.5);
        let p = c.fleet_profile().unwrap();
        assert_eq!((p.period_s, p.duty), (120.0, 0.5));
        // duty >= 1 spells always-on (valid).
        c.fleet.trace_duty = Some(1.0);
        assert!(c.fleet_profile().is_ok());
        // Rejections: unreachable fleet / nonsense shapes.
        c.fleet.trace_duty = Some(0.0);
        assert!(c.fleet_profile().is_err(), "zero duty");
        c.fleet.trace_duty = Some(f64::NAN);
        assert!(c.fleet_profile().is_err(), "NaN duty");
        c.fleet.trace_duty = Some(0.5);
        c.fleet.trace_period_s = Some(-3.0);
        assert!(c.fleet_profile().is_err(), "negative period");
        c.fleet.trace_period_s = Some(f64::INFINITY);
        assert!(c.fleet_profile().is_err(), "non-finite period");
    }

    #[test]
    fn fleet_cfg_defaults_mirror_engine_policy_defaults() {
        // Single source of truth: the bare `deadline`/`over-select`/
        // `async` spellings fall back to the engine's PolicyDefaults,
        // and FleetCfg::default() is derived from the same struct — so
        // the two can never silently diverge.
        let cfg = FleetCfg::default();
        let policy = PolicyDefaults::default();
        assert_eq!(cfg.deadline_s.to_bits(), policy.deadline_s.to_bits());
        assert_eq!(cfg.over_select_extra, policy.over_select_extra);
        assert_eq!(cfg.max_staleness, policy.max_staleness);
        // buffer_k intentionally differs: config resolves None → per_round.
        assert_eq!(cfg.buffer_k, None);
    }

    #[test]
    fn deadline_seconds_are_validated_at_resolution() {
        // `--deadline-s` flows through cli.rs (which accepts negative
        // numerics by design) into this knob; resolution is the gate.
        let mut c = RunConfig::default();
        c.fleet.round_policy = "deadline".into();
        for bad in [-5.0, 0.0, f64::NAN, f64::INFINITY, f64::NEG_INFINITY] {
            c.fleet.deadline_s = bad;
            assert!(c.round_policy().is_err(), "deadline_s {bad} must be rejected");
        }
        c.fleet.deadline_s = 45.0;
        assert_eq!(c.round_policy().unwrap(), RoundPolicy::Deadline { secs: 45.0 });
        // The knob is validated even when another policy is active — a
        // nonsense value is a config bug whatever consumes it.
        c.fleet.round_policy = "sync".into();
        c.fleet.deadline_s = f64::NAN;
        assert!(c.round_policy().is_err(), "NaN deadline_s under sync");
        // The explicit spelling is gated too (parse-level).
        c.fleet.deadline_s = 60.0;
        c.fleet.round_policy = "deadline:0".into();
        assert!(c.round_policy().is_err(), "deadline:0 closes instantly");
    }

    #[test]
    fn thread_knob_validates_and_defaults_positive() {
        let mut c = RunConfig::default();
        // The default honors PROFL_THREADS in CI, so assert the invariant
        // rather than the literal: always a positive inline-safe count.
        assert!(c.fleet.threads >= 1);
        assert!(c.fleet_profile().is_ok());
        c.fleet.threads = 8;
        assert!(c.fleet_profile().is_ok());
        c.fleet.threads = 0;
        assert!(c.fleet_profile().is_err(), "0 threads can plan nothing");
    }

    #[test]
    fn bad_fleet_knobs_are_rejected() {
        // A negative alpha would up-weight stale updates; out-of-range
        // dropout is a probability typo — both must fail fast.
        let mut c = RunConfig::default();
        c.fleet.round_policy = "async".into();
        c.fleet.staleness_alpha = -1.0;
        assert!(c.round_policy().is_err(), "negative alpha");
        c.fleet.staleness_alpha = f64::NAN;
        assert!(c.round_policy().is_err(), "non-finite alpha");
        c.fleet.staleness_alpha = 0.0;
        assert!(c.round_policy().is_ok(), "alpha 0 is the degenerate knob");
        // Alpha is an async-only knob; sync runs ignore it.
        c.fleet.staleness_alpha = -1.0;
        c.fleet.round_policy = "sync".into();
        assert!(c.round_policy().is_ok());

        c.fleet.dropout_p = Some(1.5);
        assert!(c.fleet_profile().is_err(), "dropout > 1");
        c.fleet.dropout_p = Some(-0.2);
        assert!(c.fleet_profile().is_err(), "negative dropout");
        c.fleet.dropout_p = Some(0.3);
        assert_eq!(c.fleet_profile().unwrap().dropout_p, 0.3);
    }

    #[test]
    fn checkpoint_plan_resolves_and_validates() {
        let mut c = RunConfig::default();
        // Backwards-compatible default: checkpointing off.
        assert_eq!(c.checkpoint_plan().unwrap(), None);
        c.checkpoint = Some("/tmp/run.ckpt".into());
        assert_eq!(c.checkpoint_plan().unwrap(), Some(("/tmp/run.ckpt".into(), 1)));
        c.checkpoint_every = 5;
        assert_eq!(c.checkpoint_plan().unwrap(), Some(("/tmp/run.ckpt".into(), 5)));
        c.checkpoint_every = 0;
        assert!(c.checkpoint_plan().is_err(), "a zero cadence can never fire");
        // The cadence is validated even with no path — a nonsense value
        // is a config bug whatever consumes it.
        c.checkpoint = None;
        assert!(c.checkpoint_plan().is_err());
    }

    #[test]
    fn from_value_inverts_config_value() {
        // The resume path reconstructs the config from the checkpoint's
        // embedded canonical JSON; everything config_sha256 covers must
        // round-trip exactly — including Options in both states and a
        // seed that does not fit an f64 mantissa.
        let mut c = RunConfig::default();
        let rt = RunConfig::from_value(&crate::telemetry::config_value(&c)).unwrap();
        assert_eq!(
            crate::telemetry::config_value(&c).to_json(),
            crate::telemetry::config_value(&rt).to_json()
        );
        c.dirichlet_alpha = Some(0.3);
        c.seed = u64::MAX - 7; // needs the string channel, not f64
        c.lr = 0.017;
        c.telemetry_jsonl = Some("t.jsonl".into());
        c.fleet.round_policy = "async:3".into();
        c.fleet.buffer_k = Some(6);
        c.fleet.dropout_p = Some(0.15);
        c.fleet.trace_period_s = Some(120.0);
        c.fleet.trace_duty = Some(0.5);
        c.fleet.lazy_pool = true;
        c.strategy.name = Some("elastic".into());
        c.strategy.elastic_phases = Some(3);
        c.strategy.freeze_step_cap = Some(8);
        let rt = RunConfig::from_value(&crate::telemetry::config_value(&c)).unwrap();
        assert_eq!(
            crate::telemetry::config_value(&c).to_json(),
            crate::telemetry::config_value(&rt).to_json()
        );
        assert_eq!(rt.seed, c.seed);
        assert_eq!(rt.lr.to_bits(), c.lr.to_bits());
        // Strictness: a truncated image errors instead of defaulting.
        let v = crate::json::Value::parse("{\"model_tag\":\"m\"}").unwrap();
        assert!(RunConfig::from_value(&v).is_err());
        let v = crate::json::Value::parse("[1,2]").unwrap();
        assert!(RunConfig::from_value(&v).is_err());
    }

    #[test]
    fn checkpoint_knobs_are_hash_neutral() {
        // Like threads, the checkpoint sink is a wall-clock knob: turning
        // it on must not change the run's config fingerprint, or a
        // resumed run could never verify against a plain run's manifest.
        let plain = RunConfig::default();
        let mut ck = RunConfig::default();
        ck.checkpoint = Some("/tmp/run-{round}.ckpt".into());
        ck.checkpoint_every = 7;
        ck.fleet.threads = plain.fleet.threads + 3;
        ck.telemetry_max_mb = Some(64);
        assert_eq!(
            crate::telemetry::config_sha256(&plain),
            crate::telemetry::config_sha256(&ck)
        );
    }
}
