//! Adversarial in-tree fuzzer for every untrusted-input parser.
//!
//! The offline image carries no cargo-fuzz/libFuzzer, so this file is a
//! seeded deterministic mutation fuzzer: each target starts from valid
//! seed inputs, applies ≥200 rng-driven mutations (bit flips, truncations,
//! splices, length-field tampering, token shuffles), and asserts that the
//! parser under attack returns a clean `Err` — it must **never** panic,
//! abort, or allocate unboundedly. Every run is reproducible from the
//! fixed seeds; failures print the mutation index for replay.
//!
//! Targets:
//!   * [`Checkpoint::decode`] — raw byte mutations (digest rejects) AND
//!     payload mutations with the digest recomputed (so the structural
//!     validators inside `decode_payload` face the hostile bytes).
//!   * [`crate::json::Value::parse`] + [`RunConfig::from_value`] — the
//!     config resurrection path `profl resume` trusts.
//!   * [`cli::Args::parse`] — random token streams.
//!   * [`RoundPolicy::parse`] / [`ChurnPolicy::parse`] — policy strings.
//!
//! A small regression corpus lives in `tests/corpus/`: inputs that once
//! exercised interesting decoder paths, replayed verbatim before the
//! random campaign so past near-misses stay covered.

use profl::checkpoint::{Checkpoint, Dec, Enc};
use profl::cli::Args;
use profl::clients::{ClientCkpt, PoolCkptKind, PoolCkptState};
use profl::fleet::{ChurnPolicy, InFlightUpload, PolicyDefaults, RoundPolicy};
use profl::freezing::Transition;
use profl::json::Value;
use profl::rng::Rng;
use profl::telemetry::sha256_hex;
use profl::RunConfig;
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::path::PathBuf;

/// Mutations per parser target; the issue floor is 200.
const MUTATIONS: usize = 256;

fn corpus_dir() -> PathBuf {
    PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("tests").join("corpus")
}

/// Replay every corpus file whose name starts with `prefix` through `f`;
/// returns how many were replayed (the corpus is committed, so zero
/// means the checkout is broken).
fn replay_corpus(prefix: &str, mut f: impl FnMut(&str, Vec<u8>)) -> usize {
    let mut names: Vec<_> = std::fs::read_dir(corpus_dir())
        .expect("tests/corpus must exist")
        .map(|e| e.unwrap().path())
        .filter(|p| {
            p.file_name().and_then(|n| n.to_str()).is_some_and(|n| n.starts_with(prefix))
        })
        .collect();
    names.sort();
    let n = names.len();
    for path in names {
        let name = path.file_name().unwrap().to_string_lossy().into_owned();
        let bytes = std::fs::read(&path).unwrap();
        f(&name, bytes);
    }
    n
}

/// Run `f` on hostile input `tag`; propagate a clean Err silently, turn
/// a panic into a test failure that names the case.
fn must_not_panic<T>(tag: &str, f: impl FnOnce() -> T) -> T {
    match catch_unwind(AssertUnwindSafe(f)) {
        Ok(v) => v,
        Err(e) => {
            let msg = e
                .downcast_ref::<String>()
                .map(String::as_str)
                .or_else(|| e.downcast_ref::<&str>().copied())
                .unwrap_or("<non-string panic>");
            panic!("parser panicked on {tag}: {msg}");
        }
    }
}

/// A structurally valid checkpoint to mutate from: non-trivial values in
/// every section so mutations land on interesting bytes.
fn seed_checkpoint() -> Checkpoint {
    Checkpoint {
        crate_version: env!("CARGO_PKG_VERSION").to_string(),
        config_sha256: "c0ffee".repeat(10),
        config_json: "{\"seed\":7}".to_string(),
        round: 12,
        sim_time_s: 512.25,
        prefix_version: 3,
        transitions: vec![
            Transition { version: 1, round: 4, sim_time_s: 96.5 },
            Transition { version: 2, round: 8, sim_time_s: 256.0 },
        ],
        fleet_rng: 0x1234_5678_9abc_def0,
        threads: 4,
        inflight: vec![InFlightUpload { client: 3, arrive_s: 530.0, dispatch_round: 11 }],
        pending: Vec::new(),
        params: vec![
            ("block1_w".to_string(), vec![2, 3], vec![0.5; 6]),
            ("head_w".to_string(), vec![4], vec![-1.25, 0.0, 3.5, f32::NAN]),
        ],
        pool: PoolCkptState {
            select_rng: 99,
            kind: PoolCkptKind::Eager(vec![
                ClientCkpt { id: 0, mem_rng: 11, cursor: 2, prefix_version: 3 },
                ClientCkpt { id: 1, mem_rng: 22, cursor: 0, prefix_version: u64::MAX },
            ]),
        },
        records: Vec::new(),
        strategy_name: "ProFL".to_string(),
        strategy_blob: vec![1, 0, 0, 0, 0, 0, 0, 0, 2],
        mid: None,
    }
}

/// One byte-level mutation: flip, splice, overwrite, truncate, extend,
/// or zero a run — the classic dumb-fuzzer move set.
fn mutate_bytes(rng: &mut Rng, bytes: &mut Vec<u8>) {
    if bytes.is_empty() {
        bytes.push((rng.next_u64() & 0xff) as u8);
        return;
    }
    match rng.below(6) {
        0 => {
            let i = rng.below(bytes.len());
            bytes[i] ^= 1 << rng.below(8);
        }
        1 => {
            let cut = rng.below(bytes.len());
            bytes.truncate(cut);
        }
        2 => {
            let i = rng.below(bytes.len());
            bytes[i] = (rng.next_u64() & 0xff) as u8;
        }
        3 => {
            // Stomp 8 aligned-ish bytes with an extreme length-like value:
            // the best way to provoke an allocation-amplification bug.
            let i = rng.below(bytes.len());
            let v: u64 = [u64::MAX, u64::MAX / 2, 1 << 32, 0][rng.below(4)];
            for (k, b) in v.to_le_bytes().iter().enumerate() {
                if i + k < bytes.len() {
                    bytes[i + k] = *b;
                }
            }
        }
        4 => {
            let i = rng.below(bytes.len());
            let extra = rng.below(16) + 1;
            for _ in 0..extra {
                bytes.insert(i, (rng.next_u64() & 0xff) as u8);
            }
        }
        _ => {
            let i = rng.below(bytes.len());
            let j = (i + 1 + rng.below(8)).min(bytes.len());
            for b in &mut bytes[i..j] {
                *b = 0;
            }
        }
    }
}

// ---------------------------------------------------------------------------
// Checkpoint deserializer
// ---------------------------------------------------------------------------

#[test]
fn fuzz_checkpoint_decode_raw_mutations_never_panic() {
    let seed = seed_checkpoint().encode();
    let mut rng = Rng::new(0xfa22_0001);
    let mut errs = 0usize;
    for case in 0..MUTATIONS {
        let mut bytes = seed.clone();
        for _ in 0..(1 + rng.below(4)) {
            mutate_bytes(&mut rng, &mut bytes);
        }
        let out = must_not_panic(&format!("ckpt raw mutation #{case}"), || {
            Checkpoint::decode(&bytes).map(drop)
        });
        if out.is_err() {
            errs += 1;
        }
    }
    // Survivors are limited to no-op mutations (zeroing already-zero
    // bytes) and flips inside the non-digested config_sha256 header
    // string; anything touching the payload must hit the digest wall.
    assert!(errs >= MUTATIONS / 2, "only {errs}/{MUTATIONS} mutants were rejected");
}

/// Split an encoded checkpoint into (header-without-digest-fields, payload):
/// returns (format_version, crate_version, config_sha256, payload).
fn split_checkpoint(bytes: &[u8]) -> (u32, String, String, Vec<u8>) {
    let mut d = Dec::new(&bytes[8..]);
    let fv = d.u32().unwrap();
    let cv = d.str().unwrap();
    let cs = d.str().unwrap();
    let _digest = d.str().unwrap();
    let len = d.u64().unwrap() as usize;
    let start = bytes.len() - d.remaining();
    (fv, cv, cs, bytes[start..start + len].to_vec())
}

/// Reassemble a checkpoint file around a (possibly hostile) payload,
/// recomputing the digest and length so the header checks all pass and
/// `decode_payload`'s own validators face the mutated bytes.
fn reassemble(fv: u32, cv: &str, cs: &str, payload: &[u8]) -> Vec<u8> {
    let mut e = Enc::new();
    e.u32(fv);
    e.str(cv);
    e.str(cs);
    e.str(&sha256_hex(payload));
    e.u64(payload.len() as u64);
    let mut out = b"PROFLCKP".to_vec();
    out.extend_from_slice(&e.finish());
    out.extend_from_slice(payload);
    out
}

#[test]
fn fuzz_checkpoint_decode_payload_mutations_with_valid_digest_never_panic() {
    let seed = seed_checkpoint().encode();
    let (fv, cv, cs, payload) = split_checkpoint(&seed);
    // Sanity: an untouched reassembly must still decode.
    Checkpoint::decode(&reassemble(fv, &cv, &cs, &payload)).unwrap();
    let mut rng = Rng::new(0xfa22_0002);
    for case in 0..MUTATIONS {
        let mut p = payload.clone();
        for _ in 0..(1 + rng.below(4)) {
            mutate_bytes(&mut rng, &mut p);
        }
        let bytes = reassemble(fv, &cv, &cs, &p);
        // With the digest recomputed the mutant reaches the structural
        // validators; Ok is possible for no-op-ish mutations, a panic
        // or runaway allocation is the only failure.
        let _ = must_not_panic(&format!("ckpt payload mutation #{case}"), || {
            Checkpoint::decode(&bytes).map(drop)
        });
    }
}

#[test]
fn fuzz_checkpoint_every_truncation_errs() {
    let seed = seed_checkpoint().encode();
    for cut in 0..seed.len() {
        let out = must_not_panic(&format!("ckpt truncated to {cut} bytes"), || {
            Checkpoint::decode(&seed[..cut]).map(drop)
        });
        assert!(out.is_err(), "strict {cut}-byte prefix decoded");
    }
}

#[test]
fn fuzz_checkpoint_corpus_regressions() {
    let n = replay_corpus("ckpt_", |name, bytes| {
        let out = must_not_panic(name, || Checkpoint::decode(&bytes).map(drop));
        assert!(out.is_err(), "corpus case {name} must be rejected");
    });
    assert!(n >= 4, "checkpoint corpus lost files ({n} found)");
}

// ---------------------------------------------------------------------------
// Config JSON (the `profl resume` resurrection path)
// ---------------------------------------------------------------------------

#[test]
fn fuzz_config_json_mutations_never_panic() {
    let cfg = RunConfig::smoke("fuzz");
    let seed = profl::telemetry::config_value(&cfg).to_json();
    let mut rng = Rng::new(0xfa22_0003);
    for case in 0..MUTATIONS {
        let mut bytes = seed.clone().into_bytes();
        for _ in 0..(1 + rng.below(4)) {
            mutate_bytes(&mut rng, &mut bytes);
        }
        // Hostile inputs include invalid UTF-8: that must already be a
        // clean error at the string layer, not a parser panic.
        let text = String::from_utf8_lossy(&bytes).into_owned();
        let _ = must_not_panic(&format!("config json mutation #{case}"), || {
            Value::parse(&text).and_then(|v| RunConfig::from_value(&v)).map(drop)
        });
    }
}

#[test]
fn fuzz_json_corpus_regressions() {
    let n = replay_corpus("json_", |name, bytes| {
        let text = String::from_utf8_lossy(&bytes).into_owned();
        let _ = must_not_panic(name, || {
            Value::parse(&text).and_then(|v| RunConfig::from_value(&v)).map(drop)
        });
    });
    assert!(n >= 3, "json corpus lost files ({n} found)");
}

// ---------------------------------------------------------------------------
// CLI argument parser
// ---------------------------------------------------------------------------

#[test]
fn fuzz_cli_token_streams_never_panic() {
    let vocab: &[&str] = &[
        "run", "resume", "sweep", "--", "---", "--=", "--seed", "--seed=", "--seed=9",
        "--threads", "--checkpoint", "--checkpoint-every", "--csv", "=", "-x", "--model=",
        "{round}", "checkpoint.ckpt", "-1", "1e309", "NaN", "", " ", "--flag=--flag",
        "--a=b=c", "über", "💾", "\"", "--round-policy", "async:0", "deadline:-5",
    ];
    let mut rng = Rng::new(0xfa22_0004);
    for case in 0..MUTATIONS {
        let len = rng.below(10);
        let mut argv: Vec<String> = (0..len).map(|_| vocab[rng.below(vocab.len())].into()).collect();
        // Also splice random bytes into one token occasionally.
        if !argv.is_empty() && rng.below(3) == 0 {
            let i = rng.below(argv.len());
            let mut b = argv[i].clone().into_bytes();
            mutate_bytes(&mut rng, &mut b);
            argv[i] = String::from_utf8_lossy(&b).into_owned();
        }
        let _ = must_not_panic(&format!("cli token stream #{case}"), || {
            Args::parse(argv.clone().into_iter()).map(drop)
        });
    }
}

#[test]
fn fuzz_cli_corpus_regressions() {
    // Each cli_ corpus file holds one newline-separated argv.
    let n = replay_corpus("cli_", |name, bytes| {
        let text = String::from_utf8_lossy(&bytes).into_owned();
        let argv: Vec<String> = text.lines().map(String::from).collect();
        let _ = must_not_panic(name, || Args::parse(argv.into_iter()).map(drop));
    });
    assert!(n >= 2, "cli corpus lost files ({n} found)");
}

// ---------------------------------------------------------------------------
// Policy string parsers
// ---------------------------------------------------------------------------

fn rand_policy_string(rng: &mut Rng) -> String {
    let heads = [
        "sync", "deadline", "over-select", "overselect", "async", "none", "off", "abort",
        "resume", "checkpoint", "", "Sync", "dead line", "asy nc",
    ];
    let args = ["", "0", "1", "-1", "4", "1e309", "-0.0", "NaN", "inf", "9999999999999999999",
        "1.5", "abc", ":", "4:4", "∞"];
    let mut s = heads[rng.below(heads.len())].to_string();
    if rng.below(2) == 0 {
        s.push(':');
        s.push_str(args[rng.below(args.len())]);
    }
    // Occasional raw byte damage.
    if rng.below(4) == 0 {
        let mut b = s.into_bytes();
        mutate_bytes(rng, &mut b);
        s = String::from_utf8_lossy(&b).into_owned();
    }
    s
}

#[test]
fn fuzz_policy_parsers_never_panic() {
    let defaults = PolicyDefaults::default();
    let mut rng = Rng::new(0xfa22_0005);
    for case in 0..MUTATIONS {
        let s = rand_policy_string(&mut rng);
        let _ = must_not_panic(&format!("round policy #{case} ({s:?})"), || {
            RoundPolicy::parse(&s, &defaults).map(drop)
        });
        let _ = must_not_panic(&format!("churn policy #{case} ({s:?})"), || {
            ChurnPolicy::parse(&s, 4).map(drop)
        });
    }
    // Known-hostile values must be clean errors, not silent acceptance.
    assert!(RoundPolicy::parse("deadline:NaN", &defaults).is_err());
    assert!(RoundPolicy::parse("deadline:-1", &defaults).is_err());
    assert!(RoundPolicy::parse("async:0", &defaults).is_err());
    assert!(ChurnPolicy::parse("checkpoint:0", 4).is_err());
    assert!(ChurnPolicy::parse("abort:3", 4).is_err());
}
