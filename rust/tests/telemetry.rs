//! Telemetry contracts: the JSONL stream is strictly well-formed, the
//! manifest is reproducible, and — the degeneracy contract that matters —
//! arming telemetry changes *nothing* about a run's arithmetic: the
//! per-round records of a telemetry-on run are bit-identical to the
//! telemetry-off run (the hooks only read simulator state).
//!
//! The on/off bit-identity test needs the compiled artifacts
//! (`make artifacts`) and skips gracefully without them; the appender
//! property test and the manifest tests run everywhere.

use profl::config::RunConfig;
use profl::json::Value;
use profl::methods::{Method, ProFL};
use profl::rng::Rng;
use profl::telemetry::{build_manifest, config_sha256, strip_wall_time, Appender};
use std::path::PathBuf;

fn artifacts() -> Option<PathBuf> {
    let dir = std::env::var_os("PROFL_ARTIFACTS")
        .map(PathBuf::from)
        .unwrap_or_else(|| PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts"));
    dir.join("manifest.json").exists().then_some(dir)
}

macro_rules! require_artifacts {
    () => {
        match artifacts() {
            Some(d) => d,
            None => {
                eprintln!("skipping: run `make artifacts` first");
                return;
            }
        }
    };
}

fn tmp(name: &str) -> PathBuf {
    std::env::temp_dir().join("profl_telemetry_it").join(name)
}

/// Property: whatever mix of events — hostile strings, non-finite
/// numbers, empty attrs — every emitted line parses through the strict
/// parser with the required keys, and seq strictly increases across the
/// whole stream.
#[test]
fn every_line_parses_and_seq_strictly_increases() {
    let path = tmp("property.jsonl");
    let mut rng = Rng::new(0x7e1e);
    {
        let mut a = Appender::create(&path).unwrap();
        for i in 0..500 {
            let name = format!("ev.{}", rng.below(6));
            let value = match rng.below(4) {
                0 => f64::NAN,
                1 => f64::INFINITY,
                2 => -(rng.below(1_000_000) as f64) / 7.0,
                _ => rng.below(1_000) as f64,
            };
            let hostile = format!("q\"{}\" \\ \n\t\u{8} {}", rng.below(100), "\u{1f}");
            let attrs = [("note", Value::Str(hostile)), ("i", Value::Num(i as f64))];
            match rng.below(3) {
                0 => a.span(&name, i, i as f64 * 1.5, value, &attrs),
                1 => a.counter(&name, i, i as f64 * 1.5, value, &[]),
                _ => a.gauge(&name, i, f64::NAN, value, &attrs),
            }
        }
        assert_eq!(a.lines(), 500);
        assert_eq!(a.dropped_writes(), 0);
    }
    let text = std::fs::read_to_string(&path).unwrap();
    let lines: Vec<&str> = text.lines().collect();
    assert_eq!(lines.len(), 500);
    let mut prev = -1i64;
    for line in lines {
        let v = Value::parse(line).unwrap_or_else(|e| panic!("unparseable line {line}: {e}"));
        for key in ["seq", "wall_ms", "sim_s", "round", "kind", "name"] {
            assert!(v.get(key).is_ok(), "missing `{key}` in {line}");
        }
        let seq = v.get("seq").unwrap().as_u64().unwrap() as i64;
        assert!(seq > prev, "seq {seq} after {prev}");
        prev = seq;
        match v.get("kind").unwrap().as_str().unwrap() {
            "span" => assert!(v.get("dur_s").is_ok()),
            "counter" | "gauge" => assert!(v.get("value").is_ok()),
            other => panic!("unknown kind {other}"),
        }
    }
    std::fs::remove_file(&path).ok();
}

#[test]
fn manifests_reproducible_and_hash_tracks_flags() {
    let mut cfg = RunConfig::smoke("m");
    cfg.telemetry_jsonl = Some("stream.jsonl".into());
    let argv = vec!["profl".into(), "run".into(), "--method".into(), "profl".into()];
    let m1 = build_manifest(&cfg, &argv, None, None);
    let m2 = build_manifest(&cfg, &argv, None, None);
    assert_eq!(
        strip_wall_time(&m1).to_json(),
        strip_wall_time(&m2).to_json(),
        "identical runs ⇒ identical manifests modulo wall time"
    );
    // The config hash in the manifest is the canonical one, and any flag
    // change moves it.
    let h = m1.get("config_sha256").unwrap().as_str().unwrap().to_string();
    assert_eq!(h, config_sha256(&cfg));
    let mut flipped = cfg.clone();
    flipped.fleet.round_policy = "async".into();
    let m3 = build_manifest(&flipped, &argv, None, None);
    assert_ne!(h, m3.get("config_sha256").unwrap().as_str().unwrap());
}

/// The tentpole degeneracy contract: a run with telemetry armed produces
/// bit-identical per-round records to the same run with telemetry off —
/// and the stream it writes is a parseable account of every layer
/// (dispatch, simulate, merge, pool cache, freeze detector).
#[test]
fn telemetry_on_is_bit_identical_to_off_and_stream_covers_the_layers() {
    let dir = require_artifacts!();
    let rt = profl::Runtime::new(&dir).unwrap();
    let mut cfg = RunConfig::smoke("resnet18_w8_c10");
    cfg.num_clients = 6;
    cfg.per_round = 3;
    cfg.total_samples = 600;
    cfg.max_rounds_per_step = 3;
    cfg.min_rounds_per_step = 1;
    cfg.max_rounds_total = 6;
    cfg.distill_rounds = 1;
    cfg.eval_every = 3;
    cfg.fleet.lazy_pool = true;

    let off = ProFL::default().run(&rt, &cfg).unwrap();

    let stream = tmp("on_off/telemetry.jsonl");
    let mut cfg_on = cfg.clone();
    cfg_on.telemetry_jsonl = Some(stream.display().to_string());
    let on = ProFL::default().run(&rt, &cfg_on).unwrap();

    assert_eq!(off.history.len(), on.history.len(), "round counts diverged");
    for (a, b) in off.history.iter().zip(on.history.iter()) {
        assert_eq!(a.csv_row(), b.csv_row(), "telemetry perturbed round {}", a.round);
    }
    assert_eq!(off.final_acc.to_bits(), on.final_acc.to_bits());
    assert_eq!(off.sim_time_s.to_bits(), on.sim_time_s.to_bits());

    // The stream exists, parses, and covers every instrumented layer.
    let text = std::fs::read_to_string(&stream).unwrap();
    let mut names = std::collections::BTreeSet::new();
    let mut prev = -1i64;
    for line in text.lines() {
        let v = Value::parse(line).unwrap();
        let seq = v.get("seq").unwrap().as_u64().unwrap() as i64;
        assert!(seq > prev, "seq not strictly increasing");
        prev = seq;
        names.insert(v.get("name").unwrap().as_str().unwrap().to_string());
    }
    for expected in [
        "round.dispatch",
        "round.simulate",
        "aggregate.merge",
        "freeze.observe",
        "freeze.em",
        "round.participants",
        "round.bytes_up",
        "pool.cache_hits",
        "pool.peak_materialized",
        "fleet.queue_peak",
        "coordinator.pending_len",
        "fleet.threads",
        "fleet.worker_utilization",
    ] {
        assert!(names.contains(expected), "stream never emitted `{expected}`; saw {names:?}");
    }
    std::fs::remove_file(&stream).ok();
}
