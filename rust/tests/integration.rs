//! Integration tests over the real artifacts: runtime → coordinator →
//! methods. Require `make artifacts` (skipped gracefully otherwise).
//!
//! These are the cross-layer contracts: HLO loads + executes, lr=0 is an
//! identity, frozen params never change, training actually learns, runs
//! are deterministic per seed.

use profl::config::RunConfig;
use profl::coordinator::{RoundOutcome, ServerCtx};
use profl::methods::{by_name, table_methods, Method, ProFL};
use profl::runtime::{literal_f32, literal_i32, Runtime};
use std::path::PathBuf;

fn artifacts() -> Option<PathBuf> {
    let dir = std::env::var_os("PROFL_ARTIFACTS")
        .map(PathBuf::from)
        .unwrap_or_else(|| PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts"));
    dir.join("manifest.json").exists().then_some(dir)
}

macro_rules! require_artifacts {
    () => {
        match artifacts() {
            Some(d) => d,
            None => {
                eprintln!("skipping: run `make artifacts` first");
                return;
            }
        }
    };
}

const TAG: &str = "resnet18_w8_c10";

/// Tiny-but-real config used by the training integration tests.
fn tiny() -> RunConfig {
    let mut c = RunConfig::smoke(TAG);
    c.num_clients = 6;
    c.per_round = 3;
    c.total_samples = 600;
    c.max_rounds_per_step = 3;
    c.min_rounds_per_step = 1;
    c.max_rounds_total = 6;
    c.distill_rounds = 1;
    c.eval_every = 3;
    c
}

#[test]
fn manifest_loads_and_inventories_models() {
    let dir = require_artifacts!();
    let rt = Runtime::new(&dir).unwrap();
    let m = rt.model(TAG).unwrap();
    assert_eq!(m.num_blocks, 4);
    assert_eq!(m.block_params.len(), 4);
    assert!(m.artifacts.contains_key("train_t1"));
    assert!(m.artifacts.contains_key("distill_t2"));
    assert!(m.artifacts.contains_key("depthfl_eval"));
    // paper-twin memory must be present and larger than mini memory
    let a = m.artifact("train_t1").unwrap();
    assert!(a.mem_paper.unwrap().bytes_at(128) > a.mem.unwrap().bytes_at(128));
}

#[test]
fn train_step_lr_zero_is_identity_through_pjrt() {
    let dir = require_artifacts!();
    let rt = Runtime::new(&dir).unwrap();
    let model = rt.model(TAG).unwrap().clone();
    let art = rt.load(TAG, "train_t2").unwrap();
    let store = profl::store::ParamStore::init(&model.params, 7);
    let params = rt.param_literals(&art.meta, &store).unwrap();
    let scan = rt.manifest.scan_steps;
    let batch = rt.manifest.train_batch;
    let xs = literal_f32(&[scan, batch, 32, 32, 3], &vec![0.1; scan * batch * 3072]).unwrap();
    let ys = literal_i32(&[scan, batch], &vec![1; scan * batch]).unwrap();
    let lr = xla::Literal::scalar(0.0f32);
    let mut inputs: Vec<&xla::Literal> = params.iter().collect();
    inputs.push(&xs);
    inputs.push(&ys);
    inputs.push(&lr);
    let outs = art.execute(&inputs).unwrap();
    let (updated, scalars) = Runtime::unpack_train_outputs(&art.meta, outs).unwrap();
    assert!(scalars[0].is_finite(), "loss {}", scalars[0]);
    for (name, data) in updated {
        let orig = &store.get(&name).unwrap().data;
        assert_eq!(&data, orig, "lr=0 changed `{name}`");
    }
}

#[test]
fn train_round_updates_trainable_and_preserves_frozen() {
    let dir = require_artifacts!();
    let rt = Runtime::new(&dir).unwrap();
    let mut ctx = ServerCtx::new(&rt, tiny()).unwrap();
    let before_b1 = ctx.store.flatten(&["b1/stem/conv/w".to_string()]);
    let before_b2 = ctx.store.flatten(&rt.model(TAG).unwrap().block_params[1].clone());
    let out = ctx.run_train_round("train_t2", None, 0.1, "test", 2).unwrap();
    assert!(out.participants > 0);
    assert!(out.mean_loss.is_finite());
    let after_b1 = ctx.store.flatten(&["b1/stem/conv/w".to_string()]);
    let after_b2 = ctx.store.flatten(&rt.model(TAG).unwrap().block_params[1].clone());
    assert_eq!(before_b1, after_b1, "frozen block 1 changed");
    assert_ne!(before_b2, after_b2, "trainable block 2 did not change");
}

#[test]
fn evaluation_counts_are_sane() {
    let dir = require_artifacts!();
    let rt = Runtime::new(&dir).unwrap();
    let mut ctx = ServerCtx::new(&rt, tiny()).unwrap();
    let ev = ctx.evaluate("eval_t4").unwrap();
    assert!(ev.loss.is_finite() && ev.loss > 0.0);
    assert!((0.0..=1.0).contains(&ev.acc));
    // Untrained model ≈ chance on 10 classes.
    assert!(ev.acc < 0.35, "untrained acc suspiciously high: {}", ev.acc);
}

#[test]
fn distill_round_moves_surrogate_only() {
    let dir = require_artifacts!();
    let rt = Runtime::new(&dir).unwrap();
    let mut ctx = ServerCtx::new(&rt, tiny()).unwrap();
    let s_names: Vec<String> =
        ctx.store.names().filter(|n| n.starts_with("s2/")).cloned().collect();
    let b2_names = rt.model(TAG).unwrap().block_params[1].clone();
    let s_before = ctx.store.flatten(&s_names);
    let b_before = ctx.store.flatten(&b2_names);
    let out = ctx.run_distill_round("distill_t2", 0.1).unwrap();
    assert!(out.mean_loss.is_finite());
    assert_ne!(s_before, ctx.store.flatten(&s_names), "surrogate did not move");
    assert_eq!(b_before, ctx.store.flatten(&b2_names), "frozen block moved");
}

#[test]
fn profl_smoke_learns_above_chance_and_is_deterministic() {
    let dir = require_artifacts!();
    let rt = Runtime::new(&dir).unwrap();
    let cfg = tiny();
    let s1 = ProFL::default().run(&rt, &cfg).unwrap();
    assert!(s1.final_acc > 0.2, "no learning: {}", s1.final_acc);
    assert!(s1.participation_rate > 0.9);
    assert!(s1.rounds > 0);
    let s2 = ProFL::default().run(&rt, &cfg).unwrap();
    assert_eq!(s1.final_acc, s2.final_acc, "non-deterministic run");
    assert_eq!(s1.rounds, s2.rounds);
}

#[test]
fn baselines_run_one_tiny_round_each() {
    let dir = require_artifacts!();
    let rt = Runtime::new(&dir).unwrap();
    let mut cfg = tiny();
    cfg.max_rounds_total = 2;
    cfg.eval_every = 2;
    for name in ["allsmall", "heterofl", "depthfl", "exclusivefl"] {
        let m = by_name(name).unwrap();
        let s = m.run(&rt, &cfg).unwrap_or_else(|e| panic!("{name}: {e}"));
        // ExclusiveFL may be NA (acc NaN) if no client fits — that is valid.
        if !s.final_acc.is_nan() {
            assert!((0.0..=1.0).contains(&s.final_acc), "{name}: {}", s.final_acc);
        }
        assert!((0.0..=1.0).contains(&s.participation_rate), "{name}");
    }
}

#[test]
fn heterofl_memory_collapse_on_big_model() {
    // On ResNet34 paper-twin footprints, no 100-900MB client fits r=1.0 —
    // HeteroFL's largest-ratio channels can never train (Table 1's 9.8%).
    let dir = require_artifacts!();
    let rt = Runtime::new(&dir).unwrap();
    if rt.model("resnet34_w8_c10").is_err() {
        eprintln!("skipping: resnet34 artifacts not built");
        return;
    }
    let full = rt.model("resnet34_w8_c10").unwrap().artifact("train_full").unwrap().participation_mem();
    let cfg = RunConfig { model_tag: "resnet34_w8_c10".into(), ..Default::default() };
    let ctx = ServerCtx::new(&rt, cfg).unwrap();
    assert_eq!(ctx.pool.participation_rate(&full), 0.0, "resnet34 full model should fit nobody");
}

#[test]
fn fleet_sync_round_advances_virtual_time_deterministically() {
    let dir = require_artifacts!();
    let rt = Runtime::new(&dir).unwrap();
    let mut a = ServerCtx::new(&rt, tiny()).unwrap();
    let mut b = ServerCtx::new(&rt, tiny()).unwrap();
    let oa = a.run_train_round("train_t2", None, 0.05, "t", 2).unwrap();
    let ob = b.run_train_round("train_t2", None, 0.05, "t", 2).unwrap();
    assert!(oa.sim_time_s > 0.0, "sync round must cost virtual time");
    assert_eq!(oa.sim_time_s.to_bits(), ob.sim_time_s.to_bits(), "non-deterministic sim time");
    assert_eq!(a.sim_time_s.to_bits(), b.sim_time_s.to_bits());
    // Default fleet (uniform/sync/no dropout): nobody is lost.
    assert_eq!((oa.stragglers, oa.dropouts), (0, 0));
}

#[test]
fn fleet_deadline_policy_cuts_mobile_stragglers() {
    let dir = require_artifacts!();
    let rt = Runtime::new(&dir).unwrap();
    let mut cfg = tiny();
    // Whole fleet sampled; ~15% of mobile devices are offline at t=0 and
    // only return after the availability period, so a short deadline is
    // guaranteed to cut somebody.
    cfg.num_clients = 30;
    cfg.per_round = 30;
    cfg.fleet.profile = "mobile".into();
    cfg.fleet.round_policy = "deadline".into();
    cfg.fleet.deadline_s = 2.0;
    cfg.fleet.dropout_p = Some(0.0); // isolate straggling from dropout
    let mut ctx = ServerCtx::new(&rt, cfg.clone()).unwrap();
    let out = ctx.run_train_round("train_t1", None, 0.05, "t", 1).unwrap();
    assert!(out.stragglers > 0, "2s deadline on a mobile fleet should cut somebody");
    assert!(out.sim_time_s <= 2.0 + 1e-9, "round cannot outlive its deadline");

    // The same fleet under sync keeps everyone and takes at least as long.
    cfg.fleet.round_policy = "sync".into();
    let mut sync_ctx = ServerCtx::new(&rt, cfg).unwrap();
    let sync_out = sync_ctx.run_train_round("train_t1", None, 0.05, "t", 1).unwrap();
    assert_eq!(sync_out.stragglers, 0);
    assert!(sync_out.participants >= out.participants);
    assert!(sync_out.sim_time_s >= out.sim_time_s);
}

#[test]
fn async_with_full_buffer_degenerates_to_sync_bit_for_bit() {
    // ISSUE 2 acceptance: `--round-policy async` with buffer_k = per_round
    // and staleness_alpha = 0 closes every round at its last upload and
    // discounts nothing — the whole run's round records must reproduce the
    // sync policy's bit for bit, on the hardest fleet (mobile: stragglers,
    // dropout, availability gaps).
    let dir = require_artifacts!();
    let rt = Runtime::new(&dir).unwrap();
    let mut sync_cfg = tiny();
    sync_cfg.fleet.profile = "mobile".into();
    let mut async_cfg = sync_cfg.clone();
    async_cfg.fleet.round_policy = "async".into(); // buffer_k defaults to per_round
    async_cfg.fleet.staleness_alpha = 0.0;

    let s = ProFL::default().run(&rt, &sync_cfg).unwrap();
    let a = ProFL::default().run(&rt, &async_cfg).unwrap();
    assert_eq!(s.rounds, a.rounds, "round schedules diverged");
    assert_eq!(s.final_acc.to_bits(), a.final_acc.to_bits());
    assert_eq!(s.sim_time_s.to_bits(), a.sim_time_s.to_bits());
    assert_eq!(s.history.len(), a.history.len());
    for (x, y) in s.history.iter().zip(&a.history) {
        let at = format!("round {} ({} step {})", x.round, x.stage, x.step);
        assert_eq!((x.round, &x.stage, x.step), (y.round, &y.stage, y.step), "{at}");
        assert_eq!(x.train_loss.to_bits(), y.train_loss.to_bits(), "{at}: train_loss");
        assert_eq!(x.train_acc.to_bits(), y.train_acc.to_bits(), "{at}: train_acc");
        assert_eq!(x.test_acc.to_bits(), y.test_acc.to_bits(), "{at}: test_acc");
        assert_eq!(
            x.effective_movement.to_bits(),
            y.effective_movement.to_bits(),
            "{at}: effective_movement"
        );
        assert_eq!(x.participants, y.participants, "{at}: participants");
        assert_eq!(x.fallback_participants, y.fallback_participants, "{at}");
        assert_eq!((x.bytes_up, x.bytes_down), (y.bytes_up, y.bytes_down), "{at}: comm");
        assert_eq!(x.client_mem_bytes, y.client_mem_bytes, "{at}");
        assert_eq!(x.sim_time_s.to_bits(), y.sim_time_s.to_bits(), "{at}: sim_time");
        assert_eq!((x.stragglers, x.dropouts), (y.stragglers, y.dropouts), "{at}");
        assert_eq!((x.late_merged, y.late_merged), (0, 0), "{at}: degenerate async defers nobody");
        assert_eq!(y.mean_staleness.to_bits(), 0f64.to_bits(), "{at}");
        assert_eq!((y.projected_merged, y.projected_dropped_params), (0, 0), "{at}: projection");
    }
}

/// The shared fleet-stress config for the projection tests: mobile fleet,
/// semi-synchronous async windows, generous staleness cap, no dropout.
fn projection_cfg() -> RunConfig {
    let mut cfg = tiny();
    cfg.num_clients = 30;
    cfg.per_round = 8;
    cfg.fleet.profile = "mobile".into();
    cfg.fleet.dropout_p = Some(0.0);
    cfg.fleet.round_policy = "async".into();
    cfg.fleet.buffer_k = Some(3);
    cfg.fleet.max_staleness = 16;
    cfg
}

#[test]
fn stale_projection_on_without_transitions_is_bit_identical_to_off() {
    // ISSUE 4 acceptance: with no freeze transition in flight, a
    // projection-on run reproduces the projection-off run bit for bit —
    // deferrals and late merges DO happen here (asserted below), they
    // are just all version-exact, and the projection machinery must cost
    // nothing until an update actually crosses a transition.
    let dir = require_artifacts!();
    let rt = Runtime::new(&dir).unwrap();
    let run = |stale: &str| -> Vec<RoundOutcome> {
        let mut cfg = projection_cfg();
        cfg.fleet.stale_projection = stale.into();
        let mut ctx = ServerCtx::new(&rt, cfg).unwrap();
        (0..9).map(|_| ctx.run_train_round("train_op_t1", None, 0.05, "t", 1).unwrap()).collect()
    };
    let off = run("off");
    let on = run("on");
    let late: usize = off.iter().map(|o| o.late_merged).sum();
    assert!(late > 0, "vacuous test: nothing merged late");
    for (i, (x, y)) in off.iter().zip(&on).enumerate() {
        assert_eq!(x.participants, y.participants, "round {i}: participants");
        assert_eq!(x.mean_loss.to_bits(), y.mean_loss.to_bits(), "round {i}: loss");
        assert_eq!((x.bytes_up, x.bytes_down), (y.bytes_up, y.bytes_down), "round {i}: comm");
        assert_eq!(x.sim_time_s.to_bits(), y.sim_time_s.to_bits(), "round {i}: sim time");
        assert_eq!(x.deferred, y.deferred, "round {i}: deferred");
        assert_eq!((x.late_merged, x.late_dropped), (y.late_merged, y.late_dropped), "round {i}");
        assert_eq!(x.mean_staleness.to_bits(), y.mean_staleness.to_bits(), "round {i}");
        assert_eq!((y.projected_merged, y.projected_dropped_params), (0, 0), "round {i}");
        assert_eq!(y.transition_staleness.to_bits(), 0f64.to_bits(), "round {i}");
    }
}

#[test]
fn stale_projection_recovers_updates_dropped_at_freeze_transitions() {
    // ISSUE 4 acceptance: where the drop behaviour discards
    // transition-crossed uploads (late_dropped), projection merges their
    // still-trainable suffix instead (projected_merged). Fleet timing is
    // value-independent, so both runs see the identical arrival stream
    // and the bookkeeping identity holds exactly: every recovered update
    // comes out of the drop bucket, at identical byte totals — the
    // recovered accuracy is free per byte.
    let dir = require_artifacts!();
    let rt = Runtime::new(&dir).unwrap();
    let run = |stale: &str| -> Vec<RoundOutcome> {
        let mut cfg = projection_cfg();
        cfg.fleet.stale_projection = stale.into();
        let mut ctx = ServerCtx::new(&rt, cfg).unwrap();
        ctx.bump_prefix_version();
        let r0 = ctx.run_train_round("train_t1", None, 0.05, "t", 1).unwrap();
        assert!(r0.deferred > 0, "no uploads in flight at the transition");
        // The freeze transition: block 1 converges and the server moves
        // to step 2 while uploads trained against train_t1 are in flight.
        ctx.bump_prefix_version();
        let mut outs = vec![r0];
        for _ in 0..8 {
            outs.push(ctx.run_train_round("train_t2", None, 0.05, "t", 2).unwrap());
        }
        outs
    };
    let off = run("off");
    let on = run("on");
    let drops = |v: &[RoundOutcome]| -> usize { v.iter().map(|o| o.late_dropped).sum() };
    let projs = |v: &[RoundOutcome]| -> usize { v.iter().map(|o| o.projected_merged).sum() };
    assert!(drops(&off) > 0, "the transition must drop something under the old behaviour");
    assert_eq!(projs(&off), 0, "projection off must never project");
    assert!(projs(&on) > 0, "projection must recover transition-crossed work");
    assert_eq!(
        drops(&off),
        drops(&on) + projs(&on),
        "every recovered update comes out of the drop bucket"
    );
    let dropped_params: u64 = on.iter().map(|o| o.projected_dropped_params).sum();
    assert!(dropped_params > 0, "frozen-block deltas are discarded and counted");
    assert!(
        on.iter().any(|o| o.transition_staleness > 0.0),
        "projected merges crossed at least one transition"
    );
    let bytes = |v: &[RoundOutcome]| -> (u64, u64) {
        v.iter().fold((0, 0), |a, o| (a.0 + o.bytes_up, a.1 + o.bytes_down))
    };
    assert_eq!(bytes(&off), bytes(&on), "projection changes what merges, not what ships");
}

#[test]
fn transition_history_matches_round_records_across_methods() {
    // The TransitionLog satellite: every method's RunSummary carries the
    // freeze/step transition history, versions are contiguous from 1,
    // rounds/times are monotone and inside the run, baselines bump
    // exactly once up front, and ProFL's history lines up with the
    // shrink/grow segments of its emitted round records.
    let dir = require_artifacts!();
    let rt = Runtime::new(&dir).unwrap();
    let mut base_cfg = tiny();
    base_cfg.max_rounds_total = 2;
    base_cfg.eval_every = 2;
    let profl_cfg = tiny();
    for m in table_methods() {
        let cfg = if m.name() == "ProFL" { profl_cfg.clone() } else { base_cfg.clone() };
        let s = m.run(&rt, &cfg).unwrap_or_else(|e| panic!("{}: {e}", m.name()));
        for (i, t) in s.transitions.iter().enumerate() {
            assert_eq!(t.version, i as u64 + 1, "{}: versions not contiguous", m.name());
            assert!(t.round <= s.rounds, "{}: transition outside the run", m.name());
            assert!(t.sim_time_s <= s.sim_time_s + 1e-9, "{}: time outside the run", m.name());
        }
        for w in s.transitions.windows(2) {
            assert!(w[0].round <= w[1].round, "{}: rounds not monotone", m.name());
            assert!(w[0].sim_time_s <= w[1].sim_time_s, "{}: times not monotone", m.name());
        }
        if s.rounds == 0 {
            // ExclusiveFL's NA case trains nothing and bumps nothing.
            assert!(s.transitions.is_empty(), "{}", m.name());
            continue;
        }
        if m.name() == "ProFL" {
            // One transition per shrink/grow step: reconstruct the
            // expected count (and each step's first round) from the
            // emitted records and check the log matches.
            let mut firsts = Vec::new();
            let mut prev: Option<(String, usize)> = None;
            for r in &s.history {
                let key = (r.stage.clone(), r.step);
                if (r.stage == "shrink" || r.stage == "grow") && prev.as_ref() != Some(&key) {
                    firsts.push(r.round);
                }
                prev = Some(key);
            }
            assert_eq!(s.transitions.len(), firsts.len(), "ProFL: history/record mismatch");
            for (t, first_round) in s.transitions.iter().zip(firsts) {
                // Records stamp the post-increment round index, so the
                // bump entering a step sits one round before its first
                // record.
                assert_eq!(t.round + 1, first_round, "ProFL: transition round misaligned");
            }
        } else {
            assert_eq!(s.transitions.len(), 1, "{}: baselines bump once up front", m.name());
            assert_eq!(s.transitions[0].round, 0, "{}: bump precedes round 0", m.name());
        }
    }
}

#[test]
fn async_merges_stragglers_where_deadline_cuts_them() {
    // ISSUE 2 acceptance: on the mobile fleet where `deadline` reports
    // stragglers cut, `async` must merge at least one straggler update
    // (non-zero late_merged) instead of discarding the work.
    let dir = require_artifacts!();
    let rt = Runtime::new(&dir).unwrap();
    let mut cfg = tiny();
    cfg.num_clients = 30;
    cfg.fleet.profile = "mobile".into();
    cfg.fleet.dropout_p = Some(0.0); // isolate straggling from dropout

    // Deadline: slow clients are cut and their work is thrown away.
    let mut dl_cfg = cfg.clone();
    dl_cfg.per_round = 30;
    dl_cfg.fleet.round_policy = "deadline".into();
    dl_cfg.fleet.deadline_s = 2.0;
    let mut dl = ServerCtx::new(&rt, dl_cfg).unwrap();
    let dl_out = dl.run_train_round("train_t1", None, 0.05, "t", 1).unwrap();
    assert!(dl_out.stragglers > 0, "deadline on a mobile fleet must cut stragglers");

    // Async with a small buffer on the same fleet: the window-missers are
    // deferred (not discarded) and their updates merge in later rounds.
    // The op artifact fits every device, so all 8 sampled clients train
    // and the k=3 window must defer the slow tail.
    let mut a_cfg = cfg.clone();
    a_cfg.per_round = 8; // keep most deferred clients un-resampled
    a_cfg.fleet.round_policy = "async".into();
    a_cfg.fleet.buffer_k = Some(3);
    a_cfg.fleet.max_staleness = 16;
    let mut ctx = ServerCtx::new(&rt, a_cfg).unwrap();
    let r0 = ctx.run_train_round("train_op_t1", None, 0.05, "t", 1).unwrap();
    assert!(r0.deferred > 0, "a k=3 window on a slow mobile cohort must defer uploads");
    assert_eq!(r0.stragglers, 0, "async discards nobody reachable");
    let mut late_total = r0.late_merged;
    for _ in 0..8 {
        let out = ctx.run_train_round("train_op_t1", None, 0.05, "t", 1).unwrap();
        late_total += out.late_merged;
    }
    assert!(late_total > 0, "straggler updates must merge on arrival");
}

#[test]
fn churn_abort_with_always_on_traces_degenerates_bit_for_bit() {
    // ISSUE 3 acceptance: `--churn-policy abort` on always-on traces
    // (the uniform fleet) must reproduce the churn-free round records
    // bit for bit, under both the sync policy and the sync-degenerate
    // async policy — the same guarantee style as the async/sync test
    // above. The churn engine's fast path costs nothing when no device
    // can flip offline.
    let dir = require_artifacts!();
    let rt = Runtime::new(&dir).unwrap();
    for round_policy in ["sync", "async"] {
        let mut base_cfg = tiny();
        base_cfg.fleet.round_policy = round_policy.into();
        if round_policy == "async" {
            base_cfg.fleet.staleness_alpha = 0.0; // degenerate async
        }
        let mut churn_cfg = base_cfg.clone();
        churn_cfg.fleet.churn_policy = "abort".into();

        let b = ProFL::default().run(&rt, &base_cfg).unwrap();
        let c = ProFL::default().run(&rt, &churn_cfg).unwrap();
        let at = format!("round_policy={round_policy}");
        assert_eq!(b.rounds, c.rounds, "{at}: round schedules diverged");
        assert_eq!(b.final_acc.to_bits(), c.final_acc.to_bits(), "{at}: final_acc");
        assert_eq!(b.sim_time_s.to_bits(), c.sim_time_s.to_bits(), "{at}: sim_time");
        assert_eq!(b.history.len(), c.history.len(), "{at}");
        for (x, y) in b.history.iter().zip(&c.history) {
            let at = format!("{at}, round {} ({} step {})", x.round, x.stage, x.step);
            assert_eq!(x.train_loss.to_bits(), y.train_loss.to_bits(), "{at}: train_loss");
            assert_eq!(x.test_acc.to_bits(), y.test_acc.to_bits(), "{at}: test_acc");
            assert_eq!(x.participants, y.participants, "{at}: participants");
            assert_eq!((x.bytes_up, x.bytes_down), (y.bytes_up, y.bytes_down), "{at}: comm");
            assert_eq!(x.sim_time_s.to_bits(), y.sim_time_s.to_bits(), "{at}: sim_time");
            assert_eq!((x.stragglers, x.dropouts), (y.stragglers, y.dropouts), "{at}");
            assert_eq!((y.interrupted, y.resumed), (0, 0), "{at}: churn events on always-on");
            assert_eq!(y.partial_merged, 0, "{at}: no partials without churn");
            assert_eq!(y.wasted_compute_s.to_bits(), 0f64.to_bits(), "{at}: wasted");
        }
    }
}

#[test]
fn churn_abort_on_mobile_fleet_wastes_compute() {
    // The churn engine actually bites on a duty-cycled fleet: with a
    // short availability window, sync rounds under `abort` lose work
    // mid-round (interrupts + wasted compute seconds reported), while
    // the same fleet under `resume` loses nothing but takes longer.
    let dir = require_artifacts!();
    let rt = Runtime::new(&dir).unwrap();
    let mut cfg = tiny();
    cfg.num_clients = 30;
    cfg.per_round = 30;
    cfg.fleet.profile = "mobile".into();
    cfg.fleet.dropout_p = Some(0.0); // isolate churn from dropout
    // Tight trace: 60s online out of every 120s — mobile train times
    // (> 44s on the slow tier) guarantee mid-span offline flips.
    cfg.fleet.trace_period_s = Some(120.0);
    cfg.fleet.trace_duty = Some(0.5);

    let mut abort_cfg = cfg.clone();
    abort_cfg.fleet.churn_policy = "abort".into();
    let mut ctx = ServerCtx::new(&rt, abort_cfg).unwrap();
    let out = ctx.run_train_round("train_t1", None, 0.05, "t", 1).unwrap();
    assert!(out.interrupted > 0, "tight duty cycle must interrupt somebody");
    assert!(out.wasted_compute_s > 0.0, "aborted work must be accounted");

    let mut resume_cfg = cfg.clone();
    resume_cfg.fleet.churn_policy = "resume".into();
    let mut rctx = ServerCtx::new(&rt, resume_cfg).unwrap();
    let rout = rctx.run_train_round("train_t1", None, 0.05, "t", 1).unwrap();
    assert_eq!(rout.wasted_compute_s, 0.0, "resume loses no compute");
    assert!(rout.participants >= out.participants, "resume keeps interrupted clients");
    assert!(
        rout.sim_time_s >= out.sim_time_s,
        "stretched finishes cannot beat a round that dropped its slow tail"
    );
}

#[test]
fn thread_count_never_changes_round_records_bit_for_bit() {
    // Parallel-rounds acceptance: the same run at --threads 1, 4, and 8
    // produces bit-identical RoundRecord histories — thread count buys
    // wall time, never arithmetic. Mobile fleet + resume churn so the
    // span planner actually works through pauses and interrupts.
    let dir = require_artifacts!();
    let rt = Runtime::new(&dir).unwrap();
    let run = |threads: usize| {
        let mut cfg = tiny();
        cfg.fleet.profile = "mobile".into();
        cfg.fleet.churn_policy = "resume".into();
        cfg.fleet.threads = threads;
        ProFL::default().run(&rt, &cfg).unwrap()
    };
    let base = run(1);
    for threads in [4usize, 8] {
        let s = run(threads);
        assert_eq!(base.rounds, s.rounds, "threads={threads}: round schedules diverged");
        assert_eq!(base.final_acc.to_bits(), s.final_acc.to_bits(), "threads={threads}: acc");
        assert_eq!(base.sim_time_s.to_bits(), s.sim_time_s.to_bits(), "threads={threads}");
        assert_eq!(base.history.len(), s.history.len(), "threads={threads}");
        for (a, b) in base.history.iter().zip(&s.history) {
            assert_eq!(
                a.csv_row(),
                b.csv_row(),
                "threads={threads}: round {} diverged",
                a.round
            );
        }
    }
}

#[test]
fn comm_accounting_prefix_cached_after_first_download() {
    let dir = require_artifacts!();
    let rt = Runtime::new(&dir).unwrap();
    let mut cfg = tiny();
    cfg.per_round = cfg.num_clients; // everyone sampled every round
    let mut ctx = ServerCtx::new(&rt, cfg).unwrap();
    ctx.bump_prefix_version();
    let r1 = ctx.run_train_round("train_t3", None, 0.05, "t", 3).unwrap();
    let r2 = ctx.run_train_round("train_t3", None, 0.05, "t", 3).unwrap();
    // Round 1 ships the frozen prefix; round 2 should not (cached).
    assert!(r1.bytes_down > r2.bytes_down, "{} vs {}", r1.bytes_down, r2.bytes_down);
    assert_eq!(r1.bytes_up, r2.bytes_up);
}

#[test]
fn checkpoint_resume_reproduces_uninterrupted_run_bit_for_bit() {
    // The checkpoint/resume tentpole acceptance: a run checkpointed at
    // EVERY round boundary, then resumed from each file in turn, must
    // reproduce the uninterrupted run's whole RoundRecord history, CSV
    // rows, and manifest history_sha256 bit for bit — including resumes
    // that deliberately change the planner thread count.
    let dir = require_artifacts!();
    let rt = Runtime::new(&dir).unwrap();
    let cfg = tiny();
    let base = ProFL::default().run(&rt, &cfg).unwrap();
    let base_rows = rows(&base);
    let base_sha = history_sha(&base);

    let tmp = std::env::temp_dir().join(format!("profl_resume_it_{}", std::process::id()));
    std::fs::create_dir_all(&tmp).unwrap();
    let mut ccfg = cfg.clone();
    ccfg.checkpoint = Some(tmp.join("r{round}.ckpt").display().to_string());
    ccfg.checkpoint_every = 1;
    let with_ckpt = ProFL::default().run(&rt, &ccfg).unwrap();
    assert_eq!(base_rows, rows(&with_ckpt), "checkpointing must not perturb the run");

    for k in 1..=base.rounds {
        // Train and distill rounds both advance `ctx.round`, so a file
        // exists at every boundary.
        let path = tmp.join(format!("r{k}.ckpt"));
        assert!(path.exists(), "missing checkpoint at boundary {k}");
        let ck = profl::checkpoint::Checkpoint::read(&path).unwrap();
        assert_eq!(ck.round, k);
        let mut rcfg = ck.resolve_config().unwrap();
        // Resume at a different thread count on odd boundaries: the
        // contract holds at any worker count.
        rcfg.fleet.threads = if k % 2 == 1 { 4 } else { 1 };
        let resumed = profl::strategy::resume_strategy(&rt, &ck, &rcfg).unwrap();
        assert_eq!(
            base_rows,
            rows(&resumed),
            "resume from boundary {k} diverged from the uninterrupted run"
        );
        assert_eq!(base_sha, history_sha(&resumed), "boundary {k}: history_sha256");
        assert_eq!(base.final_acc.to_bits(), resumed.final_acc.to_bits(), "boundary {k}");
        assert_eq!(base.sim_time_s.to_bits(), resumed.sim_time_s.to_bits(), "boundary {k}");
        assert_eq!(
            (base.total_bytes_up, base.total_bytes_down),
            (resumed.total_bytes_up, resumed.total_bytes_down),
            "boundary {k}: comm totals"
        );
    }
    std::fs::remove_dir_all(&tmp).ok();
}

#[test]
fn every_strategy_resumes_bit_for_bit_from_a_mid_run_checkpoint() {
    // Same contract across the whole strategy zoo (including the lazy
    // pool and an async round policy, the states with real cross-round
    // residue), resuming from a mid-run boundary file.
    let dir = require_artifacts!();
    let rt = Runtime::new(&dir).unwrap();
    for (name, lazy, policy) in [
        ("paramaware", false, "sync"),
        ("layerfreeze", true, "sync"),
        ("elastic", false, "async"),
    ] {
        let mut cfg = tiny();
        cfg.fleet.lazy_pool = lazy;
        cfg.fleet.round_policy = policy.into();
        let m = by_name(name).unwrap();
        let base = m.run(&rt, &cfg).unwrap();
        assert!(base.rounds >= 2, "{name}: need a mid-run boundary");

        let tmp = std::env::temp_dir()
            .join(format!("profl_resume_zoo_{}_{name}", std::process::id()));
        std::fs::create_dir_all(&tmp).unwrap();
        let mut ccfg = cfg.clone();
        ccfg.checkpoint = Some(tmp.join("r{round}.ckpt").display().to_string());
        ccfg.checkpoint_every = 1;
        m.run(&rt, &ccfg).unwrap();

        let k = base.rounds / 2;
        let ck = profl::checkpoint::Checkpoint::read(&tmp.join(format!("r{k}.ckpt"))).unwrap();
        let rcfg = ck.resolve_config().unwrap();
        let resumed = profl::strategy::resume_strategy(&rt, &ck, &rcfg).unwrap();
        assert_eq!(rows(&base), rows(&resumed), "{name}: resume from boundary {k} diverged");
        assert_eq!(
            base.final_acc.to_bits(),
            resumed.final_acc.to_bits(),
            "{name}: final accuracy"
        );
        std::fs::remove_dir_all(&tmp).ok();
    }
}

#[test]
fn resume_rejects_a_config_that_hashes_differently() {
    // Mismatch-rejection acceptance: resuming under a config whose
    // hash-relevant knobs changed must fail with a diagnostic naming
    // both fingerprints — never silently continue a different run.
    let dir = require_artifacts!();
    let rt = Runtime::new(&dir).unwrap();
    let cfg = tiny();
    let tmp = std::env::temp_dir().join(format!("profl_resume_rej_{}", std::process::id()));
    std::fs::create_dir_all(&tmp).unwrap();
    let mut ccfg = cfg.clone();
    ccfg.checkpoint = Some(tmp.join("r{round}.ckpt").display().to_string());
    ccfg.checkpoint_every = 1;
    ProFL::default().run(&rt, &ccfg).unwrap();
    let ck = profl::checkpoint::Checkpoint::read(&tmp.join("r1.ckpt")).unwrap();
    let mut bad = ck.resolve_config().unwrap();
    bad.seed ^= 1; // hash-relevant
    let err = profl::strategy::resume_strategy(&rt, &ck, &bad).unwrap_err().to_string();
    assert!(err.contains("config fingerprint mismatch"), "got: {err}");
    assert!(err.contains(&ck.config_sha256), "diagnostic must name the checkpoint hash: {err}");
    // Hash-neutral knobs (threads) are fine.
    let mut ok = ck.resolve_config().unwrap();
    ok.fleet.threads = 8;
    profl::strategy::resume_strategy(&rt, &ck, &ok).unwrap();
    std::fs::remove_dir_all(&tmp).ok();
}

fn rows(s: &profl::RunSummary) -> Vec<String> {
    s.history.iter().map(|r| r.csv_row()).collect()
}

/// The manifest's `history_sha256` recipe (telemetry::build_manifest):
/// sha256 over newline-joined CSV rows.
fn history_sha(s: &profl::RunSummary) -> String {
    let mut text = String::new();
    for r in &s.history {
        text.push_str(&r.csv_row());
        text.push('\n');
    }
    profl::telemetry::sha256_hex(text.as_bytes())
}
