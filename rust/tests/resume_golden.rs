//! Differential resume tests against the committed golden traces.
//!
//! For every committed golden-trace configuration (round policy × churn
//! policy) this suite re-runs the golden fleet, but **interrupts it at
//! every round boundary k**: the pre-cut rounds run normally, the
//! engine-relevant state (fleet rng stream, cross-round in-flight queue,
//! virtual clock, round index) is captured into a real [`Checkpoint`],
//! round-tripped through the full on-disk codec (encode → write → read →
//! decode), and a **fresh** engine is reconstructed from the decoded
//! checkpoint to run the remaining rounds. The merged pre-cut + post-cut
//! event stream must equal the committed golden file **bit for bit** —
//! same event order, same seq numbers, same f64 bit patterns — at 1 and
//! 4 planner threads.
//!
//! There is deliberately no `UPDATE_GOLDEN` escape hatch here: this
//! suite compares against the committed files directly, so a resume
//! divergence can never be "regenerated away". CI runs the whole test
//! tree under `PROFL_THREADS=4` as well.

use profl::checkpoint::Checkpoint;
use profl::clients::{PoolCkptKind, PoolCkptState};
use profl::fleet::{
    AvailabilityTrace, ChurnPolicy, ClientWork, EventKind, FleetEngine, RoundPlan, RoundPolicy,
};
use profl::rng::Rng;
use std::fmt::Write as _;
use std::path::PathBuf;

fn golden_dir() -> PathBuf {
    PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("tests").join("golden")
}

/// The golden fleet (duplicated from `golden_trace.rs` so the two suites
/// stay independently readable): one always-on fast device, two
/// duty-cycled devices, one phase-shifted device, one unreachable.
fn golden_works(start: f64) -> Vec<ClientWork> {
    let always = AvailabilityTrace::always_on();
    let b = AvailabilityTrace { period_s: 32.0, duty: 0.5, phase_s: 0.0 };
    let c = AvailabilityTrace { period_s: 32.0, duty: 0.5, phase_s: 20.0 };
    let dead = AvailabilityTrace { period_s: 32.0, duty: 0.0, phase_s: 0.0 };
    let spec: [(usize, AvailabilityTrace, f64, f64, f64); 5] = [
        (0, always, 1.0, 4.0, 1.0),
        (1, b, 2.0, 10.0, 5.0),
        (2, b, 2.0, 20.0, 2.0),
        (3, c, 1.0, 2.0, 1.0),
        (4, dead, 1.0, 1.0, 1.0),
    ];
    spec.iter()
        .map(|&(id, trace, down_s, train_s, up_s)| ClientWork {
            id,
            ready_s: trace.next_online(start),
            down_s,
            train_s,
            up_s,
            dropout_p: 0.0,
            trace,
        })
        .collect()
}

fn fmt_f(t: f64) -> String {
    format!("0x{:016x} ({:.3})", t.to_bits(), t)
}

fn fmt_ids(ids: &[usize]) -> String {
    let parts: Vec<String> = ids.iter().map(|i| i.to_string()).collect();
    format!("[{}]", parts.join(", "))
}

fn render_round(round: usize, plan: &RoundPlan) -> String {
    let mut s = String::new();
    writeln!(s, "# round {round} start={}", fmt_f(plan.start_s)).unwrap();
    for e in &plan.events {
        let (kind, client) = match e.kind {
            EventKind::Dispatch { client } => ("Dispatch", Some(client)),
            EventKind::TrainDone { client } => ("TrainDone", Some(client)),
            EventKind::UploadDone { client } => ("UploadDone", Some(client)),
            EventKind::LateUpload { client } => ("LateUpload", Some(client)),
            EventKind::Interrupt { client } => ("Interrupt", Some(client)),
            EventKind::Resume { client } => ("Resume", Some(client)),
            EventKind::Deadline => ("Deadline", None),
        };
        let who = client.map(|c| format!("c{c}")).unwrap_or_else(|| "-".into());
        writeln!(s, "ev seq={} t={} {kind} {who}", e.seq, fmt_f(e.time_s)).unwrap();
    }
    writeln!(s, "end={}", fmt_f(plan.end_s)).unwrap();
    writeln!(
        s,
        "completers={} stragglers={} dropouts={} aborted={} deferred={}",
        fmt_ids(&plan.completers),
        fmt_ids(&plan.stragglers),
        fmt_ids(&plan.dropouts),
        fmt_ids(&plan.aborted),
        fmt_ids(&plan.deferred),
    )
    .unwrap();
    let partials: Vec<String> =
        plan.partials.iter().map(|(c, f)| format!("({c},{f:.3})")).collect();
    let late: Vec<String> = plan
        .late_arrivals
        .iter()
        .map(|u| format!("({},{},{})", u.client, u.dispatch_round, fmt_f(u.arrive_s)))
        .collect();
    writeln!(
        s,
        "partials=[{}] late=[{}] interrupts={} resumes={} wasted={}",
        partials.join(","),
        late.join(","),
        plan.interrupts,
        plan.resumes,
        fmt_f(plan.wasted_compute_s),
    )
    .unwrap();
    s
}

const ROUNDS: usize = 2;

/// Capture the engine-relevant slice of run state into a real
/// [`Checkpoint`]. Run-level fields that the fleet layer does not own
/// (params, pool residues, records, strategy blob) are stubbed with
/// valid empty values — the strategy-level integration is covered by the
/// `checkpoint`/`strategy` unit tests and the property suite.
fn fleet_checkpoint(
    round: usize,
    start: f64,
    threads: usize,
    engine: &FleetEngine,
    rng: &Rng,
) -> Checkpoint {
    Checkpoint {
        crate_version: env!("CARGO_PKG_VERSION").to_string(),
        config_sha256: "golden-fleet-slice".to_string(),
        config_json: "{}".to_string(),
        round,
        sim_time_s: start,
        prefix_version: 0,
        transitions: Vec::new(),
        fleet_rng: rng.state(),
        threads,
        inflight: engine.inflight().to_vec(),
        pending: Vec::new(),
        params: Vec::new(),
        pool: PoolCkptState { select_rng: 0, kind: PoolCkptKind::Eager(Vec::new()) },
        records: Vec::new(),
        strategy_name: "ProFL".to_string(),
        strategy_blob: Vec::new(),
        mid: None,
    }
}

/// Run the golden fleet with a kill at round boundary `cut`: rounds
/// `0..cut` on one engine, a real checkpoint file round-trip, rounds
/// `cut..ROUNDS` on an engine rebuilt from the decoded checkpoint.
fn trace_with_cut(
    policy: RoundPolicy,
    keep: usize,
    churn: ChurnPolicy,
    threads: usize,
    cut: usize,
    tag: &str,
) -> String {
    let mut out = String::new();
    let mut engine = FleetEngine::with_threads(threads);
    let mut rng = Rng::new(77);
    let mut start = 0.0;
    let mut round = 0;
    while round < cut {
        let works = golden_works(start);
        let plan = engine.simulate_round(round, start, &works, policy, keep, churn, &mut rng);
        out.push_str(&render_round(round, &plan));
        start = plan.end_s;
        round += 1;
    }
    // Kill: everything below survives only through the checkpoint file.
    let ck = fleet_checkpoint(round, start, threads, &engine, &rng);
    let path = std::env::temp_dir()
        .join(format!("profl_resume_golden_{}_{tag}_{cut}_{threads}.ckpt", std::process::id()));
    ck.write(&path).unwrap();
    let ck = Checkpoint::read(&path).unwrap();
    std::fs::remove_file(&path).ok();
    drop(engine);
    drop(rng);
    // Resume: fresh engine + rng reconstructed from the decoded file.
    let mut engine = FleetEngine::with_threads(threads);
    engine.restore_inflight(ck.inflight.clone());
    let mut rng = Rng::from_state(ck.fleet_rng);
    let mut start = ck.sim_time_s;
    for round in ck.round..ROUNDS {
        let works = golden_works(start);
        let plan = engine.simulate_round(round, start, &works, policy, keep, churn, &mut rng);
        out.push_str(&render_round(round, &plan));
        start = plan.end_s;
    }
    out
}

const CHURNS: [(&str, ChurnPolicy); 4] = [
    ("none", ChurnPolicy::None),
    ("abort", ChurnPolicy::Abort),
    ("resume", ChurnPolicy::Resume),
    ("checkpoint", ChurnPolicy::Checkpoint { epochs: 4 }),
];

const POLICIES: [(&str, RoundPolicy, usize); 4] = [
    ("sync", RoundPolicy::Sync, usize::MAX),
    ("deadline", RoundPolicy::Deadline { secs: 21.0 }, usize::MAX),
    ("overselect", RoundPolicy::OverSelect { extra: 2 }, 3),
    ("async", RoundPolicy::Async { buffer_k: 2, max_staleness: 8 }, usize::MAX),
];

#[test]
fn resume_at_every_boundary_matches_committed_goldens() {
    let mut checked = 0;
    for (pn, policy, keep) in POLICIES {
        for (cn, churn) in CHURNS {
            let name = format!("{pn}_{cn}");
            let path = golden_dir().join(format!("{name}.txt"));
            let want = match std::fs::read_to_string(&path) {
                Ok(s) => s,
                Err(_) => panic!("golden `{name}` missing at {path:?}; run the golden_trace suite"),
            };
            for threads in [1usize, 4] {
                // cut=0 resumes from the initial boundary (degenerate full
                // run through the codec); cut=1.. are genuine mid-run kills.
                for cut in 0..ROUNDS {
                    let got = trace_with_cut(policy, keep, churn, threads, cut, &name);
                    assert_eq!(
                        got, want,
                        "{name}: resume at boundary {cut} with {threads} threads diverged \
                         from the uninterrupted committed golden"
                    );
                    checked += 1;
                }
            }
        }
    }
    // 4 policies × 4 churns × 2 thread counts × 2 boundaries.
    assert_eq!(checked, 64);
}

#[test]
fn async_inflight_queue_survives_the_cut() {
    // The async policy is the one with genuine cross-round state: a
    // straggler's upload is in flight across the boundary. Make sure the
    // checkpoint actually carries a non-empty queue at the cut (otherwise
    // the test above would pass vacuously for the interesting case).
    let (_, policy, keep) = POLICIES[3];
    let mut engine = FleetEngine::with_threads(1);
    let mut rng = Rng::new(77);
    let works = golden_works(0.0);
    let plan = engine.simulate_round(0, 0.0, &works, policy, keep, ChurnPolicy::None, &mut rng);
    let ck = fleet_checkpoint(1, plan.end_s, 1, &engine, &rng);
    assert!(
        !ck.inflight.is_empty(),
        "golden async round 0 should leave uploads in flight across the boundary"
    );
    let decoded = Checkpoint::decode(&ck.encode()).unwrap();
    assert_eq!(decoded.inflight, ck.inflight);
}
