//! Golden traces for the async × stale-projection decision layer.
//!
//! The fleet golden suite (`golden_trace.rs`) pins the *event* algebra;
//! this suite pins the *merge-decision* algebra layered on top of it:
//! a scripted async scenario drives the real [`FleetEngine`] through a
//! progressive-freezing schedule (artifact + prefix-version changes
//! between rounds, exactly like ProFL's grow stage) while a pending
//! buffer mirrors the coordinator's, and every arriving stale update is
//! classified through the *production* decision procedure
//! ([`classify_stale`]) and merged through the *production* accumulator
//! ([`BufferedAggregator`], including the masked projection path). The
//! serialized trace — close times, arrival streams, per-update
//! decisions, effective weights as exact f64 bits, and post-merge store
//! values as exact f32 bits — is compared bit for bit against
//! `tests/golden/async_projection_*.txt`.
//!
//! Everything is dyadic (times, weights, tensor fills, decay 0.5/0.25,
//! `alpha = 0`), so all arithmetic is exact in IEEE binary floating
//! point and the files are platform-independent.
//!
//! Regeneration (after an *intentional* decision-layer change):
//!
//! ```bash
//! UPDATE_GOLDEN=1 cargo test --test golden_projection
//! git diff rust/tests/golden/          # review every change!
//! ```

use profl::aggregate::{staleness_discount, transition_decay, BufferedAggregator};
use profl::coordinator::projection::{classify_stale, MergeContext, StaleDecision, TrainableLayout};
use profl::fleet::{AvailabilityTrace, ChurnPolicy, ClientWork, FleetEngine, RoundPolicy};
use profl::rng::Rng;
use profl::store::{ParamStore, Tensor};
use std::collections::{BTreeMap, HashMap};
use std::fmt::Write as _;
use std::path::PathBuf;
use std::sync::Arc;

/// Dyadic weights + `powf(x, 0) == 1` keep every merge weight exact.
const ALPHA: f64 = 0.0;
const MAX_STALENESS: usize = 8;

fn golden_dir() -> PathBuf {
    PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("tests").join("golden")
}

fn fmt_f(t: f64) -> String {
    format!("0x{:016x} ({:.3})", t.to_bits(), t)
}

fn fmt_f32(v: f32) -> String {
    format!("0x{:08x} ({:.3})", v.to_bits(), v)
}

fn check(name: &str, got: &str) {
    let path = golden_dir().join(format!("{name}.txt"));
    let update = std::env::var_os("UPDATE_GOLDEN").is_some();
    if update || !path.exists() {
        std::fs::create_dir_all(golden_dir()).unwrap();
        std::fs::write(&path, got).unwrap();
        if !update {
            eprintln!("golden `{name}`: bootstrapped {path:?}; commit it");
        }
        return;
    }
    let want = std::fs::read_to_string(&path).unwrap();
    assert_eq!(
        got, want,
        "golden trace `{name}` diverged from {path:?}; if the decision-layer \
         change is intentional, regenerate with UPDATE_GOLDEN=1 and review the diff"
    );
}

/// Always-on client (the scenario's churn axis is off: projection is
/// orthogonal to churn, and the churn goldens already pin that algebra).
fn work(id: usize, down: f64, train: f64, up: f64) -> ClientWork {
    ClientWork {
        id,
        ready_s: 0.0,
        down_s: down,
        train_s: train,
        up_s: up,
        dropout_p: 0.0,
        trace: AvailabilityTrace::always_on(),
    }
}

/// Synthetic progressive-step layouts, shaped like ProFL's grow stage
/// (trainable block + surrogate tail + op linear, T = 3): each
/// transition freezes a block and drops its surrogate from the tail.
fn layout(artifact: &str) -> TrainableLayout {
    match artifact {
        "train_t1" => TrainableLayout::new(&[("b1/w", 8), ("s2/w", 4), ("s3/w", 4), ("op/fc/w", 2)]),
        "train_t2" => TrainableLayout::new(&[("b2/w", 8), ("s3/w", 4), ("op/fc/w", 2)]),
        "train_t3" => TrainableLayout::new(&[("b3/w", 8), ("op/fc/w", 2)]),
        other => panic!("unknown artifact {other}"),
    }
}

fn fill(l: &TrainableLayout, v: f32) -> Vec<Vec<f32>> {
    l.lens.iter().map(|&n| vec![v; n]).collect()
}

/// Fresh store for one round's layout, every tensor at a 0.25 baseline
/// (so untouched-tensor preservation is visible in the trace).
fn store_for(l: &TrainableLayout) -> ParamStore {
    let shapes: BTreeMap<String, Vec<usize>> =
        l.names.iter().zip(&l.lens).map(|(n, &len)| (n.clone(), vec![len])).collect();
    let mut s = ParamStore::init(&shapes, 0);
    for (n, &len) in l.names.iter().zip(&l.lens) {
        s.set(n, Tensor { shape: vec![len], data: vec![0.25; len] });
    }
    s
}

/// The coordinator's version-stamped pending buffer, minus the runtime.
/// Tensors ride behind an `Arc` exactly like [`PendingUpdate`]'s — the
/// zero-copy handle the production pending map hands `classify_stale`.
struct Pending {
    artifact: &'static str,
    prefix_version: u64,
    dispatch_round: usize,
    weight: f64,
    tensors: Arc<Vec<Vec<f32>>>,
}

/// Run the scripted async×projection scenario and serialize every fleet
/// close, arrival, merge decision, and post-merge store state.
///
/// Schedule: round 0 trains `train_t1` (pv 1) and defers two slow
/// uploads; round 1 crosses a freeze transition to `train_t2` (pv 2)
/// and defers another; round 2 stays on `train_t2` and receives a
/// transition-crossed arrival (projectable) plus a version-exact one;
/// round 3 crosses to `train_t3` (pv 3) and receives a two-transition
/// arrival whose only surviving tensor is the op linear.
fn scenario(projection: Option<f64>) -> String {
    let mut out = String::new();
    let mut engine = FleetEngine::new();
    let mut rng = Rng::new(7);
    let mut pending: HashMap<usize, Pending> = HashMap::new();
    let mut start = 0.0;

    // (artifact, prefix version, buffer_k, cohort of (work, weight, fill)).
    type Cohort = Vec<(ClientWork, f64, f32)>;
    let rounds: Vec<(&'static str, u64, usize, Cohort)> = vec![
        (
            "train_t1",
            1,
            1,
            vec![
                (work(0, 1.0, 2.0, 1.0), 128.0, 1.0), // arrives t=4 (closes the round)
                (work(1, 2.0, 18.0, 4.0), 64.0, 2.0), // arrives t=24 (deferred)
                (work(2, 4.0, 36.0, 8.0), 32.0, 3.0), // arrives t=48 (deferred)
            ],
        ),
        (
            "train_t2",
            2,
            1,
            vec![
                (work(3, 1.0, 2.0, 1.0), 128.0, 4.0), // arrives t=8 (closes the round)
                (work(4, 1.0, 32.0, 3.0), 16.0, 5.0), // arrives t=40 (deferred)
            ],
        ),
        ("train_t2", 2, 2, vec![]), // c1 (crossed 1 transition) + c4 (exact) land
        ("train_t3", 3, 1, vec![]), // c2 (crossed 2 transitions) lands
    ];

    for (round, (artifact, pv, k, cohort)) in rounds.into_iter().enumerate() {
        let lay = layout(artifact);
        let works: Vec<ClientWork> = cohort.iter().map(|&(w, _, _)| w).collect();
        let policy = RoundPolicy::Async { buffer_k: k, max_staleness: MAX_STALENESS };
        let plan = engine
            .simulate_round(round, start, &works, policy, usize::MAX, ChurnPolicy::None, &mut rng);
        start = plan.end_s;

        writeln!(out, "# round {round} artifact={artifact} pv={pv} k={k}").unwrap();
        writeln!(out, "close={}", fmt_f(plan.end_s)).unwrap();
        let ids = |v: &[usize]| {
            let parts: Vec<String> = v.iter().map(|c| c.to_string()).collect();
            format!("[{}]", parts.join(", "))
        };
        let lates: Vec<String> = plan
            .late_arrivals
            .iter()
            .map(|u| format!("({},{},{})", u.client, u.dispatch_round, fmt_f(u.arrive_s)))
            .collect();
        writeln!(
            out,
            "completers={} deferred={} late=[{}]",
            ids(&plan.completers),
            ids(&plan.deferred),
            lates.join(",")
        )
        .unwrap();

        let mut store = store_for(&lay);
        let mut agg = BufferedAggregator::new(&lay.names, &store, ALPHA).unwrap();

        // Fresh completers merge at staleness 0 (synthetic local pass:
        // constant-fill tensors stand in for the XLA executable).
        for (w, weight, fillv) in &cohort {
            if plan.completers.contains(&w.id) {
                agg.add(&fill(&lay, *fillv), *weight, 0);
                writeln!(out, "fresh c{} w={}", w.id, fmt_f(*weight)).unwrap();
            }
        }

        // Classify arrivals through the production decision procedure,
        // then merge in coordinator order: exact lates, then projections.
        let mctx = MergeContext {
            artifact,
            prefix_version: pv,
            round,
            max_staleness: MAX_STALENESS,
            projection: if projection.is_some() { Some(&lay) } else { None },
        };
        let decay = projection.unwrap_or(1.0);
        let mut exact = Vec::new();
        let mut projected = Vec::new();
        for la in &plan.late_arrivals {
            let p = pending.remove(&la.client).expect("arrival without a pending update");
            let trained = p.artifact;
            let decision = classify_stale(
                &mctx,
                trained,
                p.prefix_version,
                p.dispatch_round,
                p.tensors,
                || Some(layout(trained)),
            );
            match decision {
                StaleDecision::Exact { tensors, staleness } => {
                    let w = fmt_f(p.weight * staleness_discount(staleness, ALPHA));
                    writeln!(out, "late c{} staleness={staleness} -> exact w={w}", la.client)
                        .unwrap();
                    exact.push((tensors, p.weight, staleness));
                }
                StaleDecision::Projected { kept, dropped_params, staleness, transitions } => {
                    let extra = transition_decay(decay, transitions);
                    let w = p.weight * staleness_discount(staleness, ALPHA) * extra;
                    let kmap: Vec<String> =
                        kept.iter().map(|(i, _)| format!("{}->{}", lay.names[*i], i)).collect();
                    writeln!(
                        out,
                        "late c{} staleness={staleness} transitions={transitions} -> projected \
                         kept=[{}] dropped_params={dropped_params} w={}",
                        la.client,
                        kmap.join(","),
                        fmt_f(w)
                    )
                    .unwrap();
                    projected.push((kept, p.weight, staleness, extra));
                }
                StaleDecision::Dropped => {
                    writeln!(out, "late c{} -> dropped", la.client).unwrap();
                }
            }
        }
        for (tensors, weight, staleness) in exact {
            agg.add_shared(tensors, weight, staleness);
        }
        for (kept, weight, staleness, extra) in projected {
            agg.add_projected(&kept, weight, staleness, extra);
        }

        // Buffer this round's deferred clients, version-stamped exactly
        // like the coordinator's pending map.
        for (w, weight, fillv) in &cohort {
            if plan.deferred.contains(&w.id) {
                let p = Pending {
                    artifact,
                    prefix_version: pv,
                    dispatch_round: round,
                    weight: *weight,
                    tensors: Arc::new(fill(&lay, *fillv)),
                };
                pending.insert(w.id, p);
            }
        }

        if agg.has_weight() {
            agg.finish(&mut store).unwrap();
        } else {
            writeln!(out, "merge none").unwrap();
        }
        let vals: Vec<String> = lay
            .names
            .iter()
            .map(|n| format!("{n}={}", fmt_f32(store.get(n).unwrap().data[0])))
            .collect();
        writeln!(out, "store {}", vals.join(" ")).unwrap();
    }
    out
}

#[test]
fn async_projection_off_golden() {
    // The historical drop behaviour: both transition-crossers discard.
    check("async_projection_off", &scenario(None));
}

#[test]
fn async_projection_on_golden() {
    // Default decay 0.5: suffix merges at half weight per transition.
    check("async_projection_on", &scenario(Some(0.5)));
}

#[test]
fn async_projection_decay_golden() {
    // Steeper decay 0.25: same decisions, quarter weight per transition.
    check("async_projection_decay25", &scenario(Some(0.25)));
}

#[test]
fn projection_changes_merges_not_timing() {
    // The fleet lines (round headers, close instants, arrival streams)
    // are identical across all three modes: projection decides what
    // merges, never when anything happens.
    let fleet_lines = |s: &str| -> Vec<String> {
        s.lines()
            .filter(|l| {
                l.starts_with("# round") || l.starts_with("close=") || l.starts_with("completers=")
            })
            .map(String::from)
            .collect()
    };
    let off = scenario(None);
    let on = scenario(Some(0.5));
    let steep = scenario(Some(0.25));
    assert_eq!(fleet_lines(&off), fleet_lines(&on));
    assert_eq!(fleet_lines(&on), fleet_lines(&steep));
    // And the decay knob changes weights only, not decisions.
    let decisions = |s: &str| -> Vec<String> {
        s.lines()
            .filter(|l| l.starts_with("late c"))
            .map(|l| l.split(" w=").next().unwrap().to_string())
            .collect()
    };
    assert_eq!(decisions(&on), decisions(&steep));
}
