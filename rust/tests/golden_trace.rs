//! Golden-trace regression tests for the fleet simulator.
//!
//! Every (round policy × churn policy) combination runs a small
//! fixed-seed fleet for two rounds and serializes the full event trace
//! (kind, virtual time as exact f64 bits, client, queue seq) plus the
//! round's bucket summary. The output is compared **bit for bit** against
//! the checked-in files under `tests/golden/` — any change to event
//! ordering, span arithmetic, churn classification, or the queue's
//! tie-breaking shows up as a diff here before it can silently shift
//! simulation results.
//!
//! Regeneration workflow (after an *intentional* engine change):
//!
//! ```bash
//! UPDATE_GOLDEN=1 cargo test --test golden_trace   # or: make test-golden-update
//! git diff rust/tests/golden/                      # review every change!
//! ```
//!
//! A missing golden file is created on first run (bootstrap) and the test
//! passes with a note; commit the new file. The scenario uses zero
//! dropout so no rng draw influences the trace — the whole text is a
//! pure function of the engine's event algebra.

use profl::aggregate::{Aggregator, SlicedAggregator};
use profl::fleet::{
    AvailabilityTrace, ChurnPolicy, ClientWork, EventKind, FleetEngine, RoundPlan, RoundPolicy,
};
use profl::rng::Rng;
use profl::store::ParamStore;
use std::collections::BTreeMap;
use std::fmt::Write as _;
use std::path::PathBuf;

fn golden_dir() -> PathBuf {
    PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("tests").join("golden")
}

/// The golden fleet: one always-on fast device, two duty-cycled devices
/// that hit the offline edge during training/upload, one phase-shifted
/// device that starts offline, and one unreachable device. All times are
/// dyadic rationals, so every derived time is exact in f64.
fn golden_works(start: f64) -> Vec<ClientWork> {
    let always = AvailabilityTrace::always_on();
    let b = AvailabilityTrace { period_s: 32.0, duty: 0.5, phase_s: 0.0 };
    let c = AvailabilityTrace { period_s: 32.0, duty: 0.5, phase_s: 20.0 };
    let dead = AvailabilityTrace { period_s: 32.0, duty: 0.0, phase_s: 0.0 };
    let spec: [(usize, AvailabilityTrace, f64, f64, f64); 5] = [
        (0, always, 1.0, 4.0, 1.0),
        (1, b, 2.0, 10.0, 5.0),
        (2, b, 2.0, 20.0, 2.0),
        (3, c, 1.0, 2.0, 1.0),
        (4, dead, 1.0, 1.0, 1.0),
    ];
    spec.iter()
        .map(|&(id, trace, down_s, train_s, up_s)| ClientWork {
            id,
            ready_s: trace.next_online(start),
            down_s,
            train_s,
            up_s,
            dropout_p: 0.0,
            trace,
        })
        .collect()
}

/// Exact f64 serialization: raw bits plus a fixed-precision readable form.
fn fmt_f(t: f64) -> String {
    format!("0x{:016x} ({:.3})", t.to_bits(), t)
}

fn fmt_ids(ids: &[usize]) -> String {
    let parts: Vec<String> = ids.iter().map(|i| i.to_string()).collect();
    format!("[{}]", parts.join(", "))
}

fn render_round(round: usize, plan: &RoundPlan) -> String {
    let mut s = String::new();
    writeln!(s, "# round {round} start={}", fmt_f(plan.start_s)).unwrap();
    for e in &plan.events {
        let (kind, client) = match e.kind {
            EventKind::Dispatch { client } => ("Dispatch", Some(client)),
            EventKind::TrainDone { client } => ("TrainDone", Some(client)),
            EventKind::UploadDone { client } => ("UploadDone", Some(client)),
            EventKind::LateUpload { client } => ("LateUpload", Some(client)),
            EventKind::Interrupt { client } => ("Interrupt", Some(client)),
            EventKind::Resume { client } => ("Resume", Some(client)),
            EventKind::Deadline => ("Deadline", None),
        };
        let who = client.map(|c| format!("c{c}")).unwrap_or_else(|| "-".into());
        writeln!(s, "ev seq={} t={} {kind} {who}", e.seq, fmt_f(e.time_s)).unwrap();
    }
    writeln!(s, "end={}", fmt_f(plan.end_s)).unwrap();
    writeln!(
        s,
        "completers={} stragglers={} dropouts={} aborted={} deferred={}",
        fmt_ids(&plan.completers),
        fmt_ids(&plan.stragglers),
        fmt_ids(&plan.dropouts),
        fmt_ids(&plan.aborted),
        fmt_ids(&plan.deferred),
    )
    .unwrap();
    let partials: Vec<String> =
        plan.partials.iter().map(|(c, f)| format!("({c},{f:.3})")).collect();
    let late: Vec<String> = plan
        .late_arrivals
        .iter()
        .map(|u| format!("({},{},{})", u.client, u.dispatch_round, fmt_f(u.arrive_s)))
        .collect();
    writeln!(
        s,
        "partials=[{}] late=[{}] interrupts={} resumes={} wasted={}",
        partials.join(","),
        late.join(","),
        plan.interrupts,
        plan.resumes,
        fmt_f(plan.wasted_compute_s),
    )
    .unwrap();
    s
}

/// Run the golden fleet for two rounds under one policy combination and
/// serialize both plans. Uses [`FleetEngine::new`], so the trace is also
/// exercised at whatever `PROFL_THREADS` the environment sets (CI runs
/// the whole suite at 4) — the goldens must hold at any thread count.
fn trace_for(policy: RoundPolicy, keep: usize, churn: ChurnPolicy) -> String {
    trace_for_threads(policy, keep, churn, profl::fleet::default_threads())
}

/// Same trace under an explicit span-planner worker count.
fn trace_for_threads(
    policy: RoundPolicy,
    keep: usize,
    churn: ChurnPolicy,
    threads: usize,
) -> String {
    let mut engine = FleetEngine::with_threads(threads);
    let mut rng = Rng::new(77);
    let mut out = String::new();
    let mut start = 0.0;
    for round in 0..2 {
        let works = golden_works(start);
        let plan = engine.simulate_round(round, start, &works, policy, keep, churn, &mut rng);
        out.push_str(&render_round(round, &plan));
        start = plan.end_s;
    }
    out
}

fn check(name: &str, got: &str) {
    let path = golden_dir().join(format!("{name}.txt"));
    let update = std::env::var_os("UPDATE_GOLDEN").is_some();
    if update || !path.exists() {
        std::fs::create_dir_all(golden_dir()).unwrap();
        std::fs::write(&path, got).unwrap();
        if !update {
            eprintln!("golden `{name}`: bootstrapped {path:?}; commit it");
        }
        return;
    }
    let want = std::fs::read_to_string(&path).unwrap();
    assert_eq!(
        got, want,
        "golden trace `{name}` diverged from {path:?}; if the engine change \
         is intentional, regenerate with UPDATE_GOLDEN=1 and review the diff"
    );
}

const CHURNS: [(&str, ChurnPolicy); 4] = [
    ("none", ChurnPolicy::None),
    ("abort", ChurnPolicy::Abort),
    ("resume", ChurnPolicy::Resume),
    ("checkpoint", ChurnPolicy::Checkpoint { epochs: 4 }),
];

#[test]
fn sync_golden_traces() {
    for (cn, churn) in CHURNS {
        check(&format!("sync_{cn}"), &trace_for(RoundPolicy::Sync, usize::MAX, churn));
    }
}

#[test]
fn deadline_golden_traces() {
    for (cn, churn) in CHURNS {
        let policy = RoundPolicy::Deadline { secs: 21.0 };
        check(&format!("deadline_{cn}"), &trace_for(policy, usize::MAX, churn));
    }
}

#[test]
fn overselect_golden_traces() {
    for (cn, churn) in CHURNS {
        // extra=2 over a keep of 3: the engine sees the whole cohort and
        // keeps the first 3 finishers.
        let policy = RoundPolicy::OverSelect { extra: 2 };
        check(&format!("overselect_{cn}"), &trace_for(policy, 3, churn));
    }
}

#[test]
fn async_golden_traces() {
    for (cn, churn) in CHURNS {
        let policy = RoundPolicy::Async { buffer_k: 2, max_staleness: 8 };
        check(&format!("async_{cn}"), &trace_for(policy, usize::MAX, churn));
    }
}

/// The merge-golden model: three tensors whose flat lengths (7, 12, 33)
/// make every sharded window straddle at least one tensor boundary.
const MERGE_NAMES: [&str; 3] = ["a", "b", "c"];
const MERGE_SHAPES: [&[usize]; 3] = [&[7], &[3, 4], &[33]];

fn merge_names_store() -> (Vec<String>, ParamStore) {
    let shapes: BTreeMap<String, Vec<usize>> = MERGE_NAMES
        .iter()
        .zip(MERGE_SHAPES)
        .map(|(n, s)| (n.to_string(), s.to_vec()))
        .collect();
    let names: Vec<String> = MERGE_NAMES.iter().map(|n| n.to_string()).collect();
    (names, ParamStore::init(&shapes, 0))
}

/// One deterministic update set: a single `Rng` stream drawn across the
/// tensors in order (so the values are a pure function of the seed).
fn merge_fill(seed: u64) -> Vec<Vec<f32>> {
    let mut rng = Rng::new(seed);
    MERGE_SHAPES
        .iter()
        .map(|s| (0..s.iter().product()).map(|_| rng.f32() - 0.5).collect())
        .collect()
}

fn render_merged(tag: &str, names: &[String], store: &ParamStore, out: &mut String) {
    for n in names {
        let words: Vec<String> =
            store.get(n).unwrap().data.iter().map(|v| format!("0x{:08x}", v.to_bits())).collect();
        writeln!(out, "{tag} {n}: {}", words.join(" ")).unwrap();
    }
}

/// Merge a fixed cohort through the plain (full + masked adds) and
/// sliced aggregators at `threads` merge workers and serialize the
/// resulting store bits. Every input is a pure function of fixed seeds,
/// so the whole string is a deterministic merge fingerprint.
fn merge_trace(threads: usize) -> String {
    let mut out = String::from("# merge golden v1\n");
    let (names, mut store) = merge_names_store();
    let mut agg = Aggregator::new(&names, &store).unwrap();
    agg.set_merge_threads(threads);
    for c in 0..6u64 {
        agg.add_owned(merge_fill(0xA11CE ^ c), (c + 1) as f64);
    }
    for k in 0..2u64 {
        let vals = merge_fill(0xB0B ^ k);
        let parts: Vec<(usize, Vec<f32>)> = vec![(1, vals[1].clone()), (2, vals[2].clone())];
        agg.add_masked_owned(parts, 0.5 + k as f64);
    }
    agg.finish(&mut store).unwrap();
    render_merged("plain", &names, &store, &mut out);

    let (names, mut store) = merge_names_store();
    let mut agg = SlicedAggregator::new(&names, &store).unwrap();
    agg.set_merge_threads(threads);
    let full: Vec<Vec<usize>> = MERGE_SHAPES.iter().map(|s| s.to_vec()).collect();
    for c in 0..4u64 {
        agg.add_owned(full.clone(), merge_fill(0x51CED ^ c), (c + 1) as f64);
    }
    agg.finish(&mut store).unwrap();
    render_merged("sliced", &names, &store, &mut out);
    out
}

#[test]
fn merge_golden_identical_at_any_merge_thread_count() {
    // Companion to the planner-thread sweep below, for the sharded
    // cohort merge (the PR's tentpole): the merged store bits under
    // plain (full + masked) and sliced aggregation are pinned by a
    // committed golden, and merge threads 2/4/8 must reproduce the
    // serial bits exactly — no UPDATE_GOLDEN escape for the sweep.
    let reference = merge_trace(1);
    check("merge_threads", &reference);
    for threads in [2usize, 4, 8] {
        assert_eq!(
            merge_trace(threads),
            reference,
            "merge trace at {threads} merge threads diverged from serial"
        );
    }
}

#[test]
fn golden_traces_identical_at_any_thread_count() {
    // The determinism tentpole: the checked-in goldens (and therefore
    // every event, seq, bucket, and bit of every virtual time) must be
    // reproduced exactly by the parallel span planner at 1, 4, and 8
    // workers. No UPDATE_GOLDEN escape hatch here — this compares against
    // the committed files directly.
    let policies: [(&str, RoundPolicy, usize); 4] = [
        ("sync", RoundPolicy::Sync, usize::MAX),
        ("deadline", RoundPolicy::Deadline { secs: 21.0 }, usize::MAX),
        ("overselect", RoundPolicy::OverSelect { extra: 2 }, 3),
        ("async", RoundPolicy::Async { buffer_k: 2, max_staleness: 8 }, usize::MAX),
    ];
    for (pn, policy, keep) in policies {
        for (cn, churn) in CHURNS {
            let path = golden_dir().join(format!("{pn}_{cn}.txt"));
            let want = match std::fs::read_to_string(&path) {
                Ok(s) => s,
                Err(_) => {
                    // Bootstrap run: the per-policy tests create the files.
                    eprintln!("golden `{pn}_{cn}` not committed yet; skipping");
                    continue;
                }
            };
            for threads in [1usize, 4, 8] {
                let got = trace_for_threads(policy, keep, churn, threads);
                assert_eq!(
                    got, want,
                    "{pn}_{cn}: trace at {threads} threads diverged from the committed golden"
                );
            }
        }
    }
}
