//! Property-based tests over the pure-Rust L3 substrates.
//!
//! The offline image has no proptest crate, so this file carries a small
//! seeded-random property harness (`cases`): each property runs across a
//! few hundred randomized cases drawn from `profl::rng::Rng`; failures
//! print the case seed for deterministic replay.

use profl::aggregate::{
    staleness_discount, transition_decay, Aggregator, BufferedAggregator, SlicedAggregator,
};
use profl::RunConfig;
use profl::clients::ClientPool;
use profl::coordinator::projection::{project_tensors, TrainableLayout};
use profl::data::{partition, Partition, SyntheticDataset};
use profl::fleet::{
    simulate_round, AvailabilityTrace, ChurnPolicy, ClientWork, EventKind, FleetEngine,
    RoundPolicy,
};
use profl::freezing::{ls_slope, EffectiveMovement};
use profl::json::Value;
use profl::manifest::MemCoeffs;
use profl::memory::{can_train, DeviceMemory, MemoryConfig};
use profl::rng::Rng;
use profl::store::{ParamStore, Tensor};
use profl::strategy::{depth_cap, elastic, layout_mem, BlockLayout};
use std::collections::BTreeMap;

/// Run `f` over `n` seeded cases; panics include the failing seed.
fn cases(n: u64, f: impl Fn(&mut Rng)) {
    for seed in 0..n {
        let mut rng = Rng::new(0xabcd_0000 + seed);
        let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| f(&mut rng)));
        if let Err(e) = result {
            panic!("property failed at case seed {seed}: {e:?}");
        }
    }
}

fn rand_shape(rng: &mut Rng) -> Vec<usize> {
    let rank = 1 + rng.below(3);
    (0..rank).map(|_| 1 + rng.below(6)).collect()
}

fn rand_tensor(rng: &mut Rng, shape: &[usize]) -> Vec<f32> {
    (0..shape.iter().product::<usize>()).map(|_| rng.normal()).collect()
}

fn store_with(name: &str, shape: &[usize], data: Vec<f32>) -> ParamStore {
    let shapes: BTreeMap<String, Vec<usize>> = [(name.to_string(), shape.to_vec())].into();
    let mut s = ParamStore::init(&shapes, 0);
    s.set(name, Tensor { shape: shape.to_vec(), data });
    s
}

// ---------------------------------------------------------------------------
// FedAvg aggregation invariants (Eq. 1)
// ---------------------------------------------------------------------------

#[test]
fn prop_aggregate_within_envelope() {
    // The weighted mean of client updates is bounded by their min/max.
    cases(200, |rng| {
        let shape = rand_shape(rng);
        let n: usize = shape.iter().product();
        let mut store = store_with("w", &shape, vec![0.0; n]);
        let names = vec!["w".to_string()];
        let mut agg = Aggregator::new(&names, &store).unwrap();
        let k = 1 + rng.below(5);
        let mut lo = vec![f32::MAX; n];
        let mut hi = vec![f32::MIN; n];
        for _ in 0..k {
            let t = rand_tensor(rng, &shape);
            for i in 0..n {
                lo[i] = lo[i].min(t[i]);
                hi[i] = hi[i].max(t[i]);
            }
            agg.add(&[t], rng.uniform(0.1, 10.0));
        }
        agg.finish(&mut store).unwrap();
        let out = &store.get("w").unwrap().data;
        for i in 0..n {
            assert!(out[i] >= lo[i] - 1e-4 && out[i] <= hi[i] + 1e-4, "i={i}");
        }
    });
}

#[test]
fn prop_buffered_staleness_merge_stays_in_envelope() {
    // A staleness-discounted weighted mean is still a convex combination:
    // whatever the alpha/staleness mix, the merge stays inside the
    // per-position min/max envelope of the contributing updates, and the
    // total weight equals the sum of discounted weights.
    cases(150, |rng| {
        let shape = rand_shape(rng);
        let n: usize = shape.iter().product();
        let mut store = store_with("w", &shape, vec![0.0; n]);
        let names = vec!["w".to_string()];
        let alpha = rng.uniform(0.0, 2.0);
        let mut agg = BufferedAggregator::new(&names, &store, alpha).unwrap();
        let k = 1 + rng.below(5);
        let mut lo = vec![f32::MAX; n];
        let mut hi = vec![f32::MIN; n];
        let mut expect_w = 0.0f64;
        for _ in 0..k {
            let t = rand_tensor(rng, &shape);
            for i in 0..n {
                lo[i] = lo[i].min(t[i]);
                hi[i] = hi[i].max(t[i]);
            }
            let w = rng.uniform(0.1, 10.0);
            let staleness = rng.below(6);
            expect_w += w * staleness_discount(staleness, alpha);
            agg.add(&[t], w, staleness);
        }
        assert_eq!(agg.merged(), k);
        assert!((agg.total_weight() - expect_w).abs() < 1e-9);
        agg.finish(&mut store).unwrap();
        let out = &store.get("w").unwrap().data;
        for i in 0..n {
            assert!(out[i] >= lo[i] - 1e-4 && out[i] <= hi[i] + 1e-4, "i={i}");
        }
    });
}

#[test]
fn prop_aggregate_equal_weights_is_mean() {
    cases(100, |rng| {
        let shape = rand_shape(rng);
        let n: usize = shape.iter().product();
        let mut store = store_with("w", &shape, vec![0.0; n]);
        let names = vec!["w".to_string()];
        let mut agg = Aggregator::new(&names, &store).unwrap();
        let k = 1 + rng.below(4);
        let mut mean = vec![0.0f64; n];
        for _ in 0..k {
            let t = rand_tensor(rng, &shape);
            for i in 0..n {
                mean[i] += t[i] as f64 / k as f64;
            }
            agg.add(&[t], 1.0);
        }
        agg.finish(&mut store).unwrap();
        let out = &store.get("w").unwrap().data;
        for i in 0..n {
            assert!((out[i] as f64 - mean[i]).abs() < 1e-4);
        }
    });
}

#[test]
fn prop_sliced_full_cover_equals_plain() {
    cases(100, |rng| {
        let shape = rand_shape(rng);
        let mut s1 = store_with("w", &shape, vec![0.0; shape.iter().product()]);
        let mut s2 = s1.clone();
        let names = vec!["w".to_string()];
        let mut plain = Aggregator::new(&names, &s1).unwrap();
        let mut sliced = SlicedAggregator::new(&names, &s2).unwrap();
        for _ in 0..(1 + rng.below(4)) {
            let t = rand_tensor(rng, &shape);
            let w = rng.uniform(0.5, 3.0);
            plain.add(&[t.clone()], w);
            sliced.add(&[shape.clone()], &[t], w);
        }
        plain.finish(&mut s1).unwrap();
        sliced.finish(&mut s2).unwrap();
        for (a, b) in s1.get("w").unwrap().data.iter().zip(&s2.get("w").unwrap().data) {
            assert!((a - b).abs() < 1e-4);
        }
    });
}

#[test]
fn prop_slice_corner_roundtrip() {
    // slicing then scatter-accumulating with weight 1 reproduces the slice
    // region and leaves the rest untouched.
    cases(200, |rng| {
        let shape = rand_shape(rng);
        let full = rand_tensor(rng, &shape);
        let t = Tensor { shape: shape.clone(), data: full.clone() };
        let sub_shape: Vec<usize> = shape.iter().map(|&d| 1 + rng.below(d)).collect();
        let sub = t.slice_corner(&sub_shape).unwrap();
        assert_eq!(sub.data.len(), sub_shape.iter().product::<usize>());
        let mut acc = vec![0.0; full.len()];
        let mut wacc = vec![0.0; full.len()];
        Tensor::accumulate_corner(&shape, &mut acc, &mut wacc, &sub_shape, &sub.data, 1.0);
        for i in 0..full.len() {
            if wacc[i] > 0.0 {
                assert!((acc[i] - full[i]).abs() < 1e-6);
            } else {
                assert_eq!(acc[i], 0.0);
            }
        }
        let covered: f32 = wacc.iter().sum();
        assert_eq!(covered as usize, sub.data.len());
    });
}

// ---------------------------------------------------------------------------
// Fleet-simulator churn invariants
// ---------------------------------------------------------------------------

fn rand_trace(rng: &mut Rng) -> AvailabilityTrace {
    if rng.f64() < 0.3 {
        AvailabilityTrace::always_on()
    } else {
        let period = rng.uniform(20.0, 200.0);
        let duty = rng.uniform(0.2, 1.0);
        let phase = rng.uniform(0.0, period);
        AvailabilityTrace { period_s: period, duty, phase_s: phase }
    }
}

fn rand_works(rng: &mut Rng, with_dropout: bool) -> Vec<ClientWork> {
    let n = 2 + rng.below(8);
    (0..n)
        .map(|id| {
            let trace = rand_trace(rng);
            ClientWork {
                id,
                ready_s: trace.next_online(0.0),
                down_s: rng.uniform(0.1, 10.0),
                train_s: rng.uniform(1.0, 300.0),
                up_s: rng.uniform(0.1, 20.0),
                dropout_p: if with_dropout && rng.f64() < 0.3 {
                    rng.uniform(0.0, 1.0)
                } else {
                    0.0
                },
                trace,
            }
        })
        .collect()
}

fn rand_policy(rng: &mut Rng) -> (RoundPolicy, usize) {
    match rng.below(4) {
        0 => (RoundPolicy::Sync, usize::MAX),
        1 => (RoundPolicy::Deadline { secs: rng.uniform(10.0, 400.0) }, usize::MAX),
        2 => (RoundPolicy::OverSelect { extra: 2 }, 1 + rng.below(4)),
        _ => (RoundPolicy::Async { buffer_k: 1 + rng.below(5), max_staleness: 8 }, usize::MAX),
    }
}

fn rand_churn(rng: &mut Rng) -> ChurnPolicy {
    match rng.below(4) {
        0 => ChurnPolicy::None,
        1 => ChurnPolicy::Abort,
        2 => ChurnPolicy::Resume,
        _ => ChurnPolicy::Checkpoint { epochs: 1 + rng.below(8) },
    }
}

#[test]
fn prop_churn_clock_monotone_and_finite() {
    // Interrupt/Resume events slot into the queue like any other: the
    // processed-event stream stays time-ordered and finite under every
    // policy × churn combination.
    cases(200, |rng| {
        let works = rand_works(rng, true);
        let (policy, keep) = rand_policy(rng);
        let churn = rand_churn(rng);
        let mut engine = FleetEngine::new();
        let plan = engine.simulate_round(0, 0.0, &works, policy, keep, churn, rng);
        assert!(plan.end_s.is_finite() && plan.end_s >= plan.start_s);
        for pair in plan.events.windows(2) {
            assert!(pair[0].time_s.is_finite());
            assert!(
                pair[0].time_s <= pair[1].time_s,
                "clock went backwards: {} -> {} ({policy:?} × {churn:?})",
                pair[0].time_s,
                pair[1].time_s
            );
        }
    });
}

#[test]
fn prop_wasted_compute_nonnegative_and_zero_without_loss() {
    // wasted_compute_s is a loss meter: never negative, never NaN, and
    // identically zero under churn policies that lose no work.
    cases(200, |rng| {
        let works = rand_works(rng, true);
        let (policy, keep) = rand_policy(rng);
        let churn = rand_churn(rng);
        let mut engine = FleetEngine::new();
        let plan = engine.simulate_round(0, 0.0, &works, policy, keep, churn, rng);
        assert!(plan.wasted_compute_s.is_finite());
        assert!(plan.wasted_compute_s >= 0.0, "{policy:?} × {churn:?}");
        if matches!(churn, ChurnPolicy::None | ChurnPolicy::Resume) {
            assert_eq!(plan.wasted_compute_s, 0.0, "lossless churn wasted compute");
            assert!(plan.aborted.is_empty());
        }
        if !matches!(churn, ChurnPolicy::Checkpoint { .. }) {
            assert!(plan.partials.is_empty(), "only checkpoint produces partials");
        }
    });
}

#[test]
fn prop_partial_update_weight_below_full() {
    // A checkpointed fraction is epoch-truncated strictly below 1 (and
    // above 0), so a partial update's merge weight is always less than
    // the client's full-shard weight.
    cases(200, |rng| {
        let works = rand_works(rng, false);
        let (policy, keep) = rand_policy(rng);
        let epochs = 1 + rng.below(8);
        let churn = ChurnPolicy::Checkpoint { epochs };
        let mut engine = FleetEngine::new();
        let plan = engine.simulate_round(0, 0.0, &works, policy, keep, churn, rng);
        for &(c, f) in &plan.partials {
            assert!(f > 0.0 && f < 1.0, "client {c}: fraction {f} out of (0,1)");
            let scaled = (f * epochs as f64).round();
            assert!((scaled - f * epochs as f64).abs() < 1e-9, "not epoch-granular: {f}");
        }
    });
}

#[test]
fn prop_resume_never_finishes_earlier_than_uninterrupted() {
    // Pausing across offline windows can only delay an upload relative
    // to the churn-free schedule (same works, same sync policy).
    cases(200, |rng| {
        let works = rand_works(rng, false);
        let upload_times = |churn: ChurnPolicy| -> BTreeMap<usize, f64> {
            let plan =
                simulate_round(0.0, &works, RoundPolicy::Sync, usize::MAX, churn, &mut Rng::new(1));
            plan.events
                .iter()
                .filter_map(|e| match e.kind {
                    EventKind::UploadDone { client } => Some((client, e.time_s)),
                    _ => None,
                })
                .collect()
        };
        let base = upload_times(ChurnPolicy::None);
        let resumed = upload_times(ChurnPolicy::Resume);
        assert_eq!(base.len(), resumed.len(), "resume loses nobody under sync");
        for (c, t) in &resumed {
            assert!(
                *t >= base[c] - 1e-9,
                "client {c} finished early: resume {} < uninterrupted {}",
                t,
                base[c]
            );
        }
    });
}

#[test]
fn prop_churn_buckets_conserve_the_cohort() {
    // Conservation across multiple async rounds: every dispatched client
    // is merged, partial-merged, dropped, aborted, straggled, or still
    // in flight — exactly one of them, every round.
    cases(150, |rng| {
        let (policy, keep) = rand_policy(rng);
        let churn = rand_churn(rng);
        let mut engine = FleetEngine::new();
        let mut start = 0.0;
        for round in 0..3 {
            // Fresh ids per round so in-flight uploads are never
            // superseded (the coordinator's sampling guarantees this).
            let mut works = rand_works(rng, true);
            for w in &mut works {
                w.id += round * 100;
            }
            let inflight_before: Vec<usize> =
                engine.inflight().iter().map(|u| u.client).collect();
            let plan = engine.simulate_round(round, start, &works, policy, keep, churn, rng);
            let mut seen = std::collections::BTreeSet::new();
            for bucket in
                [&plan.completers, &plan.stragglers, &plan.dropouts, &plan.aborted, &plan.deferred]
            {
                for &id in bucket.iter() {
                    assert!(seen.insert(id), "client {id} in two buckets ({policy:?}×{churn:?})");
                }
            }
            assert_eq!(seen.len(), works.len(), "client unaccounted ({policy:?}×{churn:?})");
            // In-flight uploads either landed this round or are still
            // queued — none vanish.
            let landed: Vec<usize> = plan.late_arrivals.iter().map(|u| u.client).collect();
            let still: Vec<usize> = engine.inflight().iter().map(|u| u.client).collect();
            for c in inflight_before {
                assert!(
                    landed.contains(&c) || still.contains(&c),
                    "in-flight upload of {c} vanished"
                );
            }
            start = plan.end_s;
        }
    });
}

#[test]
fn prop_download_fractions_bounded_and_charged_once() {
    // Partial-download accounting (ROADMAP churn follow-on): every
    // churn-aborted client records exactly one completed-download
    // fraction in [0, 1] — so charging `fraction × bytes` can never
    // exceed the full download — and lossless policies record none.
    // Under `resume`, paused downloads complete exactly once: each
    // client emits at most one TrainDone and one UploadDone, so the
    // ordinary charge sites fire at most once per download.
    cases(200, |rng| {
        let works = rand_works(rng, true);
        let (policy, keep) = rand_policy(rng);
        let churn = rand_churn(rng);
        let mut engine = FleetEngine::new();
        let plan = engine.simulate_round(0, 0.0, &works, policy, keep, churn, rng);
        assert_eq!(plan.download_frac.len(), plan.aborted.len(), "one fraction per abort");
        for &(c, f) in &plan.download_frac {
            assert!(plan.aborted.contains(&c), "fraction for a non-aborted client");
            assert!((0.0..=1.0).contains(&f), "fraction {f} outside [0, 1]");
            let bytes = 44_000_000u64;
            assert!((f * bytes as f64) as u64 <= bytes, "partial charge exceeds full");
        }
        let unique: std::collections::BTreeSet<usize> =
            plan.download_frac.iter().map(|(c, _)| *c).collect();
        assert_eq!(unique.len(), plan.download_frac.len(), "a download charged twice");
        if matches!(churn, ChurnPolicy::None | ChurnPolicy::Resume) {
            assert!(plan.download_frac.is_empty(), "lossless churn aborts nothing");
        }
        if matches!(churn, ChurnPolicy::Resume) {
            let mut train_done: BTreeMap<usize, usize> = BTreeMap::new();
            let mut upload_done: BTreeMap<usize, usize> = BTreeMap::new();
            for e in &plan.events {
                match e.kind {
                    EventKind::TrainDone { client } => *train_done.entry(client).or_insert(0) += 1,
                    EventKind::UploadDone { client } => {
                        *upload_done.entry(client).or_insert(0) += 1
                    }
                    _ => {}
                }
            }
            for (&c, &n) in train_done.iter().chain(upload_done.iter()) {
                assert!(n <= 1, "client {c} finished a span {n} times under resume");
            }
        }
    });
}

#[test]
fn prop_parallel_plan_equals_sequential_sorted_order() {
    // The deterministic-merge contract: the worker-pool span planner,
    // merged through the event queue's (time, seq) order, reproduces the
    // sequential plan exactly — same events (virtual times to the bit,
    // seqs, kinds), same buckets — under rng-varied schedules, policies,
    // churn, and dropout, across rounds with async in-flight state
    // crossing them.
    cases(120, |rng| {
        let (policy, keep) = rand_policy(rng);
        let churn = rand_churn(rng);
        let threads = 2 + rng.below(7);
        let seed = rng.next_u64();
        let mut seq_engine = FleetEngine::with_threads(1);
        let mut par_engine = FleetEngine::with_threads(threads);
        let mut seq_rng = Rng::new(seed);
        let mut par_rng = Rng::new(seed);
        let mut start = 0.0;
        for round in 0..3 {
            // Fresh ids per round so in-flight uploads are never
            // superseded (the coordinator's sampling guarantees this).
            let mut works = rand_works(rng, true);
            for w in &mut works {
                w.id += round * 100;
            }
            let a = seq_engine
                .simulate_round(round, start, &works, policy, keep, churn, &mut seq_rng);
            let b = par_engine
                .simulate_round(round, start, &works, policy, keep, churn, &mut par_rng);
            assert_eq!(
                a, b,
                "{policy:?}×{churn:?} diverged at {threads} threads, round {round}"
            );
            assert_eq!(a.end_s.to_bits(), b.end_s.to_bits(), "round end drifted");
            // The merged stream really is (time, seq)-sorted.
            for pair in b.events.windows(2) {
                let (t0, s0) = (pair[0].time_s, pair[0].seq);
                let (t1, s1) = (pair[1].time_s, pair[1].seq);
                assert!(
                    t0 < t1 || (t0 == t1 && s0 < s1),
                    "merge order violated (time, seq): ({t0}, {s0}) -> ({t1}, {s1})"
                );
            }
            start = a.end_s;
        }
    });
}

// ---------------------------------------------------------------------------
// Stale-update projection invariants
// ---------------------------------------------------------------------------

#[test]
fn prop_projection_conserves_scalars_and_masks_frozen() {
    // Over random layout pairs drawn from a shared name pool: every
    // scalar of the stale update is either kept (remapped onto a
    // still-trainable tensor of identical length) or counted dropped —
    // nothing is lost or invented — and no kept tensor lands on a name
    // absent from the update or the new layout (frozen blocks never
    // receive mass).
    cases(200, |rng| {
        let n_pool = 8usize;
        let base: Vec<usize> = (0..n_pool).map(|_| 1 + rng.below(5)).collect();
        let mut old = TrainableLayout::default();
        let mut new = TrainableLayout::default();
        for (i, len) in base.iter().enumerate() {
            let name = format!("p{i}");
            if rng.f64() < 0.6 {
                old.names.push(name.clone());
                old.lens.push(*len);
            }
            if rng.f64() < 0.6 {
                // Occasionally reshape a tensor in the new layout: same
                // name, different length — must be dropped, not merged.
                let l = if rng.f64() < 0.1 { *len + 1 } else { *len };
                new.names.push(name);
                new.lens.push(l);
            }
        }
        let tensors: Vec<Vec<f32>> = old.lens.iter().map(|&l| vec![1.0; l]).collect();
        let total: usize = old.lens.iter().sum();
        let (kept, dropped) = project_tensors(&old, &new, tensors);
        let kept_scalars: usize = kept.iter().map(|(_, t)| t.len()).sum();
        assert_eq!(kept_scalars as u64 + dropped, total as u64, "scalars not conserved");
        let mut seen = std::collections::BTreeSet::new();
        for (idx, t) in &kept {
            assert!(seen.insert(*idx), "tensor merged twice at index {idx}");
            assert_eq!(new.lens[*idx], t.len(), "length mismatch survived projection");
            let name = &new.names[*idx];
            assert!(old.names.contains(name), "kept tensor not from the update");
        }
        // Weight side of the contract: the projected merge factor never
        // exceeds the original weight's, and decays monotonically in
        // transitions crossed.
        let alpha = rng.uniform(0.0, 2.0);
        let decay = rng.uniform(0.0, 1.0);
        let staleness = rng.below(6);
        let mut prev = f64::INFINITY;
        for transitions in 0..5u64 {
            let f = staleness_discount(staleness, alpha) * transition_decay(decay, transitions);
            assert!(f <= 1.0 + 1e-12, "projected weight amplified");
            assert!(f <= prev + 1e-12, "decay not monotone in transitions");
            prev = f;
        }
    });
}

// ---------------------------------------------------------------------------
// Effective movement invariants (§3.3)
// ---------------------------------------------------------------------------

#[test]
fn prop_effective_movement_bounded() {
    cases(100, |rng| {
        let n = 1 + rng.below(200);
        let h = 1 + rng.below(5);
        let mut em = EffectiveMovement::new(h);
        let mut v: Vec<f32> = (0..n).map(|_| rng.normal()).collect();
        for _ in 0..(h + 3 + rng.below(5)) {
            for x in v.iter_mut() {
                *x += rng.normal() * 0.1;
            }
            if let Some(e) = em.push(&v) {
                assert!((0.0..=1.0 + 1e-9).contains(&e), "em={e}");
            }
        }
    });
}

#[test]
fn prop_effective_movement_one_for_monotone() {
    // Any per-scalar *consistent-sign* motion gives EM == 1 regardless of
    // magnitudes (the numerator equals the denominator scalar-wise).
    cases(100, |rng| {
        let n = 1 + rng.below(100);
        let h = 1 + rng.below(4);
        let signs: Vec<f32> = (0..n).map(|_| if rng.f64() < 0.5 { -1.0 } else { 1.0 }).collect();
        let mut em = EffectiveMovement::new(h);
        let mut v = vec![0.0f32; n];
        let mut last = None;
        for _ in 0..(h + 2) {
            for (x, s) in v.iter_mut().zip(&signs) {
                *x += s * (0.01 + rng.f32().abs());
            }
            last = em.push(&v).or(last);
        }
        let e = last.unwrap();
        assert!((e - 1.0).abs() < 1e-6, "em={e}");
    });
}

#[test]
fn prop_ls_slope_exact_on_lines() {
    cases(200, |rng| {
        let n = 2 + rng.below(20);
        let a = rng.normal() as f64 * 3.0;
        let b = rng.normal() as f64;
        let ys: Vec<f64> = (0..n).map(|i| a * i as f64 + b).collect();
        assert!((ls_slope(&ys) - a).abs() < 1e-6 * (1.0 + a.abs()));
    });
}

// ---------------------------------------------------------------------------
// Data partition invariants
// ---------------------------------------------------------------------------

#[test]
fn prop_partition_indices_unique_and_labels_valid() {
    cases(30, |rng| {
        let classes = 2 + rng.below(20);
        let data = SyntheticDataset::new(classes, rng.next_u64());
        let clients = 2 + rng.below(30);
        let scheme = if rng.f64() < 0.5 {
            Partition::Iid
        } else {
            Partition::Dirichlet { alpha: rng.uniform(0.05, 10.0) }
        };
        let shards = partition(&data, clients, 50 * clients, scheme, rng.next_u64());
        assert_eq!(shards.len(), clients);
        let mut seen = std::collections::HashSet::new();
        for s in &shards {
            assert!(s.num_samples() >= 8);
            for &l in &s.labels {
                assert!((l as usize) < classes);
            }
            for &i in &s.indices {
                assert!(seen.insert(i), "duplicate index {i}");
            }
        }
    });
}

// ---------------------------------------------------------------------------
// ParamStore init invariants
// ---------------------------------------------------------------------------

#[test]
fn prop_store_init_finite_and_rule_based() {
    cases(50, |rng| {
        let mut shapes = BTreeMap::new();
        for i in 0..(1 + rng.below(6)) {
            let kind = rng.below(3);
            let name = match kind {
                0 => format!("b1/l{i}/w"),
                1 => format!("b1/l{i}/scale"),
                _ => format!("b1/l{i}/shift"),
            };
            shapes.insert(name, rand_shape(rng));
        }
        let store = ParamStore::init(&shapes, rng.next_u64());
        for name in shapes.keys() {
            let t = store.get(name).unwrap();
            for &v in &t.data {
                assert!(v.is_finite());
                if name.ends_with("/scale") {
                    assert_eq!(v, 1.0);
                }
                if name.ends_with("/shift") {
                    assert_eq!(v, 0.0);
                }
            }
        }
    });
}

// ---------------------------------------------------------------------------
// JSON parser invariants
// ---------------------------------------------------------------------------

fn rand_json(rng: &mut Rng, depth: usize) -> Value {
    if depth == 0 {
        return match rng.below(4) {
            0 => Value::Null,
            1 => Value::Bool(rng.f64() < 0.5),
            2 => Value::Num((rng.normal() as f64 * 100.0).round()),
            _ => Value::Str(format!("s{}", rng.below(1000))),
        };
    }
    match rng.below(2) {
        0 => Value::Arr((0..rng.below(4)).map(|_| rand_json(rng, depth - 1)).collect()),
        _ => Value::Obj(
            (0..rng.below(4)).map(|i| (format!("k{i}"), rand_json(rng, depth - 1))).collect(),
        ),
    }
}

#[test]
fn prop_json_roundtrip() {
    cases(300, |rng| {
        let v = rand_json(rng, 3);
        let text = v.to_json();
        let v2 = Value::parse(&text).unwrap();
        assert_eq!(v, v2, "text: {text}");
    });
}

// ---------------------------------------------------------------------------
// RNG invariants
// ---------------------------------------------------------------------------

#[test]
fn prop_dirichlet_valid_simplex() {
    cases(100, |rng| {
        let k = 2 + rng.below(50);
        let alpha = rng.uniform(0.01, 20.0);
        let p = rng.dirichlet(alpha, k);
        assert_eq!(p.len(), k);
        assert!((p.iter().sum::<f64>() - 1.0).abs() < 1e-9);
        assert!(p.iter().all(|&x| x > 0.0));
    });
}

#[test]
fn prop_sample_indices_is_permutation_prefix() {
    cases(100, |rng| {
        let n = 1 + rng.below(100);
        let k = rng.below(n + 1);
        let s = rng.sample_indices(n, k);
        assert_eq!(s.len(), k);
        let mut u: Vec<_> = s.clone();
        u.sort_unstable();
        u.dedup();
        assert_eq!(u.len(), k);
        assert!(s.iter().all(|&i| i < n));
    });
}

// ---------------------------------------------------------------------------
// Lazy client pool ≡ eager build (the O(cohort) round-scheduling contract)
// ---------------------------------------------------------------------------

fn rand_scheme(rng: &mut Rng) -> Partition {
    if rng.below(2) == 0 {
        Partition::Iid
    } else {
        Partition::Dirichlet { alpha: rng.uniform(0.2, 3.0) }
    }
}

fn pool_pair(rng: &mut Rng) -> (ClientPool, ClientPool, usize) {
    let seed = rng.next_u64();
    let n = 10 + rng.below(110);
    let scheme = rand_scheme(rng);
    let profile_name = ["uniform", "mobile", "datacenter"][rng.below(3)];
    let cap = 4 + rng.below(40);
    let data = SyntheticDataset::new(10, seed);
    let fleet = profl::fleet::FleetProfileConfig::named(profile_name).unwrap();
    let eager = ClientPool::build(
        n,
        n * 60,
        &data,
        scheme,
        MemoryConfig::default(),
        &fleet,
        seed,
    );
    let lazy = ClientPool::build_lazy(
        n,
        n * 60,
        &data,
        scheme,
        MemoryConfig::default(),
        &fleet,
        seed,
        cap,
    );
    (eager, lazy, n)
}

#[test]
fn prop_lazy_materialization_bit_identical_to_eager() {
    // Satellite acceptance: same seeds ⇒ same memory budgets, device
    // profiles, shard bounds (labels, indices, counts) — for random
    // fleet sizes, partition schemes, profiles, and resident caps, with
    // clients materialized in random order.
    cases(25, |rng| {
        let (eager, mut lazy, n) = pool_pair(rng);
        assert_eq!(eager.len(), lazy.len());
        assert_eq!(eager.total_samples(), lazy.total_samples());
        for _ in 0..20 {
            let id = rng.below(n);
            let l = lazy.client_mut(id);
            assert_eq!(l.id, id);
            let e = eager.client(id);
            let l = lazy.client(id);
            assert_eq!(e.memory.budget, l.memory.budget, "client {id} budget");
            assert_eq!(e.profile, l.profile, "client {id} profile");
            assert_eq!(e.shard.num_samples(), l.shard.num_samples(), "client {id} bound");
            assert_eq!(e.shard.labels, l.shard.labels, "client {id} labels");
            assert_eq!(e.shard.indices, l.shard.indices, "client {id} indices");
        }
        // Fleet-wide pure aggregates agree without materialization.
        let probe = MemCoeffs {
            fixed_bytes: 400 * 1_000_000,
            per_sample_bytes: 0,
            params_total: 0,
            params_trainable: 0,
        };
        assert_eq!(eager.participation_rate(&probe), lazy.participation_rate(&probe));
        assert_eq!(
            eager.capability_assignment(&[probe]),
            lazy.capability_assignment(&[probe])
        );
    });
}

#[test]
fn prop_lazy_selection_streams_match_eager_across_rounds() {
    // Satellite acceptance: the selection rng stream (positions AND
    // outputs) is identical across storage modes over many rounds, with
    // random in-flight exclusion sets — including the empty set, which
    // must consume the stream exactly like plain select.
    cases(15, |rng| {
        let (mut eager, mut lazy, n) = pool_pair(rng);
        let probe = MemCoeffs {
            fixed_bytes: 350 * 1_000_000,
            per_sample_bytes: 0,
            params_total: 0,
            params_trainable: 0,
        };
        for round in 0..8 {
            let busy: Vec<usize> = if rng.below(3) == 0 {
                Vec::new()
            } else {
                (0..rng.below(n / 2 + 1)).map(|_| rng.below(n)).collect()
            };
            let k = 1 + rng.below(n.min(30));
            let a = eager.select_excluding(k, &probe, &busy);
            let b = lazy.select_excluding(k, &probe, &busy);
            assert_eq!(a.trainers, b.trainers, "round {round} busy={busy:?}");
            assert_eq!(a.fallback, b.fallback, "round {round}");
            assert_eq!(a.availability, b.availability, "round {round}");
            for (id, _) in &a.availability {
                assert!(!busy.contains(id), "busy client {id} sampled");
            }
        }
    });
}

#[test]
fn prop_select_excluding_empty_consumes_identical_stream() {
    // Regression (satellite): select_excluding(∅) must stay draw-for-draw
    // identical to select — interleaving the two spellings across rounds
    // on same-seed pools cannot make them diverge.
    cases(15, |rng| {
        let (mut a, mut b, n) = pool_pair(rng);
        let probe = MemCoeffs {
            fixed_bytes: 300 * 1_000_000,
            per_sample_bytes: 0,
            params_total: 0,
            params_trainable: 0,
        };
        for _ in 0..6 {
            let k = 1 + rng.below(n.min(25));
            let s1 = a.select(k, &probe);
            let s2 = b.select_excluding(k, &probe, &[]);
            assert_eq!(s1.availability, s2.availability);
        }
    });
}

#[test]
fn prop_sparse_sampling_equals_dense_fisher_yates() {
    // sample_indices must reproduce the dense partial Fisher-Yates bit
    // for bit (outputs and draw count) whatever (n, k) — the sparse path
    // is an invisible optimization.
    cases(200, |rng| {
        let n = 1 + rng.below(3_000);
        let k = rng.below(n + 1);
        let mut a = Rng::new(rng.next_u64());
        let mut b = a.clone();
        let sparse = a.sample_indices(n, k);
        let dense = {
            let mut idx: Vec<usize> = (0..n).collect();
            for i in 0..k {
                let j = i + b.below(n - i);
                idx.swap(i, j);
            }
            idx.truncate(k);
            idx
        };
        assert_eq!(sparse, dense, "n={n} k={k}");
        assert_eq!(a.next_u64(), b.next_u64(), "stream positions diverged");
    });
}

#[test]
fn prop_lazy_peak_materialized_bounded_by_cap() {
    // The memory wall: whatever the access pattern, a lazy pool never
    // holds more than its resident cap.
    cases(20, |rng| {
        let (_, mut lazy, n) = pool_pair(rng);
        let cap_probe = MemCoeffs {
            fixed_bytes: 0,
            per_sample_bytes: 0,
            params_total: 0,
            params_trainable: 0,
        };
        for _ in 0..10 {
            let k = 1 + rng.below(n.min(20));
            let _ = lazy.select(k, &cap_probe);
        }
        assert!(lazy.peak_materialized() <= n, "peak can never exceed the fleet");
        assert!(lazy.materialized() <= lazy.peak_materialized());
    });
}

// ---------------------------------------------------------------------------
// Memory-strategy invariants (strategy::, docs/STRATEGIES.md)
// ---------------------------------------------------------------------------

fn rand_counts(rng: &mut Rng) -> Vec<u64> {
    let n = 2 + rng.below(8);
    (0..n).map(|_| 100_000 + rng.below(5_000_000) as u64).collect()
}

#[test]
fn prop_footprint_monotone_in_trainable_prefix() {
    // Deepening the trainable window over a fixed frozen floor never
    // shrinks the analytical footprint, at any accounting batch.
    cases(200, |rng| {
        let counts = rand_counts(rng);
        let frozen = rng.below(counts.len());
        let batch = 1 + rng.below(256) as u64;
        let mut prev = 0u64;
        for depth in frozen + 1..=counts.len() {
            let m = layout_mem(&counts, &BlockLayout { frozen, depth });
            let b = m.bytes_at(batch);
            assert!(b >= prev, "footprint shrank at depth {depth}");
            assert!(m.params_trainable <= m.params_total);
            prev = b;
        }
    });
}

#[test]
fn prop_footprint_never_exceeds_full_model() {
    // No partial layout costs more than training the whole model: the
    // bound the strategy zoo's peak-memory column leans on.
    cases(200, |rng| {
        let counts = rand_counts(rng);
        let batch = 1 + rng.below(256) as u64;
        let full = layout_mem(&counts, &BlockLayout::full(counts.len())).bytes_at(batch);
        let frozen = rng.below(counts.len());
        let depth = frozen + 1 + rng.below(counts.len() - frozen);
        let m = layout_mem(&counts, &BlockLayout { frozen, depth });
        assert!(
            m.bytes_at(batch) <= full,
            "partial layout ({frozen}, {depth}) out-costs the full model"
        );
    });
}

#[test]
fn prop_layerfreeze_depth_caps_respect_fits_static() {
    // The per-client depth cap is sound and maximal: the capped layout
    // always fits the device's static budget, one block deeper never
    // does, and a None cap means even a single block does not fit. Any
    // client the contended can_train filter then admits for the capped
    // layout fits it statically (dispatch respects fits_static).
    cases(100, |rng| {
        let counts = rand_counts(rng);
        let mcfg = MemoryConfig::default();
        let mut pool_rng = Rng::new(rng.next_u64());
        let frozen = rng.below(counts.len());
        for i in 0..40 {
            let mut d = DeviceMemory::sample(&mcfg, &mut pool_rng, i);
            match depth_cap(&counts, frozen, d.budget, mcfg.accounting_batch) {
                Some(layout) => {
                    assert_eq!(layout.frozen, frozen);
                    assert!(layout.depth > frozen && layout.depth <= counts.len());
                    let m = layout_mem(&counts, &layout);
                    assert!(d.fits_static(&mcfg, &m), "capped layout overflows budget");
                    if layout.depth < counts.len() {
                        let deeper =
                            layout_mem(&counts, &BlockLayout { frozen, depth: layout.depth + 1 });
                        assert!(!d.fits_static(&mcfg, &deeper), "cap is not maximal");
                    }
                    let avail = d.available(&mcfg);
                    if can_train(avail, &mcfg, &m) {
                        assert!(d.fits_static(&mcfg, &m), "dispatched client overflows");
                    }
                }
                None => {
                    let min = layout_mem(&counts, &BlockLayout { frozen, depth: frozen + 1 });
                    assert!(!d.fits_static(&mcfg, &min), "a fit exists but the cap is None");
                }
            }
        }
    });
}

#[test]
fn prop_elastic_windows_fit_budgets_and_dispatch_respects_fits_static() {
    // Every planned elastic window fits its own budget-curve point (or
    // is the guaranteed single-block floor), windows tile the depth
    // without gaps, and every device the can_train filter admits for a
    // phase's footprint also fits it statically.
    cases(100, |rng| {
        let counts = rand_counts(rng);
        let mut cfg = RunConfig::smoke("m");
        cfg.memory.budget_min_mb = 50 + rng.below(300) as u64;
        cfg.memory.budget_max_mb = cfg.memory.budget_min_mb + 50 + rng.below(800) as u64;
        cfg.strategy.elastic_phases = Some(1 + rng.below(6));
        let phases = elastic::plan(&counts, &cfg);
        assert!(!phases.is_empty());
        let mut expect_frozen = 0;
        for ph in &phases {
            assert_eq!(ph.layout.frozen, expect_frozen, "windows must tile");
            assert!(ph.layout.depth > ph.layout.frozen);
            assert!(ph.rounds >= 1);
            let m = layout_mem(&counts, &ph.layout);
            let fits = m.bytes_at(cfg.memory.accounting_batch) <= ph.budget_bytes;
            let floor = ph.layout.depth == ph.layout.frozen + 1;
            assert!(fits || floor, "window neither fits its budget nor is the floor");
            expect_frozen = ph.layout.depth;
        }
        assert!(phases.last().unwrap().layout.depth <= counts.len());
        let mcfg: MemoryConfig = cfg.memory.into();
        let mut pool_rng = Rng::new(rng.next_u64());
        for i in 0..30 {
            let mut d = DeviceMemory::sample(&mcfg, &mut pool_rng, i);
            let avail = d.available(&mcfg);
            for ph in &phases {
                let m = layout_mem(&counts, &ph.layout);
                if can_train(avail, &mcfg, &m) {
                    assert!(d.fits_static(&mcfg, &m), "dispatched client overflows");
                }
            }
        }
    });
}
